#!/usr/bin/env python3
"""Toolchain-free mirror of `cargo xtask lint`.

CI runs the real linter (rust/xtask, syn-driven). This script mirrors
its seven rules with regexes so the lint gate can also run where no
Rust toolchain is installed (pre-commit hooks, docs-only containers).
Rule semantics are kept in lockstep with rust/xtask/src/main.rs — if
you change one, change the other:

  unwrap/expect     no .unwrap()/.expect() outside tests without a
                    `// lint: allow(unwrap|expect, reason)` marker
  safety            every `unsafe {` block preceded by `// SAFETY:`
  metric            every bitdelta_* token in Rust string literals and
                    docs is an exact member or proper prefix of
                    coordinator::metric_names::EXPORTED_SERIES
  exec-kind         string literals that are decode_* words must be in
                    delta::codec::KNOWN_EXEC_KINDS
  codec-registered  every src/delta/codecs/*.rs module is wired into
                    CodecRegistry::builtin()
  std-sync          the loom-migrated concurrency core imports sync
                    primitives from crate::sync, not std::sync/thread
  raw-time          clock-migrated files (cluster, admission, the sim
                    harness and its tests) never read std::time::Instant
                    or call raw thread::sleep — time goes through
                    crate::sync::clock. Unlike std-sync this rule scans
                    test code too: a raw sleep in a virtual-clock test
                    is exactly the flake the rule exists to prevent

Exit 0 and print `lint: clean` when green; exit 1 with
`path:line: [rule] message` diagnostics otherwise.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
RUST = ROOT / "rust"

SYNC_MIGRATED = {
    "src/cluster/worker.rs",
    "src/cluster/frontend.rs",
    "src/cluster/autoscaler.rs",
    "src/coordinator/admission.rs",
    "src/gemm/dispatch.rs",
    "src/kvcache/pool.rs",
}

# Files migrated onto the crate::sync::clock virtual-clock seam. Kept in
# lockstep with TIME_MIGRATED in rust/xtask/src/main.rs. src/sync.rs is
# deliberately absent (it *implements* the seam) and so is src/main.rs
# (the CLI measures real wall time by design).
TIME_MIGRATED = [
    "src/cluster/autoscaler.rs",
    "src/cluster/frontend.rs",
    "src/cluster/metrics.rs",
    "src/cluster/placement.rs",
    "src/cluster/testutil.rs",
    "src/cluster/worker.rs",
    "src/coordinator/admission.rs",
    "src/simharness/harness.rs",
    "src/simharness/mod.rs",
    "src/simharness/monitor.rs",
    "src/simharness/schedule.rs",
    "src/simharness/tenants.rs",
    "tests/service_concurrency.rs",
    "tests/sim_cluster.rs",
]

DOC_FILES = ["README.md", "ROADMAP.md"]  # CHANGES.md is a log: skipped

STRING_RE = re.compile(r'"((?:[^"\\]|\\.)*)"')
CALL_RE = re.compile(r"\.\s*(unwrap|expect)\s*\(")
METRIC_RE = re.compile(r"(?<![A-Za-z0-9_])(bitdelta_[a-z0-9_]*[a-z0-9])")
EXEC_RE = re.compile(r"decode_[a-z0-9_]+\Z")


def parse_string_table(src: str, name: str) -> list[str]:
    start = src.find(f"const {name}")
    if start < 0:
        return []
    end = src.find("];", start)
    return re.findall(r'"([^"]+)"', src[start:end])


def registered(registry: list[str], tok: str) -> bool:
    return any(s == tok or (len(s) > len(tok) and s.startswith(tok))
               for s in registry)


def test_region_mask(lines: list[str]) -> list[bool]:
    """True for lines inside `#[cfg(test)] mod`/`fn` regions."""
    mask = [False] * len(lines)
    depth = 0
    region_depth: int | None = None
    pending = False
    for i, line in enumerate(lines):
        t = line.lstrip()
        if t.startswith("#[cfg(test)"):
            pending = True
        elif pending and (t.startswith("mod ") or t.startswith("fn ")
                          or t.startswith("pub fn ")
                          or t.startswith("pub(crate) fn ")):
            if region_depth is None:
                region_depth = depth
            pending = False
        elif pending and not t.startswith("#["):
            pending = False
        depth += line.count("{") - line.count("}")
        if region_depth is not None:
            mask[i] = True
            if depth <= region_depth:
                region_depth = None
    return mask


def window_allows(lines: list[str], i: int, rule: str) -> bool:
    """Marker on the site line or any of the 4 lines above (i 0-based)."""
    return any("lint: allow(" in w and rule in w
               for w in lines[max(0, i - 4):i + 1])


def strip_line_comment(line: str) -> str:
    return line.split("//", 1)[0]


def lint_rust_file(path: Path, registry: list[str],
                   exec_kinds: list[str], findings: list[str]) -> None:
    rel = path.relative_to(RUST).as_posix()
    lines = path.read_text().splitlines()
    in_tests = test_region_mask(lines)

    for i, line in enumerate(lines):
        code = strip_line_comment(line)

        # unwrap / expect -------------------------------------------------
        if not in_tests[i]:
            for m in CALL_RE.finditer(code):
                rule = m.group(1)
                if not window_allows(lines, i, rule):
                    findings.append(
                        f"{rel}:{i + 1}: [{rule}] .{rule}() without "
                        f"`// lint: allow({rule}, reason)` — return a "
                        f"typed error or justify the invariant")

        # safety ----------------------------------------------------------
        if re.search(r"\bunsafe\s*\{", code) and "unsafe fn" not in code:
            if "SAFETY:" not in line:
                j = i - 1
                ok = False
                while j >= 0:
                    t = lines[j].lstrip()
                    if t.startswith("//"):
                        if "SAFETY:" in t:
                            ok = True
                            break
                        j -= 1
                    elif t.startswith("#[") or not t:
                        j -= 1
                    else:
                        break
                if not ok:
                    findings.append(
                        f"{rel}:{i + 1}: [safety] unsafe block without "
                        f"a preceding // SAFETY: comment")

        # metric + exec-kind (string literals only) -----------------------
        for sm in STRING_RE.finditer(code):
            text = sm.group(1)
            if EXEC_RE.fullmatch(text) and text not in exec_kinds \
                    and not window_allows(lines, i, "exec-kind"):
                findings.append(
                    f"{rel}:{i + 1}: [exec-kind] \"{text}\" is not in "
                    f"delta::codec::KNOWN_EXEC_KINDS")
            for tok in METRIC_RE.findall(text):
                tok = tok.rstrip("_")
                if not registered(registry, tok) \
                        and not window_allows(lines, i, "metric"):
                    findings.append(
                        f"{rel}:{i + 1}: [metric] \"{tok}\" is not in "
                        f"metric_names::EXPORTED_SERIES "
                        f"(exact or prefix)")

        # std-sync --------------------------------------------------------
        if rel in SYNC_MIGRATED and not in_tests[i]:
            if ("std::sync::" in code or "std::thread::" in code) \
                    and not window_allows(lines, i, "std-sync"):
                findings.append(
                    f"{rel}:{i + 1}: [std-sync] direct std primitive "
                    f"in a loom-migrated module — import from "
                    f"crate::sync")


def lint_raw_time(findings: list[str]) -> None:
    """Wall-clock sources in clock-migrated files (tests included)."""
    for rel in TIME_MIGRATED:
        path = RUST / rel
        if not path.exists():
            findings.append(
                f"{rel}:1: [raw-time] listed in TIME_MIGRATED but "
                f"missing or unreadable")
            continue
        lines = path.read_text().splitlines()
        for i, line in enumerate(lines):
            code = strip_line_comment(line)
            if ("std::time::Instant" in code
                    or "thread::sleep(" in code) \
                    and not window_allows(lines, i, "raw-time"):
                findings.append(
                    f"{rel}:{i + 1}: [raw-time] wall-clock time source "
                    f"in a clock-migrated file — use crate::sync::clock "
                    f"(Instant / sleep) so virtual-clock runs stay "
                    f"deterministic, or justify the one real wait with "
                    f"`// lint: allow(raw-time, reason)`")


def lint_codec_registration(findings: list[str]) -> None:
    codec_rs = (RUST / "src/delta/codec.rs").read_text()
    for p in sorted((RUST / "src/delta/codecs").glob("*.rs")):
        module = p.stem
        if module == "mod":
            continue
        if f"codecs::{module}::" not in codec_rs:
            findings.append(
                f"src/delta/codecs/{p.name}:1: [codec-registered] "
                f"module {module} is not registered in "
                f"CodecRegistry::builtin()")


def lint_doc(path: Path, registry: list[str],
             findings: list[str]) -> None:
    if not path.exists():
        return
    for i, line in enumerate(path.read_text().splitlines()):
        for tok in METRIC_RE.findall(line):
            tok = tok.rstrip("_")
            if not registered(registry, tok):
                findings.append(
                    f"{path.name}:{i + 1}: [metric] \"{tok}\" is not "
                    f"in metric_names::EXPORTED_SERIES "
                    f"(exact or prefix)")


def main() -> int:
    registry = parse_string_table(
        (RUST / "src/coordinator/metric_names.rs").read_text(),
        "EXPORTED_SERIES")
    exec_kinds = parse_string_table(
        (RUST / "src/delta/codec.rs").read_text(), "KNOWN_EXEC_KINDS")
    if not registry or not exec_kinds:
        print("lint: failed to parse the metric/exec registries")
        return 1

    findings: list[str] = []
    for path in sorted((RUST / "src").rglob("*.rs")):
        lint_rust_file(path, registry, exec_kinds, findings)
    lint_raw_time(findings)
    lint_codec_registration(findings)
    for doc in DOC_FILES:
        lint_doc(ROOT / doc, registry, findings)
    for doc in sorted((ROOT / "docs").glob("*.md")):
        lint_doc(doc, registry, findings)

    if not findings:
        print("lint: clean")
        return 0
    for f in sorted(findings):
        print(f)
    print(f"lint: {len(findings)} finding(s)")
    return 1


if __name__ == "__main__":
    sys.exit(main())
