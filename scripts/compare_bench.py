#!/usr/bin/env python3
"""Perf-trajectory gate: diff BENCH_<name>.json snapshots against the
committed baselines under perf/.

Usage:
    compare_bench.py [--baseline-dir perf] [--tolerance 0.25]
                     [--update] BENCH_fig4.json [more snapshots...]

Snapshot schema (written by rust/src/util/bench.rs::write_snapshot):
one JSON object per file with an envelope (bench, schema, git_rev,
smoke, threads, dispatch) and a "rows" array. Each row mixes identity
fields (strings, bools, and numbers with no known metric suffix) with
metric fields; a row in the current snapshot is matched to the
baseline row with the same identity, then each shared metric is
compared directionally:

  lower is better:  keys ending in _us / _ms / p50 / p99 / errors
  higher is better: keys ending in gbps / tok_per_s / speedup / served

A metric regresses when it is worse than baseline by more than
--tolerance (relative). Rows or metrics missing on either side are
reported and skipped, never failed: machines differ (dispatch tier,
thread count are identity fields, so an avx2 baseline simply does not
gate a neon runner).

Baselines with "provisional": true in the envelope report but never
fail — they mark hand-written placeholders committed before a real
runner blessed them with --update.

Exit codes: 0 ok / 1 regression / 2 bad input.
"""

import argparse
import json
import os
import sys

LOWER_SUFFIXES = ("_us", "_ms", "p50", "p99", "errors")
HIGHER_SUFFIXES = ("gbps", "tok_per_s", "speedup", "served")


def metric_direction(key):
    """-1 = lower is better, +1 = higher is better, None = identity."""
    for s in LOWER_SUFFIXES:
        if key.endswith(s):
            return -1
    for s in HIGHER_SUFFIXES:
        if key.endswith(s):
            return +1
    return None


def split_row(row):
    """(identity dict, metrics dict) for one snapshot row."""
    ident, metrics = {}, {}
    for k, v in row.items():
        if isinstance(v, (int, float)) and not isinstance(v, bool) \
                and metric_direction(k) is not None:
            metrics[k] = float(v)
        else:
            ident[k] = v
    return ident, metrics


def row_key(ident):
    return json.dumps(ident, sort_keys=True)


def load_snapshot(path):
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "rows" not in doc:
        raise ValueError(f"{path}: not a bench snapshot (no rows)")
    return doc


def compare(current, baseline, tolerance, label):
    """Return (regressions, notes) comparing two snapshot docs."""
    base_rows = {}
    for row in baseline.get("rows", []):
        ident, metrics = split_row(row)
        base_rows[row_key(ident)] = metrics
    regressions, notes = [], []
    for row in current.get("rows", []):
        ident, metrics = split_row(row)
        key = row_key(ident)
        base = base_rows.get(key)
        if base is None:
            notes.append(f"{label}: no baseline row for {key} — skipped")
            continue
        for k, cur in sorted(metrics.items()):
            if k not in base:
                notes.append(
                    f"{label}: {key}: metric {k} not in baseline — "
                    "skipped")
                continue
            want = base[k]
            direction = metric_direction(k)
            if want == 0:
                continue
            if direction < 0:
                ratio = cur / want          # >1 means slower
            else:
                ratio = want / cur          # >1 means less throughput
            if ratio > 1.0 + tolerance:
                regressions.append(
                    f"{label}: {key}: {k} regressed "
                    f"{cur:g} vs baseline {want:g} "
                    f"({(ratio - 1.0) * 100:.0f}% worse, "
                    f"tolerance {tolerance * 100:.0f}%)")
    return regressions, notes


def main():
    ap = argparse.ArgumentParser(
        description="diff bench snapshots against committed baselines")
    ap.add_argument("snapshots", nargs="+",
                    help="BENCH_<name>.json files from a bench run")
    ap.add_argument("--baseline-dir", default="perf",
                    help="directory holding committed baselines")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="relative slack before a metric fails (0.25 "
                         "= 25%% worse than baseline)")
    ap.add_argument("--update", action="store_true",
                    help="bless: copy the snapshots over the baselines "
                         "instead of comparing")
    args = ap.parse_args()

    failed = False
    regressed = False
    for path in args.snapshots:
        name = os.path.basename(path)
        base_path = os.path.join(args.baseline_dir, name)
        try:
            current = load_snapshot(path)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"error: {e}", file=sys.stderr)
            failed = True
            continue
        if args.update:
            os.makedirs(args.baseline_dir, exist_ok=True)
            current.pop("provisional", None)
            with open(base_path, "w", encoding="utf-8") as f:
                json.dump(current, f, indent=1, sort_keys=True)
                f.write("\n")
            print(f"blessed {base_path} "
                  f"(git_rev {current.get('git_rev', '?')})")
            continue
        if not os.path.exists(base_path):
            print(f"{name}: no baseline at {base_path} — skipped "
                  "(run with --update to create one)")
            continue
        try:
            baseline = load_snapshot(base_path)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"error: {e}", file=sys.stderr)
            failed = True
            continue
        regressions, notes = compare(current, baseline,
                                     args.tolerance, name)
        for n in notes:
            print(n)
        provisional = bool(baseline.get("provisional"))
        for r in regressions:
            tag = "would regress (provisional baseline)" if provisional \
                else "REGRESSION"
            print(f"{tag}: {r}")
        if regressions and not provisional:
            regressed = True
        if not regressions:
            n = len(current.get("rows", []))
            print(f"{name}: ok ({n} rows within tolerance)")

    if failed:
        return 2
    if regressed:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
