//! Figure 4 — kernel decode latency of the three linear paths:
//! shared dense backbone (`W_base·x`), batched 1-bit deltas (BitDelta),
//! batched rank-r adapters (S-LoRA).
//!
//! Left panel:  ablate hidden size N = M at B = 1.
//! Right panel: ablate batch size at N = M = 2048 (the paper uses 4096;
//!              we shrink one notch to keep single-core runtime sane —
//!              the byte ratios that set the curve shapes are
//!              size-independent).
//!
//! Expected shape (paper §4.3): backbone ~flat in B (streamed once);
//! BitDelta/S-LoRA delta terms scale with B but are ~16-32x cheaper per
//! tenant; the naive per-tenant dense path scales with B at full weight
//! cost.

use bitdelta::gemm::{batched_binary_gemv, batched_dense_gemv,
                     batched_lora_gemv, dense_gemv};
use bitdelta::gemm::dense::per_tenant_dense_gemv;
use bitdelta::tensor::Tensor;
use bitdelta::util::bench::{black_box, Bench};

fn main() {
    println!("=== Figure 4 (left): latency vs hidden size, B=1 ===");
    let mut bench = Bench::new(3, 15);
    for n in [512usize, 1024, 2048, 4096] {
        let m = n;
        let w = Tensor::randn(vec![n, m], 1);
        let bits = vec![0xA5u8; n * m / 8];
        let a = Tensor::randn(vec![128, m], 2);        // r = 128
        let bu = Tensor::randn(vec![n, 128], 3);
        let x = Tensor::randn(vec![m], 4);
        let mut y = vec![0f32; n];

        bench.run(format!("backbone/dense n={n}"), || {
            dense_gemv(w.data(), n, m, x.data(), &mut y);
            black_box(&y);
        });
        bench.run(format!("delta/bitdelta n={n}"), || {
            batched_binary_gemv(&bits, n, m, x.data(), &[0.01], 1,
                                &mut y);
            black_box(&y);
        });
        // §Perf ablation: the pre-optimization bit-extract kernel
        bench.run(format!("delta/bitdelta-bitextract n={n}"), || {
            bitdelta::gemm::binary::binary_gemv_bitextract(
                &bits, n, m, x.data(), 0.01, &mut y);
            black_box(&y);
        });
        bench.run(format!("delta/slora-r128 n={n}"), || {
            batched_lora_gemv(a.data(), bu.data(), 128, n, m, x.data(),
                              1, &mut y);
            black_box(&y);
        });
    }

    println!("\n=== Figure 4 (right): latency vs batch, N=M=2048 ===");
    let n = 2048usize;
    let m = n;
    let w = Tensor::randn(vec![n, m], 5);
    let mut bench2 = Bench::new(2, 10);
    for b in [1usize, 2, 4, 8, 16, 32] {
        let bits = vec![0x5Au8; b * n * m / 8];
        let alphas = vec![0.01f32; b];
        let a = Tensor::randn(vec![b, 128, m], 6);
        let bu = Tensor::randn(vec![b, n, 128], 7);
        let xs = Tensor::randn(vec![b, m], 8);
        let ws = Tensor::randn(vec![b, n, m], 9);
        let mut ys = vec![0f32; b * n];

        bench2.run(format!("backbone b={b}"), || {
            batched_dense_gemv(w.data(), n, m, xs.data(), b, &mut ys);
            black_box(&ys);
        });
        bench2.run(format!("bitdelta-deltas b={b}"), || {
            batched_binary_gemv(&bits, n, m, xs.data(), &alphas, b,
                                &mut ys);
            black_box(&ys);
        });
        bench2.run(format!("slora-deltas b={b}"), || {
            batched_lora_gemv(a.data(), bu.data(), 128, n, m, xs.data(),
                              b, &mut ys);
            black_box(&ys);
        });
        bench2.run(format!("naive-per-tenant b={b}"), || {
            per_tenant_dense_gemv(ws.data(), n, m, xs.data(), b, &mut ys);
            black_box(&ys);
        });
    }

    // machine-readable series for the figure
    println!("\n--- CSV ---");
    println!("{}", bench.csv("series,us"));
    println!("{}", bench2.csv("series,us"));
}
