//! Figure 4 — kernel decode latency of the three linear paths:
//! shared dense backbone (`W_base·x`), batched 1-bit deltas (BitDelta),
//! batched rank-r adapters (S-LoRA).
//!
//! Left panel:  ablate hidden size N = M at B = 1.
//! Right panel: ablate batch size at N = M = 2048 (the paper uses 4096;
//!              we shrink one notch to keep single-core runtime sane —
//!              the byte ratios that set the curve shapes are
//!              size-independent).
//! Engine panel: the packed-GEMV kernel engine swept over dispatch
//!              tier (scalar Four-Russians vs the detected SIMD tier)
//!              × worker-pool width {1, 2, 4} — the perf-trajectory
//!              panel behind the `simd_vs_scalar_1t_speedup` and
//!              `scaling_{2,4}t_speedup` summary metrics.
//!
//! Expected shape (paper §4.3): backbone ~flat in B (streamed once);
//! BitDelta/S-LoRA delta terms scale with B but are ~16-32x cheaper per
//! tenant; the naive per-tenant dense path scales with B at full weight
//! cost.
//!
//! Every measurement is also emitted as a JSON row (after
//! `--- JSON ---`) and the whole run is archived to `BENCH_fig4.json`
//! via [`bitdelta::util::bench::write_snapshot`] for the CI perf gate.
//!
//! Flags: `--smoke` (or env `FIG4_SMOKE=1`) = tiny sizes, 2
//! iterations — a trend sample for CI, not a measurement.

use std::collections::BTreeMap;

use bitdelta::gemm::dispatch::{self, Tier};
use bitdelta::gemm::{batched_binary_gemv, batched_dense_gemv,
                     batched_lora_gemv, dense_gemv, try_binary_gemv};
use bitdelta::gemm::dense::per_tenant_dense_gemv;
use bitdelta::tensor::Tensor;
use bitdelta::util::bench::{black_box, write_snapshot, Bench,
                            Measurement};
use bitdelta::util::json::Json;

fn round2(v: f64) -> f64 {
    (v * 100.0).round() / 100.0
}

/// One measurement as a snapshot row, stamped with the kernel config
/// that was active while it ran.
fn row(m: &Measurement, smoke: bool) -> Json {
    let us = |d: std::time::Duration| round2(d.as_secs_f64() * 1e6);
    let mut o = BTreeMap::new();
    o.insert("series".to_string(), Json::Str(m.name.clone()));
    o.insert("mean_us".to_string(), Json::Num(us(m.mean())));
    o.insert("p50_us".to_string(), Json::Num(us(m.quantile(0.5))));
    o.insert("p99_us".to_string(), Json::Num(us(m.quantile(0.99))));
    o.insert("threads".to_string(),
             Json::Num(dispatch::pool_threads() as f64));
    o.insert("dispatch".to_string(),
             Json::Str(dispatch::active_tier().name().to_string()));
    o.insert("smoke".to_string(), Json::Bool(smoke));
    Json::Obj(o)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("FIG4_SMOKE").is_ok();
    let sizes: &[usize] = if smoke { &[512] } else { &[512, 1024, 2048, 4096] };
    let batches: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8, 16, 32] };
    let nb = if smoke { 512usize } else { 2048 };
    let (warmup, iters) = if smoke { (0, 2) } else { (3, 15) };
    let mut rows: Vec<Json> = Vec::new();

    println!("=== Figure 4 (left): latency vs hidden size, B=1{} ===",
             if smoke { " (smoke)" } else { "" });
    let mut bench = Bench::new(warmup, iters);
    for &n in sizes {
        let m = n;
        let w = Tensor::randn(vec![n, m], 1);
        let bits = vec![0xA5u8; n * m / 8];
        let a = Tensor::randn(vec![128, m], 2);        // r = 128
        let bu = Tensor::randn(vec![n, 128], 3);
        let x = Tensor::randn(vec![m], 4);
        let mut y = vec![0f32; n];

        let mm = bench.run(format!("backbone/dense n={n}"), || {
            dense_gemv(w.data(), n, m, x.data(), &mut y);
            black_box(&y);
        }).clone();
        rows.push(row(&mm, smoke));
        let mm = bench.run(format!("delta/bitdelta n={n}"), || {
            batched_binary_gemv(&bits, n, m, x.data(), &[0.01], 1,
                                &mut y);
            black_box(&y);
        }).clone();
        rows.push(row(&mm, smoke));
        // §Perf ablation: the pre-optimization bit-extract kernel
        let mm = bench.run(format!("delta/bitdelta-bitextract n={n}"),
                           || {
            bitdelta::gemm::binary::binary_gemv_bitextract(
                &bits, n, m, x.data(), 0.01, &mut y);
            black_box(&y);
        }).clone();
        rows.push(row(&mm, smoke));
        let mm = bench.run(format!("delta/slora-r128 n={n}"), || {
            batched_lora_gemv(a.data(), bu.data(), 128, n, m, x.data(),
                              1, &mut y);
            black_box(&y);
        }).clone();
        rows.push(row(&mm, smoke));
    }

    println!("\n=== Figure 4 (right): latency vs batch, N=M={nb} ===");
    let n = nb;
    let m = n;
    let w = Tensor::randn(vec![n, m], 5);
    let mut bench2 = Bench::new(warmup.min(2), iters.min(10));
    for &b in batches {
        let bits = vec![0x5Au8; b * n * m / 8];
        let alphas = vec![0.01f32; b];
        let a = Tensor::randn(vec![b, 128, m], 6);
        let bu = Tensor::randn(vec![b, n, 128], 7);
        let xs = Tensor::randn(vec![b, m], 8);
        let ws = Tensor::randn(vec![b, n, m], 9);
        let mut ys = vec![0f32; b * n];

        let mm = bench2.run(format!("backbone b={b}"), || {
            batched_dense_gemv(w.data(), n, m, xs.data(), b, &mut ys);
            black_box(&ys);
        }).clone();
        rows.push(row(&mm, smoke));
        let mm = bench2.run(format!("bitdelta-deltas b={b}"), || {
            batched_binary_gemv(&bits, n, m, xs.data(), &alphas, b,
                                &mut ys);
            black_box(&ys);
        }).clone();
        rows.push(row(&mm, smoke));
        let mm = bench2.run(format!("slora-deltas b={b}"), || {
            batched_lora_gemv(a.data(), bu.data(), 128, n, m, xs.data(),
                              b, &mut ys);
            black_box(&ys);
        }).clone();
        rows.push(row(&mm, smoke));
        let mm = bench2.run(format!("naive-per-tenant b={b}"), || {
            per_tenant_dense_gemv(ws.data(), n, m, xs.data(), b,
                                  &mut ys);
            black_box(&ys);
        }).clone();
        rows.push(row(&mm, smoke));
    }

    // ----------------------------------------------------------------
    // Kernel engine: dispatch tier × worker-pool width, N=M fixed.
    // Scalar @ 1 thread is the pre-engine baseline; the detected SIMD
    // tier at 1/2/4 threads is the trajectory CI tracks.
    // ----------------------------------------------------------------
    println!("\n=== kernel engine: tier x threads, N=M={nb} ===");
    let bits = vec![0xC3u8; nb * nb / 8];
    let x = Tensor::randn(vec![nb], 10);
    let mut y = vec![0f32; nb];
    let prev_forced = dispatch::forced_tier();
    let prev_threads = dispatch::pool_threads();
    let det = dispatch::detected_tier();
    let tiers: Vec<Tier> = if det == Tier::Scalar {
        vec![Tier::Scalar]
    } else {
        vec![Tier::Scalar, det]
    };
    let mut bench3 = Bench::new(warmup, iters);
    let mut engine_us: BTreeMap<(&'static str, usize), f64> =
        BTreeMap::new();
    for &tier in &tiers {
        dispatch::force_tier(Some(tier));
        for threads in [1usize, 2, 4] {
            dispatch::set_pool_threads(threads);
            let mm = bench3.run(
                format!("engine/{} t={threads}", tier.name()), || {
                    try_binary_gemv(&bits, nb, nb, x.data(), 0.01,
                                    &mut y).unwrap();
                    black_box(&y);
                }).clone();
            engine_us.insert((tier.name(), threads),
                             mm.mean().as_secs_f64() * 1e6);
            rows.push(row(&mm, smoke));
        }
    }
    dispatch::force_tier(prev_forced);
    dispatch::set_pool_threads(prev_threads);

    // Summary metrics the CI baseline gate watches.
    let at = |t: &'static str, th: usize| {
        engine_us.get(&(t, th)).copied()
    };
    let fast = tiers.last().map_or("scalar", |t| t.name());
    if let (Some(s1), Some(f1), Some(f2), Some(f4)) =
        (at("scalar", 1), at(fast, 1), at(fast, 2), at(fast, 4))
    {
        println!("\n{fast} vs scalar @1 thread: {:.2}x; {fast} \
thread scaling 1->2: {:.2}x, 1->4: {:.2}x",
                 s1 / f1, f1 / f2, f1 / f4);
        let mut o = BTreeMap::new();
        o.insert("series".to_string(),
                 Json::Str("engine/summary".to_string()));
        o.insert("fast_tier".to_string(), Json::Str(fast.to_string()));
        o.insert("simd_vs_scalar_1t_speedup".to_string(),
                 Json::Num(round2(s1 / f1)));
        o.insert("scaling_2t_speedup".to_string(),
                 Json::Num(round2(f1 / f2)));
        o.insert("scaling_4t_speedup".to_string(),
                 Json::Num(round2(f1 / f4)));
        o.insert("smoke".to_string(), Json::Bool(smoke));
        rows.push(Json::Obj(o));
    }

    // machine-readable series for the figure
    println!("\n--- CSV ---");
    println!("{}", bench.csv("series,us"));
    println!("{}", bench2.csv("series,us"));
    println!("{}", bench3.csv("series,us"));

    println!("--- JSON ---");
    for r in &rows {
        println!("{r}");
    }
    match write_snapshot("fig4", smoke, rows) {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("\nsnapshot write failed: {e}"),
    }
}
