//! KV paging — resident KV bytes and incremental-restack time vs
//! sequence count × shared-prefix fraction.
//!
//! The claim under test (ROADMAP open item 2): the paged KV cache
//! makes resident KV bytes **sublinear in sequence count** when
//! sequences share a system prompt — the shared prefix is resident
//! once, not once per sequence — while the dense-slab design pays
//! `seqs * max_seq_len` regardless of actual lengths. The bench
//! drives `bitdelta::kvcache` directly (no artifacts needed): one
//! shared weight signature across four distinct tenant labels (the
//! BitDelta cross-tenant case — all deltas ride one base, so
//! identically-served prompts have bit-identical KV), a registered
//! system-prompt prefix, and per-sequence divergent tails.
//!
//! Measured per (seqs × shared%) combo:
//! * `resident_kib` vs the slab comparator `slab_kib` (exact,
//!   deterministic — identity fields in the snapshot rows)
//! * `fill_us` — one admission end-to-end: prefix lookup, shared-block
//!   reuse, tail appends, release
//! * `restack_us` — incremental restack: gather ONE slot into the
//!   dense staging pair (the engine never rebuilds the whole batch)
//! * `mem_speedup` — slab / paged resident bytes (higher is better)
//!
//! Emits a human table plus one JSON object per row and archives
//! `BENCH_kv_paging.json` (shared snapshot schema) for the
//! `scripts/compare_bench.py` baseline gate.
//!
//! Flags: `--smoke` (or env `KV_PAGING_SMOKE=1`) = 8/32 sequences at
//! mean length 64 — a trend sample for CI, not a measurement.

use std::collections::BTreeMap;

use anyhow::Result;
use bitdelta::kvcache::{share_sig, BlockDims, BlockPool, BlockTable,
                        PrefixIndex};
use bitdelta::util::bench::{black_box, write_snapshot, Bench};
use bitdelta::util::json::Json;

/// Distinct tenant labels sharing one weight signature — prefix hits
/// recorded below cross these tenant boundaries.
const TENANTS: [&str; 4] = ["tenant-chat", "tenant-math",
                            "tenant-rlhf", "tenant-code"];
const BLOCK_SIZE: usize = 16;

fn dims() -> BlockDims {
    BlockDims { n_layers: 2, n_heads: 4, block_size: BLOCK_SIZE,
                head_dim: 32 }
}

struct Row {
    seqs: usize,
    shared_pct: usize,
    mean_len: usize,
    prefix_hits: u64,
    resident_kib: usize,
    slab_kib: usize,
    fill_us: f64,
    restack_us: f64,
    mem_speedup: f64,
    smoke: bool,
}

fn run_combo(seqs: usize, shared_pct: usize, mean_len: usize,
             smoke: bool) -> Row {
    let d = dims();
    let rf = d.row_floats();
    // block-aligned shared prompt; block-aligned private tail
    let shared_len =
        (mean_len * shared_pct / 100) / BLOCK_SIZE * BLOCK_SIZE;
    let shared_blocks = shared_len / BLOCK_SIZE;
    let private_blocks = (mean_len - shared_len).div_ceil(BLOCK_SIZE);
    let n_blocks = shared_blocks + seqs * private_blocks
        + mean_len.div_ceil(BLOCK_SIZE) + 8;
    let mut pool = BlockPool::new(d, n_blocks);
    let mut index = PrefixIndex::new();

    // every tenant label maps to the same served weights: same codec,
    // same tier, same artifact — the only regime where cross-tenant
    // KV sharing is sound
    let sig = share_sig(&["bitdelta", "1", "base", "distilled"]);
    let shared_toks: Vec<i32> = (0..shared_len as i32).collect();
    let k_row = vec![0.37f32; rf];
    let v_row = vec![-0.37f32; rf];

    // prompt cache warm-up: one prefill owns the system prompt, the
    // index keeps the blocks alive past the sequence
    if shared_len > 0 {
        let mut owner = BlockTable::new();
        for _ in 0..shared_len {
            owner.append_row(&mut pool, &k_row, &v_row).unwrap();
        }
        index.register(&mut pool, sig, 1.0, &shared_toks,
                       owner.blocks());
        owner.free(&mut pool);
    }

    // admit `seqs` sequences round-robin across the tenant labels:
    // shared prefix reused from the index, divergent tail appended
    let admit = |pool: &mut BlockPool, index: &mut PrefixIndex,
                 seq_id: usize| -> BlockTable {
        let _tenant = TENANTS[seq_id % TENANTS.len()];
        let mut t = if shared_len > 0 {
            let (blocks, len) = index
                .lookup(sig, 1.0, &shared_toks, BLOCK_SIZE)
                .expect("registered prefix must hit");
            assert_eq!(len, shared_len);
            BlockTable::with_shared_prefix(pool, &blocks)
        } else {
            BlockTable::new()
        };
        for _ in t.len()..mean_len {
            t.append_row(pool, &k_row, &v_row).unwrap();
        }
        t
    };
    let mut tables: Vec<BlockTable> = (0..seqs)
        .map(|i| admit(&mut pool, &mut index, i)).collect();

    // deterministic accounting, recorded before the timed phase so
    // timing iterations cannot perturb the counters
    let prefix_hits = index.hits;
    let resident_kib = pool.resident_bytes() / 1024;
    let max_seq = 2 * mean_len; // the slab design preallocates this
    let slab_kib = seqs * max_seq * rf * 4 * 2 / 1024;
    let mem_speedup = slab_kib as f64 / resident_kib as f64;

    let mut b = if smoke { Bench::new(1, 5) } else { Bench::new(3, 20) };
    let label = format!("fill seqs={seqs} shared={shared_pct}%");
    let fill = b.run(label, || {
        let mut t = admit(&mut pool, &mut index, 0);
        t.free(&mut pool);
        black_box(t.len());
    });
    let fill_us = fill.mean().as_secs_f64() * 1e6;

    let (batch, slot) = (4usize, 1usize);
    let total = d.n_layers * batch * d.n_heads * max_seq * d.head_dim;
    let mut k_dst = vec![0f32; total];
    let mut v_dst = vec![0f32; total];
    let label = format!("restack seqs={seqs} shared={shared_pct}%");
    let restack = b.run(label, || {
        tables[0].gather_into(&pool, slot, batch, max_seq, &mut k_dst,
                              &mut v_dst);
        black_box(k_dst[0]);
    });
    let restack_us = restack.mean().as_secs_f64() * 1e6;

    for t in &mut tables {
        t.free(&mut pool);
    }
    index.clear(&mut pool);
    assert_eq!(pool.used_blocks(), 0, "bench leaked blocks");

    Row { seqs, shared_pct, mean_len, prefix_hits, resident_kib,
          slab_kib, fill_us, restack_us, mem_speedup, smoke }
}

fn json_row(r: &Row) -> Json {
    let round1 = |x: f64| (x * 10.0).round() / 10.0;
    let round2 = |x: f64| (x * 100.0).round() / 100.0;
    let mut o = BTreeMap::new();
    o.insert("bench".to_string(), Json::Str("kv_paging".to_string()));
    o.insert("seqs".to_string(), Json::Num(r.seqs as f64));
    o.insert("shared_pct".to_string(), Json::Num(r.shared_pct as f64));
    o.insert("mean_len".to_string(), Json::Num(r.mean_len as f64));
    o.insert("block_size".to_string(), Json::Num(BLOCK_SIZE as f64));
    o.insert("tenants".to_string(),
             Json::Num(TENANTS.len() as f64));
    o.insert("prefix_hits".to_string(),
             Json::Num(r.prefix_hits as f64));
    o.insert("resident_kib".to_string(),
             Json::Num(r.resident_kib as f64));
    o.insert("slab_kib".to_string(), Json::Num(r.slab_kib as f64));
    o.insert("fill_us".to_string(), Json::Num(round1(r.fill_us)));
    o.insert("restack_us".to_string(),
             Json::Num(round1(r.restack_us)));
    o.insert("mem_speedup".to_string(),
             Json::Num(round2(r.mem_speedup)));
    o.insert("smoke".to_string(), Json::Bool(r.smoke));
    Json::Obj(o)
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("KV_PAGING_SMOKE").is_ok();
    let mean_len = if smoke { 64 } else { 256 };
    let seq_counts: &[usize] =
        if smoke { &[8, 32] } else { &[8, 32, 128] };

    println!("kv_paging — mean len {mean_len} of {} slab, block {}, \
{} tenant labels on one weight sig",
             2 * mean_len, BLOCK_SIZE, TENANTS.len());
    println!("{:<6} {:<8} {:>13} {:>10} {:>8} {:>9} {:>11} {:>6}",
             "seqs", "shared", "resident KiB", "slab KiB", "win",
             "fill us", "restack us", "hits");

    let mut rows: Vec<Row> = Vec::new();
    for &shared_pct in &[0usize, 50] {
        for &seqs in seq_counts {
            let r = run_combo(seqs, shared_pct, mean_len, smoke);
            println!("{:<6} {:<8} {:>13} {:>10} {:>7.2}x {:>9.1} \
{:>11.1} {:>6}",
                     r.seqs, format!("{}%", r.shared_pct),
                     r.resident_kib, r.slab_kib, r.mem_speedup,
                     r.fill_us, r.restack_us, r.prefix_hits);
            rows.push(r);
        }
    }

    // the acceptance gates, checked on every run:
    // 1. shared prompts hit the prefix cache across tenant labels
    for r in rows.iter().filter(|r| r.shared_pct > 0) {
        assert!(r.prefix_hits as usize >= r.seqs,
                "shared prompt never hit the index");
    }
    // 2. resident KV bytes are sublinear in sequence count when a
    //    system prompt is shared (strictly better than pro-rata)
    let shared: Vec<&Row> =
        rows.iter().filter(|r| r.shared_pct > 0).collect();
    for w in shared.windows(2) {
        let (a, b) = (w[0], w[1]);
        assert!(b.resident_kib * a.seqs < a.resident_kib * b.seqs,
                "resident KV not sublinear: {} seqs -> {} KiB, \
{} seqs -> {} KiB",
                a.seqs, a.resident_kib, b.seqs, b.resident_kib);
    }
    println!("\nresident KV is sublinear in sequence count under a \
shared system prompt; prefix hits span {} tenant labels",
             TENANTS.len());

    println!("\n--- JSON ---");
    let json_rows: Vec<Json> = rows.iter().map(json_row).collect();
    for r in &json_rows {
        println!("{r}");
    }
    match write_snapshot("kv_paging", smoke, json_rows) {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("\nsnapshot write failed: {e}"),
    }
    Ok(())
}
