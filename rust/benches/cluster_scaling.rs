//! Cluster scaling — aggregate decode throughput and request latency
//! vs worker count (1/2/4) × placement policy on a Zipf-skewed trace.
//!
//! The claim under test: one engine thread caps the system, and because
//! a BitDelta tenant is a ~1/16-cost delta on a shared base, adding
//! workers is nearly free in memory — so aggregate throughput should
//! scale with worker count, with `delta-aware` placement keeping hot
//! tenants replicated and queues balanced. The trace is open-loop
//! (arrival times honored) at a rate high enough to saturate a single
//! worker, replayed from multiple client threads
//! (`bitdelta::cluster::replay_trace` — the same harness `repro
//! loadtest --workers N` uses).
//!
//! Emits a human table plus one JSON object per row (the usual bench
//! JSON, parseable line-by-line).

use std::collections::BTreeMap;

use anyhow::Result;
use bitdelta::cluster::{apply_trace_weights, policy_by_name,
                        replay_trace, tenant_profiles, Cluster,
                        ClusterConfig, ReplayReport};
use bitdelta::coordinator::workload::{generate, stats, ArrivalPattern,
                                      TraceConfig, TraceEvent};
use bitdelta::serving::engine::EngineConfig;
use bitdelta::util::json::Json;

const PROMPT: &str = "Q: what color is the sky ?\nA:";

struct Summary {
    workers: usize,
    policy: &'static str,
    report: ReplayReport,
}

fn run_combo(workers: usize, policy: &'static str, trace: &[TraceEvent],
             counts: &[usize], batch: usize)
             -> Result<Option<Summary>> {
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = batch;
    let mut profiles = tenant_profiles(&ec)?;
    apply_trace_weights(&mut profiles, counts);
    let names: Vec<String> =
        profiles.iter().map(|t| t.name.clone()).collect();
    let ccfg = ClusterConfig {
        policy: policy_by_name(policy)?,
        delta_budget_bytes: 256 << 20,
        admission: None,
    };
    let cluster =
        match Cluster::spawn_engines(&ccfg, &ec, workers, profiles) {
            Ok(c) => c,
            // only a missing AOT executable for this batch width is a
            // benign skip; every other spawn failure is a real bug
            Err(e) if format!("{e:#}").contains("executable") => {
                return Ok(None);
            }
            Err(e) => return Err(e),
        };
    let handle = cluster.handle();
    let clients = (workers * 2).clamp(2, 8);
    let report = replay_trace(&handle, trace, &names, &[PROMPT],
                              clients)?;
    cluster.shutdown()?;
    Ok(Some(Summary { workers, policy, report }))
}

fn json_row(s: &Summary) -> Json {
    let round1 = |x: f64| (x * 10.0).round() / 10.0;
    let mut o = BTreeMap::new();
    o.insert("bench".to_string(),
             Json::Str("cluster_scaling".to_string()));
    o.insert("workers".to_string(), Json::Num(s.workers as f64));
    o.insert("policy".to_string(), Json::Str(s.policy.to_string()));
    o.insert("served".to_string(),
             Json::Num(s.report.served() as f64));
    o.insert("errors".to_string(), Json::Num(s.report.errors as f64));
    o.insert("tok_per_s".to_string(),
             Json::Num(round1(s.report.tok_per_s())));
    o.insert("p50_ms".to_string(),
             Json::Num(round1(s.report.quantile_ms(0.50))));
    o.insert("p99_ms".to_string(),
             Json::Num(round1(s.report.quantile_ms(0.99))));
    Json::Obj(o)
}

fn main() -> Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    // Zipf-skewed open-loop trace: 8 ranks at s=0.9, arrival rate high
    // enough that a single worker saturates and queues
    let tcfg = TraceConfig {
        n_tenants: 8,
        n_requests: 96,
        rate: 400.0,
        zipf_s: 0.9,
        min_tokens: 8,
        max_tokens: 16,
        seed: 7,
        pattern: ArrivalPattern::Steady,
    };
    let trace = generate(&tcfg);
    let st = stats(&trace, tcfg.n_tenants);
    println!("cluster_scaling — {} requests, zipf {} over {} ranks, \
hottest {:.0}% of traffic",
             st.n, tcfg.zipf_s, tcfg.n_tenants,
             st.hottest_share * 100.0);
    println!("{:<8} {:<14} {:>8} {:>10} {:>9} {:>9} {:>7}",
             "workers", "policy", "served", "tok/s", "p50 ms",
             "p99 ms", "errors");

    let mut rows: Vec<Summary> = Vec::new();
    for workers in [1usize, 2, 4] {
        for policy in ["affinity", "least-loaded", "delta-aware"] {
            match run_combo(workers, policy, &trace, &st.per_tenant, 4)? {
                Some(s) => {
                    println!("{:<8} {:<14} {:>8} {:>10.1} {:>9.1} \
{:>9.1} {:>7}",
                             s.workers, s.policy, s.report.served(),
                             s.report.tok_per_s(),
                             s.report.quantile_ms(0.50),
                             s.report.quantile_ms(0.99),
                             s.report.errors);
                    rows.push(s);
                }
                None => println!("{workers:<8} {policy:<14} (no \
executable for this batch size)"),
            }
        }
    }

    println!("\n--- JSON ---");
    for s in &rows {
        println!("{}", json_row(s));
    }

    // the scaling claim: 4 delta-aware workers beat 1 worker
    let thr = |w: usize, p: &str| rows.iter()
        .find(|s| s.workers == w && s.policy == p)
        .map(|s| s.report.tok_per_s());
    if let (Some(t4), Some(t1)) = (thr(4, "delta-aware"),
                                   thr(1, "delta-aware")) {
        println!("\ndelta-aware 4-worker vs 1-worker aggregate decode \
throughput: {t4:.1} vs {t1:.1} tok/s ({:.2}x)", t4 / t1);
    }
    Ok(())
}
