//! Cluster scaling — aggregate decode throughput and request latency
//! vs worker count (1/2/4) × placement policy on a Zipf-skewed trace.
//!
//! The claim under test: one engine thread caps the system, and because
//! a BitDelta tenant is a ~1/16-cost delta on a shared base, adding
//! workers is nearly free in memory — so aggregate throughput should
//! scale with worker count, with `delta-aware` placement keeping hot
//! tenants replicated and queues balanced. The trace is open-loop
//! (arrival times honored) at a rate high enough to saturate a single
//! worker, replayed from multiple client threads
//! (`bitdelta::cluster::replay_trace` — the same harness `repro
//! loadtest --workers N` uses).
//!
//! Emits a human table plus one JSON object per row (the usual bench
//! JSON, parseable line-by-line), and archives the run to
//! `BENCH_cluster_scaling.json` (shared snapshot schema) for the
//! `scripts/compare_bench.py` baseline gate — with an empty row set
//! when artifacts are missing, so CI always has the artifact.
//!
//! Flags: `--smoke` (or env `CLUSTER_SCALING_SMOKE=1`) = 32 requests,
//! workers {1, 2} — a trend sample for CI, not a measurement.

use std::collections::BTreeMap;

use anyhow::Result;
use bitdelta::cluster::{apply_trace_weights, policy_by_name,
                        replay_trace, tenant_profiles, Cluster,
                        ClusterConfig, ReplayReport};
use bitdelta::coordinator::workload::{generate, stats, ArrivalPattern,
                                      TraceConfig, TraceEvent};
use bitdelta::serving::engine::EngineConfig;
use bitdelta::util::bench::write_snapshot;
use bitdelta::util::json::Json;

const PROMPT: &str = "Q: what color is the sky ?\nA:";

struct Summary {
    workers: usize,
    policy: &'static str,
    smoke: bool,
    report: ReplayReport,
}

fn run_combo(workers: usize, policy: &'static str, trace: &[TraceEvent],
             counts: &[usize], batch: usize, smoke: bool)
             -> Result<Option<Summary>> {
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = batch;
    let mut profiles = tenant_profiles(&ec)?;
    apply_trace_weights(&mut profiles, counts);
    let names: Vec<String> =
        profiles.iter().map(|t| t.name.clone()).collect();
    let ccfg = ClusterConfig {
        policy: policy_by_name(policy)?,
        delta_budget_bytes: 256 << 20,
        admission: None,
    };
    let cluster =
        match Cluster::spawn_engines(&ccfg, &ec, workers, profiles) {
            Ok(c) => c,
            // only a missing AOT executable for this batch width is a
            // benign skip; every other spawn failure is a real bug
            Err(e) if format!("{e:#}").contains("executable") => {
                return Ok(None);
            }
            Err(e) => return Err(e),
        };
    let handle = cluster.handle();
    let clients = (workers * 2).clamp(2, 8);
    let report = replay_trace(&handle, trace, &names, &[PROMPT],
                              clients)?;
    cluster.shutdown()?;
    Ok(Some(Summary { workers, policy, smoke, report }))
}

fn json_row(s: &Summary) -> Json {
    let round1 = |x: f64| (x * 10.0).round() / 10.0;
    let mut o = BTreeMap::new();
    o.insert("bench".to_string(),
             Json::Str("cluster_scaling".to_string()));
    o.insert("workers".to_string(), Json::Num(s.workers as f64));
    o.insert("policy".to_string(), Json::Str(s.policy.to_string()));
    o.insert("served".to_string(),
             Json::Num(s.report.served() as f64));
    o.insert("errors".to_string(), Json::Num(s.report.errors as f64));
    o.insert("tok_per_s".to_string(),
             Json::Num(round1(s.report.tok_per_s())));
    o.insert("p50_ms".to_string(),
             Json::Num(round1(s.report.quantile_ms(0.50))));
    o.insert("p99_ms".to_string(),
             Json::Num(round1(s.report.quantile_ms(0.99))));
    o.insert("threads".to_string(),
             Json::Num(s.report.kernel_threads as f64));
    o.insert("dispatch".to_string(),
             Json::Str(s.report.dispatch_tier.to_string()));
    o.insert("smoke".to_string(), Json::Bool(s.smoke));
    Json::Obj(o)
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("CLUSTER_SCALING_SMOKE").is_ok();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("artifacts missing — run `make artifacts` first");
        // still write the snapshot so the CI artifact set is stable
        match write_snapshot("cluster_scaling", smoke, Vec::new()) {
            Ok(p) => println!("wrote {} (empty)", p.display()),
            Err(e) => eprintln!("snapshot write failed: {e}"),
        }
        return Ok(());
    }
    // Zipf-skewed open-loop trace: 8 ranks at s=0.9, arrival rate high
    // enough that a single worker saturates and queues
    let tcfg = TraceConfig {
        n_tenants: 8,
        n_requests: if smoke { 32 } else { 96 },
        rate: 400.0,
        zipf_s: 0.9,
        min_tokens: 8,
        max_tokens: 16,
        seed: 7,
        pattern: ArrivalPattern::Steady,
    };
    let trace = generate(&tcfg);
    let st = stats(&trace, tcfg.n_tenants);
    println!("cluster_scaling — {} requests, zipf {} over {} ranks, \
hottest {:.0}% of traffic",
             st.n, tcfg.zipf_s, tcfg.n_tenants,
             st.hottest_share * 100.0);
    println!("{:<8} {:<14} {:>8} {:>10} {:>9} {:>9} {:>7}",
             "workers", "policy", "served", "tok/s", "p50 ms",
             "p99 ms", "errors");

    let mut rows: Vec<Summary> = Vec::new();
    let worker_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    for &workers in worker_counts {
        for policy in ["affinity", "least-loaded", "delta-aware"] {
            match run_combo(workers, policy, &trace, &st.per_tenant, 4,
                            smoke)? {
                Some(s) => {
                    println!("{:<8} {:<14} {:>8} {:>10.1} {:>9.1} \
{:>9.1} {:>7}",
                             s.workers, s.policy, s.report.served(),
                             s.report.tok_per_s(),
                             s.report.quantile_ms(0.50),
                             s.report.quantile_ms(0.99),
                             s.report.errors);
                    rows.push(s);
                }
                None => println!("{workers:<8} {policy:<14} (no \
executable for this batch size)"),
            }
        }
    }

    println!("\n--- JSON ---");
    let json_rows: Vec<Json> = rows.iter().map(json_row).collect();
    for r in &json_rows {
        println!("{r}");
    }
    match write_snapshot("cluster_scaling", smoke, json_rows) {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("\nsnapshot write failed: {e}"),
    }

    // the scaling claim: the widest delta-aware config beats 1 worker
    let wmax = *worker_counts.last().unwrap();
    let thr = |w: usize, p: &str| rows.iter()
        .find(|s| s.workers == w && s.policy == p)
        .map(|s| s.report.tok_per_s());
    if let (Some(tw), Some(t1)) = (thr(wmax, "delta-aware"),
                                   thr(1, "delta-aware")) {
        println!("\ndelta-aware {wmax}-worker vs 1-worker aggregate \
decode throughput: {tw:.1} vs {t1:.1} tok/s ({:.2}x)", tw / t1);
    }
    Ok(())
}
