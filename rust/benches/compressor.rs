//! Offline-compressor throughput: quantize+pack bandwidth of the
//! rust-native BitDelta compressor and the Jacobi-SVD baseline.
//!
//! The paper reports compressing a 70B model in ~10 minutes (dominated
//! by scale distillation on GPUs); the quantization stage itself must be
//! I/O-speed. This bench pins the rust quantizer's bytes/s so
//! regressions in the hot pack loop are visible.

use bitdelta::config::ModelConfig;
use bitdelta::delta::bitdelta::compress;
use bitdelta::delta::packing::{pack_signs, unpack_signs};
use bitdelta::delta::svd::svd;
use bitdelta::store::bdw::RawTensor;
use bitdelta::tensor::Tensor;
use bitdelta::util::bench::{black_box, Bench};
use std::collections::HashMap;

fn model(cfg: &ModelConfig, seed: u64) -> HashMap<String, RawTensor> {
    cfg.param_names().into_iter().enumerate().map(|(i, n)| {
        let shape = cfg.param_shape(&n);
        let t = Tensor::randn(shape.clone(), seed + i as u64);
        (n, RawTensor::f32(shape, t.data()))
    }).collect()
}

fn main() {
    let mut bench = Bench::new(1, 8);

    // raw pack/unpack bandwidth
    let m = 4096usize;
    let rows = 1024usize;
    let vals = Tensor::randn(vec![rows, m], 11);
    let mb = (rows * m * 4) as f64 / (1024.0 * 1024.0);
    let meas = bench.run(format!("pack_signs {rows}x{m}"), || {
        black_box(pack_signs(vals.data(), m));
    });
    println!("  -> {:.0} MB/s of f32 input",
             mb / meas.mean().as_secs_f64());
    let packed = pack_signs(vals.data(), m);
    bench.run(format!("unpack_signs {rows}x{m}"), || {
        black_box(unpack_signs(&packed, m));
    });

    // full-model compression (sim-s and sim-m shapes)
    for cfg in [ModelConfig::sim_s(), ModelConfig::sim_m()] {
        let base = model(&cfg, 1);
        let fine = model(&cfg, 2);
        let params_mb = (cfg.n_params() * 4) as f64 / (1024.0 * 1024.0);
        let meas = bench.run(format!("compress full {}", cfg.name), || {
            black_box(compress(&cfg, &base, &fine).unwrap());
        });
        println!("  -> {:.0} MB/s of model weights",
                 params_mb / meas.mean().as_secs_f64());
    }

    // SVD baseline cost at a representative matrix size (Table 1's cost
    // asymmetry: SVD is *far* slower than sign-quantization)
    let d = Tensor::randn(vec![128, 128], 3);
    bench.run("jacobi_svd 128x128", || {
        black_box(svd(&d));
    });
}
