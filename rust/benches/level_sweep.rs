//! Level sweep — multi-level (Fig. 3 fidelity tier) apply throughput
//! vs level count k.
//!
//! Two implementations of `y = Σ_l α_l·Sign_l @ x` are priced against
//! each other at k ∈ {1, 2, 4, 8}:
//!
//! * **loop**  — k independent single-level `binary_gemv` calls summed
//!   (what `forward_linear` did before the fused kernel): every level
//!   pays the O(4m) nibble-table build and the `Σx` reduction again.
//! * **fused** — `try_binary_gemv_multi`: one shared preamble, then k
//!   packed-byte streams. The marginal cost of a level approaches its
//!   pure byte traffic, so fidelity tiers scale close to linearly.
//!
//! Emits a human table plus one JSON object per row (line-parseable,
//! the usual bench JSON — CI runs this in smoke mode and archives the
//! rows as a workflow artifact to track the perf trajectory). The run
//! is also archived to `BENCH_level_sweep.json` (shared snapshot
//! schema) for the `scripts/compare_bench.py` baseline gate.
//!
//! Flags: `--smoke` (or env `LEVEL_SWEEP_SMOKE=1`) = 1 iteration, no
//! warmup, smaller matrix — a trend sample, not a measurement.

use std::collections::BTreeMap;

use bitdelta::delta::packing::pack_signs;
use bitdelta::gemm::dispatch;
use bitdelta::gemm::{binary_gemv, binary_gemv_multi};
use bitdelta::tensor::Tensor;
use bitdelta::util::bench::{black_box, write_snapshot, Bench};
use bitdelta::util::json::Json;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("LEVEL_SWEEP_SMOKE").is_ok();
    let (n, m) = if smoke { (512usize, 512usize) } else { (2048, 2048) };
    let (warmup, iters) = if smoke { (0, 1) } else { (3, 15) };
    let max_levels = 8usize;

    println!("=== level sweep: multi-level apply, {n}x{m}{} ===",
             if smoke { " (smoke)" } else { "" });

    // k independent sign planes with decaying scales (like the
    // iterative compressor produces)
    let packed: Vec<Vec<u8>> = (0..max_levels).map(|l| {
        let d = Tensor::randn(vec![n, m], 100 + l as u64);
        pack_signs(d.data(), m)
    }).collect();
    let alphas: Vec<f32> =
        (0..max_levels).map(|l| 0.1 / (1 << l) as f32).collect();
    let x = Tensor::randn(vec![m], 7);
    let mut y = vec![0f32; n];
    let mut tmp = vec![0f32; n];

    let mut rows: Vec<Json> = Vec::new();
    for k in [1usize, 2, 4, 8] {
        let mut bench = Bench::new(warmup, iters);
        let levels: Vec<(&[u8], f32)> = packed[..k].iter()
            .map(|b| b.as_slice())
            .zip(alphas.iter().copied())
            .collect();

        let fused_m = bench.run(format!("fused   k={k}"), || {
            binary_gemv_multi(&levels, n, m, x.data(), &mut y);
            black_box(&y);
        }).clone();
        let fused = fused_m.mean().as_secs_f64();

        let looped = bench.run(format!("loop    k={k}"), || {
            y.fill(0.0);
            for (bits, alpha) in &levels {
                binary_gemv(bits, n, m, x.data(), *alpha, &mut tmp);
                for (yv, t) in y.iter_mut().zip(&tmp) {
                    *yv += t;
                }
            }
            black_box(&y);
        }).mean().as_secs_f64();

        // packed bytes streamed per fused apply: k mask planes
        let bytes = k * n * m / 8;
        let gbps = bytes as f64 / fused.max(1e-12) / 1e9;
        let round2 = |v: f64| (v * 100.0).round() / 100.0;
        let mut o = BTreeMap::new();
        o.insert("bench".into(), Json::Str("level_sweep".into()));
        o.insert("n".into(), Json::Num(n as f64));
        o.insert("m".into(), Json::Num(m as f64));
        o.insert("levels".into(), Json::Num(k as f64));
        o.insert("fused_us".into(), Json::Num(round2(fused * 1e6)));
        o.insert("fused_p50_us".into(),
                 Json::Num(round2(
                     fused_m.quantile(0.5).as_secs_f64() * 1e6)));
        o.insert("fused_p99_us".into(),
                 Json::Num(round2(
                     fused_m.quantile(0.99).as_secs_f64() * 1e6)));
        o.insert("loop_us".into(), Json::Num(round2(looped * 1e6)));
        o.insert("speedup".into(),
                 Json::Num(round2(looped / fused.max(1e-12))));
        o.insert("fused_gbps".into(), Json::Num(round2(gbps)));
        o.insert("threads".into(),
                 Json::Num(dispatch::pool_threads() as f64));
        o.insert("dispatch".into(),
                 Json::Str(dispatch::active_tier().name().into()));
        o.insert("smoke".into(), Json::Bool(smoke));
        rows.push(Json::Obj(o));
    }

    println!("\n--- JSON ---");
    for r in &rows {
        println!("{r}");
    }
    match write_snapshot("level_sweep", smoke, rows) {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("\nsnapshot write failed: {e}"),
    }
}
