//! Figure 6 — end-to-end decoding latency of the serving engine, ablated
//! over batch size, across the registered delta codecs (dense/naive,
//! BitDelta, precomputed low-rank) plus a **mixed-format** batch.
//!
//! Measures steady-state decode-step latency (prefill excluded) by
//! saturating the batch with long generations and timing `Engine::step`
//! once every slot is generating. Reports per-step and per-user latency;
//! the paper's claims: naive scales with B (and OOMs), BitDelta/S-LoRA
//! share the backbone and win from B≈2, >10x per-user in the B≥16 regime.
//! The mixed row prices format freedom: tenants on two codecs in one
//! batch fall back to the stacked-dense executable.
//!
//! Note on the lora codec: only tenants with SVD factors are servable
//! there, so the lora sweep serves `sim-s-chat` in every slot.

use std::collections::HashMap;

use anyhow::Result;
use bitdelta::model::sampling::SamplingParams;
use bitdelta::serving::engine::{Engine, EngineConfig};
use bitdelta::serving::request::Request;

fn steady_state_step_us(codec: &str,
                        overrides: &HashMap<String, String>,
                        batch: usize, steps: usize)
                        -> Result<Option<(f64, f64)>> {
    let mut ec = EngineConfig::new("artifacts");
    ec.codec = Some(codec.to_string());
    ec.codec_overrides = overrides.clone();
    ec.batch = batch;
    ec.stop_token = None;              // run full max_new_tokens
    let mut engine = match Engine::from_artifacts(ec) {
        Ok(e) => e,
        Err(_) => return Ok(None),     // batch size not exported
    };
    let tenants = engine.tenants();
    // tenants[] order is not deterministic (manifest map); the mixed
    // run must guarantee one lora slot (chat) AND one bitdelta slot
    let non_chat: Vec<&String> = tenants.iter()
        .filter(|t| t.as_str() != "sim-s-chat").collect();
    let pick = |i: usize| -> String {
        if codec == "lora" || (!overrides.is_empty() && i == 0) {
            "sim-s-chat".to_string()
        } else if !overrides.is_empty() && !non_chat.is_empty() {
            non_chat[(i - 1) % non_chat.len()].clone()
        } else {
            tenants[i % tenants.len()].clone()
        }
    };
    for i in 0..batch {
        engine.submit(Request {
            tenant: pick(i),
            prompt: "Q: what color is the sky ?\nA:".into(),
            max_new_tokens: 220,
            sampling: SamplingParams::greedy(),
        })?;
    }
    // ramp until every slot is past prefill. step() can fail here even
    // though construction succeeded: the mixed path loads its
    // decode_naive executable lazily at first re-stack, and that batch
    // size may not be exported (naive is the mode that OOMs at large B)
    for _ in 0..64 {
        if engine.step().is_err() {
            return Ok(None);
        }
        if engine.batcher.occupancy() == batch {
            break;
        }
    }
    let mut exec_s = 0.0;
    let mut total_s = 0.0;
    for _ in 0..steps {
        let r = match engine.step() {
            Ok(r) => r,
            Err(_) => return Ok(None),
        };
        exec_s += r.exec_seconds;
        total_s += r.total_seconds;
    }
    Ok(Some((total_s / steps as f64 * 1e6,
             exec_s / steps as f64 * 1e6)))
}

fn main() -> Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    println!("Figure 6 — end-to-end decode latency (sim-s, steady \
state, 24 steps/point)");
    println!("{:<10} {:>5} {:>14} {:>14} {:>16}",
             "codec", "B", "step us", "exec us", "per-user us");
    let mut csv = String::from("codec,batch,step_us,per_user_us\n");
    // mixed: chat rides the low-rank codec, everyone else bitdelta
    let mixed: HashMap<String, String> =
        [("sim-s-chat".to_string(), "lora".to_string())].into();
    let none = HashMap::new();
    for (codec, overrides, name) in [
        ("dense", &none, "naive"),
        ("bitdelta", &none, "bitdelta"),
        ("lora", &none, "slora"),
        ("bitdelta", &mixed, "mixed"),
    ] {
        for b in [1usize, 2, 4, 8] {
            if name == "mixed" && b < 2 {
                // a single-slot batch is always homogeneous; there is
                // no mixed composition to measure at B=1
                continue;
            }
            match steady_state_step_us(codec, overrides, b, 24)? {
                Some((step, exec)) => {
                    println!("{:<10} {:>5} {:>14.1} {:>14.1} {:>16.1}",
                             name, b, step, exec, step / b as f64);
                    csv.push_str(&format!("{name},{b},{step:.1},{:.1}\n",
                                          step / b as f64));
                }
                None => println!("{:<10} {:>5} {:>14} {:>14} {:>16}",
                                 name, b, "n/a", "n/a",
                                 "(no executable)"),
            }
        }
    }
    println!("\n--- CSV ---\n{csv}");
    Ok(())
}
