//! Figure 6 — end-to-end decoding latency of the serving engine, ablated
//! over batch size, in all three modes (naive / BitDelta / S-LoRA).
//!
//! Measures steady-state decode-step latency (prefill excluded) by
//! saturating the batch with long generations and timing `Engine::step`
//! once every slot is generating. Reports per-step and per-user latency;
//! the paper's claims: naive scales with B (and OOMs), BitDelta/S-LoRA
//! share the backbone and win from B≈2, >10x per-user in the B≥16 regime.
//!
//! Note on the lora mode: only tenants with SVD factors are servable
//! there, so the lora sweep serves `sim-s-chat` in every slot.

use anyhow::Result;
use bitdelta::model::sampling::SamplingParams;
use bitdelta::serving::engine::{Engine, EngineConfig, ExecMode};
use bitdelta::serving::request::Request;

fn steady_state_step_us(mode: ExecMode, batch: usize, steps: usize)
                        -> Result<Option<(f64, f64)>> {
    let mut ec = EngineConfig::new("artifacts");
    ec.mode = mode;
    ec.batch = batch;
    ec.stop_token = None;              // run full max_new_tokens
    let mut engine = match Engine::from_artifacts(ec) {
        Ok(e) => e,
        Err(_) => return Ok(None),     // batch size not exported
    };
    let tenants = engine.tenants();
    let pick = |i: usize| -> String {
        if mode == ExecMode::Lora {
            "sim-s-chat".to_string()
        } else {
            tenants[i % tenants.len()].clone()
        }
    };
    for i in 0..batch {
        engine.submit(Request {
            tenant: pick(i),
            prompt: "Q: what color is the sky ?\nA:".into(),
            max_new_tokens: 220,
            sampling: SamplingParams::greedy(),
        })?;
    }
    // ramp until every slot is past prefill
    for _ in 0..64 {
        engine.step()?;
        if engine.batcher.occupancy() == batch {
            break;
        }
    }
    let mut exec_s = 0.0;
    let mut total_s = 0.0;
    for _ in 0..steps {
        let r = engine.step()?;
        exec_s += r.exec_seconds;
        total_s += r.total_seconds;
    }
    Ok(Some((total_s / steps as f64 * 1e6,
             exec_s / steps as f64 * 1e6)))
}

fn main() -> Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    println!("Figure 6 — end-to-end decode latency (sim-s, steady \
state, 24 steps/point)");
    println!("{:<10} {:>5} {:>14} {:>14} {:>16}",
             "mode", "B", "step us", "exec us", "per-user us");
    let mut csv = String::from("mode,batch,step_us,per_user_us\n");
    for (mode, name) in [(ExecMode::Naive, "naive"),
                         (ExecMode::BitDelta, "bitdelta"),
                         (ExecMode::Lora, "slora")] {
        for b in [1usize, 2, 4, 8] {
            match steady_state_step_us(mode, b, 24)? {
                Some((step, exec)) => {
                    println!("{:<10} {:>5} {:>14.1} {:>14.1} {:>16.1}",
                             name, b, step, exec, step / b as f64);
                    csv.push_str(&format!("{name},{b},{step:.1},{:.1}\n",
                                          step / b as f64));
                }
                None => println!("{:<10} {:>5} {:>14} {:>14} {:>16}",
                                 name, b, "n/a", "n/a",
                                 "(no executable)"),
            }
        }
    }
    println!("\n--- CSV ---\n{csv}");
    Ok(())
}
