//! Decode hot path — steady-state step latency with device-resident
//! KV vs the per-step full-KV host<->device round trip
//! (`--kv-roundtrip`).
//!
//! The claim under test: once a batch composition is steady, a decode
//! step should move only the per-step small tensors (pos/token/rope up;
//! logits + each slot's freshly written KV row down) — the KV tensors
//! themselves stay on the device between launches. The round-trip mode
//! re-uploads and re-downloads the full `[L, B, H, S, hd]` K and V
//! every step; the ratio of the two step times is the headline number
//! (`resident_speedup`), tracked by the `scripts/compare_bench.py`
//! baseline gate.
//!
//! Per batch width the bench saturates every slot with long greedy
//! generations (prefill excluded), measures steady-state `Engine::step`
//! in both modes, and **asserts in-run** that the resident mode's
//! steady-state steps perform zero full-KV transfers (per-step bytes a
//! small fraction of the KV tensor footprint) whenever the fast path
//! is available. Emits a human table plus JSON rows and archives
//! `BENCH_decode_hotpath.json` — with an empty row set when artifacts
//! (or the row-extract executable) are missing, so the CI artifact set
//! stays stable.
//!
//! Flags: `--smoke` (or env `DECODE_HOTPATH_SMOKE=1`) = batch 2 only,
//! 16 measured steps — a trend sample for CI, not a measurement.

use std::collections::BTreeMap;

use anyhow::Result;
use bitdelta::config::Manifest;
use bitdelta::model::sampling::SamplingParams;
use bitdelta::serving::engine::{Engine, EngineConfig};
use bitdelta::serving::request::Request;
use bitdelta::util::bench::write_snapshot;
use bitdelta::util::json::Json;

const PROMPT: &str = "Q: what color is the sky ?\nA:";

/// One measured mode: mean step time plus deterministic per-step
/// transfer accounting.
struct ModeStats {
    step_us: f64,
    h2d_per_step: u64,
    d2h_per_step: u64,
    /// How many measured steps ran with KV left on the device.
    resident_steps: u64,
}

/// First value of an exposed metric series, 0 when absent.
fn metric(exposition: &str, name: &str) -> f64 {
    exposition.lines()
        .filter_map(|l| l.trim().strip_prefix(name))
        .filter_map(|rest| rest.strip_prefix(' '))
        .find_map(|v| v.trim().parse::<f64>().ok())
        .unwrap_or(0.0)
}

fn steady_state(batch: usize, roundtrip: bool, steps: usize)
                -> Result<Option<ModeStats>> {
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = batch;
    ec.stop_token = None;              // run full max_new_tokens
    ec.kv_roundtrip = roundtrip;
    let mut engine = match Engine::from_artifacts(ec) {
        Ok(e) => e,
        Err(_) => return Ok(None),     // batch size not exported
    };
    let tenants = engine.tenants();
    for i in 0..batch {
        engine.submit(Request {
            tenant: tenants[i % tenants.len()].clone(),
            prompt: PROMPT.into(),
            max_new_tokens: steps + 96,
            sampling: SamplingParams::greedy(),
        })?;
    }
    // ramp until every slot is past prefill and the composition is
    // steady (no admissions left to disturb the device cache)
    for _ in 0..64 {
        if engine.step().is_err() {
            return Ok(None);
        }
        if engine.batcher.occupancy() == batch {
            break;
        }
    }
    let device_before =
        metric(&engine.metrics.exposition(),
               "bitdelta_step_kv_device_total");
    let mut total_s = 0.0;
    let (mut h2d, mut d2h) = (0u64, 0u64);
    for _ in 0..steps {
        let r = engine.step()?;
        assert_eq!(r.admitted, 0, "steady state perturbed by admission");
        total_s += r.total_seconds;
        h2d += r.bytes_h2d;
        d2h += r.bytes_d2h;
    }
    let device_after =
        metric(&engine.metrics.exposition(),
               "bitdelta_step_kv_device_total");
    Ok(Some(ModeStats {
        step_us: total_s / steps as f64 * 1e6,
        h2d_per_step: h2d / steps as u64,
        d2h_per_step: d2h / steps as u64,
        resident_steps: (device_after - device_before) as u64,
    }))
}

fn json_row(batch: usize, steps: usize, res: &ModeStats,
            rt: &ModeStats, smoke: bool) -> Json {
    let round1 = |x: f64| (x * 10.0).round() / 10.0;
    let round2 = |x: f64| (x * 100.0).round() / 100.0;
    let mut o = BTreeMap::new();
    o.insert("bench".to_string(),
             Json::Str("decode_hotpath".to_string()));
    o.insert("batch".to_string(), Json::Num(batch as f64));
    o.insert("steps".to_string(), Json::Num(steps as f64));
    o.insert("resident_step_us".to_string(),
             Json::Num(round1(res.step_us)));
    o.insert("roundtrip_step_us".to_string(),
             Json::Num(round1(rt.step_us)));
    o.insert("resident_speedup".to_string(),
             Json::Num(round2(rt.step_us / res.step_us)));
    // deterministic identity fields: per-step transfer volume of each
    // mode (a change here is a data-path change, not noise)
    o.insert("resident_h2d_bytes".to_string(),
             Json::Num(res.h2d_per_step as f64));
    o.insert("resident_d2h_bytes".to_string(),
             Json::Num(res.d2h_per_step as f64));
    o.insert("roundtrip_h2d_bytes".to_string(),
             Json::Num(rt.h2d_per_step as f64));
    o.insert("smoke".to_string(), Json::Bool(smoke));
    Json::Obj(o)
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("DECODE_HOTPATH_SMOKE").is_ok();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("artifacts missing — run `make artifacts` first");
        // still write the snapshot so the CI artifact set is stable
        match write_snapshot("decode_hotpath", smoke, Vec::new()) {
            Ok(p) => println!("wrote {} (empty)", p.display()),
            Err(e) => eprintln!("snapshot write failed: {e}"),
        }
        return Ok(());
    }
    let m = Manifest::load("artifacts")?;
    let cfg = m.config("sim-s")?.clone();
    let steps = if smoke { 16 } else { 64 };
    let batches: &[usize] = if smoke { &[2] } else { &[2, 4] };

    println!("decode_hotpath — steady-state decode step, resident KV \
vs full round trip ({steps} steps/point)");
    println!("{:<6} {:>14} {:>15} {:>9} {:>13} {:>13}",
             "B", "resident us", "roundtrip us", "ratio",
             "res h2d B/st", "rt h2d B/st");

    let mut rows: Vec<Json> = Vec::new();
    for &batch in batches {
        let (Some(res), Some(rt)) =
            (steady_state(batch, false, steps)?,
             steady_state(batch, true, steps)?)
        else {
            println!("{batch:<6} (no executable for this batch size)");
            continue;
        };
        // k + v for the whole batch: what the round trip moves per step
        let full_kv = (2 * cfg.n_layers * batch * cfg.n_heads
                       * cfg.max_seq_len * cfg.head_dim() * 4) as u64;
        // the acceptance gate, checked in-run: when every measured
        // step kept KV on the device, none of them moved the full KV
        if res.resident_steps >= steps as u64 {
            assert!(res.h2d_per_step < full_kv / 8,
                    "resident steady state still uploads KV: {} B of \
full-KV {} B", res.h2d_per_step, full_kv);
            assert!(res.d2h_per_step < full_kv / 8,
                    "resident steady state still downloads full KV: \
{} B", res.d2h_per_step);
            assert!(rt.h2d_per_step >= full_kv,
                    "round-trip mode moved less than the full KV");
        } else {
            println!("  (row-extract executable absent — resident \
mode fell back to the round trip; rebuild artifacts)");
        }
        println!("{:<6} {:>14.1} {:>15.1} {:>8.2}x {:>13} {:>13}",
                 batch, res.step_us, rt.step_us,
                 rt.step_us / res.step_us, res.h2d_per_step,
                 rt.h2d_per_step);
        rows.push(json_row(batch, steps, &res, &rt, smoke));
    }

    println!("\n--- JSON ---");
    for r in &rows {
        println!("{r}");
    }
    match write_snapshot("decode_hotpath", smoke, rows) {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("\nsnapshot write failed: {e}"),
    }
    Ok(())
}
