//! Table 5 — compression factor and delta-load latency, measured on the
//! artifacts plus computed exactly for the paper's real model shapes.
//!
//! The paper's storage claim: a 1-bit delta is >10x smaller than the
//! dense fine-tune, so it loads >10x faster (disk -> memory). The
//! measured half iterates the **delta codec registry**: every
//! registered format is priced (resident bytes, load latency,
//! compression factor vs the dense fine-tune) for every tenant that has
//! an artifact in that format — a newly registered codec appears in
//! this table with zero bench code.

use std::collections::HashMap;
use std::time::Instant;

use bitdelta::config::Manifest;
use bitdelta::delta::codec::{CodecRegistry, LoadCtx, Model};
use bitdelta::sim::memory::ModelSpec;
use bitdelta::store::delta_file::load_model;
use bitdelta::util::bench::black_box;

fn main() -> anyhow::Result<()> {
    println!("=== Table 5: analytic (paper's model shapes, fp16) ===");
    println!("{:<20} {:>10} {:>10} {:>8}", "model", "size GB",
             "delta GB", "factor");
    for spec in [ModelSpec::llama2_7b(), ModelSpec::llama2_13b(),
                 ModelSpec::llama2_70b(), ModelSpec::mistral_7b()] {
        let gb = |b: usize| b as f64 / (1024.0 * 1024.0 * 1024.0);
        println!("{:<20} {:>10.2} {:>10.2} {:>7.2}x", spec.name,
                 gb(spec.dense_bytes()), gb(spec.delta_bytes()),
                 spec.compression_factor());
    }

    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(_) => {
            println!("\n(artifacts not built; analytic half only)");
            return Ok(());
        }
    };

    println!("\n=== measured: per-codec payload bytes + load latency ===");
    println!("{:<16} {:<10} {:>12} {:>12} {:>10} {:>8}",
             "tenant", "codec", "dense B", "payload B", "load ms",
             "factor");
    let registry = CodecRegistry::builtin();
    let mut bases: HashMap<String, Model> = HashMap::new();
    let mut tenants: Vec<_> = manifest.tenants.iter().collect();
    tenants.sort_by_key(|(n, _)| n.to_string());
    for (name, t) in tenants {
        let cfg = manifest.config(&t.config)?.clone();
        if !bases.contains_key(&t.config) {
            let base_name = format!("{}-base", t.config);
            let base_entry = manifest.models.get(&base_name)
                .ok_or_else(|| anyhow::anyhow!(
                    "manifest missing {base_name}"))?;
            bases.insert(t.config.clone(),
                         load_model(manifest.path(&base_entry.file),
                                    &cfg)?);
        }
        let base = &bases[&t.config];
        let dense_bytes = std::fs::metadata(
            manifest.path(&t.finetune))?.len() as usize;

        for codec in registry.iter() {
            let Some(path) = codec.artifact_path(&manifest, t, true, 1)
            else { continue };
            // the svd codec factorizes at load time (Jacobi per
            // linear): one reps is plenty, it is the point being priced
            let reps = if codec.name() == "svd" { 1 } else { 5 };
            let ctx = LoadCtx { cfg: &cfg, base: Some(base),
                                levels: 0 };
            let t0 = Instant::now();
            let mut payload = None;
            for _ in 0..reps {
                payload = Some(black_box(codec.load(&path, &ctx)?));
            }
            let load_ms =
                t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
            let bytes = payload.unwrap().resident_bytes();
            println!("{:<16} {:<10} {:>12} {:>12} {:>10.2} {:>7.2}x",
                     name, codec.name(), dense_bytes, bytes, load_ms,
                     dense_bytes as f64 / bytes as f64);
        }
    }
    Ok(())
}
