//! Table 5 — compression factor and delta-load latency, measured on the
//! artifacts plus computed exactly for the paper's real model shapes.
//!
//! The paper's storage claim: a 1-bit delta is >10x smaller than the
//! dense fine-tune, so it loads >10x faster (disk -> memory). We measure
//! both directions on the artifact files.

use std::time::Instant;

use bitdelta::config::Manifest;
use bitdelta::sim::memory::ModelSpec;
use bitdelta::store::bdw::read_bdw;
use bitdelta::store::delta_file::DeltaFile;
use bitdelta::util::bench::black_box;

fn main() -> anyhow::Result<()> {
    println!("=== Table 5: analytic (paper's model shapes, fp16) ===");
    println!("{:<20} {:>10} {:>10} {:>8}", "model", "size GB",
             "delta GB", "factor");
    for spec in [ModelSpec::llama2_7b(), ModelSpec::llama2_13b(),
                 ModelSpec::llama2_70b(), ModelSpec::mistral_7b()] {
        let gb = |b: usize| b as f64 / (1024.0 * 1024.0 * 1024.0);
        println!("{:<20} {:>10.2} {:>10.2} {:>7.2}x", spec.name,
                 gb(spec.dense_bytes()), gb(spec.delta_bytes()),
                 spec.compression_factor());
    }

    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(_) => {
            println!("\n(artifacts not built; analytic half only)");
            return Ok(());
        }
    };

    println!("\n=== measured: load latency, dense model vs delta ===");
    println!("{:<16} {:>12} {:>12} {:>10} {:>10} {:>8}",
             "tenant", "model B", "delta B", "model ms", "delta ms",
             "speedup");
    let mut tenants: Vec<_> = manifest.tenants.iter().collect();
    tenants.sort_by_key(|(n, _)| n.to_string());
    for (name, t) in tenants {
        let cfg = manifest.config(&t.config)?;
        let mpath = manifest.path(&t.finetune);
        let dpath = manifest.path(&t.delta);

        let reps = 5;
        let t0 = Instant::now();
        for _ in 0..reps {
            black_box(read_bdw(&mpath)?);
        }
        let model_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        let t0 = Instant::now();
        for _ in 0..reps {
            black_box(DeltaFile::load(&dpath, cfg)?);
        }
        let delta_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

        let mb = std::fs::metadata(&mpath)?.len();
        let db = std::fs::metadata(&dpath)?.len();
        println!("{:<16} {:>12} {:>12} {:>10.2} {:>10.2} {:>7.2}x",
                 name, mb, db, model_ms, delta_ms, model_ms / delta_ms);
    }
    Ok(())
}
