//! Minimal dense f32 tensor — the substrate for the rust-native
//! compressor, the SVD baseline, and the CPU GEMV kernels.
//!
//! Deliberately tiny: contiguous row-major storage, shape vector, and the
//! handful of ops the compression path needs. Model *serving* math runs
//! inside the AOT HLO executables, not here.

use std::fmt;

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} != data len {}", data.len());
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    /// Deterministic pseudo-random tensor (xorshift) for tests/benches.
    pub fn randn(shape: Vec<usize>, seed: u64) -> Self {
        let n: usize = shape.iter().product();
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let mut data = Vec::with_capacity(n);
        for _ in 0..(n + 1) / 2 {
            // Box-Muller over two uniform draws
            let u1 = (next_u64(&mut state) >> 11) as f64 / (1u64 << 53) as f64;
            let u2 = (next_u64(&mut state) >> 11) as f64 / (1u64 << 53) as f64;
            let r = (-2.0 * (u1.max(1e-12)).ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            data.push((r * c) as f32);
            data.push((r * s) as f32);
        }
        data.truncate(n);
        Self { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Rows/cols of a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.ndim(), 2, "dims2 on {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    /// Element-wise subtraction: `self - other`.
    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data)
            .map(|(a, b)| a - b).collect();
        Tensor::new(self.shape.clone(), data)
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data)
            .map(|(a, b)| a + b).collect();
        Tensor::new(self.shape.clone(), data)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        Tensor::new(self.shape.clone(),
                    self.data.iter().map(|a| a * s).collect())
    }

    /// Mean of |x| — BitDelta's optimal scale (Eq. 4).
    pub fn mean_abs(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        let s: f64 = self.data.iter().map(|a| a.abs() as f64).sum();
        (s / self.data.len() as f64) as f32
    }

    pub fn frob_norm(&self) -> f32 {
        (self.data.iter().map(|a| (a * a) as f64).sum::<f64>()).sqrt() as f32
    }

    /// `self @ other` for 2-D tensors (reference-quality triple loop with
    /// an ikj ordering; hot-path GEMMs live in [`crate::gemm`]).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let (n, k) = self.dims2();
        let (k2, m) = other.dims2();
        assert_eq!(k, k2, "matmul {:?} x {:?}", self.shape, other.shape);
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let row = &other.data[p * m..(p + 1) * m];
                let orow = &mut out[i * m..(i + 1) * m];
                for j in 0..m {
                    orow[j] += a * row[j];
                }
            }
        }
        Tensor::new(vec![n, m], out)
    }

    /// Transpose of a 2-D tensor.
    pub fn t(&self) -> Tensor {
        let (n, m) = self.dims2();
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            for j in 0..m {
                out[j * n + i] = self.data[i * m + j];
            }
        }
        Tensor::new(vec![m, n], out)
    }

    /// Max |x| per row (used by the RTN quantizer).
    pub fn row_abs_max(&self) -> Vec<f32> {
        let (n, m) = self.dims2();
        (0..n).map(|i| {
            self.data[i * m..(i + 1) * m].iter()
                .fold(0.0f32, |acc, v| acc.max(v.abs()))
        }).collect()
    }
}

#[inline]
fn next_u64(state: &mut u64) -> u64 {
    // xorshift64*
    let mut x = *state;
    x ^= x >> 12;
    x ^= x << 25;
    x ^= x >> 27;
    *state = x;
    x.wrapping_mul(0x2545F4914F6CDD1D)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Tensor::randn(vec![4, 4], 1);
        let mut eye = Tensor::zeros(vec![4, 4]);
        for i in 0..4 {
            eye.data_mut()[i * 4 + i] = 1.0;
        }
        let b = a.matmul(&eye);
        assert_eq!(a, b);
    }

    #[test]
    fn transpose_involution() {
        let a = Tensor::randn(vec![3, 5], 2);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn mean_abs_simple() {
        let t = Tensor::new(vec![2, 2], vec![1.0, -1.0, 3.0, -3.0]);
        assert!((t.mean_abs() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn randn_roughly_standard() {
        let t = Tensor::randn(vec![10_000], 7);
        let mean: f32 = t.data().iter().sum::<f32>() / 10_000.0;
        let var: f32 = t.data().iter().map(|x| x * x).sum::<f32>() / 10_000.0;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }
}
