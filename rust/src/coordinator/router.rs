//! Tenant registry and per-tenant FIFO request queues.
//!
//! Requests name a tenant (a fine-tune identity); the router validates
//! the tenant, applies the admission policy, and enqueues. The batcher
//! drains queues round-robin so a hot tenant cannot starve others — the
//! fairness property multi-tenant serving needs when "traffic is low or
//! unbalanced" (paper §3.3).

use std::collections::{HashMap, VecDeque};

use anyhow::{bail, Result};

use crate::coordinator::admission::{AdmissionPolicy, Verdict};
use crate::serving::request::QueuedRequest;

/// Static description of one servable tenant.
#[derive(Debug, Clone)]
pub struct TenantInfo {
    pub name: String,
    /// RoPE position-interpolation factor (1.0 = none; the context-
    /// extension tenants use 0.5).
    pub rope_scale: f32,
    /// Name of the [`crate::delta::codec::DeltaCodec`] this tenant's
    /// delta payload uses — tenants on different codecs may share one
    /// decode batch (mixed-format batching).
    pub codec: String,
    /// Fidelity tier: how many 1-bit mask levels the tenant is served
    /// with (Fig. 3). Tier 1 is the standard single-mask delta; higher
    /// tiers trade delta residency for reconstruction fidelity.
    pub levels: usize,
}

impl TenantInfo {
    /// Convenience constructor defaulting to the paper's own format.
    pub fn new(name: impl Into<String>, rope_scale: f32) -> Self {
        Self { name: name.into(), rope_scale, codec: "bitdelta".into(),
               levels: 1 }
    }

    pub fn with_codec(mut self, codec: impl Into<String>) -> Self {
        self.codec = codec.into();
        self
    }

    pub fn with_levels(mut self, levels: usize) -> Self {
        self.levels = levels;
        self
    }
}

/// Router state: tenants + queues + round-robin cursor.
pub struct Router {
    tenants: HashMap<String, TenantInfo>,
    queues: HashMap<String, VecDeque<QueuedRequest>>,
    order: Vec<String>,
    cursor: usize,
    policy: AdmissionPolicy,
    pub enqueued: u64,
    pub rejected: u64,
}

impl Router {
    pub fn new(policy: AdmissionPolicy) -> Self {
        Self { tenants: HashMap::new(), queues: HashMap::new(),
               order: Vec::new(), cursor: 0, policy,
               enqueued: 0, rejected: 0 }
    }

    pub fn register_tenant(&mut self, info: TenantInfo) {
        if !self.tenants.contains_key(&info.name) {
            self.order.push(info.name.clone());
            self.queues.insert(info.name.clone(), VecDeque::new());
        }
        self.tenants.insert(info.name.clone(), info);
    }

    pub fn tenant(&self, name: &str) -> Option<&TenantInfo> {
        self.tenants.get(name)
    }

    pub fn tenant_names(&self) -> &[String] {
        &self.order
    }

    /// Route one request into its tenant queue (admission-checked).
    pub fn enqueue(&mut self, req: QueuedRequest) -> Result<()> {
        let total = self.total_queued_inner();
        // queues and tenants are inserted together in add(), so one
        // lookup both authenticates the tenant and finds its queue
        let Some(q) = self.queues.get_mut(&req.request.tenant) else {
            bail!("unknown tenant {}", req.request.tenant);
        };
        match self.policy.admit(q.len(), total) {
            Verdict::Admit => {
                q.push_back(req);
                self.enqueued += 1;
                Ok(())
            }
            Verdict::Reject(reason) => {
                self.rejected += 1;
                bail!("request rejected: {reason}");
            }
        }
    }

    fn total_queued_inner(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }

    pub fn total_queued(&self) -> usize {
        self.total_queued_inner()
    }

    pub fn queued_for(&self, tenant: &str) -> usize {
        self.queues.get(tenant).map_or(0, |q| q.len())
    }

    /// Pop up to `n` requests, round-robin across tenants starting after
    /// the last-served tenant (fair draining). Runs every engine step:
    /// the cursor indexes `order` directly so a pop never allocates.
    pub fn drain(&mut self, n: usize) -> Vec<QueuedRequest> {
        let mut out = Vec::new();
        let len = self.order.len();
        if len == 0 {
            return out;
        }
        let mut empty_rounds = 0;
        while out.len() < n && empty_rounds < len {
            let idx = self.cursor % len;
            self.cursor = (self.cursor + 1) % len;
            if let Some(req) = self.queues.get_mut(&self.order[idx])
                .and_then(|q| q.pop_front()) {
                out.push(req);
                empty_rounds = 0;
            } else {
                empty_rounds += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::sampling::SamplingParams;
    use crate::serving::request::{QueuedRequest, Request};

    fn req(tenant: &str, id: u64) -> QueuedRequest {
        QueuedRequest::for_test(Request {
            tenant: tenant.into(),
            prompt: "Q:".into(),
            max_new_tokens: 4,
            sampling: SamplingParams::greedy(),
        }, id)
    }

    fn router() -> Router {
        let mut r = Router::new(AdmissionPolicy::default());
        r.register_tenant(TenantInfo::new("a", 1.0));
        r.register_tenant(TenantInfo::new("b", 1.0).with_codec("lora"));
        r
    }

    #[test]
    fn tenant_codec_is_recorded() {
        let r = router();
        assert_eq!(r.tenant("a").unwrap().codec, "bitdelta");
        assert_eq!(r.tenant("b").unwrap().codec, "lora");
    }

    #[test]
    fn unknown_tenant_rejected() {
        let mut r = router();
        assert!(r.enqueue(req("zz", 1)).is_err());
    }

    #[test]
    fn round_robin_is_fair() {
        let mut r = router();
        for i in 0..4 {
            r.enqueue(req("a", i)).unwrap();
        }
        for i in 4..6 {
            r.enqueue(req("b", i)).unwrap();
        }
        let drained = r.drain(4);
        let tenants: Vec<&str> = drained.iter()
            .map(|q| q.request.tenant.as_str()).collect();
        // a and b must interleave, not a,a,a,a
        assert_eq!(tenants.iter().filter(|t| **t == "b").count(), 2,
                   "{tenants:?}");
    }

    #[test]
    fn drain_stops_when_empty() {
        let mut r = router();
        r.enqueue(req("a", 1)).unwrap();
        let drained = r.drain(10);
        assert_eq!(drained.len(), 1);
        assert_eq!(r.total_queued(), 0);
    }

    #[test]
    fn queue_cap_backpressure() {
        let mut r = Router::new(AdmissionPolicy {
            per_tenant_cap: 2, total_cap: 100 });
        r.register_tenant(TenantInfo::new("a", 1.0));
        assert!(r.enqueue(req("a", 1)).is_ok());
        assert!(r.enqueue(req("a", 2)).is_ok());
        assert!(r.enqueue(req("a", 3)).is_err());
        assert_eq!(r.rejected, 1);
    }
}
