//! The declared registry of exported Prometheus series.
//!
//! Exposition is compositional ([`super::metrics::Metrics`] derives
//! `bitdelta_{counter}_total`, `bitdelta_{gauge}`,
//! `bitdelta_{name}{tenant=...}`, and the histogram family
//! `bitdelta_{name}_us_{mean,p50,p99}` / `_us_bucket` / `_count` from
//! short internal keys), so nothing at the update site spells out the
//! full series name — which is exactly how docs, dashboards, and tests
//! drift from what the process actually exports. This module is the
//! fix: **every full series name lives here, once.**
//!
//! The house lint (`cargo xtask lint`, rule `metric`) extracts every
//! `bitdelta_*` token found in Rust string literals and markdown code
//! spans and checks it against [`EXPORTED_SERIES`]: a token passes if
//! it is an exact member or a proper prefix of a member (docs often
//! name a family by prefix, e.g. `bitdelta_cluster_admission_…`).
//! Non-metric tokens that happen to share the prefix carry a
//! `// lint: allow(metric, reason)` marker instead of polluting this
//! list. Unit tests below tie the list back to the code that composes
//! the names, so the registry cannot itself go stale.

/// Every Prometheus series name this process can export, sorted.
///
/// Label sets are not part of the name: `bitdelta_queue_depth` stands
/// for `bitdelta_queue_depth{tenant="..."}` and so on. When you add a
/// metric, add the full exported name(s) here — the lint and the
/// round-trip tests below will hold you to it.
pub const EXPORTED_SERIES: &[&str] = &[
    // --- engine counters (`Metrics::inc(k)` → `bitdelta_{k}_total`)
    "bitdelta_completed_total",
    "bitdelta_delta_restack_bytes_total",
    "bitdelta_delta_restacks_total",
    "bitdelta_kv_cow_copies_total",
    "bitdelta_kv_prefix_hits_total",
    "bitdelta_kv_prefix_lookups_total",
    "bitdelta_kv_prefix_reclaimed_total",
    "bitdelta_kv_restacked_slots_total",
    "bitdelta_mixed_batches_total",
    "bitdelta_mixed_native_subbatches_total",
    "bitdelta_plan_cache_hits_total",
    "bitdelta_rejected_total",
    "bitdelta_requests_total",
    "bitdelta_step_bank_us_total",
    "bitdelta_step_bytes_d2h_total",
    "bitdelta_step_bytes_h2d_total",
    "bitdelta_step_download_us_total",
    "bitdelta_step_exec_us_total",
    "bitdelta_step_kv_device_total",
    "bitdelta_step_upload_us_total",
    "bitdelta_steps_total",
    "bitdelta_tokens_generated_total",
    // --- per-executable launch counters (`Metrics::inc(exec_kind)`,
    //     one per `crate::delta::codec::KNOWN_EXEC_KINDS` entry)
    "bitdelta_decode_bitdelta_l2_total",
    "bitdelta_decode_bitdelta_l4_total",
    "bitdelta_decode_bitdelta_total",
    "bitdelta_decode_dense_total",
    "bitdelta_decode_lora_total",
    "bitdelta_decode_naive_total",
    // --- engine gauges (`Metrics::set(k)` → `bitdelta_{k}`)
    "bitdelta_batch_occupancy",
    "bitdelta_kv_blocks_total",
    "bitdelta_kv_blocks_used",
    // --- tenant-labeled gauges (`Metrics::set_tenant_gauge`)
    "bitdelta_queue_depth",
    // --- engine latency histograms (`bitdelta_{h}_us_*`; ttft
    //     additionally exports cumulative `_us_bucket{le=...}` lines)
    "bitdelta_request_latency_count",
    "bitdelta_request_latency_us_mean",
    "bitdelta_request_latency_us_p50",
    "bitdelta_request_latency_us_p99",
    "bitdelta_step_latency_count",
    "bitdelta_step_latency_us_mean",
    "bitdelta_step_latency_us_p50",
    "bitdelta_step_latency_us_p99",
    "bitdelta_ttft_count",
    "bitdelta_ttft_us_bucket",
    "bitdelta_ttft_us_mean",
    "bitdelta_ttft_us_p50",
    "bitdelta_ttft_us_p99",
    // --- delta-store residency accounting (codec-labeled, emitted by
    //     `Engine::codec_accounting`)
    "bitdelta_delta_bytes_loaded_total",
    "bitdelta_delta_evictions_total",
    "bitdelta_delta_loads_total",
    "bitdelta_delta_resident_bytes",
    // --- cluster front door (`ClusterHandle::metrics_exposition`)
    "bitdelta_cluster_admission_inflight",
    "bitdelta_cluster_admission_rejected_total",
    "bitdelta_cluster_drain_us_bucket",
    "bitdelta_cluster_drain_us_count",
    "bitdelta_cluster_drain_us_sum",
    "bitdelta_cluster_failovers_total",
    "bitdelta_cluster_placement_degraded",
    "bitdelta_cluster_replaced_tenants_total",
    "bitdelta_cluster_routed_total",
    "bitdelta_cluster_scale_events_total",
    "bitdelta_cluster_workers_alive",
    "bitdelta_cluster_workers_draining",
];

/// Exact-or-proper-prefix membership — the rule the house lint applies
/// to every `bitdelta_*` token it finds in strings and docs.
pub fn is_registered(token: &str) -> bool {
    EXPORTED_SERIES.iter().any(|s| {
        *s == token
            || (s.len() > token.len() && s.starts_with(token))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;
    use std::time::Duration;

    #[test]
    fn registry_is_sorted_within_sections_and_duplicate_free() {
        let mut seen = std::collections::BTreeSet::new();
        for s in EXPORTED_SERIES {
            assert!(seen.insert(*s), "duplicate registry entry {s}");
            assert!(s.starts_with("bitdelta_"), "bad prefix: {s}");
        }
    }

    #[test]
    fn prefix_rule_accepts_families_and_rejects_strangers() {
        assert!(is_registered("bitdelta_requests_total"));
        // a docs-style family prefix
        assert!(is_registered("bitdelta_cluster_admission_"));
        assert!(is_registered("bitdelta_"));
        // lint: allow(metric, deliberately unregistered drift examples)
        assert!(!is_registered("bitdelta_requests_totals"));
        assert!(!is_registered("bitdelta_queue_depths"));
    }

    /// Every composed series an exercised `Metrics` exports must be
    /// registered — the registry cannot lag the exposition code.
    #[test]
    fn live_exposition_only_emits_registered_series() {
        let mut m = Metrics::default();
        for k in ["requests", "completed", "tokens_generated", "steps",
                  "kv_restacked_slots", "kv_prefix_reclaimed",
                  "kv_prefix_hits", "kv_prefix_lookups",
                  "kv_cow_copies", "mixed_batches",
                  "mixed_native_subbatches", "delta_restacks",
                  "delta_restack_bytes", "plan_cache_hits", "rejected",
                  "step_bytes_h2d", "step_bytes_d2h", "step_upload_us",
                  "step_exec_us", "step_download_us", "step_bank_us",
                  "step_kv_device"] {
            m.inc(k, 1);
        }
        for k in crate::delta::codec::KNOWN_EXEC_KINDS {
            m.inc(k, 1);
        }
        m.set("batch_occupancy", 0.5);
        m.set("kv_blocks_used", 1.0);
        m.set("kv_blocks_total", 2.0);
        m.set_tenant_gauge("queue_depth", "t0", 1.0);
        m.request_latency.observe(Duration::from_millis(3));
        m.ttft.observe(Duration::from_millis(1));
        m.step_latency.observe(Duration::from_millis(2));
        for line in m.exposition().lines() {
            let name = line.split(['{', ' ']).next().unwrap_or("");
            assert!(is_registered(name),
                    "exposition emits unregistered series {name:?}");
        }
    }

    /// One registry entry per known executable kind, no extras.
    #[test]
    fn exec_kind_counters_track_the_exec_table() {
        for k in crate::delta::codec::KNOWN_EXEC_KINDS {
            assert!(is_registered(&format!("bitdelta_{k}_total")),
                    "missing launch counter for exec kind {k}");
        }
    }
}
