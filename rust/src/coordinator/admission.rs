//! Admission control / backpressure.
//!
//! A serving system that accepts unboundedly simply moves the OOM from
//! the GPU to the host. Caps are enforced at enqueue time; callers see a
//! typed rejection they can surface as HTTP 429-equivalent.

/// Queue caps. `Default` is sized for the example workloads.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionPolicy {
    /// Max queued requests per tenant.
    pub per_tenant_cap: usize,
    /// Max queued requests across all tenants.
    pub total_cap: usize,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        Self { per_tenant_cap: 64, total_cap: 512 }
    }
}

/// Admission decision.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Verdict {
    Admit,
    Reject(&'static str),
}

impl AdmissionPolicy {
    pub fn admit(&self, tenant_queued: usize, total_queued: usize)
                 -> Verdict {
        if tenant_queued >= self.per_tenant_cap {
            Verdict::Reject("per-tenant queue full")
        } else if total_queued >= self.total_cap {
            Verdict::Reject("global queue full")
        } else {
            Verdict::Admit
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_under_caps() {
        let p = AdmissionPolicy { per_tenant_cap: 2, total_cap: 4 };
        assert_eq!(p.admit(0, 0), Verdict::Admit);
        assert_eq!(p.admit(1, 3), Verdict::Admit);
    }

    #[test]
    fn rejects_at_caps() {
        let p = AdmissionPolicy { per_tenant_cap: 2, total_cap: 4 };
        assert!(matches!(p.admit(2, 2), Verdict::Reject(_)));
        assert!(matches!(p.admit(0, 4), Verdict::Reject(_)));
    }
}
