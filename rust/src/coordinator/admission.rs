//! Admission control / backpressure.
//!
//! A serving system that accepts unboundedly simply moves the OOM from
//! the GPU to the host. Caps are enforced at enqueue time; callers see a
//! typed rejection they can surface as HTTP 429-equivalent.
//!
//! Two layers use the same policy machinery:
//!
//! * **per worker** — the engine's router consults
//!   [`AdmissionPolicy::admit`] against its own queue depths before
//!   enqueueing;
//! * **cluster front door** — [`AdmissionGate`] wraps the same policy in
//!   a thread-safe live-count tracker so the
//!   [`crate::cluster::ClusterHandle`] can cap *global* in-flight work
//!   (with per-tenant fairness) before a request is ever routed.
//!   Admission hands out an RAII [`AdmissionPermit`]; dropping the
//!   permit (when the response has been delivered or the caller gave
//!   up) releases the slot. Rejections are typed ([`AdmissionError`])
//!   so load generators can count shed load separately from real
//!   failures.
//!
//! The gate is deliberately time-free: no deadlines, no rate windows —
//! only live counts, released by RAII. That makes it clock-agnostic
//! (identical behavior under the `crate::sync::clock` virtual clock),
//! and the `raw-time` house-lint rule keeps wall-clock reads from
//! creeping in.

use std::collections::HashMap;

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{lock, Arc, Mutex};

/// Queue caps. `Default` is sized for the example workloads.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionPolicy {
    /// Max queued requests per tenant.
    pub per_tenant_cap: usize,
    /// Max queued requests across all tenants.
    pub total_cap: usize,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        Self { per_tenant_cap: 64, total_cap: 512 }
    }
}

/// Admission decision.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Verdict {
    Admit,
    Reject(&'static str),
}

/// Rejection reason for a breached per-tenant cap. Carried in
/// [`AdmissionError::reason`] and its `Display`; the metrics
/// exposition uses its own stable label vocabulary
/// (`reason="per_tenant"` / `reason="global"`), not these strings.
pub const REASON_TENANT: &str = "per-tenant queue full";
/// Rejection reason for a breached global cap (see [`REASON_TENANT`]
/// for how reasons relate to the metrics labels).
pub const REASON_GLOBAL: &str = "global queue full";

impl AdmissionPolicy {
    pub fn admit(&self, tenant_queued: usize, total_queued: usize)
                 -> Verdict {
        if tenant_queued >= self.per_tenant_cap {
            Verdict::Reject(REASON_TENANT)
        } else if total_queued >= self.total_cap {
            Verdict::Reject(REASON_GLOBAL)
        } else {
            Verdict::Admit
        }
    }

    /// A cluster-front-door policy from one `--admission-budget` number:
    /// `total` caps global in-flight work; the per-tenant cap is set to
    /// twice the fair share (`2·total/n_tenants`, floor 1) so a hot
    /// tenant can burst past uniform but can never starve the rest of
    /// the budget.
    pub fn for_budget(total: usize, n_tenants: usize) -> Self {
        let fair2 = (2 * total).div_ceil(n_tenants.max(1));
        Self {
            per_tenant_cap: fair2.clamp(1, total.max(1)),
            total_cap: total.max(1),
        }
    }
}

/// Typed admission rejection — the cluster front door's HTTP
/// 429-equivalent. Carried through `anyhow` so callers can
/// `downcast_ref::<AdmissionError>()` to distinguish shed load from
/// real request failures.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AdmissionError {
    pub tenant: String,
    /// One of [`REASON_TENANT`] / [`REASON_GLOBAL`].
    pub reason: &'static str,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request for tenant {:?} rejected: {}",
               self.tenant, self.reason)
    }
}

impl std::error::Error for AdmissionError {}

#[derive(Default)]
struct GateCounts {
    total: usize,
    per_tenant: HashMap<String, usize>,
}

struct GateInner {
    counts: Mutex<GateCounts>,
    rejected_tenant: AtomicU64,
    rejected_global: AtomicU64,
}

/// Thread-safe admission gate: an [`AdmissionPolicy`] applied to *live*
/// in-flight counts instead of queue snapshots. `try_admit` either
/// reserves a slot (returning the RAII [`AdmissionPermit`] that frees
/// it on drop) or returns the typed rejection. Check-and-increment is
/// atomic under one lock, so concurrent submitters can never
/// collectively overshoot the caps.
pub struct AdmissionGate {
    policy: AdmissionPolicy,
    inner: Arc<GateInner>,
}

impl AdmissionGate {
    pub fn new(policy: AdmissionPolicy) -> Self {
        Self {
            policy,
            inner: Arc::new(GateInner {
                counts: Mutex::new(GateCounts::default()),
                rejected_tenant: AtomicU64::new(0),
                rejected_global: AtomicU64::new(0),
            }),
        }
    }

    pub fn policy(&self) -> &AdmissionPolicy {
        &self.policy
    }

    /// Reserve one in-flight slot for `tenant`, or reject.
    pub fn try_admit(&self, tenant: &str)
                     -> Result<AdmissionPermit, AdmissionError> {
        let mut c = lock(&self.inner.counts);
        let tenant_now = c.per_tenant.get(tenant).copied().unwrap_or(0);
        match self.policy.admit(tenant_now, c.total) {
            Verdict::Admit => {
                c.total += 1;
                *c.per_tenant.entry(tenant.to_string()).or_default() += 1;
                Ok(AdmissionPermit {
                    inner: self.inner.clone(),
                    tenant: tenant.to_string(),
                })
            }
            Verdict::Reject(reason) => {
                if reason == REASON_TENANT {
                    self.inner.rejected_tenant
                        .fetch_add(1, Ordering::Relaxed);
                } else {
                    self.inner.rejected_global
                        .fetch_add(1, Ordering::Relaxed);
                }
                Err(AdmissionError { tenant: tenant.to_string(), reason })
            }
        }
    }

    /// Live in-flight count across all tenants.
    pub fn in_flight(&self) -> usize {
        lock(&self.inner.counts).total
    }

    /// `(per-tenant-cap, global-cap)` rejection counts so far.
    pub fn rejected(&self) -> (u64, u64) {
        (self.inner.rejected_tenant.load(Ordering::Relaxed),
         self.inner.rejected_global.load(Ordering::Relaxed))
    }
}

/// RAII reservation handed out by [`AdmissionGate::try_admit`]. Holding
/// it keeps one in-flight slot charged to the tenant; dropping it
/// releases the slot.
pub struct AdmissionPermit {
    inner: Arc<GateInner>,
    tenant: String,
}

impl Drop for AdmissionPermit {
    fn drop(&mut self) {
        let mut c = lock(&self.inner.counts);
        c.total = c.total.saturating_sub(1);
        if let Some(n) = c.per_tenant.get_mut(&self.tenant) {
            *n = n.saturating_sub(1);
            if *n == 0 {
                c.per_tenant.remove(&self.tenant);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_under_caps() {
        let p = AdmissionPolicy { per_tenant_cap: 2, total_cap: 4 };
        assert_eq!(p.admit(0, 0), Verdict::Admit);
        assert_eq!(p.admit(1, 3), Verdict::Admit);
    }

    #[test]
    fn rejects_at_caps() {
        let p = AdmissionPolicy { per_tenant_cap: 2, total_cap: 4 };
        assert!(matches!(p.admit(2, 2), Verdict::Reject(_)));
        assert!(matches!(p.admit(0, 4), Verdict::Reject(_)));
    }

    #[test]
    fn budget_policy_fair_share() {
        let p = AdmissionPolicy::for_budget(64, 8);
        assert_eq!(p.total_cap, 64);
        assert_eq!(p.per_tenant_cap, 16);   // 2 * 64 / 8
        // few tenants: per-tenant cap never exceeds the global budget
        let p = AdmissionPolicy::for_budget(4, 1);
        assert_eq!(p.per_tenant_cap, 4);
        // degenerate budgets stay usable
        let p = AdmissionPolicy::for_budget(0, 0);
        assert!(p.total_cap >= 1 && p.per_tenant_cap >= 1);
    }

    #[test]
    fn gate_caps_live_in_flight_and_releases_on_drop() {
        let g = AdmissionGate::new(
            AdmissionPolicy { per_tenant_cap: 2, total_cap: 3 });
        let a1 = g.try_admit("a").unwrap();
        let _a2 = g.try_admit("a").unwrap();
        // per-tenant cap hit
        let e = g.try_admit("a").unwrap_err();
        assert_eq!(e.reason, REASON_TENANT);
        assert_eq!(e.tenant, "a");
        // other tenants still fit under the global cap
        let _b1 = g.try_admit("b").unwrap();
        assert_eq!(g.in_flight(), 3);
        let e = g.try_admit("c").unwrap_err();
        assert_eq!(e.reason, REASON_GLOBAL);
        // releasing a permit frees exactly one slot
        drop(a1);
        assert_eq!(g.in_flight(), 2);
        let _c1 = g.try_admit("c").unwrap();
        assert_eq!(g.rejected(), (1, 1));
    }

    #[test]
    fn admission_error_downcasts_through_anyhow() {
        let g = AdmissionGate::new(
            AdmissionPolicy { per_tenant_cap: 1, total_cap: 1 });
        let _p = g.try_admit("t").unwrap();
        let err: anyhow::Error = g.try_admit("t").unwrap_err().into();
        let ae = err.downcast_ref::<AdmissionError>()
            .expect("typed rejection survives anyhow");
        assert_eq!(ae.reason, REASON_TENANT);
        assert!(err.to_string().contains("rejected"), "{err}");
    }

    #[test]
    fn gate_is_safe_across_threads() {
        let g = std::sync::Arc::new(AdmissionGate::new(
            AdmissionPolicy { per_tenant_cap: 64, total_cap: 10 }));
        // permits are parked in shared storage for the whole run, so no
        // slot is ever released: exactly total_cap admissions can
        // succeed across all threads, however they interleave
        let held = std::sync::Arc::new(Mutex::new(Vec::new()));
        let mut joins = Vec::new();
        for _ in 0..4 {
            let g = g.clone();
            let held = held.clone();
            joins.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    if let Ok(p) = g.try_admit("t") {
                        held.lock().unwrap().push(p);
                    }
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(held.lock().unwrap().len(), 10);
        assert_eq!(g.in_flight(), 10);
        held.lock().unwrap().clear();
        assert_eq!(g.in_flight(), 0);
    }
}
