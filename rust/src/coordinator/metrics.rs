//! Serving metrics: counters, gauges, and latency histograms with a
//! Prometheus-style text exposition.

use std::collections::BTreeMap;
use std::time::Duration;

/// Fixed-bucket latency histogram (µs buckets, log-spaced).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Upper bounds in microseconds.
    bounds: Vec<u64>,
    counts: Vec<u64>,
    pub count: u64,
    pub sum_us: u64,
    pub max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        // 10µs .. ~100s, half-decade spacing
        let bounds: Vec<u64> = (0..15)
            .map(|i| (10.0f64 * 10f64.powf(i as f64 / 2.0)) as u64)
            .collect();
        let n = bounds.len();
        Self { bounds, counts: vec![0; n + 1], count: 0, sum_us: 0,
               max_us: 0 }
    }
}

impl Histogram {
    pub fn observe(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = self.bounds.iter().position(|&b| us <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Cumulative Prometheus `_bucket{le=...}` lines (the full
    /// histogram shape, not just summary quantiles).
    pub fn bucket_exposition(&self, name: &str) -> String {
        let mut out = String::new();
        let mut acc = 0u64;
        for (i, &b) in self.bounds.iter().enumerate() {
            acc += self.counts[i];
            out.push_str(&format!(
                "bitdelta_{name}_us_bucket{{le=\"{b}\"}} {acc}\n"));
        }
        out.push_str(&format!(
            "bitdelta_{name}_us_bucket{{le=\"+Inf\"}} {}\n", self.count));
        out
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.max_us
                };
            }
        }
        self.max_us
    }
}

/// Engine-wide metrics registry.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub counters: BTreeMap<&'static str, u64>,
    pub gauges: BTreeMap<&'static str, f64>,
    /// Per-tenant gauges, `metric name -> tenant -> value` (the label
    /// syntax is composed at exposition time, so steady-state updates
    /// never allocate).
    pub tenant_gauges: BTreeMap<&'static str, BTreeMap<String, f64>>,
    /// request end-to-end latency
    pub request_latency: Histogram,
    /// time-to-first-token
    pub ttft: Histogram,
    /// one engine decode step (whole batch)
    pub step_latency: Histogram,
}

impl Metrics {
    pub fn inc(&mut self, name: &'static str, by: u64) {
        *self.counters.entry(name).or_insert(0) += by;
    }

    pub fn set(&mut self, name: &'static str, v: f64) {
        self.gauges.insert(name, v);
    }

    /// Set a gauge labeled by tenant, exported as
    /// `bitdelta_<name>{tenant="<tenant>"}`. Called every engine step,
    /// so the tenant key is only allocated the first time it is seen.
    pub fn set_tenant_gauge(&mut self, name: &'static str, tenant: &str,
                            v: f64) {
        let per = self.tenant_gauges.entry(name).or_default();
        match per.get_mut(tenant) {
            Some(slot) => *slot = v,
            None => {
                per.insert(tenant.to_string(), v);
            }
        }
    }

    /// Prometheus-ish text dump.
    pub fn exposition(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("bitdelta_{k}_total {v}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("bitdelta_{k} {v}\n"));
        }
        for (name, per) in &self.tenant_gauges {
            for (tenant, v) in per {
                out.push_str(&format!(
                    "bitdelta_{name}{{tenant=\"{tenant}\"}} {v}\n"));
            }
        }
        for (name, h) in [("request_latency", &self.request_latency),
                          ("ttft", &self.ttft),
                          ("step_latency", &self.step_latency)] {
            out.push_str(&format!(
                "bitdelta_{name}_us_mean {:.1}\n\
                 bitdelta_{name}_us_p50 {}\n\
                 bitdelta_{name}_us_p99 {}\n\
                 bitdelta_{name}_count {}\n",
                h.mean_us(), h.quantile_us(0.5), h.quantile_us(0.99),
                h.count));
        }
        // the full TTFT shape: first-token latency is the user-facing
        // SLO, so it gets real buckets, not just summary quantiles
        out.push_str(&self.ttft.bucket_exposition("ttft"));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_and_quantiles() {
        let mut h = Histogram::default();
        for ms in [1u64, 2, 3, 4, 100] {
            h.observe(Duration::from_millis(ms));
        }
        assert_eq!(h.count, 5);
        assert!(h.mean_us() > 20_000.0);
        assert!(h.quantile_us(0.5) >= 1_000);
        assert!(h.quantile_us(0.99) >= 100_000 / 2);
    }

    #[test]
    fn exposition_contains_counters() {
        let mut m = Metrics::default();
        m.inc("requests", 3);
        m.set("batch_occupancy", 0.75);
        let text = m.exposition();
        assert!(text.contains("bitdelta_requests_total 3"));
        assert!(text.contains("bitdelta_batch_occupancy 0.75"));
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::default();
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.quantile_us(0.99), 0);
    }

    #[test]
    fn tenant_gauges_are_exported() {
        let mut m = Metrics::default();
        m.set_tenant_gauge("queue_depth", "sim-s-chat", 3.0);
        m.set_tenant_gauge("queue_depth", "sim-s-math", 0.0);
        let text = m.exposition();
        assert!(text.contains(
            "bitdelta_queue_depth{tenant=\"sim-s-chat\"} 3"), "{text}");
        assert!(text.contains(
            "bitdelta_queue_depth{tenant=\"sim-s-math\"} 0"), "{text}");
    }

    #[test]
    fn ttft_buckets_are_cumulative_and_exported() {
        let mut m = Metrics::default();
        for ms in [1u64, 1, 50] {
            m.ttft.observe(Duration::from_millis(ms));
        }
        let text = m.exposition();
        assert!(text.contains("bitdelta_ttft_us_bucket{le=\"+Inf\"} 3"),
                "{text}");
        // cumulative counts never decrease across bucket bounds
        let counts: Vec<u64> = text.lines()
            .filter(|l| l.starts_with("bitdelta_ttft_us_bucket"))
            .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
            .collect();
        assert!(counts.windows(2).all(|w| w[0] <= w[1]), "{counts:?}");
        assert_eq!(*counts.last().unwrap(), 3);
    }
}
