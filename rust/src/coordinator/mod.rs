//! The multi-tenant coordinator — the L3 systems contribution.
//!
//! BitDelta's serving story (paper §3.3, §4.3): one high-precision base
//! model stays resident; per-tenant 1-bit deltas are hot-swapped in and
//! batched through the decomposed forward (Eq. 6). The pieces:
//!
//! * [`router`]      — tenant registry + per-tenant FIFO queues.
//! * [`batcher`]     — continuous batching: assemble each decode step's
//!   batch across tenants, track composition changes (which trigger
//!   delta re-stacking), admit waiting requests into free slots.
//! * [`deltastore`]  — delta residency manager: loads `.bdd` files,
//!   LRU-evicts against a memory budget (the "hot-swap" half of the
//!   paper's storage story).
//! * [`admission`]   — queue caps + backpressure policy, reused at two
//!   levels: per-worker enqueue caps, and the cluster front door's
//!   thread-safe [`admission::AdmissionGate`] (global in-flight budget
//!   with typed rejections).
//! * [`metrics`]     — counters/latency histograms, text exposition.

pub mod admission;
pub mod batcher;
pub mod deltastore;
pub mod metric_names;
pub mod metrics;
pub mod router;
pub mod workload;
