//! Delta residency manager — the "hot-swap" half of BitDelta serving.
//!
//! Deltas live on disk as `.bdd` files (>10× smaller than the dense
//! fine-tune, so they load >10× faster — the paper's storage claim).
//! This store loads them on demand, pins the ones referenced by active
//! sequences, and LRU-evicts unpinned deltas against a byte budget,
//! modelling the bounded "GPU cache" the kernel streams deltas from.

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::store::delta_file::DeltaFile;

/// Load/evict statistics (surfaced in metrics and the serving report).
#[derive(Debug, Default, Clone)]
pub struct DeltaStoreStats {
    pub loads: u64,
    pub hits: u64,
    pub evictions: u64,
    pub load_seconds_total: f64,
    pub bytes_loaded_total: u64,
}

struct Entry {
    delta: Rc<DeltaFile>,
    bytes: usize,
    last_used: u64,
    pins: usize,
}

/// LRU-with-pinning delta cache.
pub struct DeltaStore {
    cfg: ModelConfig,
    paths: HashMap<String, PathBuf>,
    resident: HashMap<String, Entry>,
    budget_bytes: usize,
    clock: u64,
    pub stats: DeltaStoreStats,
}

impl DeltaStore {
    pub fn new(cfg: ModelConfig, budget_bytes: usize) -> Self {
        Self { cfg, paths: HashMap::new(), resident: HashMap::new(),
               budget_bytes, clock: 0, stats: DeltaStoreStats::default() }
    }

    /// Register a tenant's delta file (not loaded yet).
    pub fn register(&mut self, tenant: impl Into<String>, path: PathBuf) {
        self.paths.insert(tenant.into(), path);
    }

    pub fn resident_bytes(&self) -> usize {
        self.resident.values().map(|e| e.bytes).sum()
    }

    pub fn is_resident(&self, tenant: &str) -> bool {
        self.resident.contains_key(tenant)
    }

    /// Fetch a tenant's delta, loading (and possibly evicting) as needed.
    pub fn fetch(&mut self, tenant: &str) -> Result<Rc<DeltaFile>> {
        self.clock += 1;
        if let Some(e) = self.resident.get_mut(tenant) {
            e.last_used = self.clock;
            self.stats.hits += 1;
            return Ok(e.delta.clone());
        }
        let path = self.paths.get(tenant)
            .with_context(|| format!("tenant {tenant} not registered"))?
            .clone();
        let t0 = Instant::now();
        let delta = DeltaFile::load(&path, &self.cfg)
            .with_context(|| format!("loading delta for {tenant}"))?;
        let bytes = delta.delta_bytes();
        self.stats.loads += 1;
        self.stats.load_seconds_total += t0.elapsed().as_secs_f64();
        self.stats.bytes_loaded_total += bytes as u64;

        self.make_room(bytes)?;
        let rc = Rc::new(delta);
        self.resident.insert(tenant.to_string(), Entry {
            delta: rc.clone(), bytes, last_used: self.clock, pins: 0,
        });
        Ok(rc)
    }

    /// Pin a resident delta (active in the current batch — not evictable).
    pub fn pin(&mut self, tenant: &str) {
        if let Some(e) = self.resident.get_mut(tenant) {
            e.pins += 1;
        }
    }

    pub fn unpin(&mut self, tenant: &str) {
        if let Some(e) = self.resident.get_mut(tenant) {
            e.pins = e.pins.saturating_sub(1);
        }
    }

    fn make_room(&mut self, incoming: usize) -> Result<()> {
        if incoming > self.budget_bytes {
            bail!("delta ({incoming} B) exceeds the residency budget \
({} B)", self.budget_bytes);
        }
        while self.resident_bytes() + incoming > self.budget_bytes {
            // LRU over unpinned entries
            let victim = self.resident.iter()
                .filter(|(_, e)| e.pins == 0)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    self.resident.remove(&k);
                    self.stats.evictions += 1;
                }
                None => bail!("residency budget exhausted and every delta \
is pinned (budget {} B, need {incoming} B more)", self.budget_bytes),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::packing::pack_signs;
    use crate::store::bdw::{write_bdw, RawTensor};
    use crate::store::delta_file::{DeltaFile, MaskLevel};

    fn tiny_cfg() -> ModelConfig {
        ModelConfig { name: "t".into(), vocab_size: 16, d_model: 8,
                      n_layers: 1, n_heads: 2, d_ff: 16, max_seq_len: 8,
                      rope_theta: 1e4, norm_eps: 1e-5 }
    }

    fn write_delta(cfg: &ModelConfig, path: &std::path::Path, seed: f32) {
        let mut bits = HashMap::new();
        let mut scales = Vec::new();
        for (i, name) in cfg.linear_names().iter().enumerate() {
            let (n, m) = cfg.linear_shape(name);
            let vals: Vec<f32> = (0..n * m)
                .map(|j| ((j as f32 + seed) * 0.7).sin()).collect();
            bits.insert(name.clone(), pack_signs(&vals, m));
            scales.push(0.01 * (i + 1) as f32);
        }
        let mut extras = HashMap::new();
        for name in cfg.nonlinear_names() {
            let shape = cfg.param_shape(&name);
            let n: usize = shape.iter().product();
            extras.insert(name, RawTensor::f32(shape, &vec![seed; n]));
        }
        let d = DeltaFile { levels: vec![MaskLevel { bits, scales }],
                            extras };
        write_bdw(path, &d.to_bdw(cfg)).unwrap();
    }

    fn store_with(n: usize, budget: usize) -> (DeltaStore, Vec<String>) {
        let cfg = tiny_cfg();
        let dir = std::env::temp_dir()
            .join(format!("deltastore_test_{n}_{budget}"));
        std::fs::create_dir_all(&dir).unwrap();
        let mut store = DeltaStore::new(cfg.clone(), budget);
        let mut names = Vec::new();
        for i in 0..n {
            let p = dir.join(format!("t{i}.bdd"));
            write_delta(&cfg, &p, i as f32);
            store.register(format!("t{i}"), p);
            names.push(format!("t{i}"));
        }
        (store, names)
    }

    #[test]
    fn fetch_loads_then_hits() {
        let (mut s, names) = store_with(2, usize::MAX / 2);
        s.fetch(&names[0]).unwrap();
        s.fetch(&names[0]).unwrap();
        assert_eq!(s.stats.loads, 1);
        assert_eq!(s.stats.hits, 1);
    }

    #[test]
    fn lru_evicts_oldest() {
        let (mut s, names) = store_with(3, 0);
        // budget 0 is too small for anything -> use one-delta budget
        let one = {
            let (mut probe, n2) = store_with(1, usize::MAX / 2);
            probe.fetch(&n2[0]).unwrap();
            probe.resident_bytes()
        };
        s.budget_bytes = one * 2 + 8;
        s.fetch(&names[0]).unwrap();
        s.fetch(&names[1]).unwrap();
        s.fetch(&names[2]).unwrap();   // evicts t0
        assert!(!s.is_resident(&names[0]));
        assert!(s.is_resident(&names[2]));
        assert_eq!(s.stats.evictions, 1);
    }

    #[test]
    fn pinned_never_evicted() {
        let (mut s, names) = store_with(3, 0);
        let one = {
            let (mut probe, n2) = store_with(1, usize::MAX / 2);
            probe.fetch(&n2[0]).unwrap();
            probe.resident_bytes()
        };
        s.budget_bytes = one * 2 + 8;
        s.fetch(&names[0]).unwrap();
        s.pin(&names[0]);
        s.fetch(&names[1]).unwrap();
        s.fetch(&names[2]).unwrap();   // must evict t1, not pinned t0
        assert!(s.is_resident(&names[0]));
        assert!(!s.is_resident(&names[1]));
    }

    #[test]
    fn over_budget_delta_rejected() {
        let (mut s, names) = store_with(1, 4);
        assert!(s.fetch(&names[0]).is_err());
    }
}
