//! Delta residency manager — the "hot-swap" half of BitDelta serving,
//! generalized over [`crate::delta::codec::DeltaCodec`] payloads.
//!
//! Deltas live on disk in whatever format their codec reads (packed
//! 1-bit `.bdd`, low-rank factor files, or the dense fine-tune itself
//! for the naive baseline). The store loads them on demand through the
//! tenant's codec, pins the ones referenced by active sequences, and
//! LRU-evicts unpinned payloads against a byte budget, modelling the
//! bounded "GPU cache" the kernel streams deltas from. Bytes are
//! accounted **per codec** ([`DeltaStoreStats::by_codec`]) so a mixed
//! fleet can see exactly which format is eating the budget.

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::delta::codec::{DeltaCodec, LoadCtx, Model, Payload};

/// Per-codec load/eviction byte accounting.
#[derive(Debug, Default, Clone)]
pub struct CodecStats {
    pub loads: u64,
    pub evictions: u64,
    pub bytes_loaded: u64,
}

/// Load/evict statistics (surfaced in metrics and the serving report).
#[derive(Debug, Default, Clone)]
pub struct DeltaStoreStats {
    pub loads: u64,
    pub hits: u64,
    pub evictions: u64,
    pub load_seconds_total: f64,
    pub bytes_loaded_total: u64,
    /// Keyed by codec name.
    pub by_codec: HashMap<String, CodecStats>,
}

struct Entry {
    payload: Rc<dyn Payload>,
    codec_name: &'static str,
    bytes: usize,
    last_used: u64,
    pins: usize,
}

struct Registration {
    codec: Rc<dyn DeltaCodec>,
    path: PathBuf,
    /// Fidelity tier: mask levels to load (0 = every level in the
    /// artifact). Only multi-level codecs honor it.
    levels: usize,
}

/// LRU-with-pinning payload cache.
pub struct DeltaStore {
    cfg: ModelConfig,
    /// Base model for codecs whose `load` needs it (e.g. `svd`).
    base: Option<Rc<Model>>,
    registered: HashMap<String, Registration>,
    resident: HashMap<String, Entry>,
    /// Pins taken before the payload is resident (the engine pins at
    /// admission, which may precede the first fetch); applied on load
    /// so an early pin is never silently dropped.
    pending_pins: HashMap<String, usize>,
    budget_bytes: usize,
    clock: u64,
    pub stats: DeltaStoreStats,
}

impl DeltaStore {
    pub fn new(cfg: ModelConfig, budget_bytes: usize) -> Self {
        Self { cfg, base: None, registered: HashMap::new(),
               resident: HashMap::new(), pending_pins: HashMap::new(),
               budget_bytes, clock: 0,
               stats: DeltaStoreStats::default() }
    }

    /// Provide the base model to load-time-compressing codecs.
    pub fn set_base(&mut self, base: Rc<Model>) {
        self.base = Some(base);
    }

    /// Register a tenant's artifact under its codec (not loaded yet).
    /// `levels` is the tenant's fidelity tier (0 = every level the
    /// artifact carries) — it scales what the payload's
    /// `resident_bytes` charge against the budget.
    pub fn register(&mut self, tenant: impl Into<String>,
                    codec: Rc<dyn DeltaCodec>, path: PathBuf,
                    levels: usize) {
        self.registered.insert(tenant.into(),
                               Registration { codec, path, levels });
    }

    pub fn resident_bytes(&self) -> usize {
        self.resident.values().map(|e| e.bytes).sum()
    }

    /// Resident bytes broken down by codec name.
    pub fn resident_bytes_by_codec(&self) -> HashMap<&'static str, usize> {
        let mut out: HashMap<&'static str, usize> = HashMap::new();
        for e in self.resident.values() {
            *out.entry(e.codec_name).or_default() += e.bytes;
        }
        out
    }

    pub fn is_resident(&self, tenant: &str) -> bool {
        self.resident.contains_key(tenant)
    }

    /// Fetch a tenant's payload, loading (and possibly evicting) as
    /// needed.
    pub fn fetch(&mut self, tenant: &str) -> Result<Rc<dyn Payload>> {
        self.clock += 1;
        if let Some(e) = self.resident.get_mut(tenant) {
            e.last_used = self.clock;
            self.stats.hits += 1;
            return Ok(e.payload.clone());
        }
        let (codec, path, levels) = {
            let r = self.registered.get(tenant).with_context(
                || format!("tenant {tenant} has no registered delta \
artifact (codec lacks one for this tenant?)"))?;
            (r.codec.clone(), r.path.clone(), r.levels)
        };
        let t0 = Instant::now();
        let payload = {
            let ctx = LoadCtx { cfg: &self.cfg,
                                base: self.base.as_deref(),
                                levels };
            codec.load(&path, &ctx).with_context(
                || format!("loading {} payload for {tenant}",
                           codec.name()))?
        };
        let bytes = payload.resident_bytes();
        self.stats.loads += 1;
        self.stats.load_seconds_total += t0.elapsed().as_secs_f64();
        self.stats.bytes_loaded_total += bytes as u64;
        let per = self.stats.by_codec.entry(codec.name().to_string())
            .or_default();
        per.loads += 1;
        per.bytes_loaded += bytes as u64;

        self.make_room(bytes)?;
        let pins = self.pending_pins.remove(tenant).unwrap_or(0);
        self.resident.insert(tenant.to_string(), Entry {
            payload: payload.clone(), codec_name: codec.name(),
            bytes, last_used: self.clock, pins,
        });
        Ok(payload)
    }

    /// Pin a tenant's payload (active in the current batch — not
    /// evictable). Pinning before the first fetch is honored: the pin
    /// is applied when the payload loads.
    pub fn pin(&mut self, tenant: &str) {
        if let Some(e) = self.resident.get_mut(tenant) {
            e.pins += 1;
        } else {
            *self.pending_pins.entry(tenant.to_string()).or_default() += 1;
        }
    }

    pub fn unpin(&mut self, tenant: &str) {
        if let Some(e) = self.resident.get_mut(tenant) {
            e.pins = e.pins.saturating_sub(1);
        } else if let Some(p) = self.pending_pins.get_mut(tenant) {
            *p = p.saturating_sub(1);
            if *p == 0 {
                self.pending_pins.remove(tenant);
            }
        }
    }

    fn make_room(&mut self, incoming: usize) -> Result<()> {
        if incoming > self.budget_bytes {
            bail!("delta ({incoming} B) exceeds the residency budget \
({} B)", self.budget_bytes);
        }
        while self.resident_bytes() + incoming > self.budget_bytes {
            // LRU over unpinned entries
            let victim = self.resident.iter()
                .filter(|(_, e)| e.pins == 0)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    // lint: allow(unwrap, victim key was drawn from
                    // this map under the same &mut borrow)
                    let e = self.resident.remove(&k).unwrap();
                    self.stats.evictions += 1;
                    self.stats.by_codec
                        .entry(e.codec_name.to_string())
                        .or_default().evictions += 1;
                }
                None => bail!("residency budget exhausted and every delta \
is pinned (budget {} B, need {incoming} B more)", self.budget_bytes),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::codecs::bitdelta::BitDeltaCodec;
    use crate::delta::packing::pack_signs;
    use crate::store::bdw::{write_bdw, RawTensor};
    use crate::store::delta_file::{DeltaFile, MaskLevel};

    fn tiny_cfg() -> ModelConfig {
        ModelConfig { name: "t".into(), vocab_size: 16, d_model: 8,
                      n_layers: 1, n_heads: 2, d_ff: 16, max_seq_len: 8,
                      rope_theta: 1e4, norm_eps: 1e-5 }
    }

    fn write_delta(cfg: &ModelConfig, path: &std::path::Path, seed: f32) {
        let mut bits = HashMap::new();
        let mut scales = Vec::new();
        for (i, name) in cfg.linear_names().iter().enumerate() {
            let (n, m) = cfg.linear_shape(name);
            let vals: Vec<f32> = (0..n * m)
                .map(|j| ((j as f32 + seed) * 0.7).sin()).collect();
            bits.insert(name.clone(), pack_signs(&vals, m));
            scales.push(0.01 * (i + 1) as f32);
        }
        let mut extras = HashMap::new();
        for name in cfg.nonlinear_names() {
            let shape = cfg.param_shape(&name);
            let n: usize = shape.iter().product();
            extras.insert(name, RawTensor::f32(shape, &vec![seed; n]));
        }
        let d = DeltaFile { levels: vec![MaskLevel { bits, scales }],
                            extras };
        write_bdw(path, &d.to_bdw(cfg)).unwrap();
    }

    fn store_with(n: usize, budget: usize) -> (DeltaStore, Vec<String>) {
        let cfg = tiny_cfg();
        let dir = std::env::temp_dir()
            .join(format!("deltastore_test_{n}_{budget}"));
        std::fs::create_dir_all(&dir).unwrap();
        let mut store = DeltaStore::new(cfg.clone(), budget);
        let codec: Rc<dyn DeltaCodec> = Rc::new(BitDeltaCodec);
        let mut names = Vec::new();
        for i in 0..n {
            let p = dir.join(format!("t{i}.bdd"));
            write_delta(&cfg, &p, i as f32);
            store.register(format!("t{i}"), codec.clone(), p, 0);
            names.push(format!("t{i}"));
        }
        (store, names)
    }

    /// Resident bytes of exactly one delta (probe store).
    fn one_delta_bytes() -> usize {
        let (mut probe, n) = store_with(1, usize::MAX / 2);
        probe.fetch(&n[0]).unwrap();
        probe.resident_bytes()
    }

    #[test]
    fn fetch_loads_then_hits() {
        let (mut s, names) = store_with(2, usize::MAX / 2);
        s.fetch(&names[0]).unwrap();
        s.fetch(&names[0]).unwrap();
        assert_eq!(s.stats.loads, 1);
        assert_eq!(s.stats.hits, 1);
        // per-codec accounting mirrors the totals
        let per = &s.stats.by_codec["bitdelta"];
        assert_eq!(per.loads, 1);
        assert_eq!(per.bytes_loaded, s.stats.bytes_loaded_total);
    }

    #[test]
    fn lru_evicts_oldest() {
        let (mut s, names) = store_with(3, 0);
        s.budget_bytes = one_delta_bytes() * 2 + 8;
        s.fetch(&names[0]).unwrap();
        s.fetch(&names[1]).unwrap();
        s.fetch(&names[2]).unwrap();   // evicts t0
        assert!(!s.is_resident(&names[0]));
        assert!(s.is_resident(&names[2]));
        assert_eq!(s.stats.evictions, 1);
        assert_eq!(s.stats.by_codec["bitdelta"].evictions, 1);
    }

    #[test]
    fn pinned_never_evicted() {
        let (mut s, names) = store_with(3, 0);
        s.budget_bytes = one_delta_bytes() * 2 + 8;
        s.fetch(&names[0]).unwrap();
        s.pin(&names[0]);
        s.fetch(&names[1]).unwrap();
        s.fetch(&names[2]).unwrap();   // must evict t1, not pinned t0
        assert!(s.is_resident(&names[0]));
        assert!(!s.is_resident(&names[1]));
    }

    #[test]
    fn all_pinned_under_pressure_errors_not_corrupts() {
        // Budget for exactly two deltas, both pinned: the third fetch
        // must fail with the "every delta is pinned" diagnosis, leave
        // the pinned entries resident, and count the load that couldn't
        // be placed.
        let (mut s, names) = store_with(3, 0);
        let one = one_delta_bytes();
        s.budget_bytes = one * 2 + 8;
        s.fetch(&names[0]).unwrap();
        s.pin(&names[0]);
        s.fetch(&names[1]).unwrap();
        s.pin(&names[1]);
        let err = s.fetch(&names[2]).unwrap_err().to_string();
        assert!(err.contains("pinned"), "unexpected error: {err}");
        assert!(s.is_resident(&names[0]) && s.is_resident(&names[1]));
        assert!(!s.is_resident(&names[2]));
        assert_eq!(s.stats.evictions, 0);
        // unpinning frees the LRU victim and the fetch now succeeds
        s.unpin(&names[0]);
        s.fetch(&names[2]).unwrap();
        assert!(!s.is_resident(&names[0]));
        assert!(s.is_resident(&names[2]));
        assert_eq!(s.stats.evictions, 1);
    }

    #[test]
    fn pin_before_first_fetch_is_honored() {
        // The engine pins at admission, which can precede the first
        // fetch — that pin must survive and protect the entry.
        let (mut s, names) = store_with(2, 0);
        s.budget_bytes = one_delta_bytes() + 8;
        s.pin(&names[0]);               // not resident yet
        s.fetch(&names[0]).unwrap();    // pending pin applied on load
        // t1 cannot displace the pinned t0
        assert!(s.fetch(&names[1]).is_err());
        s.unpin(&names[0]);
        s.fetch(&names[1]).unwrap();
        assert!(!s.is_resident(&names[0]));
        // pin+unpin with no fetch in between leaves no stale state
        s.pin("ghost");
        s.unpin("ghost");
        s.fetch(&names[1]).unwrap();    // hit, nothing odd
    }

    #[test]
    fn double_pin_requires_double_unpin() {
        let (mut s, names) = store_with(2, 0);
        s.budget_bytes = one_delta_bytes() + 8;
        s.fetch(&names[0]).unwrap();
        s.pin(&names[0]);
        s.pin(&names[0]);
        s.unpin(&names[0]);
        // still pinned once -> t1 cannot displace it
        assert!(s.fetch(&names[1]).is_err());
        s.unpin(&names[0]);
        s.fetch(&names[1]).unwrap();
        assert!(!s.is_resident(&names[0]));
    }

    #[test]
    fn unpin_of_absent_tenant_is_noop() {
        let (mut s, names) = store_with(1, usize::MAX / 2);
        s.unpin("ghost");
        s.unpin(&names[0]);             // not resident yet: no-op
        s.fetch(&names[0]).unwrap();
        assert_eq!(s.stats.loads, 1);
    }

    #[test]
    fn stats_counters_exact_over_mixed_sequence() {
        // 3 tenants, room for two: a scripted fetch/pin sequence with
        // every counter asserted exactly.
        let (mut s, names) = store_with(3, 0);
        let one = one_delta_bytes();
        s.budget_bytes = one * 2 + 8;

        s.fetch(&names[0]).unwrap();             // load #1
        s.fetch(&names[0]).unwrap();             // hit  #1
        s.fetch(&names[1]).unwrap();             // load #2
        s.pin(&names[1]);
        s.fetch(&names[2]).unwrap();             // load #3, evicts t0
        s.fetch(&names[1]).unwrap();             // hit  #2 (pinned)
        s.fetch(&names[0]).unwrap();             // load #4, evicts t2

        assert_eq!(s.stats.loads, 4);
        assert_eq!(s.stats.hits, 2);
        assert_eq!(s.stats.evictions, 2);
        assert_eq!(s.stats.bytes_loaded_total, 4 * one as u64);
        assert_eq!(s.resident_bytes(), 2 * one);
        let per = &s.stats.by_codec["bitdelta"];
        assert_eq!((per.loads, per.evictions, per.bytes_loaded),
                   (4, 2, 4 * one as u64));
    }

    #[test]
    fn fidelity_tier_scales_resident_bytes() {
        // one 3-level artifact registered at tiers 1 and 3: the tier-1
        // payload must charge fewer bytes against the budget, and the
        // gap must be exactly the two dropped mask levels.
        use crate::tensor::Tensor;

        let cfg = tiny_cfg();
        let model = |seed: u64| -> HashMap<String, RawTensor> {
            cfg.param_names().into_iter().enumerate().map(|(i, n)| {
                let shape = cfg.param_shape(&n);
                let t = Tensor::randn(shape.clone(), seed + i as u64);
                (n, RawTensor::f32(shape, t.data()))
            }).collect()
        };
        let base = model(31);
        let fine = model(32);
        let d = crate::delta::iterative::compress_iterative(
            &cfg, &base, &fine, 3).unwrap();
        let dir = std::env::temp_dir().join("deltastore_test_levels");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("multi.bdd");
        write_bdw(&p, &d.to_bdw(&cfg)).unwrap();

        let codec: Rc<dyn DeltaCodec> = Rc::new(BitDeltaCodec);
        let mut s = DeltaStore::new(cfg.clone(), usize::MAX / 2);
        s.register("tier1", codec.clone(), p.clone(), 1);
        s.register("tier3", codec.clone(), p, 3);
        let b1 = s.fetch("tier1").unwrap().resident_bytes();
        let b3 = s.fetch("tier3").unwrap().resident_bytes();
        assert!(b1 < b3, "tier1 {b1} !< tier3 {b3}");
        let per_level: usize = cfg.linear_names().iter().map(|n| {
            let (rows, mp) = cfg.packed_shape(n);
            rows * mp
        }).sum::<usize>() + cfg.linear_names().len() * 4;
        assert_eq!(b3 - b1, 2 * per_level);
        assert_eq!(s.resident_bytes(), b1 + b3);
    }

    #[test]
    fn over_budget_delta_rejected() {
        let (mut s, names) = store_with(1, 4);
        assert!(s.fetch(&names[0]).is_err());
    }

    #[test]
    fn unregistered_tenant_has_clear_error() {
        let (mut s, _) = store_with(1, usize::MAX / 2);
        let e = s.fetch("nobody").unwrap_err().to_string();
        assert!(e.contains("no registered delta artifact"), "{e}");
    }
}
