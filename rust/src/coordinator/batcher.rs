//! Continuous batcher: fixed-width slot table + composition tracking.
//!
//! Each decode step runs one batched executable over up to `B` slots.
//! Sequences enter a free slot after prefill and leave on completion;
//! the *composition* (which tenant occupies which slot) determines the
//! stacked delta arguments, so the batcher exposes a composition id the
//! engine uses to re-assemble [`crate::runtime::StackedArgs`] (via the
//! slot tenants' delta codecs) only when it actually changed — the
//! hot-swap fast path.

use std::time::Instant;

use crate::kvcache::SeqKv;
use crate::serving::request::QueuedRequest;

/// One in-flight sequence.
pub struct ActiveSeq {
    pub req: QueuedRequest,
    pub tenant: String,
    pub rope_scale: f32,
    /// KV backing: paged block table, or dense slab under
    /// `EngineConfig::kv_slab_fallback`.
    pub kv: SeqKv,
    pub prompt: Vec<i32>,
    /// Prompt tokens already consumed (== kv.pos() during prefill).
    pub prompt_pos: usize,
    pub generated: Vec<i32>,
    /// Next token to feed to the decode step.
    pub next_token: i32,
    pub started: Instant,
    pub first_token_at: Option<Instant>,
}

impl ActiveSeq {
    pub fn in_prefill(&self) -> bool {
        self.prompt_pos < self.prompt.len()
    }

    pub fn done(&self, max_seq: usize) -> bool {
        self.generated.len() >= self.req.request.max_new_tokens
            || self.kv.pos() + 1 >= max_seq
    }
}

/// Slot table + composition tracking.
pub struct Batcher {
    slots: Vec<Option<ActiveSeq>>,
    composition_id: u64,
    pub admitted: u64,
    pub completed: u64,
}

impl Batcher {
    pub fn new(batch: usize) -> Self {
        Self {
            slots: (0..batch).map(|_| None).collect(),
            composition_id: 0,
            admitted: 0,
            completed: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn occupancy(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn free_slots(&self) -> usize {
        self.capacity() - self.occupancy()
    }

    /// Changes whenever the slot→tenant mapping changes; the engine keys
    /// its stacked-delta cache on this.
    pub fn composition_id(&self) -> u64 {
        self.composition_id
    }

    /// Install a sequence in the first free slot.
    pub fn admit(&mut self, seq: ActiveSeq) -> Result<usize, ActiveSeq> {
        match self.slots.iter().position(|s| s.is_none()) {
            Some(i) => {
                self.slots[i] = Some(seq);
                self.composition_id += 1;
                self.admitted += 1;
                Ok(i)
            }
            None => Err(seq),
        }
    }

    /// Remove and return a completed sequence.
    pub fn release(&mut self, slot: usize) -> Option<ActiveSeq> {
        let s = self.slots[slot].take();
        if s.is_some() {
            self.composition_id += 1;
            self.completed += 1;
        }
        s
    }

    pub fn slot(&self, i: usize) -> Option<&ActiveSeq> {
        self.slots[i].as_ref()
    }

    pub fn slot_mut(&mut self, i: usize) -> Option<&mut ActiveSeq> {
        self.slots[i].as_mut()
    }

    /// Indices of occupied slots (ascending — the batch order).
    pub fn active_slots(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&i| self.slots[i].is_some()).collect()
    }

    /// Tenant per occupied slot, the composition key.
    pub fn composition(&self) -> Vec<(usize, String)> {
        self.slots.iter().enumerate()
            .filter_map(|(i, s)| {
                s.as_ref().map(|s| (i, s.tenant.clone()))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::kvcache::SeqCache;
    use crate::model::sampling::SamplingParams;
    use crate::serving::request::{QueuedRequest, Request};

    fn cfg() -> ModelConfig {
        ModelConfig { name: "t".into(), vocab_size: 16, d_model: 8,
                      n_layers: 1, n_heads: 2, d_ff: 16, max_seq_len: 8,
                      rope_theta: 1e4, norm_eps: 1e-5 }
    }

    fn seq(tenant: &str, id: u64) -> ActiveSeq {
        ActiveSeq {
            req: QueuedRequest::for_test(Request {
                tenant: tenant.into(), prompt: "ab".into(),
                max_new_tokens: 2, sampling: SamplingParams::greedy(),
            }, id),
            tenant: tenant.into(),
            rope_scale: 1.0,
            kv: SeqKv::Slab(SeqCache::new(&cfg())),
            prompt: vec![97, 98],
            prompt_pos: 0,
            generated: vec![],
            next_token: 97,
            started: Instant::now(),
            first_token_at: None,
        }
    }

    #[test]
    fn admit_fills_first_free_slot() {
        let mut b = Batcher::new(2);
        assert_eq!(b.admit(seq("a", 1)).map_err(|_| ()).unwrap(), 0);
        assert_eq!(b.admit(seq("b", 2)).map_err(|_| ()).unwrap(), 1);
        assert!(b.admit(seq("c", 3)).is_err());
        assert_eq!(b.occupancy(), 2);
    }

    #[test]
    fn composition_changes_on_admit_and_release() {
        let mut b = Batcher::new(2);
        let c0 = b.composition_id();
        b.admit(seq("a", 1)).map_err(|_| ()).unwrap();
        let c1 = b.composition_id();
        assert_ne!(c0, c1);
        b.release(0);
        assert_ne!(b.composition_id(), c1);
        assert_eq!(b.occupancy(), 0);
    }

    #[test]
    fn composition_stable_between_events() {
        let mut b = Batcher::new(2);
        b.admit(seq("a", 1)).map_err(|_| ()).unwrap();
        let c = b.composition_id();
        let _ = b.slot_mut(0);           // mutation of a seq: no change
        assert_eq!(b.composition_id(), c);
    }

    #[test]
    fn release_empty_slot_noop() {
        let mut b = Batcher::new(1);
        let c = b.composition_id();
        assert!(b.release(0).is_none());
        assert_eq!(b.composition_id(), c);
    }

    #[test]
    fn done_respects_max_tokens_and_seq_len() {
        let mut s = seq("a", 1);
        assert!(!s.done(8));
        s.generated = vec![1, 2];
        assert!(s.done(8));
        let mut s2 = seq("a", 2);
        s2.kv.slab_mut().pos = 7;
        assert!(s2.done(8));
    }
}
