//! Synthetic multi-tenant workload traces.
//!
//! The paper motivates BitDelta with serving fine-tunes whose "traffic
//! is low or unbalanced" (§3.3). This module generates reproducible
//! request traces with Poisson arrivals and Zipf-skewed tenant
//! popularity so the serving engine can be load-tested across traffic
//! regimes (`repro loadtest`), and computes the trace statistics the
//! reports quote.

use crate::util::prop::Rng;

/// One request in a trace.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Arrival time offset from trace start, seconds.
    pub at: f64,
    pub tenant: usize,
    pub prompt_idx: usize,
    pub max_new_tokens: usize,
}

/// Shape of the arrival process over time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalPattern {
    /// Constant-rate Poisson arrivals (the classic open-loop trace).
    Steady,
    /// Square-wave Poisson: the rate alternates between the base
    /// `rate` and `rate * high_mult` every `half_period` seconds —
    /// the autoscaler's natural adversary (sustained bursts it must
    /// absorb, quiet valleys it must drain back down in). The rate at
    /// each arrival is the phase the *previous* arrival landed in (a
    /// standard piecewise approximation — exact at every point except
    /// the instant a phase flips, which is far finer than any
    /// control-loop tick).
    Burst { half_period: f64, high_mult: f64 },
}

/// Trace generation parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub n_tenants: usize,
    pub n_requests: usize,
    /// Mean arrival rate, requests/second (Poisson process). Under
    /// [`ArrivalPattern::Burst`] this is the *valley* rate.
    pub rate: f64,
    /// Zipf exponent for tenant popularity (0 = uniform; ~1 = heavy
    /// skew — a few hot fine-tunes, a long cold tail).
    pub zipf_s: f64,
    pub min_tokens: usize,
    pub max_tokens: usize,
    pub seed: u64,
    pub pattern: ArrivalPattern,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self { n_tenants: 4, n_requests: 32, rate: 50.0, zipf_s: 0.9,
               min_tokens: 8, max_tokens: 24, seed: 0,
               pattern: ArrivalPattern::Steady }
    }
}

/// Zipf sampler over `n` ranks with exponent `s` (rank 0 hottest).
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let weights: Vec<f64> = (1..=n)
            .map(|k| 1.0 / (k as f64).powf(s)).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        let cdf = weights.iter().map(|w| {
            acc += w / total;
            acc
        }).collect();
        Self { cdf }
    }

    pub fn sample(&self, u: f64) -> usize {
        self.cdf.iter().position(|&c| u <= c)
            .unwrap_or(self.cdf.len() - 1)
    }

    /// Probability mass of rank k.
    pub fn pmf(&self, k: usize) -> f64 {
        let prev = if k == 0 { 0.0 } else { self.cdf[k - 1] };
        self.cdf[k] - prev
    }
}

/// Instantaneous arrival rate at time `t` under a pattern.
pub fn rate_at(cfg: &TraceConfig, t: f64) -> f64 {
    match cfg.pattern {
        ArrivalPattern::Steady => cfg.rate,
        ArrivalPattern::Burst { half_period, high_mult } => {
            let phase = (t / half_period.max(1e-9)) as u64;
            if phase % 2 == 1 {
                cfg.rate * high_mult
            } else {
                cfg.rate
            }
        }
    }
}

/// Generate a reproducible trace.
pub fn generate(cfg: &TraceConfig) -> Vec<TraceEvent> {
    let mut rng = Rng::new(cfg.seed);
    let zipf = Zipf::new(cfg.n_tenants, cfg.zipf_s);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(cfg.n_requests);
    for _ in 0..cfg.n_requests {
        // exponential inter-arrival at the current phase's rate
        let u = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        t += -(1.0 - u).ln() / rate_at(cfg, t);
        let tu = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        let tenant = zipf.sample(tu);
        let span = cfg.max_tokens - cfg.min_tokens + 1;
        let tokens = cfg.min_tokens + rng.usize_in(0, span);
        out.push(TraceEvent {
            at: t,
            tenant,
            prompt_idx: rng.usize_in(0, 1 << 16),
            max_new_tokens: tokens,
        });
    }
    out
}

/// Summary statistics of a trace (quoted by the loadtest report).
#[derive(Debug, Clone)]
pub struct TraceStats {
    pub n: usize,
    pub duration: f64,
    pub per_tenant: Vec<usize>,
    /// Fraction of traffic on the hottest tenant.
    pub hottest_share: f64,
    /// Number of distinct tenants actually hit.
    pub tenants_hit: usize,
}

pub fn stats(events: &[TraceEvent], n_tenants: usize) -> TraceStats {
    let mut per_tenant = vec![0usize; n_tenants];
    for e in events {
        per_tenant[e.tenant] += 1;
    }
    let hottest = per_tenant.iter().copied().max().unwrap_or(0);
    TraceStats {
        n: events.len(),
        duration: events.last().map(|e| e.at).unwrap_or(0.0),
        hottest_share: hottest as f64 / events.len().max(1) as f64,
        tenants_hit: per_tenant.iter().filter(|&&c| c > 0).count(),
        per_tenant,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic() {
        let cfg = TraceConfig::default();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tenant, y.tenant);
            assert!((x.at - y.at).abs() < 1e-12);
        }
    }

    #[test]
    fn arrivals_monotone_and_rate_plausible() {
        let cfg = TraceConfig { n_requests: 2000, rate: 100.0,
                                ..Default::default() };
        let ev = generate(&cfg);
        for w in ev.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        let s = stats(&ev, cfg.n_tenants);
        let measured_rate = s.n as f64 / s.duration;
        assert!((measured_rate - 100.0).abs() < 15.0,
                "rate {measured_rate}");
    }

    #[test]
    fn zipf_skew_orders_tenants() {
        let cfg = TraceConfig { n_requests: 5000, n_tenants: 5,
                                zipf_s: 1.2, ..Default::default() };
        let s = stats(&generate(&cfg), cfg.n_tenants);
        // hottest tenant must dominate under heavy skew
        assert!(s.hottest_share > 0.35, "share {}", s.hottest_share);
        assert!(s.per_tenant[0] > s.per_tenant[4],
                "{:?}", s.per_tenant);
    }

    #[test]
    fn zipf_zero_is_roughly_uniform() {
        let cfg = TraceConfig { n_requests: 4000, n_tenants: 4,
                                zipf_s: 0.0, ..Default::default() };
        let s = stats(&generate(&cfg), cfg.n_tenants);
        for &c in &s.per_tenant {
            let frac = c as f64 / s.n as f64;
            assert!((frac - 0.25).abs() < 0.05, "{:?}", s.per_tenant);
        }
    }

    #[test]
    fn zipf_pmf_sums_to_one() {
        let z = Zipf::new(7, 0.8);
        let total: f64 = (0..7).map(|k| z.pmf(k)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn burst_pattern_alternates_rates() {
        let cfg = TraceConfig {
            rate: 10.0,
            pattern: ArrivalPattern::Burst {
                half_period: 1.0, high_mult: 5.0,
            },
            ..Default::default()
        };
        assert_eq!(rate_at(&cfg, 0.2), 10.0);   // valley
        assert_eq!(rate_at(&cfg, 1.5), 50.0);   // burst
        assert_eq!(rate_at(&cfg, 2.9), 10.0);   // valley again
    }

    #[test]
    fn burst_trace_is_denser_in_burst_phases() {
        let cfg = TraceConfig {
            n_requests: 4000,
            rate: 50.0,
            pattern: ArrivalPattern::Burst {
                half_period: 1.0, high_mult: 8.0,
            },
            ..Default::default()
        };
        let ev = generate(&cfg);
        for w in ev.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        // count arrivals landing in burst vs valley half-periods
        let (mut burst, mut valley) = (0usize, 0usize);
        for e in &ev {
            if (e.at as u64) % 2 == 1 {
                burst += 1;
            } else {
                valley += 1;
            }
        }
        assert!(burst > valley * 3,
                "burst {burst} vs valley {valley}: square wave lost");
        // same config, same seed -> identical trace (determinism holds
        // for the time-varying pattern too)
        let ev2 = generate(&cfg);
        assert_eq!(ev.len(), ev2.len());
        for (a, b) in ev.iter().zip(&ev2) {
            assert!((a.at - b.at).abs() < 1e-12);
        }
    }

    #[test]
    fn token_budget_respected() {
        let cfg = TraceConfig { min_tokens: 4, max_tokens: 9,
                                n_requests: 500, ..Default::default() };
        for e in generate(&cfg) {
            assert!((4..=9).contains(&e.max_new_tokens));
        }
    }
}
