//! The paper's core algorithm, rust-native — plus the codec layer that
//! makes delta *formats* pluggable.
//!
//! * [`packing`] — 1-bit sign pack/unpack (byte-exact twin of
//!   `python/compile/kernels/ref.py`), byte-boundary padding for
//!   arbitrary logical widths.
//! * [`bitdelta`] — Eq. 1-4 quantization: `Δ̂ = α·Sign(Δ)`, `α = mean|Δ|`
//!   (scale *distillation* lives in the python build path — it needs
//!   autodiff — but the quantizer itself is fully functional here and is
//!   what `repro compress` ships).
//! * [`iterative`] — successive-residual multi-mask deltas (Fig. 3 /
//!   Table 9).
//! * [`svd`] — one-sided Jacobi SVD + the low-rank baseline (Table 1,
//!   Fig. 2).
//! * [`codec`] — the [`codec::DeltaCodec`] trait + [`codec::CodecRegistry`]:
//!   one seam for load / byte-accounting / ABI stacking / dense
//!   materialization / CPU apply, per format.
//! * [`codecs`] — the four in-tree formats (`bitdelta`, `lora`, `svd`,
//!   `dense`). New formats go here; see the "adding a new delta codec"
//!   section in `ROADMAP.md`.

pub mod bitdelta;
pub mod codec;
pub mod codecs;
pub mod extras_quant;
pub mod iterative;
pub mod packing;
pub mod svd;
