//! The paper's core algorithm, rust-native.
//!
//! * [`packing`] — 1-bit sign pack/unpack (byte-exact twin of
//!   `python/compile/kernels/ref.py`).
//! * [`bitdelta`] — Eq. 1-4 quantization: `Δ̂ = α·Sign(Δ)`, `α = mean|Δ|`
//!   (scale *distillation* lives in the python build path — it needs
//!   autodiff — but the quantizer itself is fully functional here and is
//!   what `repro compress` ships).
//! * [`iterative`] — successive-residual multi-mask deltas (Fig. 3 /
//!   Table 9).
//! * [`svd`] — one-sided Jacobi SVD + the low-rank baseline (Table 1,
//!   Fig. 2).

pub mod bitdelta;
pub mod extras_quant;
pub mod iterative;
pub mod packing;
pub mod svd;
