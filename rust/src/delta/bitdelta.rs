//! Rust-native BitDelta quantizer (paper Eq. 1-4) — the `repro compress`
//! tool, byte-compatible with the python compressor (cross-checked by an
//! integration test against the artifacts the build path wrote).
//!
//! ```text
//! Δ = W_fine − W_base        (per transformer-block linear)
//! Δ̂ = α · Sign(Δ)            α = mean|Δ|   (L2-optimal, Eq. 3-4)
//! ```
//!
//! Scale **distillation** (Eq. 5) needs autodiff and lives in the python
//! build path; the quantizer here produces the `BitDelta-Initial` scales,
//! and [`BitDeltaCompressed::with_scales`] installs distilled ones.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::config::ModelConfig;
use crate::delta::packing::{pack_signs, unpack_signs};
use crate::store::bdw::RawTensor;
use crate::store::delta_file::{DeltaFile, MaskLevel};

/// Output of the rust-native compressor.
#[derive(Debug, Clone)]
pub struct BitDeltaCompressed {
    pub delta: DeltaFile,
    /// Reconstruction error ‖Δ − Δ̂‖_F per linear, diagnostics.
    pub residual_norms: Vec<f32>,
}

/// Compress `fine` against `base`: 1-bit masks on every transformer-block
/// linear, full precision on embeddings/norms/head (paper Table 5).
pub fn compress(cfg: &ModelConfig,
                base: &HashMap<String, RawTensor>,
                fine: &HashMap<String, RawTensor>)
                -> Result<BitDeltaCompressed> {
    let mut bits = HashMap::new();
    let mut scales = Vec::new();
    let mut residual_norms = Vec::new();

    for name in cfg.linear_names() {
        let wb = get_f32(base, &name)?;
        let wf = get_f32(fine, &name)?;
        if wb.len() != wf.len() {
            bail!("{name}: base {} elems vs fine {}", wb.len(), wf.len());
        }
        let (_, m) = cfg.linear_shape(&name);
        let delta: Vec<f32> = wf.iter().zip(&wb).map(|(f, b)| f - b)
            .collect();
        let alpha = mean_abs(&delta);
        let packed = pack_signs(&delta, m);

        // residual diagnostics: ‖Δ − α·Sign(Δ)‖_F
        let mut res = 0f64;
        for &d in &delta {
            let s = if d > 0.0 { alpha } else { -alpha };
            res += ((d - s) as f64).powi(2);
        }
        residual_norms.push(res.sqrt() as f32);

        bits.insert(name.clone(), packed);
        scales.push(alpha);
    }

    let mut extras = HashMap::new();
    for name in cfg.nonlinear_names() {
        extras.insert(name.clone(), fine[&name].clone());
    }

    Ok(BitDeltaCompressed {
        delta: DeltaFile { levels: vec![MaskLevel { bits, scales }], extras },
        residual_norms,
    })
}

impl BitDeltaCompressed {
    /// Install externally-distilled scales (level 0). A malformed
    /// distilled-scales artifact (wrong vector length) is an error the
    /// codec load path can surface, not a process abort.
    pub fn with_scales(mut self, scales: Vec<f32>) -> Result<Self> {
        let want = self.delta.levels[0].scales.len();
        if scales.len() != want {
            bail!("distilled scales have {} entries, want {want} \
(one per linear)", scales.len());
        }
        self.delta.levels[0].scales = scales;
        Ok(self)
    }

    /// Dense-model compression factor for this config (Table 5).
    pub fn compression_factor(&self, cfg: &ModelConfig) -> f64 {
        let dense: usize = cfg.param_names().iter()
            .map(|n| cfg.param_shape(n).iter().product::<usize>() * 4)
            .sum();
        dense as f64 / self.delta.delta_bytes() as f64
    }
}

/// Reconstruct the dense fine-tuned weights `W_base + Σ_k α_k·Sign_k`
/// (exactly what the serving path computes — used by the eval harness).
pub fn materialize(cfg: &ModelConfig,
                   base: &HashMap<String, RawTensor>,
                   delta: &DeltaFile)
                   -> Result<HashMap<String, RawTensor>> {
    materialize_levels(cfg, base, delta, delta.levels.len())
}

/// Reconstruct using only the first `k` mask levels (Fig. 3 fidelity
/// ablation).
pub fn materialize_levels(cfg: &ModelConfig,
                          base: &HashMap<String, RawTensor>,
                          delta: &DeltaFile, k: usize)
                          -> Result<HashMap<String, RawTensor>> {
    if k == 0 || k > delta.levels.len() {
        bail!("level count {k} out of range 1..={}", delta.levels.len());
    }
    let mut out = HashMap::new();
    for (i, name) in cfg.linear_names().iter().enumerate() {
        let (_, m) = cfg.linear_shape(name);
        let mut w = get_f32(base, name)?;
        for level in &delta.levels[..k] {
            let alpha = level.scales[i];
            let signs = unpack_signs(&level.bits[name], m);
            for (wv, s) in w.iter_mut().zip(&signs) {
                *wv += alpha * s;
            }
        }
        let shape = cfg.param_shape(name);
        out.insert(name.clone(), RawTensor::f32(shape, &w));
    }
    for name in cfg.nonlinear_names() {
        let t = delta.extras.get(&name)
            .ok_or_else(|| anyhow::anyhow!("missing extra.{name}"))?;
        out.insert(name, t.clone());
    }
    Ok(out)
}

fn get_f32(map: &HashMap<String, RawTensor>, name: &str) -> Result<Vec<f32>> {
    map.get(name)
        .ok_or_else(|| anyhow::anyhow!("missing tensor {name}"))?
        .as_f32()
}

fn mean_abs(v: &[f32]) -> f32 {
    if v.is_empty() {
        return 0.0;
    }
    (v.iter().map(|x| x.abs() as f64).sum::<f64>() / v.len() as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig { name: "tiny".into(), vocab_size: 16, d_model: 8,
                      n_layers: 1, n_heads: 2, d_ff: 16, max_seq_len: 16,
                      rope_theta: 1e4, norm_eps: 1e-5 }
    }

    fn model(cfg: &ModelConfig, seed: u64) -> HashMap<String, RawTensor> {
        cfg.param_names().into_iter().enumerate().map(|(i, n)| {
            let shape = cfg.param_shape(&n);
            let t = Tensor::randn(shape.clone(), seed + i as u64);
            (n, RawTensor::f32(shape, t.data()))
        }).collect()
    }

    fn perturbed(base: &HashMap<String, RawTensor>, eps: f32, seed: u64)
                 -> HashMap<String, RawTensor> {
        base.iter().map(|(n, t)| {
            let v = t.as_f32().unwrap();
            let noise = Tensor::randn(vec![v.len()], seed);
            let fv: Vec<f32> = v.iter().zip(noise.data())
                .map(|(a, b)| a + eps * b).collect();
            (n.clone(), RawTensor::f32(t.shape.clone(), &fv))
        }).collect()
    }

    #[test]
    fn alpha_is_mean_abs_delta() {
        let cfg = tiny_cfg();
        let base = model(&cfg, 1);
        let fine = perturbed(&base, 0.01, 99);
        let c = compress(&cfg, &base, &fine).unwrap();
        let name = &cfg.linear_names()[0];
        let d: Vec<f32> = fine[name].as_f32().unwrap().iter()
            .zip(base[name].as_f32().unwrap())
            .map(|(f, b)| f - b).collect();
        let want = mean_abs(&d);
        assert!((c.delta.levels[0].scales[0] - want).abs() < 1e-7);
    }

    #[test]
    fn materialize_reduces_to_base_plus_alpha_sign() {
        let cfg = tiny_cfg();
        let base = model(&cfg, 2);
        let fine = perturbed(&base, 0.05, 7);
        let c = compress(&cfg, &base, &fine).unwrap();
        let mat = materialize(&cfg, &base, &c.delta).unwrap();
        let name = &cfg.linear_names()[0];
        let wb = base[name].as_f32().unwrap();
        let wf = fine[name].as_f32().unwrap();
        let wm = mat[name].as_f32().unwrap();
        let alpha = c.delta.levels[0].scales[0];
        for ((b, f), m) in wb.iter().zip(&wf).zip(&wm) {
            let want = b + if f - b > 0.0 { alpha } else { -alpha };
            assert!((m - want).abs() < 1e-6);
        }
    }

    #[test]
    fn quantization_error_leq_naive_zero() {
        // α·Sign is at least as good (in L2) as dropping the delta.
        let cfg = tiny_cfg();
        let base = model(&cfg, 3);
        let fine = perturbed(&base, 0.02, 13);
        let c = compress(&cfg, &base, &fine).unwrap();
        for (i, name) in cfg.linear_names().iter().enumerate() {
            let d: Vec<f32> = fine[name].as_f32().unwrap().iter()
                .zip(base[name].as_f32().unwrap())
                .map(|(f, b)| f - b).collect();
            let zero_err = d.iter().map(|x| (*x as f64).powi(2))
                .sum::<f64>().sqrt() as f32;
            assert!(c.residual_norms[i] <= zero_err + 1e-6,
                    "{name}: {} > {}", c.residual_norms[i], zero_err);
        }
    }

    #[test]
    fn extras_carry_finetune_values() {
        let cfg = tiny_cfg();
        let base = model(&cfg, 4);
        let fine = perturbed(&base, 0.02, 17);
        let c = compress(&cfg, &base, &fine).unwrap();
        assert_eq!(c.delta.extras["tok_embed"], fine["tok_embed"]);
        assert_eq!(c.delta.extras["lm_head"], fine["lm_head"]);
    }

    #[test]
    fn with_scales_rejects_length_mismatch() {
        let cfg = tiny_cfg();
        let base = model(&cfg, 6);
        let fine = perturbed(&base, 0.02, 23);
        let c = compress(&cfg, &base, &fine).unwrap();
        let want = cfg.linear_names().len();
        let e = c.clone().with_scales(vec![0.1; want + 1])
            .unwrap_err().to_string();
        assert!(e.contains("one per linear"), "{e}");
        let ok = c.with_scales(vec![0.1; want]).unwrap();
        assert_eq!(ok.delta.levels[0].scales, vec![0.1; want]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let cfg = tiny_cfg();
        let base = model(&cfg, 5);
        let mut fine = perturbed(&base, 0.02, 19);
        let name = cfg.linear_names()[0].clone();
        fine.insert(name, RawTensor::f32(vec![4], &[0.0; 4]));
        assert!(compress(&cfg, &base, &fine).is_err());
    }
}
