//! Dense codec (the paper's "naive" baseline): the payload is the full
//! fine-tuned weight set — no compression at all. Decodes through
//! `decode_naive`, which stacks every parameter with a leading `[B]`
//! tenant axis (the memory hog that OOMs in Figs. 5/6; we materialize it
//! faithfully). Doubles as the **mixed-format fallback**: any codec's
//! payload can be materialized into this shape, so a batch mixing
//! bitdelta/lora/svd tenants runs through this codec's stacking.

use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::config::{Manifest, ModelConfig, TenantEntry};
use crate::delta::codec::{downcast, pick, DeltaCodec, LoadCtx, Model,
                          Payload};
use crate::gemm::dense_gemv;
use crate::runtime::client::Runtime;
use crate::runtime::variants::StackedArgs;
use crate::store::delta_file::load_model;

/// Newtype payload over the dense weight map (shared via `Rc` so
/// `materialize` can hand the same weights back without a copy).
pub struct DenseWeights(pub Rc<Model>);

impl Payload for DenseWeights {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn resident_bytes(&self) -> usize {
        self.0.values().map(|t| t.bytes.len()).sum()
    }
}

/// Stack full weight sets into the `decode_naive` ABI (`params…` in
/// canonical order, each `[B, …]`). Public within the crate: the engine
/// uses it directly for mixed-format batches after materializing each
/// slot.
pub(crate) fn stack_dense_models(rt: &Runtime, cfg: &ModelConfig,
                                 models: &[&Model], batch: usize)
                                 -> Result<StackedArgs> {
    if models.is_empty() || models.len() > batch {
        bail!("need 1..={batch} weight sets, got {}", models.len());
    }
    let mut buffers = Vec::new();
    let mut staged = 0usize;
    for name in cfg.param_names() {
        let shape = cfg.param_shape(&name);
        let elems: usize = shape.iter().product();
        let mut stacked = Vec::with_capacity(batch * elems);
        for b in 0..batch {
            let t = pick(models, b).get(&name).ok_or_else(
                || anyhow::anyhow!("weight set missing {name}"))?;
            stacked.extend_from_slice(&t.as_f32()?);
        }
        staged += stacked.len() * 4;
        let mut full = vec![batch];
        full.extend(&shape);
        buffers.push(rt.upload_f32(&stacked, &full)?);
    }
    Ok(StackedArgs { buffers, batch, staged_bytes: staged,
                     exec_kind: None })
}

pub struct DenseCodec;

impl DeltaCodec for DenseCodec {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn exec_kind(&self) -> &'static str {
        "decode_naive"
    }

    fn needs_base(&self) -> bool {
        false
    }

    fn artifact_path(&self, manifest: &Manifest, tenant: &TenantEntry,
                     _distilled: bool, levels: usize) -> Option<PathBuf> {
        if levels > 1 {
            return None;    // dense weights have no fidelity tiers
        }
        Some(manifest.path(&tenant.finetune))
    }

    fn load(&self, path: &Path, ctx: &LoadCtx) -> Result<Rc<dyn Payload>> {
        let m = load_model(path, ctx.cfg)
            .with_context(|| format!("dense codec: {path:?}"))?;
        Ok(Rc::new(DenseWeights(Rc::new(m))))
    }

    fn assemble(&self, rt: &Runtime, cfg: &ModelConfig,
                payloads: &[&dyn Payload], batch: usize)
                -> Result<StackedArgs> {
        let models: Vec<&Model> = payloads.iter()
            .map(|p| downcast::<DenseWeights>(*p, self.name())
                 .map(|w| w.0.as_ref()))
            .collect::<Result<_>>()?;
        stack_dense_models(rt, cfg, &models, batch)
    }

    /// Identity: the payload already IS the dense weights — the `Rc` is
    /// shared, not cloned, so a dense tenant in a mixed batch does not
    /// double its host-memory footprint.
    fn materialize(&self, _cfg: &ModelConfig, _base: &Model,
                   payload: &dyn Payload) -> Result<Rc<Model>> {
        let w = downcast::<DenseWeights>(payload, self.name())?;
        Ok(w.0.clone())
    }

    fn forward_linear(&self, cfg: &ModelConfig, _base: &Model,
                      payload: &dyn Payload, name: &str, x: &[f32],
                      y: &mut [f32]) -> Result<()> {
        let w = downcast::<DenseWeights>(payload, self.name())?;
        let (n, m) = cfg.linear_shape(name);
        let wf = w.0.get(name)
            .with_context(|| format!("weights missing {name}"))?
            .as_f32()?;
        dense_gemv(&wf, n, m, x, y);
        Ok(())
    }
}
