//! The paper's own format: per-linear packed 1-bit sign masks + one f32
//! scale per mask, possibly several successive-residual **levels**
//! (Fig. 3 / Table 9 fidelity tiers), full-precision extras. Payload
//! type: [`DeltaFile`]. Single-level batches decode through
//! `decode_bitdelta` (shared base linears + stacked masks); multi-level
//! batches through `decode_bitdelta_l{L}`, whose bits/scales carry a
//! level axis summed inside the executable.
//!
//! **Fidelity tiers.** A tenant served at tier `k` loads the first `k`
//! levels of its fidelity artifact ([`LoadCtx::levels`]), so
//! `resident_bytes` — the delta store's budget unit and the placement
//! bin-packing weight — scales with the tier. Tenants at different
//! tiers may share one decode batch: [`BitDeltaCodec::assemble`] pads
//! every slot to the batch-max level count with **zero-scale no-op
//! levels** (an all-zero mask contributes `0·Sign @ x = 0`), keeping
//! the batch homogeneous while each tenant's output stays bit-identical
//! to being served alone at its own tier.

use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::config::{Manifest, ModelConfig, TenantEntry};
use crate::delta::codec::{downcast, pick, stack_extras, DeltaCodec,
                          LoadCtx, Model, Payload};
use crate::gemm::{dense_gemv, try_binary_gemv_multi};
use crate::runtime::client::Runtime;
use crate::runtime::variants::StackedArgs;
use crate::store::delta_file::DeltaFile;

impl Payload for DeltaFile {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn resident_bytes(&self) -> usize {
        self.delta_bytes()
    }
}

/// Level counts with an AOT decode executable, ascending, paired with
/// the executable kind. A batch whose max level count is not an exact
/// tier is padded up to the next one (zero-scale levels are free).
pub const LEVEL_TIERS: [(usize, &str); 3] = [
    (1, "decode_bitdelta"),
    (2, "decode_bitdelta_l2"),
    (4, "decode_bitdelta_l4"),
];

/// Smallest exported tier that fits `levels` stacked masks.
pub fn exec_tier_for(levels: usize) -> Option<(usize, &'static str)> {
    LEVEL_TIERS.iter().copied().find(|(l, _)| *l >= levels)
}

pub struct BitDeltaCodec;

impl DeltaCodec for BitDeltaCodec {
    fn name(&self) -> &'static str {
        "bitdelta"
    }

    fn exec_kind(&self) -> &'static str {
        "decode_bitdelta"
    }

    /// Tier table: `decode_bitdelta` at 1 level, `decode_bitdelta_l{L}`
    /// above (rounded up to the smallest exported tier).
    fn exec_kind_for_levels(&self, levels: usize)
                            -> Option<&'static str> {
        exec_tier_for(levels).map(|(_, kind)| kind)
    }

    fn needs_base(&self) -> bool {
        true
    }

    /// Tier `<= 1`: the standard delta (distilled or initial). Tier
    /// `k > 1`: the tenant's Fig. 3 fidelity artifact with the fewest
    /// levels `>= k` (load truncates down to exactly `k`); `None` when
    /// no fidelity artifact reaches the tier.
    fn artifact_path(&self, manifest: &Manifest, tenant: &TenantEntry,
                     distilled: bool, levels: usize) -> Option<PathBuf> {
        if levels <= 1 {
            let rel = if distilled { &tenant.delta }
                      else { &tenant.delta_initial };
            return Some(manifest.path(rel));
        }
        let mut ks: Vec<usize> = tenant.fidelity.keys()
            .filter_map(|k| k.parse().ok())
            .filter(|&k| k >= levels)
            .collect();
        ks.sort_unstable();
        ks.first()
            .map(|k| manifest.path(&tenant.fidelity[&k.to_string()]))
    }

    fn load(&self, path: &Path, ctx: &LoadCtx) -> Result<Rc<dyn Payload>> {
        let mut d = DeltaFile::load(path, ctx.cfg)
            .with_context(|| format!("bitdelta codec: {path:?}"))?;
        if ctx.levels > 0 {
            if ctx.levels > d.levels.len() {
                bail!("bitdelta codec: {path:?} carries {} mask \
level(s), fidelity tier {} requested", d.levels.len(), ctx.levels);
            }
            // serve exactly the requested tier: resident_bytes (store
            // budget, placement weight) and every downstream consumer
            // see only the retained levels
            d.levels.truncate(ctx.levels);
        }
        Ok(Rc::new(d))
    }

    /// ABI slice: `bits…(per linear), scales, extras…` — each with a
    /// leading `[B]` tenant axis. When any payload carries more than one
    /// mask level the batch is raised to the smallest exported level
    /// tier (`decode_bitdelta_l{L}`): bits become `[B, L, N, ⌈M/8⌉]`,
    /// scales `[B, L, n_linears]`, and slots with fewer levels are
    /// padded with zero-scale no-op levels.
    fn assemble(&self, rt: &Runtime, cfg: &ModelConfig,
                payloads: &[&dyn Payload], batch: usize)
                -> Result<StackedArgs> {
        if payloads.is_empty() || payloads.len() > batch {
            bail!("need 1..={batch} deltas, got {}", payloads.len());
        }
        let deltas: Vec<&DeltaFile> = payloads.iter()
            .map(|p| downcast::<DeltaFile>(*p, self.name()))
            .collect::<Result<_>>()?;
        // lint: allow(unwrap, payloads checked non-empty above)
        let lmax = deltas.iter().map(|d| d.levels.len()).max().unwrap();
        let Some((lexec, exec_kind)) = exec_tier_for(lmax) else {
            let deepest = LEVEL_TIERS[LEVEL_TIERS.len() - 1].0;
            bail!("a {lmax}-level delta exceeds the deepest exported \
decode tier ({deepest}) — serve it at a fidelity tier <= {deepest}");
        };

        let mut staged = 0usize;
        let mut buffers = Vec::new();

        for name in cfg.linear_names() {
            let (n, mp) = cfg.packed_shape(&name);
            let mut stacked = Vec::with_capacity(batch * lexec * n * mp);
            for b in 0..batch {
                let d = pick(&deltas, b);
                for l in 0..lexec {
                    match d.levels.get(l) {
                        Some(level) => stacked.extend_from_slice(
                            &level.bits[&name]),
                        // zero-scale padding level: mask bytes are
                        // arbitrary as long as padding bits are clear —
                        // all-zero keeps the buffer valid everywhere
                        None => stacked.resize(stacked.len() + n * mp, 0),
                    }
                }
            }
            staged += stacked.len();
            let shape: Vec<usize> = if lexec == 1 {
                vec![batch, n, mp]
            } else {
                vec![batch, lexec, n, mp]
            };
            buffers.push(rt.upload_u8(&stacked, &shape)?);
        }

        let n_lin = cfg.linear_names().len();
        let mut scales = Vec::with_capacity(batch * lexec * n_lin);
        for b in 0..batch {
            let d = pick(&deltas, b);
            for l in 0..lexec {
                match d.levels.get(l) {
                    Some(level) => scales.extend_from_slice(&level.scales),
                    None => scales.resize(scales.len() + n_lin, 0.0),
                }
            }
        }
        staged += scales.len() * 4;
        let sshape: Vec<usize> = if lexec == 1 {
            vec![batch, n_lin]
        } else {
            vec![batch, lexec, n_lin]
        };
        buffers.push(rt.upload_f32(&scales, &sshape)?);

        let extras: Vec<&Model> = deltas.iter().map(|d| &d.extras)
            .collect();
        let (extra_bufs, extra_bytes) =
            stack_extras(rt, cfg, &extras, batch)?;
        staged += extra_bytes;
        buffers.extend(extra_bufs);

        Ok(StackedArgs {
            buffers, batch, staged_bytes: staged,
            exec_kind: if lexec == 1 { None } else { Some(exec_kind) },
        })
    }

    fn materialize(&self, cfg: &ModelConfig, base: &Model,
                   payload: &dyn Payload) -> Result<Rc<Model>> {
        let d = downcast::<DeltaFile>(payload, self.name())?;
        crate::delta::bitdelta::materialize_levels(cfg, base, d,
                                                   d.levels.len())
            .map(Rc::new)
    }

    /// `y = W_base@x + Σ_k α_k·Sign_k@x` straight from the packed
    /// bytes, all levels through the fused multi-level kernel (the
    /// shared `Σx` term and nibble tables are computed once, not per
    /// level).
    fn forward_linear(&self, cfg: &ModelConfig, base: &Model,
                      payload: &dyn Payload, name: &str, x: &[f32],
                      y: &mut [f32]) -> Result<()> {
        let d = downcast::<DeltaFile>(payload, self.name())?;
        let (n, m) = cfg.linear_shape(name);
        let wb = base.get(name)
            .with_context(|| format!("base missing {name}"))?.as_f32()?;
        dense_gemv(&wb, n, m, x, y);
        let (i, _) = cfg.linear_names().iter().enumerate()
            .find(|(_, ln)| ln.as_str() == name)
            .with_context(|| format!("{name} is not a canonical linear"))?;
        let mut levels: Vec<(&[u8], f32)> =
            Vec::with_capacity(d.levels.len());
        for level in &d.levels {
            let bits = level.bits.get(name)
                .with_context(|| format!("delta missing bits for {name}"))?;
            levels.push((bits.as_slice(), level.scales[i]));
        }
        let mut tmp = vec![0f32; n];
        try_binary_gemv_multi(&levels, n, m, x, &mut tmp)?;
        for (yv, t) in y.iter_mut().zip(&tmp) {
            *yv += t;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_tier_rounds_up_to_exported_levels() {
        assert_eq!(exec_tier_for(1), Some((1, "decode_bitdelta")));
        assert_eq!(exec_tier_for(2), Some((2, "decode_bitdelta_l2")));
        assert_eq!(exec_tier_for(3), Some((4, "decode_bitdelta_l4")));
        assert_eq!(exec_tier_for(4), Some((4, "decode_bitdelta_l4")));
        assert_eq!(exec_tier_for(5), None);
    }
}
