//! The paper's own format: per-linear packed 1-bit sign masks + one f32
//! scale (possibly several successive-residual levels), full-precision
//! extras. Payload type: [`DeltaFile`]. Decodes through
//! `decode_bitdelta` (shared base linears + stacked masks).

use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::config::{Manifest, ModelConfig, TenantEntry};
use crate::delta::codec::{downcast, pick, stack_extras, DeltaCodec,
                          LoadCtx, Model, Payload};
use crate::gemm::{dense_gemv, try_binary_gemv};
use crate::runtime::client::Runtime;
use crate::runtime::variants::StackedArgs;
use crate::store::delta_file::DeltaFile;

impl Payload for DeltaFile {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn resident_bytes(&self) -> usize {
        self.delta_bytes()
    }
}

pub struct BitDeltaCodec;

impl DeltaCodec for BitDeltaCodec {
    fn name(&self) -> &'static str {
        "bitdelta"
    }

    fn exec_kind(&self) -> &'static str {
        "decode_bitdelta"
    }

    fn needs_base(&self) -> bool {
        true
    }

    fn artifact_path(&self, manifest: &Manifest, tenant: &TenantEntry,
                     distilled: bool) -> Option<PathBuf> {
        let rel = if distilled { &tenant.delta }
                  else { &tenant.delta_initial };
        Some(manifest.path(rel))
    }

    fn load(&self, path: &Path, ctx: &LoadCtx) -> Result<Rc<dyn Payload>> {
        let d = DeltaFile::load(path, ctx.cfg)
            .with_context(|| format!("bitdelta codec: {path:?}"))?;
        Ok(Rc::new(d))
    }

    /// ABI slice: `bits…(per linear), scales, extras…` — each with a
    /// leading `[B]` tenant axis. The `decode_bitdelta` ABI carries a
    /// single mask level, so multi-level deltas (Fig. 3 fidelity files)
    /// are rejected here with a clear error instead of silently serving
    /// level 0 while `materialize`/`forward_linear` apply all levels.
    fn assemble(&self, rt: &Runtime, cfg: &ModelConfig,
                payloads: &[&dyn Payload], batch: usize)
                -> Result<StackedArgs> {
        if payloads.is_empty() || payloads.len() > batch {
            bail!("need 1..={batch} deltas, got {}", payloads.len());
        }
        let deltas: Vec<&DeltaFile> = payloads.iter()
            .map(|p| downcast::<DeltaFile>(*p, self.name()))
            .collect::<Result<_>>()?;
        if let Some(d) = deltas.iter().find(|d| d.levels.len() > 1) {
            bail!("decode_bitdelta serves exactly one mask level, got a \
{}-level delta — use materialize_levels for fidelity evals",
                  d.levels.len());
        }
        let mut staged = 0usize;
        let mut buffers = Vec::new();

        for name in cfg.linear_names() {
            let (n, mp) = cfg.packed_shape(&name);
            let mut stacked = Vec::with_capacity(batch * n * mp);
            for b in 0..batch {
                stacked.extend_from_slice(
                    &pick(&deltas, b).levels[0].bits[&name]);
            }
            staged += stacked.len();
            buffers.push(rt.upload_u8(&stacked, &[batch, n, mp])?);
        }

        let n_lin = cfg.linear_names().len();
        let mut scales = Vec::with_capacity(batch * n_lin);
        for b in 0..batch {
            scales.extend_from_slice(&pick(&deltas, b).levels[0].scales);
        }
        staged += scales.len() * 4;
        buffers.push(rt.upload_f32(&scales, &[batch, n_lin])?);

        let extras: Vec<&Model> = deltas.iter().map(|d| &d.extras)
            .collect();
        let (extra_bufs, extra_bytes) =
            stack_extras(rt, cfg, &extras, batch)?;
        staged += extra_bytes;
        buffers.extend(extra_bufs);

        Ok(StackedArgs { buffers, batch, staged_bytes: staged })
    }

    fn materialize(&self, cfg: &ModelConfig, base: &Model,
                   payload: &dyn Payload) -> Result<Rc<Model>> {
        let d = downcast::<DeltaFile>(payload, self.name())?;
        crate::delta::bitdelta::materialize(cfg, base, d).map(Rc::new)
    }

    /// `y = W_base@x + Σ_k α_k·Sign_k@x` straight from the packed bytes.
    fn forward_linear(&self, cfg: &ModelConfig, base: &Model,
                      payload: &dyn Payload, name: &str, x: &[f32],
                      y: &mut [f32]) -> Result<()> {
        let d = downcast::<DeltaFile>(payload, self.name())?;
        let (n, m) = cfg.linear_shape(name);
        let wb = base.get(name)
            .with_context(|| format!("base missing {name}"))?.as_f32()?;
        dense_gemv(&wb, n, m, x, y);
        let (i, _) = cfg.linear_names().iter().enumerate()
            .find(|(_, ln)| ln.as_str() == name)
            .with_context(|| format!("{name} is not a canonical linear"))?;
        let mut tmp = vec![0f32; n];
        for level in &d.levels {
            let bits = level.bits.get(name)
                .with_context(|| format!("delta missing bits for {name}"))?;
            try_binary_gemv(bits, n, m, x, level.scales[i], &mut tmp)?;
            for (yv, t) in y.iter_mut().zip(&tmp) {
                *yv += t;
            }
        }
        Ok(())
    }
}
