//! The in-tree [`crate::delta::codec::DeltaCodec`] implementations.
//!
//! | codec      | payload                          | decode executable |
//! |------------|----------------------------------|-------------------|
//! | [`bitdelta`] | packed 1-bit masks + scales    | `decode_bitdelta` |
//! | [`lora`]     | precomputed low-rank factors   | `decode_lora`     |
//! | [`svd`]      | factors computed **at load**   | `decode_lora`     |
//! | [`dense`]    | the full fine-tuned weights    | `decode_naive`    |
//!
//! Each module is self-contained: adding a format means adding a sibling
//! module here and one `register` line in
//! [`crate::delta::codec::CodecRegistry::builtin`]. Nothing outside
//! `rust/src/delta/` needs to change — the engine, delta store, router,
//! eval tables, and benches all dispatch through the trait.

pub mod bitdelta;
pub mod dense;
pub mod lora;
pub mod svd;

#[cfg(test)]
mod tests {
    use std::collections::HashMap;
    use std::rc::Rc;

    use crate::config::ModelConfig;
    use crate::delta::codec::{CodecRegistry, Model, Payload};
    use crate::delta::svd::low_rank_factors;
    use crate::gemm::dense_gemv;
    use crate::store::bdw::RawTensor;
    use crate::store::delta_file::LoraFile;
    use crate::tensor::Tensor;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig { name: "t".into(), vocab_size: 16, d_model: 8,
                      n_layers: 1, n_heads: 2, d_ff: 16, max_seq_len: 8,
                      rope_theta: 1e4, norm_eps: 1e-5 }
    }

    fn model(cfg: &ModelConfig, seed: u64) -> Model {
        cfg.param_names().into_iter().enumerate().map(|(i, n)| {
            let shape = cfg.param_shape(&n);
            let t = Tensor::randn(shape.clone(), seed + i as u64);
            (n, RawTensor::f32(shape, t.data()))
        }).collect()
    }

    /// A payload for `codec` encoding (approximately) `fine − base`.
    fn sample_payload(codec: &str, cfg: &ModelConfig, base: &Model,
                      fine: &Model) -> Rc<dyn Payload> {
        match codec {
            "bitdelta" => Rc::new(
                crate::delta::bitdelta::compress(cfg, base, fine)
                    .unwrap().delta),
            "lora" | "svd" => {
                let mut a = HashMap::new();
                let mut b = HashMap::new();
                let rank = 4;
                for name in cfg.linear_names() {
                    let (n, m) = cfg.linear_shape(&name);
                    let wb = base[&name].as_f32().unwrap();
                    let wf = fine[&name].as_f32().unwrap();
                    let d: Vec<f32> = wf.iter().zip(&wb)
                        .map(|(f, x)| f - x).collect();
                    let (ad, bu) = low_rank_factors(
                        &Tensor::new(vec![n, m], d), rank);
                    a.insert(name.clone(), ad.data().to_vec());
                    b.insert(name.clone(), bu.data().to_vec());
                }
                let mut extras = HashMap::new();
                for name in cfg.nonlinear_names() {
                    extras.insert(name.clone(), fine[&name].clone());
                }
                Rc::new(LoraFile { rank, a, b, extras })
            }
            "dense" => Rc::new(
                super::dense::DenseWeights(Rc::new(fine.clone()))),
            other => panic!("no sample payload for {other}"),
        }
    }

    /// The codec-layer invariant: for EVERY registered codec,
    /// `forward_linear` equals a dense GEMV over `materialize`'s output.
    #[test]
    fn forward_linear_matches_materialized_dense_for_every_codec() {
        let cfg = tiny_cfg();
        let base = model(&cfg, 100);
        let fine = model(&cfg, 200);
        let registry = CodecRegistry::builtin();
        for codec in registry.iter() {
            let payload = sample_payload(codec.name(), &cfg, &base, &fine);
            let mat = codec.materialize(&cfg, &base, payload.as_ref())
                .unwrap();
            for name in cfg.linear_names() {
                let (n, m) = cfg.linear_shape(&name);
                let x = Tensor::randn(vec![m], 7 + n as u64);
                let mut y = vec![0f32; n];
                codec.forward_linear(&cfg, &base, payload.as_ref(),
                                     &name, x.data(), &mut y).unwrap();
                let mut want = vec![0f32; n];
                dense_gemv(&mat[&name].as_f32().unwrap(), n, m,
                           x.data(), &mut want);
                for (a, b) in y.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-2,
                            "{}::{name}: {a} vs {b}", codec.name());
                }
            }
        }
    }

    /// The same invariant at fidelity tiers k > 1: a multi-level
    /// bitdelta payload's fused `forward_linear` must equal a dense
    /// GEMV over `materialize_levels` of the same k levels — the
    /// guarantee that serving a tier and evaluating it see one model.
    #[test]
    fn forward_linear_matches_materialized_dense_multi_level() {
        let cfg = tiny_cfg();
        let base = model(&cfg, 900);
        let fine = model(&cfg, 901);
        let registry = CodecRegistry::builtin();
        let codec = registry.get("bitdelta").unwrap();
        for k in [2usize, 3, 4] {
            let payload: Rc<dyn Payload> = Rc::new(
                crate::delta::iterative::compress_iterative(
                    &cfg, &base, &fine, k).unwrap());
            let mat = codec.materialize(&cfg, &base, payload.as_ref())
                .unwrap();
            for name in cfg.linear_names() {
                let (n, m) = cfg.linear_shape(&name);
                let x = Tensor::randn(vec![m], 40 + (k * n) as u64);
                let mut y = vec![0f32; n];
                codec.forward_linear(&cfg, &base, payload.as_ref(),
                                     &name, x.data(), &mut y).unwrap();
                let mut want = vec![0f32; n];
                dense_gemv(&mat[&name].as_f32().unwrap(), n, m,
                           x.data(), &mut want);
                for (a, b) in y.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-2,
                            "k={k} {name}: {a} vs {b}");
                }
            }
        }
    }

    /// Property: per-matrix reconstruction error of the materialized
    /// model is non-increasing in the number of served levels k —
    /// every extra mask can only move `W_base + Δ̂` closer to the
    /// fine-tune (the monotonicity behind Fig. 3).
    #[test]
    fn reconstruction_error_non_increasing_in_levels() {
        use crate::delta::bitdelta::materialize_levels;
        use crate::util::prop::run_cases;

        let cfg = tiny_cfg();
        run_cases(6, |rng| {
            let seed = rng.usize_in(1, 10_000) as u64;
            let base = model(&cfg, seed);
            let fine = model(&cfg, seed + 77);
            let k_max = 5;
            let d = crate::delta::iterative::compress_iterative(
                &cfg, &base, &fine, k_max).unwrap();
            for name in cfg.linear_names() {
                let wf = fine[&name].as_f32().unwrap();
                let mut prev = f64::INFINITY;
                for k in 1..=k_max {
                    let mat = materialize_levels(&cfg, &base, &d, k)
                        .unwrap();
                    let wm = mat[&name].as_f32().unwrap();
                    let err: f64 = wf.iter().zip(&wm)
                        .map(|(f, m)| ((f - m) as f64).powi(2)).sum();
                    assert!(err <= prev + 1e-9,
                            "{name}: err grew at k={k}: {err} > {prev}");
                    prev = err;
                }
            }
        });
    }

    /// Materialize carries the tenant's extras for every codec.
    #[test]
    fn materialize_carries_extras_for_every_codec() {
        let cfg = tiny_cfg();
        let base = model(&cfg, 300);
        let fine = model(&cfg, 400);
        let registry = CodecRegistry::builtin();
        for codec in registry.iter() {
            let payload = sample_payload(codec.name(), &cfg, &base, &fine);
            let mat = codec.materialize(&cfg, &base, payload.as_ref())
                .unwrap();
            for name in cfg.nonlinear_names() {
                assert_eq!(mat[&name], fine[&name],
                           "{} lost extra {name}", codec.name());
            }
        }
    }

    /// Payload byte accounting is positive and format-shaped: 1-bit
    /// masks are far smaller than the dense payload.
    #[test]
    fn resident_bytes_orders_formats() {
        let cfg = tiny_cfg();
        let base = model(&cfg, 500);
        let fine = model(&cfg, 600);
        let registry = CodecRegistry::builtin();
        let bytes: HashMap<&str, usize> = registry.iter().map(|c| {
            let p = sample_payload(c.name(), &cfg, &base, &fine);
            (c.name(), p.resident_bytes())
        }).collect();
        assert!(bytes["bitdelta"] > 0);
        assert!(bytes["bitdelta"] < bytes["dense"],
                "bitdelta {} !< dense {}", bytes["bitdelta"],
                bytes["dense"]);
    }

    /// Wrong-payload dispatch fails with a diagnosable error, not a
    /// panic or silent garbage.
    #[test]
    fn wrong_payload_type_rejected() {
        let cfg = tiny_cfg();
        let base = model(&cfg, 700);
        let fine = model(&cfg, 800);
        let registry = CodecRegistry::builtin();
        let dense_payload = sample_payload("dense", &cfg, &base, &fine);
        let bd = registry.get("bitdelta").unwrap();
        let e = bd.materialize(&cfg, &base, dense_payload.as_ref());
        assert!(e.is_err());
        assert!(e.unwrap_err().to_string().contains("bitdelta"));
    }
}
