//! Low-rank delta codec (the S-LoRA comparator): per-linear factors
//! `a_down [r, M]` / `b_up [N, r]` with `Δ = b_up @ a_down`, plus
//! full-precision extras. Payload type: [`LoraFile`]. Decodes through
//! `decode_lora` (shared base linears + stacked factors).

use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::config::{Manifest, ModelConfig, TenantEntry};
use crate::delta::codec::{downcast, pick, stack_extras, DeltaCodec,
                          LoadCtx, Model, Payload};
use crate::gemm::{dense_gemv, lora_gemv};
use crate::runtime::client::Runtime;
use crate::runtime::variants::StackedArgs;
use crate::store::bdw::RawTensor;
use crate::store::delta_file::LoraFile;
use crate::tensor::Tensor;

impl Payload for LoraFile {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn resident_bytes(&self) -> usize {
        self.delta_bytes()
    }
}

/// `W = base + b_up @ a_down` for every linear; extras replace the base
/// values. Shared with the `svd` codec (same payload type).
pub(crate) fn materialize_lora_payload(cfg: &ModelConfig, base: &Model,
                                       lf: &LoraFile) -> Result<Model> {
    let mut out: Model = Model::new();
    for name in cfg.linear_names() {
        let (n, m) = cfg.linear_shape(&name);
        let r = lf.rank;
        let a = Tensor::new(vec![r, m], lf.a[&name].clone());
        let b = Tensor::new(vec![n, r], lf.b[&name].clone());
        let delta = b.matmul(&a);
        let wb = base[&name].as_f32()?;
        let w: Vec<f32> = wb.iter().zip(delta.data())
            .map(|(x, d)| x + d).collect();
        out.insert(name.clone(), RawTensor::f32(vec![n, m], &w));
    }
    for name in cfg.nonlinear_names() {
        let t = lf.extras.get(&name)
            .with_context(|| format!("lora payload missing extra.{name}"))?;
        out.insert(name, t.clone());
    }
    Ok(out)
}

/// `y = W_base@x + b_up(a_down x)` — the two-stage low-rank apply.
pub(crate) fn forward_lora_payload(cfg: &ModelConfig, base: &Model,
                                   lf: &LoraFile, name: &str, x: &[f32],
                                   y: &mut [f32]) -> Result<()> {
    let (n, m) = cfg.linear_shape(name);
    let wb = base.get(name)
        .with_context(|| format!("base missing {name}"))?.as_f32()?;
    dense_gemv(&wb, n, m, x, y);
    let a = lf.a.get(name)
        .with_context(|| format!("lora payload missing a.{name}"))?;
    let b = lf.b.get(name)
        .with_context(|| format!("lora payload missing b.{name}"))?;
    let mut tmp = vec![0f32; n];
    lora_gemv(a, b, lf.rank, n, m, x, &mut tmp);
    for (yv, t) in y.iter_mut().zip(&tmp) {
        *yv += t;
    }
    Ok(())
}

/// ABI slice: `a…(per linear), b…(per linear), extras…` — each with a
/// leading `[B]` tenant axis. Shared with the `svd` codec.
pub(crate) fn assemble_lora_payloads(rt: &Runtime, cfg: &ModelConfig,
                                     loras: &[&LoraFile], batch: usize)
                                     -> Result<StackedArgs> {
    if loras.is_empty() || loras.len() > batch {
        bail!("need 1..={batch} adapters, got {}", loras.len());
    }
    let rank = loras[0].rank;
    if loras.iter().any(|l| l.rank != rank) {
        bail!("mixed ranks in one batch");
    }
    let mut staged = 0usize;
    let (mut a_bufs, mut b_bufs) = (Vec::new(), Vec::new());
    for name in cfg.linear_names() {
        let (n, m) = cfg.linear_shape(&name);
        let mut sa = Vec::with_capacity(batch * rank * m);
        let mut sb = Vec::with_capacity(batch * n * rank);
        for bi in 0..batch {
            sa.extend_from_slice(&pick(loras, bi).a[&name]);
            sb.extend_from_slice(&pick(loras, bi).b[&name]);
        }
        staged += (sa.len() + sb.len()) * 4;
        a_bufs.push(rt.upload_f32(&sa, &[batch, rank, m])?);
        b_bufs.push(rt.upload_f32(&sb, &[batch, n, rank])?);
    }
    let mut buffers = a_bufs;
    buffers.extend(b_bufs);

    let extras: Vec<&Model> = loras.iter().map(|l| &l.extras).collect();
    let (extra_bufs, extra_bytes) = stack_extras(rt, cfg, &extras, batch)?;
    staged += extra_bytes;
    buffers.extend(extra_bufs);

    Ok(StackedArgs { buffers, batch, staged_bytes: staged,
                     exec_kind: None })
}

pub struct LoraCodec;

impl DeltaCodec for LoraCodec {
    fn name(&self) -> &'static str {
        "lora"
    }

    fn exec_kind(&self) -> &'static str {
        "decode_lora"
    }

    fn needs_base(&self) -> bool {
        true
    }

    /// Served from the tenant's precomputed SVD-r16 factor files (only
    /// tenants with factors can ride this codec).
    fn artifact_path(&self, manifest: &Manifest, tenant: &TenantEntry,
                     distilled: bool, levels: usize) -> Option<PathBuf> {
        if levels > 1 {
            return None;    // low-rank factors have no fidelity tiers
        }
        tenant.svd_r16.as_ref().map(|s| {
            manifest.path(if distilled { &s.distilled } else { &s.initial })
        })
    }

    fn load(&self, path: &Path, ctx: &LoadCtx) -> Result<Rc<dyn Payload>> {
        let f = LoraFile::load(path, ctx.cfg)
            .with_context(|| format!("lora codec: {path:?}"))?;
        Ok(Rc::new(f))
    }

    fn assemble(&self, rt: &Runtime, cfg: &ModelConfig,
                payloads: &[&dyn Payload], batch: usize)
                -> Result<StackedArgs> {
        let loras: Vec<&LoraFile> = payloads.iter()
            .map(|p| downcast::<LoraFile>(*p, self.name()))
            .collect::<Result<_>>()?;
        assemble_lora_payloads(rt, cfg, &loras, batch)
    }

    fn materialize(&self, cfg: &ModelConfig, base: &Model,
                   payload: &dyn Payload) -> Result<Rc<Model>> {
        let lf = downcast::<LoraFile>(payload, self.name())?;
        materialize_lora_payload(cfg, base, lf).map(Rc::new)
    }

    fn forward_linear(&self, cfg: &ModelConfig, base: &Model,
                      payload: &dyn Payload, name: &str, x: &[f32],
                      y: &mut [f32]) -> Result<()> {
        let lf = downcast::<LoraFile>(payload, self.name())?;
        forward_lora_payload(cfg, base, lf, name, x, y)
    }
}
