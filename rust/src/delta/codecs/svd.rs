//! On-the-fly SVD codec: no precomputed artifact — `load` reads the
//! tenant's dense fine-tune, forms `Δ = W_fine − W_base` per linear, and
//! truncates it to rank-`r` factors with the in-tree Jacobi SVD
//! ([`crate::delta::svd`]). The payload is the same [`LoraFile`] the
//! `lora` codec uses, so assembly/apply/decode all ride the existing
//! low-rank path (`decode_lora`).
//!
//! This is the registry's existence proof that a new delta format costs
//! one module + one registry line: the codec is ~100 lines of glue over
//! math the repo already had. Trade-off: load is compute-heavy (a Jacobi
//! sweep per linear), so payloads are priced at their resident bytes but
//! cost CPU time on first fetch — the delta store's LRU makes that a
//! once-per-eviction-cycle cost.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::config::{Manifest, ModelConfig, TenantEntry};
use crate::delta::codec::{downcast, DeltaCodec, LoadCtx, Model, Payload};
use crate::delta::svd::low_rank_factors;
use crate::runtime::client::Runtime;
use crate::runtime::variants::StackedArgs;
use crate::store::delta_file::{load_model, LoraFile};
use crate::tensor::Tensor;

use super::lora::{assemble_lora_payloads, forward_lora_payload,
                  materialize_lora_payload};

pub struct SvdCodec {
    /// Truncation rank; must not exceed any linear's `min(n, m)` (the
    /// AOT low-rank ABI is lowered for one fixed rank, so clamping is
    /// an error, not a fallback).
    pub rank: usize,
}

impl Default for SvdCodec {
    fn default() -> Self {
        Self { rank: 16 }
    }
}

impl DeltaCodec for SvdCodec {
    fn name(&self) -> &'static str {
        "svd"
    }

    fn exec_kind(&self) -> &'static str {
        "decode_lora"
    }

    fn needs_base(&self) -> bool {
        true
    }

    /// Factorizes the dense fine-tune directly; there is no separate
    /// initial/distilled artifact.
    fn artifact_path(&self, manifest: &Manifest, tenant: &TenantEntry,
                     _distilled: bool, levels: usize) -> Option<PathBuf> {
        if levels > 1 {
            return None;    // load-time factors have no fidelity tiers
        }
        Some(manifest.path(&tenant.finetune))
    }

    fn load(&self, path: &Path, ctx: &LoadCtx) -> Result<Rc<dyn Payload>> {
        let base = ctx.base.context(
            "svd codec needs the base model to factorize W_fine − W_base")?;
        let fine = load_model(path, ctx.cfg)
            .with_context(|| format!("svd codec: {path:?}"))?;
        let mut a = HashMap::new();
        let mut b = HashMap::new();
        // The decode_lora executable is lowered for one fixed rank, so
        // silently clamping would produce factors the AOT ABI rejects
        // with an opaque XLA shape error at decode time — fail here with
        // the real reason instead.
        let min_dim = ctx.cfg.linear_names().iter()
            .map(|n| { let (r, c) = ctx.cfg.linear_shape(n); r.min(c) })
            .min().unwrap_or(self.rank);
        if min_dim < self.rank {
            anyhow::bail!(
                "svd codec rank {} exceeds the smallest linear dimension \
{min_dim} of model {}", self.rank, ctx.cfg.name);
        }
        let rank = self.rank;
        for name in ctx.cfg.linear_names() {
            let (n, m) = ctx.cfg.linear_shape(&name);
            let wb = base.get(&name)
                .with_context(|| format!("base missing {name}"))?
                .as_f32()?;
            let wf = fine[&name].as_f32()?;
            let d: Vec<f32> = wf.iter().zip(&wb).map(|(f, x)| f - x)
                .collect();
            let (ad, bu) = low_rank_factors(
                &Tensor::new(vec![n, m], d), rank);
            a.insert(name.clone(), ad.data().to_vec());
            b.insert(name.clone(), bu.data().to_vec());
        }
        let mut extras = HashMap::new();
        for name in ctx.cfg.nonlinear_names() {
            extras.insert(name.clone(), fine[&name].clone());
        }
        Ok(Rc::new(LoraFile { rank, a, b, extras }))
    }

    fn assemble(&self, rt: &Runtime, cfg: &ModelConfig,
                payloads: &[&dyn Payload], batch: usize)
                -> Result<StackedArgs> {
        let loras: Vec<&LoraFile> = payloads.iter()
            .map(|p| downcast::<LoraFile>(*p, self.name()))
            .collect::<Result<_>>()?;
        assemble_lora_payloads(rt, cfg, &loras, batch)
    }

    fn materialize(&self, cfg: &ModelConfig, base: &Model,
                   payload: &dyn Payload) -> Result<Rc<Model>> {
        let lf = downcast::<LoraFile>(payload, self.name())?;
        materialize_lora_payload(cfg, base, lf).map(Rc::new)
    }

    fn forward_linear(&self, cfg: &ModelConfig, base: &Model,
                      payload: &dyn Payload, name: &str, x: &[f32],
                      y: &mut [f32]) -> Result<()> {
        let lf = downcast::<LoraFile>(payload, self.name())?;
        forward_lora_payload(cfg, base, lf, name, x, y)
    }
}
