//! Iterative BitDelta (paper §4.2 "Ablation over fidelity of Δ",
//! Fig. 3 / Table 9): apply the 1-bit quantizer successively, each round
//! treating the previously compressed model as the base, yielding `k`
//! independent (mask, scale) pairs per matrix.
//!
//! Unlike widening to a k-bit integer grid, each mask gets an *arbitrary*
//! scale — the property the paper calls out as the advantage of this
//! scheme.

use std::collections::HashMap;

use anyhow::Result;

use crate::config::ModelConfig;
use crate::delta::packing::{pack_signs, unpack_signs};
use crate::store::bdw::RawTensor;
use crate::store::delta_file::{DeltaFile, MaskLevel};

/// Compress with `levels` successive 1-bit masks.
pub fn compress_iterative(cfg: &ModelConfig,
                          base: &HashMap<String, RawTensor>,
                          fine: &HashMap<String, RawTensor>,
                          levels: usize) -> Result<DeltaFile> {
    if levels == 0 {
        anyhow::bail!("iterative compression needs >= 1 mask level");
    }
    let lin = cfg.linear_names();

    // residual deltas, updated level by level
    let mut residual: HashMap<String, Vec<f32>> = HashMap::new();
    for name in &lin {
        let wb = base[name].as_f32()?;
        let wf = fine[name].as_f32()?;
        residual.insert(name.clone(),
                        wf.iter().zip(&wb).map(|(f, b)| f - b).collect());
    }

    let mut out_levels = Vec::with_capacity(levels);
    for _ in 0..levels {
        let mut bits = HashMap::new();
        let mut scales = Vec::with_capacity(lin.len());
        for name in &lin {
            let (_, m) = cfg.linear_shape(name);
            // lint: allow(unwrap, residual was built from this same
            // `lin` name list a few lines up)
            let d = residual.get_mut(name).unwrap();
            let alpha = (d.iter().map(|x| x.abs() as f64).sum::<f64>()
                / d.len() as f64) as f32;
            let packed = pack_signs(d, m);
            let signs = unpack_signs(&packed, m);
            for (dv, s) in d.iter_mut().zip(&signs) {
                *dv -= alpha * s;
            }
            bits.insert(name.clone(), packed);
            scales.push(alpha);
        }
        out_levels.push(MaskLevel { bits, scales });
    }

    let mut extras = HashMap::new();
    for name in cfg.nonlinear_names() {
        extras.insert(name.clone(), fine[&name].clone());
    }
    Ok(DeltaFile { levels: out_levels, extras })
}

/// Per-level residual Frobenius error of one linear — the quantity that
/// must shrink monotonically as fidelity grows.
pub fn residual_curve(cfg: &ModelConfig,
                      base: &HashMap<String, RawTensor>,
                      fine: &HashMap<String, RawTensor>,
                      delta: &DeltaFile, name: &str) -> Result<Vec<f32>> {
    let (_, m) = cfg.linear_shape(name);
    let wb = base[name].as_f32()?;
    let wf = fine[name].as_f32()?;
    // lint: allow(unwrap, linear_shape(name) above already panicked on
    // any name outside linear_names())
    let idx = cfg.linear_names().iter().position(|n| n == name).unwrap();
    let mut recon = vec![0f32; wb.len()];
    let mut out = Vec::new();
    for level in &delta.levels {
        let alpha = level.scales[idx];
        let signs = unpack_signs(&level.bits[name], m);
        for (r, s) in recon.iter_mut().zip(&signs) {
            *r += alpha * s;
        }
        let err: f64 = wf.iter().zip(&wb).zip(&recon)
            .map(|((f, b), r)| (((f - b) - r) as f64).powi(2)).sum();
        out.push(err.sqrt() as f32);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig { name: "tiny".into(), vocab_size: 16, d_model: 8,
                      n_layers: 1, n_heads: 2, d_ff: 16, max_seq_len: 16,
                      rope_theta: 1e4, norm_eps: 1e-5 }
    }

    fn pair(cfg: &ModelConfig) -> (HashMap<String, RawTensor>,
                                   HashMap<String, RawTensor>) {
        let base: HashMap<String, RawTensor> = cfg.param_names()
            .into_iter().enumerate().map(|(i, n)| {
                let shape = cfg.param_shape(&n);
                let t = Tensor::randn(shape.clone(), 100 + i as u64);
                (n, RawTensor::f32(shape, t.data()))
            }).collect();
        let fine = base.iter().map(|(n, t)| {
            let v = t.as_f32().unwrap();
            let noise = Tensor::randn(vec![v.len()], 999);
            let fv: Vec<f32> = v.iter().zip(noise.data())
                .map(|(a, b)| a + 0.03 * b).collect();
            (n.clone(), RawTensor::f32(t.shape.clone(), &fv))
        }).collect();
        (base, fine)
    }

    #[test]
    fn residual_strictly_decreases() {
        let cfg = tiny_cfg();
        let (base, fine) = pair(&cfg);
        let d = compress_iterative(&cfg, &base, &fine, 6).unwrap();
        let name = cfg.linear_names()[0].clone();
        let curve = residual_curve(&cfg, &base, &fine, &d, &name).unwrap();
        for w in curve.windows(2) {
            assert!(w[1] < w[0], "curve not decreasing: {curve:?}");
        }
    }

    #[test]
    fn scales_decay() {
        let cfg = tiny_cfg();
        let (base, fine) = pair(&cfg);
        let d = compress_iterative(&cfg, &base, &fine, 5).unwrap();
        for i in 0..cfg.linear_names().len() {
            let s: Vec<f32> = d.levels.iter().map(|l| l.scales[i]).collect();
            for w in s.windows(2) {
                assert!(w[1] < w[0], "scales not decaying: {s:?}");
            }
        }
    }

    #[test]
    fn zero_levels_is_an_error_not_a_panic() {
        let cfg = tiny_cfg();
        let (base, fine) = pair(&cfg);
        let e = compress_iterative(&cfg, &base, &fine, 0)
            .unwrap_err().to_string();
        assert!(e.contains(">= 1"), "{e}");
    }

    #[test]
    fn level1_matches_plain_compress() {
        let cfg = tiny_cfg();
        let (base, fine) = pair(&cfg);
        let it = compress_iterative(&cfg, &base, &fine, 1).unwrap();
        let plain = crate::delta::bitdelta::compress(&cfg, &base, &fine)
            .unwrap().delta;
        assert_eq!(it.levels[0].scales, plain.levels[0].scales);
        for name in cfg.linear_names() {
            assert_eq!(it.levels[0].bits[&name], plain.levels[0].bits[&name]);
        }
    }
}
