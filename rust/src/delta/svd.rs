//! One-sided Jacobi SVD and the low-rank delta baseline.
//!
//! Used for (a) Table 1's SVD-compression comparator and (b) Figure 2's
//! cumulative-explained-variance series showing full-parameter fine-tune
//! deltas are high-rank. Our matrices are at most a few hundred square, so
//! a dependency-free Jacobi sweep is plenty.

use crate::tensor::Tensor;

/// Thin SVD `A = U·diag(s)·Vᵀ` with singular values sorted descending.
#[derive(Debug, Clone)]
pub struct Svd {
    /// `[n, k]`, k = min(n, m).
    pub u: Tensor,
    /// `k` singular values, descending.
    pub s: Vec<f32>,
    /// `[k, m]` (rows are right singular vectors).
    pub vt: Tensor,
}

/// One-sided Jacobi SVD: orthogonalise the columns of A by plane
/// rotations; column norms become singular values.
pub fn svd(a: &Tensor) -> Svd {
    let (n, m) = a.dims2();
    // Work on Aᵀ if m > n so the rotated matrix has ≤ columns.
    if m > n {
        let t = svd(&a.t());
        return Svd { u: t.vt.t(), s: t.s, vt: t.u.t() };
    }
    // Here n >= m: rotate columns of A (n x m), accumulate V (m x m).
    let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let mut v = vec![0f64; m * m];
    for i in 0..m {
        v[i * m + i] = 1.0;
    }

    let col_dot = |w: &[f64], p: usize, q: usize| -> f64 {
        (0..n).map(|r| w[r * m + p] * w[r * m + q]).sum()
    };

    let max_sweeps = 30;
    let eps = 1e-12;
    for _ in 0..max_sweeps {
        let mut off = 0f64;
        for p in 0..m {
            for q in (p + 1)..m {
                let app = col_dot(&w, p, p);
                let aqq = col_dot(&w, q, q);
                let apq = col_dot(&w, p, q);
                off += apq * apq;
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for r in 0..n {
                    let wp = w[r * m + p];
                    let wq = w[r * m + q];
                    w[r * m + p] = c * wp - s * wq;
                    w[r * m + q] = s * wp + c * wq;
                }
                for r in 0..m {
                    let vp = v[r * m + p];
                    let vq = v[r * m + q];
                    v[r * m + p] = c * vp - s * vq;
                    v[r * m + q] = s * vp + c * vq;
                }
            }
        }
        if off.sqrt() < 1e-14 {
            break;
        }
    }

    // singular values = column norms; U = W / s
    let mut sv: Vec<(f64, usize)> = (0..m).map(|j| {
        let norm: f64 = (0..n).map(|r| w[r * m + j].powi(2)).sum();
        (norm.sqrt(), j)
    }).collect();
    sv.sort_by(|a, b| b.0.total_cmp(&a.0));

    let mut u = vec![0f32; n * m];
    let mut vt = vec![0f32; m * m];
    let mut s_out = Vec::with_capacity(m);
    for (rank, &(sval, j)) in sv.iter().enumerate() {
        s_out.push(sval as f32);
        let inv = if sval > 1e-20 { 1.0 / sval } else { 0.0 };
        for r in 0..n {
            u[r * m + rank] = (w[r * m + j] * inv) as f32;
        }
        for r in 0..m {
            vt[rank * m + r] = v[r * m + j] as f32;
        }
    }
    Svd { u: Tensor::new(vec![n, m], u), s: s_out,
          vt: Tensor::new(vec![m, m], vt) }
}

/// Rank-r truncation factors in the serving ABI:
/// `a_down [r, m]`, `b_up [n, r]` with `Δ ≈ b_up @ a_down`
/// (A = U√Σ_r as b_up, B = √Σ_r·Vᵀ as a_down — paper §4.2).
pub fn low_rank_factors(delta: &Tensor, rank: usize) -> (Tensor, Tensor) {
    let (n, m) = delta.dims2();
    let r = rank.min(n).min(m);
    let d = svd(delta);
    let mut a_down = vec![0f32; r * m];
    let mut b_up = vec![0f32; n * r];
    for k in 0..r {
        let root = d.s[k].max(0.0).sqrt();
        for j in 0..m {
            a_down[k * m + j] = root * d.vt.data()[k * m + j];
        }
        for i in 0..n {
            b_up[i * r + k] = root * d.u.data()[i * d.s.len() + k];
        }
    }
    (Tensor::new(vec![r, m], a_down), Tensor::new(vec![n, r], b_up))
}

/// Cumulative explained variance: `cumsum(σ²)/sum(σ²)` (Figure 2 series).
pub fn cumulative_explained_variance(delta: &Tensor) -> Vec<f64> {
    let d = svd(delta);
    let e: Vec<f64> = d.s.iter().map(|&x| (x as f64).powi(2)).collect();
    let total: f64 = e.iter().sum();
    let mut acc = 0.0;
    e.iter().map(|&x| {
        acc += x;
        if total > 0.0 { acc / total } else { 1.0 }
    }).collect()
}

/// Effective rank at a CEV threshold (how many components to reach
/// `thresh` of the variance) — the scalar Figure 2 is summarised by.
pub fn rank_at_cev(delta: &Tensor, thresh: f64) -> usize {
    cumulative_explained_variance(delta).iter()
        .position(|&c| c >= thresh)
        .map(|p| p + 1)
        .unwrap_or(delta.dims2().0.min(delta.dims2().1))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reconstruct(d: &Svd) -> Tensor {
        let (n, _) = d.u.dims2();
        let k = d.s.len();
        let m = d.vt.dims2().1;
        let mut out = vec![0f32; n * m];
        for i in 0..n {
            for kk in 0..k {
                let us = d.u.data()[i * k + kk] * d.s[kk];
                for j in 0..m {
                    out[i * m + j] += us * d.vt.data()[kk * m + j];
                }
            }
        }
        Tensor::new(vec![n, m], out)
    }

    #[test]
    fn svd_reconstructs() {
        let a = Tensor::randn(vec![12, 8], 42);
        let d = svd(&a);
        let r = reconstruct(&d);
        let err = a.sub(&r).frob_norm() / a.frob_norm();
        assert!(err < 1e-4, "reconstruction err {err}");
    }

    #[test]
    fn svd_wide_matrix() {
        let a = Tensor::randn(vec![6, 14], 43);
        let d = svd(&a);
        let r = reconstruct(&d);
        let err = a.sub(&r).frob_norm() / a.frob_norm();
        assert!(err < 1e-4, "reconstruction err {err}");
    }

    #[test]
    fn singular_values_descending_nonneg() {
        let a = Tensor::randn(vec![10, 10], 44);
        let d = svd(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(d.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn low_rank_exact_on_low_rank_input() {
        // rank-2 matrix: outer products
        let u = Tensor::randn(vec![9, 2], 45);
        let v = Tensor::randn(vec![2, 7], 46);
        let a = u.matmul(&v);
        let (ad, bu) = low_rank_factors(&a, 2);
        let r = bu.matmul(&ad);
        let err = a.sub(&r).frob_norm() / a.frob_norm();
        assert!(err < 1e-3, "err {err}");
    }

    #[test]
    fn cev_monotone_to_one() {
        let a = Tensor::randn(vec![16, 16], 47);
        let cev = cumulative_explained_variance(&a);
        for w in cev.windows(2) {
            assert!(w[1] >= w[0] - 1e-12);
        }
        assert!((cev[cev.len() - 1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn random_matrix_is_high_rank() {
        // the Fig. 2 phenomenon: an i.i.d. delta needs most components
        let a = Tensor::randn(vec![32, 32], 48);
        assert!(rank_at_cev(&a, 0.9) > 16);
    }

    #[test]
    fn low_rank_matrix_is_low_rank() {
        let u = Tensor::randn(vec![32, 3], 49);
        let v = Tensor::randn(vec![3, 32], 50);
        let a = u.matmul(&v);
        assert!(rank_at_cev(&a, 0.99) <= 3);
    }
}
