//! The `DeltaCodec` trait layer — one pluggable seam for every way a
//! tenant's fine-tune can be represented on top of the shared base.
//!
//! The paper's serving claim (one high-precision base + many cheap
//! per-tenant deltas) does not care *how* a delta is encoded: 1-bit
//! masks (BitDelta), low-rank factors (S-LoRA/SVD), or the full dense
//! fine-tune (the naive baseline) are all "a payload you can load,
//! account, stack into the decode ABI, fold into dense weights, and
//! apply on the CPU hot path". This module makes that contract explicit
//! so that new formats — mixed-precision deltas à la Delta-CoMe,
//! per-axis weight deltas, sparse masks — cost one module under
//! `rust/src/delta/codecs/` plus a one-line [`CodecRegistry`] entry,
//! not a fourth copy of the engine/store/bench stack.
//!
//! The contract, layer by layer:
//!
//! * **storage**  — [`DeltaCodec::artifact_path`] locates the tenant's
//!   on-disk artifact in the manifest; [`DeltaCodec::load`] parses it
//!   into an opaque [`Payload`] (with [`Payload::resident_bytes`] for
//!   the residency budget of [`crate::coordinator::deltastore`]).
//! * **runtime**  — [`DeltaCodec::exec_kind`] names the AOT executable a
//!   homogeneous batch of this codec decodes through, and
//!   [`DeltaCodec::assemble`] stacks payloads into its positional ABI
//!   (a flat [`StackedArgs`]).
//! * **fallback** — [`DeltaCodec::materialize`] folds a payload into
//!   dense weights. This is the universal denominator that powers
//!   **mixed-format batches**: when one decode batch holds tenants on
//!   different codecs, the engine materializes each slot and runs the
//!   stacked-dense (`decode_naive`) executable.
//! * **CPU apply**— [`DeltaCodec::forward_linear`] computes one linear's
//!   output `y = W_tenant @ x` through the format's native kernel
//!   (packed-bit GEMV, two-stage low-rank GEMV, dense GEMV) — the
//!   Figure 4 apply path behind one dispatch point.
//!
//! Invariant pinned by the codec tests: for every registered codec,
//! `forward_linear(payload, name, x)` ≡ `dense_gemv(materialize(payload)
//! [name], x)`.

use std::any::Any;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Result};

use crate::config::{Manifest, ModelConfig, TenantEntry};
use crate::runtime::client::Runtime;
use crate::runtime::variants::StackedArgs;
use crate::store::bdw::RawTensor;

/// Dense weight map, `param name -> tensor` (the shape every codec can
/// materialize into).
pub type Model = HashMap<String, RawTensor>;

/// Opaque per-tenant payload a codec loads from disk. Concrete types
/// (e.g. [`crate::store::delta_file::DeltaFile`]) are recovered by the
/// owning codec via [`downcast`].
pub trait Payload: Any {
    fn as_any(&self) -> &dyn Any;
    /// Host bytes this payload occupies while resident.
    ///
    /// **Contract**: this is the single currency the rest of the stack
    /// budgets in — the delta store's eviction budget, the per-codec
    /// accounting in the metrics exposition, and the delta-aware
    /// placement weight all charge exactly this number. It must
    /// reflect what is actually held in memory *after* load-time
    /// transforms: a multi-level `bitdelta` payload truncated to a
    /// fidelity tier reports the truncated (level-scaled) size, not
    /// the artifact's on-disk size.
    fn resident_bytes(&self) -> usize;
}

/// Recover a codec's concrete payload type, with a diagnosable error when
/// a payload of the wrong codec reaches it.
pub fn downcast<T: Payload>(payload: &dyn Payload, codec: &str)
                            -> Result<&T> {
    payload.as_any().downcast_ref::<T>().ok_or_else(|| anyhow!(
        "payload is not a {codec} payload (wrong codec for this tenant?)"))
}

/// Context handed to [`DeltaCodec::load`]: some codecs (e.g. `svd`,
/// which factorizes `W_fine − W_base` at load time) need the base model.
pub struct LoadCtx<'a> {
    pub cfg: &'a ModelConfig,
    pub base: Option<&'a Model>,
    /// Fidelity tier: how many mask levels of the artifact to serve
    /// (`0` = every level it carries). Only multi-level codecs
    /// (`bitdelta`) honor it; for the rest any value `<= 1` is valid.
    pub levels: usize,
}

/// Every AOT executable kind any codec (or the engine's naive mixed
/// path) may name — the one const table `exec_kind` strings come from.
///
/// These strings are load-bearing three times over: they key the
/// manifest's executable lookup, they name the python↔rust ABI
/// variant ([`crate::runtime::variants`]), and the engine counts
/// launches per kind (`bitdelta_{kind}_total` in
/// [`crate::coordinator::metric_names`]). The house lint
/// (`cargo xtask lint`, rule `exec-kind`) checks every `decode_*`
/// string literal in `src/` against this table, so a typo'd kind
/// fails lint instead of failing a manifest lookup at 2am.
pub const KNOWN_EXEC_KINDS: &[&str] = &[
    "decode_dense",
    "decode_naive",
    "decode_bitdelta",
    "decode_bitdelta_l2",
    "decode_bitdelta_l4",
    "decode_lora",
];

/// One delta representation: storage + ABI + kernels behind a single
/// trait object. See the module docs for the layer-by-layer contract.
pub trait DeltaCodec {
    /// Registry name (`bitdelta`, `lora`, `svd`, `dense`, …).
    fn name(&self) -> &'static str;

    /// AOT executable kind a homogeneous batch decodes through.
    fn exec_kind(&self) -> &'static str;

    /// Executable kind a batch needs when this codec serves a payload
    /// at fidelity tier `levels`, or `None` when the codec has no
    /// export covering that tier. Single-tier codecs (the default)
    /// only cover `levels <= 1`; multi-level codecs override this with
    /// their tier table so construction-time validation stays
    /// codec-agnostic.
    fn exec_kind_for_levels(&self, levels: usize)
                            -> Option<&'static str> {
        (levels <= 1).then_some(self.exec_kind())
    }

    /// Whether that executable takes the shared base linears as its
    /// leading arguments (false for formats that carry full weights).
    fn needs_base(&self) -> bool;

    /// Locate this tenant's artifact, or `None` if the tenant has no
    /// artifact in this format. `levels` is the requested fidelity tier
    /// (`<= 1` = the standard single-tier artifact); codecs without
    /// multi-level artifacts return `None` for `levels > 1` so the
    /// caller can fail with a diagnosable error instead of silently
    /// serving the wrong tier.
    fn artifact_path(&self, manifest: &Manifest, tenant: &TenantEntry,
                     distilled: bool, levels: usize) -> Option<PathBuf>;

    /// Parse an artifact into a payload.
    fn load(&self, path: &Path, ctx: &LoadCtx) -> Result<Rc<dyn Payload>>;

    /// Stack `payloads` (one per leading batch slot; slots past
    /// `payloads.len()` repeat the last payload — padding slots are
    /// masked by engine bookkeeping but must hold valid data) into the
    /// executable's positional ABI.
    ///
    /// Multi-level codecs that raise a mixed-tier batch to one
    /// homogeneous level count must pad the shallower slots with the
    /// **zero-scale padding convention**: an all-zero mask plane with
    /// scale `0.0` contributes exactly nothing to the decomposed
    /// forward, so every tenant's output stays bit-identical to being
    /// served alone at its own tier (pinned by the codec tests). A
    /// codec that retargets a different executable for the raised tier
    /// reports it in [`StackedArgs::exec_kind`].
    fn assemble(&self, rt: &Runtime, cfg: &ModelConfig,
                payloads: &[&dyn Payload], batch: usize)
                -> Result<StackedArgs>;

    /// Fold a payload into the dense fine-tuned weights
    /// `W_base ⊕ delta` — the universal fallback (mixed batches, eval).
    /// Returned as `Rc` so formats whose payload *is* the dense weights
    /// can share them instead of cloning a full model.
    fn materialize(&self, cfg: &ModelConfig, base: &Model,
                   payload: &dyn Payload) -> Result<Rc<Model>>;

    /// CPU apply path: `y = W_tenant @ x` for one canonical linear,
    /// through this format's native kernel.
    fn forward_linear(&self, cfg: &ModelConfig, base: &Model,
                      payload: &dyn Payload, name: &str, x: &[f32],
                      y: &mut [f32]) -> Result<()>;
}

/// Name → codec lookup. `builtin()` is the one place a new format is
/// wired in.
pub struct CodecRegistry {
    codecs: Vec<Rc<dyn DeltaCodec>>,
}

impl CodecRegistry {
    pub fn empty() -> Self {
        Self { codecs: Vec::new() }
    }

    /// All in-tree codecs. Adding a format == one module under
    /// `delta/codecs/` + one `register` line here.
    pub fn builtin() -> Self {
        use crate::delta::codecs;
        let mut r = Self::empty();
        r.register(Rc::new(codecs::bitdelta::BitDeltaCodec));
        r.register(Rc::new(codecs::lora::LoraCodec));
        r.register(Rc::new(codecs::svd::SvdCodec::default()));
        r.register(Rc::new(codecs::dense::DenseCodec));
        r
    }

    pub fn register(&mut self, codec: Rc<dyn DeltaCodec>) {
        self.codecs.retain(|c| c.name() != codec.name());
        self.codecs.push(codec);
    }

    /// Look a codec up by name (accepts `naive` as the historical alias
    /// of `dense`).
    pub fn get(&self, name: &str) -> Result<Rc<dyn DeltaCodec>> {
        let name = if name == "naive" { "dense" } else { name };
        self.codecs.iter().find(|c| c.name() == name).cloned()
            .ok_or_else(|| anyhow!(
                "unknown delta codec {name:?} — registered: {:?}",
                self.names()))
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.codecs.iter().map(|c| c.name()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Rc<dyn DeltaCodec>> {
        self.codecs.iter()
    }
}

impl Default for CodecRegistry {
    fn default() -> Self {
        Self::builtin()
    }
}

// ---------------------------------------------------------------------
// Shared stacking helpers (used by several codec `assemble` impls)
// ---------------------------------------------------------------------

/// Pick the payload for batch slot `b`, repeating the last one for
/// padding slots.
pub(crate) fn pick<'a, T: ?Sized>(items: &'a [&'a T], b: usize) -> &'a T {
    items[b.min(items.len() - 1)]
}

/// Stack per-tenant full-precision extras (`nonlinear_names` order) with
/// a leading batch axis. Returns the buffers plus staged byte count.
pub(crate) fn stack_extras(rt: &Runtime, cfg: &ModelConfig,
                           extras: &[&Model], batch: usize)
                           -> Result<(Vec<xla::PjRtBuffer>, usize)> {
    let mut buffers = Vec::new();
    let mut staged = 0usize;
    for name in cfg.nonlinear_names() {
        let shape = cfg.param_shape(&name);
        let elems: usize = shape.iter().product();
        let mut stacked = Vec::with_capacity(batch * elems);
        for b in 0..batch {
            let t = pick(extras, b).get(&name).ok_or_else(|| anyhow!(
                "payload missing extra tensor {name}"))?;
            stacked.extend_from_slice(&t.as_f32()?);
        }
        staged += stacked.len() * 4;
        let mut full = vec![batch];
        full.extend(&shape);
        buffers.push(rt.upload_f32(&stacked, &full)?);
    }
    Ok((buffers, staged))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registry_has_all_four() {
        let r = CodecRegistry::builtin();
        for name in ["bitdelta", "lora", "svd", "dense"] {
            assert!(r.get(name).is_ok(), "missing codec {name}");
        }
        assert_eq!(r.names().len(), 4);
    }

    #[test]
    fn naive_aliases_dense() {
        let r = CodecRegistry::builtin();
        assert_eq!(r.get("naive").unwrap().name(), "dense");
    }

    #[test]
    fn unknown_codec_lists_registered() {
        let r = CodecRegistry::builtin();
        let e = r.get("zstd").unwrap_err().to_string();
        assert!(e.contains("bitdelta"), "{e}");
    }

    #[test]
    fn register_replaces_same_name() {
        let mut r = CodecRegistry::builtin();
        let n = r.names().len();
        r.register(Rc::new(crate::delta::codecs::dense::DenseCodec));
        assert_eq!(r.names().len(), n);
    }

    /// Every exec kind a builtin codec can report — the default, and
    /// every fidelity tier it covers — comes from the const table.
    #[test]
    fn builtin_exec_kinds_come_from_the_table() {
        let r = CodecRegistry::builtin();
        for c in r.iter() {
            assert!(KNOWN_EXEC_KINDS.contains(&c.exec_kind()),
                    "{} reports unknown exec kind {}",
                    c.name(), c.exec_kind());
            for levels in 0..=8 {
                if let Some(k) = c.exec_kind_for_levels(levels) {
                    assert!(KNOWN_EXEC_KINDS.contains(&k),
                            "{} tier {levels} -> unknown kind {k}",
                            c.name());
                }
            }
        }
    }

    /// Every module under `src/delta/codecs/` is wired into
    /// `builtin()` — a new format cannot be silently half-added. The
    /// same invariant is enforced statically by `cargo xtask lint`
    /// (rule `codec-registered`); this test keeps it visible to
    /// `cargo test` alone.
    #[test]
    fn every_codec_module_is_registered() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("src/delta/codecs");
        let names = CodecRegistry::builtin().names();
        for entry in std::fs::read_dir(dir).unwrap() {
            let f = entry.unwrap().file_name();
            let f = f.to_string_lossy();
            let Some(module) = f.strip_suffix(".rs") else { continue };
            if module == "mod" {
                continue;
            }
            assert!(names.iter().any(|n| *n == module),
                    "codec module {module} missing from builtin()");
        }
    }
}
