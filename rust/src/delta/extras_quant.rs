//! Extension: compress the per-tenant *extras* (embeddings, LM head) —
//! the part the paper explicitly leaves to future work (Table 5: "We can
//! further compress the embedding and LM head layers, but leave this to
//! future work due to inconsistencies in tokenizer vocabularies").
//!
//! Our tenants share one tokenizer, so the blocker doesn't apply: we
//! quantize the per-tenant embedding/head *deltas* with per-row INT8 RTN
//! (norm vectors stay f32 — they are tiny and sensitive). At sim-s
//! shapes the extras are ~60% of the delta file, so this pushes the
//! measured compression factor well past the linears-only number.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::config::ModelConfig;
use crate::quant::rtn::{rtn_dequantize, rtn_quantize_matrix,
                        RtnQuantized};
use crate::store::bdw::RawTensor;
use crate::store::delta_file::DeltaFile;
use crate::tensor::Tensor;

/// An extras-compressed delta: the level-0 masks stay as-is; embeddings
/// and head are stored as INT8 deltas against the base model.
#[derive(Debug, Clone)]
pub struct CompressedExtras {
    /// name -> (quantized delta, base reference is implicit)
    pub quantized: HashMap<String, RtnQuantized>,
    /// untouched small params (norms)
    pub raw: HashMap<String, RawTensor>,
}

/// Which extras get the INT8 treatment.
fn is_big_extra(name: &str) -> bool {
    name == "tok_embed" || name == "lm_head"
}

/// Compress a delta's extras against the base model.
pub fn compress_extras(cfg: &ModelConfig,
                       base: &HashMap<String, RawTensor>,
                       delta: &DeltaFile) -> Result<CompressedExtras> {
    let mut quantized = HashMap::new();
    let mut raw = HashMap::new();
    for name in cfg.nonlinear_names() {
        let t = delta.extras.get(&name)
            .ok_or_else(|| anyhow::anyhow!("missing extra.{name}"))?;
        if is_big_extra(&name) {
            let fine = t.as_f32()?;
            let b = base[&name].as_f32()?;
            if fine.len() != b.len() {
                bail!("extra {name}: size mismatch");
            }
            let d: Vec<f32> = fine.iter().zip(&b).map(|(f, x)| f - x)
                .collect();
            let shape = t.shape.clone();
            let tens = Tensor::new(shape, d);
            quantized.insert(name, rtn_quantize_matrix(&tens, 8));
        } else {
            raw.insert(name, t.clone());
        }
    }
    Ok(CompressedExtras { quantized, raw })
}

/// Reconstruct full-precision extras (base + dequantized INT8 delta).
pub fn decompress_extras(cfg: &ModelConfig,
                         base: &HashMap<String, RawTensor>,
                         ce: &CompressedExtras)
                         -> Result<HashMap<String, RawTensor>> {
    let mut out = HashMap::new();
    for name in cfg.nonlinear_names() {
        if let Some(q) = ce.quantized.get(&name) {
            let d = rtn_dequantize(q);
            let b = base[&name].as_f32()?;
            let vals: Vec<f32> = b.iter().zip(d.data())
                .map(|(x, dv)| x + dv).collect();
            out.insert(name.clone(),
                       RawTensor::f32(base[&name].shape.clone(), &vals));
        } else {
            out.insert(name.clone(), ce.raw[&name].clone());
        }
    }
    Ok(out)
}

/// Byte accounting: delta size with INT8 extras vs fp32 extras.
pub fn extras_bytes(cfg: &ModelConfig, ce: &CompressedExtras) -> usize {
    let q: usize = ce.quantized.values().map(|q| q.nominal_bytes()).sum();
    let r: usize = ce.raw.values().map(|t| t.bytes.len()).sum();
    let _ = cfg;
    q + r
}

/// Apply extras compression to a delta file, returning the new file and
/// the (before, after) delta byte counts.
pub fn recompress_delta(cfg: &ModelConfig,
                        base: &HashMap<String, RawTensor>,
                        delta: &DeltaFile)
                        -> Result<(DeltaFile, usize, usize)> {
    let before = delta.delta_bytes();
    let ce = compress_extras(cfg, base, delta)?;
    let extras = decompress_extras(cfg, base, &ce)?;
    let mask_bytes: usize = delta.levels.iter().map(|l| {
        l.bits.values().map(|b| b.len()).sum::<usize>()
            + l.scales.len() * 4
    }).sum();
    let after = mask_bytes + extras_bytes(cfg, &ce);
    let new = DeltaFile { levels: delta.levels.clone(), extras };
    Ok((new, before, after))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::bitdelta::compress;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig { name: "t".into(), vocab_size: 32, d_model: 8,
                      n_layers: 1, n_heads: 2, d_ff: 16, max_seq_len: 8,
                      rope_theta: 1e4, norm_eps: 1e-5 }
    }

    fn pair(cfg: &ModelConfig) -> (HashMap<String, RawTensor>,
                                   HashMap<String, RawTensor>) {
        let base: HashMap<String, RawTensor> = cfg.param_names()
            .into_iter().enumerate().map(|(i, n)| {
                let shape = cfg.param_shape(&n);
                let t = Tensor::randn(shape.clone(), 50 + i as u64);
                (n, RawTensor::f32(shape, t.data()))
            }).collect();
        let fine = base.iter().map(|(n, t)| {
            let v = t.as_f32().unwrap();
            let noise = Tensor::randn(vec![v.len()], 777);
            let fv: Vec<f32> = v.iter().zip(noise.data())
                .map(|(a, b)| a + 0.05 * b).collect();
            (n.clone(), RawTensor::f32(t.shape.clone(), &fv))
        }).collect();
        (base, fine)
    }

    #[test]
    fn roundtrip_error_is_int8_small() {
        let cfg = tiny_cfg();
        let (base, fine) = pair(&cfg);
        let delta = compress(&cfg, &base, &fine).unwrap().delta;
        let ce = compress_extras(&cfg, &base, &delta).unwrap();
        let back = decompress_extras(&cfg, &base, &ce).unwrap();
        for name in ["tok_embed", "lm_head"] {
            let a = delta.extras[name].as_f32().unwrap();
            let b = back[name].as_f32().unwrap();
            let rel: f64 = a.iter().zip(&b)
                .map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>()
                .sqrt()
                / a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>()
                .sqrt();
            assert!(rel < 0.01, "{name} rel err {rel}");
        }
    }

    #[test]
    fn norms_pass_through_exactly() {
        let cfg = tiny_cfg();
        let (base, fine) = pair(&cfg);
        let delta = compress(&cfg, &base, &fine).unwrap().delta;
        let ce = compress_extras(&cfg, &base, &delta).unwrap();
        let back = decompress_extras(&cfg, &base, &ce).unwrap();
        assert_eq!(back["final_norm"], delta.extras["final_norm"]);
    }

    #[test]
    fn compression_factor_improves() {
        let cfg = tiny_cfg();
        let (base, fine) = pair(&cfg);
        let delta = compress(&cfg, &base, &fine).unwrap().delta;
        let (_, before, after) = recompress_delta(&cfg, &base, &delta)
            .unwrap();
        // INT8 extras shave most of the fp32 extras' bytes
        assert!(after < before, "{after} !< {before}");
        let embed_bytes = 2 * cfg.vocab_size * cfg.d_model * 4;
        assert!(before - after > embed_bytes / 2);
    }
}
