//! 1-bit sign packing — the bit-level ABI shared with the Pallas kernel
//! and python's `kernels/ref.py`.
//!
//! Signs are packed along the **input dimension** (row-major columns),
//! LSB-first: byte `k` of a row holds columns `8k..8k+8`; bit `j` set
//! means the value at column `8k+j` is strictly positive (+1); clear
//! means non-positive (-1). Paper Eq. 2: `Sign(0) = -1`.
//!
//! Rows whose logical width `m` is not a multiple of 8 are **padded to a
//! byte boundary**: the trailing `8·⌈m/8⌉ − m` bits of the last byte of
//! each row MUST be clear. Every consumer ([`unpack_signs`], the GEMV
//! kernels in [`crate::gemm::binary`]) honors the logical width and
//! rejects buffers with set padding bits instead of silently folding
//! them into the dot product.

/// Packed bytes per row for a logical width of `m` columns.
#[inline]
pub fn packed_row_bytes(m: usize) -> usize {
    (m + 7) / 8
}

/// Pack the sign pattern of a row-major `[rows, m]` matrix into
/// `[rows, ⌈m/8⌉]` bytes. Any `m ≥ 1` is accepted; partial trailing
/// bytes carry clear padding bits.
pub fn pack_signs(values: &[f32], m: usize) -> Vec<u8> {
    assert!(m > 0, "logical width must be positive");
    assert_eq!(values.len() % m, 0,
               "value count {} not a multiple of width {m}", values.len());
    let rows = values.len() / m;
    let mb = packed_row_bytes(m);
    let mut out = vec![0u8; rows * mb];
    for r in 0..rows {
        let row = &values[r * m..(r + 1) * m];
        let orow = &mut out[r * mb..(r + 1) * mb];
        for (k, chunk) in row.chunks(8).enumerate() {
            let mut byte = 0u8;
            for (j, &v) in chunk.iter().enumerate() {
                if v > 0.0 {
                    byte |= 1 << j;
                }
            }
            orow[k] = byte;
        }
    }
    out
}

/// Unpack to ±1.0 f32 at logical width `m`, inverse of [`pack_signs`].
/// Padding bits are skipped, not emitted.
pub fn unpack_signs(packed: &[u8], m: usize) -> Vec<f32> {
    let mb = packed_row_bytes(m);
    assert_eq!(packed.len() % mb, 0,
               "packed length {} not a multiple of the {mb}-byte row \
stride for width {m}", packed.len());
    let rows = packed.len() / mb;
    let mut out = Vec::with_capacity(rows * m);
    for r in 0..rows {
        let brow = &packed[r * mb..(r + 1) * mb];
        for j in 0..m {
            let byte = brow[j / 8];
            out.push(if byte >> (j % 8) & 1 == 1 { 1.0 } else { -1.0 });
        }
    }
    out
}

/// Expand one packed byte to 8 sign multipliers without branching —
/// used by the hot GEMV kernel. Returns entries in column order.
#[inline(always)]
pub fn byte_to_signs(byte: u8) -> [f32; 8] {
    let mut out = [0f32; 8];
    for j in 0..8 {
        // bit -> {0,1} -> {-1,+1}
        out[j] = ((byte >> j & 1) as i32 * 2 - 1) as f32;
    }
    out
}

/// Count of +1 bits in a packed matrix (used for sanity metrics: a healthy
/// fine-tune delta is ~50% positive).
pub fn popcount(packed: &[u8]) -> usize {
    packed.iter().map(|b| b.count_ones() as usize).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact() {
        let vals: Vec<f32> = (0..64)
            .map(|i| if i % 3 == 0 { -(i as f32) - 1.0 } else { i as f32 + 1.0 })
            .collect();
        let packed = pack_signs(&vals, 16);
        assert_eq!(packed.len(), 8);
        let signs = unpack_signs(&packed, 16);
        for (v, s) in vals.iter().zip(&signs) {
            assert_eq!(*s, if *v > 0.0 { 1.0 } else { -1.0 });
        }
    }

    #[test]
    fn zero_is_minus_one() {
        let packed = pack_signs(&[0.0; 8], 8);
        assert_eq!(packed, vec![0u8]);
        assert!(unpack_signs(&packed, 8).iter().all(|&s| s == -1.0));
    }

    #[test]
    fn lsb_first_convention() {
        // only column 0 positive -> bit 0 set -> byte == 1
        let mut vals = [-1.0f32; 8];
        vals[0] = 1.0;
        assert_eq!(pack_signs(&vals, 8), vec![1u8]);
        // only column 7 positive -> bit 7 -> byte == 128
        let mut vals = [-1.0f32; 8];
        vals[7] = 1.0;
        assert_eq!(pack_signs(&vals, 8), vec![128u8]);
    }

    #[test]
    fn non_multiple_of_eight_width_pads() {
        // width 5: one byte per row, bits 5..8 clear
        let vals = [1.0f32, -1.0, 1.0, -1.0, 1.0,   // row 0
                    -1.0, -1.0, -1.0, -1.0, 1.0];   // row 1
        let packed = pack_signs(&vals, 5);
        assert_eq!(packed.len(), 2);
        assert_eq!(packed[0], 0b0001_0101);
        assert_eq!(packed[1], 0b0001_0000);
        let signs = unpack_signs(&packed, 5);
        assert_eq!(signs.len(), 10);
        for (v, s) in vals.iter().zip(&signs) {
            assert_eq!(*s, if *v > 0.0 { 1.0 } else { -1.0 });
        }
    }

    #[test]
    fn padded_roundtrip_multi_byte_rows() {
        // width 11 -> 2 bytes/row, 5 padding bits
        let mut vals = Vec::new();
        for i in 0..33 {
            vals.push(if i % 4 == 0 { -1.0 } else { 1.0 });
        }
        let packed = pack_signs(&vals, 11);
        assert_eq!(packed.len(), 3 * 2);
        let signs = unpack_signs(&packed, 11);
        for (v, s) in vals.iter().zip(&signs) {
            assert_eq!(*s, if *v > 0.0 { 1.0 } else { -1.0 });
        }
    }

    #[test]
    fn byte_to_signs_matches_unpack() {
        for byte in [0u8, 1, 0x80, 0xAA, 0x55, 0xFF] {
            let a = byte_to_signs(byte);
            let b = unpack_signs(&[byte], 8);
            assert_eq!(&a[..], &b[..]);
        }
    }

    #[test]
    fn popcount_counts() {
        assert_eq!(popcount(&[0xFF, 0x00, 0x0F]), 12);
    }
}
