//! PJRT runtime: load the AOT HLO-text executables and run them from the
//! rust hot path.
//!
//! * [`client`] — thin wrapper over the `xla` crate: HLO-text loading
//!   (NEVER serialized protos — xla_extension 0.5.1 rejects jax≥0.5's
//!   64-bit ids; the text parser reassigns them), literal/buffer helpers,
//!   and device-resident argument sets.
//! * [`variants`] — the python↔rust executable ABI: the dense/base
//!   argument sets, the generic [`variants::StackedArgs`] per-tenant
//!   bundle codecs assemble, and decode-output parsing, in the exact
//!   positional order `aot.py` lowered.

pub mod client;
pub mod variants;

pub use client::{Executable, Runtime};
pub use variants::{BaseLinears, DenseArgs, StackedArgs};
