//! PJRT client wrapper: compile-once / execute-many over HLO text, with
//! device-resident buffers for weights that persist across decode steps
//! (the runtime realisation of "keep the base model in GPU memory and
//! hot-swap 1-bit deltas").

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Result};

/// Owns the PJRT client and a cache of compiled executables.
///
/// NOT `Send`: PJRT objects stay on the engine thread (the tokio
/// front-end talks to the engine over channels — see
/// [`crate::serving::engine`]).
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<String, std::rc::Rc<Executable>>,
}

/// One compiled executable plus load metadata.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    pub compile_seconds: f64,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self { client, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text file (cached by path).
    pub fn load(&mut self, path: impl AsRef<Path>)
                -> Result<std::rc::Rc<Executable>> {
        let key = path.as_ref().to_string_lossy().into_owned();
        if let Some(e) = self.cache.get(&key) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&key)
            .map_err(|e| anyhow!("parsing HLO text {key}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .map_err(|e| anyhow!("compiling {key}: {e}"))?;
        let compiled = std::rc::Rc::new(Executable {
            name: key.clone(),
            exe,
            compile_seconds: t0.elapsed().as_secs_f64(),
        });
        self.cache.insert(key, compiled.clone());
        Ok(compiled)
    }

    /// Upload an f32 array once; reuse across steps via [`Executable::run_buffers`].
    pub fn upload_f32(&self, data: &[f32], dims: &[usize])
                      -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32 {dims:?}: {e}"))
    }

    pub fn upload_u8(&self, data: &[u8], dims: &[usize])
                     -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload u8 {dims:?}: {e}"))
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize])
                      -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32 {dims:?}: {e}"))
    }

    pub fn upload_scalar_i32(&self, v: i32) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(&[v], &[], None)
            .map_err(|e| anyhow!("upload scalar: {e}"))
    }

    pub fn upload_scalar_f32(&self, v: f32) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(&[v], &[], None)
            .map_err(|e| anyhow!("upload scalar: {e}"))
    }
}

impl Executable {
    /// Execute over device buffers; returns all outputs as host
    /// literals. Handles both lowering shapes: tupled executables
    /// (`return_tuple=True`, one tuple buffer to decompose) and
    /// untupled ones (each output is its own buffer).
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer])
                       -> Result<Vec<xla::Literal>> {
        let out = self.exe.execute_b(args)
            .map_err(|e| anyhow!("execute {}: {e}", self.name))?;
        if out[0].len() > 1 {
            // untupled lowering: literalize each output buffer in order
            return out[0].iter()
                .map(|b| b.to_literal_sync()
                     .map_err(|e| anyhow!("fetch output {}: {e}",
                                          self.name)))
                .collect();
        }
        let lit = out[0][0].to_literal_sync()
            .map_err(|e| anyhow!("fetch output {}: {e}", self.name))?;
        lit.to_tuple().map_err(|e| anyhow!("tuple {}: {e}", self.name))
    }

    /// Execute and keep every output on device. With the untupled
    /// decode lowering (aot.py `untuple=True`) this returns
    /// `[logits, k, v]` as three separate `PjRtBuffer`s, each feedable
    /// straight back into the next step's argument list — the primary
    /// decode path (device-resident KV). For tupled executables the
    /// single returned buffer is the tuple itself and cannot be fed
    /// back; those go through [`Self::run_buffers`] instead.
    pub fn run_buffers_device(&self, args: &[&xla::PjRtBuffer])
                              -> Result<Vec<xla::PjRtBuffer>> {
        let mut out = self.exe.execute_b(args)
            .map_err(|e| anyhow!("execute {}: {e}", self.name))?;
        Ok(out.remove(0))
    }
}

/// Decode a literal into f32s.
pub fn literal_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal->f32: {e}"))
}

/// Shape dims of an array literal.
pub fn literal_dims(lit: &xla::Literal) -> Result<Vec<usize>> {
    let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e}"))?;
    Ok(shape.dims().iter().map(|&d| d as usize).collect())
}

/// Host-side staged argument: raw data + dims, uploadable on demand.
/// Lets the engine assemble argument lists cheaply and upload only what
/// changed since the previous step.
pub enum HostArg {
    F32(Vec<f32>, Vec<usize>),
    U8(Vec<u8>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostArg {
    pub fn upload(&self, rt: &Runtime) -> Result<xla::PjRtBuffer> {
        match self {
            HostArg::F32(d, s) => rt.upload_f32(d, s),
            HostArg::U8(d, s) => rt.upload_u8(d, s),
            HostArg::I32(d, s) => rt.upload_i32(d, s),
        }
    }

    pub fn byte_len(&self) -> usize {
        match self {
            HostArg::F32(d, _) => d.len() * 4,
            HostArg::U8(d, _) => d.len(),
            HostArg::I32(d, _) => d.len() * 4,
        }
    }
}
