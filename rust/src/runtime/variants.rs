//! Executable argument assembly — the positional ABI lowered by
//! `python/compile/aot.py`.
//!
//! Orders (must match `aot.py` exactly):
//!
//! * `logits_fwd`      : `[params…, tokens]`
//! * `prefill`         : `[params…, tokens, length, rope_scale]`
//! * `decode_dense`    : `[params…, k, v, pos, token, rope_scale]`
//! * `decode_naive`    : `[stacked params…, k, v, pos, token, rope_scale]`
//! * `decode_bitdelta` : `[base linears…(28), bits…(28), scales,
//!                        extras…(11), k, v, pos, token, rope_scale]`
//! * `decode_bitdelta_l{L}` : same order, but each `bits` buffer is
//!                        `[B, L, N, ⌈M/8⌉]` and `scales` is
//!                        `[B, L, n_linears]` — `L` stacked mask levels
//!                        summed inside the executable (Fig. 3 fidelity
//!                        tiers; zero-scale levels are no-ops)
//! * `decode_lora`     : `[base linears…(28), a…(28), b…(28),
//!                        extras…(11), k, v, pos, token, rope_scale]`
//!
//! `params…` is `ModelConfig::param_names()` order; linears/extras are
//! `linear_names()` / `nonlinear_names()` order. Per-tenant args carry a
//! leading batch axis and are re-stacked only when the batch composition
//! changes (the delta "hot-swap" path).
//!
//! The per-format stacking logic (what used to be `BitDeltaArgs`,
//! `NaiveArgs`, `LoraArgs`) lives with each codec under
//! [`crate::delta::codecs`]; every codec's `assemble` returns the same
//! [`StackedArgs`] — a flat, ABI-ordered buffer list the engine splices
//! between the (optional) shared base linears and the per-step tensors.

use anyhow::Result;
use std::collections::HashMap;

use crate::config::ModelConfig;
use crate::runtime::client::Runtime;
use crate::store::bdw::RawTensor;

/// Device-resident full weight set (dense / base model).
pub struct DenseArgs {
    pub buffers: Vec<xla::PjRtBuffer>,
}

impl DenseArgs {
    /// Upload every canonical parameter once.
    pub fn from_model(rt: &Runtime, cfg: &ModelConfig,
                      model: &HashMap<String, RawTensor>) -> Result<Self> {
        let mut buffers = Vec::new();
        for name in cfg.param_names() {
            let t = model.get(&name)
                .ok_or_else(|| anyhow::anyhow!("model missing {name}"))?;
            buffers.push(rt.upload_f32(&t.as_f32()?, &t.shape)?);
        }
        Ok(Self { buffers })
    }

    pub fn refs(&self) -> Vec<&xla::PjRtBuffer> {
        self.buffers.iter().collect()
    }
}

/// Device-resident shared base linears (uploaded once per base model).
pub struct BaseLinears {
    pub buffers: Vec<xla::PjRtBuffer>,
}

impl BaseLinears {
    pub fn from_model(rt: &Runtime, cfg: &ModelConfig,
                      base: &HashMap<String, RawTensor>) -> Result<Self> {
        let mut buffers = Vec::new();
        for name in cfg.linear_names() {
            let t = &base[&name];
            buffers.push(rt.upload_f32(&t.as_f32()?, &t.shape)?);
        }
        Ok(Self { buffers })
    }
}

/// Stacked per-tenant arguments for one batch composition, produced by a
/// [`crate::delta::codec::DeltaCodec`]. The buffers are already in the
/// codec's executable ABI order (everything between the shared base
/// linears — if the codec uses them — and the `k/v/pos/token/rope`
/// tail). Rebuilt only on composition change (hot-swap); kept on device
/// between steps.
pub struct StackedArgs {
    pub buffers: Vec<xla::PjRtBuffer>,
    pub batch: usize,
    /// Host bytes staged (== per-step upload saved by residency).
    pub staged_bytes: usize,
    /// Executable kind this stacking targets when it differs from the
    /// codec's default (`None` = use [`DeltaCodec::exec_kind`]). The
    /// bitdelta codec sets it for multi-level batches, whose level-axis
    /// ABI needs the matching `decode_bitdelta_l{L}` export.
    ///
    /// [`DeltaCodec::exec_kind`]: crate::delta::codec::DeltaCodec::exec_kind
    pub exec_kind: Option<&'static str>,
}

impl StackedArgs {
    pub fn refs(&self) -> Vec<&xla::PjRtBuffer> {
        self.buffers.iter().collect()
    }
}

/// Parsed decode-step output.
pub struct DecodeOut {
    /// `[B, V]` logits, row-major.
    pub logits: Vec<f32>,
    pub vocab: usize,
    /// Updated stacked caches `[L, B, H, S, hd]`.
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl DecodeOut {
    pub fn from_literals(mut lits: Vec<xla::Literal>, batch: usize)
                         -> Result<Self> {
        if lits.len() != 3 {
            anyhow::bail!("decode output: want 3 literals, got {}",
                          lits.len());
        }
        // lint: allow(unwrap, len == 3 was checked immediately above)
        let v = super::client::literal_f32(&lits.pop().unwrap())?;
        // lint: allow(unwrap, len == 3 was checked immediately above)
        let k = super::client::literal_f32(&lits.pop().unwrap())?;
        // lint: allow(unwrap, len == 3 was checked immediately above)
        let logits = super::client::literal_f32(&lits.pop().unwrap())?;
        let vocab = logits.len() / batch;
        Ok(Self { logits, vocab, k, v })
    }

    pub fn logits_row(&self, b: usize) -> &[f32] {
        &self.logits[b * self.vocab..(b + 1) * self.vocab]
    }
}
