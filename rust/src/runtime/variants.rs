//! Executable argument assembly — the positional ABI lowered by
//! `python/compile/aot.py`.
//!
//! Orders (must match `aot.py` exactly):
//!
//! * `logits_fwd`      : `[params…, tokens]`
//! * `prefill`         : `[params…, tokens, length, rope_scale]`
//! * `decode_dense`    : `[params…, k, v, pos, token, rope_scale]`
//! * `decode_naive`    : `[stacked params…, k, v, pos, token, rope_scale]`
//! * `decode_bitdelta` : `[base linears…(28), bits…(28), scales,
//!                        extras…(11), k, v, pos, token, rope_scale]`
//! * `decode_lora`     : `[base linears…(28), a…(28), b…(28),
//!                        extras…(11), k, v, pos, token, rope_scale]`
//!
//! `params…` is `ModelConfig::param_names()` order; linears/extras are
//! `linear_names()` / `nonlinear_names()` order. Per-tenant args carry a
//! leading batch axis and are re-stacked only when the batch composition
//! changes (the delta "hot-swap" path).

use anyhow::{bail, Result};
use std::collections::HashMap;

use crate::config::ModelConfig;
use crate::runtime::client::Runtime;
use crate::store::bdw::RawTensor;
use crate::store::delta_file::{DeltaFile, LoraFile};

/// Device-resident full weight set (dense / base model).
pub struct DenseArgs {
    pub buffers: Vec<xla::PjRtBuffer>,
}

impl DenseArgs {
    /// Upload every canonical parameter once.
    pub fn from_model(rt: &Runtime, cfg: &ModelConfig,
                      model: &HashMap<String, RawTensor>) -> Result<Self> {
        let mut buffers = Vec::new();
        for name in cfg.param_names() {
            let t = model.get(&name)
                .ok_or_else(|| anyhow::anyhow!("model missing {name}"))?;
            buffers.push(rt.upload_f32(&t.as_f32()?, &t.shape)?);
        }
        Ok(Self { buffers })
    }

    pub fn refs(&self) -> Vec<&xla::PjRtBuffer> {
        self.buffers.iter().collect()
    }
}

/// Device-resident stacked weights for the naive mode: every parameter
/// carries a leading `[B]` tenant axis (this is the memory hog the paper's
/// Figure 5 shows OOMing — we materialise it faithfully).
pub struct NaiveArgs {
    pub buffers: Vec<xla::PjRtBuffer>,
    pub batch: usize,
}

impl NaiveArgs {
    pub fn from_models(rt: &Runtime, cfg: &ModelConfig,
                       models: &[&HashMap<String, RawTensor>],
                       batch: usize) -> Result<Self> {
        if models.is_empty() || models.len() > batch {
            bail!("need 1..={batch} models, got {}", models.len());
        }
        let mut buffers = Vec::new();
        for name in cfg.param_names() {
            let shape = cfg.param_shape(&name);
            let elems: usize = shape.iter().product();
            let mut stacked = Vec::with_capacity(batch * elems);
            for b in 0..batch {
                let m = models[b.min(models.len() - 1)];
                stacked.extend_from_slice(&m[&name].as_f32()?);
            }
            let mut full_shape = vec![batch];
            full_shape.extend(&shape);
            buffers.push(rt.upload_f32(&stacked, &full_shape)?);
        }
        Ok(Self { buffers, batch })
    }

    pub fn refs(&self) -> Vec<&xla::PjRtBuffer> {
        self.buffers.iter().collect()
    }
}

/// Device-resident shared base linears (uploaded once per base model).
pub struct BaseLinears {
    pub buffers: Vec<xla::PjRtBuffer>,
}

impl BaseLinears {
    pub fn from_model(rt: &Runtime, cfg: &ModelConfig,
                      base: &HashMap<String, RawTensor>) -> Result<Self> {
        let mut buffers = Vec::new();
        for name in cfg.linear_names() {
            let t = &base[&name];
            buffers.push(rt.upload_f32(&t.as_f32()?, &t.shape)?);
        }
        Ok(Self { buffers })
    }
}

/// Stacked per-tenant BitDelta args for one batch composition:
/// 28 bits buffers + 1 scales + 11 extras. Rebuilt only on composition
/// change (hot-swap); kept on device between steps.
pub struct BitDeltaArgs {
    pub bits: Vec<xla::PjRtBuffer>,
    pub scales: xla::PjRtBuffer,
    pub extras: Vec<xla::PjRtBuffer>,
    pub batch: usize,
    /// Host bytes staged (== per-step upload saved by residency).
    pub staged_bytes: usize,
}

impl BitDeltaArgs {
    /// `deltas[b]` is the delta for batch slot `b`; slots past
    /// `deltas.len()` repeat the last delta (padding slots are masked by
    /// the engine's bookkeeping, but must hold valid data).
    pub fn assemble(rt: &Runtime, cfg: &ModelConfig,
                    deltas: &[&DeltaFile], batch: usize) -> Result<Self> {
        if deltas.is_empty() || deltas.len() > batch {
            bail!("need 1..={batch} deltas, got {}", deltas.len());
        }
        let pick = |b: usize| deltas[b.min(deltas.len() - 1)];
        let mut staged = 0usize;

        let mut bits = Vec::new();
        for name in cfg.linear_names() {
            let (n, mp) = cfg.packed_shape(&name);
            let mut stacked = Vec::with_capacity(batch * n * mp);
            for b in 0..batch {
                stacked.extend_from_slice(&pick(b).levels[0].bits[&name]);
            }
            staged += stacked.len();
            bits.push(rt.upload_u8(&stacked, &[batch, n, mp])?);
        }

        let n_lin = cfg.linear_names().len();
        let mut scales = Vec::with_capacity(batch * n_lin);
        for b in 0..batch {
            scales.extend_from_slice(&pick(b).levels[0].scales);
        }
        staged += scales.len() * 4;
        let scales = rt.upload_f32(&scales, &[batch, n_lin])?;

        let mut extras = Vec::new();
        for name in cfg.nonlinear_names() {
            let shape = cfg.param_shape(&name);
            let elems: usize = shape.iter().product();
            let mut stacked = Vec::with_capacity(batch * elems);
            for b in 0..batch {
                stacked.extend_from_slice(&pick(b).extras[&name].as_f32()?);
            }
            staged += stacked.len() * 4;
            let mut full = vec![batch];
            full.extend(&shape);
            extras.push(rt.upload_f32(&stacked, &full)?);
        }

        Ok(Self { bits, scales, extras, batch, staged_bytes: staged })
    }
}

/// Stacked per-tenant LoRA/SVD factors (S-LoRA mode).
pub struct LoraArgs {
    pub a: Vec<xla::PjRtBuffer>,
    pub b: Vec<xla::PjRtBuffer>,
    pub extras: Vec<xla::PjRtBuffer>,
    pub batch: usize,
    pub rank: usize,
}

impl LoraArgs {
    pub fn assemble(rt: &Runtime, cfg: &ModelConfig,
                    loras: &[&LoraFile], batch: usize) -> Result<Self> {
        if loras.is_empty() || loras.len() > batch {
            bail!("need 1..={batch} adapters, got {}", loras.len());
        }
        let rank = loras[0].rank;
        if loras.iter().any(|l| l.rank != rank) {
            bail!("mixed ranks in one batch");
        }
        let pick = |b: usize| loras[b.min(loras.len() - 1)];

        let (mut a_bufs, mut b_bufs) = (Vec::new(), Vec::new());
        for name in cfg.linear_names() {
            let (n, m) = cfg.linear_shape(&name);
            let mut sa = Vec::with_capacity(batch * rank * m);
            let mut sb = Vec::with_capacity(batch * n * rank);
            for bi in 0..batch {
                sa.extend_from_slice(&pick(bi).a[&name]);
                sb.extend_from_slice(&pick(bi).b[&name]);
            }
            a_bufs.push(rt.upload_f32(&sa, &[batch, rank, m])?);
            b_bufs.push(rt.upload_f32(&sb, &[batch, n, rank])?);
        }

        let mut extras = Vec::new();
        for name in cfg.nonlinear_names() {
            let shape = cfg.param_shape(&name);
            let elems: usize = shape.iter().product();
            let mut stacked = Vec::with_capacity(batch * elems);
            for bi in 0..batch {
                stacked.extend_from_slice(&pick(bi).extras[&name].as_f32()?);
            }
            let mut full = vec![batch];
            full.extend(&shape);
            extras.push(rt.upload_f32(&stacked, &full)?);
        }
        Ok(Self { a: a_bufs, b: b_bufs, extras, batch, rank })
    }
}

/// Parsed decode-step output.
pub struct DecodeOut {
    /// `[B, V]` logits, row-major.
    pub logits: Vec<f32>,
    pub vocab: usize,
    /// Updated stacked caches `[L, B, H, S, hd]`.
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl DecodeOut {
    pub fn from_literals(mut lits: Vec<xla::Literal>, batch: usize)
                         -> Result<Self> {
        if lits.len() != 3 {
            bail!("decode output: want 3 literals, got {}", lits.len());
        }
        let v = super::client::literal_f32(&lits.pop().unwrap())?;
        let k = super::client::literal_f32(&lits.pop().unwrap())?;
        let logits = super::client::literal_f32(&lits.pop().unwrap())?;
        let vocab = logits.len() / batch;
        Ok(Self { logits, vocab, k, v })
    }

    pub fn logits_row(&self, b: usize) -> &[f32] {
        &self.logits[b * self.vocab..(b + 1) * self.vocab]
    }
}
