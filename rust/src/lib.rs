//! # BitDelta — 1-bit fine-tune deltas, multi-tenant serving
//!
//! Rust reproduction of *"BitDelta: Your Fine-Tune May Only Be Worth One
//! Bit"* (Liu et al., NeurIPS 2024). The crate is the **L3 coordinator**
//! of a three-layer stack:
//!
//! * **L1** — Pallas kernel (`python/compile/kernels/`): the batched
//!   `W_INT1·A_FP16` delta GEMM, AOT-lowered into every serving
//!   executable.
//! * **L2** — JAX transformer (`python/compile/model.py`): the model
//!   forward in four serving modes (dense / naive / bitdelta / lora),
//!   lowered once to HLO text at build time.
//! * **L3** — this crate: PJRT runtime, weight/delta storage, the
//!   BitDelta compressor, the **delta codec registry**
//!   ([`delta::codec`]: pluggable formats — `bitdelta`, `lora`, `svd`,
//!   `dense` — behind one trait, with mixed-format decode batches), the
//!   multi-tenant serving engine (router, continuous batcher, delta
//!   hot-swap store, **paged KV cache** ([`kvcache`]: ref-counted
//!   block pool, copy-on-write block tables, cross-tenant shared-prefix
//!   reuse, dense-slab A/B fallback)), the **cluster layer**
//!   ([`cluster`]: an elastic set of worker engines behind one handle,
//!   with pluggable delta-aware tenant placement, failover,
//!   queue-pressure autoscaling with graceful drain, and front-door
//!   admission control), the memory simulator, the eval harness, and
//!   every benchmark that regenerates the paper's tables and figures.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! `repro` binary and the examples are self-contained.
//!
//! ## Quick tour
//!
//! ```no_run
//! use bitdelta::prelude::*;
//! use bitdelta::store::delta_file::load_model;
//!
//! // Offline: compress a fine-tune into a 1-bit delta (rust-native).
//! let cfg  = ModelConfig::sim_s();
//! let base = load_model("artifacts/models/sim-s-base.bdw", &cfg).unwrap();
//! let fine = load_model("artifacts/models/sim-s-chat.bdw", &cfg).unwrap();
//! let delta = compress(&cfg, &base, &fine).unwrap();
//! println!("compression factor: {:.1}x", delta.compression_factor(&cfg));
//! ```
//!
//! See `examples/` for the serving path, the repo-level `README.md`
//! for the CLI tour, and `docs/ARCHITECTURE.md` for the layer map.

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod delta;
pub mod eval;
pub mod gemm;
pub mod kvcache;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod simharness;
pub mod store;
pub mod sync;
pub mod tensor;
pub mod util;

/// Convenient re-exports for downstream users.
pub mod prelude {
    pub use crate::cluster::{
        Cluster, ClusterConfig, ClusterHandle, PlacementPolicy,
    };
    pub use crate::config::{Manifest, ModelConfig};
    pub use crate::delta::bitdelta::{compress, BitDeltaCompressed};
    pub use crate::delta::codec::{CodecRegistry, DeltaCodec, Payload};
    pub use crate::model::tokenizer::ByteTokenizer;
    pub use crate::serving::engine::{Engine, EngineConfig, ExecMode};
    pub use crate::serving::request::{Request, Response};
    pub use crate::store::bdw;
    pub use crate::tensor::Tensor;
}
