//! Runtime kernel dispatch + shared worker pool for the packed GEMV
//! engine.
//!
//! The binary-GEMV hot path ([`crate::gemm::binary`]) has one inner
//! primitive — "sum the activations under a packed sign row" — with
//! three interchangeable implementations:
//!
//! * **scalar** — the Four-Russians nibble-LUT walk; safe Rust,
//!   universal fallback, and the bit-exactness reference;
//! * **avx2** — x86_64 mask-expand over 8 lanes per packed byte
//!   (`_mm256_cmpeq` select + masked add), runtime-detected;
//! * **neon** — the aarch64 analog (`vtst` select over two 4-lane
//!   halves per byte), runtime-detected.
//!
//! [`active_tier`] picks once per call site: a forced tier if one is
//! set (env `BITDELTA_KERNEL=scalar|avx2|neon|auto`, or
//! [`force_tier`] from tests/benches), else the best tier the CPU
//! reports. Forcing a tier the host cannot run falls back to scalar,
//! so a tier sweep is portable across machines.
//!
//! **Threading.** [`run_rows`] tiles an output vector into contiguous
//! row chunks and fans them out over a lazily-spawned shared worker
//! pool (env `BITDELTA_THREADS`, or [`set_pool_threads`] — the CLI
//! `--threads` flag lands there). Chunks are sized by packed bytes so
//! small GEMVs stay inline, and each row's arithmetic is independent,
//! so results are bit-identical at every pool width. The caller
//! thread helps drain the queue while it waits, so a 1-worker pool
//! never deadlocks and an N-way `run_rows` uses N cores, not N−1.
//!
//! Adding a backend = one `row set-sum` kernel in `binary.rs`, one
//! [`Tier`] variant here, and arms in [`Tier::ALL`]/detection — the
//! property suite in `tests/properties.rs` sweeps every tier
//! automatically.

use std::collections::VecDeque;

// The process-global config cells below (forced tier, pool width, pool
// slot) live in `static`s, which loom types cannot (no const
// constructors) — they are configuration, deliberately outside every
// loom model (see the `crate::sync` module docs).
// lint: allow(std-sync, global config cells cannot be loom types)
use std::sync::atomic::{AtomicU8, AtomicUsize};

use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::{lock, wait, Arc, Condvar, Mutex, OnceLock};

/// One SIMD dispatch tier of the packed-GEMV engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Four-Russians nibble-LUT scalar kernel (universal fallback).
    Scalar,
    /// AVX2 mask-expand kernel (x86_64, runtime-detected).
    Avx2,
    /// NEON mask-expand kernel (aarch64, runtime-detected).
    Neon,
}

impl Tier {
    /// Every tier, for exhaustive sweeps in tests and benches.
    pub const ALL: [Tier; 3] = [Tier::Scalar, Tier::Avx2, Tier::Neon];

    /// Stable lowercase name (bench JSON rows, metrics, env parsing).
    pub fn name(&self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Avx2 => "avx2",
            Tier::Neon => "neon",
        }
    }

    /// Parse a tier name as used by `BITDELTA_KERNEL` (`"auto"` and
    /// unknown strings mean "no forced tier").
    pub fn from_name(s: &str) -> Option<Tier> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Tier::Scalar),
            "avx2" => Some(Tier::Avx2),
            "neon" => Some(Tier::Neon),
            _ => None,
        }
    }
}

impl std::fmt::Display for Tier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(target_arch = "x86_64")]
fn have_avx2() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn have_avx2() -> bool {
    false
}

#[cfg(target_arch = "aarch64")]
fn have_neon() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

#[cfg(not(target_arch = "aarch64"))]
fn have_neon() -> bool {
    false
}

/// Can this host actually execute `tier`?
pub fn available(tier: Tier) -> bool {
    match tier {
        Tier::Scalar => true,
        Tier::Avx2 => have_avx2(),
        Tier::Neon => have_neon(),
    }
}

/// Best tier the CPU reports, ignoring any forced override.
pub fn detected_tier() -> Tier {
    if have_avx2() {
        Tier::Avx2
    } else if have_neon() {
        Tier::Neon
    } else {
        Tier::Scalar
    }
}

// Forced-tier cell: 0 = auto (follow detection), 1..=3 = Tier::ALL
// index + 1. Seeded once from BITDELTA_KERNEL, then owned by
// force_tier (tests/benches sweep it).
fn forced_cell() -> &'static AtomicU8 {
    static CELL: OnceLock<AtomicU8> = OnceLock::new();
    CELL.get_or_init(|| {
        let init = std::env::var("BITDELTA_KERNEL")
            .ok()
            .and_then(|s| Tier::from_name(&s))
            .map_or(0, tier_code);
        AtomicU8::new(init)
    })
}

fn tier_code(t: Tier) -> u8 {
    match t {
        Tier::Scalar => 1,
        Tier::Avx2 => 2,
        Tier::Neon => 3,
    }
}

/// Force a dispatch tier (`None` restores auto-detection). Global —
/// tests that sweep tiers must serialize with each other.
pub fn force_tier(tier: Option<Tier>) {
    forced_cell().store(tier.map_or(0, tier_code), Ordering::SeqCst);
}

/// The currently forced tier, if any.
pub fn forced_tier() -> Option<Tier> {
    match forced_cell().load(Ordering::SeqCst) {
        1 => Some(Tier::Scalar),
        2 => Some(Tier::Avx2),
        3 => Some(Tier::Neon),
        _ => None,
    }
}

/// The tier the next kernel call will run: the forced tier when set
/// and runnable here (forcing an unavailable tier falls back to
/// scalar, keeping tier sweeps portable), else the detected best.
pub fn active_tier() -> Tier {
    match forced_tier() {
        Some(t) if available(t) => t,
        Some(_) => Tier::Scalar,
        None => detected_tier(),
    }
}

// ---------------------------------------------------------------------
// Shared worker pool
// ---------------------------------------------------------------------

type Task = Box<dyn FnOnce() + Send>;

/// The pool's wait/notify protocol object. `pub` only so the loom
/// models in `tests/loom_models.rs` can drive the *real* queue,
/// condvar, and shutdown-flag protocol with model-owned threads;
/// production code reaches it exclusively through [`run_rows`] and the
/// process-global pool.
#[doc(hidden)]
pub struct PoolInner {
    /// Pending tasks + shutdown flag; workers exit only once the flag
    /// is set *and* the queue is drained, so a resize never drops
    /// queued work.
    queue: Mutex<(VecDeque<Task>, bool)>,
    cv: Condvar,
}

impl PoolInner {
    // Written out (not derived) because loom's Mutex/Condvar are not
    // const-constructible and do not implement `Default`.
    #[doc(hidden)]
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        Self {
            queue: Mutex::new((VecDeque::new(), false)),
            cv: Condvar::new(),
        }
    }

    fn push(&self, task: Task) {
        lock(&self.queue).0.push_back(task);
        self.cv.notify_one();
    }

    /// Pop one task without blocking (callers helping to drain).
    fn try_pop(&self) -> Option<Task> {
        lock(&self.queue).0.pop_front()
    }

    /// Raise the shutdown flag and wake every worker. Workers still
    /// drain the queue before exiting (the respawn-vs-`run_rows` loom
    /// model pins exactly this: shutdown never drops queued work).
    #[doc(hidden)]
    pub fn shut_down(&self) {
        lock(&self.queue).1 = true;
        self.cv.notify_all();
    }
}

/// One pool worker's pump loop (`pub` for the loom models only).
#[doc(hidden)]
pub fn worker_loop(inner: Arc<PoolInner>) {
    loop {
        let task = {
            let mut q = lock(&inner.queue);
            loop {
                if let Some(t) = q.0.pop_front() {
                    break t;
                }
                if q.1 {
                    return;
                }
                q = wait(&inner.cv, q);
            }
        };
        task();
    }
}

#[cfg(not(loom))]
struct PoolHandle {
    inner: Arc<PoolInner>,
    workers: usize,
}

#[cfg(not(loom))]
fn pool_slot() -> &'static Mutex<Option<PoolHandle>> {
    static SLOT: OnceLock<Mutex<Option<PoolHandle>>> = OnceLock::new();
    SLOT.get_or_init(|| Mutex::new(None))
}

fn threads_cell() -> &'static AtomicUsize {
    static CELL: OnceLock<AtomicUsize> = OnceLock::new();
    CELL.get_or_init(|| {
        let init = std::env::var("BITDELTA_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .unwrap_or(1);
        AtomicUsize::new(resolve_threads(init))
    })
}

fn resolve_threads(n: usize) -> usize {
    if n == 0 {
        crate::sync::thread::available_parallelism()
            .map_or(1, |p| p.get())
    } else {
        n
    }
}

/// Set the kernel worker-pool width (`0` = one per available core).
/// The pool itself is (re)spawned lazily on the next tiled call.
pub fn set_pool_threads(n: usize) {
    threads_cell().store(resolve_threads(n), Ordering::SeqCst);
}

/// Current kernel worker-pool width (1 = no pool, all inline).
pub fn pool_threads() -> usize {
    threads_cell().load(Ordering::SeqCst).max(1)
}

/// The live pool at the configured width, spawning or resizing it if
/// needed. `None` when the configured width is 1 or no worker thread
/// could be spawned (callers then run inline).
#[cfg(not(loom))]
fn current_pool() -> Option<Arc<PoolInner>> {
    let want = pool_threads();
    let mut slot = lock(pool_slot());
    if want <= 1 {
        if let Some(old) = slot.take() {
            old.inner.shut_down();
        }
        return None;
    }
    if let Some(h) = slot.as_ref() {
        if h.workers == want {
            return Some(h.inner.clone());
        }
    }
    if let Some(old) = slot.take() {
        old.inner.shut_down();
    }
    // The caller thread is worker 0; spawn the other want-1.
    let inner = Arc::new(PoolInner::new());
    let mut spawned = 0;
    for i in 1..want {
        let arc = inner.clone();
        let spawn = crate::sync::thread::Builder::new()
            .name(format!("bitdelta-gemv-{i}"))
            .spawn(move || worker_loop(arc));
        if spawn.is_ok() {
            spawned += 1;
        }
    }
    if spawned == 0 {
        return None;
    }
    *slot = Some(PoolHandle { inner: inner.clone(), workers: want });
    Some(inner)
}

/// Under loom there is no process-global pool: statics cannot hold
/// loom types, and models drive [`scope_on`] with explicit pools and
/// model-owned threads instead.
#[cfg(loom)]
fn current_pool() -> Option<Arc<PoolInner>> {
    None
}

// ---------------------------------------------------------------------
// Scoped spawn (borrowing tasks on the shared pool)
// ---------------------------------------------------------------------

struct ScopeSync {
    remaining: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
}

/// A `std::thread::scope`-alike over the shared pool: spawned
/// closures may borrow from the caller's stack because the scope
/// blocks (helping to drain the queue) until every task finished.
/// `pub` only for the loom models (via [`scope_on`]).
#[doc(hidden)]
pub struct Scope<'env> {
    sync: Arc<ScopeSync>,
    pool: Option<Arc<PoolInner>>,
    _marker: std::marker::PhantomData<&'env mut ()>,
}

impl<'env> Scope<'env> {
    #[doc(hidden)]
    pub fn spawn<F: FnOnce() + Send + 'env>(&self, f: F) {
        let Some(pool) = &self.pool else {
            f();
            return;
        };
        *lock(&self.sync.remaining) += 1;
        let sync = self.sync.clone();
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(f);
        // SAFETY: lifetime erasure only — the fat pointer layout of
        // `Box<dyn FnOnce>` is lifetime-independent, and `Scope::drop`
        // blocks until `remaining == 0`, so the closure (and anything
        // it borrows from 'env) never outlives the borrowed data.
        let job: Task = unsafe {
            std::mem::transmute::<
                Box<dyn FnOnce() + Send + 'env>,
                Box<dyn FnOnce() + Send + 'static>,
            >(job)
        };
        pool.push(Box::new(move || {
            let r = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(job));
            if r.is_err() {
                sync.panicked.store(true, Ordering::SeqCst);
            }
            let mut left = lock(&sync.remaining);
            *left -= 1;
            if *left == 0 {
                sync.cv.notify_all();
            }
        }));
    }
}

impl Drop for Scope<'_> {
    fn drop(&mut self) {
        let Some(pool) = &self.pool else { return };
        // Help drain: run queued tasks (ours or a concurrent scope's)
        // on this thread instead of idling.
        while let Some(task) = pool.try_pop() {
            task();
        }
        let mut left = lock(&self.sync.remaining);
        while *left > 0 {
            left = wait(&self.sync.cv, left);
        }
    }
}

fn scope<'env, F: FnOnce(&Scope<'env>)>(f: F) {
    scope_on(current_pool(), f)
}

/// [`scope`] with an explicit pool instead of the process-global one.
/// `pub` only so the loom models can run the real scope protocol
/// (spawn / help-drain / wait) against a model-owned [`PoolInner`].
#[doc(hidden)]
pub fn scope_on<'env, F: FnOnce(&Scope<'env>)>(
    pool: Option<Arc<PoolInner>>,
    f: F,
) {
    let sc = Scope {
        sync: Arc::new(ScopeSync {
            remaining: Mutex::new(0),
            cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        }),
        pool,
        _marker: std::marker::PhantomData,
    };
    let sync = sc.sync.clone();
    f(&sc);
    drop(sc);
    if sync.panicked.load(Ordering::SeqCst) {
        panic!("bitdelta kernel worker task panicked");
    }
}

/// Minimum packed bytes a chunk must cover before it is worth a
/// cross-thread hand-off (empirically ~a few µs of scalar work).
const MIN_BYTES_PER_CHUNK: usize = 8 << 10;

/// Row-tiled parallel fill of `y`: splits the output rows into
/// contiguous chunks and calls `f(first_row, chunk)` for each, inline
/// when the pool is off or the matrix is small. `bytes_per_row` is
/// the packed input traffic per output row (levels × row bytes) and
/// sizes the chunks. Per-row results are independent of the split,
/// so output bits do not depend on the pool width.
pub fn run_rows<F>(y: &mut [f32], bytes_per_row: usize, f: &F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let rows = y.len();
    let threads = pool_threads();
    let min_rows = (MIN_BYTES_PER_CHUNK / bytes_per_row.max(1)).max(1);
    if threads <= 1 || rows < 2 * min_rows {
        f(0, y);
        return;
    }
    let chunks = threads.min(rows / min_rows).max(1);
    let per = (rows + chunks - 1) / chunks;
    scope(|s| {
        let mut rest = y;
        let mut start = 0;
        while !rest.is_empty() {
            let take = per.min(rest.len());
            let tmp = std::mem::take(&mut rest);
            let (head, tail) = tmp.split_at_mut(take);
            rest = tail;
            let r0 = start;
            start += take;
            s.spawn(move || f(r0, head));
        }
    });
}

/// Unit tests mutating the global tier/pool config (or asserting
/// bit-identity between two kernel calls) serialize on this lock so
/// the harness's default test parallelism cannot interleave them.
#[cfg(test)]
pub(crate) fn test_lock() -> crate::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_names_round_trip() {
        for t in Tier::ALL {
            assert_eq!(Tier::from_name(t.name()), Some(t));
        }
        assert_eq!(Tier::from_name("auto"), None);
        assert_eq!(Tier::from_name("AVX2"), Some(Tier::Avx2));
    }

    #[test]
    fn scalar_always_available_and_detection_is_runnable() {
        assert!(available(Tier::Scalar));
        assert!(available(detected_tier()));
    }

    #[test]
    fn forcing_unavailable_tier_falls_back_to_scalar() {
        let _g = test_lock();
        // At most one SIMD tier exists per arch, so the other one is
        // always the portable "unavailable" probe.
        let missing = if available(Tier::Avx2) {
            Tier::Neon
        } else {
            Tier::Avx2
        };
        force_tier(Some(missing));
        assert_eq!(active_tier(), Tier::Scalar);
        force_tier(Some(Tier::Scalar));
        assert_eq!(active_tier(), Tier::Scalar);
        force_tier(None);
        assert_eq!(active_tier(), detected_tier());
    }

    #[test]
    fn run_rows_covers_every_row_once_at_any_width() {
        let _g = test_lock();
        for threads in [1usize, 2, 5] {
            set_pool_threads(threads);
            // bytes_per_row=2048 → min_rows=4 → tiling engages.
            let mut y = vec![0f32; 37];
            run_rows(&mut y, 2048, &|r0, chunk: &mut [f32]| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v += (r0 + i) as f32;
                }
            });
            let want: Vec<f32> = (0..37).map(|r| r as f32).collect();
            assert_eq!(y, want, "threads={threads}");
        }
        set_pool_threads(1);
    }

    #[test]
    fn small_matrices_stay_inline() {
        let _g = test_lock();
        set_pool_threads(4);
        let mut y = vec![0f32; 8];
        // 1 byte/row → min_rows huge → must run as one inline chunk.
        run_rows(&mut y, 1, &|r0, chunk: &mut [f32]| {
            assert_eq!(r0, 0);
            assert_eq!(chunk.len(), 8);
            chunk.fill(1.0);
        });
        assert_eq!(y, vec![1.0; 8]);
        set_pool_threads(1);
    }
}
