//! Packed 1-bit delta GEMV — the CPU analog of BitBLAS's `W_INT1·A_FP16`
//! kernel (the "Kernel" brace of Eq. 6).
//!
//! Computes `y = α · Sign(Δ) · x` **directly from the packed bytes** —
//! the sign matrix is never materialised, so the weight stream is
//! `N·⌈M/8⌉` bytes instead of `4·N·M`: a 32× traffic reduction over the
//! f32 backbone (16× in the paper's fp16 terms). That traffic ratio is
//! the entire latency story of Figures 4 and 6.
//!
//! The kernels honor a **logical width** `m`: rows are stored padded to a
//! byte boundary (see [`crate::delta::packing`]) and the trailing padding
//! bits must be clear. All shape/padding validation happens up front in
//! the `try_*` variants, which return a [`KernelShapeError`] — malformed
//! packed buffers produce a clear error instead of a panic (or a silent
//! wrong answer) deep in the hot loop. The unsuffixed wrappers keep the
//! historical panicking signature for callers that have already
//! validated.
//!
//! Identity used to avoid per-bit sign selects:
//!
//! ```text
//! Σ_j s_j·x_j  =  Σ_set x_j − Σ_clear x_j  =  2·Σ_set x_j − Σ_all x_j
//! ```
//!
//! so the inner primitive is "sum the activations under a packed row's
//! set bits" ([`RowKernel::set_sum`]) and the row finishes with one
//! fused correction by the precomputed total. With clear padding bits
//! and zero-padded `x`, the identity holds unchanged at any logical
//! width.
//!
//! **Kernel engine.** The set-sum primitive is implemented per dispatch
//! tier — the Four-Russians nibble-LUT walk (scalar fallback +
//! bit-exactness reference), an AVX2 mask-expand loop, and a NEON
//! mask-expand loop — selected once per call by
//! [`crate::gemm::dispatch::active_tier`] (runtime feature detection,
//! forcible via `BITDELTA_KERNEL` for tests). Rows are tiled over the
//! shared worker pool with [`dispatch::run_rows`]; each row's
//! arithmetic is independent, so outputs are bit-identical at every
//! pool width.

use crate::delta::packing::packed_row_bytes;
use crate::gemm::dispatch::{self, Tier};

/// Shape/padding validation failure for a packed GEMV call.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KernelShapeError(pub String);

impl std::fmt::Display for KernelShapeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "packed gemv: {}", self.0)
    }
}

impl std::error::Error for KernelShapeError {}

fn err(msg: String) -> KernelShapeError {
    KernelShapeError(msg)
}

/// Validate a packed `[n, ⌈m/8⌉]` buffer against logical shape `[n, m]`
/// plus `x`/`y` lengths; rejects set padding bits (malformed buffers).
fn validate(bits: &[u8], n: usize, m: usize, x: &[f32], y: &[f32])
            -> Result<usize, KernelShapeError> {
    if m == 0 {
        return Err(err("logical width m must be positive".into()));
    }
    let mb = packed_row_bytes(m);
    if bits.len() != n * mb {
        return Err(err(format!(
            "bits buffer has {} bytes, want n*ceil(m/8) = {}*{} = {} \
for logical shape [{n}, {m}]", bits.len(), n, mb, n * mb)));
    }
    if x.len() != m {
        return Err(err(format!("x has {} entries, want m = {m}", x.len())));
    }
    if y.len() != n {
        return Err(err(format!("y has {} entries, want n = {n}", y.len())));
    }
    let pad = mb * 8 - m;
    if pad > 0 {
        // padding bits live in the high end of each row's last byte and
        // must be clear, else the 2·Σ_set − total identity is corrupted
        let mask: u8 = !0u8 << (8 - pad);
        for r in 0..n {
            let last = bits[r * mb + mb - 1];
            if last & mask != 0 {
                return Err(err(format!(
                    "malformed packed buffer: row {r} has set padding \
bits (last byte {last:#04x}, logical width {m})")));
            }
        }
    }
    Ok(mb)
}

/// Does the active dispatch tier have a compiled SIMD variant on this
/// target? (A forced tier the target cannot even compile for is
/// handled upstream: [`dispatch::active_tier`] never returns it.)
fn simd_compiled(tier: Tier) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        tier == Tier::Avx2
    }
    #[cfg(target_arch = "aarch64")]
    {
        tier == Tier::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let _ = tier;
        false
    }
}

/// 16-entry Four-Russians partial-sum tables, one per 4-column nibble
/// group of the zero-padded activations: `lut[g*16+v] = Σ_{bit j of v}
/// xp[4g+j]`, built incrementally in 15 adds per group. Shared by the
/// single- and multi-level scalar kernels.
fn build_lut(xp: &[f32], mb: usize) -> Vec<f32> {
    let groups = mb * 2;
    let mut lut = vec![0f32; groups * 16];
    for g in 0..groups {
        let xs = &xp[g * 4..g * 4 + 4];
        let t = &mut lut[g * 16..g * 16 + 16];
        for v in 1usize..16 {
            t[v] = t[v & (v - 1)] + xs[v.trailing_zeros() as usize];
        }
    }
    lut
}

/// Shared per-call preamble of every packed kernel: the dispatch tier,
/// the activations zero-padded to the byte boundary (padded columns
/// contribute 0 under any clear bit pattern), the `Σx` total behind
/// the `2·Σ_set − total` identity, and — on the scalar tier only —
/// the nibble tables (SIMD tiers mask-expand `xp` directly and skip
/// the O(4m) table build).
struct Prep {
    tier: Tier,
    xp: Vec<f32>,
    lut: Vec<f32>,
    total: f32,
}

impl Prep {
    fn new(x: &[f32], mb: usize) -> Self {
        let tier = dispatch::active_tier();
        let mut xp = x.to_vec();
        xp.resize(mb * 8, 0.0);
        let lut = if simd_compiled(tier) {
            Vec::new()
        } else {
            build_lut(&xp, mb)
        };
        let total: f32 = x.iter().sum();
        Prep { tier, xp, lut, total }
    }

    fn kernel(&self) -> RowKernel<'_> {
        match self.tier {
            #[cfg(target_arch = "x86_64")]
            Tier::Avx2 => RowKernel::Avx2 { xp: &self.xp },
            #[cfg(target_arch = "aarch64")]
            Tier::Neon => RowKernel::Neon { xp: &self.xp },
            _ => RowKernel::Scalar { lut: &self.lut },
        }
    }
}

/// One row's `Σ_set x` under the active dispatch tier. `Copy`, so the
/// row-tiling closures capture it by value and stay `Fn + Sync`.
#[derive(Clone, Copy)]
enum RowKernel<'a> {
    Scalar { lut: &'a [f32] },
    #[cfg(target_arch = "x86_64")]
    Avx2 { xp: &'a [f32] },
    #[cfg(target_arch = "aarch64")]
    Neon { xp: &'a [f32] },
}

impl RowKernel<'_> {
    #[inline]
    fn set_sum(&self, brow: &[u8]) -> f32 {
        match *self {
            RowKernel::Scalar { lut } => scalar_set_sum(brow, lut),
            #[cfg(target_arch = "x86_64")]
            // SAFETY: this variant is only built when AVX2 was
            // runtime-detected (Prep::kernel gates on active_tier),
            // and Prep zero-pads xp to 8 floats per packed byte.
            RowKernel::Avx2 { xp } => unsafe { avx2::set_sum(brow, xp) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: as above, with NEON runtime-detected.
            RowKernel::Neon { xp } => unsafe { neon::set_sum(brow, xp) },
        }
    }
}

/// Four-Russians walk: each weight byte costs two table lookups + two
/// adds instead of eight bit-extract/convert/multiply chains; two
/// accumulators hide the add latency.
#[inline]
fn scalar_set_sum(brow: &[u8], lut: &[f32]) -> f32 {
    let (mut a0, mut a1) = (0f32, 0f32);
    for (k, &byte) in brow.iter().enumerate() {
        let lo = (byte & 0xF) as usize;
        let hi = (byte >> 4) as usize;
        a0 += lut[(2 * k) * 16 + lo];
        a1 += lut[(2 * k + 1) * 16 + hi];
    }
    a0 + a1
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! AVX2 mask-expand row kernel: one packed byte selects 8 f32
    //! lanes at once (`cmpeq` against per-lane bit masks builds a
    //! select mask; `andps` zeroes unselected activations).

    use std::arch::x86_64::*;

    /// `Σ_{set bits} xp` for one packed row.
    ///
    /// # Safety
    ///
    /// AVX2 must be available on the running CPU, and `xp` must hold
    /// at least `bits.len() * 8` floats (zero-padded activations).
    #[target_feature(enable = "avx2")]
    pub unsafe fn set_sum(bits: &[u8], xp: &[f32]) -> f32 {
        // SAFETY: the caller contract above guarantees AVX2 is
        // available and `xp.len() >= bits.len() * 8`, so every
        // unaligned 8-lane load below reads in-bounds floats.
        unsafe {
            let bitsel = _mm256_setr_epi32(1, 2, 4, 8, 16, 32, 64, 128);
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            let mut k = 0usize;
            // 2-byte unroll on independent accumulators
            while k + 2 <= bits.len() {
                let m0 = _mm256_cmpeq_epi32(
                    _mm256_and_si256(_mm256_set1_epi32(bits[k] as i32),
                                     bitsel),
                    bitsel);
                let m1 = _mm256_cmpeq_epi32(
                    _mm256_and_si256(_mm256_set1_epi32(bits[k + 1] as i32),
                                     bitsel),
                    bitsel);
                let x0 = _mm256_loadu_ps(xp.as_ptr().add(k * 8));
                let x1 = _mm256_loadu_ps(xp.as_ptr().add(k * 8 + 8));
                acc0 = _mm256_add_ps(
                    acc0, _mm256_and_ps(_mm256_castsi256_ps(m0), x0));
                acc1 = _mm256_add_ps(
                    acc1, _mm256_and_ps(_mm256_castsi256_ps(m1), x1));
                k += 2;
            }
            if k < bits.len() {
                let m0 = _mm256_cmpeq_epi32(
                    _mm256_and_si256(_mm256_set1_epi32(bits[k] as i32),
                                     bitsel),
                    bitsel);
                let x0 = _mm256_loadu_ps(xp.as_ptr().add(k * 8));
                acc0 = _mm256_add_ps(
                    acc0, _mm256_and_ps(_mm256_castsi256_ps(m0), x0));
            }
            let mut t = [0f32; 8];
            _mm256_storeu_ps(t.as_mut_ptr(),
                             _mm256_add_ps(acc0, acc1));
            ((t[0] + t[4]) + (t[1] + t[5]))
                + ((t[2] + t[6]) + (t[3] + t[7]))
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON mask-expand row kernel: each packed byte is tested against
    //! two 4-lane bit masks (`vtst` yields all-ones where the bit is
    //! set) and the selected activations accumulate in two halves.

    use std::arch::aarch64::*;

    /// `Σ_{set bits} xp` for one packed row.
    ///
    /// # Safety
    ///
    /// NEON must be available on the running CPU, and `xp` must hold
    /// at least `bits.len() * 8` floats (zero-padded activations).
    #[target_feature(enable = "neon")]
    pub unsafe fn set_sum(bits: &[u8], xp: &[f32]) -> f32 {
        // SAFETY: the caller contract above guarantees NEON is
        // available and `xp.len() >= bits.len() * 8`, so both 4-lane
        // loads per byte read in-bounds floats.
        unsafe {
            let sel_lo = vld1q_u32([1u32, 2, 4, 8].as_ptr());
            let sel_hi = vld1q_u32([16u32, 32, 64, 128].as_ptr());
            let mut acc_lo = vdupq_n_f32(0.0);
            let mut acc_hi = vdupq_n_f32(0.0);
            for (k, &byte) in bits.iter().enumerate() {
                let b = vdupq_n_u32(byte as u32);
                let x_lo = vld1q_f32(xp.as_ptr().add(k * 8));
                let x_hi = vld1q_f32(xp.as_ptr().add(k * 8 + 4));
                acc_lo = vaddq_f32(
                    acc_lo,
                    vreinterpretq_f32_u32(vandq_u32(
                        vreinterpretq_u32_f32(x_lo),
                        vtstq_u32(b, sel_lo))));
                acc_hi = vaddq_f32(
                    acc_hi,
                    vreinterpretq_f32_u32(vandq_u32(
                        vreinterpretq_u32_f32(x_hi),
                        vtstq_u32(b, sel_hi))));
            }
            vaddvq_f32(vaddq_f32(acc_lo, acc_hi))
        }
    }
}

/// `y = alpha * Sign(bits) @ x`; `bits` row-major `[n, ⌈m/8⌉]`,
/// LSB-first, clear padding bits. Checked variant — see module docs.
///
/// Runs under the active dispatch tier (Four-Russians scalar / AVX2 /
/// NEON) with rows tiled over the shared worker pool; the per-row
/// stream is exactly the packed bytes, so the kernel stays
/// memory-bound down to L2-resident sizes.
pub fn try_binary_gemv(bits: &[u8], n: usize, m: usize, x: &[f32],
                       alpha: f32, y: &mut [f32])
                       -> Result<(), KernelShapeError> {
    let mb = validate(bits, n, m, x, y)?;
    let prep = Prep::new(x, mb);
    let kern = prep.kernel();
    let total = prep.total;
    dispatch::run_rows(y, mb, &|r0, chunk: &mut [f32]| {
        for (i, yv) in chunk.iter_mut().enumerate() {
            let r = r0 + i;
            let s = kern.set_sum(&bits[r * mb..(r + 1) * mb]);
            *yv = alpha * (2.0 * s - total);
        }
    });
    Ok(())
}

/// Panicking wrapper over [`try_binary_gemv`] (validates up front; any
/// failure carries the full shape diagnosis).
pub fn binary_gemv(bits: &[u8], n: usize, m: usize, x: &[f32],
                   alpha: f32, y: &mut [f32]) {
    if let Err(e) = try_binary_gemv(bits, n, m, x, alpha, y) {
        panic!("{e}");
    }
}

/// Fused multi-level packed GEMV (Fig. 3 fidelity tiers on the serving
/// path): `y = Σ_l alpha_l · Sign(bits_l) @ x` over `levels` stacked
/// `(packed bits, scale)` pairs sharing one logical shape `[n, m]`.
///
/// The win over calling [`try_binary_gemv`] per level is that the
/// per-call preamble ([`Prep`]: padded `x`, `Σx`, scalar-tier nibble
/// tables) is built **once** and shared by every level, so level `l ≥
/// 2` costs only its packed-byte stream. Per row,
///
/// ```text
/// y[r] = 2·Σ_l alpha_l·S_l(r) − (Σ_l alpha_l)·Σ_j x_j
/// ```
///
/// with `S_l(r)` the set-bit partial sum of level `l`'s row `r`.
///
/// Every level plane gets the full [`validate`] treatment
/// (buffer-length *and* set-padding-bit checks); a malformed level
/// reports its index, e.g. `packed gemv: level 1: row 0 has set
/// padding bits …`.
///
/// A level with `alpha == 0` contributes exactly `0.0` to both sums, so
/// the engine's **zero-scale padding convention** (padding a tenant to
/// the batch-max level count with zero-scale no-op levels) leaves the
/// output bit-identical to serving the tenant at its own level count.
pub fn try_binary_gemv_multi(levels: &[(&[u8], f32)], n: usize, m: usize,
                             x: &[f32], y: &mut [f32])
                             -> Result<(), KernelShapeError> {
    if levels.is_empty() {
        return Err(err("multi-level gemv needs >= 1 level".into()));
    }
    let mut mb = 0usize;
    for (l, (bits, _)) in levels.iter().enumerate() {
        mb = validate(bits, n, m, x, y)
            .map_err(|e| err(format!("level {l}: {}", e.0)))?;
    }
    let prep = Prep::new(x, mb);
    let kern = prep.kernel();
    let alpha_total: f32 =
        levels.iter().map(|(_, a)| a).sum::<f32>() * prep.total;
    dispatch::run_rows(y, mb * levels.len(), &|r0, chunk: &mut [f32]| {
        for (i, yv) in chunk.iter_mut().enumerate() {
            let r = r0 + i;
            let mut acc = 0f32;
            for (bits, alpha) in levels {
                acc += alpha * kern.set_sum(&bits[r * mb..(r + 1) * mb]);
            }
            *yv = 2.0 * acc - alpha_total;
        }
    });
    Ok(())
}

/// Panicking wrapper over [`try_binary_gemv_multi`].
pub fn binary_gemv_multi(levels: &[(&[u8], f32)], n: usize, m: usize,
                         x: &[f32], y: &mut [f32]) {
    if let Err(e) = try_binary_gemv_multi(levels, n, m, x, y) {
        panic!("{e}");
    }
}

/// The pre-optimization bit-extract kernel, kept for the §Perf ablation
/// and as an independent correctness witness. Checked variant.
pub fn try_binary_gemv_bitextract(bits: &[u8], n: usize, m: usize,
                                  x: &[f32], alpha: f32, y: &mut [f32])
                                  -> Result<(), KernelShapeError> {
    let mb = validate(bits, n, m, x, y)?;
    let total: f32 = x.iter().sum();
    for r in 0..n {
        let brow = &bits[r * mb..(r + 1) * mb];
        let mut acc = 0f32;
        for (k, &byte) in brow.iter().enumerate() {
            let lo = k * 8;
            let hi = (lo + 8).min(m);
            for (j, &xv) in x[lo..hi].iter().enumerate() {
                acc += xv * (byte >> j & 1) as f32;
            }
        }
        y[r] = alpha * (2.0 * acc - total);
    }
    Ok(())
}

/// Panicking wrapper over [`try_binary_gemv_bitextract`].
pub fn binary_gemv_bitextract(bits: &[u8], n: usize, m: usize,
                              x: &[f32], alpha: f32, y: &mut [f32]) {
    if let Err(e) = try_binary_gemv_bitextract(bits, n, m, x, alpha, y) {
        panic!("{e}");
    }
}

/// Batched per-tenant delta GEMV: `y[b] = alpha[b] * Sign(bits[b]) @ x[b]`
/// — one packed matrix per tenant, the multi-tenant batching of Eq. 6.
pub fn try_batched_binary_gemv(bits: &[u8], n: usize, m: usize,
                               xs: &[f32], alphas: &[f32], batch: usize,
                               ys: &mut [f32])
                               -> Result<(), KernelShapeError> {
    let mb = packed_row_bytes(m);
    if bits.len() != batch * n * mb {
        return Err(err(format!(
            "batched bits buffer has {} bytes, want batch*n*ceil(m/8) \
= {}", bits.len(), batch * n * mb)));
    }
    if alphas.len() != batch {
        return Err(err(format!("{} alphas for batch {batch}",
                               alphas.len())));
    }
    if xs.len() != batch * m || ys.len() != batch * n {
        return Err(err(format!(
            "xs/ys have {}/{} entries, want {}/{}", xs.len(), ys.len(),
            batch * m, batch * n)));
    }
    for b in 0..batch {
        try_binary_gemv(&bits[b * n * mb..(b + 1) * n * mb], n, m,
                        &xs[b * m..(b + 1) * m], alphas[b],
                        &mut ys[b * n..(b + 1) * n])?;
    }
    Ok(())
}

/// Panicking wrapper over [`try_batched_binary_gemv`].
pub fn batched_binary_gemv(bits: &[u8], n: usize, m: usize,
                           xs: &[f32], alphas: &[f32], batch: usize,
                           ys: &mut [f32]) {
    if let Err(e) = try_batched_binary_gemv(bits, n, m, xs, alphas, batch,
                                            ys) {
        panic!("{e}");
    }
}

/// Fused Eq. 6 output: `y[b] = W_base @ x[b] + alpha[b]·Sign(bits[b])@x[b]`
/// — the complete decomposed linear for a batch of tenants.
pub fn fused_delta_gemv(w_base: &[f32], bits: &[u8], n: usize, m: usize,
                        xs: &[f32], alphas: &[f32], batch: usize,
                        ys: &mut [f32]) {
    super::dense::batched_dense_gemv(w_base, n, m, xs, batch, ys);
    let mb = packed_row_bytes(m);
    let mut tmp = vec![0f32; n];
    for b in 0..batch {
        binary_gemv(&bits[b * n * mb..(b + 1) * n * mb], n, m,
                    &xs[b * m..(b + 1) * m], alphas[b], &mut tmp);
        for (yv, t) in ys[b * n..(b + 1) * n].iter_mut().zip(&tmp) {
            *yv += t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::packing::pack_signs;
    use crate::tensor::Tensor;

    fn reference(delta_signs: &[f32], n: usize, m: usize, x: &[f32],
                 alpha: f32) -> Vec<f32> {
        (0..n).map(|r| {
            alpha * (0..m).map(|j| delta_signs[r * m + j] * x[j])
                .sum::<f32>()
        }).collect()
    }

    #[test]
    fn lut_matches_bitextract_kernel() {
        let (n, m) = (9, 48);
        let d = Tensor::randn(vec![n, m], 55);
        let bits = pack_signs(d.data(), m);
        let x = Tensor::randn(vec![m], 56);
        let mut y1 = vec![0f32; n];
        let mut y2 = vec![0f32; n];
        binary_gemv(&bits, n, m, x.data(), 0.21, &mut y1);
        binary_gemv_bitextract(&bits, n, m, x.data(), 0.21, &mut y2);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn matches_reference() {
        let (n, m) = (13, 32);
        let d = Tensor::randn(vec![n, m], 5);
        let signs: Vec<f32> = d.data().iter()
            .map(|&v| if v > 0.0 { 1.0 } else { -1.0 }).collect();
        let bits = pack_signs(d.data(), m);
        let x = Tensor::randn(vec![m], 6);
        let mut y = vec![0f32; n];
        binary_gemv(&bits, n, m, x.data(), 0.37, &mut y);
        let want = reference(&signs, n, m, x.data(), 0.37);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn non_multiple_of_eight_width_matches_reference() {
        for m in [1usize, 3, 5, 7, 9, 13, 27] {
            let n = 6;
            let d = Tensor::randn(vec![n, m], 60 + m as u64);
            let signs: Vec<f32> = d.data().iter()
                .map(|&v| if v > 0.0 { 1.0 } else { -1.0 }).collect();
            let bits = pack_signs(d.data(), m);
            let x = Tensor::randn(vec![m], 70 + m as u64);
            let mut y = vec![0f32; n];
            binary_gemv(&bits, n, m, x.data(), 0.5, &mut y);
            let want = reference(&signs, n, m, x.data(), 0.5);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3, "m={m}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn every_compiled_tier_matches_the_bitextract_witness() {
        let _g = dispatch::test_lock();
        let (n, m) = (11, 53);
        let d = Tensor::randn(vec![n, m], 101);
        let bits = pack_signs(d.data(), m);
        let x = Tensor::randn(vec![m], 102);
        let mut want = vec![0f32; n];
        binary_gemv_bitextract(&bits, n, m, x.data(), 0.33, &mut want);
        for tier in Tier::ALL {
            dispatch::force_tier(Some(tier));
            let mut y = vec![0f32; n];
            binary_gemv(&bits, n, m, x.data(), 0.33, &mut y);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3,
                        "tier {tier}: {a} vs {b}");
            }
        }
        dispatch::force_tier(None);
    }

    #[test]
    fn malformed_padding_bits_rejected_with_clear_error() {
        let (n, m) = (2, 5);               // 1 byte/row, 3 padding bits
        let mut bits = pack_signs(&[1.0f32; 10], m);
        bits[1] |= 0b1000_0000;            // set a padding bit in row 1
        let x = [0.5f32; 5];
        let mut y = [0f32; 2];
        let e = try_binary_gemv(&bits, n, m, &x, 1.0, &mut y).unwrap_err();
        assert!(e.to_string().contains("row 1"), "{e}");
        assert!(e.to_string().contains("padding"), "{e}");
        let e2 = try_binary_gemv_bitextract(&bits, n, m, &x, 1.0, &mut y)
            .unwrap_err();
        assert_eq!(e, e2);
    }

    #[test]
    fn wrong_buffer_length_rejected() {
        let x = [0.0f32; 8];
        let mut y = [0f32; 2];
        let e = try_binary_gemv(&[0u8; 3], 2, 8, &x, 1.0, &mut y)
            .unwrap_err();
        assert!(e.to_string().contains("3 bytes"), "{e}");
    }

    #[test]
    fn all_ones_matrix() {
        let (n, m) = (4, 16);
        let bits = vec![0xFFu8; n * m / 8];
        let x = Tensor::randn(vec![m], 7);
        let total: f32 = x.data().iter().sum();
        let mut y = vec![0f32; n];
        binary_gemv(&bits, n, m, x.data(), 1.0, &mut y);
        for v in y {
            assert!((v - total).abs() < 1e-4);
        }
    }

    #[test]
    fn all_zeros_matrix_negates() {
        let (n, m) = (4, 16);
        let bits = vec![0u8; n * m / 8];
        let x = Tensor::randn(vec![m], 8);
        let total: f32 = x.data().iter().sum();
        let mut y = vec![0f32; n];
        binary_gemv(&bits, n, m, x.data(), 1.0, &mut y);
        for v in y {
            assert!((v + total).abs() < 1e-4);
        }
    }

    #[test]
    fn multi_level_matches_per_level_loop() {
        // fused kernel == k independent single-level calls summed
        for (n, m) in [(9usize, 48usize), (6, 13), (4, 32)] {
            let k = 3;
            let d = Tensor::randn(vec![k, n, m], 90 + m as u64);
            let alphas = [0.31f32, 0.11, 0.04];
            let packed: Vec<Vec<u8>> = (0..k).map(|l| {
                pack_signs(&d.data()[l * n * m..(l + 1) * n * m], m)
            }).collect();
            let levels: Vec<(&[u8], f32)> = packed.iter()
                .map(|b| b.as_slice()).zip(alphas).collect();
            let x = Tensor::randn(vec![m], 91 + m as u64);
            let mut fused = vec![0f32; n];
            binary_gemv_multi(&levels, n, m, x.data(), &mut fused);

            let mut want = vec![0f32; n];
            let mut tmp = vec![0f32; n];
            for (bits, alpha) in &levels {
                binary_gemv(bits, n, m, x.data(), *alpha, &mut tmp);
                for (w, t) in want.iter_mut().zip(&tmp) {
                    *w += t;
                }
            }
            for (a, b) in fused.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3, "[{n}x{m}] {a} vs {b}");
            }
        }
    }

    #[test]
    fn zero_scale_padding_levels_are_bit_identical_noops() {
        // The engine pads a tenant to the batch-max level count with
        // zero-scale levels; the padded output must be *bit-identical*
        // to the tenant served alone at its own level count — the
        // mixed-fidelity batching guarantee.
        let _g = dispatch::test_lock();
        let (n, m) = (7, 29);
        let d = Tensor::randn(vec![2, n, m], 77);
        let b0 = pack_signs(&d.data()[..n * m], m);
        let b1 = pack_signs(&d.data()[n * m..], m);
        let pad = vec![0u8; n * packed_row_bytes(m)];
        let x = Tensor::randn(vec![m], 78);

        let own: Vec<(&[u8], f32)> = vec![(&b0, 0.2), (&b1, 0.05)];
        let padded: Vec<(&[u8], f32)> =
            vec![(&b0, 0.2), (&b1, 0.05), (&pad, 0.0), (&pad, 0.0)];
        let mut y_own = vec![0f32; n];
        let mut y_pad = vec![0f32; n];
        binary_gemv_multi(&own, n, m, x.data(), &mut y_own);
        binary_gemv_multi(&padded, n, m, x.data(), &mut y_pad);
        for (a, b) in y_own.iter().zip(&y_pad) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }

    #[test]
    fn multi_level_rejects_empty_and_malformed() {
        let x = [0.0f32; 8];
        let mut y = [0f32; 2];
        assert!(try_binary_gemv_multi(&[], 2, 8, &x, &mut y).is_err());
        let good = vec![0u8; 2];
        let bad = vec![0u8; 3];
        let levels: Vec<(&[u8], f32)> = vec![(&good, 1.0), (&bad, 1.0)];
        let e = try_binary_gemv_multi(&levels, 2, 8, &x, &mut y)
            .unwrap_err();
        assert!(e.to_string().contains("level 1"), "{e}");
    }

    #[test]
    fn multi_level_set_padding_bits_name_the_level() {
        let m = 5;                         // 3 padding bits per byte
        let good = pack_signs(&[1.0f32; 10], m);
        let mut bad = good.clone();
        bad[0] |= 0b1110_0000;             // corrupt level 1, row 0
        let x = [0.5f32; 5];
        let mut y = [0f32; 2];
        let levels: Vec<(&[u8], f32)> = vec![(&good, 0.4), (&bad, 0.1)];
        let e = try_binary_gemv_multi(&levels, 2, m, &x, &mut y)
            .unwrap_err();
        assert!(e.to_string().contains("level 1"), "{e}");
        assert!(e.to_string().contains("padding"), "{e}");
    }

    #[test]
    fn fused_equals_parts() {
        let (n, m, b) = (8, 24, 2);
        let w = Tensor::randn(vec![n, m], 9);
        let d = Tensor::randn(vec![b, n, m], 10);
        let bits: Vec<u8> = (0..b).flat_map(|bi| {
            pack_signs(&d.data()[bi * n * m..(bi + 1) * n * m], m)
        }).collect();
        let xs = Tensor::randn(vec![b, m], 11);
        let alphas = [0.2f32, 0.05];
        let mut fused = vec![0f32; b * n];
        fused_delta_gemv(w.data(), &bits, n, m, xs.data(), &alphas, b,
                         &mut fused);
        // parts
        let mut parts = vec![0f32; b * n];
        super::super::dense::batched_dense_gemv(w.data(), n, m, xs.data(),
                                                b, &mut parts);
        let mut tmp = vec![0f32; b * n];
        batched_binary_gemv(&bits, n, m, xs.data(), &alphas, b, &mut tmp);
        for i in 0..b * n {
            assert!((fused[i] - (parts[i] + tmp[i])).abs() < 1e-3);
        }
    }
}
