//! Packed 1-bit delta GEMV — the CPU analog of BitBLAS's `W_INT1·A_FP16`
//! kernel (the "Kernel" brace of Eq. 6).
//!
//! Computes `y = α · Sign(Δ) · x` **directly from the packed bytes** —
//! the sign matrix is never materialised, so the weight stream is
//! `N·M/8` bytes instead of `4·N·M`: a 32× traffic reduction over the
//! f32 backbone (16× in the paper's fp16 terms). That traffic ratio is
//! the entire latency story of Figures 4 and 6.
//!
//! Identity used to avoid per-bit sign selects:
//!
//! ```text
//! Σ_j s_j·x_j  =  Σ_set x_j − Σ_clear x_j  =  2·Σ_set x_j − Σ_all x_j
//! ```
//!
//! so the inner loop only accumulates `x_j·bit_j` (a branchless 0/1
//! multiply the compiler vectorises) and the row finishes with one fused
//! correction by the precomputed total.

/// `y = alpha * Sign(bits) @ x`; `bits` row-major `[n, m/8]`, LSB-first.
///
/// Four-Russians formulation: per call, build a 16-entry partial-sum
/// table for every 4-column group of `x` (`lut[g][v] = Σ_{bit j of v}
/// x[4g+j]`, built incrementally in 15 adds/group); each weight byte
/// then costs two table lookups + two adds instead of eight
/// bit-extract/convert/multiply chains. The O(4m) table build amortises
/// over the `n` rows, and the per-row stream is exactly the packed
/// bytes — the kernel stays memory-bound down to L2-resident sizes
/// (§Perf before/after: ~4-6x over the bit-extract loop).
pub fn binary_gemv(bits: &[u8], n: usize, m: usize, x: &[f32],
                   alpha: f32, y: &mut [f32]) {
    assert_eq!(m % 8, 0);
    let mb = m / 8;
    assert_eq!(bits.len(), n * mb);
    assert_eq!(x.len(), m);
    assert_eq!(y.len(), n);

    // nibble tables: group g covers columns [4g, 4g+4)
    let groups = m / 4;
    let mut lut = vec![0f32; groups * 16];
    for g in 0..groups {
        let xs = &x[g * 4..g * 4 + 4];
        let t = &mut lut[g * 16..g * 16 + 16];
        for v in 1usize..16 {
            t[v] = t[v & (v - 1)] + xs[v.trailing_zeros() as usize];
        }
    }
    let total: f32 = x.iter().sum();

    for r in 0..n {
        let brow = &bits[r * mb..(r + 1) * mb];
        // two accumulators hide the add latency
        let (mut a0, mut a1) = (0f32, 0f32);
        for (k, &byte) in brow.iter().enumerate() {
            let lo = (byte & 0xF) as usize;
            let hi = (byte >> 4) as usize;
            a0 += lut[(2 * k) * 16 + lo];
            a1 += lut[(2 * k + 1) * 16 + hi];
        }
        y[r] = alpha * (2.0 * (a0 + a1) - total);
    }
}

/// The pre-optimization bit-extract kernel, kept for the §Perf ablation
/// and as an independent correctness witness.
pub fn binary_gemv_bitextract(bits: &[u8], n: usize, m: usize,
                              x: &[f32], alpha: f32, y: &mut [f32]) {
    assert_eq!(m % 8, 0);
    let mb = m / 8;
    let total: f32 = x.iter().sum();
    for r in 0..n {
        let brow = &bits[r * mb..(r + 1) * mb];
        let mut acc = 0f32;
        for (k, &byte) in brow.iter().enumerate() {
            let xs = &x[k * 8..k * 8 + 8];
            acc += xs[0] * (byte & 1) as f32
                + xs[1] * (byte >> 1 & 1) as f32
                + xs[2] * (byte >> 2 & 1) as f32
                + xs[3] * (byte >> 3 & 1) as f32
                + xs[4] * (byte >> 4 & 1) as f32
                + xs[5] * (byte >> 5 & 1) as f32
                + xs[6] * (byte >> 6 & 1) as f32
                + xs[7] * (byte >> 7 & 1) as f32;
        }
        y[r] = alpha * (2.0 * acc - total);
    }
}

/// Batched per-tenant delta GEMV: `y[b] = alpha[b] * Sign(bits[b]) @ x[b]`
/// — one packed matrix per tenant, the multi-tenant batching of Eq. 6.
pub fn batched_binary_gemv(bits: &[u8], n: usize, m: usize,
                           xs: &[f32], alphas: &[f32], batch: usize,
                           ys: &mut [f32]) {
    let mb = m / 8;
    assert_eq!(bits.len(), batch * n * mb);
    assert_eq!(alphas.len(), batch);
    assert_eq!(xs.len(), batch * m);
    assert_eq!(ys.len(), batch * n);
    for b in 0..batch {
        binary_gemv(&bits[b * n * mb..(b + 1) * n * mb], n, m,
                    &xs[b * m..(b + 1) * m], alphas[b],
                    &mut ys[b * n..(b + 1) * n]);
    }
}

/// Fused Eq. 6 output: `y[b] = W_base @ x[b] + alpha[b]·Sign(bits[b])@x[b]`
/// — the complete decomposed linear for a batch of tenants.
pub fn fused_delta_gemv(w_base: &[f32], bits: &[u8], n: usize, m: usize,
                        xs: &[f32], alphas: &[f32], batch: usize,
                        ys: &mut [f32]) {
    super::dense::batched_dense_gemv(w_base, n, m, xs, batch, ys);
    let mb = m / 8;
    let mut tmp = vec![0f32; n];
    for b in 0..batch {
        binary_gemv(&bits[b * n * mb..(b + 1) * n * mb], n, m,
                    &xs[b * m..(b + 1) * m], alphas[b], &mut tmp);
        for (yv, t) in ys[b * n..(b + 1) * n].iter_mut().zip(&tmp) {
            *yv += t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::packing::pack_signs;
    use crate::tensor::Tensor;

    fn reference(delta_signs: &[f32], n: usize, m: usize, x: &[f32],
                 alpha: f32) -> Vec<f32> {
        (0..n).map(|r| {
            alpha * (0..m).map(|j| delta_signs[r * m + j] * x[j])
                .sum::<f32>()
        }).collect()
    }

    #[test]
    fn lut_matches_bitextract_kernel() {
        let (n, m) = (9, 48);
        let d = Tensor::randn(vec![n, m], 55);
        let bits = pack_signs(d.data(), m);
        let x = Tensor::randn(vec![m], 56);
        let mut y1 = vec![0f32; n];
        let mut y2 = vec![0f32; n];
        binary_gemv(&bits, n, m, x.data(), 0.21, &mut y1);
        binary_gemv_bitextract(&bits, n, m, x.data(), 0.21, &mut y2);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn matches_reference() {
        let (n, m) = (13, 32);
        let d = Tensor::randn(vec![n, m], 5);
        let signs: Vec<f32> = d.data().iter()
            .map(|&v| if v > 0.0 { 1.0 } else { -1.0 }).collect();
        let bits = pack_signs(d.data(), m);
        let x = Tensor::randn(vec![m], 6);
        let mut y = vec![0f32; n];
        binary_gemv(&bits, n, m, x.data(), 0.37, &mut y);
        let want = reference(&signs, n, m, x.data(), 0.37);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn all_ones_matrix() {
        let (n, m) = (4, 16);
        let bits = vec![0xFFu8; n * m / 8];
        let x = Tensor::randn(vec![m], 7);
        let total: f32 = x.data().iter().sum();
        let mut y = vec![0f32; n];
        binary_gemv(&bits, n, m, x.data(), 1.0, &mut y);
        for v in y {
            assert!((v - total).abs() < 1e-4);
        }
    }

    #[test]
    fn all_zeros_matrix_negates() {
        let (n, m) = (4, 16);
        let bits = vec![0u8; n * m / 8];
        let x = Tensor::randn(vec![m], 8);
        let total: f32 = x.data().iter().sum();
        let mut y = vec![0f32; n];
        binary_gemv(&bits, n, m, x.data(), 1.0, &mut y);
        for v in y {
            assert!((v + total).abs() < 1e-4);
        }
    }

    #[test]
    fn fused_equals_parts() {
        let (n, m, b) = (8, 24, 2);
        let w = Tensor::randn(vec![n, m], 9);
        let d = Tensor::randn(vec![b, n, m], 10);
        let bits: Vec<u8> = (0..b).flat_map(|bi| {
            pack_signs(&d.data()[bi * n * m..(bi + 1) * n * m], m)
        }).collect();
        let xs = Tensor::randn(vec![b, m], 11);
        let alphas = [0.2f32, 0.05];
        let mut fused = vec![0f32; b * n];
        fused_delta_gemv(w.data(), &bits, n, m, xs.data(), &alphas, b,
                         &mut fused);
        // parts
        let mut parts = vec![0f32; b * n];
        super::super::dense::batched_dense_gemv(w.data(), n, m, xs.data(),
                                                b, &mut parts);
        let mut tmp = vec![0f32; b * n];
        batched_binary_gemv(&bits, n, m, xs.data(), &alphas, b, &mut tmp);
        for i in 0..b * n {
            assert!((fused[i] - (parts[i] + tmp[i])).abs() < 1e-3);
        }
    }
}
