//! Batched low-rank (S-LoRA baseline) GEMV: `y[b] = B_b (A_b x[b])`.
//!
//! The comparison kernel of Figures 4/6: at the paper's memory-equivalent
//! rank the factor stream `4·r·(N+M)` bytes matches the packed 1-bit
//! stream `N·M/8`, so the two delta paths cost the same traffic; BitDelta
//! wins on simplicity (no rank hyper-parameter, no second GEMV stage).

use super::dense::dense_gemv;

/// One tenant: `a_down [r, m]`, `b_up [n, r]`, `y = b_up @ (a_down @ x)`.
pub fn lora_gemv(a_down: &[f32], b_up: &[f32], r: usize, n: usize,
                 m: usize, x: &[f32], y: &mut [f32]) {
    assert_eq!(a_down.len(), r * m);
    assert_eq!(b_up.len(), n * r);
    let mut h = vec![0f32; r];
    dense_gemv(a_down, r, m, x, &mut h);
    dense_gemv(b_up, n, r, &h, y);
}

/// Batch of tenants, each with its own factors.
pub fn batched_lora_gemv(a_down: &[f32], b_up: &[f32], r: usize,
                         n: usize, m: usize, xs: &[f32], batch: usize,
                         ys: &mut [f32]) {
    assert_eq!(a_down.len(), batch * r * m);
    assert_eq!(b_up.len(), batch * n * r);
    for b in 0..batch {
        lora_gemv(&a_down[b * r * m..(b + 1) * r * m],
                  &b_up[b * n * r..(b + 1) * n * r],
                  r, n, m,
                  &xs[b * m..(b + 1) * m],
                  &mut ys[b * n..(b + 1) * n]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn matches_dense_product() {
        let (n, m, r) = (10, 14, 3);
        let a = Tensor::randn(vec![r, m], 1);
        let b = Tensor::randn(vec![n, r], 2);
        let x = Tensor::randn(vec![m], 3);
        let mut y = vec![0f32; n];
        lora_gemv(a.data(), b.data(), r, n, m, x.data(), &mut y);

        let dense = b.matmul(&a); // [n, m]
        let mut want = vec![0f32; n];
        dense_gemv(dense.data(), n, m, x.data(), &mut want);
        for (u, v) in y.iter().zip(&want) {
            assert!((u - v).abs() < 1e-4);
        }
    }

    #[test]
    fn rank_zero_edge() {
        let (n, m, r) = (4, 8, 1);
        let a = vec![0f32; r * m];
        let b = vec![0f32; n * r];
        let x = Tensor::randn(vec![m], 4);
        let mut y = vec![1f32; n];
        lora_gemv(&a, &b, r, n, m, x.data(), &mut y);
        assert!(y.iter().all(|&v| v == 0.0));
    }
}
