//! CPU hot-path kernels — the measurable analog of the paper's GPU
//! kernels for Figure 4 (kernel decode latency) and Figure 6 (end-to-end).
//!
//! All three are **memory-bandwidth-bound** at decode (L = 1), exactly
//! like their GPU counterparts, so the latency *shape* the paper reports
//! (backbone flat in batch; per-tenant delta term 16-32× cheaper than a
//! per-tenant dense backbone; crossovers at B≈6-8) is reproduced by byte
//! counting:
//!
//! | kernel                | bytes streamed per tenant  |
//! |-----------------------|----------------------------|
//! | [`dense`] backbone    | `4·N·M` (f32 weights)      |
//! | [`binary`] 1-bit delta| `N·⌈M/8⌉` (packed signs)   |
//! | [`lora`] rank-r delta | `4·r·(N+M)`                |
//!
//! Serving code should not call these directly per format: the
//! per-format apply path is dispatched through
//! [`crate::delta::codec::DeltaCodec::forward_linear`], which routes to
//! the right kernel for whichever delta codec a tenant uses.
//!
//! The packed [`binary`] kernels run under a small **kernel engine**
//! ([`dispatch`]): runtime-detected SIMD tiers (AVX2/NEON, scalar
//! Four-Russians fallback) and row-tiled execution over a shared
//! worker pool, both overridable via `BITDELTA_KERNEL` /
//! `BITDELTA_THREADS` (or the CLI `--threads` flag).

pub mod binary;
pub mod dense;
pub mod dispatch;
pub mod lora;

pub use binary::{batched_binary_gemv, binary_gemv, binary_gemv_multi,
                 try_batched_binary_gemv, try_binary_gemv,
                 try_binary_gemv_multi, KernelShapeError};
pub use dispatch::Tier;
pub use dense::{batched_dense_gemv, dense_gemv};
pub use lora::{batched_lora_gemv, lora_gemv};
