//! Dense f32 GEMV — the shared-backbone term `W_base·x` of Eq. 6 and the
//! per-tenant weight stream of the naive baseline.

/// `y = W @ x` for row-major `W [n, m]`, `x [m]`, `y [n]`.
///
/// Four independent accumulators per row keep the FP add chains short
/// enough for the compiler to vectorise; the kernel streams each weight
/// row exactly once (memory-bound regime).
pub fn dense_gemv(w: &[f32], n: usize, m: usize, x: &[f32], y: &mut [f32]) {
    assert_eq!(w.len(), n * m);
    assert_eq!(x.len(), m);
    assert_eq!(y.len(), n);
    let chunks = m / 4 * 4;
    for r in 0..n {
        let row = &w[r * m..(r + 1) * m];
        let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
        let mut j = 0;
        while j < chunks {
            a0 += row[j] * x[j];
            a1 += row[j + 1] * x[j + 1];
            a2 += row[j + 2] * x[j + 2];
            a3 += row[j + 3] * x[j + 3];
            j += 4;
        }
        let mut acc = (a0 + a1) + (a2 + a3);
        while j < m {
            acc += row[j] * x[j];
            j += 1;
        }
        y[r] = acc;
    }
}

/// Shared backbone over a batch: `y[b] = W @ x[b]` — one weight stream
/// serves every tenant (the reason backbone latency is flat in B).
pub fn batched_dense_gemv(w: &[f32], n: usize, m: usize,
                          xs: &[f32], batch: usize, ys: &mut [f32]) {
    assert_eq!(xs.len(), batch * m);
    assert_eq!(ys.len(), batch * n);
    // Stream W once; accumulate all batch outputs per row.
    for r in 0..n {
        let row = &w[r * m..(r + 1) * m];
        for b in 0..batch {
            let x = &xs[b * m..(b + 1) * m];
            let mut acc = 0f32;
            for j in 0..m {
                acc += row[j] * x[j];
            }
            ys[b * n + r] = acc;
        }
    }
}

/// Naive multi-tenant decode: each tenant streams its own full weights
/// (`ws [batch, n, m]`) — the baseline whose traffic scales with B.
pub fn per_tenant_dense_gemv(ws: &[f32], n: usize, m: usize,
                             xs: &[f32], batch: usize, ys: &mut [f32]) {
    assert_eq!(ws.len(), batch * n * m);
    for b in 0..batch {
        dense_gemv(&ws[b * n * m..(b + 1) * n * m], n, m,
                   &xs[b * m..(b + 1) * m],
                   &mut ys[b * n..(b + 1) * n]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn naive(w: &[f32], n: usize, m: usize, x: &[f32]) -> Vec<f32> {
        (0..n).map(|r| (0..m).map(|j| w[r * m + j] * x[j]).sum()).collect()
    }

    #[test]
    fn matches_naive() {
        let (n, m) = (17, 23);
        let w = Tensor::randn(vec![n, m], 1);
        let x = Tensor::randn(vec![m], 2);
        let mut y = vec![0f32; n];
        dense_gemv(w.data(), n, m, x.data(), &mut y);
        let want = naive(w.data(), n, m, x.data());
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn batched_matches_loop() {
        let (n, m, b) = (8, 16, 3);
        let w = Tensor::randn(vec![n, m], 3);
        let xs = Tensor::randn(vec![b, m], 4);
        let mut y1 = vec![0f32; b * n];
        batched_dense_gemv(w.data(), n, m, xs.data(), b, &mut y1);
        for bi in 0..b {
            let mut y2 = vec![0f32; n];
            dense_gemv(w.data(), n, m, &xs.data()[bi * m..(bi + 1) * m],
                       &mut y2);
            for (a, c) in y1[bi * n..(bi + 1) * n].iter().zip(&y2) {
                assert!((a - c).abs() < 1e-4);
            }
        }
    }
}
