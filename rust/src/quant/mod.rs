//! Base-model weight quantizers (Table 6 substrate).
//!
//! The build path's python quantizers (`python/compile/quant.py`) produce
//! the artifact variants; this module provides the rust-native RTN family
//! so `repro compress --base-quant intN` works offline, plus the shared
//! accounting used by the Table 6 harness.

pub mod rtn;

pub use rtn::{rtn_dequantize, rtn_quantize_matrix, RtnQuantized};
