//! Per-channel symmetric round-to-nearest quantization.
//!
//! The INT8-RTN rows of Table 6; also usable at 4/2 bits for ablations.
//! Matches `python/compile/quant.py::rtn_quantize_matrix` numerically
//! (same grid, same clamping) so rust- and python-produced variants are
//! interchangeable.

use crate::tensor::Tensor;

/// A quantized matrix: int codes + per-row scales.
#[derive(Debug, Clone)]
pub struct RtnQuantized {
    pub bits: u8,
    pub rows: usize,
    pub cols: usize,
    /// Codes in row-major order, each in `[-2^(b-1), 2^(b-1)-1]`.
    pub codes: Vec<i8>,
    /// One scale per output channel (row).
    pub scales: Vec<f32>,
}

/// Quantize a `[rows, cols]` matrix at `bits` precision (2..=8).
pub fn rtn_quantize_matrix(w: &Tensor, bits: u8) -> RtnQuantized {
    assert!((2..=8).contains(&bits));
    let (rows, cols) = w.dims2();
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let qmin = -(1i32 << (bits - 1)) as f32;
    let row_max = w.row_abs_max();
    let mut codes = Vec::with_capacity(rows * cols);
    let mut scales = Vec::with_capacity(rows);
    for r in 0..rows {
        let scale = row_max[r].max(1e-12) / qmax;
        scales.push(scale);
        for c in 0..cols {
            let q = (w.data()[r * cols + c] / scale).round()
                .clamp(qmin, qmax);
            codes.push(q as i8);
        }
    }
    RtnQuantized { bits, rows, cols, codes, scales }
}

/// Dequantize back to dense f32.
pub fn rtn_dequantize(q: &RtnQuantized) -> Tensor {
    let mut out = Vec::with_capacity(q.rows * q.cols);
    for r in 0..q.rows {
        let s = q.scales[r];
        for c in 0..q.cols {
            out.push(q.codes[r * q.cols + c] as f32 * s);
        }
    }
    Tensor::new(vec![q.rows, q.cols], out)
}

impl RtnQuantized {
    /// Stored bytes at the nominal bit width (codes packed + f16 scales —
    /// the Table 6 memory accounting).
    pub fn nominal_bytes(&self) -> usize {
        (self.rows * self.cols * self.bits as usize + 7) / 8
            + self.rows * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int8_roundtrip_error_small() {
        let w = Tensor::randn(vec![16, 32], 1);
        let q = rtn_quantize_matrix(&w, 8);
        let d = rtn_dequantize(&q);
        let err = w.sub(&d).frob_norm() / w.frob_norm();
        assert!(err < 0.01, "int8 err {err}");
    }

    #[test]
    fn lower_bits_more_error() {
        let w = Tensor::randn(vec![16, 32], 2);
        let errs: Vec<f32> = [8u8, 4, 2].iter().map(|&b| {
            let q = rtn_quantize_matrix(&w, b);
            w.sub(&rtn_dequantize(&q)).frob_norm()
        }).collect();
        assert!(errs[0] < errs[1] && errs[1] < errs[2], "{errs:?}");
    }

    #[test]
    fn codes_in_range() {
        let w = Tensor::randn(vec![8, 8], 3);
        for bits in [2u8, 4, 8] {
            let q = rtn_quantize_matrix(&w, bits);
            let lim = 1i16 << (bits - 1);
            assert!(q.codes.iter()
                .all(|&c| (c as i16) >= -lim && (c as i16) < lim));
        }
    }

    #[test]
    fn matches_python_formula() {
        // python: scale = max(|row|)/qmax; q = clip(round(w/scale))
        let w = Tensor::new(vec![1, 4], vec![0.5, -1.0, 0.25, 0.75]);
        let q = rtn_quantize_matrix(&w, 8);
        assert!((q.scales[0] - 1.0 / 127.0).abs() < 1e-9);
        assert_eq!(q.codes[1], -127);
        let d = rtn_dequantize(&q);
        assert!((d.data()[1] + 1.0).abs() < 1e-6);
    }
}
