//! Model configuration and the `artifacts/manifest.json` schema — the ABI
//! shared with the python build path (`python/compile/config.py`).
//!
//! The canonical parameter ordering (`param_names`) and the linear-weight
//! ordering (`linear_names`) defined here must match python exactly: HLO
//! executables take weights positionally in this order, and BDD delta
//! files index their scale vectors by it.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Architecture hyper-parameters of one model size (mirror of
/// `python/compile/config.py::ModelConfig`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq_len: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
}

/// The seven per-layer linear kinds, in canonical order.
pub const LINEAR_KINDS: [&str; 7] =
    ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"];

impl ModelConfig {
    /// Built-in `sim-s` (must match python's SIM_S).
    pub fn sim_s() -> Self {
        Self { name: "sim-s".into(), vocab_size: 256, d_model: 128,
               n_layers: 4, n_heads: 4, d_ff: 344, max_seq_len: 256,
               rope_theta: 10000.0, norm_eps: 1e-5 }
    }

    /// Built-in `sim-m` (must match python's SIM_M).
    pub fn sim_m() -> Self {
        Self { name: "sim-m".into(), vocab_size: 256, d_model: 256,
               n_layers: 6, n_heads: 8, d_ff: 688, max_seq_len: 256,
               rope_theta: 10000.0, norm_eps: 1e-5 }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Per-layer linear weight names, canonical order (the delta ABI).
    pub fn linear_names(&self) -> Vec<String> {
        let mut out = Vec::with_capacity(self.n_layers * 7);
        for layer in 0..self.n_layers {
            for kind in LINEAR_KINDS {
                out.push(format!("layers.{layer}.{kind}"));
            }
        }
        out
    }

    /// (out_features, in_features) of a canonical linear weight.
    pub fn linear_shape(&self, name: &str) -> (usize, usize) {
        // lint: allow(unwrap, rsplit always yields at least one piece)
        let kind = name.rsplit('.').next().unwrap();
        let (d, f) = (self.d_model, self.d_ff);
        match kind {
            "wq" | "wk" | "wv" | "wo" => (d, d),
            "w_gate" | "w_up" => (f, d),
            "w_down" => (d, f),
            _ => panic!("not a linear: {name}"),
        }
    }

    /// Shape of the packed 1-bit sign matrix for a linear (u8). Rows pad
    /// to a byte boundary; see [`crate::delta::packing`].
    pub fn packed_shape(&self, name: &str) -> (usize, usize) {
        let (n, m) = self.linear_shape(name);
        (n, crate::delta::packing::packed_row_bytes(m))
    }

    /// All weight names in canonical flattening order (the HLO ABI).
    pub fn param_names(&self) -> Vec<String> {
        let mut names = vec!["tok_embed".to_string()];
        for layer in 0..self.n_layers {
            names.push(format!("layers.{layer}.attn_norm"));
            for kind in ["wq", "wk", "wv", "wo"] {
                names.push(format!("layers.{layer}.{kind}"));
            }
            names.push(format!("layers.{layer}.mlp_norm"));
            for kind in ["w_gate", "w_up", "w_down"] {
                names.push(format!("layers.{layer}.{kind}"));
            }
        }
        names.push("final_norm".into());
        names.push("lm_head".into());
        names
    }

    pub fn param_shape(&self, name: &str) -> Vec<usize> {
        match name {
            "tok_embed" | "lm_head" => vec![self.vocab_size, self.d_model],
            n if n.ends_with("norm") => vec![self.d_model],
            n => {
                let (a, b) = self.linear_shape(n);
                vec![a, b]
            }
        }
    }

    /// Names of params that stay full-precision per tenant (non-linears).
    pub fn nonlinear_names(&self) -> Vec<String> {
        let lin: std::collections::HashSet<String> =
            self.linear_names().into_iter().collect();
        self.param_names().into_iter()
            .filter(|n| !lin.contains(n)).collect()
    }

    pub fn n_params(&self) -> usize {
        self.param_names().iter()
            .map(|n| self.param_shape(n).iter().product::<usize>())
            .sum()
    }
}

// ---------------------------------------------------------------------------
// Manifest (artifacts/manifest.json)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub configs: HashMap<String, ModelConfig>,
    pub models: HashMap<String, ModelEntry>,
    pub tenants: HashMap<String, TenantEntry>,
    pub executables: HashMap<String, ExecutableEntry>,
    pub evals: Vec<String>,
    pub quantized_bases: HashMap<String, QuantBaseEntry>,
    pub lora_rank: usize,
    pub root: PathBuf,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub file: String,
    pub config: String,
}

#[derive(Debug, Clone)]
pub struct TenantEntry {
    pub config: String,
    pub kind: String,
    pub rope_scale: f32,
    pub finetune: String,
    pub delta: String,
    pub delta_initial: String,
    pub svd_r16: Option<SvdEntry>,
    pub svd_req: Option<SvdEntry>,
    pub fidelity: HashMap<String, String>,
}

#[derive(Debug, Clone)]
pub struct SvdEntry {
    pub rank: usize,
    pub initial: String,
    pub distilled: String,
}

#[derive(Debug, Clone)]
pub struct QuantBaseEntry {
    pub base: String,
    pub chat_quantized: String,
    pub delta: String,
}

#[derive(Debug, Clone)]
pub struct ExecutableEntry {
    pub path: String,
    pub kind: String,
    pub config: String,
    pub batch: usize,
    pub seq: usize,
    pub rank: usize,
    /// Mask levels a `decode_bitdelta_l{L}` export sums (1 for the
    /// single-level ABI and for every non-bitdelta kind).
    pub levels: usize,
}

fn model_config_from_json(j: &Json) -> Result<ModelConfig> {
    Ok(ModelConfig {
        name: j.str_field("name")?,
        vocab_size: j.usize_field("vocab_size")?,
        d_model: j.usize_field("d_model")?,
        n_layers: j.usize_field("n_layers")?,
        n_heads: j.usize_field("n_heads")?,
        d_ff: j.usize_field("d_ff")?,
        max_seq_len: j.usize_field("max_seq_len")?,
        rope_theta: j.f64_field("rope_theta")?,
        norm_eps: j.f64_field("norm_eps")?,
    })
}

fn svd_entry_from_json(j: &Json) -> Result<SvdEntry> {
    Ok(SvdEntry {
        rank: j.usize_field("rank")?,
        initial: j.str_field("initial")?,
        distilled: j.str_field("distilled")?,
    })
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make \
artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let mut configs = HashMap::new();
        for (k, v) in j.req("configs")?.as_obj()? {
            configs.insert(k.clone(), model_config_from_json(v)?);
        }
        let mut models = HashMap::new();
        for (k, v) in j.req("models")?.as_obj()? {
            models.insert(k.clone(), ModelEntry {
                file: v.str_field("file")?,
                config: v.str_field("config")?,
            });
        }
        let mut tenants = HashMap::new();
        for (k, v) in j.req("tenants")?.as_obj()? {
            let mut fidelity = HashMap::new();
            if let Some(f) = v.get("fidelity") {
                for (fk, fv) in f.as_obj()? {
                    fidelity.insert(fk.clone(),
                                    fv.as_str()?.to_string());
                }
            }
            tenants.insert(k.clone(), TenantEntry {
                config: v.str_field("config")?,
                kind: v.str_field("kind")?,
                rope_scale: v.f64_field("rope_scale")? as f32,
                finetune: v.str_field("finetune")?,
                delta: v.str_field("delta")?,
                delta_initial: v.str_field("delta_initial")?,
                svd_r16: v.get("svd_r16")
                    .map(svd_entry_from_json).transpose()?,
                svd_req: v.get("svd_req")
                    .map(svd_entry_from_json).transpose()?,
                fidelity,
            });
        }
        let mut executables = HashMap::new();
        for (k, v) in j.req("executables")?.as_obj()? {
            executables.insert(k.clone(), ExecutableEntry {
                path: v.str_field("path")?,
                kind: v.str_field("kind")?,
                config: v.str_field("config")?,
                batch: v.get("batch").map(|b| b.as_usize())
                    .transpose()?.unwrap_or(0),
                seq: v.get("seq").map(|b| b.as_usize())
                    .transpose()?.unwrap_or(0),
                rank: v.get("rank").map(|b| b.as_usize())
                    .transpose()?.unwrap_or(0),
                levels: v.get("levels").map(|b| b.as_usize())
                    .transpose()?.unwrap_or(1),
            });
        }
        let mut quantized_bases = HashMap::new();
        if let Some(q) = j.get("quantized_bases") {
            for (k, v) in q.as_obj()? {
                quantized_bases.insert(k.clone(), QuantBaseEntry {
                    base: v.str_field("base")?,
                    chat_quantized: v.str_field("chat_quantized")?,
                    delta: v.str_field("delta")?,
                });
            }
        }
        let evals = match j.get("evals") {
            Some(e) => e.as_arr()?.iter()
                .map(|v| Ok(v.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
            None => vec![],
        };
        Ok(Manifest {
            version: j.usize_field("version")? as u32,
            configs, models, tenants, executables, evals,
            quantized_bases,
            lora_rank: j.get("lora_rank").map(|v| v.as_usize())
                .transpose()?.unwrap_or(16),
            root,
        })
    }

    /// Absolute path of an artifact-relative file.
    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }

    pub fn config(&self, name: &str) -> Result<&ModelConfig> {
        self.configs.get(name)
            .with_context(|| format!("config {name} not in manifest"))
    }

    /// Find an executable entry by config + kind + batch.
    pub fn find_exec(&self, config: &str, kind: &str, batch: usize)
                     -> Option<&ExecutableEntry> {
        self.executables.values().find(|e| {
            e.config == config && e.kind == kind
                && (batch == 0 || e.batch == batch)
        })
    }

    /// All batch sizes available for (config, kind), ascending.
    pub fn exec_batches(&self, config: &str, kind: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self.executables.values()
            .filter(|e| e.config == config && e.kind == kind)
            .map(|e| e.batch).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_order_matches_python_convention() {
        let cfg = ModelConfig::sim_s();
        let names = cfg.param_names();
        assert_eq!(names[0], "tok_embed");
        assert_eq!(names[1], "layers.0.attn_norm");
        assert_eq!(names[2], "layers.0.wq");
        assert_eq!(names[names.len() - 1], "lm_head");
        assert_eq!(names[names.len() - 2], "final_norm");
        // 1 embed + L*(2 norms + 7 linears) + final_norm + lm_head
        assert_eq!(names.len(), 1 + cfg.n_layers * 9 + 2);
    }

    #[test]
    fn linear_shapes() {
        let cfg = ModelConfig::sim_s();
        assert_eq!(cfg.linear_shape("layers.0.wq"), (128, 128));
        assert_eq!(cfg.linear_shape("layers.3.w_gate"), (344, 128));
        assert_eq!(cfg.linear_shape("layers.3.w_down"), (128, 344));
        assert_eq!(cfg.packed_shape("layers.0.wq"), (128, 16));
    }

    #[test]
    fn n_params_sim_s() {
        let cfg = ModelConfig::sim_s();
        // embed + head: 2*256*128; per layer: 4*128^2 + 3*344*128 + 2*128
        let expect = 2 * 256 * 128
            + cfg.n_layers * (4 * 128 * 128 + 3 * 344 * 128 + 2 * 128)
            + 128;
        assert_eq!(cfg.n_params(), expect);
    }

    #[test]
    fn nonlinear_names_excludes_linears() {
        let cfg = ModelConfig::sim_s();
        let nl = cfg.nonlinear_names();
        assert!(nl.contains(&"tok_embed".to_string()));
        assert!(nl.contains(&"lm_head".to_string()));
        assert!(!nl.iter().any(|n| n.ends_with(".wq")));
        assert_eq!(nl.len(), 2 + 2 * cfg.n_layers + 1);
    }
}
