//! The serving engine: request types, the synchronous engine core, and
//! the async (tokio) front-end service.
//!
//! Thread model: PJRT objects are not `Send`, so the whole engine lives
//! on one dedicated thread; [`service::ServingHandle`] bridges async
//! callers to it over channels. Python is never involved.

pub mod engine;
pub mod request;
pub mod service;
