//! The serving engine: request types, the synchronous engine core, and
//! the concurrent front-end service.
//!
//! Thread model: PJRT objects are not `Send`, so each engine lives on
//! one dedicated thread; [`service::ServingHandle`] bridges concurrent
//! callers to it over channels (the pump loop is shared with the
//! multi-worker [`crate::cluster`] layer). Python is never involved.

pub mod engine;
pub mod request;
pub mod service;
