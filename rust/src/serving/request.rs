//! Request/response types of the serving API.

use std::sync::mpsc::Sender;
use std::time::Duration;

use crate::model::sampling::SamplingParams;

/// A generation request addressed to one tenant (fine-tune identity).
#[derive(Debug, Clone)]
pub struct Request {
    pub tenant: String,
    pub prompt: String,
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
}

/// Completed generation plus serving telemetry.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tenant: String,
    pub text: String,
    pub tokens: Vec<i32>,
    /// end-to-end latency (enqueue -> completion)
    pub latency: Duration,
    /// time to first generated token
    pub ttft: Duration,
    pub prompt_tokens: usize,
}

impl Response {
    /// Per-token decode latency after the first token — the paper's
    /// per-user decoding-latency metric (Fig. 6).
    pub fn decode_latency_per_token(&self) -> Duration {
        let n = self.tokens.len().saturating_sub(1).max(1) as u32;
        (self.latency.saturating_sub(self.ttft)) / n
    }
}

/// A request inside the coordinator, with its response channel.
pub struct QueuedRequest {
    pub request: Request,
    pub id: u64,
    pub respond: Option<Sender<Response>>,
    pub enqueued_at: std::time::Instant,
}

impl QueuedRequest {
    pub fn new(request: Request, id: u64, respond: Sender<Response>)
               -> Self {
        Self { request, id, respond: Some(respond),
               enqueued_at: std::time::Instant::now() }
    }

    /// Channel-less constructor for unit tests.
    pub fn for_test(request: Request, id: u64) -> Self {
        Self { request, id, respond: None,
               enqueued_at: std::time::Instant::now() }
    }
}
