//! Request/response types of the serving API.

use std::sync::mpsc::Sender;
use std::time::Duration;

use crate::model::sampling::SamplingParams;

/// A generation request addressed to one tenant (fine-tune identity).
#[derive(Debug, Clone)]
pub struct Request {
    pub tenant: String,
    pub prompt: String,
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
}

/// Completed generation plus serving telemetry.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tenant: String,
    pub text: String,
    pub tokens: Vec<i32>,
    /// end-to-end latency (enqueue -> completion)
    pub latency: Duration,
    /// time to first generated token
    pub ttft: Duration,
    pub prompt_tokens: usize,
}

impl Response {
    /// Per-token decode latency after the first token — the paper's
    /// per-user decoding-latency metric (Fig. 6).
    pub fn decode_latency_per_token(&self) -> Duration {
        let n = self.tokens.len().saturating_sub(1).max(1) as u32;
        (self.latency.saturating_sub(self.ttft)) / n
    }
}

/// A malformed request, rejected at admission on its own response
/// channel. Never fails the engine step: in-flight sequences keep
/// decoding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestError {
    /// Prompt tokenized to zero tokens.
    EmptyPrompt { id: u64 },
    /// Prompt + max_new_tokens exceeds the model's context window.
    TooLong { id: u64, need: usize, max_seq_len: usize },
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::EmptyPrompt { id } => {
                write!(f, "empty prompt (request {id})")
            }
            RequestError::TooLong { id, need, max_seq_len } => {
                write!(f, "request {id} needs {need} tokens but \
max_seq_len is {max_seq_len}")
            }
        }
    }
}

impl std::error::Error for RequestError {}

/// A request inside the coordinator, with its response channel.
pub struct QueuedRequest {
    pub request: Request,
    pub id: u64,
    pub respond: Option<Sender<Result<Response, RequestError>>>,
    pub enqueued_at: std::time::Instant,
}

impl QueuedRequest {
    pub fn new(request: Request, id: u64,
               respond: Sender<Result<Response, RequestError>>)
               -> Self {
        Self { request, id, respond: Some(respond),
               enqueued_at: std::time::Instant::now() }
    }

    /// Channel-less constructor for unit tests.
    pub fn for_test(request: Request, id: u64) -> Self {
        Self { request, id, respond: None,
               enqueued_at: std::time::Instant::now() }
    }
}
