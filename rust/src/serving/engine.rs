//! The serving engine: continuous-batching decode loop over the AOT
//! executables, in three execution modes.
//!
//! * [`ExecMode::BitDelta`] — the paper's system: shared base linears
//!   (device-resident, uploaded once) + per-tenant stacked 1-bit deltas,
//!   re-assembled **only when the batch composition changes** (hot-swap).
//! * [`ExecMode::Naive`]    — B full fine-tuned models stacked per slot;
//!   faithful to the baseline that OOMs in Figs. 5/6.
//! * [`ExecMode::Lora`]     — per-tenant low-rank adapters (S-LoRA
//!   comparator).
//!
//! Prefill is piggybacked on the batched decode step (Orca-style
//! continuous batching): a freshly admitted sequence consumes one prompt
//! token per step through the same executable, so prefill and decode
//! coexist in one batch and no separate prefill executable sits on the
//! hot path.

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{Manifest, ModelConfig};
use crate::coordinator::admission::AdmissionPolicy;
use crate::coordinator::batcher::{ActiveSeq, Batcher};
use crate::coordinator::deltastore::DeltaStore;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::{Router, TenantInfo};
use crate::kvcache::SeqCache;
use crate::model::sampling::sample;
use crate::model::tokenizer::ByteTokenizer;
use crate::runtime::client::{Executable, Runtime};
use crate::runtime::variants::{BaseLinears, BitDeltaArgs, DecodeOut,
                               LoraArgs, NaiveArgs};
use crate::serving::request::{QueuedRequest, Request, Response};
use crate::store::bdw::RawTensor;
use crate::store::delta_file::{load_model, LoraFile};

/// Which decomposed forward the engine runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    BitDelta,
    Naive,
    Lora,
}

impl ExecMode {
    pub fn exec_kind(&self) -> &'static str {
        match self {
            ExecMode::BitDelta => "decode_bitdelta",
            ExecMode::Naive => "decode_naive",
            ExecMode::Lora => "decode_lora",
        }
    }
}

/// Engine construction parameters.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub artifacts_dir: PathBuf,
    /// Model size name, e.g. "sim-s".
    pub model: String,
    pub mode: ExecMode,
    /// Decode batch width; must match an exported executable.
    pub batch: usize,
    /// Delta residency budget (bytes) for the hot-swap store.
    pub delta_budget_bytes: usize,
    /// Generation stops at this token (None = length-only). Our corpus
    /// terminates answers with '\n'.
    pub stop_token: Option<i32>,
    /// Use pre-distilled scales (`.bdd`) vs initial (`.initial.bdd`).
    pub distilled: bool,
}

impl EngineConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Self {
        Self {
            artifacts_dir: artifacts_dir.into(),
            model: "sim-s".into(),
            mode: ExecMode::BitDelta,
            batch: 4,
            delta_budget_bytes: 256 << 20,
            stop_token: Some(10),
            distilled: true,
        }
    }
}

/// Per-step report (metrics source + bench hook).
#[derive(Debug, Clone, Default)]
pub struct StepReport {
    pub active: usize,
    pub admitted: usize,
    pub completed: usize,
    pub restacked: bool,
    pub exec_seconds: f64,
    pub total_seconds: f64,
}

/// The multi-tenant serving engine (single-threaded; see
/// [`crate::serving::service`] for the async front-end).
pub struct Engine {
    pub cfg: ModelConfig,
    econfig: EngineConfig,
    rt: Runtime,
    decode_exe: Rc<Executable>,
    tok: ByteTokenizer,

    // mode-specific device-resident state
    base_linears: Option<BaseLinears>,
    stacked_bitdelta: Option<(u64, BitDeltaArgs)>,
    stacked_naive: Option<(u64, NaiveArgs)>,
    stacked_lora: Option<(u64, LoraArgs)>,

    // host-side model/adapter caches
    models: HashMap<String, Rc<HashMap<String, RawTensor>>>,
    model_paths: HashMap<String, PathBuf>,
    lora_files: HashMap<String, Rc<LoraFile>>,
    lora_paths: HashMap<String, PathBuf>,

    pub router: Router,
    pub batcher: Batcher,
    pub deltas: DeltaStore,
    pub metrics: Metrics,

    // authoritative stacked KV cache (host copy, ABI layout [L,B,H,S,hd])
    kv_k: Vec<f32>,
    kv_v: Vec<f32>,
    next_id: u64,
}

impl Engine {
    /// Build an engine from artifacts: loads the manifest, compiles the
    /// decode executable, uploads the base weights, registers every
    /// tenant of the chosen model size.
    pub fn from_artifacts(econfig: EngineConfig) -> Result<Self> {
        let manifest = Manifest::load(&econfig.artifacts_dir)?;
        let cfg = manifest.config(&econfig.model)?.clone();
        let mut rt = Runtime::cpu()?;

        let exec = manifest
            .find_exec(&econfig.model, econfig.mode.exec_kind(),
                       econfig.batch)
            .with_context(|| format!(
                "no {} executable at batch {} for {} — available: {:?}",
                econfig.mode.exec_kind(), econfig.batch, econfig.model,
                manifest.exec_batches(&econfig.model,
                                      econfig.mode.exec_kind())))?;
        let decode_exe = rt.load(manifest.path(&exec.path))?;

        // base model (shared linears for bitdelta/lora modes)
        let base_name = format!("{}-base", econfig.model);
        let base_entry = manifest.models.get(&base_name)
            .with_context(|| format!("manifest missing {base_name}"))?;
        let base = load_model(manifest.path(&base_entry.file), &cfg)?;
        let base_linears = match econfig.mode {
            ExecMode::BitDelta | ExecMode::Lora =>
                Some(BaseLinears::from_model(&rt, &cfg, &base)?),
            ExecMode::Naive => None,
        };

        let mut router = Router::new(AdmissionPolicy::default());
        let mut deltas = DeltaStore::new(cfg.clone(),
                                         econfig.delta_budget_bytes);
        let mut model_paths = HashMap::new();
        let mut lora_paths = HashMap::new();
        for (tname, t) in &manifest.tenants {
            if t.config != econfig.model {
                continue;
            }
            router.register_tenant(TenantInfo {
                name: tname.clone(), rope_scale: t.rope_scale });
            let dfile = if econfig.distilled { &t.delta }
                        else { &t.delta_initial };
            deltas.register(tname.clone(), manifest.path(dfile));
            model_paths.insert(tname.clone(),
                               manifest.path(&t.finetune));
            if let Some(svd) = &t.svd_r16 {
                lora_paths.insert(tname.clone(),
                                  manifest.path(&svd.distilled));
            }
        }

        let kv_len = cfg.n_layers * econfig.batch * cfg.n_heads
            * cfg.max_seq_len * cfg.head_dim();
        let batch = econfig.batch;
        Ok(Self {
            cfg, econfig, rt, decode_exe,
            tok: ByteTokenizer::new(),
            base_linears,
            stacked_bitdelta: None,
            stacked_naive: None,
            stacked_lora: None,
            models: HashMap::new(),
            model_paths,
            lora_files: HashMap::new(),
            lora_paths,
            router,
            batcher: Batcher::new(batch),
            deltas,
            metrics: Metrics::default(),
            kv_k: vec![0.0; kv_len],
            kv_v: vec![0.0; kv_len],
            next_id: 1,
        })
    }

    pub fn mode(&self) -> ExecMode {
        self.econfig.mode
    }

    pub fn tenants(&self) -> Vec<String> {
        self.router.tenant_names().to_vec()
    }

    /// Submit a request; response arrives on the returned channel.
    pub fn submit(&mut self, request: Request)
                  -> Result<std::sync::mpsc::Receiver<Response>> {
        let (tx, rx) = std::sync::mpsc::channel();
        let id = self.next_id;
        self.next_id += 1;
        self.router.enqueue(QueuedRequest::new(request, id, tx))?;
        self.metrics.inc("requests", 1);
        Ok(rx)
    }

    /// Run decode steps until every queue and slot is empty.
    pub fn run_until_idle(&mut self, max_steps: usize)
                          -> Result<Vec<StepReport>> {
        let mut reports = Vec::new();
        for _ in 0..max_steps {
            if self.router.total_queued() == 0
                && self.batcher.occupancy() == 0 {
                break;
            }
            reports.push(self.step()?);
        }
        if self.batcher.occupancy() > 0 {
            bail!("run_until_idle: work left after {max_steps} steps");
        }
        Ok(reports)
    }

    /// One engine iteration: admit → assemble → execute → scatter.
    pub fn step(&mut self) -> Result<StepReport> {
        let t_start = Instant::now();
        let mut report = StepReport::default();

        // ---- admission: move queued requests into free slots ----------
        let free = self.batcher.free_slots();
        if free > 0 {
            for qreq in self.router.drain(free) {
                let info = self.router.tenant(&qreq.request.tenant)
                    .ok_or_else(|| anyhow!("tenant vanished"))?.clone();
                let prompt = self.tok.encode(&qreq.request.prompt);
                if prompt.is_empty() {
                    bail!("empty prompt (request {})", qreq.id);
                }
                if prompt.len() + qreq.request.max_new_tokens
                    > self.cfg.max_seq_len {
                    bail!("request {} longer than max_seq_len", qreq.id);
                }
                let first = prompt[0];
                let seq = ActiveSeq {
                    tenant: qreq.request.tenant.clone(),
                    rope_scale: info.rope_scale,
                    cache: SeqCache::new(&self.cfg),
                    prompt,
                    prompt_pos: 0,
                    generated: vec![],
                    next_token: first,
                    started: qreq.enqueued_at,
                    first_token_at: None,
                    req: qreq,
                };
                let slot = self.batcher.admit(seq)
                    .map_err(|_| anyhow!("no free slot after check"))?;
                self.zero_slot_cache(slot);
                self.deltas.pin(&self.batcher.slot(slot).unwrap()
                    .tenant.clone());
                report.admitted += 1;
            }
        }

        let active = self.batcher.active_slots();
        report.active = active.len();
        if active.is_empty() {
            report.total_seconds = t_start.elapsed().as_secs_f64();
            return Ok(report);
        }

        // ---- per-tenant argument assembly (only on composition change)
        let comp = self.batcher.composition_id();
        report.restacked = self.ensure_stacked(comp)?;

        // ---- per-step tensors -----------------------------------------
        let b = self.econfig.batch;
        let mut tokens = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut rope = vec![1.0f32; b];
        for &i in &active {
            let s = self.batcher.slot(i).unwrap();
            tokens[i] = s.next_token;
            pos[i] = s.cache.pos as i32;
            rope[i] = s.rope_scale;
        }

        let kv_shape = [self.cfg.n_layers, b, self.cfg.n_heads,
                        self.cfg.max_seq_len, self.cfg.head_dim()];
        let k_buf = self.rt.upload_f32(&self.kv_k, &kv_shape)?;
        let v_buf = self.rt.upload_f32(&self.kv_v, &kv_shape)?;
        let pos_buf = self.rt.upload_i32(&pos, &[b])?;
        let tok_buf = self.rt.upload_i32(&tokens, &[b])?;
        let rope_buf = self.rt.upload_f32(&rope, &[b])?;

        let mut args: Vec<&xla::PjRtBuffer> = Vec::new();
        match self.econfig.mode {
            ExecMode::BitDelta => {
                let bl = self.base_linears.as_ref().unwrap();
                let st = &self.stacked_bitdelta.as_ref().unwrap().1;
                args.extend(bl.buffers.iter());
                args.extend(st.bits.iter());
                args.push(&st.scales);
                args.extend(st.extras.iter());
            }
            ExecMode::Naive => {
                let st = &self.stacked_naive.as_ref().unwrap().1;
                args.extend(st.buffers.iter());
            }
            ExecMode::Lora => {
                let bl = self.base_linears.as_ref().unwrap();
                let st = &self.stacked_lora.as_ref().unwrap().1;
                args.extend(bl.buffers.iter());
                args.extend(st.a.iter());
                args.extend(st.b.iter());
                args.extend(st.extras.iter());
            }
        }
        args.push(&k_buf);
        args.push(&v_buf);
        args.push(&pos_buf);
        args.push(&tok_buf);
        args.push(&rope_buf);

        // ---- execute -----------------------------------------------------
        let t_exec = Instant::now();
        let lits = self.decode_exe.run_buffers(&args)?;
        report.exec_seconds = t_exec.elapsed().as_secs_f64();
        let out = DecodeOut::from_literals(lits, b)?;
        self.kv_k = out.k.clone();
        self.kv_v = out.v.clone();

        // ---- scatter results ---------------------------------------------
        let stop = self.econfig.stop_token;
        let max_seq = self.cfg.max_seq_len;
        let mut to_release = Vec::new();
        for &i in &active {
            let s = self.batcher.slot_mut(i).unwrap();
            s.cache.pos += 1;
            if s.in_prefill() {
                s.prompt_pos += 1;
                if s.prompt_pos < s.prompt.len() {
                    s.next_token = s.prompt[s.prompt_pos];
                    continue;
                }
                // prefill just finished: fall through to sample the
                // first generated token from this step's logits
                s.first_token_at = Some(Instant::now());
            }
            let t = sample(out.logits_row(i), &s.req.request.sampling,
                           s.generated.len() as u64);
            s.generated.push(t);
            s.next_token = t;
            if s.first_token_at.is_none() {
                s.first_token_at = Some(Instant::now());
            }
            let stopped = stop.map_or(false, |st| t == st);
            if stopped || s.done(max_seq) {
                to_release.push(i);
            }
        }

        for i in to_release {
            let s = self.batcher.release(i).unwrap();
            self.deltas.unpin(&s.tenant);
            let now = Instant::now();
            let latency = now.duration_since(s.started);
            let ttft = s.first_token_at.unwrap_or(now)
                .duration_since(s.started);
            self.metrics.request_latency.observe(latency);
            self.metrics.ttft.observe(ttft);
            self.metrics.inc("completed", 1);
            self.metrics.inc("tokens_generated",
                             s.generated.len() as u64);
            report.completed += 1;
            let resp = Response {
                id: s.req.id,
                tenant: s.tenant.clone(),
                text: self.tok.decode(&s.generated),
                tokens: s.generated.clone(),
                latency,
                ttft,
                prompt_tokens: s.prompt.len(),
            };
            if let Some(tx) = &s.req.respond {
                let _ = tx.send(resp);
            }
        }

        report.total_seconds = t_start.elapsed().as_secs_f64();
        self.metrics.step_latency
            .observe(std::time::Duration::from_secs_f64(
                report.total_seconds));
        self.metrics.inc("steps", 1);
        self.metrics.set("batch_occupancy",
                         report.active as f64 / b as f64);
        Ok(report)
    }

    /// Re-assemble the stacked per-tenant arguments if the batch
    /// composition changed. Returns true if a re-stack happened.
    fn ensure_stacked(&mut self, comp: u64) -> Result<bool> {
        let fresh = match self.econfig.mode {
            ExecMode::BitDelta =>
                self.stacked_bitdelta.as_ref().map(|(c, _)| *c) != Some(comp),
            ExecMode::Naive =>
                self.stacked_naive.as_ref().map(|(c, _)| *c) != Some(comp),
            ExecMode::Lora =>
                self.stacked_lora.as_ref().map(|(c, _)| *c) != Some(comp),
        };
        if !fresh {
            return Ok(false);
        }
        let slots = self.batcher.active_slots();
        let tenants: Vec<String> = {
            let mut order: Vec<String> = Vec::new();
            // slot-indexed tenant list, padding holes with the first
            // active tenant (padding slots are masked by bookkeeping)
            let first = self.batcher.slot(slots[0]).unwrap().tenant.clone();
            for i in 0..self.econfig.batch {
                order.push(self.batcher.slot(i)
                    .map(|s| s.tenant.clone())
                    .unwrap_or_else(|| first.clone()));
            }
            order
        };
        match self.econfig.mode {
            ExecMode::BitDelta => {
                let mut deltas = Vec::new();
                for t in &tenants {
                    deltas.push(self.deltas.fetch(t)?);
                }
                let refs: Vec<&crate::store::delta_file::DeltaFile> =
                    deltas.iter().map(|d| d.as_ref()).collect();
                let stacked = BitDeltaArgs::assemble(
                    &self.rt, &self.cfg, &refs, self.econfig.batch)?;
                self.metrics.inc("delta_restacks", 1);
                self.metrics.inc("delta_restack_bytes",
                                 stacked.staged_bytes as u64);
                self.stacked_bitdelta = Some((comp, stacked));
            }
            ExecMode::Naive => {
                let mut models = Vec::new();
                for t in &tenants {
                    models.push(self.fetch_model(t)?);
                }
                let refs: Vec<&HashMap<String, RawTensor>> =
                    models.iter().map(|m| m.as_ref()).collect();
                let stacked = NaiveArgs::from_models(
                    &self.rt, &self.cfg, &refs, self.econfig.batch)?;
                self.metrics.inc("naive_restacks", 1);
                self.stacked_naive = Some((comp, stacked));
            }
            ExecMode::Lora => {
                let mut files = Vec::new();
                for t in &tenants {
                    files.push(self.fetch_lora(t)?);
                }
                let refs: Vec<&LoraFile> =
                    files.iter().map(|f| f.as_ref()).collect();
                let stacked = LoraArgs::assemble(
                    &self.rt, &self.cfg, &refs, self.econfig.batch)?;
                self.metrics.inc("lora_restacks", 1);
                self.stacked_lora = Some((comp, stacked));
            }
        }
        Ok(true)
    }

    fn fetch_model(&mut self, tenant: &str)
                   -> Result<Rc<HashMap<String, RawTensor>>> {
        if let Some(m) = self.models.get(tenant) {
            return Ok(m.clone());
        }
        let path = self.model_paths.get(tenant)
            .with_context(|| format!("no model file for {tenant}"))?;
        let m = Rc::new(load_model(path, &self.cfg)?);
        self.models.insert(tenant.to_string(), m.clone());
        Ok(m)
    }

    fn fetch_lora(&mut self, tenant: &str) -> Result<Rc<LoraFile>> {
        if let Some(f) = self.lora_files.get(tenant) {
            return Ok(f.clone());
        }
        let path = self.lora_paths.get(tenant)
            .with_context(|| format!(
                "no lora/svd adapter for {tenant} (lora mode only serves \
tenants with svd factors)"))?;
        let f = Rc::new(LoraFile::load(path, &self.cfg)?);
        self.lora_files.insert(tenant.to_string(), f.clone());
        Ok(f)
    }

    fn zero_slot_cache(&mut self, slot: usize) {
        let per_seq = self.cfg.n_heads * self.cfg.max_seq_len
            * self.cfg.head_dim();
        let b = self.econfig.batch;
        for layer in 0..self.cfg.n_layers {
            let off = (layer * b + slot) * per_seq;
            self.kv_k[off..off + per_seq].fill(0.0);
            self.kv_v[off..off + per_seq].fill(0.0);
        }
    }
}
