//! The serving engine: continuous-batching decode loop over the AOT
//! executables, dispatched through the [`DeltaCodec`] registry.
//!
//! Every tenant is served under a **delta codec** (`bitdelta`, `lora`,
//! `svd`, `dense`, …): the codec loads the tenant's payload, accounts
//! its bytes in the hot-swap store, stacks it into the decode ABI, and
//! names the executable to run. The engine itself no longer knows any
//! format — it only distinguishes two batch shapes:
//!
//! * **homogeneous batch** — every active tenant uses the same codec:
//!   run that codec's native executable (`decode_bitdelta`,
//!   `decode_lora`, `decode_naive`) over `codec.assemble(...)`. This is
//!   the paper's fast path: shared base linears device-resident,
//!   per-tenant payloads re-stacked **only when the batch composition
//!   changes** (hot-swap).
//! * **mixed-format batch** — tenants on different codecs share one
//!   decode step: the active slots are grouped by codec and each
//!   group runs **natively** as a sub-batch through its own codec's
//!   `assemble` + executable (non-group slots carry padding payloads
//!   and are masked at harvest); each sub's slot-owned logits and KV
//!   rows are merged after the launches. No dense materialization, no
//!   `4·N·M` byte detour — a mixed batch streams the same bytes per
//!   tenant as a homogeneous one, at the cost of one executable
//!   launch per distinct codec in the batch.
//!   [`EngineConfig::mixed_dense_fallback`] restores the old behavior
//!   (materialize every slot + one stacked-dense `decode_naive`
//!   launch), kept as the A/B correctness reference.
//!
//! Within the `bitdelta` codec, tenants may additionally sit at
//! different **fidelity tiers** ([`EngineConfig::tenant_levels`],
//! Fig. 3): a tier-k tenant's payload carries k mask levels, and the
//! codec's `assemble` keeps a mixed-tier batch homogeneous by padding
//! to the batch-max tier with zero-scale no-op levels (the executable
//! kind is then `decode_bitdelta_l{L}`).
//!
//! Prefill is piggybacked on the batched decode step (Orca-style
//! continuous batching): a freshly admitted sequence consumes one prompt
//! token per step through the same executable, so prefill and decode
//! coexist in one batch and no separate prefill executable sits on the
//! hot path.
//!
//! The KV cache is **paged** by default (see [`crate::kvcache`]):
//! each sequence owns a block table over a ref-counted
//! [`BlockPool`] instead of a preallocated `max_seq_len` slab, appends
//! copy-on-write through shared blocks, and a content-hash prefix
//! index turns a re-seen prompt prefix (same weights + rope + tokens)
//! into shared physical blocks plus skipped prefill steps. The dense
//! staging pair the executables consume is restacked *incrementally* —
//! only an admitted slot is gathered, never the whole batch.
//! [`EngineConfig::kv_slab_fallback`] restores the slab design as the
//! A/B correctness reference, mirroring `mixed_dense_fallback`.
//!
//! Decode K/V is **device-resident** across steps on the single-launch
//! fast path: the untupled decode executables return `[logits, k, v]`
//! as three separate device buffers, the engine feeds `k`/`v` straight
//! back into the next launch, and per step only the logits plus each
//! active slot's freshly produced KV row (pulled by the
//! `kv_row_extract` executable) cross the device boundary. The host
//! staging pair stays authoritative — extracted rows are mirrored into
//! it as they are banked — so admissions (which zero + gather their
//! slot) and native mixed-codec compositions (whose sub-launches each
//! rewrite disjoint slots of a full K/V) fall back transparently to
//! the full round-trip merge. [`EngineConfig::kv_roundtrip`] forces
//! the round-trip everywhere, kept as the A/B correctness reference,
//! mirroring `kv_slab_fallback` and `mixed_dense_fallback`.

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{Manifest, ModelConfig};
use crate::coordinator::admission::AdmissionPolicy;
use crate::coordinator::batcher::{ActiveSeq, Batcher};
use crate::coordinator::deltastore::DeltaStore;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::{Router, TenantInfo};
use crate::delta::codec::{CodecRegistry, DeltaCodec, Model};
use crate::delta::codecs::dense::stack_dense_models;
use crate::kvcache::{share_sig, BlockDims, BlockPool, BlockTable,
                     PrefixIndex, SeqCache, SeqKv};
use crate::model::sampling::sample;
use crate::model::tokenizer::ByteTokenizer;
use crate::runtime::client::{literal_f32, Executable, Runtime};
use crate::runtime::variants::{BaseLinears, DecodeOut, StackedArgs};
use crate::serving::request::{QueuedRequest, Request, RequestError,
                              Response};
use crate::store::delta_file::load_model;

/// Historical three-way mode switch, kept as a thin compatibility shim:
/// each variant is just a default codec name. New code should set
/// [`EngineConfig::codec`] directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    BitDelta,
    Naive,
    Lora,
}

impl ExecMode {
    /// The registry name this legacy mode maps to.
    pub fn codec_name(&self) -> &'static str {
        match self {
            ExecMode::BitDelta => "bitdelta",
            ExecMode::Naive => "dense",
            ExecMode::Lora => "lora",
        }
    }
}

/// Engine construction parameters.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub artifacts_dir: PathBuf,
    /// Model size name, e.g. "sim-s".
    pub model: String,
    /// Legacy mode switch (compatibility shim); ignored when `codec` is
    /// set.
    pub mode: ExecMode,
    /// Default delta codec for every tenant (registry name). Overrides
    /// `mode` when set.
    pub codec: Option<String>,
    /// Per-tenant codec overrides (`tenant -> codec name`): tenants on
    /// different codecs may share a decode batch (mixed-format batch).
    pub codec_overrides: HashMap<String, String>,
    /// Per-tenant fidelity tiers (`tenant -> mask level count`, Fig. 3):
    /// a tenant at tier `k` serves the first `k` levels of its
    /// multi-level delta, paying `k` mask planes of residency for a
    /// fidelity step up. Tenants at different tiers share decode
    /// batches (padded with zero-scale no-op levels). Absent tenants
    /// serve tier 1 (the standard single-mask delta).
    pub tenant_levels: HashMap<String, usize>,
    /// Decode batch width; must match an exported executable.
    pub batch: usize,
    /// Delta residency budget (bytes) for the hot-swap store.
    pub delta_budget_bytes: usize,
    /// Generation stops at this token (None = length-only). Our corpus
    /// terminates answers with '\n'.
    pub stop_token: Option<i32>,
    /// Use pre-distilled scales (`.bdd`) vs initial (`.initial.bdd`).
    pub distilled: bool,
    /// Serve mixed-format batches through dense materialization + the
    /// stacked `decode_naive` executable instead of native per-codec
    /// sub-batches. Kept as the A/B correctness reference (and an
    /// escape hatch for a codec whose only executable is the naive
    /// one).
    pub mixed_dense_fallback: bool,
    /// Serve KV from the dense per-sequence slab (the pre-paging
    /// design) instead of the paged block pool. Kept as the A/B
    /// correctness reference; tests pin the two paths token-identical.
    pub kv_slab_fallback: bool,
    /// Force the full per-step KV host↔device round trip (the
    /// pre-device-resident design) even on single-launch plans. Kept
    /// as the A/B correctness reference (CLI `--kv-roundtrip`),
    /// mirroring `kv_slab_fallback` and `mixed_dense_fallback`; tests
    /// pin the two paths token-identical.
    pub kv_roundtrip: bool,
    /// Tokens per KV block in paged mode (CLI `--kv-block-size`).
    pub kv_block_size: usize,
    /// Total blocks in the paged pool (CLI `--kv-blocks`). `0` =
    /// auto-size to twice a full batch at `max_seq_len`, leaving
    /// headroom for prompt-cache (prefix index) entries.
    pub kv_blocks: usize,
    /// CPU kernel worker-pool width, applied at engine construction
    /// (`0` = leave the process-global `BITDELTA_THREADS` setting
    /// untouched; see [`crate::gemm::dispatch::set_pool_threads`]).
    pub threads: usize,
}

impl EngineConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Self {
        Self {
            artifacts_dir: artifacts_dir.into(),
            model: "sim-s".into(),
            mode: ExecMode::BitDelta,
            codec: None,
            codec_overrides: HashMap::new(),
            tenant_levels: HashMap::new(),
            batch: 4,
            delta_budget_bytes: 256 << 20,
            stop_token: Some(10),
            distilled: true,
            mixed_dense_fallback: false,
            kv_slab_fallback: false,
            kv_roundtrip: false,
            kv_block_size: 16,
            kv_blocks: 0,
            threads: 0,
        }
    }

    /// The effective default codec name (`codec` wins over `mode`).
    pub fn default_codec_name(&self) -> String {
        self.codec.clone()
            .unwrap_or_else(|| self.mode.codec_name().to_string())
    }
}

/// Per-step report (metrics source + bench hook), with a phase
/// breakdown of where the step spent its time and how many bytes
/// crossed the host↔device boundary in each direction. On the
/// device-resident fast path `bytes_h2d`/`bytes_d2h` shrink to the
/// per-step tensors, logits, and per-slot KV rows; a full-KV transfer
/// appearing here in steady state means the round-trip fallback ran.
#[derive(Debug, Clone, Default)]
pub struct StepReport {
    pub active: usize,
    pub admitted: usize,
    pub completed: usize,
    pub restacked: bool,
    pub exec_seconds: f64,
    pub total_seconds: f64,
    /// Host→device staging time (KV + per-step tensors).
    pub upload_seconds: f64,
    /// Device→host fetch time (logits, KV rows or full KV).
    pub download_seconds: f64,
    /// Paged-KV banking time (row scatter + prefix registration).
    pub bank_seconds: f64,
    /// Bytes uploaded this step (staged args counted on restack).
    pub bytes_h2d: u64,
    /// Bytes downloaded this step.
    pub bytes_d2h: u64,
}

/// Reusable per-step buffers: the steady-state decode loop allocates
/// nothing — token/position/rope staging, `bank_kv_row`'s two row
/// gathers, and the mixed-batch merged-logits buffer all live here.
struct StepScratch {
    tokens: Vec<i32>,
    pos: Vec<i32>,
    rope: Vec<f32>,
    row_k: Vec<f32>,
    row_v: Vec<f32>,
    merged_logits: Vec<f32>,
}

impl StepScratch {
    fn new(batch: usize) -> Self {
        Self {
            tokens: vec![0; batch],
            pos: vec![0; batch],
            rope: vec![1.0; batch],
            row_k: Vec::new(),
            row_v: Vec::new(),
            merged_logits: Vec::new(),
        }
    }
}

/// One executable launch within a decode step: the stacked arguments,
/// the executable, and the batch slots whose outputs it owns.
struct SubPlan {
    exec: Rc<Executable>,
    /// Prepend the shared base linears to the argument list.
    needs_base: bool,
    /// Name of the executable kind (metrics label).
    exec_kind: &'static str,
    args: StackedArgs,
    /// Slots harvested from this launch: all of them for a single-sub
    /// plan, the codec group's own slots for a native mixed batch
    /// (whose remaining slots carry padding payloads).
    slots: Vec<usize>,
}

/// The execution plan for one batch composition: a single sub-batch
/// for homogeneous (and dense-fallback mixed) compositions, one per
/// codec group for native mixed-format batches.
struct StackedPlan {
    comp: u64,
    /// Composition *content* (slot → tenant), the plan-cache key.
    /// `comp` ids are monotonic and never repeat, so recurring
    /// compositions under churn are recognized by content.
    key: Vec<(usize, String)>,
    subs: Vec<SubPlan>,
}

/// Stacked plans retained for recurring compositions (churny traffic
/// re-admitting the same tenant mix skips re-assembly + re-upload).
const PLAN_CACHE_CAP: usize = 8;

/// The multi-tenant serving engine (single-threaded; see
/// [`crate::serving::service`] for the async front-end).
pub struct Engine {
    pub cfg: ModelConfig,
    econfig: EngineConfig,
    manifest: Manifest,
    rt: Runtime,
    tok: ByteTokenizer,

    /// Tenant -> its codec (default codec unless overridden).
    codec_of: HashMap<String, Rc<dyn DeltaCodec>>,
    /// Executables by exec kind, loaded lazily (a mixed batch needs
    /// `decode_naive` even when the default codec is `bitdelta`).
    execs: HashMap<&'static str, Rc<Executable>>,

    /// Host copy of the base model (materialize fallback + svd loads).
    base_model: Rc<Model>,
    /// Shared base linears, uploaded once, built on first need.
    base_linears: Option<BaseLinears>,
    /// Current composition's stacked arguments.
    stacked: Option<StackedPlan>,
    /// Recently displaced plans, keyed by composition content (LRU,
    /// oldest first). Device payload buffers stay resident with the
    /// plan, so a cache hit re-uploads nothing.
    plan_cache: Vec<(Vec<(usize, String)>, StackedPlan)>,
    /// Dense weights materialized for mixed-format batches, per tenant.
    materialized: HashMap<String, Rc<Model>>,

    pub router: Router,
    pub batcher: Batcher,
    pub deltas: DeltaStore,
    pub metrics: Metrics,

    // authoritative stacked KV cache (host copy, ABI layout [L,B,H,S,hd])
    kv_k: Vec<f32>,
    kv_v: Vec<f32>,
    /// Device-resident KV pair from the last fast-path step — fed
    /// straight back into the next launch. `None` = host staging must
    /// be (re-)uploaded (after admission gathers, fallback steps, or
    /// before the first step).
    kv_dev: Option<(xla::PjRtBuffer, xla::PjRtBuffer)>,
    /// The `kv_row_extract` executable at this batch width (absent on
    /// artifact sets predating device-resident decode — the engine
    /// then serves via the round-trip path).
    row_extract: Option<Rc<Executable>>,
    /// Sticky degrade: false once a decode launch returned a tupled
    /// output (pre-untuple artifacts), pinning the round-trip path.
    device_outputs_ok: bool,
    scratch: StepScratch,
    /// Paged KV state (`None` under `kv_slab_fallback`).
    kv_pool: Option<BlockPool>,
    kv_prefix: PrefixIndex,
    /// Tenant -> weight-identity signature (codec, fidelity tier,
    /// artifact, distillation flag). Prefix sharing is gated on equal
    /// sigs: only identically-served prompts have bit-identical KV.
    share_sig_of: HashMap<String, u64>,
    // Metrics counters are inc-only while pool/index totals are
    // absolute; these remember what was already exported.
    kv_hits_synced: u64,
    kv_lookups_synced: u64,
    kv_cow_synced: u64,
    next_id: u64,
}

impl Engine {
    /// Build an engine from artifacts: loads the manifest, compiles the
    /// default codec's decode executable, loads the base weights,
    /// registers every tenant of the chosen model size under its codec.
    pub fn from_artifacts(econfig: EngineConfig) -> Result<Self> {
        if econfig.threads > 0 {
            crate::gemm::dispatch::set_pool_threads(econfig.threads);
        }
        let manifest = Manifest::load(&econfig.artifacts_dir)?;
        let cfg = manifest.config(&econfig.model)?.clone();
        let mut rt = Runtime::cpu()?;
        let registry = CodecRegistry::builtin();
        let default_codec = registry.get(&econfig.default_codec_name())?;

        // fail fast: the default codec's decode executable must exist
        let kind = default_codec.exec_kind();
        let exec = manifest
            .find_exec(&econfig.model, kind, econfig.batch)
            .with_context(|| format!(
                "no {} executable at batch {} for {} — available: {:?}",
                kind, econfig.batch, econfig.model,
                manifest.exec_batches(&econfig.model, kind)))?;
        let decode_exe = rt.load(manifest.path(&exec.path))?;
        let mut execs: HashMap<&'static str, Rc<Executable>> =
            HashMap::new();
        execs.insert(kind, decode_exe);

        // device-resident decode downloads per-slot KV rows through
        // this helper; absent on older artifact sets (round-trip path)
        let row_extract = match manifest.find_exec(
            &econfig.model, "kv_row_extract", econfig.batch) {
            Some(e) => Some(rt.load(manifest.path(&e.path))?),
            None => None,
        };

        // base model (shared linears + materialize/svd substrate)
        let base_name = format!("{}-base", econfig.model);
        let base_entry = manifest.models.get(&base_name)
            .with_context(|| format!("manifest missing {base_name}"))?;
        let base_model = Rc::new(
            load_model(manifest.path(&base_entry.file), &cfg)?);

        let mut router = Router::new(AdmissionPolicy::default());
        let mut deltas = DeltaStore::new(cfg.clone(),
                                         econfig.delta_budget_bytes);
        deltas.set_base(base_model.clone());
        let mut codec_of: HashMap<String, Rc<dyn DeltaCodec>> =
            HashMap::new();
        let mut share_sig_of: HashMap<String, u64> = HashMap::new();
        for (tname, t) in &manifest.tenants {
            if t.config != econfig.model {
                continue;
            }
            let codec = match econfig.codec_overrides.get(tname) {
                Some(name) => registry.get(name)?,
                None => default_codec.clone(),
            };
            let levels = econfig.tenant_levels.get(tname).copied()
                .unwrap_or(1);
            if levels == 0 {
                bail!("tenant {tname}: fidelity tier must be >= 1 \
mask level (0 given)");
            }
            router.register_tenant(
                TenantInfo::new(tname.clone(), t.rope_scale)
                    .with_codec(codec.name())
                    .with_levels(levels));
            let apath = codec.artifact_path(&manifest, t,
                                            econfig.distilled, levels);
            // everything that changes the served weights goes into the
            // KV-sharing signature: two tenants may share prefix KV
            // only when their sigs (and rope scales + tokens) agree
            let levels_s = levels.to_string();
            let apath_s = apath.as_ref()
                .map(|p| p.display().to_string())
                .unwrap_or_else(|| "base".into());
            share_sig_of.insert(tname.clone(), share_sig(&[
                codec.name(), &levels_s, &apath_s,
                if econfig.distilled { "distilled" } else { "initial" },
            ]));
            match apath {
                Some(path) => deltas.register(tname.clone(),
                                              codec.clone(), path,
                                              levels),
                None if levels > 1 => bail!(
                    "tenant {tname}: no {levels}-level artifact under \
codec {:?} — fidelity tiers need a bitdelta tenant with a Fig. 3 \
fidelity file of >= {levels} levels", codec.name()),
                None => {}
            }
            codec_of.insert(tname.clone(), codec);
        }
        // a --tenant-levels key naming no served tenant would otherwise
        // be silently ignored — the operator believes a fidelity tier
        // is live that never is; same for a tier whose decode
        // executable was not exported at this batch width, which would
        // only surface mid-serving on the first batch containing the
        // tenant
        for (tname, &lv) in &econfig.tenant_levels {
            let Some(codec) = codec_of.get(tname) else {
                bail!("--tenant-levels names unknown tenant {tname:?} \
— tenants of model {}: {:?}", econfig.model,
                      router.tenant_names());
            };
            if lv <= 1 {
                continue;
            }
            let Some(kind) = codec.exec_kind_for_levels(lv) else {
                bail!("tenant {tname}: codec {:?} has no decode export \
covering fidelity tier {lv}", codec.name());
            };
            if manifest.find_exec(&econfig.model, kind,
                                  econfig.batch).is_none() {
                bail!("tenant {tname} at fidelity tier {lv} needs a \
{kind} executable at batch {} — available batches: {:?}",
                      econfig.batch,
                      manifest.exec_batches(&econfig.model, kind));
            }
        }

        let kv_len = cfg.n_layers * econfig.batch * cfg.n_heads
            * cfg.max_seq_len * cfg.head_dim();
        let batch = econfig.batch;
        let kv_pool = if econfig.kv_slab_fallback {
            None
        } else {
            let bs = econfig.kv_block_size.max(1);
            let per_seq = cfg.max_seq_len.div_ceil(bs);
            let n_blocks = if econfig.kv_blocks > 0 {
                econfig.kv_blocks
            } else {
                batch * per_seq * 2
            };
            Some(BlockPool::new(BlockDims::from_config(&cfg, bs),
                                n_blocks))
        };
        Ok(Self {
            cfg, econfig, manifest, rt,
            tok: ByteTokenizer::new(),
            codec_of,
            execs,
            base_model,
            base_linears: None,
            stacked: None,
            plan_cache: Vec::new(),
            materialized: HashMap::new(),
            router,
            batcher: Batcher::new(batch),
            deltas,
            metrics: Metrics::default(),
            kv_k: vec![0.0; kv_len],
            kv_v: vec![0.0; kv_len],
            kv_dev: None,
            row_extract,
            device_outputs_ok: true,
            scratch: StepScratch::new(batch),
            kv_pool,
            kv_prefix: PrefixIndex::new(),
            share_sig_of,
            kv_hits_synced: 0,
            kv_lookups_synced: 0,
            kv_cow_synced: 0,
            next_id: 1,
        })
    }

    /// Legacy mode accessor (compatibility shim — reflects the config
    /// field, not per-tenant overrides).
    pub fn mode(&self) -> ExecMode {
        self.econfig.mode
    }

    /// The codec name a tenant is served under.
    pub fn tenant_codec(&self, tenant: &str) -> Option<&'static str> {
        self.codec_of.get(tenant).map(|c| c.name())
    }

    /// The fidelity tier (mask level count) a tenant is served at.
    pub fn tenant_fidelity(&self, tenant: &str) -> usize {
        self.router.tenant(tenant).map(|t| t.levels).unwrap_or(1)
    }

    pub fn tenants(&self) -> Vec<String> {
        self.router.tenant_names().to_vec()
    }

    /// Submit a request; the response — or a typed
    /// [`RequestError`] for a malformed request — arrives on the
    /// returned channel.
    pub fn submit(&mut self, request: Request)
                  -> Result<std::sync::mpsc::Receiver<
                      Result<Response, RequestError>>> {
        let (tx, rx) = std::sync::mpsc::channel();
        let id = self.next_id;
        self.next_id += 1;
        self.router.enqueue(QueuedRequest::new(request, id, tx))?;
        self.metrics.inc("requests", 1);
        Ok(rx)
    }

    /// Run decode steps until every queue and slot is empty.
    pub fn run_until_idle(&mut self, max_steps: usize)
                          -> Result<Vec<StepReport>> {
        let mut reports = Vec::new();
        for _ in 0..max_steps {
            if self.router.total_queued() == 0
                && self.batcher.occupancy() == 0 {
                break;
            }
            reports.push(self.step()?);
        }
        if self.batcher.occupancy() > 0 {
            bail!("run_until_idle: work left after {max_steps} steps");
        }
        Ok(reports)
    }

    /// One engine iteration: admit → assemble → execute → scatter.
    pub fn step(&mut self) -> Result<StepReport> {
        let t_start = Instant::now();
        let mut report = StepReport::default();

        // ---- admission: move queued requests into free slots ----------
        let free = self.batcher.free_slots();
        if free > 0 {
            for qreq in self.router.drain(free) {
                let info = self.router.tenant(&qreq.request.tenant)
                    .ok_or_else(|| anyhow!("tenant vanished"))?.clone();
                let prompt = self.tok.encode(&qreq.request.prompt);
                // a malformed request fails on its own response
                // channel — never the step: in-flight sequences (and
                // the rest of this admission drain) keep going
                let malformed = if prompt.is_empty() {
                    Some(RequestError::EmptyPrompt { id: qreq.id })
                } else if prompt.len() + qreq.request.max_new_tokens
                    > self.cfg.max_seq_len {
                    Some(RequestError::TooLong {
                        id: qreq.id,
                        need: prompt.len()
                            + qreq.request.max_new_tokens,
                        max_seq_len: self.cfg.max_seq_len,
                    })
                } else {
                    None
                };
                if let Some(err) = malformed {
                    self.metrics.inc("rejected", 1);
                    if let Some(tx) = &qreq.respond {
                        let _ = tx.send(Err(err));
                    }
                    continue;
                }
                // paged admission: reuse the longest registered prefix
                // (same weights sig + rope + tokens). The matched
                // prefill steps are skipped — the last prompt token
                // always runs so this step's logits seed sampling.
                let mut prompt_pos = 0usize;
                let kv = match &mut self.kv_pool {
                    None => SeqKv::Slab(SeqCache::new(&self.cfg)),
                    Some(pool) => {
                        let sig = self.share_sig_of
                            .get(&qreq.request.tenant).copied()
                            .unwrap_or(0);
                        let bs = pool.dims().block_size;
                        let usable = &prompt[..prompt.len() - 1];
                        let table = match self.kv_prefix.lookup(
                            sig, info.rope_scale, usable, bs) {
                            Some((blocks, len)) => {
                                prompt_pos = len;
                                BlockTable::with_shared_prefix(
                                    pool, &blocks)
                            }
                            None => BlockTable::new(),
                        };
                        SeqKv::Paged(table)
                    }
                };
                let first = prompt[prompt_pos];
                let seq = ActiveSeq {
                    tenant: qreq.request.tenant.clone(),
                    rope_scale: info.rope_scale,
                    kv,
                    prompt,
                    prompt_pos,
                    generated: vec![],
                    next_token: first,
                    started: qreq.enqueued_at,
                    first_token_at: None,
                    req: qreq,
                };
                let slot = self.batcher.admit(seq)
                    .map_err(|_| anyhow!("no free slot after check"))?;
                // incremental restack: only the admitted slot's staging
                // region is rewritten, never the whole batch
                self.zero_slot_cache(slot);
                if let Some(pool) = &self.kv_pool {
                    // lint: allow(unwrap, admit() just filled this slot)
                    let s = self.batcher.slot(slot).unwrap();
                    if let SeqKv::Paged(t) = &s.kv {
                        if !t.is_empty() {
                            t.gather_into(pool, slot,
                                          self.econfig.batch,
                                          self.cfg.max_seq_len,
                                          &mut self.kv_k,
                                          &mut self.kv_v);
                        }
                    }
                }
                self.metrics.inc("kv_restacked_slots", 1);
                // lint: allow(unwrap, admit() just filled this slot)
                self.deltas.pin(&self.batcher.slot(slot).unwrap()
                    .tenant.clone());
                report.admitted += 1;
            }
        }

        // per-tenant queue depth after admission (exported as labeled
        // gauges; the routing signal a cluster front-end also reads)
        for t in self.router.tenant_names() {
            let depth = self.router.queued_for(t) as f64;
            self.metrics.set_tenant_gauge("queue_depth", t, depth);
        }

        let active = self.batcher.active_slots();
        report.active = active.len();
        if active.is_empty() {
            report.total_seconds = t_start.elapsed().as_secs_f64();
            return Ok(report);
        }

        // ---- per-tenant argument assembly (only on composition change)
        let comp = self.batcher.composition_id();
        report.restacked = self.ensure_stacked(comp)?;
        if report.restacked {
            if let Some(p) = &self.stacked {
                report.bytes_h2d += p.subs.iter()
                    .map(|s| s.args.staged_bytes as u64).sum::<u64>();
            }
        }

        // ---- per-step tensors (persistent scratch, zero allocation) ---
        let b = self.econfig.batch;
        self.scratch.tokens.fill(0);
        self.scratch.pos.fill(0);
        self.scratch.rope.fill(1.0);
        for &i in &active {
            // lint: allow(unwrap, active_slots() yields occupied slots)
            let s = self.batcher.slot(i).unwrap();
            let (nt, p, rs) = (s.next_token, s.kv.pos() as i32,
                               s.rope_scale);
            self.scratch.tokens[i] = nt;
            self.scratch.pos[i] = p;
            self.scratch.rope[i] = rs;
        }

        // the fast path needs one launch owning every slot (homogeneous
        // or dense-fallback mixed), untupled outputs, and the row
        // extractor; otherwise this step runs the full round trip
        let single_launch = self.stacked.as_ref().map_or(false, |p| {
            p.subs.len() == 1 && p.subs[0].slots.len() == b
        });
        let fast = single_launch && !self.econfig.kv_roundtrip
            && self.row_extract.is_some() && self.device_outputs_ok;

        let kv_shape = [self.cfg.n_layers, b, self.cfg.n_heads,
                        self.cfg.max_seq_len, self.cfg.head_dim()];
        let t_upload = Instant::now();
        let pos_buf = self.rt.upload_i32(&self.scratch.pos, &[b])?;
        let tok_buf = self.rt.upload_i32(&self.scratch.tokens, &[b])?;
        let rope_buf = self.rt.upload_f32(&self.scratch.rope, &[b])?;
        report.bytes_h2d += (3 * b * 4) as u64;
        // KV upload only when the device copy is stale (admission wrote
        // host staging) or this step round-trips anyway; a steady-state
        // fast-path step uploads 3 small per-step tensors and nothing
        // else
        let fresh_kv = if fast && self.kv_dev.is_some() {
            None
        } else {
            let k_buf = self.rt.upload_f32(&self.kv_k, &kv_shape)?;
            let v_buf = self.rt.upload_f32(&self.kv_v, &kv_shape)?;
            report.bytes_h2d += (self.kv_k.len() + self.kv_v.len())
                as u64 * 4;
            Some((k_buf, v_buf))
        };
        report.upload_seconds = t_upload.elapsed().as_secs_f64();

        // ---- execute + harvest -------------------------------------------
        // fast path: one launch, `[logits, k, v]` stay on device, K/V
        // feed the next step; downloads = logits + per-slot KV rows.
        // round trip: one launch per sub-batch, full K/V downloaded and
        // merged on host (subs own disjoint slots, so their updates
        // never overlap).
        let logits: Vec<f32>;
        let vocab: usize;
        // per-slot new KV rows from the device path: `(B, L, H, hd)`
        // each — slot i's row is `rows_*[i*row_len..(i+1)*row_len]`,
        // already in `bank_row`'s `[L*H, hd]` layout
        let mut rows: Option<(Vec<f32>, Vec<f32>)> = None;
        if fast {
            let mut out = {
                let plan = self.stacked.as_ref().ok_or_else(
                    || anyhow!("no stacked plan after assembly"))?;
                let sub = &plan.subs[0];
                let mut args: Vec<&xla::PjRtBuffer> = Vec::new();
                if sub.needs_base {
                    let bl = self.base_linears.as_ref().ok_or_else(
                        || anyhow!("base linears missing for {}",
                                   sub.exec_kind))?;
                    args.extend(bl.buffers.iter());
                }
                args.extend(sub.args.buffers.iter());
                let (k_ref, v_ref) =
                    if let Some((k, v)) = &fresh_kv {
                        (k, v)
                    } else if let Some((k, v)) = &self.kv_dev {
                        (k, v)
                    } else {
                        bail!("no KV source for device-resident step");
                    };
                args.push(k_ref);
                args.push(v_ref);
                args.push(&pos_buf);
                args.push(&tok_buf);
                args.push(&rope_buf);
                let t_exec = Instant::now();
                let out = sub.exec.run_buffers_device(&args)?;
                report.exec_seconds += t_exec.elapsed().as_secs_f64();
                out
            };
            if out.len() == 3 {
                // lint: allow(unwrap, len == 3 checked just above)
                let v_dev = out.pop().unwrap();
                // lint: allow(unwrap, len == 3 checked just above)
                let k_dev = out.pop().unwrap();
                // lint: allow(unwrap, len == 3 checked just above)
                let logits_dev = out.pop().unwrap();
                let t_dl = Instant::now();
                let lit = logits_dev.to_literal_sync()
                    .map_err(|e| anyhow!("fetch logits: {e}"))?;
                logits = literal_f32(&lit)?;
                vocab = logits.len() / b;
                // lint: allow(unwrap, `fast` implies row_extract is Some)
                let rex = self.row_extract.as_ref().unwrap().clone();
                let ex_args: [&xla::PjRtBuffer; 3] =
                    [&k_dev, &v_dev, &pos_buf];
                let row_lits = rex.run_buffers(&ex_args)?;
                if row_lits.len() != 2 {
                    bail!("kv_row_extract: want 2 outputs, got {}",
                          row_lits.len());
                }
                let rows_k = literal_f32(&row_lits[0])?;
                let rows_v = literal_f32(&row_lits[1])?;
                report.bytes_d2h += (logits.len() + rows_k.len()
                                     + rows_v.len()) as u64 * 4;
                report.download_seconds +=
                    t_dl.elapsed().as_secs_f64();
                rows = Some((rows_k, rows_v));
                self.kv_dev = Some((k_dev, v_dev));
                self.metrics.inc("step_kv_device", 1);
            } else {
                // tupled output: artifacts predate the untupled
                // lowering — decompose on host and degrade permanently
                // to the round-trip path
                self.device_outputs_ok = false;
                let t_dl = Instant::now();
                let lit = out[0].to_literal_sync()
                    .map_err(|e| anyhow!("fetch decode tuple: {e}"))?;
                let lits = lit.to_tuple()
                    .map_err(|e| anyhow!("decode tuple: {e}"))?;
                let dec = DecodeOut::from_literals(lits, b)?;
                report.bytes_d2h += (dec.logits.len() + dec.k.len()
                                     + dec.v.len()) as u64 * 4;
                report.download_seconds +=
                    t_dl.elapsed().as_secs_f64();
                vocab = dec.vocab;
                logits = dec.logits;
                self.kv_k = dec.k;
                self.kv_v = dec.v;
                self.kv_dev = None;
            }
        } else {
            let (k_buf, v_buf) = fresh_kv.as_ref().ok_or_else(
                || anyhow!("round-trip step without KV upload"))?;
            let mut outs: Vec<(&[usize], DecodeOut)> = Vec::new();
            {
                let plan = self.stacked.as_ref().ok_or_else(
                    || anyhow!("no stacked plan after assembly"))?;
                for sub in &plan.subs {
                    let mut args: Vec<&xla::PjRtBuffer> = Vec::new();
                    if sub.needs_base {
                        let bl = self.base_linears.as_ref().ok_or_else(
                            || anyhow!("base linears missing for {}",
                                       sub.exec_kind))?;
                        args.extend(bl.buffers.iter());
                    }
                    args.extend(sub.args.buffers.iter());
                    args.push(k_buf);
                    args.push(v_buf);
                    args.push(&pos_buf);
                    args.push(&tok_buf);
                    args.push(&rope_buf);

                    let t_exec = Instant::now();
                    let lits = sub.exec.run_buffers(&args)?;
                    report.exec_seconds +=
                        t_exec.elapsed().as_secs_f64();
                    let t_dl = Instant::now();
                    let dec = DecodeOut::from_literals(lits, b)?;
                    report.bytes_d2h += (dec.logits.len() + dec.k.len()
                                         + dec.v.len()) as u64 * 4;
                    report.download_seconds +=
                        t_dl.elapsed().as_secs_f64();
                    outs.push((&sub.slots, dec));
                }
            }
            // the round trip leaves host staging authoritative; any
            // device KV pair is stale from here on
            self.kv_dev = None;
            if outs.len() == 1 && outs[0].0.len() == b {
                // lint: allow(unwrap, len == 1 checked on this same line)
                let (_, out) = outs.pop().unwrap();
                vocab = out.vocab;
                logits = out.logits;
                self.kv_k = out.k;
                self.kv_v = out.v;
            } else {
                vocab = outs.first().ok_or_else(
                    || anyhow!("no sub-batch outputs"))?.1.vocab;
                let mut merged =
                    std::mem::take(&mut self.scratch.merged_logits);
                merged.clear();
                merged.resize(b * vocab, 0.0);
                let per_seq = self.cfg.n_heads * self.cfg.max_seq_len
                    * self.cfg.head_dim();
                for (slots, out) in &outs {
                    for &i in *slots {
                        merged[i * vocab..(i + 1) * vocab]
                            .copy_from_slice(out.logits_row(i));
                        for layer in 0..self.cfg.n_layers {
                            let off = (layer * b + i) * per_seq;
                            self.kv_k[off..off + per_seq]
                                .copy_from_slice(
                                    &out.k[off..off + per_seq]);
                            self.kv_v[off..off + per_seq]
                                .copy_from_slice(
                                    &out.v[off..off + per_seq]);
                        }
                    }
                }
                logits = merged;
            }
        }

        // ---- scatter results ---------------------------------------------
        let stop = self.econfig.stop_token;
        let max_seq = self.cfg.max_seq_len;
        let row_len = self.cfg.n_layers * self.cfg.n_heads
            * self.cfg.head_dim();
        let mut to_release = Vec::new();
        for &i in &active {
            let t_bank = Instant::now();
            if let Some((rows_k, rows_v)) = &rows {
                // device path: bank the extracted row directly and
                // mirror it into host staging, which stays
                // authoritative for fallback steps + admission gathers
                let p = self.scratch.pos[i] as usize;
                let rk = &rows_k[i * row_len..(i + 1) * row_len];
                let rv = &rows_v[i * row_len..(i + 1) * row_len];
                self.mirror_row_to_staging(i, b, p, rk, rv);
                self.bank_row(i, rk, rv)?;
            } else {
                self.bank_kv_row(i, b)?;
            }
            report.bank_seconds += t_bank.elapsed().as_secs_f64();
            // lint: allow(unwrap, active_slots() yields occupied slots)
            let s = self.batcher.slot_mut(i).unwrap();
            if s.in_prefill() {
                s.prompt_pos += 1;
                if s.prompt_pos < s.prompt.len() {
                    s.next_token = s.prompt[s.prompt_pos];
                    continue;
                }
                // prefill just finished: fall through to sample the
                // first generated token from this step's logits
                s.first_token_at = Some(Instant::now());
            }
            let t = sample(&logits[i * vocab..(i + 1) * vocab],
                           &s.req.request.sampling,
                           s.generated.len() as u64);
            s.generated.push(t);
            s.next_token = t;
            if s.first_token_at.is_none() {
                s.first_token_at = Some(Instant::now());
            }
            let stopped = stop.map_or(false, |st| t == st);
            if stopped || s.done(max_seq) {
                to_release.push(i);
            }
        }

        for i in to_release {
            // lint: allow(unwrap, to_release holds active slot indices)
            let mut s = self.batcher.release(i).unwrap();
            if let (Some(pool), SeqKv::Paged(t)) =
                (&mut self.kv_pool, &mut s.kv) {
                // prefix-index references keep registered prompt
                // blocks alive past the sequence (the prompt cache)
                t.free(pool);
            }
            self.deltas.unpin(&s.tenant);
            let now = Instant::now();
            let latency = now.duration_since(s.started);
            let ttft = s.first_token_at.unwrap_or(now)
                .duration_since(s.started);
            self.metrics.request_latency.observe(latency);
            self.metrics.ttft.observe(ttft);
            self.metrics.inc("completed", 1);
            self.metrics.inc("tokens_generated",
                             s.generated.len() as u64);
            report.completed += 1;
            let resp = Response {
                id: s.req.id,
                tenant: s.tenant.clone(),
                text: self.tok.decode(&s.generated),
                tokens: s.generated.clone(),
                latency,
                ttft,
                prompt_tokens: s.prompt.len(),
            };
            if let Some(tx) = &s.req.respond {
                let _ = tx.send(Ok(resp));
            }
        }

        // recycle the step's logits buffer (mixed merges resize it)
        self.scratch.merged_logits = logits;

        self.sync_kv_metrics();
        report.total_seconds = t_start.elapsed().as_secs_f64();
        self.metrics.step_latency
            .observe(std::time::Duration::from_secs_f64(
                report.total_seconds));
        self.metrics.inc("steps", 1);
        self.metrics.inc("step_bytes_h2d", report.bytes_h2d);
        self.metrics.inc("step_bytes_d2h", report.bytes_d2h);
        self.metrics.inc("step_upload_us",
                         (report.upload_seconds * 1e6) as u64);
        self.metrics.inc("step_exec_us",
                         (report.exec_seconds * 1e6) as u64);
        self.metrics.inc("step_download_us",
                         (report.download_seconds * 1e6) as u64);
        self.metrics.inc("step_bank_us",
                         (report.bank_seconds * 1e6) as u64);
        self.metrics.set("batch_occupancy",
                         report.active as f64 / b as f64);
        Ok(report)
    }

    /// Scatter one slot's freshly produced KV row from the dense
    /// staging pair into the sequence's backing store (the round-trip
    /// path: gathers the row out of staging, then banks it). The
    /// device path skips the gather and calls [`Self::bank_row`] with
    /// the extracted row directly.
    fn bank_kv_row(&mut self, i: usize, b: usize) -> Result<()> {
        let Some(pool) = &self.kv_pool else {
            // slab: the staging pair *is* the store — just bump pos
            // lint: allow(unwrap, callers pass active slot indices)
            self.batcher.slot_mut(i).unwrap().kv.slab_mut().pos += 1;
            return Ok(());
        };
        let d = pool.dims();
        // lint: allow(unwrap, callers pass active slot indices)
        let p = self.batcher.slot(i).unwrap().kv.pos();
        let (hd, max_seq) = (d.head_dim, self.cfg.max_seq_len);
        let mut row_k = std::mem::take(&mut self.scratch.row_k);
        let mut row_v = std::mem::take(&mut self.scratch.row_v);
        row_k.resize(d.row_floats(), 0.0);
        row_v.resize(d.row_floats(), 0.0);
        for lh in 0..d.n_layers * d.n_heads {
            let (l, h) = (lh / d.n_heads, lh % d.n_heads);
            let src = (((l * b + i) * d.n_heads + h) * max_seq + p)
                * hd;
            row_k[lh * hd..(lh + 1) * hd]
                .copy_from_slice(&self.kv_k[src..src + hd]);
            row_v[lh * hd..(lh + 1) * hd]
                .copy_from_slice(&self.kv_v[src..src + hd]);
        }
        let res = self.bank_row(i, &row_k, &row_v);
        self.scratch.row_k = row_k;
        self.scratch.row_v = row_v;
        res
    }

    /// Append one freshly produced KV row (layout `[L*H, hd]`) to slot
    /// `i`'s backing store. Slab: bump `pos` (the staging pair *is*
    /// the store). Paged: append the row to the block table
    /// (copy-on-write through shared tails, reclaiming prompt-cache
    /// entries under pool pressure) and register completed
    /// prompt-region blocks in the prefix index.
    fn bank_row(&mut self, i: usize, row_k: &[f32], row_v: &[f32])
                -> Result<()> {
        let Some(pool) = &mut self.kv_pool else {
            // lint: allow(unwrap, callers pass active slot indices)
            self.batcher.slot_mut(i).unwrap().kv.slab_mut().pos += 1;
            return Ok(());
        };
        let d = pool.dims();
        // lint: allow(unwrap, callers pass active slot indices)
        let s = self.batcher.slot_mut(i).unwrap();
        let table = s.kv.table_mut();
        if table.append_row(pool, row_k, row_v).is_err() {
            // drop oldest prompt-cache entries, then retry once; a
            // still-full pool surfaces the typed KvOomError
            let dropped = self.kv_prefix.reclaim(pool, 1);
            self.metrics.inc("kv_prefix_reclaimed", dropped as u64);
            table.append_row(pool, row_k, row_v)
                .map_err(|e| anyhow::Error::new(e).context(
                    "KV pool exhausted (raise --kv-blocks)"))?;
        }
        // register every completed prompt-region block: the prompt
        // cache later admissions hit, within and across tenants
        let len = table.len();
        if len % d.block_size == 0 && len <= s.prompt.len() {
            let sig = self.share_sig_of.get(&s.tenant).copied()
                .unwrap_or(0);
            self.kv_prefix.register(pool, sig, s.rope_scale,
                                    &s.prompt[..len], table.blocks());
        }
        Ok(())
    }

    /// Mirror one slot's device-extracted KV row into the host staging
    /// pair at its ABI offsets, keeping staging authoritative for
    /// round-trip steps and admission-time gathers.
    fn mirror_row_to_staging(&mut self, i: usize, b: usize, p: usize,
                             row_k: &[f32], row_v: &[f32]) {
        let (nh, hd) = (self.cfg.n_heads, self.cfg.head_dim());
        let max_seq = self.cfg.max_seq_len;
        for lh in 0..self.cfg.n_layers * nh {
            let (l, h) = (lh / nh, lh % nh);
            let dst = (((l * b + i) * nh + h) * max_seq + p) * hd;
            self.kv_k[dst..dst + hd]
                .copy_from_slice(&row_k[lh * hd..(lh + 1) * hd]);
            self.kv_v[dst..dst + hd]
                .copy_from_slice(&row_v[lh * hd..(lh + 1) * hd]);
        }
    }

    /// Export paged-KV occupancy gauges and bump the inc-only prefix /
    /// COW counters by their deltas since the last step.
    fn sync_kv_metrics(&mut self) {
        let Some(pool) = &self.kv_pool else { return };
        self.metrics.set("kv_blocks_used", pool.used_blocks() as f64);
        self.metrics.set("kv_blocks_total",
                         pool.total_blocks() as f64);
        let hits = self.kv_prefix.hits - self.kv_hits_synced;
        self.metrics.inc("kv_prefix_hits", hits);
        self.kv_hits_synced = self.kv_prefix.hits;
        let lookups = self.kv_prefix.lookups - self.kv_lookups_synced;
        self.metrics.inc("kv_prefix_lookups", lookups);
        self.kv_lookups_synced = self.kv_prefix.lookups;
        let cow = pool.cow_copies - self.kv_cow_synced;
        self.metrics.inc("kv_cow_copies", cow);
        self.kv_cow_synced = pool.cow_copies;
    }

    /// Re-assemble the stacked per-tenant arguments if the batch
    /// composition changed. Returns true if a re-stack happened
    /// (plan-cache hits swap in a retained plan without one).
    fn ensure_stacked(&mut self, comp: u64) -> Result<bool> {
        if self.stacked.as_ref().map(|p| p.comp) == Some(comp) {
            return Ok(false);
        }
        // the composition *id* moved, but ids are monotonic (bumped on
        // admit AND release) — recognize recurring compositions by
        // content so churny traffic skips re-assembly + re-upload
        let key = self.batcher.composition();
        if let Some(plan) = &mut self.stacked {
            if plan.key == key {
                plan.comp = comp;
                self.metrics.inc("plan_cache_hits", 1);
                return Ok(false);
            }
        }
        if let Some(idx) = self.plan_cache.iter()
            .position(|(k, _)| *k == key) {
            let (_, mut plan) = self.plan_cache.remove(idx);
            plan.comp = comp;
            if let Some(old) = self.stacked.replace(plan) {
                self.stash_plan(old);
            }
            self.metrics.inc("plan_cache_hits", 1);
            return Ok(false);
        }
        let slots = self.batcher.active_slots();
        // slot-indexed tenant list, padding holes with the first active
        // tenant (padding slots are masked by bookkeeping)
        let tenants: Vec<String> = {
            // lint: allow(unwrap, active_slots() yields occupied slots)
            let first = self.batcher.slot(slots[0]).unwrap().tenant.clone();
            (0..self.econfig.batch).map(|i| {
                self.batcher.slot(i)
                    .map(|s| s.tenant.clone())
                    .unwrap_or_else(|| first.clone())
            }).collect()
        };
        let codecs: Vec<Rc<dyn DeltaCodec>> = tenants.iter().map(|t| {
            self.codec_of.get(t).cloned()
                .ok_or_else(|| anyhow!("tenant {t} has no codec"))
        }).collect::<Result<_>>()?;
        let homogeneous = codecs.windows(2)
            .all(|w| w[0].name() == w[1].name());

        let mut subs: Vec<SubPlan> = Vec::new();
        if homogeneous {
            let codec = codecs[0].clone();
            let mut payloads = Vec::new();
            for t in &tenants {
                payloads.push(self.deltas.fetch(t)?);
            }
            let refs: Vec<&dyn crate::delta::codec::Payload> =
                payloads.iter().map(|p| p.as_ref()).collect();
            let args = codec.assemble(&self.rt, &self.cfg, &refs,
                                      self.econfig.batch)?;
            // homogeneous compositions need no dense fallbacks at all —
            // release any weights a previous mixed batch materialized
            self.materialized.clear();
            // a codec may retarget the batch (e.g. bitdelta raising a
            // mixed-fidelity batch to the decode_bitdelta_l{L} tier)
            let kind = args.exec_kind.unwrap_or_else(|| codec.exec_kind());
            drop(refs);
            drop(payloads);
            let exec = self.exec_for(kind)?;
            subs.push(SubPlan {
                exec,
                needs_base: codec.needs_base(),
                exec_kind: kind,
                args,
                slots: (0..self.econfig.batch).collect(),
            });
        } else if self.econfig.mixed_dense_fallback {
            // dense materialization: every slot's payload becomes full
            // dense weights and one stacked `decode_naive` launch
            // covers the batch — correct for any codec combination at
            // the naive path's memory cost
            let mut models = Vec::new();
            for (t, c) in tenants.iter().zip(&codecs) {
                models.push(self.fetch_materialized(t, c.clone())?);
            }
            let refs: Vec<&Model> =
                models.iter().map(|m| m.as_ref()).collect();
            let args = stack_dense_models(&self.rt, &self.cfg, &refs,
                                          self.econfig.batch)?;
            drop(refs);
            drop(models);
            // bound the dense cache to the tenants actually in this
            // composition — without this, every tenant that ever rode a
            // mixed batch would keep a full fine-tune resident (naive-
            // mode memory, invisible to the delta budget)
            self.materialized.retain(|t, _| tenants.contains(t));
            self.metrics.inc("mixed_batches", 1);
            let exec = self.exec_for("decode_naive")?;
            subs.push(SubPlan {
                exec,
                needs_base: false,
                exec_kind: "decode_naive",
                args,
                slots: (0..self.econfig.batch).collect(),
            });
        } else {
            // native mixed-format batch: group the active slots by
            // codec and stack each group through its own codec's
            // assemble + executable — the 1-bit (or low-rank) traffic
            // win survives mixing, no 4·N·M dense detour
            let mut groups: Vec<(&'static str, Vec<usize>)> = Vec::new();
            for &i in &slots {
                let name = codecs[i].name();
                match groups.iter_mut().find(|(n, _)| *n == name) {
                    Some((_, g)) => g.push(i),
                    None => groups.push((name, vec![i])),
                }
            }
            for (_, group) in groups {
                let codec = codecs[group[0]].clone();
                // batch-width payload list: the group's slots carry
                // their own tenant's payload; every other slot repeats
                // the group's first payload as valid padding, masked
                // out at harvest (only `group` slots are read back)
                let mut payloads = Vec::new();
                for i in 0..self.econfig.batch {
                    let t = if group.contains(&i) {
                        &tenants[i]
                    } else {
                        &tenants[group[0]]
                    };
                    payloads.push(self.deltas.fetch(t)?);
                }
                let refs: Vec<&dyn crate::delta::codec::Payload> =
                    payloads.iter().map(|p| p.as_ref()).collect();
                let args = codec.assemble(&self.rt, &self.cfg, &refs,
                                          self.econfig.batch)?;
                let kind = args.exec_kind
                    .unwrap_or_else(|| codec.exec_kind());
                drop(refs);
                drop(payloads);
                let exec = self.exec_for(kind)?;
                subs.push(SubPlan {
                    exec,
                    needs_base: codec.needs_base(),
                    exec_kind: kind,
                    args,
                    slots: group,
                });
            }
            // the native path materializes nothing
            self.materialized.clear();
            self.metrics.inc("mixed_batches", 1);
            self.metrics.inc("mixed_native_subbatches",
                             subs.len() as u64);
        }

        if subs.iter().any(|s| s.needs_base)
            && self.base_linears.is_none() {
            self.base_linears = Some(BaseLinears::from_model(
                &self.rt, &self.cfg, &self.base_model)?);
        }
        self.metrics.inc("delta_restacks", 1);
        let staged: usize =
            subs.iter().map(|s| s.args.staged_bytes).sum();
        self.metrics.inc("delta_restack_bytes", staged as u64);
        for s in &subs {
            self.metrics.inc(s.exec_kind, 1);
        }
        if let Some(old) =
            self.stacked.replace(StackedPlan { comp, key, subs }) {
            self.stash_plan(old);
        }
        Ok(true)
    }

    /// Retain a displaced plan for later reuse (bounded LRU: oldest
    /// entry evicted at capacity, dropping its device buffers).
    fn stash_plan(&mut self, plan: StackedPlan) {
        if self.plan_cache.len() >= PLAN_CACHE_CAP {
            self.plan_cache.remove(0);
        }
        let key = plan.key.clone();
        self.plan_cache.push((key, plan));
    }

    /// Executable for an exec kind at the engine's batch width (lazy,
    /// cached).
    fn exec_for(&mut self, kind: &'static str) -> Result<Rc<Executable>> {
        if let Some(e) = self.execs.get(kind) {
            return Ok(e.clone());
        }
        let entry = self.manifest
            .find_exec(&self.econfig.model, kind, self.econfig.batch)
            .with_context(|| format!(
                "no {} executable at batch {} for {} — available: {:?}",
                kind, self.econfig.batch, self.econfig.model,
                self.manifest.exec_batches(&self.econfig.model, kind)))?;
        let exe = self.rt.load(self.manifest.path(&entry.path))?;
        self.execs.insert(kind, exe.clone());
        Ok(exe)
    }

    /// Per-codec residency/load accounting in Prometheus-ish text,
    /// appended to the metrics exposition by the CLI (`repro serve`).
    pub fn codec_accounting(&self) -> String {
        let mut out = String::new();
        let mut resident: Vec<_> = self.deltas.resident_bytes_by_codec()
            .into_iter().collect();
        resident.sort();
        for (codec, bytes) in resident {
            out.push_str(&format!(
                "bitdelta_delta_resident_bytes{{codec=\"{codec}\"}} \
{bytes}\n"));
        }
        let mut loaded: Vec<_> = self.deltas.stats.by_codec.iter()
            .collect();
        loaded.sort_by_key(|(k, _)| k.to_string());
        for (codec, cs) in loaded {
            out.push_str(&format!(
                "bitdelta_delta_loads_total{{codec=\"{codec}\"}} {}\n\
                 bitdelta_delta_bytes_loaded_total{{codec=\"{codec}\"}} \
{}\n\
                 bitdelta_delta_evictions_total{{codec=\"{codec}\"}} {}\n",
                cs.loads, cs.bytes_loaded, cs.evictions));
        }
        out
    }

    /// Dense weights for a tenant under its codec (mixed-batch path),
    /// cached per tenant.
    fn fetch_materialized(&mut self, tenant: &str,
                          codec: Rc<dyn DeltaCodec>) -> Result<Rc<Model>> {
        if let Some(m) = self.materialized.get(tenant) {
            return Ok(m.clone());
        }
        let payload = self.deltas.fetch(tenant)?;
        let m = codec.materialize(&self.cfg, &self.base_model,
                                  payload.as_ref())?;
        self.materialized.insert(tenant.to_string(), m.clone());
        Ok(m)
    }

    fn zero_slot_cache(&mut self, slot: usize) {
        let per_seq = self.cfg.n_heads * self.cfg.max_seq_len
            * self.cfg.head_dim();
        let b = self.econfig.batch;
        for layer in 0..self.cfg.n_layers {
            let off = (layer * b + slot) * per_seq;
            self.kv_k[off..off + per_seq].fill(0.0);
            self.kv_v[off..off + per_seq].fill(0.0);
        }
        // host staging just diverged from the device pair (admission
        // zeroes + gathers its slot): next step re-uploads staging
        self.kv_dev = None;
    }
}
