//! Concurrent front-end: bridges multi-threaded callers to the
//! single-threaded engine.
//!
//! PJRT objects are not `Send`, so the engine runs on a dedicated OS
//! thread; this service owns the command channel and pumps the engine
//! loop whenever there is work. Handles are `Clone + Send` — any number
//! of client threads can submit concurrently (the async-runtime role;
//! the build image has no tokio, so the bridge is std channels —
//! semantics are identical: submit returns immediately, the response
//! arrives on a per-request channel).

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::serving::engine::{Engine, EngineConfig};
use crate::serving::request::{Request, Response};

enum Command {
    Submit(Request, mpsc::Sender<Result<Response>>),
    Metrics(mpsc::Sender<String>),
    Shutdown,
}

/// Cloneable, `Send` handle to a running engine thread.
#[derive(Clone)]
pub struct ServingHandle {
    tx: mpsc::Sender<Command>,
}

/// The engine thread + its handle.
pub struct ServingService {
    handle: ServingHandle,
    join: Option<JoinHandle<Result<()>>>,
}

impl ServingService {
    /// Spawn the engine on its own thread; fails fast if engine
    /// construction fails.
    pub fn spawn(config: EngineConfig) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Command>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("bitdelta-engine".into())
            .spawn(move || engine_thread(config, rx, ready_tx))?;
        ready_rx.recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Self { handle: ServingHandle { tx }, join: Some(join) })
    }

    pub fn handle(&self) -> ServingHandle {
        self.handle.clone()
    }

    /// Stop the engine thread (drains in-flight work first).
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.handle.tx.send(Command::Shutdown);
        if let Some(j) = self.join.take() {
            j.join().map_err(|_| anyhow!("engine thread panicked"))??;
        }
        Ok(())
    }
}

impl ServingHandle {
    /// Submit a request; returns a channel the response arrives on.
    pub fn submit(&self, req: Request)
                  -> Result<mpsc::Receiver<Result<Response>>> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Command::Submit(req, tx))
            .map_err(|_| anyhow!("engine is gone"))?;
        Ok(rx)
    }

    /// Submit and block until the response arrives.
    pub fn generate(&self, req: Request) -> Result<Response> {
        self.submit(req)?
            .recv().map_err(|_| anyhow!("engine dropped the request"))?
    }

    /// Fetch the metrics exposition text.
    pub fn metrics(&self) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Command::Metrics(tx))
            .map_err(|_| anyhow!("engine is gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped the request"))
    }
}

type Pending = Vec<(mpsc::Receiver<Response>,
                    mpsc::Sender<Result<Response>>)>;

fn engine_thread(config: EngineConfig, rx: mpsc::Receiver<Command>,
                 ready: mpsc::Sender<Result<()>>) -> Result<()> {
    let mut engine = match Engine::from_artifacts(config) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow!("{e:#}")));
            return Ok(());
        }
    };

    let mut pending: Pending = Vec::new();

    loop {
        // 1. ingest commands (non-blocking while busy, blocking if idle)
        let busy = engine.batcher.occupancy() > 0
            || engine.router.total_queued() > 0;
        let cmd = if busy {
            match rx.try_recv() {
                Ok(c) => Some(c),
                Err(mpsc::TryRecvError::Empty) => None,
                Err(mpsc::TryRecvError::Disconnected) => return Ok(()),
            }
        } else {
            match rx.recv() {
                Ok(c) => Some(c),
                Err(_) => return Ok(()),
            }
        };
        match cmd {
            Some(Command::Submit(req, reply)) => {
                match engine.submit(req) {
                    Ok(chan) => pending.push((chan, reply)),
                    Err(e) => {
                        let _ = reply.send(Err(anyhow!("{e:#}")));
                    }
                }
            }
            Some(Command::Metrics(reply)) => {
                let _ = reply.send(engine.metrics.exposition());
            }
            Some(Command::Shutdown) => {
                let _ = engine.run_until_idle(1_000_000);
                deliver_ready(&mut pending);
                return Ok(());
            }
            None => {}
        }

        // 2. advance the engine
        if engine.batcher.occupancy() > 0
            || engine.router.total_queued() > 0 {
            if let Err(e) = engine.step() {
                for (_, reply) in pending.drain(..) {
                    let _ = reply.send(Err(anyhow!("engine: {e:#}")));
                }
                return Err(e);
            }
        }

        // 3. deliver finished responses
        deliver_ready(&mut pending);
    }
}

fn deliver_ready(pending: &mut Pending) {
    let mut i = 0;
    while i < pending.len() {
        match pending[i].0.try_recv() {
            Ok(resp) => {
                let (_, reply) = pending.remove(i);
                let _ = reply.send(Ok(resp));
            }
            Err(mpsc::TryRecvError::Empty) => i += 1,
            Err(mpsc::TryRecvError::Disconnected) => {
                let (_, reply) = pending.remove(i);
                let _ = reply.send(Err(anyhow!("request dropped")));
            }
        }
    }
}
