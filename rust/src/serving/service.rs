//! Concurrent front-end: bridges multi-threaded callers to the
//! single-threaded engine.
//!
//! PJRT objects are not `Send`, so the engine runs on a dedicated OS
//! thread; this service owns the command channel and pumps the engine
//! loop whenever there is work. Handles are `Clone + Send` — any number
//! of client threads can submit concurrently (the async-runtime role;
//! the build image has no tokio, so the bridge is std channels —
//! semantics are identical: submit returns immediately, the response
//! arrives on a per-request channel).
//!
//! The pump loop itself lives in [`crate::cluster::worker`] — this
//! service is the single-worker special case of the cluster layer, kept
//! as its own type because "one engine, one handle" is the right API
//! for examples and small deployments.

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::cluster::worker::{spawn_worker, CoreFactory, WorkerCore,
                             WorkerHandle};
use crate::serving::engine::{Engine, EngineConfig};
use crate::serving::request::{Request, Response};

/// Cloneable, `Send` handle to a running engine thread.
#[derive(Clone)]
pub struct ServingHandle {
    inner: WorkerHandle,
}

/// The engine thread + its handle.
pub struct ServingService {
    handle: ServingHandle,
    join: Option<JoinHandle<Result<()>>>,
}

impl ServingService {
    /// Spawn the engine on its own thread; fails fast if engine
    /// construction fails.
    pub fn spawn(config: EngineConfig) -> Result<Self> {
        let factory: CoreFactory = Box::new(move || {
            Ok(Box::new(Engine::from_artifacts(config)?)
               as Box<dyn WorkerCore>)
        });
        let (inner, join) = spawn_worker("bitdelta-engine".into(),
                                         factory)?;
        Ok(Self {
            handle: ServingHandle { inner },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> ServingHandle {
        self.handle.clone()
    }

    /// Stop the engine thread (drains in-flight work first).
    pub fn shutdown(mut self) -> Result<()> {
        self.handle.inner.shutdown_signal();
        if let Some(j) = self.join.take() {
            j.join().map_err(|_| anyhow!("engine thread panicked"))??;
        }
        Ok(())
    }
}

impl ServingHandle {
    /// Submit a request; returns a channel the response arrives on.
    pub fn submit(&self, req: Request)
                  -> Result<mpsc::Receiver<Result<Response>>> {
        self.inner.submit(req)
    }

    /// Submit and block until the response arrives.
    pub fn generate(&self, req: Request) -> Result<Response> {
        self.inner.generate(req)
    }

    /// Fetch the metrics exposition text.
    pub fn metrics(&self) -> Result<String> {
        self.inner.metrics()
    }
}
