//! Likelihood/generation scoring over the AOT logits executable.
//!
//! All scoring goes through `logits_fwd` (the full causal forward). A
//! model under evaluation is always a *dense* weight set: plain models
//! directly, compressed ones via materialisation (`W_base + α·Sign(Δ)`),
//! which computes the same numbers as the serving kernels (pinned by the
//! cross-path equivalence tests).

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::config::ModelConfig;
use crate::eval::tasks::{EvalSet, Scores, TaskKind};
use crate::model::sampling::{argmax, log_softmax};
use crate::model::tokenizer::ByteTokenizer;
use crate::runtime::client::{literal_f32, Executable, Runtime};
use crate::runtime::variants::DenseArgs;
use crate::store::bdw::RawTensor;

/// Evaluates one dense weight set via a `logits_fwd_b{B}_t{T}` executable.
pub struct Evaluator {
    cfg: ModelConfig,
    exe: Rc<Executable>,
    args: DenseArgs,
    tok: ByteTokenizer,
    pub batch: usize,
    pub seq: usize,
    /// Forward passes run (cost accounting).
    pub forwards: u64,
}

impl Evaluator {
    pub fn new(rt: &mut Runtime, cfg: &ModelConfig,
               exe_path: &std::path::Path, batch: usize, seq: usize,
               model: &HashMap<String, RawTensor>) -> Result<Self> {
        let exe = rt.load(exe_path)?;
        let args = DenseArgs::from_model(rt, cfg, model)?;
        Ok(Self { cfg: cfg.clone(), exe, args,
                  tok: ByteTokenizer::new(), batch, seq, forwards: 0 })
    }

    /// Swap in a different dense model (same executable).
    pub fn set_model(&mut self, rt: &Runtime,
                     model: &HashMap<String, RawTensor>) -> Result<()> {
        self.args = DenseArgs::from_model(rt, &self.cfg, model)?;
        Ok(())
    }

    /// Run the batched forward over padded token rows.
    /// Returns per-row logits `[seq][vocab]` (flattened).
    fn forward(&mut self, rt: &Runtime, rows: &[Vec<i32>])
               -> Result<Vec<Vec<f32>>> {
        if rows.len() > self.batch {
            bail!("{} rows > batch {}", rows.len(), self.batch);
        }
        let mut tokens = vec![0i32; self.batch * self.seq];
        for (r, row) in rows.iter().enumerate() {
            if row.len() > self.seq {
                bail!("row of {} tokens > seq {}", row.len(), self.seq);
            }
            tokens[r * self.seq..r * self.seq + row.len()]
                .copy_from_slice(row);
        }
        let tok_buf = rt.upload_i32(&tokens, &[self.batch, self.seq])?;
        let mut args: Vec<&xla::PjRtBuffer> = self.args.refs();
        args.push(&tok_buf);
        let lits = self.exe.run_buffers(&args)?;
        self.forwards += 1;
        let flat = literal_f32(&lits[0])?;
        let v = self.cfg.vocab_size;
        Ok((0..self.batch).map(|r| {
            flat[r * self.seq * v..(r + 1) * self.seq * v].to_vec()
        }).collect())
    }

    /// Score a likelihood pair item batch-at-a-time.
    pub fn score_pair(&mut self, rt: &Runtime, set: &EvalSet)
                      -> Result<f64> {
        assert_eq!(set.kind, TaskKind::Pair);
        let mut correct = 0usize;
        let items: Vec<_> = set.items.iter().collect();
        for chunk in items.chunks(self.batch / 2) {
            // two rows per item: prompt+correct, prompt+incorrect
            let mut rows = Vec::new();
            let mut meta = Vec::new();
            for item in chunk {
                let p = self.tok.encode(&item.prompt);
                // lint: allow(unwrap, TaskKind::Mc items carry both
                // continuations — asserted at fn entry)
                let c = self.tok.encode(item.correct.as_ref().unwrap());
                // lint: allow(unwrap, TaskKind::Mc items carry both
                // continuations — asserted at fn entry)
                let i = self.tok.encode(item.incorrect.as_ref().unwrap());
                let mut rc = p.clone();
                rc.extend(&c);
                let mut ri = p.clone();
                ri.extend(&i);
                meta.push((p.len(), rc.len(), ri.len()));
                rows.push(rc);
                rows.push(ri);
            }
            let logits = self.forward(rt, &rows)?;
            let v = self.cfg.vocab_size;
            for (j, &(plen, clen, ilen)) in meta.iter().enumerate() {
                let lp_c = row_logprob(&logits[2 * j], &rows[2 * j], v,
                                       plen, clen);
                let lp_i = row_logprob(&logits[2 * j + 1],
                                       &rows[2 * j + 1], v, plen, ilen);
                // length-normalised comparison
                if lp_c.0 / lp_c.1 as f64 > lp_i.0 / lp_i.1 as f64 {
                    correct += 1;
                }
            }
        }
        Ok(100.0 * correct as f64 / set.items.len() as f64)
    }

    /// Greedy-decode `answer.len()` tokens via repeated full forwards and
    /// exact-match (GSM8K analog; prompt+answer ≤ seq).
    pub fn score_gen(&mut self, rt: &Runtime, set: &EvalSet)
                     -> Result<f64> {
        assert_eq!(set.kind, TaskKind::Gen);
        let mut correct = 0usize;
        let items: Vec<_> = set.items.iter().collect();
        for chunk in items.chunks(self.batch) {
            let mut rows: Vec<Vec<i32>> = chunk.iter()
                .map(|it| self.tok.encode(&it.prompt)).collect();
            let answers: Vec<Vec<i32>> = chunk.iter()
                // lint: allow(unwrap, TaskKind::Gen items carry an
                // answer — asserted at fn entry)
                .map(|it| self.tok.encode(it.answer.as_ref().unwrap()))
                .collect();
            let max_len =
                answers.iter().map(|a| a.len()).max().unwrap_or(0);
            let v = self.cfg.vocab_size;
            for _ in 0..max_len {
                let logits = self.forward(rt, &rows)?;
                for (j, row) in rows.iter_mut().enumerate() {
                    let pos = row.len() - 1;
                    let t = argmax(&logits[j][pos * v..(pos + 1) * v]);
                    row.push(t);
                }
            }
            for (j, ans) in answers.iter().enumerate() {
                let start = rows[j].len() - max_len;
                let got = &rows[j][start..start + ans.len()];
                if got == &ans[..] {
                    correct += 1;
                }
            }
        }
        Ok(100.0 * correct as f64 / set.items.len() as f64)
    }

    /// Reference-NLL scoring mapped to 0-10 (MT-Bench analog):
    /// `score = 10 · exp(−mean per-token NLL of the reference)`.
    pub fn score_nll(&mut self, rt: &Runtime, set: &EvalSet)
                     -> Result<f64> {
        assert_eq!(set.kind, TaskKind::Nll);
        let mut total_nll = 0f64;
        let mut total_tok = 0usize;
        let items: Vec<_> = set.items.iter().collect();
        for chunk in items.chunks(self.batch) {
            let mut rows = Vec::new();
            let mut meta = Vec::new();
            for item in chunk {
                let p = self.tok.encode(&item.prompt);
                // lint: allow(unwrap, TaskKind::Nll items carry a
                // reference — asserted at fn entry)
                let r = self.tok.encode(item.reference.as_ref().unwrap());
                let mut row = p.clone();
                row.extend(&r);
                meta.push((p.len(), row.len()));
                rows.push(row);
            }
            let logits = self.forward(rt, &rows)?;
            let v = self.cfg.vocab_size;
            for (j, &(plen, tlen)) in meta.iter().enumerate() {
                let (lp, n) = row_logprob(&logits[j], &rows[j], v, plen,
                                          tlen);
                total_nll += -lp;
                total_tok += n;
            }
        }
        let mean_nll = total_nll / total_tok.max(1) as f64;
        Ok(10.0 * (-mean_nll).exp())
    }

    /// Run the whole battery from an eval directory.
    pub fn score_all(&mut self, rt: &Runtime,
                     eval_dir: &std::path::Path) -> Result<Scores> {
        let mut s = Scores::default();
        let mut cloze = Vec::new();
        for entry in std::fs::read_dir(eval_dir)? {
            let path = entry?.path();
            if path.extension().map_or(true, |e| e != "json") {
                continue;
            }
            let set = EvalSet::load(&path)?;
            match (set.task.as_str(), set.kind) {
                ("styleqa", TaskKind::Pair) =>
                    s.styleqa = self.score_pair(rt, &set)?,
                ("arith", TaskKind::Gen) =>
                    s.arith = self.score_gen(rt, &set)?,
                ("instruct", TaskKind::Nll) =>
                    s.instruct = self.score_nll(rt, &set)?,
                (name, TaskKind::Pair) => {
                    let acc = self.score_pair(rt, &set)?;
                    cloze.push((name.to_string(), acc));
                }
                _ => {}
            }
        }
        cloze.sort_by(|a: &(String, f64), b| a.0.cmp(&b.0));
        s.cloze_avg = if cloze.is_empty() { 0.0 } else {
            cloze.iter().map(|(_, a)| a).sum::<f64>() / cloze.len() as f64
        };
        s.cloze = cloze;
        Ok(s)
    }
}

/// Sum log p(tokens[prompt_len..total_len]) from one row's logits.
fn row_logprob(logits: &[f32], row: &[i32], vocab: usize,
               prompt_len: usize, total_len: usize) -> (f64, usize) {
    let mut sum = 0f64;
    let mut n = 0usize;
    for pos in (prompt_len - 1)..(total_len - 1) {
        let ls = log_softmax(&logits[pos * vocab..(pos + 1) * vocab]);
        sum += ls[row[pos + 1] as usize] as f64;
        n += 1;
    }
    (sum, n)
}
