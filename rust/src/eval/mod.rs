//! Evaluation harness — regenerates the paper's quality tables.
//!
//! * [`tasks`]   — eval-set schema (written by `python/compile/data.py`):
//!   likelihood-pair tasks (TruthfulQA/cloze analogs), greedy-exact-match
//!   generation (GSM8K analog), and reference-NLL scoring (MT-Bench
//!   analog).
//! * [`harness`] — runs a dense weight set through the AOT logits
//!   executable and scores every task. Compressed models are evaluated by
//!   **materialising** `W_base + α·Sign(Δ)` — bit-identical to what the
//!   serving path computes (the equivalence is pinned by
//!   `python/tests/test_bitdelta.py::TestServingPathEquivalence` and the
//!   rust integration tests).
//! * [`tables`]  — the per-exhibit drivers (`repro table1`, `repro
//!   table2`, …) that print paper-shaped rows.

pub mod harness;
pub mod tables;
pub mod tasks;
