//! Eval-set schema, shared with `python/compile/data.py::write_evals`.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One evaluation task file.
#[derive(Debug, Clone)]
pub struct EvalSet {
    pub task: String,
    pub kind: TaskKind,
    pub items: Vec<Item>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Choose `correct` vs `incorrect` completion by likelihood
    /// (TruthfulQA / cloze battery analog). Score: accuracy ×100.
    Pair,
    /// Greedy-decode and exact-match `answer` (GSM8K analog).
    /// Score: accuracy ×100.
    Gen,
    /// Reference-NLL scoring (MT-Bench analog).
    /// Score: 10·exp(−mean NLL) ∈ (0, 10].
    Nll,
}

/// One eval item; fields depend on the task kind.
#[derive(Debug, Clone)]
pub struct Item {
    pub prompt: String,
    pub correct: Option<String>,
    pub incorrect: Option<String>,
    pub answer: Option<String>,
    pub reference: Option<String>,
}

impl EvalSet {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        let set = Self::parse(&text)?;
        set.validate()?;
        Ok(set)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("parsing eval set")?;
        let kind = match j.str_field("type")?.as_str() {
            "pair" => TaskKind::Pair,
            "gen" => TaskKind::Gen,
            "nll" => TaskKind::Nll,
            other => bail!("unknown task type {other:?}"),
        };
        let opt = |v: &Json, k: &str| -> Result<Option<String>> {
            Ok(match v.get(k) {
                Some(Json::Null) | None => None,
                Some(s) => Some(s.as_str()?.to_string()),
            })
        };
        let mut items = Vec::new();
        for v in j.req("items")?.as_arr()? {
            items.push(Item {
                prompt: v.str_field("prompt")?,
                correct: opt(v, "correct")?,
                incorrect: opt(v, "incorrect")?,
                answer: opt(v, "answer")?,
                reference: opt(v, "reference")?,
            });
        }
        Ok(EvalSet { task: j.str_field("task")?, kind, items })
    }

    pub fn validate(&self) -> Result<()> {
        for (i, item) in self.items.iter().enumerate() {
            let ok = match self.kind {
                TaskKind::Pair => item.correct.is_some()
                    && item.incorrect.is_some(),
                TaskKind::Gen => item.answer.is_some(),
                TaskKind::Nll => item.reference.is_some(),
            };
            if !ok {
                bail!("task {}: item {i} missing fields for {:?}",
                      self.task, self.kind);
            }
        }
        Ok(())
    }
}

/// Scores for one model over the full battery, in paper-table layout.
#[derive(Debug, Clone, Default)]
pub struct Scores {
    /// TruthfulQA analog (styleqa accuracy ×100).
    pub styleqa: f64,
    /// GSM8K analog (arith exact-match ×100).
    pub arith: f64,
    /// MT-Bench analog (0-10).
    pub instruct: f64,
    /// Adjusted-Average analog (mean of the cloze battery ×100).
    pub cloze_avg: f64,
    /// Each cloze task by name.
    pub cloze: Vec<(String, f64)>,
}

impl Scores {
    pub fn row(&self, label: &str, with_instruct: bool) -> String {
        let mt = if with_instruct {
            format!("{:8.2}", self.instruct)
        } else {
            format!("{:>8}", "-")
        };
        format!("{label:<28} {:>10.2} {:>7.2} {mt} {:>9.2}",
                self.styleqa, self.arith, self.cloze_avg)
    }

    pub fn header() -> String {
        format!("{:<28} {:>10} {:>7} {:>8} {:>9}",
                "Model/Method", "StyleQA*", "Arith*", "MTB*", "ClozeAvg*")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_pair_task() {
        let json = r#"{"task":"styleqa","type":"pair","items":
            [{"prompt":"p","correct":" a","incorrect":" b"}]}"#;
        let s = EvalSet::parse(json).unwrap();
        s.validate().unwrap();
        assert_eq!(s.kind, TaskKind::Pair);
    }

    #[test]
    fn missing_fields_rejected() {
        let json = r#"{"task":"arith","type":"gen","items":
            [{"prompt":"p"}]}"#;
        let s = EvalSet::parse(json).unwrap();
        assert!(s.validate().is_err());
    }
}
