//! Per-exhibit drivers: each function regenerates one of the paper's
//! tables or figures from the artifacts and prints paper-shaped rows.
//!
//! Metric mapping (DESIGN.md §3): StyleQA* ≙ TruthfulQA, Arith* ≙ GSM8K,
//! MTB* ≙ MT-Bench, ClozeAvg* ≙ Adjusted Average.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::config::{Manifest, ModelConfig};
use crate::delta::bitdelta::{materialize, materialize_levels};
use crate::delta::codec::{CodecRegistry, LoadCtx};
use crate::delta::svd::cumulative_explained_variance;
use crate::eval::harness::Evaluator;
use crate::eval::tasks::Scores;
use crate::runtime::client::Runtime;
use crate::store::bdw::RawTensor;
use crate::store::delta_file::{load_model, DeltaFile, LoraFile};
use crate::tensor::Tensor;

type Model = HashMap<String, RawTensor>;

/// Shared evaluation context for the table drivers.
pub struct TableCtx {
    pub manifest: Manifest,
    pub rt: Runtime,
}

impl TableCtx {
    pub fn load(artifacts: impl AsRef<Path>) -> Result<Self> {
        Ok(Self {
            manifest: Manifest::load(artifacts)?,
            rt: Runtime::cpu()?,
        })
    }

    fn evaluator(&mut self, size: &str, model: &Model) -> Result<Evaluator> {
        let cfg = self.manifest.config(size)?.clone();
        let exec = self.manifest.find_exec(size, "logits_fwd", 8)
            .context("no logits_fwd_b8 executable")?;
        let (batch, seq) = (exec.batch, exec.seq);
        let path = self.manifest.path(&exec.path);
        Evaluator::new(&mut self.rt, &cfg, &path, batch, seq, model)
    }

    fn eval_dir(&self) -> std::path::PathBuf {
        self.manifest.root.join("eval")
    }

    fn model(&self, name: &str) -> Result<Model> {
        let entry = self.manifest.models.get(name)
            .with_context(|| format!("model {name} not in manifest"))?;
        let cfg = self.manifest.config(&entry.config)?;
        load_model(self.manifest.path(&entry.file), cfg)
    }

    fn cfg_of_tenant(&self, tenant: &str) -> Result<ModelConfig> {
        let t = self.manifest.tenants.get(tenant)
            .with_context(|| format!("tenant {tenant}"))?;
        Ok(self.manifest.config(&t.config)?.clone())
    }

    fn delta(&self, rel: &str, cfg: &ModelConfig) -> Result<DeltaFile> {
        DeltaFile::load(self.manifest.path(rel), cfg)
    }

    /// Score one dense model over the full battery.
    pub fn score(&mut self, size: &str, model: &Model) -> Result<Scores> {
        let mut ev = self.evaluator(size, model)?;
        let dir = self.eval_dir();
        ev.score_all(&self.rt, &dir)
    }
}

/// Fold LoRA/SVD factors into dense weights: `W = base + b_up @ a_down`.
/// (Thin wrapper over the lora codec's materialization, kept for
/// callers holding a bare [`LoraFile`].)
pub fn materialize_lora(cfg: &ModelConfig, base: &Model, lf: &LoraFile)
                        -> Result<Model> {
    crate::delta::codecs::lora::materialize_lora_payload(cfg, base, lf)
}

/// Human-facing row label for a codec's registry name.
fn codec_label(name: &str) -> &str {
    match name {
        "bitdelta" => "BitDelta",
        "lora" => "SVD (precomputed, r16)",
        "svd" => "SVD (load-time Jacobi)",
        "dense" => "Baseline (fine-tune)",
        other => other,
    }
}

// ---------------------------------------------------------------------------
// Table 1: every registered delta codec vs the fine-tune baseline
// ---------------------------------------------------------------------------

/// One quality row per (codec, phase) that has an artifact for the
/// tenant — driven by the [`CodecRegistry`], so a newly registered codec
/// shows up here (and in the compression bench) with zero table code.
pub fn table1(ctx: &mut TableCtx, size: &str) -> Result<String> {
    let tenant = format!("{size}-chat");
    let cfg = ctx.cfg_of_tenant(&tenant)?;
    let t = ctx.manifest.tenants[&tenant].clone();
    let base = ctx.model(&format!("{size}-base"))?;

    let mut out = String::new();
    out.push_str(&format!(
        "Table 1 — delta codecs vs baseline ({tenant})\n{}\n",
        Scores::header()));

    let s = ctx.score(size, &base)?;
    out.push_str(&format!("{}\n", s.row(&format!("{size}-base"), false)));

    let registry = CodecRegistry::builtin();
    for codec in registry.iter() {
        let mut seen: Vec<std::path::PathBuf> = Vec::new();
        for (phase, distilled) in [("", true), ("-Initial", false)] {
            let Some(path) =
                codec.artifact_path(&ctx.manifest, &t, distilled, 1)
            else { continue };
            if seen.contains(&path) {
                continue;   // e.g. dense: initial == distilled artifact
            }
            seen.push(path.clone());
            let payload = {
                let lctx = LoadCtx { cfg: &cfg, base: Some(&base),
                                     levels: 0 };
                codec.load(&path, &lctx)?
            };
            let m = codec.materialize(&cfg, &base, payload.as_ref())?;
            let s = ctx.score(size, &m)?;
            let label = format!("{}{phase}", codec_label(codec.name()));
            out.push_str(&format!("{}\n", s.row(&label, true)));
        }
    }

    // memory-equivalent SVD comparator (paper Table 1's second SVD
    // column) — an artifact-only baseline, not a serving codec
    if let Some(entry) = &t.svd_req {
        for (phase, rel) in [("", &entry.distilled),
                             ("-Initial", &entry.initial)] {
            let lf = LoraFile::load(ctx.manifest.path(rel), &cfg)?;
            let m = materialize_lora(&cfg, &base, &lf)?;
            let s = ctx.score(size, &m)?;
            out.push_str(&format!(
                "{}\n", s.row(&format!("SVD{phase} (mem-eq, r={})",
                                       entry.rank), true)));
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Tables 2/3 (+10): every tenant, both sizes
// ---------------------------------------------------------------------------

pub fn table2(ctx: &mut TableCtx) -> Result<String> {
    let mut out = String::new();
    out.push_str(&format!(
        "Table 2/3 — BitDelta across sizes and fine-tune types\n{}\n",
        Scores::header()));

    let mut sizes: Vec<String> = ctx.manifest.configs.keys()
        .cloned().collect();
    sizes.sort();
    for size in sizes {
        let base_name = format!("{size}-base");
        if !ctx.manifest.models.contains_key(&base_name) {
            continue;
        }
        let base = ctx.model(&base_name)?;
        let s = ctx.score(&size, &base)?;
        out.push_str(&format!("{}\n", s.row(&base_name, false)));

        let mut tenants: Vec<String> = ctx.manifest.tenants.iter()
            .filter(|(_, t)| t.config == size)
            .map(|(n, _)| n.clone()).collect();
        tenants.sort();
        for tname in tenants {
            let t = ctx.manifest.tenants[&tname].clone();
            let cfg = ctx.cfg_of_tenant(&tname)?;
            let fine = ctx.model(&tname)?;
            let s = ctx.score(&size, &fine)?;
            out.push_str(&format!(
                "{}\n", s.row(&format!("{tname} [{}] Baseline", t.kind),
                              true)));
            for (label, rel) in [("BitDelta-Initial", &t.delta_initial),
                                 ("BitDelta", &t.delta)] {
                let d = ctx.delta(rel, &cfg)?;
                let m = materialize(&cfg, &base, &d)?;
                let s = ctx.score(&size, &m)?;
                out.push_str(&format!(
                    "{}\n", s.row(&format!("{tname} {label}"), true)));
            }
        }
        out.push('\n');
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 6 (+8): BitDelta over quantized base models
// ---------------------------------------------------------------------------

pub fn table6(ctx: &mut TableCtx, size: &str) -> Result<String> {
    let tenant = format!("{size}-chat");
    let cfg = ctx.cfg_of_tenant(&tenant)?;
    let mut out = String::new();
    out.push_str(&format!(
        "Table 6 — BitDelta on quantized bases ({tenant})\n{}\n",
        Scores::header()));

    // FP32 rows (our full-precision analog of the paper's FP16)
    let base = ctx.model(&format!("{size}-base"))?;
    let fine = ctx.model(&tenant)?;
    let s = ctx.score(size, &fine)?;
    out.push_str(&format!("{}\n", s.row("Baseline FP32", true)));
    let t = ctx.manifest.tenants[&tenant].clone();
    let d = ctx.delta(&t.delta, &cfg)?;
    let m = materialize(&cfg, &base, &d)?;
    let s = ctx.score(size, &m)?;
    out.push_str(&format!("{}\n", s.row("FP32 + Δ", true)));

    let mut methods: Vec<String> = ctx.manifest.quantized_bases.keys()
        .cloned().collect();
    methods.sort();
    for method in methods {
        let q = ctx.manifest.quantized_bases[&method].clone();
        // Baseline: the fine-tune itself quantized with this method
        let qf_name = q.chat_quantized.trim_start_matches("models/")
            .trim_end_matches(".bdw").to_string();
        let qf = ctx.model(&qf_name)?;
        let s = ctx.score(size, &qf)?;
        out.push_str(&format!(
            "{}\n", s.row(&format!("Baseline {method}"), true)));
        // BitDelta on the quantized base
        let qb_name = q.base.trim_start_matches("models/")
            .trim_end_matches(".bdw").to_string();
        let qb = ctx.model(&qb_name)?;
        let d = ctx.delta(&q.delta, &cfg)?;
        let m = materialize(&cfg, &qb, &d)?;
        let s = ctx.score(size, &m)?;
        out.push_str(&format!(
            "{}\n", s.row(&format!("{method} + Δ"), true)));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 7: BitDelta on a LoRA fine-tune
// ---------------------------------------------------------------------------

pub fn table7(ctx: &mut TableCtx, size: &str) -> Result<String> {
    let tenant = format!("{size}-lora");
    let cfg = ctx.cfg_of_tenant(&tenant)?;
    let t = ctx.manifest.tenants[&tenant].clone();
    let base = ctx.model(&format!("{size}-base"))?;

    let mut out = String::new();
    out.push_str(&format!(
        "Table 7 — BitDelta on a rank-16 LoRA fine-tune ({tenant})\n{}\n",
        Scores::header()));
    let s = ctx.score(size, &base)?;
    out.push_str(&format!("{}\n", s.row(&format!("{size}-base"), false)));
    let fine = ctx.model(&tenant)?;
    let s = ctx.score(size, &fine)?;
    out.push_str(&format!("{}\n", s.row("LoRA fine-tune (merged)", true)));
    let d = ctx.delta(&t.delta, &cfg)?;
    let m = materialize(&cfg, &base, &d)?;
    let s = ctx.score(size, &m)?;
    out.push_str(&format!("{}\n", s.row("BitDelta", true)));
    Ok(out)
}

// ---------------------------------------------------------------------------
// Figure 3 / Table 9: fidelity ablation
// ---------------------------------------------------------------------------

/// Relative reconstruction error of a k-level materialization over
/// **all** linears: `‖Δ − Δ̂_k‖_F / ‖Δ‖_F` with both norms taken across
/// the whole set of delta matrices (the scalar the Fig. 3 x-axis walks
/// down). Takes the already-materialized model so the caller pays the
/// reconstruction once per level.
fn recon_rel_err(cfg: &ModelConfig, base: &Model, fine: &Model,
                 mat: &Model) -> Result<f64> {
    let mut err2 = 0f64;
    let mut norm2 = 0f64;
    for name in cfg.linear_names() {
        let wb = base[&name].as_f32()?;
        let wf = fine[&name].as_f32()?;
        let wm = mat[&name].as_f32()?;
        for ((b, f), m) in wb.iter().zip(&wf).zip(&wm) {
            err2 += ((f - m) as f64).powi(2);
            norm2 += ((f - b) as f64).powi(2);
        }
    }
    Ok((err2 / norm2.max(1e-30)).sqrt())
}

/// Fig. 3 / Table 9 reproduction: eval quality **and** relative
/// reconstruction error vs the number of served mask levels k — the
/// table `repro fig3` / `repro table-fig3` emits. The same k-level
/// reconstruction the serving path computes (assemble/forward_linear
/// sum the identical levels), so this closes the fidelity-tier loop.
pub fn fig3(ctx: &mut TableCtx, size: &str) -> Result<String> {
    let tenant = format!("{size}-chat");
    let cfg = ctx.cfg_of_tenant(&tenant)?;
    let t = ctx.manifest.tenants[&tenant].clone();
    let base = ctx.model(&format!("{size}-base"))?;
    let fine = ctx.model(&tenant)?;

    let mut out = String::new();
    out.push_str(&format!(
        "Figure 3 / Table 9 — fidelity of Δ ({tenant})\n{}  {}\n",
        Scores::header(), "recon_rel_err"));
    let s = ctx.score(size, &base)?;
    out.push_str(&format!("{}  {:>13.5}\n",
                          s.row("base (0 bits)", false), 1.0));

    let mut levels: Vec<usize> = t.fidelity.keys()
        .filter_map(|k| k.parse().ok()).collect();
    levels.sort_unstable();
    if let Some(&max) = levels.last() {
        let rel = &t.fidelity[&max.to_string()];
        let d = ctx.delta(rel, &cfg)?;
        for k in &levels {
            let m = materialize_levels(&cfg, &base, &d, *k)?;
            let s = ctx.score(size, &m)?;
            let e = recon_rel_err(&cfg, &base, &fine, &m)?;
            out.push_str(&format!(
                "{}  {:>13.5}\n", s.row(&format!("{k} bit(s)"), false),
                e));
        }
    }
    let s = ctx.score(size, &fine)?;
    out.push_str(&format!("{}  {:>13.5}\n",
                          s.row("fine-tune (full)", true), 0.0));
    Ok(out)
}

// ---------------------------------------------------------------------------
// Figure 2: cumulative explained variance of a real fine-tune delta
// ---------------------------------------------------------------------------

pub fn fig2(ctx: &mut TableCtx, size: &str) -> Result<String> {
    let base = ctx.model(&format!("{size}-base"))?;
    let cfg = ctx.manifest.config(size)?.clone();
    let name = &cfg.linear_names()[cfg.linear_names().len() / 2];
    let (n, m) = cfg.linear_shape(name);

    let series = |fine: &Model| -> Result<Vec<f64>> {
        let wb = base[name].as_f32()?;
        let wf = fine[name].as_f32()?;
        let d: Vec<f32> = wf.iter().zip(&wb).map(|(f, b)| f - b).collect();
        Ok(cumulative_explained_variance(&Tensor::new(vec![n, m], d)))
    };

    let full = series(&ctx.model(&format!("{size}-chat"))?)?;
    let lora = series(&ctx.model(&format!("{size}-lora"))?)?;

    let mut out = String::new();
    out.push_str(&format!(
        "Figure 2 — CEV of the {name} delta ({n}x{m})\n\
         rank_frac,cev_full_ft,cev_lora_ft\n"));
    let k = full.len();
    for i in 0..k {
        out.push_str(&format!("{:.4},{:.5},{:.5}\n",
                              (i + 1) as f64 / k as f64, full[i],
                              lora.get(i).copied().unwrap_or(1.0)));
    }
    // headline scalars
    let r90_full = full.iter().position(|&c| c >= 0.9).unwrap_or(k) + 1;
    let r90_lora = lora.iter().position(|&c| c >= 0.9).unwrap_or(k) + 1;
    out.push_str(&format!(
        "# components for 90% variance: full-FT {r90_full}/{k}, \
         LoRA-FT {r90_lora}/{k}\n"));
    Ok(out)
}
