//! Model-side utilities of the L3 runtime: tokenization, sampling, and
//! weight-set assembly for the four serving modes.

pub mod sampling;
pub mod tokenizer;

pub use sampling::{sample, SamplingParams};
pub use tokenizer::ByteTokenizer;
