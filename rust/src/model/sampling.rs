//! Token sampling over the logits the decode executables return.

/// Sampling configuration for one request.
#[derive(Debug, Clone, Copy)]
pub struct SamplingParams {
    /// 0.0 = greedy.
    pub temperature: f32,
    /// Keep only the top-k logits before sampling (0 = all).
    pub top_k: usize,
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { temperature: 0.0, top_k: 0, seed: 0 }
    }
}

impl SamplingParams {
    pub fn greedy() -> Self {
        Self::default()
    }
}

/// Sample one token id from a logits row. Deterministic for a given
/// (params.seed, step) pair — reproducible serving traces.
pub fn sample(logits: &[f32], params: &SamplingParams, step: u64) -> i32 {
    if params.temperature <= 0.0 {
        return argmax(logits);
    }
    // top-k filter
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if params.top_k > 0 && params.top_k < logits.len() {
        idx.sort_unstable_by(|&a, &b| logits[b].total_cmp(&logits[a]));
        idx.truncate(params.top_k);
    }
    // softmax at temperature over the kept set
    let max = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY,
                                                  f32::max);
    let probs: Vec<f64> = idx.iter()
        .map(|&i| (((logits[i] - max) / params.temperature) as f64).exp())
        .collect();
    let total: f64 = probs.iter().sum();

    // deterministic uniform draw from (seed, step) via splitmix64
    let mut z = params.seed ^ step.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    let u = (z >> 11) as f64 / (1u64 << 53) as f64 * total;

    let mut acc = 0.0;
    for (k, &i) in idx.iter().enumerate() {
        acc += probs[k];
        if u <= acc {
            return i as i32;
        }
    }
    idx[idx.len() - 1] as i32
}

pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best as i32
}

/// Log-softmax of one logits row (likelihood scoring in the eval harness).
pub fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse = logits.iter().map(|&v| ((v - max) as f64).exp())
        .sum::<f64>().ln() as f32 + max;
    logits.iter().map(|&v| v - lse).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let logits = [0.1f32, 2.0, -1.0, 1.9];
        assert_eq!(sample(&logits, &SamplingParams::greedy(), 0), 1);
    }

    #[test]
    fn deterministic_per_seed_step() {
        let logits = [0.5f32, 0.4, 0.6, 0.3];
        let p = SamplingParams { temperature: 1.0, top_k: 0, seed: 42 };
        let a = sample(&logits, &p, 3);
        let b = sample(&logits, &p, 3);
        assert_eq!(a, b);
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = [10.0f32, 9.5, -50.0, -60.0];
        let p = SamplingParams { temperature: 1.0, top_k: 2, seed: 1 };
        for step in 0..50 {
            let t = sample(&logits, &p, step);
            assert!(t == 0 || t == 1, "sampled {t}");
        }
    }

    #[test]
    fn log_softmax_normalises() {
        let logits = [1.0f32, 2.0, 3.0];
        let ls = log_softmax(&logits);
        let total: f64 = ls.iter().map(|&v| (v as f64).exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
    }
}
