//! Byte-level tokenizer — identical to `python/compile/data.py`'s
//! encode/decode (token = byte value; vocab 256).

/// Byte-level tokenizer. Stateless; exists as a type so the serving API
/// reads like a real stack and alternative tokenizers can slot in.
#[derive(Debug, Clone, Copy, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn new() -> Self {
        Self
    }

    pub fn vocab_size(&self) -> usize {
        256
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xFF) as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn decode_one(&self, token: i32) -> char {
        ((token & 0xFF) as u8) as char
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer::new();
        let s = "Q: what color is the sky ?\nA:";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn tokens_are_bytes() {
        let t = ByteTokenizer::new();
        assert_eq!(t.encode("A"), vec![65]);
        assert_eq!(t.encode("\n"), vec![10]);
    }

    #[test]
    fn out_of_range_tokens_wrap() {
        let t = ByteTokenizer::new();
        assert_eq!(t.decode(&[65 + 256]), "A");
    }
}
