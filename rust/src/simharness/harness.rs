//! The simulation driver: a real [`Cluster`] on a virtual clock.
//!
//! Everything here is the production code path — real
//! [`ClusterHandle`] routing, real placement policies, a real
//! [`Autoscaler`] control loop, the real admission gate — driven over
//! mock [`crate::cluster::testutil::MockCore`]s whose service time
//! goes through the [`crate::sync::clock`] seam. The driver owns the
//! only call to [`clock::advance`]: each tick it fires due
//! [`FaultSchedule`] events, submits due trace arrivals, harvests
//! resolved tickets, runs the [`InvariantMonitor`], then advances
//! virtual time by one quantum (with one *real* sub-millisecond nap so
//! the worker / autoscaler OS threads get scheduled — the single
//! wall-clock dependency, which paces but never orders the
//! simulation).
//!
//! Determinism boundary, stated honestly: the tenant population, the
//! arrival trace and the fault schedule are bit-deterministic per
//! seed; OS thread interleavings are not. The monitor therefore checks
//! *safety* properties that must hold under every interleaving, and a
//! failing run's seed + schedule reproduce the same scripted inputs
//! exactly (throughput-style counts may wiggle run to run; violations
//! must stay at zero on every run).

use std::collections::HashMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::cluster::autoscaler::{Autoscaler, AutoscalerConfig};
use crate::cluster::frontend::{
    Cluster, ClusterConfig, ClusterHandle, ClusterTicket,
    WorkerFactoryFn,
};
use crate::cluster::placement::{policy_by_name, RouteError};
use crate::cluster::testutil::{req, MockCore};
use crate::cluster::worker::{CoreFactory, WorkerCore};
use crate::coordinator::admission::{AdmissionError, AdmissionPolicy};
use crate::coordinator::workload::{
    self, ArrivalPattern, TraceConfig, TraceEvent,
};
use crate::sync::clock;

use super::monitor::{InvariantMonitor, Violation};
use super::schedule::{FaultEvent, FaultSchedule};
use super::tenants::{
    generate_population, tenant_name, PopulationConfig,
};

/// What one simulation run produced. `violations` empty means every
/// invariant held on every tick; the counts are descriptive (they may
/// wiggle run-to-run with OS scheduling — only the violations are the
/// pass/fail signal).
#[derive(Debug, Clone)]
pub struct SimReport {
    pub seed: u64,
    /// The driven schedule, in its printable DSL form.
    pub schedule: String,
    pub ticks: u64,
    pub violations: Vec<Violation>,
    pub submitted: u64,
    pub served: u64,
    pub errored: u64,
    pub rejected: u64,
    /// Submits that failed with a typed `RouteError` (no routable
    /// replica — a schedule that killed every survivor). Legal, typed,
    /// and permit-releasing; counted so tests can require them.
    pub route_errors: u64,
    /// Submits that failed with anything *else* — always a bug signal
    /// (the route path must only fail typed).
    pub submit_errors: u64,
    /// Schedule events the cluster refused (e.g. retiring an
    /// already-dead slot) — legal outcomes, counted for visibility.
    pub event_errors: u64,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub failovers: u64,
    pub final_workers: usize,
    pub final_active: usize,
}

/// Everything a simulation run is parameterized by. All randomness
/// derives from `seed`.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub seed: u64,
    /// Population size (10^4 for the CI smoke tier, 10^5–10^6 soak).
    pub n_tenants: usize,
    pub initial_workers: usize,
    /// Placement policy name (see `policy_by_name`).
    pub policy: String,
    /// Zipf exponent shared by the population weights and the trace.
    pub zipf_s: f64,
    /// Total trace arrivals over the run.
    pub requests: usize,
    /// Virtual length of the driven window, milliseconds.
    pub sim_ms: u64,
    /// Virtual time advanced per driver tick.
    pub quantum: Duration,
    /// Mock per-request service time (virtual).
    pub step_delay: Duration,
    /// Valley arrival rate, requests per virtual second.
    pub rate: f64,
    pub pattern: ArrivalPattern,
    pub admission: Option<AdmissionPolicy>,
    pub autoscaler: Option<AutoscalerConfig>,
    /// Fault injection for the monitor's own regression test: never
    /// harvest any ticket, so admission permits are held past quiesce
    /// and the hung-ticket / permit-leak invariants must fire.
    pub leak_tickets: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            seed: 0,
            n_tenants: 500,
            initial_workers: 2,
            policy: "delta-aware".into(),
            zipf_s: 1.0,
            requests: 200,
            sim_ms: 250,
            quantum: Duration::from_millis(1),
            step_delay: Duration::from_millis(1),
            rate: 1_000.0,
            pattern: ArrivalPattern::Steady,
            admission: Some(AdmissionPolicy {
                per_tenant_cap: 16,
                total_cap: 64,
            }),
            autoscaler: None,
            leak_tickets: false,
        }
    }
}

impl SimConfig {
    /// The CI smoke tier: 10^4 tenants, square-wave load that forces
    /// autoscale oscillation, an admission gate tight enough to shed
    /// storms. Pairs with [`smoke_schedule`]. Completes in seconds.
    pub fn smoke(seed: u64) -> Self {
        Self {
            seed,
            n_tenants: 10_000,
            initial_workers: 3,
            requests: 2_500,
            sim_ms: 1_200,
            rate: 1_200.0,
            pattern: ArrivalPattern::Burst {
                half_period: 0.2,
                high_mult: 4.0,
            },
            autoscaler: Some(AutoscalerConfig {
                min_workers: 2,
                max_workers: 6,
                high_watermark: 6.0,
                low_watermark: 0.5,
                up_ticks: 2,
                down_ticks: 4,
                cooldown_ticks: 2,
                interval: Duration::from_millis(4),
            }),
            ..Self::default()
        }
    }
}

/// The canonical smoke schedule: every fault kind, including the
/// kill-mid-drain pair (retire slot 1, then kill it one virtual ms
/// later, while its drain is still joining) and a kill landing in the
/// post-churn re-placement window.
pub fn smoke_schedule() -> FaultSchedule {
    FaultSchedule::new()
        .at_ms(100, FaultEvent::SpawnWorker)
        .at_ms(200, FaultEvent::RetireWorker { slot: 1 })
        .at_ms(201, FaultEvent::KillWorker { slot: 1 })
        .at_ms(350, FaultEvent::KillWorker { slot: 0 })
        .at_ms(500, FaultEvent::DeltaChurn { reseed: 1 })
        .at_ms(520, FaultEvent::CompactSlots)
        .at_ms(600, FaultEvent::AdmissionStorm {
            tenant_rank: 0,
            burst: 256,
        })
        .at_ms(700, FaultEvent::DeltaChurn { reseed: 2 })
        .at_ms(750, FaultEvent::SpawnWorker)
        .at_ms(900, FaultEvent::RetireWorker { slot: 3 })
}

fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One real sub-millisecond nap per virtual tick, so worker /
/// autoscaler threads get CPU between advances. Pacing only — it never
/// orders events, which is why it is the one blessed wall-clock sleep
/// in the harness.
fn pace() {
    // lint: allow(raw-time, the driver's single real pacing nap —
    // virtual time cannot schedule OS threads)
    crate::sync::thread::sleep(Duration::from_micros(150));
}

fn pop_cfg(cfg: &SimConfig) -> PopulationConfig {
    PopulationConfig {
        n_tenants: cfg.n_tenants,
        zipf_s: cfg.zipf_s,
        min_bytes: 512,
        max_bytes: 4096,
    }
}

fn harvest(tickets: &mut Vec<ClusterTicket>,
           mon: &mut InvariantMonitor) {
    tickets.retain(|t| match t.try_recv() {
        None => true,
        Some(Ok(_)) => {
            mon.resolved_ok += 1;
            false
        }
        Some(Err(_)) => {
            mon.resolved_err += 1;
            false
        }
    });
}

/// Drive one simulation run to completion. Setup failures (bad policy
/// name, impossible initial packing) are `Err`; invariant violations
/// are *not* — they come back in the report so the caller can print
/// the seed and schedule.
pub fn run(cfg: &SimConfig, schedule: &FaultSchedule)
           -> Result<SimReport> {
    let guard = clock::install();
    let t0 = clock::virtual_now();

    // -- deterministic inputs ----------------------------------------
    let pop = generate_population(cfg.seed, &pop_cfg(cfg));
    let total: usize = pop.iter().map(|t| t.resident_bytes).sum();
    let max_item = pop.iter().map(|t| t.resident_bytes).max()
        .unwrap_or(1);
    // 3x headroom over an even split, and never tighter than a few of
    // the largest deltas: the initial FFD packing must succeed, and
    // any surviving subset of workers must be able to absorb a
    // re-placement (the budget invariant still binds per worker)
    let budget = (3 * total / cfg.initial_workers.max(1))
        .max(4 * max_item);
    let trace = workload::generate(&TraceConfig {
        n_tenants: cfg.n_tenants.min(20_000),
        n_requests: cfg.requests,
        rate: cfg.rate,
        zipf_s: cfg.zipf_s,
        min_tokens: 2,
        max_tokens: 6,
        seed: cfg.seed,
        pattern: cfg.pattern,
    });

    // -- real cluster over killable mock cores -----------------------
    // worker factory ids equal slot indices (both start at
    // `initial_workers` and increment in lockstep; slots are
    // append-only), so the kill registry can key by factory id
    let kills: Arc<Mutex<HashMap<usize, Arc<AtomicBool>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let kills_f = kills.clone();
    let step = cfg.step_delay;
    let make: WorkerFactoryFn = Box::new(move |id| {
        let kill = Arc::new(AtomicBool::new(false));
        locked(&kills_f).insert(id, kill.clone());
        let f: CoreFactory = Box::new(move || {
            Ok(Box::new(MockCore::new(id)
                        .with_kill_switch(kill.clone())
                        .with_step_delay(step))
               as Box<dyn WorkerCore>)
        });
        f
    });
    let ccfg = ClusterConfig {
        policy: policy_by_name(&cfg.policy)?,
        delta_budget_bytes: budget,
        admission: cfg.admission,
    };
    let cluster =
        Cluster::spawn_elastic(&ccfg, pop, cfg.initial_workers, make)
            .context("simharness: cluster spawn")?;
    let handle = cluster.handle();
    let scaler = cfg.autoscaler.clone()
        .map(|a| Autoscaler::spawn(handle.clone(), a));

    // -- driver loop -------------------------------------------------
    let cap = cfg.admission.map(|p| p.total_cap);
    let mut mon = InvariantMonitor::new(cfg.policy == "delta-aware");
    let mut tickets: Vec<ClusterTicket> = Vec::new();
    let mut leaked: Vec<ClusterTicket> = Vec::new();
    let mut helpers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut ev_cursor = 0usize;
    let mut tr_cursor = 0usize;
    let mut event_errors = 0u64;
    let quantum_ms = (cfg.quantum.as_millis().max(1)) as u64;
    let ticks = cfg.sim_ms.max(1) / quantum_ms;

    let mut errs = SubmitErrors::default();
    let submit_one = |tenant: usize,
                          mon: &mut InvariantMonitor,
                          tickets: &mut Vec<ClusterTicket>,
                          leaked: &mut Vec<ClusterTicket>,
                          errs: &mut SubmitErrors| {
        match handle.submit(req(&tenant_name(tenant))) {
            Ok(t) => {
                mon.submitted_ok += 1;
                if cfg.leak_tickets {
                    leaked.push(t);
                } else {
                    tickets.push(t);
                }
            }
            Err(e) if e.downcast_ref::<AdmissionError>()
                .is_some() => mon.rejected += 1,
            Err(e) if e.downcast_ref::<RouteError>()
                .is_some() => errs.route += 1,
            Err(_) => errs.other += 1,
        }
    };

    for tick in 0..ticks {
        let now = clock::virtual_now().saturating_sub(t0);
        let mut fired = false;

        while ev_cursor < schedule.events().len()
            && schedule.events()[ev_cursor].at <= now
        {
            let ev = schedule.events()[ev_cursor].event.clone();
            ev_cursor += 1;
            fired = true;
            match ev {
                FaultEvent::KillWorker { slot } => {
                    if let Some(k) = locked(&kills).get(&slot) {
                        k.store(true, Ordering::Relaxed);
                    }
                }
                FaultEvent::RetireWorker { slot } => {
                    // the drain join blocks until the worker empties
                    // its queue, which needs the driver to keep
                    // advancing — so it runs on a helper thread
                    let h = handle.clone();
                    helpers.push(std::thread::spawn(move || {
                        // kill-mid-drain makes this Err by design
                        let _ = h.retire_worker_floor(slot, 1);
                    }));
                }
                FaultEvent::SpawnWorker => {
                    let before = handle.n_workers();
                    match handle.spawn_worker() {
                        Ok(idx) if idx < before => {
                            mon.violation(now, "slot-stability",
                                format!("spawn returned recycled \
slot {idx} (table already had {before})"));
                        }
                        Ok(_) => {}
                        Err(_) => event_errors += 1,
                    }
                }
                FaultEvent::AdmissionStorm { tenant_rank, burst } => {
                    for _ in 0..burst {
                        submit_one(tenant_rank, &mut mon,
                                   &mut tickets, &mut leaked,
                                   &mut errs);
                    }
                }
                FaultEvent::DeltaChurn { reseed } => {
                    let churn_seed = cfg.seed
                        ^ reseed.wrapping_mul(0x9e37_79b9_7f4a_7c15);
                    let next = generate_population(
                        churn_seed, &pop_cfg(cfg));
                    if handle.update_tenants(next).is_err() {
                        event_errors += 1;
                    }
                }
                FaultEvent::CompactSlots => {
                    let before = handle.n_workers();
                    handle.compact_slots();
                    if handle.n_workers() < before {
                        mon.violation(now, "slot-stability",
                            format!("compaction shrank the slot \
table below {before}"));
                    }
                }
            }
        }

        while tr_cursor < trace.len()
            && duration_of(&trace[tr_cursor]) <= now
        {
            let tenant = trace[tr_cursor].tenant;
            tr_cursor += 1;
            submit_one(tenant, &mut mon, &mut tickets, &mut leaked,
                       &mut errs);
        }

        harvest(&mut tickets, &mut mon);
        mon.check_tick(&handle, now, cap);
        if fired || tick % 32 == 0 {
            mon.check_placement(&handle, now);
        }

        clock::advance(cfg.quantum);
        pace();
    }

    // -- quiesce: drain outstanding work in virtual time -------------
    let mut spare = 0u64;
    while spare < 4 * ticks.max(500) {
        harvest(&mut tickets, &mut mon);
        if tickets.is_empty()
            && helpers.iter().all(|h| h.is_finished())
        {
            break;
        }
        clock::advance(cfg.quantum);
        pace();
        spare += 1;
    }
    let now = clock::virtual_now().saturating_sub(t0);
    mon.check_placement(&handle, now);
    mon.check_quiesced(&handle, now,
                       tickets.len() + leaked.len());

    // -- report, then teardown in real time --------------------------
    let (scale_ups, scale_downs) = handle.scale_events();
    let failovers =
        metric_u64(&handle.metrics(),
                   "bitdelta_cluster_failovers_total");
    let report = SimReport {
        seed: cfg.seed,
        schedule: schedule.to_string(),
        ticks: ticks + spare,
        violations: mon.violations().to_vec(),
        submitted: mon.submitted_ok,
        served: mon.resolved_ok,
        errored: mon.resolved_err,
        rejected: mon.rejected,
        route_errors: errs.route,
        submit_errors: errs.other,
        event_errors,
        scale_ups,
        scale_downs,
        failovers,
        final_workers: handle.n_workers(),
        final_active: handle.active_workers(),
    };

    // uninstall the clock *before* joining anything: remaining sleeps
    // (worker steps, the autoscaler interval) become real and short,
    // so the joins below cannot deadlock on frozen virtual time
    drop(leaked);
    drop(guard);
    for h in helpers {
        let _ = h.join();
    }
    if let Some(s) = scaler {
        s.stop();
    }
    // killed workers make shutdown report their (expected) deaths;
    // the run's failure signal is the monitor, not this error
    let _ = cluster.shutdown();
    Ok(report)
}

fn duration_of(e: &TraceEvent) -> Duration {
    Duration::from_secs_f64(e.at.max(0.0))
}

/// Driver-side submit failure tally (see the report fields).
#[derive(Debug, Default)]
struct SubmitErrors {
    route: u64,
    other: u64,
}

/// First `name <value>` line of a Prometheus-style exposition.
fn metric_u64(text: &str, name: &str) -> u64 {
    text.lines()
        .filter_map(|l| l.strip_prefix(name))
        .find_map(|rest| rest.trim().parse::<u64>().ok())
        .unwrap_or(0)
}

impl SimReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    /// Human-readable failure block: the seed to replay, the schedule
    /// that was driven, every violation. This is what the soak CI job
    /// uploads as its artifact.
    pub fn render_failure(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "simulation seed {} — replay with \
SIM_SEED={}", self.seed, self.seed);
        let _ = writeln!(out, "schedule:");
        for line in self.schedule.lines() {
            let _ = writeln!(out, "  {line}");
        }
        let _ = writeln!(out, "violations ({}):",
                         self.violations.len());
        for v in &self.violations {
            let _ = writeln!(out, "  {v}");
        }
        let _ = writeln!(out,
            "counts: submitted={} served={} errored={} rejected={} \
route_errors={} submit_errors={} event_errors={} scale=+{}/-{} \
failovers={} workers={}/{} active",
            self.submitted, self.served, self.errored, self.rejected,
            self.route_errors, self.submit_errors, self.event_errors,
            self.scale_ups, self.scale_downs, self.failovers,
            self.final_active, self.final_workers);
        out
    }
}
