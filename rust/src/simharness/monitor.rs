//! Continuous invariant checking over a live cluster.
//!
//! The [`InvariantMonitor`] is the harness's oracle: the driver feeds
//! it its own request bookkeeping (it is the only submitter) and the
//! monitor cross-checks that against the cluster's observable state
//! every tick. All checks are *safety* properties — they must hold
//! under every OS interleaving, which is what makes them meaningful
//! even though only the schedule (not the thread scheduler) is
//! deterministic. The invariant vocabulary is stable, asserted by the
//! regression tests:
//!
//! * `no-double-routing` — the per-slot routed counters sum exactly to
//!   the requests the driver successfully submitted; a request routed
//!   to two workers (or zero) breaks the equality.
//! * `admission-in-flight` — the gate's live count never exceeds its
//!   global budget, and returns to zero once every ticket resolved.
//! * `slot-stability` — the slot table only appends: worker indices
//!   survive retires, deaths and compaction (placements and metrics
//!   labels key on them).
//! * `tenant-routable` — every placed tenant keeps at least one
//!   replica on a routable worker (checked against a single-lock
//!   [`RoutingSnapshot`], so placement and liveness are consistent).
//! * `delta-budget` — no routable worker's placed delta bytes exceed
//!   its budget, unless the placement honestly declared itself
//!   degraded (the everything-everywhere fallback).
//! * `hung-tickets` / `bookkeeping` — at quiesce, no ticket is still
//!   unresolved and submitted == served + errored.

use std::fmt;
use std::time::Duration;

use crate::cluster::frontend::ClusterHandle;

/// One invariant violation, timestamped in virtual time.
#[derive(Debug, Clone)]
pub struct Violation {
    pub at: Duration,
    /// Stable invariant name (see the module docs).
    pub invariant: &'static str,
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[t+{}ms] {}: {}",
               self.at.as_millis(), self.invariant, self.detail)
    }
}

/// Driver-side bookkeeping plus the invariant checks.
#[derive(Debug, Default)]
pub struct InvariantMonitor {
    /// Requests the driver submitted and got a ticket for.
    pub submitted_ok: u64,
    /// Typed admission rejections (shed load, not failures).
    pub rejected: u64,
    /// Tickets resolved with a response.
    pub resolved_ok: u64,
    /// Tickets resolved with an error (failover casualties).
    pub resolved_err: u64,
    /// Enforce the `delta-budget` invariant (off for policies that
    /// place without budgets, e.g. least-loaded).
    pub check_budget: bool,
    last_n_workers: usize,
    violations: Vec<Violation>,
}

impl InvariantMonitor {
    pub fn new(check_budget: bool) -> Self {
        Self { check_budget, ..Self::default() }
    }

    pub fn violations(&self) -> &[Violation] {
        &self.violations
    }

    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    /// Outstanding tickets by the driver's own arithmetic.
    pub fn outstanding(&self) -> u64 {
        self.submitted_ok
            .saturating_sub(self.resolved_ok + self.resolved_err)
    }

    /// Record a violation found by the driver itself (e.g. a spawn
    /// returning a recycled slot index).
    pub fn violation(&mut self, at: Duration, invariant: &'static str,
                     detail: String) {
        self.violations.push(Violation { at, invariant, detail });
    }

    /// Cheap per-tick checks: routed-count conservation, admission
    /// budget, slot-table monotonicity.
    pub fn check_tick(&mut self, handle: &ClusterHandle, at: Duration,
                      admission_cap: Option<usize>) {
        let routed: u64 = handle.routed_counts().iter().sum();
        if routed != self.submitted_ok {
            self.violation(at, "no-double-routing", format!(
                "slots routed {} requests, driver submitted {}",
                routed, self.submitted_ok));
        }
        if let (Some(cap), Some(in_flight)) =
            (admission_cap, handle.admission_in_flight())
        {
            if in_flight > cap {
                self.violation(at, "admission-in-flight", format!(
                    "gate holds {in_flight} > budget {cap}"));
            }
        }
        let n = handle.n_workers();
        if n < self.last_n_workers {
            self.violation(at, "slot-stability", format!(
                "slot table shrank {} -> {n}", self.last_n_workers));
        }
        self.last_n_workers = n;
    }

    /// Heavier placement checks (clones the placement): every tenant
    /// routable, budgets respected. Run on fault ticks and on a
    /// coarse cadence — at 10^6 tenants this is the expensive check.
    pub fn check_placement(&mut self, handle: &ClusterHandle,
                           at: Duration) {
        let snap = handle.routing_snapshot();
        if snap.routable.is_empty() {
            // nothing to route to at all — a schedule that kills every
            // worker; the routing invariants are vacuous, submits
            // surface typed RouteErrors instead
            return;
        }
        let mut unroutable = 0usize;
        let mut example = String::new();
        for t in snap.placement.tenants() {
            let ws = snap.placement.workers_of(t);
            if !ws.iter().any(|w| snap.routable.contains(w)) {
                unroutable += 1;
                if example.is_empty() {
                    example = format!("{t} -> {ws:?}");
                }
            }
        }
        if unroutable > 0 {
            self.violation(at, "tenant-routable", format!(
                "{unroutable} tenant(s) without a routable replica \
(routable {:?}; first: {example})", snap.routable));
        }
        if self.check_budget && !snap.degraded {
            let budget = handle.delta_budget_bytes();
            for &w in &snap.routable {
                let placed = snap.placement.placed_bytes(w);
                if placed > budget {
                    self.violation(at, "delta-budget", format!(
                        "worker {w} holds {placed} B > budget \
{budget} B (placement not degraded)"));
                }
            }
        }
    }

    /// End-of-run checks, after the drain window: nothing hung,
    /// admission fully released, arithmetic closed.
    pub fn check_quiesced(&mut self, handle: &ClusterHandle,
                          at: Duration, tickets_open: usize) {
        if tickets_open > 0 || self.outstanding() > 0 {
            self.violation(at, "hung-tickets", format!(
                "{tickets_open} ticket(s) still unresolved after \
quiesce ({} by driver arithmetic)", self.outstanding()));
        }
        if let Some(in_flight) = handle.admission_in_flight() {
            if in_flight > 0 {
                self.violation(at, "admission-in-flight", format!(
                    "gate still holds {in_flight} permit(s) after \
quiesce — a permit leaked"));
            }
        }
        if self.submitted_ok
            != self.resolved_ok + self.resolved_err + tickets_open as u64
        {
            self.violation(at, "bookkeeping", format!(
                "submitted {} != served {} + errored {} + open {}",
                self.submitted_ok, self.resolved_ok,
                self.resolved_err, tickets_open));
        }
    }
}
