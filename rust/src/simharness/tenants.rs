//! Seeded tenant-population generator.
//!
//! Produces Zipf-weighted populations from 10^4 up to 10^6 tenants,
//! bit-deterministic per `(seed, config)`: the PRNG is the crate's
//! stable [`crate::util::prop::Rng`] and the skew comes from the
//! workload [`Zipf`] pmf, both of which are fixed-algorithm (no
//! `DefaultHasher`, no platform entropy) — so a failing simulation
//! seed regenerates the *identical* population on any host.

use crate::cluster::placement::TenantProfile;
use crate::coordinator::workload::Zipf;
use crate::util::prop::Rng;

/// Shape of a generated population.
#[derive(Debug, Clone)]
pub struct PopulationConfig {
    pub n_tenants: usize,
    /// Zipf exponent of the traffic skew; tenant `weight`s follow the
    /// pmf, so they sum to ~1.0 like real profiles.
    pub zipf_s: f64,
    /// Base delta size drawn uniformly from `[min_bytes, max_bytes)`,
    /// then scaled by the tenant's fidelity tier (a `levels`-tier
    /// bitdelta tenant carries `levels` mask planes).
    pub min_bytes: usize,
    pub max_bytes: usize,
}

impl Default for PopulationConfig {
    fn default() -> Self {
        Self { n_tenants: 10_000, zipf_s: 1.0,
               min_bytes: 512, max_bytes: 4096 }
    }
}

/// Tenant name of rank `i`, zero-padded so lexicographic order equals
/// rank order (profiles are name-sorted before placement; aligning the
/// two keeps failure output readable: rank 0 is the hottest tenant and
/// also the first profile).
pub fn tenant_name(rank: usize) -> String {
    format!("t{rank:06}")
}

/// Generate a population deterministically from `seed`. Rank 0 is the
/// hottest tenant; sizes, tiers and codecs vary per tenant so the
/// delta-aware bin-packer sees a realistic mixed-format fleet.
pub fn generate_population(seed: u64, cfg: &PopulationConfig)
                           -> Vec<TenantProfile> {
    assert!(cfg.n_tenants > 0, "population must be non-empty");
    assert!(cfg.min_bytes > 0 && cfg.max_bytes > cfg.min_bytes,
            "population byte range is empty");
    let mut rng = Rng::new(seed);
    let zipf = Zipf::new(cfg.n_tenants, cfg.zipf_s);
    // registry codec names, weighted toward the paper's 1-bit format;
    // mock cores never decode, so these only exercise the per-codec
    // packing bookkeeping
    let codecs = ["bitdelta", "bitdelta", "bitdelta", "lora", "svd"];
    (0..cfg.n_tenants).map(|rank| {
        let levels = 1 + rng.usize_in(0, 4);
        let base = rng.usize_in(cfg.min_bytes, cfg.max_bytes);
        TenantProfile {
            name: tenant_name(rank),
            codec: (*rng.choose(&codecs)).to_string(),
            resident_bytes: base * levels,
            weight: zipf.pmf(rank),
            levels,
        }
    }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn population_is_bit_deterministic_per_seed() {
        let cfg = PopulationConfig {
            n_tenants: 500, ..PopulationConfig::default()
        };
        let a = generate_population(7, &cfg);
        let b = generate_population(7, &cfg);
        assert_eq!(a.len(), 500);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.codec, y.codec);
            assert_eq!(x.resident_bytes, y.resident_bytes);
            assert_eq!(x.levels, y.levels);
            assert!((x.weight - y.weight).abs() == 0.0);
        }
        // a different seed really changes the draw
        let c = generate_population(8, &cfg);
        assert!(a.iter().zip(&c)
                .any(|(x, y)| x.resident_bytes != y.resident_bytes));
    }

    #[test]
    fn weights_follow_rank_and_sum_to_one() {
        let cfg = PopulationConfig {
            n_tenants: 1000, ..PopulationConfig::default()
        };
        let pop = generate_population(1, &cfg);
        let sum: f64 = pop.iter().map(|t| t.weight).sum();
        assert!((sum - 1.0).abs() < 1e-9, "weights sum to {sum}");
        assert!(pop[0].weight > pop[999].weight,
                "rank 0 should be hottest");
        // names sort in rank order
        let mut names: Vec<_> =
            pop.iter().map(|t| t.name.clone()).collect();
        names.sort();
        assert_eq!(names[0], pop[0].name);
        assert_eq!(names[999], pop[999].name);
    }
}
