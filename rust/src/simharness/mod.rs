//! Deterministic simulation harness for the cluster layer.
//!
//! The cluster's hardest bugs — hung tickets after a worker dies
//! mid-drain, leaked admission permits, placements pointing at dead
//! workers, autoscaler oscillation races — live in timing windows that
//! wall-clock tests hit once in a thousand runs. This harness makes
//! those windows schedulable: it runs the **real** cluster stack
//! (routing, placement, failover, graceful drain, the autoscaler
//! control loop, the admission gate) over mock worker cores on the
//! [`crate::sync::clock`] virtual clock, drives it with a seeded Zipf
//! tenant population (10^4–10^6 tenants) and a declarative
//! [`schedule::FaultSchedule`], and checks invariants *continuously*
//! with the [`monitor::InvariantMonitor`].
//!
//! Layout:
//!
//! * [`tenants`]  — seeded population generator (names, sizes, tiers,
//!   codecs, Zipf weights), bit-deterministic per seed;
//! * [`schedule`] — the fault DSL: kills (incl. mid-drain), retires,
//!   spawns, admission storms, delta hot-churn, compaction — printable
//!   one event per line for CI artifacts;
//! * [`monitor`]  — the invariant oracle (no double-routing, admission
//!   within budget, tenants always routable, per-worker delta bytes
//!   within budget, append-only slot table, nothing hung at quiesce);
//! * [`harness`]  — the driver: one tick = fire faults, submit
//!   arrivals, harvest tickets, check invariants, advance the clock.
//!
//! A failing run's [`SimReport`] renders the seed and the schedule —
//! `SimConfig::smoke(seed)` + the same schedule replays the identical
//! scripted inputs. The smoke tier (10^4 tenants, every fault kind,
//! seconds of wall time) runs in default `cargo test` via
//! `tests/sim_cluster.rs`; the nightly soak tier scales the population
//! to 10^5–10^6 with rotating seeds.

pub mod harness;
pub mod monitor;
pub mod schedule;
pub mod tenants;

pub use harness::{run, smoke_schedule, SimConfig, SimReport};
pub use monitor::{InvariantMonitor, Violation};
pub use schedule::{FaultEvent, FaultSchedule, ScheduledFault};
pub use tenants::{generate_population, tenant_name, PopulationConfig};
