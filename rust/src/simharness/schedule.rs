//! The declarative fault-schedule DSL.
//!
//! A [`FaultSchedule`] is a time-sorted list of [`FaultEvent`]s the
//! harness driver fires against the cluster at virtual-time offsets:
//! worker kills (including mid-drain, by pairing a kill right after a
//! retire of the same slot), graceful retires, explicit spawns,
//! admission storms, delta hot-churn re-placements and slot-table
//! compactions. Schedules print as one event per line —
//!
//! ```text
//! t+000200ms retire-worker slot=1
//! t+000201ms kill-worker slot=1
//! t+000600ms admission-storm tenant=0 burst=256
//! ```
//!
//! — which is exactly what a failing CI run uploads next to its seed,
//! so a failure is replayable from the artifact alone.

use std::fmt;
use std::time::Duration;

use crate::util::prop::Rng;

/// One fault the driver can inject.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultEvent {
    /// Flip the slot's kill switch: its next `step` fails, modelling a
    /// worker death (mid-flight, or mid-drain when paired with a
    /// preceding [`FaultEvent::RetireWorker`] of the same slot).
    KillWorker { slot: usize },
    /// Graceful scale-down of one slot (runs on a helper thread — the
    /// drain join must not block the virtual-clock driver).
    RetireWorker { slot: usize },
    /// Explicit scale-up through the elastic factory.
    SpawnWorker,
    /// Burst-submit `burst` requests for one tenant rank in a single
    /// tick, driving the admission gate into typed rejections.
    AdmissionStorm { tenant_rank: usize, burst: usize },
    /// Delta hot-churn: regenerate the tenant population with a
    /// perturbed seed (new sizes / tiers / weights, same names) and
    /// re-place it on the live cluster.
    DeltaChurn { reseed: u64 },
    /// Sweep joined terminal slots; indices must not shift.
    CompactSlots,
}

impl fmt::Display for FaultEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultEvent::KillWorker { slot } => {
                write!(f, "kill-worker slot={slot}")
            }
            FaultEvent::RetireWorker { slot } => {
                write!(f, "retire-worker slot={slot}")
            }
            FaultEvent::SpawnWorker => write!(f, "spawn-worker"),
            FaultEvent::AdmissionStorm { tenant_rank, burst } => {
                write!(f, "admission-storm tenant={tenant_rank} \
burst={burst}")
            }
            FaultEvent::DeltaChurn { reseed } => {
                write!(f, "delta-churn reseed={reseed}")
            }
            FaultEvent::CompactSlots => write!(f, "compact-slots"),
        }
    }
}

/// A fault at a virtual-time offset from simulation start.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScheduledFault {
    pub at: Duration,
    pub event: FaultEvent,
}

/// A time-sorted fault script. Built with [`FaultSchedule::at_ms`]
/// (insertion order is preserved among events at the same instant, so
/// "retire then kill" pairs stay ordered).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultSchedule {
    events: Vec<ScheduledFault>,
}

impl FaultSchedule {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append an event at `ms` virtual milliseconds, keeping the
    /// script sorted (stable, so same-instant events keep build order).
    pub fn at_ms(mut self, ms: u64, event: FaultEvent) -> Self {
        self.events.push(ScheduledFault {
            at: Duration::from_millis(ms),
            event,
        });
        self.events.sort_by_key(|e| e.at);
        self
    }

    pub fn events(&self) -> &[ScheduledFault] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// A seed-derived schedule covering every fault kind — the soak
    /// generator. Events land in `[horizon/10, horizon)` virtual ms;
    /// kills / retires target slots `< slot_hint` (initial workers
    /// plus early spawns). Retires get a trailing same-slot kill half
    /// the time, exercising the kill-mid-drain race. Deterministic per
    /// seed.
    pub fn random(seed: u64, horizon_ms: u64, slot_hint: usize)
                  -> Self {
        let mut rng = Rng::new(seed ^ 0x5eed_5c4e_d01e_5eed);
        let lo = (horizon_ms / 10).max(1) as usize;
        let hi = horizon_ms.max(2) as usize;
        let mut s = Self::new();
        let n = 6 + rng.usize_in(0, 6);
        for _ in 0..n {
            let at = rng.usize_in(lo, hi) as u64;
            let slot = rng.usize_in(0, slot_hint.max(1));
            match rng.usize_in(0, 6) {
                0 => {
                    s = s.at_ms(at, FaultEvent::KillWorker { slot });
                }
                1 => {
                    s = s.at_ms(at,
                                FaultEvent::RetireWorker { slot });
                    if rng.bool() {
                        // kill mid-drain
                        s = s.at_ms(at + 1,
                                    FaultEvent::KillWorker { slot });
                    }
                }
                2 => s = s.at_ms(at, FaultEvent::SpawnWorker),
                3 => {
                    let burst = 64 + rng.usize_in(0, 512);
                    s = s.at_ms(at, FaultEvent::AdmissionStorm {
                        tenant_rank: rng.usize_in(0, 8),
                        burst,
                    });
                }
                4 => {
                    s = s.at_ms(at, FaultEvent::DeltaChurn {
                        reseed: rng.next_u64() | 1,
                    });
                }
                _ => s = s.at_ms(at, FaultEvent::CompactSlots),
            }
        }
        s
    }
}

impl fmt::Display for FaultSchedule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for e in &self.events {
            writeln!(f, "t+{:06}ms {}", e.at.as_millis(), e.event)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_sorts_and_keeps_same_instant_order() {
        let s = FaultSchedule::new()
            .at_ms(50, FaultEvent::SpawnWorker)
            .at_ms(10, FaultEvent::RetireWorker { slot: 1 })
            .at_ms(10, FaultEvent::KillWorker { slot: 1 });
        let ev = s.events();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].event, FaultEvent::RetireWorker { slot: 1 });
        assert_eq!(ev[1].event, FaultEvent::KillWorker { slot: 1 });
        assert_eq!(ev[2].at, Duration::from_millis(50));
    }

    #[test]
    fn display_prints_one_replayable_line_per_event() {
        let s = FaultSchedule::new()
            .at_ms(201, FaultEvent::KillWorker { slot: 1 })
            .at_ms(600, FaultEvent::AdmissionStorm {
                tenant_rank: 0, burst: 256,
            });
        let text = s.to_string();
        assert_eq!(text, "t+000201ms kill-worker slot=1\n\
                          t+000600ms admission-storm tenant=0 \
burst=256\n");
    }

    #[test]
    fn random_schedule_is_deterministic_and_in_horizon() {
        let a = FaultSchedule::random(42, 1000, 4);
        let b = FaultSchedule::random(42, 1000, 4);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        for e in a.events() {
            // +1ms slack for the paired mid-drain kill
            assert!(e.at <= Duration::from_millis(1001), "{e:?}");
        }
        assert_ne!(a, FaultSchedule::random(43, 1000, 4));
    }
}
