//! Criterion-substitute timing harness for `rust/benches/*`.
//!
//! Warmup, fixed sample count, and a one-line report with
//! mean / p50 / min — enough to read kernel and end-to-end latency
//! shapes for Figures 4/6.
//!
//! [`write_snapshot`] is the shared perf-trajectory sink: every bench
//! writes its JSON rows to `BENCH_<name>.json` in one schema (bench
//! id, git rev, kernel thread/dispatch config, rows with
//! throughput + p50/p99), and `scripts/compare_bench.py` diffs that
//! file against the committed baseline under `perf/` — the CI
//! `perf-smoke` job fails on regression beyond tolerance.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::gemm::dispatch;
use crate::util::json::Json;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl Measurement {
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len().max(1) as u32
    }

    pub fn p50(&self) -> Duration {
        let mut v = self.samples.clone();
        v.sort();
        v[v.len() / 2]
    }

    pub fn min(&self) -> Duration {
        self.samples.iter().min().copied().unwrap_or_default()
    }

    /// Nearest-rank `q`-quantile of the samples (`q` in `[0, 1]`;
    /// `quantile(0.99)` is the p99 the perf snapshots record).
    pub fn quantile(&self, q: f64) -> Duration {
        let mut v = self.samples.clone();
        v.sort();
        let last = v.len().saturating_sub(1);
        let idx = (last as f64 * q.clamp(0.0, 1.0)).round() as usize;
        v[idx.min(last)]
    }

    pub fn report(&self) -> String {
        format!("{:<44} mean {:>12?}  p50 {:>12?}  min {:>12?}  (n={})",
                self.name, self.mean(), self.p50(), self.min(),
                self.samples.len())
    }
}

/// Benchmark runner: `iters` timed samples after `warmup` untimed runs.
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
    pub results: Vec<Measurement>,
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Self { warmup, iters, results: Vec::new() }
    }

    /// Time `f` (which should do one unit of work per call).
    pub fn run<F: FnMut()>(&mut self, name: impl Into<String>, mut f: F)
                           -> &Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        let m = Measurement { name: name.into(), samples };
        println!("{}", m.report());
        self.results.push(m);
        // lint: allow(unwrap, last() right after push())
        self.results.last().unwrap()
    }

    /// Emit a CSV block (series for plots).
    pub fn csv(&self, header: &str) -> String {
        let mut out = format!("{header}\n");
        for m in &self.results {
            out.push_str(&format!("{},{:.3}\n", m.name,
                                  m.mean().as_secs_f64() * 1e6));
        }
        out
    }
}

/// Prevent the optimiser from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Short git revision of the working tree, `"unknown"` outside a
/// checkout (perf snapshots must say what they measured).
pub fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// Write the shared-schema perf snapshot `BENCH_<name>.json` into the
/// current directory and return its path. The envelope carries
/// everything needed to attribute the numbers — bench id, schema
/// version, git rev, smoke flag, kernel worker-pool width and active
/// dispatch tier — and `rows` are the bench's own JSON records (the
/// same objects it prints after `--- JSON ---`).
pub fn write_snapshot(name: &str, smoke: bool, rows: Vec<Json>)
                      -> std::io::Result<PathBuf> {
    let mut o = BTreeMap::new();
    o.insert("bench".to_string(), Json::Str(name.to_string()));
    o.insert("schema".to_string(), Json::Num(1.0));
    o.insert("git_rev".to_string(), Json::Str(git_rev()));
    o.insert("smoke".to_string(), Json::Bool(smoke));
    o.insert("threads".to_string(),
             Json::Num(dispatch::pool_threads() as f64));
    o.insert("dispatch".to_string(),
             Json::Str(dispatch::active_tier().name().to_string()));
    o.insert("rows".to_string(), Json::Arr(rows));
    let path = PathBuf::from(format!("BENCH_{name}.json"));
    std::fs::write(&path, format!("{}\n", Json::Obj(o)))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new(1, 5);
        let mut acc = 0u64;
        b.run("spin", || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].mean() > Duration::ZERO);
        assert!(acc > 0);
    }

    #[test]
    fn quantile_brackets_the_samples() {
        let m = Measurement {
            name: "q".into(),
            samples: (1..=100).map(Duration::from_micros).collect(),
        };
        assert_eq!(m.quantile(0.0), Duration::from_micros(1));
        assert_eq!(m.quantile(1.0), Duration::from_micros(100));
        assert_eq!(m.quantile(0.5), m.p50());
        assert!(m.quantile(0.99) >= m.quantile(0.5));
    }

    #[test]
    fn csv_shape() {
        let mut b = Bench::new(0, 2);
        b.run("a", || {});
        let csv = b.csv("name,us");
        assert!(csv.starts_with("name,us\n"));
        assert!(csv.contains("a,"));
    }
}
