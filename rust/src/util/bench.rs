//! Criterion-substitute timing harness for `rust/benches/*`.
//!
//! Warmup, fixed sample count, and a one-line report with
//! mean / p50 / min — enough to read kernel and end-to-end latency
//! shapes for Figures 4/6.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl Measurement {
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len().max(1) as u32
    }

    pub fn p50(&self) -> Duration {
        let mut v = self.samples.clone();
        v.sort();
        v[v.len() / 2]
    }

    pub fn min(&self) -> Duration {
        *self.samples.iter().min().unwrap()
    }

    pub fn report(&self) -> String {
        format!("{:<44} mean {:>12?}  p50 {:>12?}  min {:>12?}  (n={})",
                self.name, self.mean(), self.p50(), self.min(),
                self.samples.len())
    }
}

/// Benchmark runner: `iters` timed samples after `warmup` untimed runs.
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
    pub results: Vec<Measurement>,
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Self { warmup, iters, results: Vec::new() }
    }

    /// Time `f` (which should do one unit of work per call).
    pub fn run<F: FnMut()>(&mut self, name: impl Into<String>, mut f: F)
                           -> &Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        let m = Measurement { name: name.into(), samples };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Emit a CSV block (series for plots).
    pub fn csv(&self, header: &str) -> String {
        let mut out = format!("{header}\n");
        for m in &self.results {
            out.push_str(&format!("{},{:.3}\n", m.name,
                                  m.mean().as_secs_f64() * 1e6));
        }
        out
    }
}

/// Prevent the optimiser from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new(1, 5);
        let mut acc = 0u64;
        b.run("spin", || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].mean() > Duration::ZERO);
        assert!(acc > 0);
    }

    #[test]
    fn csv_shape() {
        let mut b = Bench::new(0, 2);
        b.run("a", || {});
        let csv = b.csv("name,us");
        assert!(csv.starts_with("name,us\n"));
        assert!(csv.contains("a,"));
    }
}
