//! In-tree substitutes for crates unavailable on the offline build image
//! (`serde_json`, `clap`, `criterion`, `proptest`):
//!
//! * [`json`]  — a small, strict JSON parser + typed accessors (manifest
//!   and eval-set loading).
//! * [`cli`]   — flag/positional argument parsing for the `repro` binary.
//! * [`bench`] — a criterion-style timing harness (warmup, N samples,
//!   mean/p50/min) used by every `rust/benches/*` target.
//! * [`prop`]  — seeded random-case property-test driver (the proptest
//!   substitute used across the unit suites).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
