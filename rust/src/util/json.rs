//! Minimal strict JSON parser (RFC 8259 subset sufficient for our
//! artifacts: no surrogate-pair escapes).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => write!(f, "{:?}", s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 { write!(f, ",")?; }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 { write!(f, ",")?; }
                    write!(f, "{:?}:{v}", k)?;
                }
                write!(f, "}}")
            }
        }
    }
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => bail!("expected array, got {other}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => bail!("expected object, got {other}"),
        }
    }

    /// `obj.str("k")` — required string field.
    pub fn str_field(&self, key: &str) -> Result<String> {
        Ok(self.req(key)?.as_str()?.to_string())
    }

    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.req(key)?.as_usize()
    }

    pub fn f64_field(&self, key: &str) -> Result<f64> {
        self.req(key)?.as_f64()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}, found {:?}",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at offset {}",
                       c as char, self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected , or }} at offset {}, got {:?}",
                           self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected , or ] at offset {}, got {:?}",
                           self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(code)
                                .ok_or_else(|| anyhow!(
                                    "bad \\u escape {hex}"))?);
                        }
                        other => bail!("bad escape \\{}", other as char),
                    }
                }
                _ => {
                    // collect the full utf-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated utf-8");
                    }
                    out.push_str(std::str::from_utf8(
                        &self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_shape() {
        let j = Json::parse(r#"{"version":1,"models":{"a":{"file":"x"}},
            "list":[1,2.5,-3e2],"flag":true,"none":null}"#).unwrap();
        assert_eq!(j.usize_field("version").unwrap(), 1);
        assert_eq!(j.req("models").unwrap().req("a").unwrap()
                   .str_field("file").unwrap(), "x");
        let arr = j.req("list").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].as_f64().unwrap(), -300.0);
        assert!(j.req("flag").unwrap().as_bool().unwrap());
        assert_eq!(*j.req("none").unwrap(), Json::Null);
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"c\" A");
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo — α\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo — α");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn nested_depth() {
        let j = Json::parse("[[[[[1]]]]]").unwrap();
        let mut v = &j;
        for _ in 0..5 {
            v = &v.as_arr().unwrap()[0];
        }
        assert_eq!(v.as_f64().unwrap(), 1.0);
    }
}
