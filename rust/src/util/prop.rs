//! Seeded random-case property testing (proptest substitute).
//!
//! `run_cases(n, |rng| { ... })` drives a closure over `n` independent
//! deterministic RNG streams; assertion failures report the case seed so
//! a failure reproduces with `case(seed)`.

/// Deterministic RNG (splitmix64 stream).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[lo, hi)`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + (self.next_u64() as usize) % (hi - lo)
    }

    /// Uniform f32 in `[-1, 1)`.
    pub fn f32_pm1(&mut self) -> f32 {
        (self.next_u64() >> 41) as f32 / (1u64 << 23) as f32 * 2.0 - 1.0
    }

    pub fn f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32_pm1()).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len())]
    }
}

/// Run `n` random cases; panics include the failing seed.
pub fn run_cases<F: Fn(&mut Rng)>(n: usize, f: F) {
    for seed in 0..n as u64 {
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            let msg = e.downcast_ref::<String>().cloned()
                .or_else(|| e.downcast_ref::<&str>()
                         .map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case seed {seed}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_respected() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let v = r.usize_in(3, 9);
            assert!((3..9).contains(&v));
            let f = r.f32_pm1();
            assert!((-1.0..1.0).contains(&f));
        }
    }

    #[test]
    #[should_panic(expected = "case seed")]
    fn failure_reports_seed() {
        run_cases(5, |rng| {
            assert!(rng.usize_in(0, 10) < 100);
            if rng.usize_in(0, 3) == 1 {
                panic!("boom");
            }
        });
    }
}
