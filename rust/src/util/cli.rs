//! Tiny `--flag value` argument parser (clap substitute).

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Parsed command line: one subcommand + `--key value` flags +
/// boolean `--key` switches.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn parse_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // value flag if next token exists and isn't a flag
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        // lint: allow(unwrap, peek() just returned Some)
                        out.flags.insert(name.to_string(),
                                         it.next().unwrap());
                    }
                    _ => out.switches.push(name.to_string()),
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                bail!("unexpected positional argument {a:?}");
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("serve --mode bitdelta --batch 4 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("mode"), Some("bitdelta"));
        assert_eq!(a.get_usize("batch", 1).unwrap(), 4);
        assert!(a.has("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("table1");
        assert_eq!(a.get_or("artifacts", "artifacts"), "artifacts");
        assert_eq!(a.get_usize("batch", 8).unwrap(), 8);
    }

    #[test]
    fn double_positional_rejected() {
        assert!(Args::parse(["a".to_string(), "b".to_string()]).is_err());
    }
}
