//! `repro` — the BitDelta command-line: offline compression tools, the
//! serving engine, and the drivers that regenerate every paper exhibit.
//!
//! ```text
//! repro compress   --base <bdw> --fine <bdw> --out <bdd> [--levels k]
//! repro inspect    --delta <bdd> [--model sim-s]
//! repro serve      --mode bitdelta --batch 4 --requests 16
//! repro table1|table2|table5|table6|table7|fig2|fig3|fig5
//! repro case-study
//! repro metrics-demo
//! ```
//!
//! Everything reads `artifacts/` (`make artifacts` builds it once;
//! python never runs at serve time). Global flag: `--artifacts <dir>`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use bitdelta::config::{Manifest, ModelConfig};
use bitdelta::delta::bitdelta::compress;
use bitdelta::delta::codec::CodecRegistry;
use bitdelta::delta::iterative::compress_iterative;
use bitdelta::eval::tables::{self, TableCtx};
use bitdelta::model::sampling::SamplingParams;
use bitdelta::serving::engine::{Engine, EngineConfig};
use bitdelta::serving::request::Request;
use bitdelta::sim::memory::{self, ModelSpec, ServingMode};
use bitdelta::store::bdw;
use bitdelta::store::delta_file::{load_model, DeltaFile};
use bitdelta::util::cli::Args;

const USAGE: &str = "\
repro — BitDelta reproduction CLI

USAGE: repro [--artifacts DIR] <command> [flags]

COMMANDS:
  compress     --base F --fine F --out F [--model sim-s] [--levels K]
               (K >= 1 successive 1-bit masks; K > 1 = Fig. 3 tiers)
  inspect      --delta F [--model sim-s]
  serve        [--codec bitdelta|lora|svd|dense] [--batch N]
               [--requests N] [--model sim-s]
               [--tenant-codecs t1=lora,t2=bitdelta]  (mixed batches
               run natively, one sub-batch per codec)
               [--tenant-levels t1=2,t2=4]  (per-tenant fidelity tiers:
               serve the first K mask levels of a multi-level delta;
               tiers mix freely in one batch via zero-scale padding)
               [--threads N]  (CPU kernel worker-pool width; 0 = one
               per core; default = BITDELTA_THREADS or 1)
               [--kv-block-size N] [--kv-blocks N]  (paged KV pool
               geometry; blocks 0 = auto-size) [--kv-slab]  (dense
               per-sequence slabs, the pre-paging A/B fallback)
               [--kv-roundtrip]  (download + re-upload the full KV
               every step — the pre-device-resident A/B fallback)
  serve-cluster multi-worker serving with tenant placement
               [--workers N] [--policy affinity|least-loaded|delta-aware]
               [--codec C] [--batch N] [--requests N] [--budget-mb MB]
               [--model sim-s] [--tenant-levels t1=2,...]
               [--admission-budget N]  (global in-flight cap at the
               cluster front door; 0 disables; default 256)
               [--threads N]  (kernel worker-pool width per engine)
               [--kv-block-size N] [--kv-blocks N] [--kv-slab]
               [--kv-roundtrip]
               (tiered tenants pay level-scaled delta bytes in placement)
  codecs       list the registered delta codecs
  table1       BitDelta vs SVD quality (paper Table 1)
  table2       all tenants x sizes (paper Tables 2/3/10)
  table5       compression factors (paper Table 5)
  table6       quantized bases (paper Tables 6/8)
  table7       LoRA fine-tune (paper Table 7)
  fig2         delta CEV series, CSV (paper Figure 2)
  fig3         fidelity-of-delta ablation: eval quality + reconstruction
               error vs k (paper Figure 3 / Table 9; alias: table-fig3)
  fig5         memory vs batch, CSV (paper Figure 5)
  case-study   initial vs distilled generation (paper Table 4)
  metrics-demo engine metrics after a burst
  loadtest     Poisson/Zipf trace through the engine or a cluster
               [--requests N] [--rate R] [--zipf S] [--batch N]
               [--workers N] [--policy P] [--clients N] [--tenants N]
               [--budget-mb MB] [--tenant-levels t1=2,...]
               [--trace steady|burst] [--burst-period S] [--burst-mult M]
               (burst = square-wave Poisson: rate alternates R and R*M
               every S seconds — the autoscaler's natural adversary)
               [--autoscale MIN..MAX] (elastic worker count: scale up
               under sustained queue pressure, graceful-drain down when
               idle) [--admission-budget N] (cluster front-door
               in-flight cap; 0 disables; default 256)
               [--threads N] (kernel worker-pool width; 0 = one per core)
               [--kv-block-size N] [--kv-blocks N] [--kv-slab]
               [--kv-roundtrip] (per-step full-KV transfer A/B mode)
               (workers > 1 or --autoscale runs the cluster)
  extras-quant INT8-compress a delta's embeddings/head (paper's
               future-work extension) [--tenant sim-s-chat]
";

fn main() -> Result<()> {
    let args = Args::parse_env()?;
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let cmd = match &args.subcommand {
        Some(c) => c.as_str(),
        None => {
            println!("{USAGE}");
            return Ok(());
        }
    };

    match cmd {
        "compress" => {
            let cfg = config_by_name(args.get_or("model", "sim-s"))?;
            let base = load_model(
                args.get("base").context("--base required")?, &cfg)?;
            let fine = load_model(
                args.get("fine").context("--fine required")?, &cfg)?;
            let out = args.get("out").context("--out required")?;
            let levels = args.get_usize("levels", 1)?;
            if levels == 0 {
                bail!("usage: --levels must be >= 1 (a delta needs at \
least one 1-bit mask; --levels K > 1 stacks K successive masks)");
            }
            let delta = if levels == 1 {
                let c = compress(&cfg, &base, &fine)?;
                println!("compression factor: {:.2}x",
                         c.compression_factor(&cfg));
                c.delta
            } else {
                compress_iterative(&cfg, &base, &fine, levels)?
            };
            bdw::write_bdw(out, &delta.to_bdw(&cfg))?;
            println!("wrote {out} ({} mask level(s), {} bytes)",
                     delta.levels.len(), delta.delta_bytes());
        }
        "inspect" => {
            let cfg = config_by_name(args.get_or("model", "sim-s"))?;
            let d = DeltaFile::load(
                args.get("delta").context("--delta required")?, &cfg)?;
            println!("levels: {}", d.levels.len());
            for (i, l) in d.levels.iter().enumerate() {
                let mean: f32 = l.scales.iter().sum::<f32>()
                    / l.scales.len() as f32;
                println!("  level {i}: {} masks, mean alpha {mean:.6}",
                         l.bits.len());
            }
            println!("delta bytes: {}", d.delta_bytes());
            let dense: usize = cfg.param_names().iter()
                .map(|n| cfg.param_shape(n).iter().product::<usize>() * 4)
                .sum();
            println!("compression factor vs dense f32: {:.2}x",
                     dense as f64 / d.delta_bytes() as f64);
        }
        "serve" => serve_demo(
            &artifacts,
            // --codec is the codec-registry name; --mode kept as alias
            args.get("codec")
                .unwrap_or_else(|| args.get_or("mode", "bitdelta")),
            args.get("tenant-codecs"),
            parse_tenant_levels(args.get("tenant-levels"))?,
            args.get_usize("batch", 4)?,
            args.get_usize("requests", 12)?,
            args.get_usize("threads", 0)?,
            kv_flags(&args)?,
            args.get_or("model", "sim-s"))?,
        "serve-cluster" => serve_cluster(
            &artifacts,
            args.get_usize("workers", 2)?,
            args.get_or("policy", "delta-aware"),
            args.get("codec")
                .unwrap_or_else(|| args.get_or("mode", "bitdelta")),
            parse_tenant_levels(args.get("tenant-levels"))?,
            args.get_usize("batch", 4)?,
            args.get_usize("requests", 16)?,
            args.get_usize("budget-mb", 256)?,
            args.get_usize("admission-budget", 256)?,
            args.get_usize("threads", 0)?,
            kv_flags(&args)?,
            args.get_or("model", "sim-s"))?,
        "codecs" => {
            let registry = CodecRegistry::builtin();
            println!("registered delta codecs:");
            for c in registry.iter() {
                println!("  {:<10} exec={:<16} shared-base={}",
                         c.name(), c.exec_kind(), c.needs_base());
            }
        }
        "table1" => {
            let mut ctx = TableCtx::load(&artifacts)?;
            println!("{}", tables::table1(&mut ctx, "sim-s")?);
        }
        "table2" => {
            let mut ctx = TableCtx::load(&artifacts)?;
            println!("{}", tables::table2(&mut ctx)?);
        }
        "table5" => println!("{}", table5(&artifacts)?),
        "table6" => {
            let mut ctx = TableCtx::load(&artifacts)?;
            println!("{}", tables::table6(&mut ctx, "sim-s")?);
        }
        "table7" => {
            let mut ctx = TableCtx::load(&artifacts)?;
            println!("{}", tables::table7(&mut ctx, "sim-s")?);
        }
        "fig2" => {
            let mut ctx = TableCtx::load(&artifacts)?;
            println!("{}", tables::fig2(&mut ctx, "sim-s")?);
        }
        // table-fig3 = alias: the Fig. 3 reproduction table (quality +
        // reconstruction error vs served level count)
        "fig3" | "table-fig3" => {
            let mut ctx = TableCtx::load(&artifacts)?;
            println!("{}", tables::fig3(&mut ctx, "sim-s")?);
        }
        "fig5" => println!("{}", fig5()),
        "loadtest" => {
            let requests = args.get_usize("requests", 24)?;
            let rate = args.get("rate").map(|r| r.parse()).transpose()?
                .unwrap_or(20.0);
            let zipf_s = args.get("zipf").map(|z| z.parse()).transpose()?
                .unwrap_or(0.9);
            let batch = args.get_usize("batch", 4)?;
            let workers = args.get_usize("workers", 1)?;
            let threads = args.get_usize("threads", 0)?;
            let tenant_levels =
                parse_tenant_levels(args.get("tenant-levels"))?;
            let autoscale = parse_autoscale(args.get("autoscale"))?;
            let kvf = kv_flags(&args)?;
            let pattern = parse_trace_pattern(
                args.get_or("trace", "steady"),
                args.get("burst-period").map(|v| v.parse())
                    .transpose()?.unwrap_or(1.0),
                args.get("burst-mult").map(|v| v.parse())
                    .transpose()?.unwrap_or(6.0))?;
            if workers <= 1 && autoscale.is_none() {
                loadtest(&artifacts, requests, rate, zipf_s, batch,
                         threads, tenant_levels, pattern, kvf)?
            } else {
                loadtest_cluster(
                    &artifacts, requests, rate, zipf_s, batch, workers,
                    args.get_or("policy", "delta-aware"),
                    args.get_usize("clients", 0)?,
                    args.get_usize("tenants", 0)?,
                    args.get_usize("budget-mb", 256)?,
                    args.get_usize("admission-budget", 256)?,
                    threads, autoscale, pattern, tenant_levels, kvf)?
            }
        }
        "extras-quant" => extras_quant(
            &artifacts, args.get_or("tenant", "sim-s-chat"))?,
        "case-study" => case_study(&artifacts)?,
        "metrics-demo" => {
            let mut engine = Engine::from_artifacts(
                EngineConfig::new(&artifacts))?;
            fire_requests(&mut engine, 6)?;
            engine.run_until_idle(100_000)?;
            println!("{}{}", engine.metrics.exposition(),
                     engine.codec_accounting());
        }
        other => {
            println!("{USAGE}");
            bail!("unknown command {other:?}");
        }
    }
    Ok(())
}

/// KV-cache geometry flags shared by every serving command.
#[derive(Debug, Clone, Copy)]
struct KvFlags {
    slab: bool,
    block_size: usize,
    blocks: usize,
    roundtrip: bool,
}

impl KvFlags {
    fn apply(&self, ec: &mut EngineConfig) {
        ec.kv_slab_fallback = self.slab;
        ec.kv_block_size = self.block_size.max(1);
        ec.kv_blocks = self.blocks;
        ec.kv_roundtrip = self.roundtrip;
    }
}

/// Parse `--kv-slab`, `--kv-block-size N`, `--kv-blocks N`,
/// `--kv-roundtrip` (defaults match [`EngineConfig`]: paged, 16-token
/// blocks, auto-sized pool, device-resident decode KV).
fn kv_flags(args: &Args) -> Result<KvFlags> {
    Ok(KvFlags {
        slab: args.has("kv-slab"),
        block_size: args.get_usize("kv-block-size", 16)?,
        blocks: args.get_usize("kv-blocks", 0)?,
        roundtrip: args.has("kv-roundtrip"),
    })
}

/// Parse `--tenant-levels t1=2,t2=4` into tenant → fidelity tier.
fn parse_tenant_levels(spec: Option<&str>)
                       -> Result<std::collections::HashMap<String, usize>> {
    let mut out = std::collections::HashMap::new();
    let Some(spec) = spec else { return Ok(out) };
    for pair in spec.split(',').filter(|s| !s.is_empty()) {
        let (tenant, k) = pair.split_once('=').with_context(
            || format!("--tenant-levels entry {pair:?}: want \
tenant=levels"))?;
        let k: usize = k.parse().with_context(
            || format!("--tenant-levels entry {pair:?}: levels must be \
a positive integer"))?;
        if k == 0 {
            bail!("--tenant-levels entry {pair:?}: a fidelity tier \
needs >= 1 mask level");
        }
        out.insert(tenant.to_string(), k);
    }
    Ok(out)
}

/// Parse `--autoscale 2..6` into `(min, max)` worker bounds.
fn parse_autoscale(spec: Option<&str>)
                   -> Result<Option<(usize, usize)>> {
    let Some(spec) = spec else { return Ok(None) };
    let (lo, hi) = spec.split_once("..").with_context(
        || format!("--autoscale {spec:?}: want MIN..MAX, e.g. 2..6"))?;
    let lo: usize = lo.trim().parse().with_context(
        || format!("--autoscale {spec:?}: MIN must be an integer"))?;
    let hi: usize = hi.trim().parse().with_context(
        || format!("--autoscale {spec:?}: MAX must be an integer"))?;
    if lo == 0 || hi < lo {
        bail!("--autoscale {spec:?}: need 1 <= MIN <= MAX");
    }
    Ok(Some((lo, hi)))
}

/// Parse `--trace steady|burst` (+ burst shape flags) into a pattern.
fn parse_trace_pattern(name: &str, period: f64, mult: f64)
                       -> Result<bitdelta::coordinator::workload::
                                 ArrivalPattern> {
    use bitdelta::coordinator::workload::ArrivalPattern;
    match name {
        "steady" => Ok(ArrivalPattern::Steady),
        "burst" => {
            if period <= 0.0 || mult < 1.0 {
                bail!("--trace burst: need --burst-period > 0 and \
--burst-mult >= 1");
            }
            Ok(ArrivalPattern::Burst {
                half_period: period, high_mult: mult,
            })
        }
        other => bail!("unknown --trace {other:?} — available: \
steady, burst"),
    }
}

fn config_by_name(name: &str) -> Result<ModelConfig> {
    match name {
        "sim-s" => Ok(ModelConfig::sim_s()),
        "sim-m" => Ok(ModelConfig::sim_m()),
        other => bail!("unknown model config {other}"),
    }
}

fn demo_prompts() -> Vec<&'static str> {
    vec![
        "Q: what color is the sky ?\nA:",
        "Q: what is 17 plus 25 ?\nA:",
        "Q: where does ada live ?\nA:",
        "Q: what does bob eat ?\nA:",
    ]
}

fn fire_requests(engine: &mut Engine, n: usize)
                 -> Result<Vec<std::sync::mpsc::Receiver<
                     Result<bitdelta::serving::request::Response,
                            bitdelta::serving::request::RequestError>>>> {
    let tenants = engine.tenants();
    let prompts = demo_prompts();
    let mut chans = Vec::new();
    for i in 0..n {
        let req = Request {
            tenant: tenants[i % tenants.len()].clone(),
            prompt: prompts[i % prompts.len()].to_string(),
            max_new_tokens: 24,
            sampling: SamplingParams::greedy(),
        };
        chans.push(engine.submit(req)?);
    }
    Ok(chans)
}

#[allow(clippy::too_many_arguments)]
fn serve_demo(artifacts: &Path, codec: &str,
              tenant_codecs: Option<&str>,
              tenant_levels: std::collections::HashMap<String, usize>,
              batch: usize, requests: usize, threads: usize,
              kvf: KvFlags, model: &str) -> Result<()> {
    let registry = CodecRegistry::builtin();
    let codec = registry.get(codec)?.name();   // validate + canonicalize
    let mut ec = EngineConfig::new(artifacts);
    ec.codec = Some(codec.to_string());
    // --tenant-codecs t1=lora,t2=bitdelta pins individual tenants to a
    // different codec; the engine then serves mixed-format batches
    if let Some(spec) = tenant_codecs {
        for pair in spec.split(',').filter(|s| !s.is_empty()) {
            let (tenant, cname) = pair.split_once('=').with_context(
                || format!("--tenant-codecs entry {pair:?}: want \
tenant=codec"))?;
            let c = registry.get(cname)?;
            ec.codec_overrides.insert(tenant.to_string(),
                                      c.name().to_string());
        }
    }
    // --tenant-levels t1=2,t2=4 serves individual tenants at higher
    // Fig. 3 fidelity tiers; mixed tiers batch via zero-scale padding
    ec.tenant_levels = tenant_levels;
    ec.batch = batch;
    ec.model = model.to_string();
    ec.threads = threads;
    kvf.apply(&mut ec);
    let mut engine = Engine::from_artifacts(ec)?;
    let assignments: Vec<String> = engine.tenants().iter()
        .map(|t| {
            let lv = engine.tenant_fidelity(t);
            let lv = if lv > 1 { format!("@l{lv}") } else { String::new() };
            format!("{t}={}{lv}", engine.tenant_codec(t).unwrap_or("?"))
        })
        .collect();
    println!("engine up: codec={codec} batch={batch} \
tenants={assignments:?}");
    println!("kernel engine: dispatch={} threads={}",
             bitdelta::gemm::dispatch::active_tier().name(),
             bitdelta::gemm::dispatch::pool_threads());
    let t0 = std::time::Instant::now();
    let chans = fire_requests(&mut engine, requests)?;
    engine.run_until_idle(1_000_000)?;
    let wall = t0.elapsed();
    let mut total_tokens = 0usize;
    for c in chans {
        if let Ok(Ok(resp)) = c.try_recv() {
            total_tokens += resp.tokens.len();
            println!("[{}] {:?} ({} tok, {:.1} ms, ttft {:.1} ms)",
                     resp.tenant, resp.text, resp.tokens.len(),
                     resp.latency.as_secs_f64() * 1e3,
                     resp.ttft.as_secs_f64() * 1e3);
        }
    }
    println!("\n{requests} requests, {total_tokens} tokens in \
{:.2}s -> {:.1} tok/s",
             wall.as_secs_f64(),
             total_tokens as f64 / wall.as_secs_f64());
    println!("\n{}{}", engine.metrics.exposition(),
             engine.codec_accounting());
    Ok(())
}

/// Multi-worker serving demo: spawn a cluster, fire requests from
/// several client threads, report per-worker + rollup metrics and the
/// placement's memory story at the paper's 7B scale.
#[allow(clippy::too_many_arguments)]
fn serve_cluster(artifacts: &Path, workers: usize, policy_name: &str,
                 codec: &str,
                 tenant_levels: std::collections::HashMap<String, usize>,
                 batch: usize, requests: usize,
                 budget_mb: usize, admission_budget: usize,
                 threads: usize, kvf: KvFlags, model: &str)
                 -> Result<()> {
    use bitdelta::cluster::{policy_by_name, tenant_profiles, Cluster,
                            ClusterConfig};
    use bitdelta::coordinator::admission::AdmissionPolicy;

    let registry = CodecRegistry::builtin();
    let codec = registry.get(codec)?.name();   // validate + canonicalize
    let mut ec = EngineConfig::new(artifacts);
    ec.codec = Some(codec.to_string());
    ec.tenant_levels = tenant_levels;
    ec.batch = batch;
    ec.model = model.to_string();
    ec.threads = threads;
    kvf.apply(&mut ec);
    let profiles = tenant_profiles(&ec)?;
    let level_of: std::collections::HashMap<String, usize> = profiles
        .iter().map(|p| (p.name.clone(), p.levels)).collect();
    let ccfg = ClusterConfig {
        policy: policy_by_name(policy_name)?,
        delta_budget_bytes: budget_mb << 20,
        admission: (admission_budget > 0).then(|| {
            AdmissionPolicy::for_budget(admission_budget,
                                        profiles.len())
        }),
    };
    let cluster = Cluster::spawn_engines(&ccfg, &ec, workers, profiles)?;
    let handle = cluster.handle();
    let tenants = handle.tenants();
    let placed = handle.placement();
    println!("cluster up: {workers} workers, policy {policy_name}, \
codec {codec}");
    println!("kernel engine: dispatch={} threads={}",
             bitdelta::gemm::dispatch::active_tier().name(),
             bitdelta::gemm::dispatch::pool_threads());
    for t in &tenants {
        let lv = level_of.get(t).copied().unwrap_or(1);
        let tier = if lv > 1 { format!(" (tier l{lv})") }
                   else { String::new() };
        println!("  {t:<16} -> workers {:?}{tier}",
                 placed.workers_of(t));
    }

    let t0 = std::time::Instant::now();
    let client_n = workers.clamp(1, 4);
    let mut joins = Vec::new();
    for c in 0..client_n {
        let h = handle.clone();
        let tenants = tenants.clone();
        let prompts: Vec<String> = demo_prompts().iter()
            .map(|p| p.to_string()).collect();
        let mine: Vec<usize> =
            (0..requests).filter(|i| i % client_n == c).collect();
        joins.push(std::thread::spawn(move || {
            mine.into_iter().map(|i| {
                h.generate(Request {
                    tenant: tenants[i % tenants.len()].clone(),
                    prompt: prompts[i % prompts.len()].clone(),
                    max_new_tokens: 24,
                    sampling: SamplingParams::greedy(),
                })
            }).collect::<Vec<_>>()
        }));
    }
    let mut total_tokens = 0usize;
    let mut served = 0usize;
    for j in joins {
        let results = j.join()
            .map_err(|_| anyhow::anyhow!("client thread panicked"))?;
        for r in results {
            let resp = r?;
            served += 1;
            total_tokens += resp.tokens.len();
            println!("[{}] {:?} ({} tok, {:.1} ms)",
                     resp.tenant, resp.text, resp.tokens.len(),
                     resp.latency.as_secs_f64() * 1e3);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("\n{served} requests, {total_tokens} tokens in {wall:.2}s \
-> {:.1} tok/s across {workers} workers",
             total_tokens as f64 / wall);
    println!("\n{}", handle.metrics());

    // this placement (replicas included, each at its fidelity tier),
    // projected onto the paper's 7B shapes: N base copies + placed
    // k-level deltas vs one dense model per placed tenant
    let reps = placed.replicas_per_worker(workers);
    let mut levels_per_worker: Vec<Vec<usize>> = vec![vec![]; workers];
    for t in placed.tenants() {
        for &w in placed.workers_of(t) {
            if w < workers {
                levels_per_worker[w]
                    .push(level_of.get(t).copied().unwrap_or(1));
            }
        }
    }
    let spec = ModelSpec::llama2_7b();
    let bd = memory::cluster_account_levels(&spec, &levels_per_worker,
                                            batch, 128,
                                            memory::A100_80GB);
    let nv = memory::cluster_account(&spec, ServingMode::Naive, &reps,
                                     batch, 128, memory::A100_80GB);
    let gb = |b: usize| b as f64 / (1024.0 * 1024.0 * 1024.0);
    println!("cluster memory @ Llama-2-7B scale ({} tenant replicas on \
{workers} workers):", bd.replicas);
    println!("  bitdelta: {:>7.1} GB total, every worker fits \
A100-80GB: {}", gb(bd.total_bytes), bd.fits_all);
    println!("  naive:    {:>7.1} GB total, every worker fits \
A100-80GB: {}", gb(nv.total_bytes), nv.fits_all);
    println!("  cluster-wide memory win: {:.2}x",
             nv.total_bytes as f64 / bd.total_bytes as f64);
    // the paged-KV win beside the delta win: the same fleet's
    // sequences priced under slab / paged / paged + shared system
    // prompt, at 7B (MHA) and 70B (GQA: n_kv_heads = 8) scale
    let seqs = workers * batch;
    for spec in [ModelSpec::llama2_7b(), ModelSpec::llama2_70b()] {
        let kv = memory::paged_kv_account(&spec, seqs, 4096, 512, 256,
                                          kvf.block_size.max(1));
        println!("  paged KV @ {} ({seqs} seqs, len 512 of 4096, \
256-token shared prompt, block {}): slab {:.1} GB -> paged {:.1} GB \
({:.1}x) -> shared-prefix {:.1} GB ({:.1}x)",
                 spec.name, kv.block_size, gb(kv.slab_bytes),
                 gb(kv.paged_bytes), kv.paged_win(),
                 gb(kv.shared_bytes), kv.shared_win());
    }
    cluster.shutdown()?;
    Ok(())
}

/// Cluster loadtest: replay a Poisson/Zipf trace (optionally a
/// square-wave burst) from several client threads, honoring arrival
/// times, against an engine-backed cluster — optionally elastic
/// (`--autoscale MIN..MAX`) and admission-controlled
/// (`--admission-budget N`).
#[allow(clippy::too_many_arguments)]
fn loadtest_cluster(artifacts: &Path, requests: usize, rate: f64,
                    zipf_s: f64, batch: usize, workers: usize,
                    policy: &str, clients: usize, trace_tenants: usize,
                    budget_mb: usize, admission_budget: usize,
                    threads: usize, autoscale: Option<(usize, usize)>,
                    pattern: bitdelta::coordinator::workload::
                        ArrivalPattern,
                    tenant_levels: std::collections::HashMap<String,
                                                             usize>,
                    kvf: KvFlags)
                    -> Result<()> {
    use std::time::Duration;

    use bitdelta::cluster::{apply_trace_weights, policy_by_name,
                            replay_trace, tenant_profiles, Autoscaler,
                            AutoscalerConfig, Cluster, ClusterConfig};
    use bitdelta::sync::clock::{self, Instant};
    use bitdelta::coordinator::admission::AdmissionPolicy;
    use bitdelta::coordinator::workload::{generate, stats, TraceConfig};

    let mut ec = EngineConfig::new(artifacts);
    ec.tenant_levels = tenant_levels;
    ec.batch = batch;
    ec.threads = threads;
    kvf.apply(&mut ec);
    let mut profiles = tenant_profiles(&ec)?;
    // trace ranks map onto engine tenants by rank % n — more ranks than
    // tenants lets a small tenant set carry an 8-way-skewed trace
    let n_ranks = if trace_tenants == 0 {
        profiles.len().max(8)
    } else {
        trace_tenants
    };
    let tcfg = TraceConfig {
        n_tenants: n_ranks,
        n_requests: requests,
        rate,
        zipf_s,
        min_tokens: 8,
        max_tokens: 24,
        seed: 7,
        pattern,
    };
    let trace = generate(&tcfg);
    let st = stats(&trace, n_ranks);
    apply_trace_weights(&mut profiles, &st.per_tenant);
    let names: Vec<String> =
        profiles.iter().map(|t| t.name.clone()).collect();
    let tenant_levels_list: Vec<usize> =
        profiles.iter().map(|p| p.levels).collect();
    println!("trace: {} requests over {:.2}s ({:?}), hottest rank \
{:.0}% of traffic, {}/{n_ranks} ranks hit, {} engine tenants",
             st.n, st.duration, pattern, st.hottest_share * 100.0,
             st.tenants_hit, names.len());

    let (min_w, max_w) = autoscale.unwrap_or((workers, workers));
    let initial = workers.clamp(min_w, max_w);
    let ccfg = ClusterConfig {
        policy: policy_by_name(policy)?,
        delta_budget_bytes: budget_mb << 20,
        admission: (admission_budget > 0).then(|| {
            AdmissionPolicy::for_budget(admission_budget,
                                        profiles.len())
        }),
    };
    let cluster = Cluster::spawn_engines(&ccfg, &ec, initial, profiles)?;
    let handle = cluster.handle();
    let scaler = autoscale.map(|(lo, hi)| {
        Autoscaler::spawn(handle.clone(), AutoscalerConfig {
            min_workers: lo,
            max_workers: hi,
            // pressured when outstanding work exceeds ~2 full batches
            // per worker; slack well under one batch
            high_watermark: (2 * batch.max(1)) as f64,
            low_watermark: 0.5,
            up_ticks: 3,
            down_ticks: 8,
            cooldown_ticks: 3,
            interval: Duration::from_millis(30),
        })
    });
    let clients = if clients == 0 {
        (initial * 2).clamp(2, 8)
    } else {
        clients
    };
    match autoscale {
        Some((lo, hi)) => println!(
            "cluster up: {initial} workers (elastic {lo}..{hi}), \
policy {policy}, {clients} client threads"),
        None => println!("cluster up: {initial} workers, policy \
{policy}, {clients} client threads"),
    }

    let r = replay_trace(&handle, &trace, &names, &demo_prompts(),
                         clients)?;

    // let the autoscaler drain back down before the final report so
    // the scale-down half of the story is visible in one run
    if let Some(s) = scaler {
        let t0 = Instant::now();
        while handle.active_workers() > min_w
            && t0.elapsed() < Duration::from_secs(20) {
            clock::sleep(Duration::from_millis(20));
        }
        s.stop();
    }

    println!("served {} requests / {} tokens in {:.2}s -> \
{:.1} tok/s ({} errors, {} admission-rejected)",
             r.served(), r.tokens, r.wall_seconds, r.tok_per_s(),
             r.errors, r.rejected);
    println!("kernel engine: dispatch={} threads={}",
             r.dispatch_tier, r.kernel_threads);
    if r.served() > 0 {
        println!("latency p50 {:.0} ms, p99 {:.0} ms, max {:.0} ms",
                 r.quantile_ms(0.5), r.quantile_ms(0.99),
                 r.quantile_ms(1.0));
    }
    if r.kv_blocks_total > 0 {
        println!("kv cache: {}/{} blocks resident ({:.0}% occupancy), \
prefix reuse {}/{} admissions ({:.0}%)",
                 r.kv_blocks_used, r.kv_blocks_total,
                 r.kv_occupancy() * 100.0, r.kv_prefix_hits,
                 r.kv_prefix_lookups, r.kv_prefix_hit_rate() * 100.0);
    } else {
        println!("kv cache: dense slab fallback (no paging metrics)");
    }
    if autoscale.is_some() {
        let (ups, downs) = handle.scale_events();
        println!("autoscale: peak {} worker slots, {} scale-up(s), \
{} graceful drain(s), {} active at end",
                 handle.n_workers(), ups, downs,
                 handle.active_workers());
        // the elasticity price at the paper's 7B scale: each scale-up
        // pays one base copy; the deltas it hosts ride along ~free.
        // Priced at the ceiling — the new worker hosting every tenant
        // replica — since bin-packing policies may re-place only a
        // subset onto it.
        let spec = ModelSpec::llama2_7b();
        let cost = memory::scale_up_cost(&spec, &tenant_levels_list,
                                         batch, 128);
        let gb = |b: usize| b as f64 / (1024.0 * 1024.0 * 1024.0);
        println!("scale-up marginal cost @ {} (ceiling: new worker \
hosts all {} tenant replicas): {:.2} GB base + {:.2} GB deltas + \
{:.2} GB kv/act = {:.2} GB",
                 spec.name, tenant_levels_list.len(),
                 gb(cost.base_bytes), gb(cost.delta_bytes),
                 gb(cost.kv_act_bytes), gb(cost.total_bytes));
    }
    println!("\n{}", handle.metrics());
    cluster.shutdown()?;
    Ok(())
}

fn table5(artifacts: &Path) -> Result<String> {
    let mut out = String::new();
    out.push_str("Table 5 — compression factors\n");
    out.push_str(&format!("{:<22} {:>12} {:>12} {:>8}\n",
                          "Base Model", "Size", "Δ Size", "Factor"));
    let gb = |b: usize| b as f64 / (1024.0 * 1024.0 * 1024.0);
    for spec in [ModelSpec::llama2_7b(), ModelSpec::llama2_13b(),
                 ModelSpec::llama2_70b(), ModelSpec::mistral_7b()] {
        out.push_str(&format!(
            "{:<22} {:>9.2} GB {:>9.2} GB {:>7.2}x\n",
            spec.name, gb(spec.dense_bytes()), gb(spec.delta_bytes()),
            spec.compression_factor()));
    }
    // measured on our artifacts
    if let Ok(manifest) = Manifest::load(artifacts) {
        let mb = |b: usize| b as f64 / (1024.0 * 1024.0);
        let mut tenants: Vec<_> = manifest.tenants.iter().collect();
        tenants.sort_by_key(|(n, _)| n.to_string());
        for (name, t) in tenants {
            let cfg = manifest.config(&t.config)?;
            let model_bytes = std::fs::metadata(
                manifest.path(&t.finetune))?.len() as usize;
            let d = DeltaFile::load(manifest.path(&t.delta), cfg)?;
            out.push_str(&format!(
                "{:<22} {:>9.2} MB {:>9.2} MB {:>7.2}x (measured)\n",
                name, mb(model_bytes), mb(d.delta_bytes()),
                model_bytes as f64 / d.delta_bytes() as f64));
        }
    }
    Ok(out)
}

fn fig5() -> String {
    let spec = ModelSpec::llama2_7b();
    let batches: Vec<usize> = (0..=6).map(|i| 1usize << i).collect();
    let mut out = String::new();
    // lint: allow(metric, bitdelta_gb is a CSV column, not a series)
    out.push_str("Figure 5 — memory vs batch (Llama 2-7B, seq 128, \
A100-80GB)\nbatch,naive_gb,bitdelta_gb,slora_gb,naive_fits\n");
    for &b in &batches {
        let n = memory::account(&spec, ServingMode::Naive, b, 128,
                                memory::A100_80GB);
        let d = memory::account(&spec, ServingMode::BitDelta, b, 128,
                                memory::A100_80GB);
        let l = memory::account(&spec, ServingMode::Lora(128), b, 128,
                                memory::A100_80GB);
        let gb = |x: usize| x as f64 / (1024.0 * 1024.0 * 1024.0);
        out.push_str(&format!("{b},{:.2},{:.2},{:.2},{}\n",
                              gb(n.total_bytes), gb(d.total_bytes),
                              gb(l.total_bytes), n.fits));
    }
    let oom = memory::oom_point(&spec, ServingMode::Naive, 128,
                                memory::A100_80GB, 128);
    out.push_str(&format!("# naive OOM at batch {oom:?}; \
bitdelta fits all tested batches\n"));
    out
}

#[allow(clippy::too_many_arguments)]
fn loadtest(artifacts: &Path, requests: usize, rate: f64,
            zipf_s: f64, batch: usize, threads: usize,
            tenant_levels: std::collections::HashMap<String, usize>,
            pattern: bitdelta::coordinator::workload::ArrivalPattern,
            kvf: KvFlags)
            -> Result<()> {
    use bitdelta::coordinator::workload::{generate, stats, TraceConfig};

    let mut ec = EngineConfig::new(artifacts);
    ec.tenant_levels = tenant_levels;
    ec.batch = batch;
    ec.threads = threads;
    kvf.apply(&mut ec);
    let mut engine = Engine::from_artifacts(ec)?;
    let tenants = engine.tenants();
    let tcfg = TraceConfig {
        n_tenants: tenants.len(),
        n_requests: requests,
        rate,
        zipf_s,
        min_tokens: 8,
        max_tokens: 24,
        seed: 7,
        pattern,
    };
    let trace = generate(&tcfg);
    let st = stats(&trace, tenants.len());
    println!("trace: {} requests over {:.2}s, hottest tenant {:.0}% of \
traffic, {}/{} tenants hit",
             st.n, st.duration, st.hottest_share * 100.0,
             st.tenants_hit, tenants.len());

    let prompts = demo_prompts();
    let t0 = std::time::Instant::now();
    let mut chans = Vec::new();
    let mut fired = 0usize;
    let mut step_reports = Vec::new();
    // replay: submit events when their arrival time passes, stepping
    // the engine in between (open-loop load generation)
    while fired < trace.len() || engine.batcher.occupancy() > 0
        || engine.router.total_queued() > 0 {
        let now = t0.elapsed().as_secs_f64();
        while fired < trace.len() && trace[fired].at <= now {
            let e = &trace[fired];
            chans.push(engine.submit(Request {
                tenant: tenants[e.tenant].clone(),
                prompt: prompts[e.prompt_idx % prompts.len()].into(),
                max_new_tokens: e.max_new_tokens,
                sampling: SamplingParams::greedy(),
            })?);
            fired += 1;
        }
        if engine.batcher.occupancy() > 0
            || engine.router.total_queued() > 0 {
            step_reports.push(engine.step()?);
        } else if fired < trace.len() {
            bitdelta::sync::thread::sleep(
                std::time::Duration::from_micros(200));
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let mut latencies: Vec<f64> = Vec::new();
    let mut tokens = 0usize;
    for c in &chans {
        if let Ok(Ok(r)) = c.try_recv() {
            latencies.push(r.latency.as_secs_f64());
            tokens += r.tokens.len();
        }
    }
    latencies.sort_by(|a, b| a.total_cmp(b));
    let occ: f64 = step_reports.iter().map(|r| r.active as f64).sum::<f64>()
        / step_reports.len().max(1) as f64;
    println!("served {} requests / {tokens} tokens in {wall:.2}s -> \
{:.1} tok/s; mean batch occupancy {occ:.2}/{batch}",
             latencies.len(), tokens as f64 / wall);
    println!("kernel engine: dispatch={} threads={}",
             bitdelta::gemm::dispatch::active_tier().name(),
             bitdelta::gemm::dispatch::pool_threads());
    if !latencies.is_empty() {
        println!("latency p50 {:.0} ms, p95 {:.0} ms, max {:.0} ms",
                 latencies[latencies.len() / 2] * 1e3,
                 latencies[latencies.len() * 95 / 100] * 1e3,
                 latencies[latencies.len() - 1] * 1e3);
    }
    if !step_reports.is_empty() {
        let n = step_reports.len() as f64;
        let up: f64 = step_reports.iter().map(|r| r.upload_seconds).sum();
        let ex: f64 = step_reports.iter().map(|r| r.exec_seconds).sum();
        let dn: f64 = step_reports.iter()
            .map(|r| r.download_seconds).sum();
        let bk: f64 = step_reports.iter().map(|r| r.bank_seconds).sum();
        let h2d: u64 = step_reports.iter().map(|r| r.bytes_h2d).sum();
        let d2h: u64 = step_reports.iter().map(|r| r.bytes_d2h).sum();
        println!("step phases (mean ms): upload {:.2}, exec {:.2}, \
download {:.2}, bank {:.2}; transfer/step: {:.0} B h2d, {:.0} B d2h",
                 up / n * 1e3, ex / n * 1e3, dn / n * 1e3, bk / n * 1e3,
                 h2d as f64 / n, d2h as f64 / n);
    }
    println!("\n{}{}", engine.metrics.exposition(),
             engine.codec_accounting());
    Ok(())
}

fn extras_quant(artifacts: &Path, tenant: &str) -> Result<()> {
    use bitdelta::delta::extras_quant::recompress_delta;

    let manifest = Manifest::load(artifacts)?;
    let t = manifest.tenants.get(tenant)
        .context("unknown tenant")?;
    let cfg = manifest.config(&t.config)?.clone();
    let base_name = format!("{}-base", t.config);
    let base = load_model(
        manifest.path(&manifest.models[&base_name].file), &cfg)?;
    let delta = DeltaFile::load(manifest.path(&t.delta), &cfg)?;
    let (recon, before, after) = recompress_delta(&cfg, &base, &delta)?;

    let dense: usize = cfg.param_names().iter()
        .map(|n| cfg.param_shape(n).iter().product::<usize>() * 4).sum();
    println!("extras-quant extension ({tenant}) — the compression the \
paper defers to future work:");
    println!("  delta bytes fp32-extras : {before:>10}  \
(factor {:.2}x)", dense as f64 / before as f64);
    println!("  delta bytes int8-extras : {after:>10}  \
(factor {:.2}x)", dense as f64 / after as f64);

    // quality check: reconstruction error on the embedding
    let a = delta.extras["tok_embed"].as_f32()?;
    let b = recon.extras["tok_embed"].as_f32()?;
    let rel = (a.iter().zip(&b)
               .map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>()
               .sqrt())
        / a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    println!("  tok_embed INT8 rel. error: {rel:.5} (lossless to \
~3 decimal places)");
    Ok(())
}

fn case_study(artifacts: &Path) -> Result<()> {
    println!("Table 4 analog — scale distillation and instruction \
following (sim-s-chat)\n");
    let prompt = "Q: what color is the rose ?\nA:";
    for (label, distilled) in [("BitDelta-Initial", false),
                               ("BitDelta (distilled)", true)] {
        let mut ec = EngineConfig::new(artifacts);
        ec.distilled = distilled;
        ec.batch = 1;
        let mut engine = Engine::from_artifacts(ec)?;
        let chan = engine.submit(Request {
            tenant: "sim-s-chat".into(),
            prompt: prompt.to_string(),
            max_new_tokens: 32,
            sampling: SamplingParams::greedy(),
        })?;
        engine.run_until_idle(100_000)?;
        let resp = chan.recv()??;
        println!("{label:<22} -> {:?}", resp.text);
    }
    Ok(())
}
