//! BDW (`BDW1`) container: named tensors with an FNV-1a integrity footer.
//!
//! Layout (little-endian), mirroring `python/compile/serialize.py`:
//!
//! ```text
//! magic   4s  = "BDW1"
//! version u32 = 1
//! count   u32
//! count × [ name_len u16 | name | dtype u8 | ndim u8 | dims u32×ndim
//!           | size u64 | payload ]
//! fnv1a   u64   (over every payload byte, in order)
//! ```

use std::collections::HashMap;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

pub const MAGIC: &[u8; 4] = b"BDW1";
pub const VERSION: u32 = 1;

const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    U8,
    I32,
}

impl Dtype {
    fn from_id(id: u8) -> Result<Self> {
        Ok(match id {
            0 => Dtype::F32,
            1 => Dtype::U8,
            2 => Dtype::I32,
            _ => bail!("unknown dtype id {id}"),
        })
    }

    fn id(self) -> u8 {
        match self {
            Dtype::F32 => 0,
            Dtype::U8 => 1,
            Dtype::I32 => 2,
        }
    }

    pub fn size(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::U8 => 1,
        }
    }
}

/// One stored tensor: raw little-endian payload plus shape/dtype.
#[derive(Debug, Clone, PartialEq)]
pub struct RawTensor {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    pub bytes: Vec<u8>,
}

impl RawTensor {
    pub fn f32(shape: Vec<usize>, values: &[f32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut bytes = Vec::with_capacity(values.len() * 4);
        for v in values {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        Self { dtype: Dtype::F32, shape, bytes }
    }

    pub fn u8(shape: Vec<usize>, values: Vec<u8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        Self { dtype: Dtype::U8, shape, bytes: values }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Decode as f32 (fails on other dtypes).
    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != Dtype::F32 {
            bail!("tensor is {:?}, not F32", self.dtype);
        }
        Ok(self.bytes.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        if self.dtype != Dtype::U8 {
            bail!("tensor is {:?}, not U8", self.dtype);
        }
        Ok(&self.bytes)
    }

    pub fn to_tensor(&self) -> Result<crate::tensor::Tensor> {
        Ok(crate::tensor::Tensor::new(self.shape.clone(), self.as_f32()?))
    }
}

/// An ordered named-tensor container.
#[derive(Debug, Default, Clone)]
pub struct Bdw {
    pub names: Vec<String>,
    pub tensors: HashMap<String, RawTensor>,
}

impl Bdw {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, t: RawTensor) {
        let name = name.into();
        if !self.tensors.contains_key(&name) {
            self.names.push(name.clone());
        }
        self.tensors.insert(name, t);
    }

    pub fn get(&self, name: &str) -> Result<&RawTensor> {
        self.tensors.get(name)
            .with_context(|| format!("tensor {name} not in container \
(has: {:?}...)", &self.names[..self.names.len().min(4)]))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.tensors.contains_key(name)
    }

    /// Total payload bytes (the on-disk weight size, Table 5 accounting).
    pub fn payload_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.bytes.len()).sum()
    }
}

#[inline]
fn fnv1a(mut state: u64, data: &[u8]) -> u64 {
    for &b in data {
        state = (state ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    state
}

/// Write a BDW container.
pub fn write_bdw(path: impl AsRef<Path>, bdw: &Bdw) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(bdw.names.len() as u32).to_le_bytes());
    let mut csum = FNV_OFFSET;
    for name in &bdw.names {
        let t = &bdw.tensors[name];
        let nb = name.as_bytes();
        buf.extend_from_slice(&(nb.len() as u16).to_le_bytes());
        buf.extend_from_slice(nb);
        buf.push(t.dtype.id());
        buf.push(t.shape.len() as u8);
        for &d in &t.shape {
            buf.extend_from_slice(&(d as u32).to_le_bytes());
        }
        buf.extend_from_slice(&(t.bytes.len() as u64).to_le_bytes());
        buf.extend_from_slice(&t.bytes);
        csum = fnv1a(csum, &t.bytes);
    }
    buf.extend_from_slice(&csum.to_le_bytes());
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(&buf)?;
    Ok(())
}

/// Read and verify a BDW container.
pub fn read_bdw(path: impl AsRef<Path>) -> Result<Bdw> {
    let buf = std::fs::read(path.as_ref())
        .with_context(|| format!("reading {:?}", path.as_ref()))?;
    parse_bdw(&buf)
}

pub fn parse_bdw(buf: &[u8]) -> Result<Bdw> {
    if buf.len() < 20 || &buf[..4] != MAGIC {
        bail!("not a BDW1 container");
    }
    let version = u32::from_le_bytes(buf[4..8].try_into()?);
    if version != VERSION {
        bail!("unsupported BDW version {version}");
    }
    let count = u32::from_le_bytes(buf[8..12].try_into()?) as usize;
    let mut off = 12usize;
    let mut out = Bdw::new();
    let mut csum = FNV_OFFSET;

    let need = |off: usize, n: usize| -> Result<()> {
        if off + n > buf.len() {
            bail!("truncated BDW container at offset {off}");
        }
        Ok(())
    };

    for _ in 0..count {
        need(off, 2)?;
        let nlen = u16::from_le_bytes(buf[off..off + 2].try_into()?) as usize;
        off += 2;
        need(off, nlen)?;
        let name = std::str::from_utf8(&buf[off..off + nlen])
            .context("tensor name not utf-8")?.to_string();
        off += nlen;
        need(off, 2)?;
        let dtype = Dtype::from_id(buf[off])?;
        let ndim = buf[off + 1] as usize;
        off += 2;
        need(off, 4 * ndim)?;
        let mut shape = Vec::with_capacity(ndim);
        for i in 0..ndim {
            shape.push(u32::from_le_bytes(
                buf[off + 4 * i..off + 4 * i + 4].try_into()?) as usize);
        }
        off += 4 * ndim;
        need(off, 8)?;
        let size = u64::from_le_bytes(buf[off..off + 8].try_into()?) as usize;
        off += 8;
        need(off, size)?;
        let payload = buf[off..off + size].to_vec();
        off += size;
        let expect = shape.iter().product::<usize>() * dtype.size();
        if expect != size {
            bail!("tensor {name}: shape {shape:?} x {dtype:?} = {expect} \
bytes but payload is {size}");
        }
        csum = fnv1a(csum, &payload);
        out.insert(name, RawTensor { dtype, shape, bytes: payload });
    }
    need(off, 8)?;
    let want = u64::from_le_bytes(buf[off..off + 8].try_into()?);
    if csum != want {
        bail!("BDW checksum mismatch: computed {csum:#x}, stored {want:#x}");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Bdw {
        let mut b = Bdw::new();
        b.insert("w", RawTensor::f32(vec![2, 3],
                                     &[1.0, -2.0, 3.5, 0.0, 1e-9, -7.25]));
        b.insert("bits", RawTensor::u8(vec![4], vec![0xDE, 0xAD, 0xBE, 0xEF]));
        b
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("bdw_test_rt.bdw");
        let b = sample();
        write_bdw(&dir, &b).unwrap();
        let r = read_bdw(&dir).unwrap();
        assert_eq!(r.names, b.names);
        assert_eq!(r.get("w").unwrap(), b.get("w").unwrap());
        assert_eq!(r.get("bits").unwrap().as_u8().unwrap(),
                   &[0xDE, 0xAD, 0xBE, 0xEF]);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn corruption_detected() {
        let b = sample();
        let dir = std::env::temp_dir().join("bdw_test_corrupt.bdw");
        write_bdw(&dir, &b).unwrap();
        let mut buf = std::fs::read(&dir).unwrap();
        // flip a payload bit
        let n = buf.len();
        buf[n - 20] ^= 0x01;
        assert!(parse_bdw(&buf).is_err());
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn truncation_detected() {
        let b = sample();
        let dir = std::env::temp_dir().join("bdw_test_trunc.bdw");
        write_bdw(&dir, &b).unwrap();
        let buf = std::fs::read(&dir).unwrap();
        assert!(parse_bdw(&buf[..buf.len() - 9]).is_err());
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        assert!(parse_bdw(b"NOTBDW00000000000000").is_err());
    }

    #[test]
    fn f32_decode() {
        let t = RawTensor::f32(vec![3], &[1.0, 2.0, 3.0]);
        assert_eq!(t.as_f32().unwrap(), vec![1.0, 2.0, 3.0]);
        assert!(t.as_u8().is_err());
    }
}
