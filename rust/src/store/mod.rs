//! Artifact storage: the BDW tensor container and its delta/LoRA
//! interpretations.
//!
//! * [`bdw`] — reader/writer for the `BDW1` container (the python twin is
//!   `python/compile/serialize.py`; the two must agree bit-for-bit).
//! * [`delta_file`] — views a BDW container as a BitDelta delta
//!   (`bits.{level}.{linear}` / `scales.{level}` / `extra.{name}`) or a
//!   LoRA/SVD factor file (`lora_a.*` / `lora_b.*`).

pub mod bdw;
pub mod delta_file;
