//! Delta and LoRA views over BDW containers.
//!
//! A **delta file** (`.bdd`, produced by python's `write_delta` or rust's
//! [`crate::delta::bitdelta::compress`]) holds, per fidelity level `k`:
//! `scales.{k}` (f32 `[n_linears]`) and `bits.{k}.{linear}` (u8 packed
//! signs), plus per-tenant full-precision `extra.{name}` tensors.
//!
//! A **LoRA file** holds `lora_a.{linear}` (`[r, M]`) / `lora_b.{linear}`
//! (`[N, r]`) factors plus the same `extra.*` tensors.
//!
//! Containers written by this crate carry a **format tag** (a tiny
//! `__format__` tensor holding the codec name) so tooling can dispatch a
//! payload to its [`crate::delta::codec::DeltaCodec`] without guessing.
//! Files from the python build path predate the tag; [`detect_format`]
//! falls back to sniffing the tensor names, so both generations of
//! artifacts load identically.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::store::bdw::{read_bdw, Bdw, RawTensor};

/// Name of the format-tag tensor inside a BDW container.
pub const FORMAT_TAG: &str = "__format__";

/// Stamp a container with its delta-format name (u8 bytes of the name).
pub fn tag_format(bdw: &mut Bdw, format: &str) {
    bdw.insert(FORMAT_TAG.to_string(),
               RawTensor::u8(vec![format.len()],
                             format.as_bytes().to_vec()));
}

/// Read a container's format: the explicit tag when present, else a
/// name-based sniff (`scales.0` ⇒ bitdelta, `lora_a.*` ⇒ lora,
/// bare `tok_embed` ⇒ dense). `None` when the shape is unrecognisable.
pub fn detect_format(bdw: &Bdw) -> Option<String> {
    if bdw.contains(FORMAT_TAG) {
        let t = bdw.get(FORMAT_TAG).ok()?;
        return String::from_utf8(t.bytes.clone()).ok();
    }
    if bdw.contains("scales.0") {
        return Some("bitdelta".into());
    }
    if bdw.names.iter().any(|n| n.starts_with("lora_a.")) {
        return Some("lora".into());
    }
    if bdw.contains("tok_embed") {
        return Some("dense".into());
    }
    None
}

/// One 1-bit mask level: packed sign matrices + per-matrix scales.
#[derive(Debug, Clone)]
pub struct MaskLevel {
    /// `linear name -> packed u8 [N, M/8]`, row-major, LSB-first columns.
    pub bits: HashMap<String, Vec<u8>>,
    /// Scale α per linear, `linear_names()` order.
    pub scales: Vec<f32>,
}

/// A parsed BitDelta delta: ≥1 mask levels plus per-tenant extras.
#[derive(Debug, Clone)]
pub struct DeltaFile {
    pub levels: Vec<MaskLevel>,
    /// Full-precision per-tenant params (embeddings, norms, head).
    pub extras: HashMap<String, RawTensor>,
}

impl DeltaFile {
    pub fn load(path: impl AsRef<Path>, cfg: &ModelConfig) -> Result<Self> {
        Self::from_bdw(&read_bdw(path)?, cfg)
    }

    pub fn from_bdw(bdw: &Bdw, cfg: &ModelConfig) -> Result<Self> {
        if let Some(f) = detect_format(bdw) {
            if f != "bitdelta" {
                bail!("container is tagged {f:?}, not a bitdelta delta \
file");
            }
        }
        let lin = cfg.linear_names();
        let mut levels = Vec::new();
        for level in 0.. {
            let sname = format!("scales.{level}");
            if !bdw.contains(&sname) {
                break;
            }
            let scales = bdw.get(&sname)?.as_f32()?;
            if scales.len() != lin.len() {
                bail!("scales.{level} has {} entries, want {}",
                      scales.len(), lin.len());
            }
            let mut bits = HashMap::new();
            for name in &lin {
                let t = bdw.get(&format!("bits.{level}.{name}"))?;
                let (n, mp) = cfg.packed_shape(name);
                if t.shape != vec![n, mp] {
                    bail!("bits.{level}.{name}: shape {:?}, want [{n},{mp}]",
                          t.shape);
                }
                bits.insert(name.clone(), t.as_u8()?.to_vec());
            }
            levels.push(MaskLevel { bits, scales });
        }
        if levels.is_empty() {
            bail!("no mask levels in delta file");
        }
        let mut extras = HashMap::new();
        for name in &bdw.names {
            if let Some(stripped) = name.strip_prefix("extra.") {
                extras.insert(stripped.to_string(),
                              bdw.get(name)?.clone());
            }
        }
        for name in cfg.nonlinear_names() {
            if !extras.contains_key(&name) {
                bail!("delta file missing extra.{name}");
            }
        }
        Ok(Self { levels, extras })
    }

    /// Serialize back to a BDW container (rust-native compressor
    /// output), stamped with the `bitdelta` format tag.
    pub fn to_bdw(&self, cfg: &ModelConfig) -> Bdw {
        let mut bdw = Bdw::new();
        tag_format(&mut bdw, "bitdelta");
        for (level, m) in self.levels.iter().enumerate() {
            bdw.insert(format!("scales.{level}"),
                       RawTensor::f32(vec![m.scales.len()], &m.scales));
            for name in cfg.linear_names() {
                let (n, mp) = cfg.packed_shape(&name);
                bdw.insert(format!("bits.{level}.{name}"),
                           RawTensor::u8(vec![n, mp],
                                         m.bits[&name].clone()));
            }
        }
        let mut extra_names: Vec<&String> = self.extras.keys().collect();
        extra_names.sort();
        for name in extra_names {
            bdw.insert(format!("extra.{name}"), self.extras[name].clone());
        }
        bdw
    }

    /// Bytes this delta occupies (packed bits + scales + fp extras) — the
    /// Table 5 "Δ size" accounting.
    pub fn delta_bytes(&self) -> usize {
        let mask_bytes: usize = self.levels.iter().map(|l| {
            l.bits.values().map(|b| b.len()).sum::<usize>()
                + l.scales.len() * 4
        }).sum();
        let extra_bytes: usize =
            self.extras.values().map(|t| t.bytes.len()).sum();
        mask_bytes + extra_bytes
    }

    /// What [`Self::delta_bytes`] returns for a `levels`-level delta
    /// over `cfg`'s shapes with f32 extras — computable without
    /// touching the artifact, so placement can size a fidelity tier
    /// with zero startup I/O.
    pub fn delta_bytes_for(cfg: &ModelConfig, levels: usize) -> usize {
        let mask: usize = cfg.linear_names().iter().map(|n| {
            let (rows, mp) = cfg.packed_shape(n);
            rows * mp
        }).sum();
        let scales = cfg.linear_names().len() * 4;
        let extras: usize = cfg.nonlinear_names().iter()
            .map(|n| cfg.param_shape(n).iter().product::<usize>() * 4)
            .sum();
        levels.max(1) * (mask + scales) + extras
    }
}

/// A parsed LoRA / SVD-factor file (kernel ABI: delta = b_up @ a_down).
#[derive(Debug, Clone)]
pub struct LoraFile {
    pub rank: usize,
    /// `linear -> a_down [r, M]` row-major.
    pub a: HashMap<String, Vec<f32>>,
    /// `linear -> b_up [N, r]` row-major.
    pub b: HashMap<String, Vec<f32>>,
    pub extras: HashMap<String, RawTensor>,
}

impl LoraFile {
    pub fn load(path: impl AsRef<Path>, cfg: &ModelConfig) -> Result<Self> {
        let bdw = read_bdw(path)?;
        if let Some(f) = detect_format(&bdw) {
            if f != "lora" {
                bail!("container is tagged {f:?}, not a lora factor file");
            }
        }
        let lin = cfg.linear_names();
        let mut a = HashMap::new();
        let mut b = HashMap::new();
        let mut rank = 0usize;
        for name in &lin {
            let ta = bdw.get(&format!("lora_a.{name}"))?;
            let tb = bdw.get(&format!("lora_b.{name}"))?;
            let (n, m) = cfg.linear_shape(name);
            if ta.shape.len() != 2 || ta.shape[1] != m {
                bail!("lora_a.{name}: bad shape {:?}", ta.shape);
            }
            if tb.shape.len() != 2 || tb.shape[0] != n
                || tb.shape[1] != ta.shape[0] {
                bail!("lora_b.{name}: bad shape {:?}", tb.shape);
            }
            rank = ta.shape[0];
            a.insert(name.clone(), ta.as_f32()?);
            b.insert(name.clone(), tb.as_f32()?);
        }
        let mut extras = HashMap::new();
        for name in &bdw.names {
            if let Some(stripped) = name.strip_prefix("extra.") {
                extras.insert(stripped.to_string(), bdw.get(name)?.clone());
            }
        }
        Ok(Self { rank, a, b, extras })
    }

    pub fn delta_bytes(&self) -> usize {
        let fac: usize = self.a.values().chain(self.b.values())
            .map(|v| v.len() * 4).sum();
        let extra: usize = self.extras.values().map(|t| t.bytes.len()).sum();
        fac + extra
    }
}

/// Load a full-precision model BDW into `name -> RawTensor`, validating
/// every canonical parameter is present with the right shape.
pub fn load_model(path: impl AsRef<Path>, cfg: &ModelConfig)
                  -> Result<HashMap<String, RawTensor>> {
    let bdw = read_bdw(path.as_ref())?;
    let mut out = HashMap::new();
    for name in cfg.param_names() {
        let t = bdw.get(&name)
            .with_context(|| format!("model {:?}", path.as_ref()))?;
        let want = cfg.param_shape(&name);
        if t.shape != want {
            bail!("param {name}: shape {:?}, want {:?}", t.shape, want);
        }
        out.insert(name, t.clone());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::packing::pack_signs;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(), vocab_size: 16, d_model: 8, n_layers: 1,
            n_heads: 2, d_ff: 16, max_seq_len: 16,
            rope_theta: 1e4, norm_eps: 1e-5,
        }
    }

    fn tiny_delta(cfg: &ModelConfig) -> DeltaFile {
        let mut bits = HashMap::new();
        let mut scales = Vec::new();
        for (i, name) in cfg.linear_names().iter().enumerate() {
            let (n, m) = cfg.linear_shape(name);
            let vals: Vec<f32> = (0..n * m)
                .map(|j| if (i + j) % 3 == 0 { -1.0 } else { 1.0 }).collect();
            bits.insert(name.clone(), pack_signs(&vals, m));
            scales.push(0.01 * (i + 1) as f32);
        }
        let mut extras = HashMap::new();
        for name in cfg.nonlinear_names() {
            let shape = cfg.param_shape(&name);
            let n: usize = shape.iter().product();
            extras.insert(name,
                          RawTensor::f32(shape, &vec![0.5f32; n]));
        }
        DeltaFile { levels: vec![MaskLevel { bits, scales }], extras }
    }

    #[test]
    fn delta_roundtrip_via_bdw() {
        let cfg = tiny_cfg();
        let d = tiny_delta(&cfg);
        let bdw = d.to_bdw(&cfg);
        let d2 = DeltaFile::from_bdw(&bdw, &cfg).unwrap();
        assert_eq!(d2.levels.len(), 1);
        for name in cfg.linear_names() {
            assert_eq!(d.levels[0].bits[&name], d2.levels[0].bits[&name]);
        }
        assert_eq!(d.levels[0].scales, d2.levels[0].scales);
        assert_eq!(d.delta_bytes(), d2.delta_bytes());
    }

    #[test]
    fn delta_bytes_for_matches_loaded_accounting() {
        let cfg = tiny_cfg();
        let d = tiny_delta(&cfg);
        assert_eq!(DeltaFile::delta_bytes_for(&cfg, 1), d.delta_bytes());
        // each extra level adds exactly one mask plane + scale set
        let per_level = DeltaFile::delta_bytes_for(&cfg, 2)
            - DeltaFile::delta_bytes_for(&cfg, 1);
        assert_eq!(DeltaFile::delta_bytes_for(&cfg, 4),
                   d.delta_bytes() + 3 * per_level);
    }

    #[test]
    fn format_tag_written_and_detected() {
        let cfg = tiny_cfg();
        let bdw = tiny_delta(&cfg).to_bdw(&cfg);
        assert_eq!(detect_format(&bdw).as_deref(), Some("bitdelta"));
    }

    #[test]
    fn untagged_container_sniffed_by_names() {
        let cfg = tiny_cfg();
        let mut bdw = tiny_delta(&cfg).to_bdw(&cfg);
        // simulate a python-era file: strip the tag
        let pos = bdw.names.iter().position(|n| n == FORMAT_TAG).unwrap();
        bdw.names.remove(pos);
        bdw.tensors.remove(FORMAT_TAG);
        assert_eq!(detect_format(&bdw).as_deref(), Some("bitdelta"));
        assert!(DeltaFile::from_bdw(&bdw, &cfg).is_ok());
    }

    #[test]
    fn mismatched_tag_rejected_with_clear_error() {
        let cfg = tiny_cfg();
        let mut bdw = tiny_delta(&cfg).to_bdw(&cfg);
        bdw.tensors.insert(FORMAT_TAG.to_string(),
                           RawTensor::u8(vec![4], b"lora".to_vec()));
        let e = DeltaFile::from_bdw(&bdw, &cfg).unwrap_err().to_string();
        assert!(e.contains("lora"), "{e}");
    }

    #[test]
    fn missing_extra_rejected() {
        let cfg = tiny_cfg();
        let d = tiny_delta(&cfg);
        let mut bdw = d.to_bdw(&cfg);
        let pos = bdw.names.iter()
            .position(|n| n == "extra.tok_embed").unwrap();
        bdw.names.remove(pos);
        bdw.tensors.remove("extra.tok_embed");
        assert!(DeltaFile::from_bdw(&bdw, &cfg).is_err());
    }
}
