//! Analytical models of serving cost.
//!
//! * [`memory`] — exact byte accounting of multi-tenant serving
//!   (weights + deltas + KV cache + activations) against a device
//!   capacity. Regenerates **Table 5** (compression factors, on the real
//!   Llama-2/Mistral dims) and **Figure 5** (memory vs batch, naive OOM),
//!   and extends to clusters (`cluster_account`: N base copies + placed
//!   deltas, the cluster layer's memory story).
//! * [`latency`] — a bandwidth-roofline latency model that predicts the
//!   decode-latency crossovers of **Figures 4/6** from bytes moved,
//!   cross-checkable against the measured CPU kernels.

pub mod latency;
pub mod memory;
