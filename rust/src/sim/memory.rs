//! GPU-memory accounting simulator.
//!
//! The paper's Figure 5 and Table 5 are arithmetic over memory footprints;
//! we compute them *exactly* for the paper's real model shapes (Llama-2
//! 7B/13B/70B, Mistral-7B — specs below) and for our sim-* models,
//! predicting the OOM point of the naive baseline on a configurable
//! device (default: the paper's A100-80GB).


/// Transformer shape spec sufficient for byte accounting.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// KV heads (GQA); == n_heads for MHA models.
    pub n_kv_heads: usize,
    pub d_ff: usize,
    /// Gated MLP (SwiGLU) has 3 FF matrices, classic has 2.
    pub gated_mlp: bool,
    /// Bytes per weight in the dense model (2 = fp16 like the paper).
    pub w_bytes: usize,
}

impl ModelSpec {
    pub const fn llama2_7b() -> Self {
        Self { name: "Llama 2-7B", vocab: 32000, d_model: 4096,
               n_layers: 32, n_heads: 32, n_kv_heads: 32, d_ff: 11008,
               gated_mlp: true, w_bytes: 2 }
    }

    pub const fn llama2_13b() -> Self {
        Self { name: "Llama 2-13B", vocab: 32000, d_model: 5120,
               n_layers: 40, n_heads: 40, n_kv_heads: 40, d_ff: 13824,
               gated_mlp: true, w_bytes: 2 }
    }

    pub const fn llama2_70b() -> Self {
        Self { name: "Llama 2-70B", vocab: 32000, d_model: 8192,
               n_layers: 80, n_heads: 64, n_kv_heads: 8, d_ff: 28672,
               gated_mlp: true, w_bytes: 2 }
    }

    pub const fn mistral_7b() -> Self {
        Self { name: "Mistral-7B v0.1", vocab: 32000, d_model: 4096,
               n_layers: 32, n_heads: 32, n_kv_heads: 8, d_ff: 14336,
               gated_mlp: true, w_bytes: 2 }
    }

    pub fn from_config(cfg: &crate::config::ModelConfig) -> Self {
        // our sim models are MHA + SwiGLU, f32 weights
        Self {
            name: "sim", vocab: cfg.vocab_size, d_model: cfg.d_model,
            n_layers: cfg.n_layers, n_heads: cfg.n_heads,
            n_kv_heads: cfg.n_heads, d_ff: cfg.d_ff, gated_mlp: true,
            w_bytes: 4,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Parameters in the transformer-block linears (what BitDelta packs).
    pub fn linear_params(&self) -> usize {
        let attn = 2 * self.d_model * self.d_model          // wq, wo
            + 2 * self.d_model * (self.n_kv_heads * self.head_dim());
        let mlp_mats = if self.gated_mlp { 3 } else { 2 };
        let mlp = mlp_mats * self.d_model * self.d_ff;
        self.n_layers * (attn + mlp)
    }

    /// Parameters outside the linears (embeddings, norms, LM head) —
    /// full-precision in the delta too.
    pub fn extra_params(&self) -> usize {
        2 * self.vocab * self.d_model                       // embed + head
            + (2 * self.n_layers + 1) * self.d_model        // norms
    }

    pub fn total_params(&self) -> usize {
        self.linear_params() + self.extra_params()
    }

    /// Dense model bytes (Table 5 "Size").
    pub fn dense_bytes(&self) -> usize {
        self.total_params() * self.w_bytes
    }

    /// BitDelta delta bytes: 1 bit per linear weight + 1 fp scale per
    /// matrix + full-precision extras (Table 5 "Δ Size").
    pub fn delta_bytes(&self) -> usize {
        self.delta_bytes_levels(1)
    }

    /// Delta bytes at fidelity tier `k` (Fig. 3): `k` stacked 1-bit
    /// masks + `k` scale sets over the linears, one shared set of
    /// full-precision extras. Tier 1 is [`Self::delta_bytes`].
    pub fn delta_bytes_levels(&self, k: usize) -> usize {
        let mats_per_layer = if self.gated_mlp { 7 } else { 6 };
        k * (self.linear_params() / 8
             + self.n_layers * mats_per_layer * self.w_bytes)
            + self.extra_params() * self.w_bytes
    }

    /// Table 5 "Comp. Factor".
    pub fn compression_factor(&self) -> f64 {
        self.dense_bytes() as f64 / self.delta_bytes() as f64
    }

    /// Rank-r LoRA adapter bytes on every linear (S-LoRA comparator).
    pub fn lora_bytes(&self, rank: usize) -> usize {
        let attn = 2 * rank * (self.d_model + self.d_model)
            + 2 * rank * (self.d_model + self.n_kv_heads * self.head_dim());
        let mlp_mats = if self.gated_mlp { 3 } else { 2 };
        let mlp = mlp_mats * rank * (self.d_model + self.d_ff);
        (self.n_layers * (attn + mlp) + self.extra_params()) * self.w_bytes
    }

    /// KV-cache bytes for one sequence of length `seq`.
    pub fn kv_bytes(&self, seq: usize) -> usize {
        2 * self.n_layers * self.n_kv_heads * self.head_dim() * seq
            * self.w_bytes
    }

    /// Peak activation bytes for one decoding sequence (residual stream +
    /// the widest intermediate; small next to weights/KV).
    pub fn act_bytes(&self) -> usize {
        (self.d_model * 4 + self.d_ff * 2) * self.w_bytes
    }

    // ---- per-decode-step TRAFFIC (≠ storage): what the latency model
    // streams. The embedding table is a gather (one row), so only the
    // block linears + LM head move per step. The paper's Fig. 4/6 kernel
    // measurements cover the Eq. 6 linear decomposition; embeddings/head
    // are shared in that comparison (its footnote defers compressing
    // them), so the per-tenant delta stream is bits + scales only. ----

    /// Bytes a *dense* model streams per decode step (naive per-tenant).
    pub fn dense_traffic_bytes(&self) -> usize {
        let mats = if self.gated_mlp { 7 } else { 6 };
        self.linear_params() * self.w_bytes          // block linears
            + self.vocab * self.d_model * self.w_bytes   // LM head
            + (2 * self.n_layers + 1) * self.d_model * self.w_bytes
            + mats * 0
    }

    /// Bytes one 1-bit delta streams per decode step.
    pub fn delta_traffic_bytes(&self) -> usize {
        let mats = if self.gated_mlp { 7 } else { 6 };
        self.linear_params() / 8 + self.n_layers * mats * 4
    }

    /// Bytes one rank-r adapter streams per decode step.
    pub fn lora_traffic_bytes(&self, rank: usize) -> usize {
        let attn = 2 * rank * (self.d_model + self.d_model)
            + 2 * rank * (self.d_model + self.n_kv_heads * self.head_dim());
        let mlp_mats = if self.gated_mlp { 3 } else { 2 };
        let mlp = mlp_mats * rank * (self.d_model + self.d_ff);
        self.n_layers * (attn + mlp) * self.w_bytes
    }
}

/// Serving strategy whose footprint we account.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServingMode {
    /// B distinct fine-tuned models resident (the paper's naive baseline).
    Naive,
    /// One base + B 1-bit deltas (BitDelta).
    BitDelta,
    /// One base + B rank-r adapters (S-LoRA).
    Lora(usize),
}

/// One point of the Figure 5 curve.
#[derive(Debug, Clone)]
pub struct MemoryPoint {
    pub batch: usize,
    pub weight_bytes: usize,
    pub delta_bytes: usize,
    pub kv_bytes: usize,
    pub act_bytes: usize,
    pub total_bytes: usize,
    pub fits: bool,
}

/// Account serving `batch` tenants (one sequence each, length `seq`) on a
/// device with `capacity` bytes.
pub fn account(spec: &ModelSpec, mode: ServingMode, batch: usize,
               seq: usize, capacity: usize) -> MemoryPoint {
    let (weight_bytes, delta_bytes) = match mode {
        ServingMode::Naive => (spec.dense_bytes() * batch, 0),
        ServingMode::BitDelta => (spec.dense_bytes(),
                                  spec.delta_bytes() * batch),
        ServingMode::Lora(r) => (spec.dense_bytes(),
                                 spec.lora_bytes(r) * batch),
    };
    let kv_bytes = spec.kv_bytes(seq) * batch;
    let act_bytes = spec.act_bytes() * batch;
    let total = weight_bytes + delta_bytes + kv_bytes + act_bytes;
    MemoryPoint {
        batch, weight_bytes, delta_bytes, kv_bytes, act_bytes,
        total_bytes: total, fits: total <= capacity,
    }
}

/// A100-80GB, the paper's device.
pub const A100_80GB: usize = 80 * 1024 * 1024 * 1024;

/// Cluster-wide accounting for one serving mode: every worker holds
/// the deltas a placement put on it — and, in the shared-base modes,
/// its own full-precision copy of the base model. This is the number
/// the cluster layer's memory win rests on: scaling to N workers costs
/// N bases **once**, while tenants (and hot-tenant replicas) cost only
/// delta bytes.
#[derive(Debug, Clone)]
pub struct ClusterMemoryPoint {
    pub n_workers: usize,
    /// Total tenant replicas across workers (≥ tenant count when hot
    /// tenants are replicated).
    pub replicas: usize,
    pub weight_bytes: usize,
    pub delta_bytes: usize,
    pub kv_bytes: usize,
    pub act_bytes: usize,
    pub total_bytes: usize,
    pub per_worker_bytes: Vec<usize>,
    /// Every worker fits its device capacity.
    pub fits_all: bool,
}

/// Account a cluster: `replicas_per_worker[w]` tenant replicas are
/// placed on worker `w`, each worker decodes `seqs_per_worker`
/// concurrent sequences of length `seq` on a device with
/// `per_worker_capacity` bytes. Unlike [`account`], tenant residency
/// and batch width are decoupled — a worker can hold 32 deltas while
/// batching 8 sequences.
pub fn cluster_account(spec: &ModelSpec, mode: ServingMode,
                       replicas_per_worker: &[usize],
                       seqs_per_worker: usize, seq: usize,
                       per_worker_capacity: usize) -> ClusterMemoryPoint {
    let replicas = replicas_per_worker.iter().sum();
    let per_worker: Vec<(usize, usize)> = replicas_per_worker.iter()
        .map(|&k| match mode {
            // naive: every placed tenant is a full dense model
            ServingMode::Naive => (spec.dense_bytes() * k, 0),
            ServingMode::BitDelta => (spec.dense_bytes(),
                                      spec.delta_bytes() * k),
            ServingMode::Lora(r) => (spec.dense_bytes(),
                                     spec.lora_bytes(r) * k),
        }).collect();
    accumulate_cluster(spec, &per_worker, replicas, seqs_per_worker,
                       seq, per_worker_capacity)
}

/// Account a BitDelta cluster whose replicas sit at per-tenant
/// **fidelity tiers**: `levels_per_worker[w]` lists the mask level
/// count of every replica placed on worker `w` (one entry per replica).
/// Each extra level costs one more packed mask plane + scale set, so a
/// worker trading fidelity for packing shows up directly in its delta
/// bytes — the cluster-level face of the Fig. 3 tradeoff.
pub fn cluster_account_levels(spec: &ModelSpec,
                              levels_per_worker: &[Vec<usize>],
                              seqs_per_worker: usize, seq: usize,
                              per_worker_capacity: usize)
                              -> ClusterMemoryPoint {
    let replicas = levels_per_worker.iter().map(|l| l.len()).sum();
    let per_worker: Vec<(usize, usize)> = levels_per_worker.iter()
        .map(|levels| {
            let delta = levels.iter()
                .map(|&k| spec.delta_bytes_levels(k.max(1))).sum();
            (spec.dense_bytes(), delta)
        }).collect();
    accumulate_cluster(spec, &per_worker, replicas, seqs_per_worker,
                       seq, per_worker_capacity)
}

/// Shared accounting core: fold per-worker `(weight, delta)` byte pairs
/// plus the batch-driven KV/activation terms into a
/// [`ClusterMemoryPoint`].
fn accumulate_cluster(spec: &ModelSpec, per_worker: &[(usize, usize)],
                      replicas: usize, seqs_per_worker: usize,
                      seq: usize, per_worker_capacity: usize)
                      -> ClusterMemoryPoint {
    let mut point = ClusterMemoryPoint {
        n_workers: per_worker.len(),
        replicas,
        weight_bytes: 0,
        delta_bytes: 0,
        kv_bytes: 0,
        act_bytes: 0,
        total_bytes: 0,
        per_worker_bytes: Vec::with_capacity(per_worker.len()),
        fits_all: true,
    };
    for &(weight, delta) in per_worker {
        let kv = spec.kv_bytes(seq) * seqs_per_worker;
        let act = spec.act_bytes() * seqs_per_worker;
        let total = weight + delta + kv + act;
        point.weight_bytes += weight;
        point.delta_bytes += delta;
        point.kv_bytes += kv;
        point.act_bytes += act;
        point.total_bytes += total;
        point.per_worker_bytes.push(total);
        point.fits_all &= total <= per_worker_capacity;
    }
    point
}

/// Marginal memory price of one elastic scale-up, itemized. The
/// autoscaler's economics in one struct: the new worker pays a full
/// base-model copy (`base_bytes` — identical everywhere, nothing
/// tenant-specific moves) plus the 1-bit deltas re-placed onto it
/// (`delta_bytes`, ~1/16 of dense each) plus KV-cache/activations for
/// the sequences it will decode. For any realistic tenant count the
/// base copy dominates — which is exactly why BitDelta makes elastic
/// capacity cheap: tenants (and their replicas) ride along nearly
/// free once the base is paid for.
#[derive(Debug, Clone)]
pub struct ScaleUpCost {
    pub base_bytes: usize,
    pub delta_bytes: usize,
    pub kv_act_bytes: usize,
    pub total_bytes: usize,
}

/// Price scaling a BitDelta cluster from N to N+1 workers:
/// `replica_levels` lists the fidelity tier of every delta replica the
/// new worker will host (one entry per replica, tier ≥ 1), and the
/// worker decodes `seqs` concurrent sequences of length `seq`.
/// Consistent with [`cluster_account_levels`]: the returned total is
/// exactly that accounting's delta between the N- and (N+1)-worker
/// clusters.
pub fn scale_up_cost(spec: &ModelSpec, replica_levels: &[usize],
                     seqs: usize, seq: usize) -> ScaleUpCost {
    let base_bytes = spec.dense_bytes();
    let delta_bytes = replica_levels.iter()
        .map(|&k| spec.delta_bytes_levels(k.max(1))).sum();
    let kv_act_bytes =
        (spec.kv_bytes(seq) + spec.act_bytes()) * seqs;
    ScaleUpCost {
        base_bytes,
        delta_bytes,
        kv_act_bytes,
        total_bytes: base_bytes + delta_bytes + kv_act_bytes,
    }
}

/// Resident KV bytes for one worker's live sequences under the three
/// cache designs the serving layer can run: the dense slab fallback,
/// plain paging, and paging with shared-prefix reuse.
#[derive(Debug, Clone)]
pub struct PagedKvPoint {
    pub seqs: usize,
    pub block_size: usize,
    /// Dense slab: every sequence preallocates `max_seq` positions
    /// regardless of how many it uses.
    pub slab_bytes: usize,
    /// Paged: `ceil(len / block_size)` blocks per sequence.
    pub paged_bytes: usize,
    /// Paged + prefix sharing: the common prefix's whole blocks are
    /// resident **once**, not once per sequence.
    pub shared_bytes: usize,
}

impl PagedKvPoint {
    /// Slab-over-paged memory factor.
    pub fn paged_win(&self) -> f64 {
        self.slab_bytes as f64 / self.paged_bytes.max(1) as f64
    }

    /// Slab-over-(paged + shared prefix) memory factor.
    pub fn shared_win(&self) -> f64 {
        self.slab_bytes as f64 / self.shared_bytes.max(1) as f64
    }
}

/// Price the paged KV designs against the slab baseline: `seqs`
/// concurrent sequences of `mean_len` live tokens — of which the first
/// `shared_prefix_len` are a common system prompt — on a model whose
/// slab would preallocate `max_seq` positions per sequence. Only the
/// prefix's *whole* blocks are shareable (the engine's prefix index
/// registers block-aligned prefixes), and GQA models price per
/// [`ModelSpec::kv_bytes`], i.e. by `n_kv_heads`, which is what makes
/// 70B-scale KV paging arithmetic differ from 7B.
pub fn paged_kv_account(spec: &ModelSpec, seqs: usize, max_seq: usize,
                        mean_len: usize, shared_prefix_len: usize,
                        block_size: usize) -> PagedKvPoint {
    let bs = block_size.max(1);
    let mean_len = mean_len.min(max_seq);
    let shared = shared_prefix_len.min(mean_len);
    let block_bytes = spec.kv_bytes(bs);
    let blocks_per_seq = mean_len.div_ceil(bs);
    let shared_whole = shared / bs;
    PagedKvPoint {
        seqs,
        block_size: bs,
        slab_bytes: seqs * spec.kv_bytes(max_seq),
        paged_bytes: seqs * blocks_per_seq * block_bytes,
        shared_bytes: (shared_whole
                       + seqs * (blocks_per_seq - shared_whole))
            * block_bytes,
    }
}

/// Figure 5 series: memory vs batch for one mode.
pub fn figure5_series(spec: &ModelSpec, mode: ServingMode,
                      batches: &[usize], seq: usize, capacity: usize)
                      -> Vec<MemoryPoint> {
    batches.iter().map(|&b| account(spec, mode, b, seq, capacity)).collect()
}

/// First batch size at which the mode no longer fits (None = all fit).
pub fn oom_point(spec: &ModelSpec, mode: ServingMode, seq: usize,
                 capacity: usize, max_batch: usize) -> Option<usize> {
    (1..=max_batch).find(|&b| !account(spec, mode, b, seq, capacity).fits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_llama7b_matches_paper() {
        // Paper Table 5: Llama 2-7B = 13.48 GB dense, 1.24 GB delta,
        // 10.87x. Our accounting should land within a few percent.
        let spec = ModelSpec::llama2_7b();
        let gb = |b: usize| b as f64 / (1024.0 * 1024.0 * 1024.0);
        let dense = gb(spec.dense_bytes());
        let delta = gb(spec.delta_bytes());
        assert!((dense - 12.55).abs() < 1.2, "dense {dense} GB");
        assert!((delta - 1.2).abs() < 0.3, "delta {delta} GB");
        assert!(spec.compression_factor() > 10.0,
                "factor {}", spec.compression_factor());
    }

    #[test]
    fn table5_factor_grows_with_size() {
        // Paper: 10.87x (7B) -> 12.45x (13B) -> 15.41x (70B).
        let f7 = ModelSpec::llama2_7b().compression_factor();
        let f13 = ModelSpec::llama2_13b().compression_factor();
        let f70 = ModelSpec::llama2_70b().compression_factor();
        assert!(f7 < f13 && f13 < f70, "{f7} {f13} {f70}");
        assert!(f70 > 14.0, "70B factor {f70}");
    }

    #[test]
    fn param_count_sanity() {
        let p7 = ModelSpec::llama2_7b().total_params();
        assert!((p7 as f64 - 6.7e9).abs() < 0.3e9, "7B params {p7}");
        let p70 = ModelSpec::llama2_70b().total_params();
        assert!((p70 as f64 - 69e9).abs() < 3e9, "70B params {p70}");
    }

    #[test]
    fn naive_ooms_bitdelta_fits() {
        // Figure 5: naive Llama-2-7B OOMs on A100-80GB at modest batch;
        // BitDelta serves 32+ tenants.
        let spec = ModelSpec::llama2_7b();
        let naive = oom_point(&spec, ServingMode::Naive, 128,
                              A100_80GB, 64);
        let bitdelta = oom_point(&spec, ServingMode::BitDelta, 128,
                                 A100_80GB, 32);
        assert!(naive.is_some() && naive.unwrap() <= 8,
                "naive OOM at {naive:?}");
        // paper Fig. 5/6 sweep to B=32: BitDelta must fit everywhere
        assert!(bitdelta.is_none(), "bitdelta OOM at {bitdelta:?}");
    }

    #[test]
    fn memory_monotone_in_batch() {
        let spec = ModelSpec::llama2_7b();
        for mode in [ServingMode::Naive, ServingMode::BitDelta,
                     ServingMode::Lora(128)] {
            let pts = figure5_series(&spec, mode, &[1, 2, 4, 8, 16], 128,
                                     A100_80GB);
            for w in pts.windows(2) {
                assert!(w[1].total_bytes > w[0].total_bytes);
            }
        }
    }

    #[test]
    fn lora128_memory_equivalent_to_bitdelta() {
        // Paper: r=128 at N=M=4096 is the memory-equivalence point.
        let spec = ModelSpec::llama2_7b();
        let lora = spec.lora_bytes(128) as f64;
        let bd = spec.delta_bytes() as f64;
        let ratio = lora / bd;
        assert!(ratio > 0.5 && ratio < 2.0, "ratio {ratio}");
    }

    #[test]
    fn cluster_bitdelta_serves_tenants_naive_cannot() {
        // 4 workers × 8 tenants each (32 tenants), batch 8, A100s:
        // BitDelta fits every worker; dense-per-tenant does not fit any.
        let spec = ModelSpec::llama2_7b();
        let placed = [8usize, 8, 8, 8];
        let bd = cluster_account(&spec, ServingMode::BitDelta, &placed,
                                 8, 128, A100_80GB);
        let naive = cluster_account(&spec, ServingMode::Naive, &placed,
                                    8, 128, A100_80GB);
        assert!(bd.fits_all, "bitdelta cluster OOMs: {bd:?}");
        assert!(!naive.fits_all);
        // the cluster-wide memory win at equal tenant count
        assert!(naive.total_bytes as f64 / bd.total_bytes as f64 > 3.0,
                "win {:.2}", naive.total_bytes as f64
                / bd.total_bytes as f64);
    }

    #[test]
    fn cluster_replication_costs_delta_not_base() {
        // replicating one hot tenant onto every worker adds delta
        // bytes only — the base copies are already paid for
        let spec = ModelSpec::llama2_7b();
        let without = cluster_account(&spec, ServingMode::BitDelta,
                                      &[8, 8, 8, 8], 8, 128, A100_80GB);
        let with = cluster_account(&spec, ServingMode::BitDelta,
                                   &[8, 9, 9, 9], 8, 128, A100_80GB);
        let added = with.total_bytes - without.total_bytes;
        assert_eq!(added, 3 * spec.delta_bytes());
        // one 1-bit replica is >10x cheaper than one dense replica
        assert!(added / 3 * 10 < spec.dense_bytes(),
                "replica {} B vs dense {} B", added / 3,
                spec.dense_bytes());
        assert_eq!(with.replicas, without.replicas + 3);
    }

    #[test]
    fn cluster_point_decouples_tenancy_from_batch() {
        // 32 resident deltas but only 4 decoding sequences: KV cost
        // follows the batch, delta cost follows residency
        let spec = ModelSpec::llama2_7b();
        let p = cluster_account(&spec, ServingMode::BitDelta, &[32],
                                4, 128, A100_80GB);
        assert_eq!(p.delta_bytes, 32 * spec.delta_bytes());
        assert_eq!(p.kv_bytes, 4 * spec.kv_bytes(128));
        assert_eq!(p.n_workers, 1);
        assert_eq!(p.per_worker_bytes.len(), 1);
        assert_eq!(p.per_worker_bytes[0], p.total_bytes);
    }

    #[test]
    fn delta_bytes_levels_tier1_is_the_table5_size() {
        let spec = ModelSpec::llama2_7b();
        assert_eq!(spec.delta_bytes_levels(1), spec.delta_bytes());
        // masks/scales scale with k, the shared extras do not
        let per_level = spec.delta_bytes_levels(2)
            - spec.delta_bytes_levels(1);
        assert_eq!(spec.delta_bytes_levels(4),
                   spec.delta_bytes() + 3 * per_level);
        // even 4 mask planes stay far below one dense replica
        assert!(spec.delta_bytes_levels(4) * 3 < spec.dense_bytes());
    }

    #[test]
    fn cluster_levels_account_matches_uniform_tier1() {
        let spec = ModelSpec::llama2_7b();
        let uniform = cluster_account(&spec, ServingMode::BitDelta,
                                      &[3, 2], 4, 128, A100_80GB);
        let tiered = cluster_account_levels(
            &spec, &[vec![1, 1, 1], vec![1, 1]], 4, 128, A100_80GB);
        assert_eq!(tiered.total_bytes, uniform.total_bytes);
        assert_eq!(tiered.replicas, uniform.replicas);
        assert_eq!(tiered.per_worker_bytes, uniform.per_worker_bytes);
    }

    #[test]
    fn cluster_levels_price_fidelity_per_replica() {
        // raising one replica from tier 1 to tier 4 adds exactly three
        // mask planes of delta bytes on its worker, nothing else
        let spec = ModelSpec::llama2_7b();
        let lo = cluster_account_levels(&spec, &[vec![1, 1]], 4, 128,
                                        A100_80GB);
        let hi = cluster_account_levels(&spec, &[vec![1, 4]], 4, 128,
                                        A100_80GB);
        let per_level = spec.delta_bytes_levels(2)
            - spec.delta_bytes_levels(1);
        assert_eq!(hi.total_bytes - lo.total_bytes, 3 * per_level);
        assert_eq!(hi.weight_bytes, lo.weight_bytes);
        assert_eq!(hi.kv_bytes, lo.kv_bytes);
    }

    #[test]
    fn scale_up_cost_is_the_cluster_account_delta() {
        // pricing one more worker == the cluster accounting difference
        // between the N-worker and (N+1)-worker clusters
        let spec = ModelSpec::llama2_7b();
        let new_worker = vec![1usize, 2, 4];
        let before = cluster_account_levels(
            &spec, &[vec![1, 1]], 8, 128, A100_80GB);
        let after = cluster_account_levels(
            &spec, &[vec![1, 1], new_worker.clone()], 8, 128,
            A100_80GB);
        let cost = scale_up_cost(&spec, &new_worker, 8, 128);
        assert_eq!(cost.total_bytes,
                   after.total_bytes - before.total_bytes);
        assert_eq!(cost.base_bytes, spec.dense_bytes());
    }

    #[test]
    fn scale_up_cost_base_copy_dominates_deltas() {
        // the elasticity price is the base copy: 8 tier-1 delta
        // replicas on the new worker together cost less than the one
        // base — where the naive baseline would pay 8 more dense
        // models for the same worker
        let spec = ModelSpec::llama2_7b();
        let cost = scale_up_cost(&spec, &[1; 8], 8, 128);
        assert!(cost.delta_bytes < cost.base_bytes,
                "deltas {} vs base {}", cost.delta_bytes,
                cost.base_bytes);
        let naive_worker = 8 * spec.dense_bytes();
        assert!(cost.total_bytes * 3 < naive_worker,
                "elastic worker {} vs naive {}", cost.total_bytes,
                naive_worker);
        // zero-tenant scale-up still pays the base + kv/act
        let empty = scale_up_cost(&spec, &[], 8, 128);
        assert_eq!(empty.delta_bytes, 0);
        assert_eq!(empty.total_bytes,
                   empty.base_bytes + empty.kv_act_bytes);
    }

    #[test]
    fn paged_kv_prices_the_slab_overprovision() {
        // 7B scale, 32 sequences averaging 512 of a 4096-token slab:
        // paging alone reclaims the 8x preallocation
        let spec = ModelSpec::llama2_7b();
        let p = paged_kv_account(&spec, 32, 4096, 512, 0, 16);
        assert_eq!(p.slab_bytes, 32 * spec.kv_bytes(4096));
        assert_eq!(p.paged_bytes, 32 * 32 * spec.kv_bytes(16));
        assert!((p.paged_win() - 8.0).abs() < 1e-9, "{}", p.paged_win());
        // no shared prefix: the two paged designs price identically
        assert_eq!(p.shared_bytes, p.paged_bytes);
    }

    #[test]
    fn paged_kv_shared_prefix_is_resident_once() {
        // a 256-token system prompt shared by 32 sequences of 512:
        // its 16 whole blocks cost one residency, not 32
        let spec = ModelSpec::llama2_7b();
        let p = paged_kv_account(&spec, 32, 4096, 512, 256, 16);
        let block = spec.kv_bytes(16);
        assert_eq!(p.shared_bytes, (16 + 32 * (32 - 16)) * block);
        assert!(p.shared_win() > p.paged_win());
        // resident bytes grow sublinearly in sequence count: doubling
        // the fleet costs less than double (the prefix is paid once)
        let p2 = paged_kv_account(&spec, 64, 4096, 512, 256, 16);
        assert!(p2.shared_bytes < 2 * p.shared_bytes,
                "{} vs {}", p2.shared_bytes, 2 * p.shared_bytes);
        assert_eq!(p2.paged_bytes, 2 * p.paged_bytes);
    }

    #[test]
    fn paged_kv_prices_gqa_at_70b_scale() {
        // 70B has 8 KV heads against 7B's 32: per-token KV is priced
        // by n_kv_heads, so the same paging scenario costs 70B only
        // head_dim-scaled bytes, not n_heads-scaled
        let b7 = paged_kv_account(&ModelSpec::llama2_7b(),
                                  16, 4096, 512, 256, 16);
        let b70 = paged_kv_account(&ModelSpec::llama2_70b(),
                                   16, 4096, 512, 256, 16);
        let per_tok_7 = ModelSpec::llama2_7b().kv_bytes(1);
        let per_tok_70 = ModelSpec::llama2_70b().kv_bytes(1);
        assert_eq!(b70.shared_bytes * per_tok_7,
                   b7.shared_bytes * per_tok_70);
        // the memory *factors* are shape-independent ratios
        assert!((b70.shared_win() - b7.shared_win()).abs() < 1e-9);
    }

    #[test]
    fn paged_kv_partial_blocks_round_up_and_sub_block_prefix_rounds_down() {
        let spec = ModelSpec::llama2_7b();
        // 17 tokens at block 16 = 2 blocks; 15-token prefix shares 0
        let p = paged_kv_account(&spec, 4, 64, 17, 15, 16);
        assert_eq!(p.paged_bytes, 4 * 2 * spec.kv_bytes(16));
        assert_eq!(p.shared_bytes, p.paged_bytes,
                   "sub-block prefixes are not shareable");
    }

    #[test]
    fn backbone_dominates_single_delta() {
        // Paper §4.3: W_base has ~16x the footprint of one delta.
        let spec = ModelSpec::llama2_7b();
        let ratio = spec.dense_bytes() as f64 / spec.delta_bytes() as f64;
        assert!(ratio > 10.0);
    }
}
