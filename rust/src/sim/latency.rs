//! Bandwidth-roofline decode-latency model.
//!
//! LLM decode is memory-bound (paper §1, §3.3): the latency of one decode
//! step ≈ bytes-of-weights-touched / memory-bandwidth. This model predicts
//! the Figure 4/6 curves from the byte accounting in
//! [`crate::sim::memory`]; the measured CPU kernels
//! ([`crate::gemm`] benches) validate the *shape* empirically.

use super::memory::{ModelSpec, ServingMode};

/// One predicted latency point.
#[derive(Debug, Clone)]
pub struct LatencyPoint {
    pub batch: usize,
    /// Bytes streamed for the shared backbone (flat in batch).
    pub backbone_bytes: usize,
    /// Bytes streamed for the per-tenant terms (scales with batch).
    pub per_tenant_bytes: usize,
    /// Predicted step time in seconds at `bandwidth` bytes/s.
    pub step_seconds: f64,
    /// Per-user decode latency (the paper's headline metric).
    pub per_user_seconds: f64,
}

/// Predict one decode step for `batch` tenants.
///
/// `bandwidth`: device memory bandwidth in bytes/s (A100 ≈ 2.0e12).
pub fn predict(spec: &ModelSpec, mode: ServingMode, batch: usize,
               seq: usize, bandwidth: f64) -> LatencyPoint {
    let kv = spec.kv_bytes(seq) * batch;
    let (backbone, per_tenant) = match mode {
        // naive: every tenant streams a full dense model
        ServingMode::Naive => (0, spec.dense_traffic_bytes() * batch),
        ServingMode::BitDelta => (spec.dense_traffic_bytes(),
                                  spec.delta_traffic_bytes() * batch),
        ServingMode::Lora(r) => (spec.dense_traffic_bytes(),
                                 spec.lora_traffic_bytes(r) * batch),
    };
    let total = backbone + per_tenant + kv;
    let step = total as f64 / bandwidth;
    LatencyPoint {
        batch,
        backbone_bytes: backbone,
        per_tenant_bytes: per_tenant,
        step_seconds: step,
        per_user_seconds: step / batch.max(1) as f64,
    }
}

/// Figure 6 prediction: per-user latency ratio naive / bitdelta at a
/// given batch (paper: >10x at B >= 16).
pub fn naive_over_bitdelta(spec: &ModelSpec, batch: usize, seq: usize)
                           -> f64 {
    let bw = 2.0e12;
    let naive = predict(spec, ServingMode::Naive, batch, seq, bw);
    let bd = predict(spec, ServingMode::BitDelta, batch, seq, bw);
    naive.per_user_seconds / bd.per_user_seconds
}

/// Figure 4 crossover: smallest batch at which the combined per-tenant
/// delta traffic exceeds the shared backbone (paper: B ≈ 6-8 at fp16).
pub fn delta_crossover(spec: &ModelSpec, mode: ServingMode,
                       max_batch: usize) -> Option<usize> {
    (1..=max_batch).find(|&b| {
        let p = predict(spec, mode, b, 0, 1.0);
        p.per_tenant_bytes > p.backbone_bytes
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_headline_10x_in_b16_regime() {
        // Paper §4.3: ">10x lower per-user decoding latency in the
        // B >= 16 regime" (naive values projected — it OOMs there).
        let spec = ModelSpec::llama2_7b();
        let r16 = naive_over_bitdelta(&spec, 16, 128);
        let r32 = naive_over_bitdelta(&spec, 32, 128);
        assert!(r16 > 6.0, "per-user ratio at B=16: {r16}");
        assert!(r32 > 10.0, "per-user ratio at B=32: {r32}");
    }

    #[test]
    fn crossover_in_paper_band() {
        // Paper Fig. 4 (right): the combined delta term exceeds the
        // backbone around B = 6-8 *measured*; pure byte arithmetic puts
        // it at W_base/delta ≈ 16 (the paper's own "16x larger
        // footprint"), with real-kernel per-tenant overheads pulling the
        // measured crossover earlier. The analytic model must land in
        // [6, 17]; the measured CPU kernels (fig4 bench) carry the
        // empirical shape.
        let spec = ModelSpec::llama2_7b();
        let x = delta_crossover(&spec, ServingMode::BitDelta, 64).unwrap();
        assert!((6..=17).contains(&x), "crossover {x}");
    }

    #[test]
    fn backbone_flat_deltas_scale() {
        let spec = ModelSpec::llama2_7b();
        let p1 = predict(&spec, ServingMode::BitDelta, 1, 128, 2e12);
        let p8 = predict(&spec, ServingMode::BitDelta, 8, 128, 2e12);
        assert_eq!(p1.backbone_bytes, p8.backbone_bytes);
        assert_eq!(p8.per_tenant_bytes, 8 * p1.per_tenant_bytes);
    }

    #[test]
    fn naive_step_scales_linearly() {
        let spec = ModelSpec::llama2_7b();
        let p1 = predict(&spec, ServingMode::Naive, 1, 0, 2e12);
        let p4 = predict(&spec, ServingMode::Naive, 4, 0, 2e12);
        let ratio = p4.step_seconds / p1.step_seconds;
        assert!((ratio - 4.0).abs() < 0.01);
    }

    #[test]
    fn bitdelta_beats_naive_from_b2() {
        // Paper Fig. 6: BitDelta overtakes naive starting at B = 2.
        let spec = ModelSpec::llama2_7b();
        for b in 2..=32usize {
            let bw = 2e12;
            let n = predict(&spec, ServingMode::Naive, b, 128, bw);
            let d = predict(&spec, ServingMode::BitDelta, b, 128, bw);
            assert!(d.step_seconds < n.step_seconds, "b={b}");
        }
    }
}
