//! Synchronization shim: `std::sync` in production, `loom` under test.
//!
//! The concurrency core (cluster frontend/worker/autoscaler, admission
//! gate, the kernel worker pool, and the loom models that wrap
//! [`crate::kvcache::BlockPool`]) imports every lock, condvar, atomic,
//! and thread primitive from this module instead of `std`. Compiled
//! normally the re-exports are zero-cost aliases of the `std` types;
//! compiled with `RUSTFLAGS="--cfg loom"` they switch to the [`loom`]
//! model-checker equivalents so `tests/loom_models.rs` can explore
//! every interleaving of the load-bearing protocols exhaustively.
//!
//! House rules enforced by `cargo xtask lint` and `clippy.toml`:
//!
//! * migrated modules must not import `std::sync`/`std::thread`
//!   directly (the lint's `std-sync` rule) — exceptions carry a
//!   `// lint: allow(std-sync, ...)` marker (e.g. the `gemm::dispatch`
//!   global config cells, which must stay `const`-constructible and
//!   are deliberately *outside* every loom model);
//! * `std::thread::sleep` is a disallowed method repo-wide; pacing
//!   loops call [`thread::sleep`] here, which loom replaces with a
//!   yield so models stay schedulable.
//!
//! Two deliberate gaps, documented rather than papered over:
//!
//! * [`mpsc`] is always the `std` implementation — loom's channel
//!   model is incomplete, so loom models express channel protocols as
//!   a `Mutex<VecDeque>` (see `route_ordered_before_drain`), and no
//!   loom model may block on a real channel;
//! * [`OnceLock`] is always the `std` implementation — loom types are
//!   not const-constructible, so process-global config cells cannot be
//!   modeled and must never guard state a loom model checks.
//!
//! [`loom`]: https://docs.rs/loom

#[cfg(not(loom))]
pub use std::sync::{
    Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard,
    RwLockWriteGuard,
};

#[cfg(loom)]
pub use loom::sync::{
    Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard,
    RwLockWriteGuard,
};

/// Atomic types (`AtomicBool`, `AtomicUsize`, `Ordering`, ...).
#[cfg(not(loom))]
pub use std::sync::atomic;

#[cfg(loom)]
pub use loom::sync::atomic;

/// Always `std`: loom's channel model is incomplete. Loom models
/// express channel hand-off as an explicit `Mutex<VecDeque>` instead.
pub use std::sync::mpsc;

/// Always `std`: loom types cannot live in `static`s. Must only hold
/// process-global configuration, never state a loom model checks.
pub use std::sync::OnceLock;

/// Lock a mutex, treating poisoning as fatal.
///
/// House policy: a poisoned lock means another holder panicked halfway
/// through an invariant-carrying update (slot lifecycle, admission
/// counts, pool queue). Continuing would serve corrupted shared state,
/// so every production `lock()` goes through here and converts poison
/// into an immediate panic with a greppable message.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // lint: allow(expect, poisoning is fatal by policy — a holder
    // panicked mid-update and the guarded invariants cannot be trusted)
    m.lock().expect("poisoned lock: a holder panicked mid-update")
}

/// [`Condvar::wait`] with the same poison-is-fatal policy as [`lock`].
pub fn wait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>)
                   -> MutexGuard<'a, T> {
    // lint: allow(expect, poisoning is fatal by policy — see lock())
    cv.wait(guard).expect("poisoned lock: a holder panicked mid-update")
}

/// Thread primitives: `std::thread` in production, `loom::thread`
/// under `--cfg loom` (where `sleep` degrades to a yield and `Builder`
/// ignores thread names — loom models time-free, unnamed threads).
#[cfg(not(loom))]
pub mod thread {
    pub use std::thread::{
        available_parallelism, spawn, yield_now, Builder, JoinHandle,
    };

    /// The one blessed `sleep` call site (see `clippy.toml`): pacing
    /// and polling loops route through here so the loom build can
    /// replace blocking sleeps with scheduler yields.
    #[allow(clippy::disallowed_methods)]
    pub fn sleep(d: std::time::Duration) {
        std::thread::sleep(d);
    }
}

#[cfg(loom)]
pub mod thread {
    pub use loom::thread::{spawn, yield_now, JoinHandle};

    /// Loom models are time-free: a sleep is just a scheduling point.
    pub fn sleep(_d: std::time::Duration) {
        loom::thread::yield_now();
    }

    /// Loom has no named-thread builder; names are dropped.
    #[derive(Default)]
    pub struct Builder;

    impl Builder {
        pub fn new() -> Self {
            Builder
        }

        pub fn name(self, _name: String) -> Self {
            self
        }

        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            Ok(spawn(f))
        }
    }

    /// Loom models a fixed small thread set; report one core.
    pub fn available_parallelism()
        -> std::io::Result<std::num::NonZeroUsize> {
        Ok(std::num::NonZeroUsize::MIN)
    }
}

/// The time half of the shim: a process-global virtual clock.
///
/// Production code never reads `std::time::Instant` or calls a raw
/// sleep in the migrated cluster/coordinator modules (the lint's
/// `raw-time` rule); it calls [`clock::Instant::now`] and
/// [`clock::sleep`] instead. With no virtual clock installed both are
/// zero-cost aliases of wall time — one relaxed atomic load on the
/// fast path. Under an installed clock (see [`clock::install`]) time
/// is a `u64` nanosecond counter that only moves when a driver calls
/// [`clock::advance`], and sleeps park on a condvar until the counter
/// passes their deadline. This is the seam the deterministic
/// simulation harness ([`crate::simharness`]) drives: autoscaler
/// sampling, drain pacing, mock-core service time, and trace replay
/// all dilate together, so a scripted fault schedule plays out
/// identically regardless of machine load.
///
/// Semantics chosen for safety over cleverness:
///
/// * the virtual counter is **monotonic across installs** and never
///   resets, so an `Instant` captured under one installation stays
///   finite (frozen) after uninstall instead of dangling;
/// * `Instant::Real` values always measure real elapsed time even
///   while a virtual clock is installed (mixed-mode safe);
/// * dropping the install guard wakes every parked sleeper — the
///   remaining sleeps in the tree are pacing/polling loops that
///   re-check their condition, so an early return is harmless;
/// * [`clock::install`] holds a global mutex for the guard's
///   lifetime, serializing virtual-time tests against each other
///   under the parallel test harness.
///
/// The globals here use `std` primitives directly: like [`OnceLock`],
/// the clock is process-global configuration outside every loom model
/// (loom types cannot live in `static`s).
pub mod clock {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Condvar, Mutex, MutexGuard, OnceLock};
    use std::time::Duration;

    /// Fast-path mirror of `State::active`, so `Instant::now()` and
    /// `sleep()` cost one relaxed load when no clock is installed.
    static VIRTUAL: AtomicBool = AtomicBool::new(false);

    /// Serializes virtual-time tests: `install` holds this for the
    /// guard's lifetime. Survives poisoning (a panicking sim test must
    /// not cascade into every later one).
    static SERIAL: Mutex<()> = Mutex::new(());

    struct State {
        active: bool,
        now_nanos: u64,
        sleepers: usize,
    }

    struct VirtualClock {
        state: Mutex<State>,
        cv: Condvar,
    }

    fn global() -> &'static VirtualClock {
        static CLOCK: OnceLock<VirtualClock> = OnceLock::new();
        CLOCK.get_or_init(|| VirtualClock {
            state: Mutex::new(State {
                active: false,
                now_nanos: 0,
                sleepers: 0,
            }),
            cv: Condvar::new(),
        })
    }

    /// Clock state is a bool + two counters: nothing a panicking
    /// holder can half-update, so poisoning is survivable here (unlike
    /// [`super::lock`]'s fatal policy for invariant-carrying state).
    fn state(c: &VirtualClock) -> MutexGuard<'_, State> {
        c.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn nanos(d: Duration) -> u64 {
        u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
    }

    /// Is a virtual clock currently installed?
    pub fn is_virtual() -> bool {
        VIRTUAL.load(Ordering::Relaxed)
    }

    /// Install the virtual clock for the guard's lifetime. Blocks
    /// until any other holder (parallel test) releases it. Dropping
    /// the guard uninstalls the clock and wakes every parked sleeper.
    pub fn install() -> VirtualClockGuard {
        let serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        let c = global();
        state(c).active = true;
        VIRTUAL.store(true, Ordering::Relaxed);
        VirtualClockGuard { _serial: serial }
    }

    /// RAII handle returned by [`install`]; see there.
    pub struct VirtualClockGuard {
        _serial: MutexGuard<'static, ()>,
    }

    impl Drop for VirtualClockGuard {
        fn drop(&mut self) {
            let c = global();
            state(c).active = false;
            VIRTUAL.store(false, Ordering::Relaxed);
            c.cv.notify_all();
        }
    }

    /// Advance virtual time by `d` and wake sleepers whose deadlines
    /// passed. Only meaningful while a clock is installed; the counter
    /// moves regardless (it is monotonic and shared across installs).
    pub fn advance(d: Duration) {
        let c = global();
        let mut st = state(c);
        st.now_nanos = st.now_nanos.saturating_add(nanos(d));
        drop(st);
        c.cv.notify_all();
    }

    /// Threads currently parked in [`sleep`] on the virtual clock.
    /// Drivers use this to wait until workers are quiescent before
    /// advancing, making wake-ups deterministic.
    pub fn sleepers() -> usize {
        state(global()).sleepers
    }

    /// The current virtual time as an offset from process start.
    pub fn virtual_now() -> Duration {
        Duration::from_nanos(state(global()).now_nanos)
    }

    /// Drop-in for `std::time::Instant` in migrated modules: real wall
    /// time normally, a virtual timestamp under an installed clock.
    #[derive(Clone, Copy, Debug)]
    pub enum Instant {
        Real(std::time::Instant),
        Virtual(u64),
    }

    impl Instant {
        pub fn now() -> Self {
            if VIRTUAL.load(Ordering::Relaxed) {
                Instant::Virtual(state(global()).now_nanos)
            } else {
                Instant::Real(std::time::Instant::now())
            }
        }

        /// Real instants always measure real elapsed time (even under
        /// an installed clock); virtual instants measure the distance
        /// the virtual counter has moved, which freezes (stays finite)
        /// once the clock is uninstalled.
        pub fn elapsed(&self) -> Duration {
            match self {
                Instant::Real(t) => t.elapsed(),
                Instant::Virtual(t0) => Duration::from_nanos(
                    state(global()).now_nanos.saturating_sub(*t0),
                ),
            }
        }
    }

    /// Drop-in for `thread::sleep` in migrated modules: a real sleep
    /// normally; under an installed clock, parks until virtual time
    /// passes the deadline (or the clock is uninstalled — pacing
    /// loops re-check their condition, so early return is safe).
    pub fn sleep(d: Duration) {
        if !VIRTUAL.load(Ordering::Relaxed) {
            return super::thread::sleep(d);
        }
        let c = global();
        let mut st = state(c);
        if !st.active {
            drop(st);
            return super::thread::sleep(d);
        }
        let deadline = st.now_nanos.saturating_add(nanos(d));
        st.sleepers += 1;
        while st.active && st.now_nanos < deadline {
            st = c.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.sleepers -= 1;
        drop(st);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_and_wait_round_trip() {
        let m = Mutex::new(7usize);
        *lock(&m) += 1;
        assert_eq!(*lock(&m), 8);
    }

    #[test]
    fn shim_thread_spawn_and_sleep() {
        let h = thread::spawn(|| {
            thread::sleep(std::time::Duration::from_millis(1));
            42
        });
        assert_eq!(h.join().expect("join"), 42);
    }

    #[test]
    fn clock_is_real_time_when_not_installed() {
        use std::time::Duration;
        assert!(!clock::is_virtual());
        let t0 = clock::Instant::now();
        clock::sleep(Duration::from_millis(1));
        assert!(t0.elapsed() >= Duration::from_millis(1));
    }

    #[test]
    fn virtual_sleep_wakes_exactly_on_advance() {
        use std::time::Duration;
        let guard = clock::install();
        assert!(clock::is_virtual());
        let t0 = clock::Instant::now();
        let h = thread::spawn(|| {
            let s0 = clock::Instant::now();
            clock::sleep(Duration::from_millis(5));
            s0.elapsed()
        });
        // wait for the sleeper to park, then move time exactly 5ms
        while clock::sleepers() == 0 {
            thread::yield_now();
        }
        clock::advance(Duration::from_millis(5));
        let slept = h.join().expect("sleeper");
        assert_eq!(slept, Duration::from_millis(5));
        assert_eq!(t0.elapsed(), Duration::from_millis(5));
        drop(guard);
        assert!(!clock::is_virtual());
    }

    #[test]
    fn uninstall_wakes_parked_sleepers() {
        use std::time::Duration;
        let guard = clock::install();
        let h = thread::spawn(|| {
            clock::sleep(Duration::from_secs(3600));
        });
        while clock::sleepers() == 0 {
            thread::yield_now();
        }
        drop(guard); // must wake the hour-long virtual sleep
        h.join().expect("sleeper woke on uninstall");
    }
}
