//! Synchronization shim: `std::sync` in production, `loom` under test.
//!
//! The concurrency core (cluster frontend/worker/autoscaler, admission
//! gate, the kernel worker pool, and the loom models that wrap
//! [`crate::kvcache::BlockPool`]) imports every lock, condvar, atomic,
//! and thread primitive from this module instead of `std`. Compiled
//! normally the re-exports are zero-cost aliases of the `std` types;
//! compiled with `RUSTFLAGS="--cfg loom"` they switch to the [`loom`]
//! model-checker equivalents so `tests/loom_models.rs` can explore
//! every interleaving of the load-bearing protocols exhaustively.
//!
//! House rules enforced by `cargo xtask lint` and `clippy.toml`:
//!
//! * migrated modules must not import `std::sync`/`std::thread`
//!   directly (the lint's `std-sync` rule) — exceptions carry a
//!   `// lint: allow(std-sync, ...)` marker (e.g. the `gemm::dispatch`
//!   global config cells, which must stay `const`-constructible and
//!   are deliberately *outside* every loom model);
//! * `std::thread::sleep` is a disallowed method repo-wide; pacing
//!   loops call [`thread::sleep`] here, which loom replaces with a
//!   yield so models stay schedulable.
//!
//! Two deliberate gaps, documented rather than papered over:
//!
//! * [`mpsc`] is always the `std` implementation — loom's channel
//!   model is incomplete, so loom models express channel protocols as
//!   a `Mutex<VecDeque>` (see `route_ordered_before_drain`), and no
//!   loom model may block on a real channel;
//! * [`OnceLock`] is always the `std` implementation — loom types are
//!   not const-constructible, so process-global config cells cannot be
//!   modeled and must never guard state a loom model checks.
//!
//! [`loom`]: https://docs.rs/loom

#[cfg(not(loom))]
pub use std::sync::{
    Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard,
    RwLockWriteGuard,
};

#[cfg(loom)]
pub use loom::sync::{
    Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard,
    RwLockWriteGuard,
};

/// Atomic types (`AtomicBool`, `AtomicUsize`, `Ordering`, ...).
#[cfg(not(loom))]
pub use std::sync::atomic;

#[cfg(loom)]
pub use loom::sync::atomic;

/// Always `std`: loom's channel model is incomplete. Loom models
/// express channel hand-off as an explicit `Mutex<VecDeque>` instead.
pub use std::sync::mpsc;

/// Always `std`: loom types cannot live in `static`s. Must only hold
/// process-global configuration, never state a loom model checks.
pub use std::sync::OnceLock;

/// Lock a mutex, treating poisoning as fatal.
///
/// House policy: a poisoned lock means another holder panicked halfway
/// through an invariant-carrying update (slot lifecycle, admission
/// counts, pool queue). Continuing would serve corrupted shared state,
/// so every production `lock()` goes through here and converts poison
/// into an immediate panic with a greppable message.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // lint: allow(expect, poisoning is fatal by policy — a holder
    // panicked mid-update and the guarded invariants cannot be trusted)
    m.lock().expect("poisoned lock: a holder panicked mid-update")
}

/// [`Condvar::wait`] with the same poison-is-fatal policy as [`lock`].
pub fn wait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>)
                   -> MutexGuard<'a, T> {
    // lint: allow(expect, poisoning is fatal by policy — see lock())
    cv.wait(guard).expect("poisoned lock: a holder panicked mid-update")
}

/// Thread primitives: `std::thread` in production, `loom::thread`
/// under `--cfg loom` (where `sleep` degrades to a yield and `Builder`
/// ignores thread names — loom models time-free, unnamed threads).
#[cfg(not(loom))]
pub mod thread {
    pub use std::thread::{
        available_parallelism, spawn, yield_now, Builder, JoinHandle,
    };

    /// The one blessed `sleep` call site (see `clippy.toml`): pacing
    /// and polling loops route through here so the loom build can
    /// replace blocking sleeps with scheduler yields.
    #[allow(clippy::disallowed_methods)]
    pub fn sleep(d: std::time::Duration) {
        std::thread::sleep(d);
    }
}

#[cfg(loom)]
pub mod thread {
    pub use loom::thread::{spawn, yield_now, JoinHandle};

    /// Loom models are time-free: a sleep is just a scheduling point.
    pub fn sleep(_d: std::time::Duration) {
        loom::thread::yield_now();
    }

    /// Loom has no named-thread builder; names are dropped.
    #[derive(Default)]
    pub struct Builder;

    impl Builder {
        pub fn new() -> Self {
            Builder
        }

        pub fn name(self, _name: String) -> Self {
            self
        }

        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            Ok(spawn(f))
        }
    }

    /// Loom models a fixed small thread set; report one core.
    pub fn available_parallelism()
        -> std::io::Result<std::num::NonZeroUsize> {
        Ok(std::num::NonZeroUsize::MIN)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_and_wait_round_trip() {
        let m = Mutex::new(7usize);
        *lock(&m) += 1;
        assert_eq!(*lock(&m), 8);
    }

    #[test]
    fn shim_thread_spawn_and_sleep() {
        let h = thread::spawn(|| {
            thread::sleep(std::time::Duration::from_millis(1));
            42
        });
        assert_eq!(h.join().expect("join"), 42);
    }
}
