//! The cluster serving layer — an elastic set of worker engines,
//! delta-aware tenant placement, failover, autoscaling, and
//! cluster-level admission control.
//!
//! BitDelta's economics at scale: the base model is the expensive
//! artifact and it is **identical on every worker**, so scaling out is
//! "spawn another engine thread and re-place some ~1/16-cost deltas" —
//! not "copy another model". This module is that scaling substrate:
//!
//! * [`worker`]     — one engine pinned to one OS thread behind a
//!   command channel; the pump loop shared with the single-engine
//!   [`crate::serving::service::ServingService`], written against the
//!   [`worker::WorkerCore`] trait so scheduling and failover are
//!   testable without artifacts.
//! * [`placement`]  — the [`placement::PlacementPolicy`] trait and the
//!   three built-ins: `affinity` (stable hashing), `least-loaded`
//!   (live queue depth), `delta-aware` (bin-pack per-codec
//!   `resident_bytes` against worker delta budgets, replicate hot
//!   tenants under skew).
//! * [`frontend`]   — [`Cluster`] / [`ClusterHandle`]: spawn, route,
//!   failover (dead workers' tenants re-placed, in-flight requests
//!   errored, never hung), **elastic scale events**
//!   ([`ClusterHandle::spawn_worker`] /
//!   [`ClusterHandle::retire_worker`] — the latter a graceful drain
//!   that completes in-flight work with zero errors), and the
//!   cluster-front-door admission gate (global in-flight budget,
//!   per-tenant fairness, typed rejections).
//! * [`autoscaler`] — the control loop that drives those scale events
//!   from the live load signals workers publish: sustained-pressure
//!   scale-up, sustained-idle scale-down, `min..max` bounds,
//!   cooldown hysteresis.
//! * [`metrics`]    — per-worker relabeling + cluster rollup of the
//!   Prometheus-style expositions (scale events, drain durations and
//!   admission rejections ride in the cluster section).
//!
//! Adding a placement policy mirrors adding a codec: implement
//! [`placement::PlacementPolicy`], add one arm to
//! [`placement::policy_by_name`].

pub mod autoscaler;
pub mod frontend;
pub mod metrics;
pub mod placement;
pub mod worker;

// not cfg(test): the deterministic simulation harness
// (crate::simharness) drives real clusters over these mock cores
pub(crate) mod testutil;

pub use autoscaler::{
    Autoscaler, AutoscalerConfig, ScaleDecision, ScalingModel,
};
pub use frontend::{
    apply_trace_weights, replay_trace, tenant_profiles, Cluster,
    ClusterConfig, ClusterHandle, ClusterTicket, ReplayReport,
    RoutingSnapshot, WorkerFactoryFn, WorkerState,
};
pub use placement::{
    policy_by_name, Placement, PlacementPolicy, RouteError, TenantProfile,
    WorkerSpec,
};
pub use worker::{CoreFactory, WorkerCore, WorkerHandle, WorkerLoad};
