//! Tenant → worker placement policies.
//!
//! BitDelta turns multi-tenant packing on its head: the expensive
//! artifact (the base model) is identical on every worker, so the only
//! per-worker residency constraint is **delta bytes** — and a 1-bit
//! delta is ~1/16 the size of a dense fine-tune, which makes replicating
//! a hot tenant across workers nearly free. A [`PlacementPolicy`]
//! decides two things:
//!
//! * **place** — which workers hold which tenants' deltas (computed at
//!   cluster start and again after a worker dies);
//! * **route** — which of a tenant's replicas serves one request (called
//!   per request, reading live load lock-free).
//!
//! Three built-ins: [`AffinityPolicy`] (stable hashing, maximal delta
//! locality), [`LeastLoadedPolicy`] (every tenant everywhere, route by
//! live queue depth), and [`DeltaAwarePolicy`] (bin-pack by per-codec
//! `resident_bytes` against each worker's delta budget, replicating hot
//! tenants when the traffic skew justifies it). New policies implement
//! the trait — the same extension recipe as the codec registry.

use std::cmp::Ordering;
use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Result};

/// What the placer knows about one tenant.
#[derive(Debug, Clone)]
pub struct TenantProfile {
    pub name: String,
    /// Registry name of the tenant's delta codec.
    pub codec: String,
    /// Host bytes the tenant's delta occupies while resident — the
    /// packing constraint (per-codec: a 1-bit delta is ~1/16 of dense,
    /// and a `levels`-tier bitdelta tenant costs `levels` mask planes).
    pub resident_bytes: usize,
    /// Expected share of traffic, summing to ~1.0 across tenants.
    pub weight: f64,
    /// Fidelity tier (mask level count) the tenant is served at; scales
    /// `resident_bytes`, making fidelity-vs-packing a placement
    /// tradeoff.
    pub levels: usize,
}

/// Per-worker placement input.
#[derive(Debug, Clone)]
pub struct WorkerSpec {
    /// Stable worker index (survives other workers dying).
    pub index: usize,
    /// Delta residency budget of this worker's store, bytes.
    pub delta_budget_bytes: usize,
}

/// The result of a placement round: tenant → workers holding its delta.
#[derive(Debug, Clone, Default)]
pub struct Placement {
    of: HashMap<String, Vec<usize>>,
    bytes: HashMap<usize, usize>,
}

impl Placement {
    pub fn add(&mut self, tenant: &str, worker: usize, bytes: usize) {
        let ws = self.of.entry(tenant.to_string()).or_default();
        if !ws.contains(&worker) {
            ws.push(worker);
            *self.bytes.entry(worker).or_default() += bytes;
        }
    }

    /// Workers holding this tenant's delta (empty if unknown).
    pub fn workers_of(&self, tenant: &str) -> &[usize] {
        self.of.get(tenant).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn replica_count(&self, tenant: &str) -> usize {
        self.workers_of(tenant).len()
    }

    /// Delta bytes placed on one worker.
    pub fn placed_bytes(&self, worker: usize) -> usize {
        self.bytes.get(&worker).copied().unwrap_or(0)
    }

    /// Tenant replica count per worker index (for the memory model).
    pub fn replicas_per_worker(&self, n_workers: usize) -> Vec<usize> {
        let mut out = vec![0usize; n_workers];
        for ws in self.of.values() {
            for &w in ws {
                if w < n_workers {
                    out[w] += 1;
                }
            }
        }
        out
    }

    pub fn tenants(&self) -> impl Iterator<Item = &String> {
        self.of.keys()
    }
}

/// Live per-worker load, as routing sees it.
pub trait LoadView {
    /// Outstanding work on a worker (queued + batched + in flight).
    fn score(&self, worker: usize) -> usize;
}

/// Static load view for tests and offline planning.
impl LoadView for &[usize] {
    fn score(&self, worker: usize) -> usize {
        self.get(worker).copied().unwrap_or(0)
    }
}

/// Typed routing failure. Reachable in production: a failover
/// re-placement race can momentarily leave a tenant's replica set
/// empty, and the frontend must surface that as a request error — never
/// a panic in the worker-routing path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RouteError {
    /// The tenant has no live replica to route to.
    NoCandidates { tenant: String },
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::NoCandidates { tenant } => write!(
                f, "no routable worker for tenant {tenant:?} (empty \
replica set — mid-failover re-placement?)"),
        }
    }
}

impl std::error::Error for RouteError {}

/// A placement policy: how tenants spread over workers, and which
/// replica serves a request. `Send + Sync` so one policy instance is
/// shared by every routing thread.
pub trait PlacementPolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Map every tenant to ≥ 1 worker. `workers` lists the live workers
    /// and their delta budgets; an error means the tenants cannot be
    /// placed (e.g. a delta larger than every remaining budget).
    fn place(&self, tenants: &[TenantProfile], workers: &[WorkerSpec])
             -> Result<Placement>;

    /// Pick one of `candidates` (all alive) for a request. An empty
    /// candidate set is a [`RouteError`], not a panic — it is reachable
    /// during failover re-placement races.
    fn route(&self, tenant: &str, candidates: &[usize],
             loads: &dyn LoadView) -> Result<usize, RouteError>;
}

/// FNV-1a — a stable tenant hash (must not vary across runs or hosts,
/// unlike `DefaultHasher`).
pub fn stable_hash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Look a policy up by CLI name.
pub fn policy_by_name(name: &str)
                      -> Result<Arc<dyn PlacementPolicy>> {
    match name {
        "affinity" => Ok(Arc::new(AffinityPolicy)),
        "least-loaded" | "least_loaded" => Ok(Arc::new(LeastLoadedPolicy)),
        "delta-aware" | "delta_aware" => {
            Ok(Arc::new(DeltaAwarePolicy::default()))
        }
        other => bail!("unknown placement policy {other:?} — available: \
affinity, least-loaded, delta-aware"),
    }
}

fn min_score(tenant: &str, candidates: &[usize], loads: &dyn LoadView)
             -> Result<usize, RouteError> {
    candidates.iter()
        .min_by_key(|&&w| (loads.score(w), w))
        .copied()
        .ok_or_else(|| RouteError::NoCandidates {
            tenant: tenant.to_string(),
        })
}

// ---------------------------------------------------------------------
// affinity
// ---------------------------------------------------------------------

/// Stable tenant→worker hashing: every tenant has exactly one home, so
/// each worker's delta store sees a disjoint tenant set (maximal
/// hot-swap locality, zero routing state). Ignores budgets and load —
/// the classic sticky-session baseline.
pub struct AffinityPolicy;

impl PlacementPolicy for AffinityPolicy {
    fn name(&self) -> &'static str {
        "affinity"
    }

    fn place(&self, tenants: &[TenantProfile], workers: &[WorkerSpec])
             -> Result<Placement> {
        if workers.is_empty() {
            bail!("affinity placement over zero workers");
        }
        let mut p = Placement::default();
        for t in tenants {
            let slot = stable_hash(&t.name) as usize % workers.len();
            p.add(&t.name, workers[slot].index, t.resident_bytes);
        }
        Ok(p)
    }

    fn route(&self, tenant: &str, candidates: &[usize],
             _loads: &dyn LoadView) -> Result<usize, RouteError> {
        if candidates.is_empty() {
            return Err(RouteError::NoCandidates {
                tenant: tenant.to_string(),
            });
        }
        Ok(candidates[stable_hash(tenant) as usize % candidates.len()])
    }
}

// ---------------------------------------------------------------------
// least-loaded
// ---------------------------------------------------------------------

/// Every tenant is servable on every worker (each engine registers the
/// whole tenant set anyway); requests chase the shortest live queue.
/// Maximal load balance, minimal delta locality — each worker's store
/// may end up holding every delta, so this wants generous budgets.
pub struct LeastLoadedPolicy;

impl PlacementPolicy for LeastLoadedPolicy {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn place(&self, tenants: &[TenantProfile], workers: &[WorkerSpec])
             -> Result<Placement> {
        if workers.is_empty() {
            bail!("least-loaded placement over zero workers");
        }
        let mut p = Placement::default();
        for t in tenants {
            for w in workers {
                p.add(&t.name, w.index, t.resident_bytes);
            }
        }
        Ok(p)
    }

    fn route(&self, tenant: &str, candidates: &[usize],
             loads: &dyn LoadView) -> Result<usize, RouteError> {
        min_score(tenant, candidates, loads)
    }
}

// ---------------------------------------------------------------------
// delta-aware
// ---------------------------------------------------------------------

/// Bin-pack tenants by `resident_bytes` against each worker's delta
/// budget (first-fit-decreasing onto the emptiest worker), then give
/// hot tenants extra replicas while budget remains: a tenant with
/// traffic share `w` on an `N`-worker cluster gets `ceil(w·N)` replicas
/// (so uniform traffic stays single-homed and a 50%-share tenant on
/// four workers gets two). Replication is priced in delta bytes, which
/// is the paper's point — a 1-bit replica is ~1/16 the cost of a dense
/// one, so skewed traffic can be spread where the naive baseline
/// could not afford to.
#[derive(Debug, Clone, Default)]
pub struct DeltaAwarePolicy;

impl PlacementPolicy for DeltaAwarePolicy {
    fn name(&self) -> &'static str {
        "delta-aware"
    }

    fn place(&self, tenants: &[TenantProfile], workers: &[WorkerSpec])
             -> Result<Placement> {
        if workers.is_empty() {
            bail!("delta-aware placement over zero workers");
        }
        // (worker index, remaining budget)
        let mut remaining: Vec<(usize, usize)> = workers.iter()
            .map(|w| (w.index, w.delta_budget_bytes)).collect();
        let mut p = Placement::default();

        // primary copies: largest delta first, onto the emptiest fit
        let mut order: Vec<&TenantProfile> = tenants.iter().collect();
        order.sort_by(|a, b| {
            b.resident_bytes.cmp(&a.resident_bytes)
                .then_with(|| a.name.cmp(&b.name))
        });
        for t in &order {
            match remaining.iter_mut()
                .filter(|(_, rem)| *rem >= t.resident_bytes)
                .max_by_key(|&&mut (i, rem)| (rem, usize::MAX - i)) {
                Some(slot) => {
                    slot.1 -= t.resident_bytes;
                    p.add(&t.name, slot.0, t.resident_bytes);
                }
                None => bail!(
                    "tenant {} ({} B, codec {}) fits no worker's \
remaining delta budget", t.name, t.resident_bytes, t.codec),
            }
        }

        // replicas: hottest first, while the skew wants them and budget
        // remains (best-effort — running out is not an error)
        let n = workers.len();
        let mut hot: Vec<&TenantProfile> = tenants.iter().collect();
        hot.sort_by(|a, b| {
            b.weight.partial_cmp(&a.weight).unwrap_or(Ordering::Equal)
                .then_with(|| a.name.cmp(&b.name))
        });
        for t in &hot {
            let want = ((t.weight * n as f64).ceil() as usize).clamp(1, n);
            while p.replica_count(&t.name) < want {
                let holders = p.workers_of(&t.name).to_vec();
                match remaining.iter_mut()
                    .filter(|(i, rem)| *rem >= t.resident_bytes
                            && !holders.contains(i))
                    .max_by_key(|&&mut (i, rem)| (rem, usize::MAX - i)) {
                    Some(slot) => {
                        slot.1 -= t.resident_bytes;
                        p.add(&t.name, slot.0, t.resident_bytes);
                    }
                    None => break,
                }
            }
        }
        Ok(p)
    }

    fn route(&self, tenant: &str, candidates: &[usize],
             loads: &dyn LoadView) -> Result<usize, RouteError> {
        min_score(tenant, candidates, loads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tenant(name: &str, bytes: usize, weight: f64) -> TenantProfile {
        TenantProfile { name: name.into(), codec: "bitdelta".into(),
                        resident_bytes: bytes, weight, levels: 1 }
    }

    fn workers(n: usize, budget: usize) -> Vec<WorkerSpec> {
        (0..n).map(|index| WorkerSpec {
            index, delta_budget_bytes: budget,
        }).collect()
    }

    fn uniform(names: &[&str], bytes: usize) -> Vec<TenantProfile> {
        let w = 1.0 / names.len() as f64;
        names.iter().map(|n| tenant(n, bytes, w)).collect()
    }

    #[test]
    fn affinity_is_stable_and_single_homed() {
        let p = AffinityPolicy;
        let ts = uniform(&["a", "b", "c", "d", "e"], 10);
        let ws = workers(4, usize::MAX / 2);
        let p1 = p.place(&ts, &ws).unwrap();
        let p2 = p.place(&ts, &ws).unwrap();
        for t in &ts {
            assert_eq!(p1.replica_count(&t.name), 1);
            assert_eq!(p1.workers_of(&t.name), p2.workers_of(&t.name));
        }
        // routing agrees with placement when all replicas are alive
        let idle: Vec<usize> = vec![0; 4];
        for t in &ts {
            let cands = p1.workers_of(&t.name);
            assert_eq!(p.route(&t.name, cands, &idle.as_slice()).unwrap(),
                       cands[0]);
        }
    }

    #[test]
    fn least_loaded_places_everywhere_routes_to_idle() {
        let p = LeastLoadedPolicy;
        let ts = uniform(&["a", "b"], 10);
        let ws = workers(3, usize::MAX / 2);
        let placed = p.place(&ts, &ws).unwrap();
        assert_eq!(placed.replica_count("a"), 3);
        let loads: Vec<usize> = vec![5, 0, 7];
        assert_eq!(p.route("a", &[0, 1, 2], &loads.as_slice()).unwrap(),
                   1);
    }

    #[test]
    fn route_with_no_candidates_is_a_typed_error_not_a_panic() {
        // reachable during failover re-placement races: every policy
        // must return RouteError, never crash the routing path
        let loads: Vec<usize> = vec![];
        for policy in ["affinity", "least-loaded", "delta-aware"] {
            let p = policy_by_name(policy).unwrap();
            let e = p.route("ghost", &[], &loads.as_slice())
                .expect_err(policy);
            assert_eq!(e, RouteError::NoCandidates {
                tenant: "ghost".into(),
            });
            assert!(e.to_string().contains("ghost"), "{e}");
        }
    }

    #[test]
    fn delta_aware_respects_budgets() {
        let p = DeltaAwarePolicy;
        // 8 tenants of 10 B on 4 workers with room for exactly 2 each
        let names = ["t0", "t1", "t2", "t3", "t4", "t5", "t6", "t7"];
        let ts = uniform(&names, 10);
        let ws = workers(4, 20);
        let placed = p.place(&ts, &ws).unwrap();
        for w in 0..4 {
            assert!(placed.placed_bytes(w) <= 20,
                    "worker {w} over budget: {}", placed.placed_bytes(w));
        }
        for t in &ts {
            assert_eq!(placed.replica_count(&t.name), 1);
        }
        let total: usize = (0..4).map(|w| placed.placed_bytes(w)).sum();
        assert_eq!(total, 80);
    }

    #[test]
    fn delta_aware_rejects_impossible_packing() {
        let p = DeltaAwarePolicy;
        let ts = vec![tenant("big", 100, 1.0)];
        let err = p.place(&ts, &workers(2, 50)).unwrap_err().to_string();
        assert!(err.contains("big"), "{err}");
    }

    #[test]
    fn delta_aware_replicates_hot_tenant_under_skew() {
        let p = DeltaAwarePolicy;
        // one tenant takes half the traffic on a 4-worker cluster
        let mut ts = uniform(&["c0", "c1", "c2", "c3", "c4", "c5", "c6"],
                             10);
        for t in &mut ts {
            t.weight = 0.5 / 7.0;
        }
        ts.push(tenant("hot", 10, 0.5));
        let placed = p.place(&ts, &workers(4, 1000)).unwrap();
        assert!(placed.replica_count("hot") >= 2,
                "hot tenant not replicated: {placed:?}");
        for t in &ts[..7] {
            assert_eq!(placed.replica_count(&t.name), 1,
                       "cold tenant {} replicated", t.name);
        }
    }

    #[test]
    fn delta_aware_uniform_traffic_stays_single_homed() {
        let p = DeltaAwarePolicy;
        let names = ["t0", "t1", "t2", "t3", "t4", "t5", "t6", "t7"];
        let ts = uniform(&names, 10);
        let placed = p.place(&ts, &workers(4, 1_000_000)).unwrap();
        for t in &ts {
            assert_eq!(placed.replica_count(&t.name), 1);
        }
    }

    #[test]
    fn delta_aware_replication_is_budget_bounded() {
        let p = DeltaAwarePolicy;
        // hot tenant wants 4 replicas but only 2 workers can hold it
        let mut ts = vec![tenant("hot", 40, 1.0)];
        ts.push(tenant("cold", 40, 0.0));
        let mut ws = workers(4, 10);
        ws[0].delta_budget_bytes = 80;
        ws[1].delta_budget_bytes = 80;
        let placed = p.place(&ts, &ws).unwrap();
        assert_eq!(placed.replica_count("hot"), 2);
        for w in 0..4 {
            let budget = if w < 2 { 80 } else { 10 };
            assert!(placed.placed_bytes(w) <= budget);
        }
    }

    #[test]
    fn fidelity_tiers_price_into_the_packing() {
        // A tier-4 tenant carries 4 mask planes, so its level-scaled
        // resident_bytes take 4x the bin space of a tier-1 tenant over
        // the same matrices — fidelity-vs-packing as a real tradeoff.
        let p = DeltaAwarePolicy;
        let mut deep = tenant("deep", 40, 0.25);
        deep.levels = 4;
        let ts = vec![deep, tenant("a", 10, 0.25),
                      tenant("b", 10, 0.25), tenant("c", 10, 0.25)];
        let placed = p.place(&ts, &workers(2, 40)).unwrap();
        // deep fills one worker's budget alone; the tier-1 tenants all
        // pack onto the other
        let w_deep = placed.workers_of("deep")[0];
        assert_eq!(placed.placed_bytes(w_deep), 40);
        for t in ["a", "b", "c"] {
            assert_ne!(placed.workers_of(t), &[w_deep][..],
                       "{t} landed on the full worker");
        }
    }

    #[test]
    fn policy_by_name_resolves_all_three() {
        for name in ["affinity", "least-loaded", "delta-aware"] {
            assert_eq!(policy_by_name(name).unwrap().name(), name);
        }
        assert!(policy_by_name("round-robin").is_err());
    }

    #[test]
    fn stable_hash_is_stable() {
        assert_eq!(stable_hash("sim-s-chat"), stable_hash("sim-s-chat"));
        assert_ne!(stable_hash("a"), stable_hash("b"));
    }

    /// Seed-parameterized law check over all three built-ins: random
    /// populations (sizes, tiers, codecs, skews) on random fleets must
    /// always yield placements where every tenant is placed on valid
    /// workers, routing picks a replica, budgets hold, and delta-aware
    /// replication triggers exactly when `ceil(share * N) > 1`. On
    /// failure `run_cases` panics with the case seed, which replays
    /// the exact population (the generator is seed-deterministic).
    #[test]
    fn property_policies_place_route_and_respect_budgets() {
        use crate::util::prop::run_cases;
        run_cases(64, |rng| {
            let n_workers = 1 + rng.usize_in(0, 8);
            let n_tenants = 1 + rng.usize_in(0, 40);
            let codecs = ["bitdelta", "lora", "svd", "dense"];
            let raw: Vec<f64> = (0..n_tenants)
                .map(|_| 1.0 + (rng.next_u64() % 1000) as f64)
                .collect();
            let total_w: f64 = raw.iter().sum();
            let ts: Vec<TenantProfile> = raw.iter().enumerate()
                .map(|(i, w)| {
                    let levels = 1 + rng.usize_in(0, 4);
                    TenantProfile {
                        name: format!("p{i:03}"),
                        codec: (*rng.choose(&codecs)).to_string(),
                        resident_bytes:
                            (1 + rng.usize_in(0, 64)) * levels,
                        weight: w / total_w,
                        levels,
                    }
                }).collect();
            let max_item = ts.iter().map(|t| t.resident_bytes)
                .max().unwrap();
            let total: usize =
                ts.iter().map(|t| t.resident_bytes).sum();
            // tight budgets still satisfy the first-fit-decreasing
            // feasibility bound (budget >= 2*max item and
            // total <= n*budget/2), so `place` must never error;
            // ample budgets let replication run to its target
            let ample = rng.bool();
            let budget = if ample {
                2 * total + max_item
            } else {
                (2 * total).div_ceil(n_workers).max(2 * max_item)
            };
            let ws = workers(n_workers, budget);
            let loads: Vec<usize> = (0..n_workers)
                .map(|_| rng.usize_in(0, 16)).collect();

            for name in ["affinity", "least-loaded", "delta-aware"] {
                let p = policy_by_name(name).unwrap();
                let placed = p.place(&ts, &ws).unwrap();
                let replay = p.place(&ts, &ws).unwrap();
                for t in &ts {
                    let cands = placed.workers_of(&t.name);
                    assert!(!cands.is_empty(),
                            "[{name}] {} unplaced", t.name);
                    assert!(cands.iter().all(|&w| w < n_workers),
                            "[{name}] {} on bogus worker {cands:?}",
                            t.name);
                    assert_eq!(cands, replay.workers_of(&t.name),
                               "[{name}] placement not deterministic");
                    let r = p.route(&t.name, cands,
                                    &loads.as_slice()).unwrap();
                    assert!(cands.contains(&r),
                            "[{name}] routed {} off-replica", t.name);
                }
                match name {
                    "affinity" => {
                        for t in &ts {
                            assert_eq!(placed.replica_count(&t.name),
                                       1);
                        }
                    }
                    "least-loaded" => {
                        for t in &ts {
                            assert_eq!(placed.replica_count(&t.name),
                                       n_workers);
                        }
                    }
                    _ => {
                        for w in 0..n_workers {
                            assert!(placed.placed_bytes(w) <= budget,
                                    "[delta-aware] worker {w}: {} > \
budget {budget}", placed.placed_bytes(w));
                        }
                        for t in &ts {
                            let want = ((t.weight * n_workers as f64)
                                        .ceil() as usize)
                                .clamp(1, n_workers);
                            let got = placed.replica_count(&t.name);
                            assert!(got <= want,
                                    "[delta-aware] {} over-replicated \
{got} > {want}", t.name);
                            if ample {
                                assert_eq!(got, want,
                                           "[delta-aware] {} under \
ample budget: {got} != {want}", t.name);
                            }
                            if want == 1 {
                                assert_eq!(got, 1,
                                           "[delta-aware] cold tenant \
{} replicated", t.name);
                            }
                        }
                    }
                }
            }
        });
    }
}
