//! Autoscaling: track load with worker count.
//!
//! BitDelta makes elasticity unusually cheap — a new worker costs one
//! base-model copy (identical everywhere, so nothing tenant-specific
//! moves) plus the ~1/16-cost deltas re-placed onto it
//! ([`crate::sim::memory::scale_up_cost`] prices this). This module
//! supplies the control loop that spends that cheapness only when the
//! load asks for it:
//!
//! * [`ScalingModel`] — the pure decision core: watches outstanding
//!   work per active worker (the same [`WorkerLoad`] score routing
//!   reads), requires **sustained** pressure before scaling up (a
//!   `up_ticks`-long streak above the high watermark — transient
//!   spikes don't spawn engines), sustained idleness before scaling
//!   down, honors `min..max` bounds, and holds a cooldown after every
//!   event so the signal can settle. Deterministic and synchronous, so
//!   every policy decision is unit-testable without threads.
//! * [`Autoscaler`] — the driver thread: samples a [`ClusterHandle`]
//!   every `interval`, feeds the model, and acts on its decisions —
//!   scale-up through [`ClusterHandle::spawn_worker`], scale-down by
//!   **gracefully draining** the least-loaded worker
//!   ([`ClusterHandle::retire_worker`]: zero in-flight errors, unlike
//!   failover).
//!
//! [`WorkerLoad`]: crate::cluster::worker::WorkerLoad

use std::time::Duration;

use crate::cluster::frontend::ClusterHandle;
use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::clock;
use crate::sync::thread::JoinHandle;
use crate::sync::{thread, Arc};

/// Autoscaler tuning. `Default` suits the in-repo loadtests: scale up
/// after ~3 consecutive pressured samples, scale down only after a
/// clearly longer idle streak (draining an engine is cheap, but
/// re-spawning one is not).
#[derive(Debug, Clone)]
pub struct AutoscalerConfig {
    pub min_workers: usize,
    pub max_workers: usize,
    /// Outstanding work per active worker above which a sample counts
    /// as scale-up pressure.
    pub high_watermark: f64,
    /// Outstanding work per active worker below which a sample counts
    /// as scale-down slack.
    pub low_watermark: f64,
    /// Consecutive pressured samples required before scaling up —
    /// the "sustained, not transient" filter.
    pub up_ticks: usize,
    /// Consecutive slack samples required before scaling down.
    pub down_ticks: usize,
    /// Samples to ignore after any scale event, letting queues and the
    /// re-placement settle before the next decision.
    pub cooldown_ticks: usize,
    /// Sampling period of the driver thread.
    pub interval: Duration,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        Self {
            min_workers: 1,
            max_workers: 4,
            high_watermark: 4.0,
            low_watermark: 0.5,
            up_ticks: 3,
            down_ticks: 8,
            cooldown_ticks: 3,
            interval: Duration::from_millis(50),
        }
    }
}

/// One autoscaling decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    Hold,
    /// Spawn one worker.
    Up,
    /// Gracefully drain and retire one worker.
    Down,
}

/// The pure hysteresis core: feed it `(active workers, outstanding
/// work)` samples, get decisions. Owns no threads and reads no clocks —
/// a tick is whatever cadence the caller samples at.
#[derive(Debug)]
pub struct ScalingModel {
    cfg: AutoscalerConfig,
    up_streak: usize,
    down_streak: usize,
    cooldown: usize,
}

impl ScalingModel {
    pub fn new(cfg: AutoscalerConfig) -> Self {
        Self { cfg, up_streak: 0, down_streak: 0, cooldown: 0 }
    }

    pub fn config(&self) -> &AutoscalerConfig {
        &self.cfg
    }

    /// Feed one load sample; returns what the cluster should do now.
    /// `active` is the routable worker count, `outstanding` the total
    /// queued + batched + in-flight work across them.
    pub fn observe(&mut self, active: usize, outstanding: usize)
                   -> ScaleDecision {
        if self.cooldown > 0 {
            // the previous event is still settling: don't let stale
            // pressure double-fire, and don't accrue streaks either
            self.cooldown -= 1;
            self.up_streak = 0;
            self.down_streak = 0;
            return ScaleDecision::Hold;
        }
        let per_worker = outstanding as f64 / active.max(1) as f64;
        if per_worker > self.cfg.high_watermark {
            self.up_streak += 1;
            self.down_streak = 0;
        } else if per_worker < self.cfg.low_watermark {
            self.down_streak += 1;
            self.up_streak = 0;
        } else {
            self.up_streak = 0;
            self.down_streak = 0;
        }
        if self.up_streak >= self.cfg.up_ticks
            && active < self.cfg.max_workers {
            self.up_streak = 0;
            self.cooldown = self.cfg.cooldown_ticks;
            return ScaleDecision::Up;
        }
        if self.down_streak >= self.cfg.down_ticks
            && active > self.cfg.min_workers {
            self.down_streak = 0;
            self.cooldown = self.cfg.cooldown_ticks;
            return ScaleDecision::Down;
        }
        ScaleDecision::Hold
    }
}

/// The background control loop: a [`ScalingModel`] sampling one
/// [`ClusterHandle`]. Spawn with [`Autoscaler::spawn`], stop with
/// [`Autoscaler::stop`] (joins the thread; any in-progress drain
/// completes first).
pub struct Autoscaler {
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl Autoscaler {
    pub fn spawn(handle: ClusterHandle, cfg: AutoscalerConfig) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let interval = cfg.interval;
        let min_workers = cfg.min_workers;
        let mut model = ScalingModel::new(cfg);
        let join = thread::Builder::new()
            .name("bitdelta-autoscaler".into())
            .spawn(move || {
                while !flag.load(Ordering::Relaxed) {
                    let active = handle.active_workers();
                    let outstanding = handle.outstanding();
                    match model.observe(active, outstanding) {
                        ScaleDecision::Up => {
                            // a failed spawn (fixed cluster, engine
                            // error) must not kill the control loop;
                            // the next samples will simply retry
                            let _ = handle.spawn_worker();
                        }
                        ScaleDecision::Down => {
                            if let Some(w) = handle.least_loaded_active()
                            {
                                // blocks for the graceful drain; the
                                // cooldown absorbs the pause. The floor
                                // is re-checked under the cluster lock:
                                // a worker death since the sample must
                                // not let this drain dip below min
                                let _ = handle.retire_worker_floor(
                                    w, min_workers);
                            }
                        }
                        ScaleDecision::Hold => {}
                    }
                    // the clock seam: real pacing in production, one
                    // virtual `interval` per driver tick under the
                    // simulation harness
                    clock::sleep(interval);
                }
            })
            // lint: allow(expect, OS refusing to spawn the one control
            // thread is unrecoverable at startup)
            .expect("spawn autoscaler thread");
        Self { stop, join: Some(join) }
    }

    /// Stop sampling and join the control thread.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Autoscaler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    use crate::cluster::frontend::{
        Cluster, ClusterConfig, ClusterTicket,
    };
    use crate::cluster::placement::policy_by_name;
    use crate::cluster::testutil::{elastic_mock, profiles, req};

    fn model(min: usize, max: usize, up: usize, down: usize)
             -> ScalingModel {
        ScalingModel::new(AutoscalerConfig {
            min_workers: min,
            max_workers: max,
            high_watermark: 4.0,
            low_watermark: 0.5,
            up_ticks: up,
            down_ticks: down,
            cooldown_ticks: 0,
            interval: Duration::from_millis(1),
        })
    }

    #[test]
    fn sustained_pressure_scales_up_transient_spike_does_not() {
        let mut m = model(1, 4, 3, 3);
        // a one-tick spike resets: no scale-up
        assert_eq!(m.observe(1, 100), ScaleDecision::Hold);
        assert_eq!(m.observe(1, 0), ScaleDecision::Hold);
        // three consecutive pressured ticks fire exactly once
        assert_eq!(m.observe(1, 100), ScaleDecision::Hold);
        assert_eq!(m.observe(1, 100), ScaleDecision::Hold);
        assert_eq!(m.observe(1, 100), ScaleDecision::Up);
        // the streak reset: the next tick starts over
        assert_eq!(m.observe(2, 100), ScaleDecision::Hold);
    }

    #[test]
    fn max_bound_blocks_scale_up() {
        let mut m = model(1, 2, 2, 2);
        assert_eq!(m.observe(2, 100), ScaleDecision::Hold);
        // pressure is sustained but the cluster is at max
        assert_eq!(m.observe(2, 100), ScaleDecision::Hold);
        assert_eq!(m.observe(2, 100), ScaleDecision::Hold);
    }

    #[test]
    fn idle_scales_down_only_to_min() {
        let mut m = model(2, 4, 2, 2);
        assert_eq!(m.observe(3, 0), ScaleDecision::Hold);
        assert_eq!(m.observe(3, 0), ScaleDecision::Down);
        // at min: idleness no longer retires workers
        assert_eq!(m.observe(2, 0), ScaleDecision::Hold);
        assert_eq!(m.observe(2, 0), ScaleDecision::Hold);
        assert_eq!(m.observe(2, 0), ScaleDecision::Hold);
    }

    #[test]
    fn cooldown_gates_back_to_back_events() {
        let mut m = ScalingModel::new(AutoscalerConfig {
            min_workers: 1,
            max_workers: 8,
            high_watermark: 4.0,
            low_watermark: 0.5,
            up_ticks: 1,
            down_ticks: 1,
            cooldown_ticks: 2,
            interval: Duration::from_millis(1),
        });
        assert_eq!(m.observe(1, 100), ScaleDecision::Up);
        // two cooldown ticks: pressure is ignored, streaks reset
        assert_eq!(m.observe(2, 100), ScaleDecision::Hold);
        assert_eq!(m.observe(2, 100), ScaleDecision::Hold);
        // cooled down: the next pressured tick may fire again
        assert_eq!(m.observe(2, 100), ScaleDecision::Up);
    }

    #[test]
    fn middle_band_resets_both_streaks() {
        let mut m = model(1, 4, 2, 2);
        assert_eq!(m.observe(1, 100), ScaleDecision::Hold); // up 1
        // per-worker load inside [low, high]: neither streak survives
        assert_eq!(m.observe(1, 2), ScaleDecision::Hold);
        assert_eq!(m.observe(1, 100), ScaleDecision::Hold); // up 1 again
        assert_eq!(m.observe(1, 100), ScaleDecision::Up);
    }

    // -- end-to-end against a mock cluster ----------------------------

    /// Runs entirely on the virtual clock: the test thread is the time
    /// driver (1 virtual ms per tick), so the grow/serve/shrink cycle
    /// is paced by simulated time instead of machine load — the
    /// wall-clock version of this test flaked under slow CI runners.
    #[test]
    fn autoscaler_grows_under_burst_and_drains_back_down() {
        let guard = clock::install();
        let ccfg = ClusterConfig {
            policy: policy_by_name("least-loaded").unwrap(),
            delta_budget_bytes: 1 << 20,
            admission: None,
        };
        let cluster = Cluster::spawn_elastic(
            &ccfg, profiles(&["a", "b"], 10), 1,
            elastic_mock(Duration::from_millis(2))).unwrap();
        let handle = cluster.handle();
        let scaler = Autoscaler::spawn(handle.clone(), AutoscalerConfig {
            min_workers: 1,
            max_workers: 3,
            high_watermark: 3.0,
            low_watermark: 0.5,
            up_ticks: 2,
            down_ticks: 3,
            cooldown_ticks: 1,
            interval: Duration::from_millis(5),
        });

        // burst: pile up far more work than one 2ms/step worker clears
        let mut tickets: Vec<ClusterTicket> = (0..120)
            .map(|i| handle.submit(req(["a", "b"][i % 2])).unwrap())
            .collect();

        // drive: advance virtual time, harvest, watch the worker count
        // ride the burst up and the idle tail back down
        let mut grew = false;
        let mut served = 0usize;
        let mut shrank = false;
        for _ in 0..20_000 {
            clock::advance(Duration::from_millis(1));
            // real pacing so worker/autoscaler threads get scheduled
            // between virtual ticks
            // lint: allow(raw-time, the driver's real pacing nap — the
            // one wall-clock sleep a virtual-time test needs)
            thread::sleep(Duration::from_micros(200));
            tickets.retain(|t| match t.try_recv() {
                None => true,
                Some(r) => {
                    // scale events never shed or lose accepted work
                    r.expect("request lost during scale events");
                    served += 1;
                    false
                }
            });
            if handle.active_workers() >= 2 {
                grew = true;
            }
            if grew && tickets.is_empty()
                && handle.active_workers() == 1 {
                shrank = true;
                break;
            }
        }
        assert!(grew, "autoscaler never scaled up under sustained load");
        assert_eq!(served, 120);
        assert!(shrank, "autoscaler never drained back down when idle");

        // uninstall first: wakes any virtually-parked sleeper so the
        // stop/join below cannot deadlock on frozen time
        drop(guard);
        scaler.stop();
        let m = handle.metrics();
        assert!(m.contains(
            "bitdelta_cluster_scale_events_total{direction=\"up\"}"),
                "{m}");
        assert!(m.contains("bitdelta_cluster_failovers_total 0"), "{m}");
        // serving still works at min scale
        handle.generate(req("a")).unwrap();
        cluster.shutdown().unwrap();
    }
}
