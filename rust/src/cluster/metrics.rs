//! Cluster-level metrics: per-worker relabeling and cross-worker rollup
//! of the Prometheus-style text each engine already exposes.
//!
//! Workers produce independent expositions
//! ([`crate::coordinator::metrics::Metrics::exposition`] plus per-codec
//! accounting). The cluster publishes both views:
//!
//! * **per-worker** — every line re-labeled with `worker="i"` so one
//!   scrape distinguishes replicas;
//! * **rollup** — one line per metric across workers: counters
//!   (`_total`, `_count`, `_bucket`) and additive gauges (queue depths,
//!   resident bytes) are summed; order statistics (`_p50`, `_p99`,
//!   `_max`) take the worst worker; `_mean` and ratio gauges
//!   (`_occupancy`) are averaged over workers (an approximation —
//!   exact pooling would need per-worker counts at every line).

/// Re-label every metric line with a `worker="i"` label (inserted as the
/// first label so per-worker series never collide in one scrape).
pub fn relabel(text: &str, worker: usize) -> String {
    let mut out = String::with_capacity(text.len() + 64);
    for line in text.lines() {
        let Some((name, value)) = line.rsplit_once(' ') else {
            continue;
        };
        match name.find('{') {
            Some(idx) => out.push_str(&format!(
                "{}{{worker=\"{worker}\",{} {value}\n",
                &name[..idx], &name[idx + 1..])),
            None => out.push_str(&format!(
                "{name}{{worker=\"{worker}\"}} {value}\n")),
        }
    }
    out
}

fn metric_base(name: &str) -> &str {
    name.split('{').next().unwrap_or(name)
}

enum Fold {
    Sum,
    Max,
    Mean,
}

fn fold_of(name: &str) -> Fold {
    let base = metric_base(name);
    if base.ends_with("_p50") || base.ends_with("_p99")
        || base.ends_with("_max") {
        Fold::Max
    } else if base.ends_with("_mean") || base.ends_with("_occupancy") {
        // ratios and means average across workers — summing a 0..1
        // occupancy over 4 workers would report an impossible 3.0
        Fold::Mean
    } else {
        Fold::Sum
    }
}

/// Fold N worker expositions into one cluster-wide exposition. Lines
/// are keyed by full metric name (labels included); the fold per metric
/// follows the module docs. Output is sorted by metric name so the
/// rollup is stable across scrapes.
pub fn rollup(texts: &[String]) -> String {
    use std::collections::BTreeMap;
    let mut acc: BTreeMap<String, (f64, u64)> = BTreeMap::new();
    for text in texts {
        for line in text.lines() {
            let Some((name, value)) = line.rsplit_once(' ') else {
                continue;
            };
            let Ok(v) = value.parse::<f64>() else {
                continue;
            };
            let e = acc.entry(name.to_string()).or_insert((0.0, 0));
            match fold_of(name) {
                Fold::Sum | Fold::Mean => e.0 += v,
                Fold::Max => e.0 = e.0.max(v),
            }
            e.1 += 1;
        }
    }
    let mut out = String::new();
    for (name, (v, n)) in acc {
        let v = match fold_of(&name) {
            Fold::Mean => v / n.max(1) as f64,
            _ => v,
        };
        out.push_str(&format!("{name} {v}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relabel_plain_and_labeled_lines() {
        let text = "bitdelta_requests_total 3\n\
                    bitdelta_delta_resident_bytes{codec=\"bitdelta\"} 64\n";
        let r = relabel(text, 2);
        assert!(r.contains(
            "bitdelta_requests_total{worker=\"2\"} 3"), "{r}");
        assert!(r.contains(
            "bitdelta_delta_resident_bytes{worker=\"2\",\
codec=\"bitdelta\"} 64"), "{r}");
    }

    #[test]
    fn rollup_sums_counters_across_workers() {
        let a = "bitdelta_requests_total 3\n\
                 bitdelta_tokens_generated_total 100\n\
                 bitdelta_queue_depth{tenant=\"t0\"} 2\n".to_string();
        let b = "bitdelta_requests_total 5\n\
                 bitdelta_tokens_generated_total 40\n\
                 bitdelta_queue_depth{tenant=\"t0\"} 1\n".to_string();
        let r = rollup(&[a, b]);
        assert!(r.contains("bitdelta_requests_total 8"), "{r}");
        assert!(r.contains("bitdelta_tokens_generated_total 140"), "{r}");
        assert!(r.contains("bitdelta_queue_depth{tenant=\"t0\"} 3"),
                "{r}");
    }

    #[test]
    fn rollup_takes_worst_quantile_and_mean_of_means() {
        let a = "bitdelta_ttft_us_p99 500\nbitdelta_ttft_us_mean 100\n"
            .to_string();
        let b = "bitdelta_ttft_us_p99 900\nbitdelta_ttft_us_mean 300\n"
            .to_string();
        let r = rollup(&[a, b]);
        assert!(r.contains("bitdelta_ttft_us_p99 900"), "{r}");
        assert!(r.contains("bitdelta_ttft_us_mean 200"), "{r}");
    }

    #[test]
    fn rollup_averages_occupancy_ratio() {
        let a = "bitdelta_batch_occupancy 0.75\n".to_string();
        let b = "bitdelta_batch_occupancy 0.25\n".to_string();
        let r = rollup(&[a, b]);
        assert!(r.contains("bitdelta_batch_occupancy 0.5"), "{r}");
    }

    #[test]
    fn rollup_sums_histogram_buckets() {
        let a = "bitdelta_ttft_us_bucket{le=\"100\"} 4\n\
                 bitdelta_ttft_count 6\n".to_string();
        let b = "bitdelta_ttft_us_bucket{le=\"100\"} 1\n\
                 bitdelta_ttft_count 2\n".to_string();
        let r = rollup(&[a, b]);
        assert!(r.contains("bitdelta_ttft_us_bucket{le=\"100\"} 5"),
                "{r}");
        assert!(r.contains("bitdelta_ttft_count 8"), "{r}");
    }

    #[test]
    fn rollup_skips_malformed_lines() {
        let a = "garbage\nbitdelta_requests_total not-a-number\n\
                 bitdelta_requests_total 1\n".to_string();
        let r = rollup(&[a]);
        assert_eq!(r, "bitdelta_requests_total 1\n");
    }
}
