//! The worker thread harness — one engine pinned to one OS thread.
//!
//! PJRT objects are not `Send`, so every engine lives on its own thread
//! and is *constructed there* (the [`CoreFactory`] runs on the worker
//! thread). The pump loop here is shared by the single-engine
//! [`crate::serving::service::ServingService`] and the multi-worker
//! [`crate::cluster::Cluster`]: ingest commands (blocking when idle),
//! advance the engine, deliver finished responses.
//!
//! The loop is written against the small [`WorkerCore`] trait rather
//! than the concrete engine so cluster scheduling and failover can be
//! unit-tested with deterministic fake cores, no artifacts required.

use anyhow::{anyhow, Result};

use crate::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::sync::mpsc;
use crate::sync::thread::JoinHandle;
use crate::sync::Arc;

use crate::serving::engine::Engine;
use crate::serving::request::{Request, RequestError, Response};

/// The engine surface the pump loop drives. Implemented by the real
/// [`Engine`]; tests substitute deterministic fakes. Cores need not be
/// `Send` — the factory builds them on the worker thread, which is
/// exactly the constraint PJRT imposes.
pub trait WorkerCore {
    /// Accept a request; the response — or a typed [`RequestError`]
    /// for a malformed one — arrives on the returned channel.
    fn submit(&mut self, req: Request)
              -> Result<mpsc::Receiver<Result<Response, RequestError>>>;
    /// One scheduling/decode iteration.
    fn step(&mut self) -> Result<()>;
    /// Queued or in-slot work remains.
    fn has_work(&self) -> bool;
    /// Requests waiting in the core's queues (not yet in a slot).
    fn queue_depth(&self) -> usize;
    /// Occupied batch slots.
    fn occupancy(&self) -> usize;
    /// Run until every queue and slot is empty (shutdown drain).
    ///
    /// **Contract**: a clean return means every request this core ever
    /// accepted has produced its response — nothing queued, nothing in
    /// a slot. Graceful scale-down leans on this: the cluster's
    /// `retire_worker` promises zero in-flight errors, which holds iff
    /// `drain` completes accepted work instead of dropping it.
    fn drain(&mut self) -> Result<()>;
    /// Prometheus-style metrics exposition for this core.
    fn metrics_text(&self) -> String;
}

impl WorkerCore for Engine {
    fn submit(&mut self, req: Request)
              -> Result<mpsc::Receiver<Result<Response, RequestError>>> {
        Engine::submit(self, req)
    }

    fn step(&mut self) -> Result<()> {
        Engine::step(self).map(|_| ())
    }

    fn has_work(&self) -> bool {
        self.batcher.occupancy() > 0 || self.router.total_queued() > 0
    }

    fn queue_depth(&self) -> usize {
        self.router.total_queued()
    }

    fn occupancy(&self) -> usize {
        self.batcher.occupancy()
    }

    fn drain(&mut self) -> Result<()> {
        self.run_until_idle(1_000_000).map(|_| ())
    }

    fn metrics_text(&self) -> String {
        format!("{}{}", self.metrics.exposition(), self.codec_accounting())
    }
}

/// Factory invoked **on the worker thread** to build its core.
pub type CoreFactory =
    Box<dyn FnOnce() -> Result<Box<dyn WorkerCore>> + Send>;

/// Commands accepted by a worker thread.
pub enum Command {
    Submit(Request, mpsc::Sender<Result<Response>>),
    Metrics(mpsc::Sender<String>),
    Shutdown,
}

/// Live load snapshot a worker publishes every loop iteration — the
/// signal least-loaded routing reads lock-free. `submitted` is bumped by
/// the sending side, `ingested` by the worker, so `submitted - ingested`
/// counts commands still in flight in the channel.
#[derive(Debug)]
pub struct WorkerLoad {
    pub queued: AtomicUsize,
    pub occupancy: AtomicUsize,
    pub inflight: AtomicUsize,
    pub submitted: AtomicUsize,
    pub ingested: AtomicUsize,
    pub alive: AtomicBool,
}

// Written out (not derived) because loom's atomics are not
// const-constructible and do not all implement `Default`.
impl Default for WorkerLoad {
    fn default() -> Self {
        Self {
            queued: AtomicUsize::new(0),
            occupancy: AtomicUsize::new(0),
            inflight: AtomicUsize::new(0),
            submitted: AtomicUsize::new(0),
            ingested: AtomicUsize::new(0),
            alive: AtomicBool::new(false),
        }
    }
}

impl WorkerLoad {
    /// Requests sent to the worker but not yet ingested from its channel.
    pub fn backlog(&self) -> usize {
        self.submitted.load(Ordering::Relaxed)
            .saturating_sub(self.ingested.load(Ordering::Relaxed))
    }

    /// Routing score: total outstanding work on this worker.
    pub fn score(&self) -> usize {
        self.backlog()
            + self.queued.load(Ordering::Relaxed)
            + self.occupancy.load(Ordering::Relaxed)
            + self.inflight.load(Ordering::Relaxed)
    }

    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
    }
}

/// Cloneable, `Send` handle to one worker thread.
#[derive(Clone)]
pub struct WorkerHandle {
    tx: mpsc::Sender<Command>,
    load: Arc<WorkerLoad>,
}

impl WorkerHandle {
    pub fn load(&self) -> &Arc<WorkerLoad> {
        &self.load
    }

    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, req: Request)
                  -> Result<mpsc::Receiver<Result<Response>>> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Command::Submit(req, tx))
            .map_err(|_| anyhow!("worker is gone"))?;
        self.load.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(rx)
    }

    /// Submit and block until the response arrives.
    pub fn generate(&self, req: Request) -> Result<Response> {
        self.submit(req)?
            .recv().map_err(|_| anyhow!("worker dropped the request"))?
    }

    /// Fetch the worker's metrics exposition text.
    pub fn metrics(&self) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Command::Metrics(tx))
            .map_err(|_| anyhow!("worker is gone"))?;
        rx.recv().map_err(|_| anyhow!("worker dropped the request"))
    }

    /// Ask the worker to drain and exit (does not wait for it).
    pub fn shutdown_signal(&self) {
        let _ = self.tx.send(Command::Shutdown);
    }
}

/// Spawn one worker thread. The factory runs on the new thread; a
/// construction failure is returned synchronously from this call.
pub fn spawn_worker(name: String, factory: CoreFactory)
                    -> Result<(WorkerHandle, JoinHandle<Result<()>>)> {
    let load = Arc::new(WorkerLoad::default());
    let (tx, rx) = mpsc::channel::<Command>();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
    let thread_load = load.clone();
    let join = crate::sync::thread::Builder::new()
        .name(name)
        .spawn(move || worker_thread(factory, rx, ready_tx, thread_load))?;
    ready_rx.recv()
        .map_err(|_| anyhow!("worker thread died during startup"))??;
    Ok((WorkerHandle { tx, load }, join))
}

type Pending = Vec<(mpsc::Receiver<Result<Response, RequestError>>,
                    mpsc::Sender<Result<Response>>)>;

/// Clears the published `alive` flag however the worker exits —
/// including a panic — so routing stops targeting a dead worker.
struct AliveGuard(Arc<WorkerLoad>);

impl Drop for AliveGuard {
    fn drop(&mut self) {
        self.0.alive.store(false, Ordering::Relaxed);
    }
}

fn worker_thread(factory: CoreFactory, rx: mpsc::Receiver<Command>,
                 ready: mpsc::Sender<Result<()>>, load: Arc<WorkerLoad>)
                 -> Result<()> {
    let mut core = match factory() {
        Ok(c) => {
            load.alive.store(true, Ordering::Relaxed);
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow!("{e:#}")));
            return Ok(());
        }
    };
    let _guard = AliveGuard(load.clone());
    let mut pending: Pending = Vec::new();

    loop {
        // 1. ingest commands (non-blocking while busy, blocking if idle)
        let cmd = if core.has_work() {
            match rx.try_recv() {
                Ok(c) => Some(c),
                Err(mpsc::TryRecvError::Empty) => None,
                Err(mpsc::TryRecvError::Disconnected) => return Ok(()),
            }
        } else {
            publish(&load, core.as_ref(), pending.len());
            match rx.recv() {
                Ok(c) => Some(c),
                Err(_) => return Ok(()),
            }
        };
        match cmd {
            Some(Command::Submit(req, reply)) => {
                load.ingested.fetch_add(1, Ordering::Relaxed);
                match core.submit(req) {
                    Ok(chan) => pending.push((chan, reply)),
                    Err(e) => {
                        let _ = reply.send(Err(anyhow!("{e:#}")));
                    }
                }
            }
            Some(Command::Metrics(reply)) => {
                let _ = reply.send(core.metrics_text());
            }
            Some(Command::Shutdown) => {
                let _ = core.drain();
                deliver_ready(&mut pending);
                // anything not delivered by a full drain is unservable:
                // reply with an error rather than dropping the channel
                for (_, reply) in pending.drain(..) {
                    let _ = reply.send(Err(anyhow!(
                        "worker shut down before the request completed")));
                }
                publish(&load, core.as_ref(), 0);
                return Ok(());
            }
            None => {}
        }

        // 2. advance the engine
        if core.has_work() {
            if let Err(e) = core.step() {
                // the worker is dying: fail every in-flight request so
                // no caller hangs on a channel that will never deliver
                for (_, reply) in pending.drain(..) {
                    let _ = reply.send(Err(anyhow!("engine: {e:#}")));
                }
                return Err(e);
            }
        }

        // 3. deliver finished responses
        deliver_ready(&mut pending);
        publish(&load, core.as_ref(), pending.len());
    }
}

fn publish(load: &WorkerLoad, core: &dyn WorkerCore, inflight: usize) {
    load.queued.store(core.queue_depth(), Ordering::Relaxed);
    load.occupancy.store(core.occupancy(), Ordering::Relaxed);
    load.inflight.store(inflight, Ordering::Relaxed);
}

fn deliver_ready(pending: &mut Pending) {
    let mut i = 0;
    while i < pending.len() {
        match pending[i].0.try_recv() {
            Ok(Ok(resp)) => {
                let (_, reply) = pending.remove(i);
                let _ = reply.send(Ok(resp));
            }
            Ok(Err(rej)) => {
                // a malformed request: surface the engine's typed
                // rejection to the caller, worker keeps serving
                let (_, reply) = pending.remove(i);
                let _ = reply.send(Err(anyhow::Error::new(rej)));
            }
            Err(mpsc::TryRecvError::Empty) => i += 1,
            Err(mpsc::TryRecvError::Disconnected) => {
                let (_, reply) = pending.remove(i);
                let _ = reply.send(Err(anyhow!("request dropped")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::testutil::MockCore;
    use crate::model::sampling::SamplingParams;

    fn req(tenant: &str) -> Request {
        Request { tenant: tenant.into(), prompt: "Q:".into(),
                  max_new_tokens: 4, sampling: SamplingParams::greedy() }
    }

    #[test]
    fn worker_serves_and_shuts_down() {
        let factory: CoreFactory =
            Box::new(|| Ok(Box::new(MockCore::new(0)) as Box<dyn WorkerCore>));
        let (h, join) = spawn_worker("w-test".into(), factory).unwrap();
        assert!(h.load().is_alive());
        let r = h.generate(req("a")).unwrap();
        assert_eq!(r.tenant, "a");
        h.shutdown_signal();
        join.join().unwrap().unwrap();
        assert!(!h.load().is_alive());
        assert!(h.generate(req("a")).is_err(), "submit after shutdown");
    }

    #[test]
    fn factory_error_is_synchronous() {
        let factory: CoreFactory =
            Box::new(|| Err(anyhow!("no artifacts here")));
        let err = spawn_worker("w-bad".into(), factory)
            .err().expect("spawn must fail").to_string();
        assert!(err.contains("no artifacts"), "{err}");
    }

    #[test]
    fn dying_core_fails_pending_instead_of_hanging() {
        let kill = Arc::new(AtomicBool::new(false));
        let k = kill.clone();
        let factory: CoreFactory = Box::new(move || {
            Ok(Box::new(MockCore::new(0).with_kill_switch(k))
               as Box<dyn WorkerCore>)
        });
        let (h, join) = spawn_worker("w-dying".into(), factory).unwrap();
        kill.store(true, Ordering::Relaxed);
        let r = h.generate(req("a"));
        assert!(r.is_err(), "request on a dying worker must error");
        assert!(join.join().unwrap().is_err());
        assert!(!h.load().is_alive());
    }

    #[test]
    fn load_score_counts_backlog() {
        let l = WorkerLoad::default();
        l.submitted.store(5, Ordering::Relaxed);
        l.ingested.store(2, Ordering::Relaxed);
        l.queued.store(1, Ordering::Relaxed);
        assert_eq!(l.backlog(), 3);
        assert_eq!(l.score(), 4);
    }
}
