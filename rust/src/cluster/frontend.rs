//! The cluster: N worker engines behind one `Clone + Send` handle.
//!
//! [`Cluster::spawn`] computes an initial tenant placement (fail-fast
//! if the deltas cannot be packed), then starts one worker thread per
//! core factory. [`ClusterHandle`] routes each request to one of the
//! tenant's placed workers via the configured
//! [`PlacementPolicy`]; any number of client threads may submit
//! concurrently.
//!
//! **Failover**: a worker that dies (engine error or panic) drops its
//! `alive` flag; in-flight requests on it are answered with errors (the
//! worker loop fails them before exiting, and a vanished reply channel
//! surfaces as an error on the caller side — never a hang). The next
//! routing decision notices the death, re-places the dead worker's
//! tenants across the survivors with the same policy, and bumps the
//! failover counters. If the survivors' budgets can no longer hold a
//! policy-respecting placement, routing degrades to
//! everything-everywhere — availability over budget.

use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context, Result};

use crate::cluster::metrics::{relabel, rollup};
use crate::cluster::placement::{
    LoadView, Placement, PlacementPolicy, TenantProfile, WorkerSpec,
};
use crate::cluster::worker::{
    spawn_worker, CoreFactory, WorkerCore, WorkerHandle,
};
use crate::config::Manifest;
use crate::coordinator::workload::TraceEvent;
use crate::delta::codec::CodecRegistry;
use crate::model::sampling::SamplingParams;
use crate::serving::engine::{Engine, EngineConfig};
use crate::serving::request::{Request, Response};

/// Cluster construction parameters.
pub struct ClusterConfig {
    pub policy: Arc<dyn PlacementPolicy>,
    /// Per-worker delta residency budget, bytes (each worker's
    /// [`crate::coordinator::deltastore::DeltaStore`] budget, and the
    /// bin the delta-aware policy packs against).
    pub delta_budget_bytes: usize,
}

/// Routing state behind the handle's mutex (everything the per-request
/// hot path needs is either here or in lock-free [`WorkerLoad`]
/// atomics).
///
/// [`WorkerLoad`]: crate::cluster::worker::WorkerLoad
struct RouteState {
    placement: Placement,
    dead: Vec<bool>,
    routed: Vec<u64>,
    failovers: u64,
    replaced_tenants: u64,
}

struct Shared {
    policy: Arc<dyn PlacementPolicy>,
    workers: Vec<WorkerHandle>,
    specs: Vec<WorkerSpec>,
    profiles: Vec<TenantProfile>,
    state: Mutex<RouteState>,
}

/// Live load view over the workers' published atomics.
struct LiveLoads<'a>(&'a [WorkerHandle]);

impl LoadView for LiveLoads<'_> {
    fn score(&self, worker: usize) -> usize {
        self.0.get(worker).map(|h| h.load().score()).unwrap_or(usize::MAX)
    }
}

/// The running cluster (owns the worker threads).
pub struct Cluster {
    handle: ClusterHandle,
    joins: Vec<JoinHandle<Result<()>>>,
}

/// Cloneable, `Send + Sync` front-end to the cluster.
#[derive(Clone)]
pub struct ClusterHandle {
    shared: Arc<Shared>,
}

impl Cluster {
    /// Start one worker per factory; tenant placement is computed first
    /// so an impossible packing fails before any engine loads.
    pub fn spawn(cfg: &ClusterConfig, profiles: Vec<TenantProfile>,
                 factories: Vec<CoreFactory>) -> Result<Self> {
        if factories.is_empty() {
            bail!("cluster needs at least one worker");
        }
        let n = factories.len();
        let specs: Vec<WorkerSpec> = (0..n).map(|index| WorkerSpec {
            index,
            delta_budget_bytes: cfg.delta_budget_bytes,
        }).collect();
        let placement = cfg.policy.place(&profiles, &specs)?;

        let mut workers = Vec::with_capacity(n);
        let mut joins = Vec::with_capacity(n);
        for (i, f) in factories.into_iter().enumerate() {
            let (h, j) = spawn_worker(format!("bitdelta-worker-{i}"), f)?;
            workers.push(h);
            joins.push(j);
        }
        let shared = Arc::new(Shared {
            policy: cfg.policy.clone(),
            workers,
            specs,
            profiles,
            state: Mutex::new(RouteState {
                placement,
                dead: vec![false; n],
                routed: vec![0; n],
                failovers: 0,
                replaced_tenants: 0,
            }),
        });
        Ok(Self { handle: ClusterHandle { shared }, joins })
    }

    /// Engine-backed cluster: every worker runs its own [`Engine`] built
    /// from `ecfg` with the cluster's per-worker delta budget.
    pub fn spawn_engines(cfg: &ClusterConfig, ecfg: &EngineConfig,
                         n_workers: usize,
                         profiles: Vec<TenantProfile>) -> Result<Self> {
        let factories: Vec<CoreFactory> = (0..n_workers).map(|_| {
            let mut wcfg = ecfg.clone();
            wcfg.delta_budget_bytes = cfg.delta_budget_bytes;
            let f: CoreFactory = Box::new(move || {
                Ok(Box::new(Engine::from_artifacts(wcfg)?)
                   as Box<dyn WorkerCore>)
            });
            f
        }).collect();
        Self::spawn(cfg, profiles, factories)
    }

    pub fn handle(&self) -> ClusterHandle {
        self.handle.clone()
    }

    /// Drain every worker and join the threads. The first worker error
    /// (e.g. a death that already triggered failover) is returned.
    pub fn shutdown(mut self) -> Result<()> {
        for h in &self.handle.shared.workers {
            h.shutdown_signal();
        }
        let mut first_err: Option<anyhow::Error> = None;
        for j in self.joins.drain(..) {
            let r = match j.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow!("worker thread panicked")),
            };
            if let Err(e) = r {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl ClusterHandle {
    /// Submit a request; the response arrives on the returned channel.
    /// Routing retries across workers when a send hits a dead one, but
    /// a request already accepted by a worker that then dies comes back
    /// as an error (no silent cross-worker replay of maybe-executed
    /// work).
    pub fn submit(&self, req: Request)
                  -> Result<mpsc::Receiver<Result<Response>>> {
        let n = self.shared.workers.len();
        for _ in 0..=n {
            let w = self.pick(&req.tenant)?;
            match self.shared.workers[w].submit(req.clone()) {
                Ok(rx) => {
                    let mut st = self.shared.state.lock().unwrap();
                    st.routed[w] += 1;
                    return Ok(rx);
                }
                Err(_) => self.mark_dead(w),
            }
        }
        bail!("no alive worker accepted the request")
    }

    /// Submit and block until the response arrives.
    pub fn generate(&self, req: Request) -> Result<Response> {
        self.submit(req)?
            .recv().map_err(|_| anyhow!("worker dropped the request"))?
    }

    /// Tenants the cluster places (sorted at profile construction).
    pub fn tenants(&self) -> Vec<String> {
        self.shared.profiles.iter().map(|t| t.name.clone()).collect()
    }

    /// Snapshot of the current placement.
    pub fn placement(&self) -> Placement {
        let mut st = self.shared.state.lock().unwrap();
        self.reap(&mut st);
        st.placement.clone()
    }

    pub fn n_workers(&self) -> usize {
        self.shared.workers.len()
    }

    pub fn alive_workers(&self) -> usize {
        self.shared.workers.iter()
            .filter(|h| h.load().is_alive()).count()
    }

    /// Cluster exposition: rollup across workers, cluster routing and
    /// failover counters, then every worker's own metrics re-labeled
    /// with `worker="i"`.
    pub fn metrics(&self) -> String {
        let mut texts = Vec::new();
        let mut per_worker = String::new();
        for (w, h) in self.shared.workers.iter().enumerate() {
            if let Ok(text) = h.metrics() {
                per_worker.push_str(&relabel(&text, w));
                texts.push(text);
            }
        }
        let mut out = rollup(&texts);
        {
            let mut st = self.shared.state.lock().unwrap();
            self.reap(&mut st);
            let alive = st.dead.iter().filter(|d| !**d).count();
            out.push_str(&format!(
                "bitdelta_cluster_workers_alive {alive}\n\
                 bitdelta_cluster_failovers_total {}\n\
                 bitdelta_cluster_replaced_tenants_total {}\n",
                st.failovers, st.replaced_tenants));
            for (w, r) in st.routed.iter().enumerate() {
                out.push_str(&format!(
                    "bitdelta_cluster_routed_total{{worker=\"{w}\"}} \
{r}\n"));
            }
        }
        out.push_str(&per_worker);
        out
    }

    // -- internals --------------------------------------------------------

    /// Choose the worker for one request (reaps dead workers first).
    fn pick(&self, tenant: &str) -> Result<usize> {
        let mut st = self.shared.state.lock().unwrap();
        self.reap(&mut st);
        let mut cands: Vec<usize> = st.placement.workers_of(tenant)
            .iter().copied().filter(|&w| !st.dead[w]).collect();
        if cands.is_empty() {
            // unknown tenant, or every replica died and re-placement
            // degraded: every engine registers every tenant, so any
            // alive worker can still serve it
            cands = (0..self.shared.workers.len())
                .filter(|&w| !st.dead[w]).collect();
        }
        if cands.is_empty() {
            bail!("cluster has no alive workers");
        }
        // a typed RouteError (empty replica set mid-failover) surfaces
        // as a request error on the caller side, not a routing panic
        Ok(self.shared.policy.route(tenant, &cands,
                                    &LiveLoads(&self.shared.workers))?)
    }

    fn mark_dead(&self, w: usize) {
        let mut st = self.shared.state.lock().unwrap();
        if !st.dead[w] {
            st.dead[w] = true;
            st.failovers += 1;
            self.replace(&mut st);
        }
    }

    /// Notice workers whose threads exited since the last call.
    fn reap(&self, st: &mut RouteState) {
        let mut newly_dead = false;
        for (w, h) in self.shared.workers.iter().enumerate() {
            if !st.dead[w] && !h.load().is_alive() {
                st.dead[w] = true;
                st.failovers += 1;
                newly_dead = true;
            }
        }
        if newly_dead {
            self.replace(st);
        }
    }

    /// Re-place every tenant across the surviving workers.
    fn replace(&self, st: &mut RouteState) {
        let alive: Vec<WorkerSpec> = self.shared.specs.iter()
            .filter(|s| !st.dead[s.index]).cloned().collect();
        if alive.is_empty() {
            return;
        }
        let moved = self.shared.profiles.iter().filter(|t| {
            st.placement.workers_of(&t.name).iter()
                .any(|&w| st.dead[w])
        }).count() as u64;
        st.replaced_tenants += moved;
        st.placement =
            match self.shared.policy.place(&self.shared.profiles, &alive) {
                Ok(p) => p,
                Err(_) => {
                    // survivors' budgets cannot hold a policy-respecting
                    // placement — degrade to everything-everywhere
                    let mut p = Placement::default();
                    for t in &self.shared.profiles {
                        for s in &alive {
                            p.add(&t.name, s.index, t.resident_bytes);
                        }
                    }
                    p
                }
            };
    }
}

/// Build tenant profiles from the manifest: one per tenant of `ecfg`'s
/// model, codec resolved like the engine resolves it, `resident_bytes`
/// estimated from the artifact's on-disk size (the loaded payload is
/// within a few percent for every in-tree codec), uniform weights.
/// Tenants with a fidelity tier in `ecfg.tenant_levels` are sized
/// exactly from the config shapes (`DeltaFile::delta_bytes_for`) — the
/// delta-aware packer sees the level-scaled residency the worker's
/// store will charge after truncating to the tier, with no artifact
/// I/O. Sorted by name so placement is deterministic.
pub fn tenant_profiles(ecfg: &EngineConfig) -> Result<Vec<TenantProfile>> {
    let manifest = Manifest::load(&ecfg.artifacts_dir)?;
    let registry = CodecRegistry::builtin();
    let default_codec = registry.get(&ecfg.default_codec_name())?;
    let mut names: Vec<&String> = manifest.tenants.keys().collect();
    names.sort();
    let mut out = Vec::new();
    for name in names {
        let t = &manifest.tenants[name];
        if t.config != ecfg.model {
            continue;
        }
        let codec = match ecfg.codec_overrides.get(name) {
            Some(c) => registry.get(c)?,
            None => default_codec.clone(),
        };
        let levels = ecfg.tenant_levels.get(name.as_str()).copied()
            .unwrap_or(1);
        // a tenant with no artifact in its codec truly costs 0 bytes
        // (nothing will ever be loaded for it) — but an artifact that
        // exists in the manifest and cannot be sized is an error, or
        // the delta-aware budget guarantees would silently evaporate
        let resident_bytes = match codec
            .artifact_path(&manifest, t, ecfg.distilled, levels) {
            None if levels > 1 => bail!(
                "tenant {name}: no {levels}-level artifact under codec \
{:?} — cannot place a fidelity tier it cannot serve", codec.name()),
            None => 0,
            Some(_) if levels > 1 => {
                // level-scaled: the fidelity artifact carries more
                // levels than the tier serves, so its file size
                // over-counts; the truncated payload's residency is
                // exactly derivable from the config shapes — no
                // artifact I/O at cluster spawn
                let cfg = manifest.config(&ecfg.model)?;
                crate::store::delta_file::DeltaFile::delta_bytes_for(
                    cfg, levels)
            }
            Some(p) => std::fs::metadata(&p).with_context(|| format!(
                "sizing delta artifact {} for tenant {name}",
                p.display()))?.len() as usize,
        };
        out.push(TenantProfile {
            name: name.clone(),
            codec: codec.name().to_string(),
            resident_bytes,
            weight: 0.0,
            levels,
        });
    }
    if out.is_empty() {
        bail!("no tenants for model {} in the manifest", ecfg.model);
    }
    let w = 1.0 / out.len() as f64;
    for t in &mut out {
        t.weight = w;
    }
    Ok(out)
}

/// Overwrite profile weights from per-trace-rank request counts:
/// trace rank `i` maps onto profile `i % len` (the same mapping the
/// loadtest replay uses), so the delta-aware policy replicates exactly
/// the tenants the trace actually hammers.
pub fn apply_trace_weights(profiles: &mut [TenantProfile],
                           counts: &[usize]) {
    if profiles.is_empty() {
        return;
    }
    let mut per = vec![0usize; profiles.len()];
    for (i, &c) in counts.iter().enumerate() {
        per[i % profiles.len()] += c;
    }
    let total: usize = per.iter().sum();
    if total == 0 {
        return;
    }
    for (t, &c) in profiles.iter_mut().zip(&per) {
        t.weight = c as f64 / total as f64;
    }
}

/// Aggregate result of a multi-threaded trace replay.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    /// Request latencies in seconds, sorted ascending.
    pub latencies: Vec<f64>,
    pub tokens: usize,
    pub errors: usize,
    pub wall_seconds: f64,
}

impl ReplayReport {
    pub fn served(&self) -> usize {
        self.latencies.len()
    }

    /// Aggregate decode throughput over the whole replay.
    pub fn tok_per_s(&self) -> f64 {
        self.tokens as f64 / self.wall_seconds.max(1e-9)
    }

    pub fn quantile_ms(&self, q: f64) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        let i = ((self.latencies.len() - 1) as f64 * q) as usize;
        self.latencies[i] * 1e3
    }
}

/// Replay a workload trace against the cluster from `clients` threads,
/// honoring arrival times (open loop): client `c` takes events
/// `c, c+clients, …`, sleeps until each event's `at`, submits without
/// blocking, then collects every response. Trace tenant ranks map onto
/// `names` by `rank % names.len()` — the same fold
/// [`apply_trace_weights`] uses, so routing sees the skew the placement
/// was computed for.
pub fn replay_trace(handle: &ClusterHandle, trace: &[TraceEvent],
                    names: &[String], prompts: &[&str], clients: usize)
                    -> Result<ReplayReport> {
    let clients = clients.max(1);
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let h = handle.clone();
        let names = names.to_vec();
        let prompts: Vec<String> =
            prompts.iter().map(|p| p.to_string()).collect();
        let events: Vec<TraceEvent> =
            trace.iter().skip(c).step_by(clients).cloned().collect();
        joins.push(std::thread::spawn(move || {
            let mut chans = Vec::new();
            let mut errors = 0usize;
            for e in &events {
                let now = t0.elapsed().as_secs_f64();
                if e.at > now {
                    std::thread::sleep(
                        std::time::Duration::from_secs_f64(e.at - now));
                }
                let req = Request {
                    tenant: names[e.tenant % names.len()].clone(),
                    prompt: prompts[e.prompt_idx % prompts.len()]
                        .clone(),
                    max_new_tokens: e.max_new_tokens,
                    sampling: SamplingParams::greedy(),
                };
                match h.submit(req) {
                    Ok(rx) => chans.push(rx),
                    Err(_) => errors += 1,
                }
            }
            let mut latencies = Vec::new();
            let mut tokens = 0usize;
            for rx in chans {
                match rx.recv() {
                    Ok(Ok(r)) => {
                        latencies.push(r.latency.as_secs_f64());
                        tokens += r.tokens.len();
                    }
                    _ => errors += 1,
                }
            }
            (latencies, tokens, errors)
        }));
    }
    let mut report = ReplayReport {
        latencies: Vec::new(),
        tokens: 0,
        errors: 0,
        wall_seconds: 0.0,
    };
    for j in joins {
        let (l, t, e) = j.join()
            .map_err(|_| anyhow!("client thread panicked"))?;
        report.latencies.extend(l);
        report.tokens += t;
        report.errors += e;
    }
    report.wall_seconds = t0.elapsed().as_secs_f64();
    report.latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::Duration;

    use crate::cluster::placement::policy_by_name;
    use crate::cluster::testutil::MockCore;
    use crate::model::sampling::SamplingParams;

    fn req(tenant: &str) -> Request {
        Request { tenant: tenant.into(), prompt: "Q:".into(),
                  max_new_tokens: 4, sampling: SamplingParams::greedy() }
    }

    fn profiles(names: &[&str], bytes: usize) -> Vec<TenantProfile> {
        let w = 1.0 / names.len() as f64;
        names.iter().map(|n| TenantProfile {
            name: n.to_string(), codec: "bitdelta".into(),
            resident_bytes: bytes, weight: w, levels: 1,
        }).collect()
    }

    fn mock_factories(n: usize) -> Vec<CoreFactory> {
        (0..n).map(|i| {
            let f: CoreFactory = Box::new(move || {
                Ok(Box::new(MockCore::new(i)) as Box<dyn WorkerCore>)
            });
            f
        }).collect()
    }

    #[test]
    fn cluster_serves_many_client_threads() {
        let cfg = ClusterConfig {
            policy: policy_by_name("least-loaded").unwrap(),
            delta_budget_bytes: 1 << 20,
        };
        let cluster = Cluster::spawn(
            &cfg, profiles(&["a", "b", "c", "d"], 10),
            mock_factories(2)).unwrap();
        let handle = cluster.handle();
        let tenants = handle.tenants();

        let mut joins = Vec::new();
        for c in 0..3 {
            let h = handle.clone();
            let ts = tenants.clone();
            joins.push(std::thread::spawn(move || {
                (0..5).map(|i| {
                    h.generate(req(&ts[(c + i) % ts.len()]))
                }).collect::<Result<Vec<_>>>()
            }));
        }
        let mut served = 0;
        for j in joins {
            served += j.join().unwrap().unwrap().len();
        }
        assert_eq!(served, 15);

        let m = handle.metrics();
        // rollup sums the per-worker counters
        assert!(m.contains("bitdelta_requests_total 15"), "{m}");
        assert!(m.contains("bitdelta_cluster_workers_alive 2"), "{m}");
        // per-worker relabeled series are also present
        assert!(m.contains("bitdelta_requests_total{worker=\"0\"}"),
                "{m}");
        cluster.shutdown().unwrap();
    }

    #[test]
    fn worker_death_fails_inflight_then_replaces_tenants() {
        let kills: Vec<Arc<AtomicBool>> =
            (0..2).map(|_| Arc::new(AtomicBool::new(false))).collect();
        let factories: Vec<CoreFactory> = (0..2).map(|i| {
            let k = kills[i].clone();
            let f: CoreFactory = Box::new(move || {
                Ok(Box::new(MockCore::new(i).with_kill_switch(k))
                   as Box<dyn WorkerCore>)
            });
            f
        }).collect();
        let cfg = ClusterConfig {
            policy: policy_by_name("delta-aware").unwrap(),
            delta_budget_bytes: 25,
        };
        // two 10 B tenants on two workers with budget 25: the packer
        // spreads them one per worker
        let cluster = Cluster::spawn(&cfg, profiles(&["a", "b"], 10),
                                     factories).unwrap();
        let handle = cluster.handle();
        let placed = handle.placement();
        assert_eq!(placed.workers_of("a").len(), 1);
        assert_eq!(placed.workers_of("b").len(), 1);
        let w_a = placed.workers_of("a")[0];
        assert_ne!(w_a, placed.workers_of("b")[0]);

        // kill tenant a's worker: the in-flight request comes back as
        // an error, not a hang
        kills[w_a].store(true, Ordering::Relaxed);
        assert!(handle.generate(req("a")).is_err());

        // routing notices the death and re-places "a" on the survivor
        let mut ok = None;
        for _ in 0..200 {
            match handle.generate(req("a")) {
                Ok(r) => {
                    ok = Some(r);
                    break;
                }
                Err(_) => std::thread::sleep(Duration::from_millis(2)),
            }
        }
        let r = ok.expect("tenant a never failed over");
        let survivor = 1 - w_a;
        assert_eq!(r.text, format!("w{survivor}"));
        assert_eq!(handle.placement().workers_of("a"), &[survivor][..]);
        assert_eq!(handle.alive_workers(), 1);

        let m = handle.metrics();
        assert!(m.contains("bitdelta_cluster_failovers_total 1"), "{m}");
        assert!(m.contains("bitdelta_cluster_workers_alive 1"), "{m}");
        // the dead worker's engine failed: shutdown reports it
        assert!(cluster.shutdown().is_err());
    }

    #[test]
    fn all_workers_dead_is_an_error_not_a_hang() {
        let kill = Arc::new(AtomicBool::new(false));
        let k = kill.clone();
        let factories: Vec<CoreFactory> = vec![Box::new(move || {
            Ok(Box::new(MockCore::new(0).with_kill_switch(k))
               as Box<dyn WorkerCore>)
        })];
        let cfg = ClusterConfig {
            policy: policy_by_name("affinity").unwrap(),
            delta_budget_bytes: 1 << 20,
        };
        let cluster = Cluster::spawn(&cfg, profiles(&["a"], 10),
                                     factories).unwrap();
        let handle = cluster.handle();
        kill.store(true, Ordering::Relaxed);
        for _ in 0..50 {
            if handle.alive_workers() == 0 {
                break;
            }
            let _ = handle.generate(req("a"));
            std::thread::sleep(Duration::from_millis(2));
        }
        let err = handle.generate(req("a"));
        assert!(err.is_err());
        let _ = cluster.shutdown();
    }

    #[test]
    fn spawn_fails_fast_on_impossible_packing() {
        let cfg = ClusterConfig {
            policy: policy_by_name("delta-aware").unwrap(),
            delta_budget_bytes: 5,
        };
        assert!(Cluster::spawn(&cfg, profiles(&["a"], 10),
                               mock_factories(2)).is_err());
    }

    #[test]
    fn replay_trace_collects_all_responses() {
        let cfg = ClusterConfig {
            policy: policy_by_name("least-loaded").unwrap(),
            delta_budget_bytes: 1 << 20,
        };
        let cluster = Cluster::spawn(&cfg, profiles(&["a", "b"], 10),
                                     mock_factories(2)).unwrap();
        let handle = cluster.handle();
        let trace: Vec<TraceEvent> = (0..10).map(|i| TraceEvent {
            at: 0.0,
            tenant: i % 5,          // ranks fold onto the 2 tenants
            prompt_idx: i,
            max_new_tokens: 4,
        }).collect();
        let names = handle.tenants();
        let r = replay_trace(&handle, &trace, &names, &["Q:"], 3)
            .unwrap();
        assert_eq!(r.served(), 10);
        assert_eq!(r.errors, 0);
        assert_eq!(r.tokens, 40);
        assert!(r.quantile_ms(0.99) >= r.quantile_ms(0.5));
        assert!(r.tok_per_s() > 0.0);
        cluster.shutdown().unwrap();
    }

    #[test]
    fn trace_weights_fold_onto_profiles() {
        let mut ps = profiles(&["a", "b", "c"], 10);
        // ranks 0..5 fold mod 3: a gets ranks 0+3, b 1+4, c 2
        apply_trace_weights(&mut ps, &[10, 4, 2, 2, 2, 0]);
        assert!((ps[0].weight - 12.0 / 20.0).abs() < 1e-9);
        assert!((ps[1].weight - 6.0 / 20.0).abs() < 1e-9);
        assert!((ps[2].weight - 2.0 / 20.0).abs() < 1e-9);
    }
}
