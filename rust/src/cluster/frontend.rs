//! The cluster: an **elastic** set of worker engines behind one
//! `Clone + Send` handle.
//!
//! [`Cluster::spawn`] computes an initial tenant placement (fail-fast
//! if the deltas cannot be packed), then starts one worker thread per
//! core factory. [`ClusterHandle`] routes each request to one of the
//! tenant's placed workers via the configured
//! [`PlacementPolicy`]; any number of client threads may submit
//! concurrently.
//!
//! **Admission**: when [`ClusterConfig::admission`] is set, every
//! request passes the cluster-level [`AdmissionGate`] before routing —
//! a global in-flight budget with per-tenant fairness. Overload sheds
//! as typed [`AdmissionError`] rejections (the caller's HTTP
//! 429-equivalent) instead of growing queues without bound; the permit
//! rides inside the returned [`ClusterTicket`] and frees its slot when
//! the ticket is dropped.
//!
//! **Elasticity**: a cluster spawned through [`Cluster::spawn_elastic`]
//! (or [`Cluster::spawn_engines`]) can grow and shrink at runtime —
//! [`ClusterHandle::spawn_worker`] adds a worker and re-places tenants
//! onto it; [`ClusterHandle::retire_worker`] removes one via **graceful
//! drain**: routing stops, the worker's tenants move to the survivors,
//! in-flight sequences run to completion (no KV-cache loss, unlike
//! failover), and only then is the thread joined. The
//! [`crate::cluster::autoscaler`] drives both from the live load
//! signals workers already publish. Slot indices are stable forever;
//! terminal slots are *compacted* to tombstones
//! ([`ClusterHandle::compact_slots`]) so a long-lived cluster's slot
//! table holds resources only for live workers.
//!
//! **Failover**: a worker that dies (engine error or panic) drops its
//! `alive` flag; in-flight requests on it are answered with errors (the
//! worker loop fails them before exiting, and a vanished reply channel
//! surfaces as an error on the caller side — never a hang). The next
//! routing decision notices the death, re-places the dead worker's
//! tenants across the survivors with the same policy, and bumps the
//! failover counters. If the survivors' budgets can no longer hold a
//! policy-respecting placement, routing degrades to
//! everything-everywhere — availability over budget.

use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::clock::{self, Instant};
use crate::sync::thread::JoinHandle;
use crate::sync::{lock, mpsc, thread, Arc, Mutex};

use crate::cluster::metrics::{relabel, rollup};
use crate::cluster::placement::{
    LoadView, Placement, PlacementPolicy, RouteError, TenantProfile,
    WorkerSpec,
};
use crate::cluster::worker::{
    spawn_worker, CoreFactory, WorkerCore, WorkerHandle,
};
use crate::config::Manifest;
use crate::coordinator::admission::{
    AdmissionError, AdmissionGate, AdmissionPermit, AdmissionPolicy,
};
use crate::coordinator::metrics::Histogram;
use crate::coordinator::workload::TraceEvent;
use crate::delta::codec::CodecRegistry;
use crate::model::sampling::SamplingParams;
use crate::serving::engine::{Engine, EngineConfig};
use crate::serving::request::{Request, Response};

/// Factory-of-factories for elastic clusters: called with a fresh
/// worker id whenever the cluster scales up, it returns the
/// [`CoreFactory`] that will build that worker's core *on* the new
/// thread (the PJRT constraint, same as at initial spawn).
pub type WorkerFactoryFn =
    Box<dyn Fn(usize) -> CoreFactory + Send + Sync>;

/// Cluster construction parameters.
pub struct ClusterConfig {
    pub policy: Arc<dyn PlacementPolicy>,
    /// Per-worker delta residency budget, bytes (each worker's
    /// [`crate::coordinator::deltastore::DeltaStore`] budget, and the
    /// bin the delta-aware policy packs against).
    pub delta_budget_bytes: usize,
    /// Cluster-front-door admission control; `None` accepts everything
    /// (per-worker queue caps still apply downstream).
    pub admission: Option<AdmissionPolicy>,
}

/// Lifecycle of one worker slot. Slots are append-only so worker
/// indices stay stable across scale events (placements, metrics labels
/// and routing state all key on the index).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerState {
    /// Routable and serving.
    Active,
    /// Graceful drain in progress: no longer routable, finishing its
    /// in-flight work before the thread is joined.
    Draining,
    /// Cleanly drained and joined by a scale-down. Not a failure.
    Retired,
    /// Died (engine error or panic); its in-flight requests were
    /// errored and its tenants failed over.
    Dead,
}

/// The resource-holding half of a slot: the channel handle, the thread
/// join handle, and the placement spec. Dropped wholesale when a
/// terminal slot is compacted.
struct LiveWorker {
    handle: WorkerHandle,
    join: Option<JoinHandle<Result<()>>>,
    spec: WorkerSpec,
}

/// One worker's slot in the cluster table. Slots are never removed
/// (indices are the stable external identity placements, routing state
/// and metrics labels key on), but a **terminal** slot (Retired or
/// Dead) can be *compacted*: its [`LiveWorker`] — channel, thread
/// handle, spec — is dropped, leaving a tombstone that still answers
/// lifecycle and metrics queries. A month-long elastic cluster that
/// scaled up and down thousands of times keeps a bounded footprint
/// instead of accreting dead worker handles.
struct Slot {
    /// `Some` while the worker holds real resources; `None` once a
    /// terminal slot has been compacted.
    live: Option<LiveWorker>,
    state: WorkerState,
    routed: u64,
}

impl Slot {
    /// Routable: Active *and* its thread still running — the one
    /// predicate routing, load sampling, and the metrics counts all
    /// share. (An Active slot whose thread has exited is dead but not
    /// yet reaped; a compacted slot is terminal, hence never Active.)
    fn routable(&self) -> bool {
        self.state == WorkerState::Active
            && self.live.as_ref()
                .map_or(false, |l| l.handle.load().is_alive())
    }

    fn handle(&self) -> Option<&WorkerHandle> {
        self.live.as_ref().map(|l| &l.handle)
    }
}

/// Routing + lifecycle state behind the handle's mutex (everything the
/// per-request hot path needs is either here or in lock-free
/// [`WorkerLoad`] atomics).
///
/// [`WorkerLoad`]: crate::cluster::worker::WorkerLoad
struct ClusterState {
    slots: Vec<Slot>,
    /// The tenant set the placement was computed from. Behind the
    /// mutex (not in [`Shared`]) because delta churn can swap it at
    /// runtime via [`ClusterHandle::update_tenants`].
    profiles: Vec<TenantProfile>,
    placement: Placement,
    /// The last re-placement fell back to everything-everywhere
    /// (active budgets could not hold a policy-respecting placement).
    /// While set, per-worker budget accounting is knowingly violated —
    /// availability over budget.
    degraded: bool,
    failovers: u64,
    replaced_tenants: u64,
    scale_ups: u64,
    scale_downs: u64,
    /// Graceful-drain durations (scale-down only; failover is not a
    /// drain).
    drain: Histogram,
}

impl ClusterState {
    fn active_count(&self) -> usize {
        self.slots.iter()
            .filter(|s| s.state == WorkerState::Active).count()
    }
}

struct Shared {
    policy: Arc<dyn PlacementPolicy>,
    delta_budget_bytes: usize,
    /// Present only for elastic clusters; fixed clusters cannot grow.
    factory_fn: Option<WorkerFactoryFn>,
    admission: Option<AdmissionGate>,
    /// Monotonic id for naming newly spawned workers (never reused,
    /// unlike slot indices which are stable but also never reused).
    next_worker_id: AtomicUsize,
    state: Mutex<ClusterState>,
}

/// Live load view over the slots' published atomics.
struct SlotLoads<'a>(&'a [Slot]);

impl LoadView for SlotLoads<'_> {
    fn score(&self, worker: usize) -> usize {
        self.0.get(worker).and_then(|s| s.handle())
            .map(|h| h.load().score())
            .unwrap_or(usize::MAX)
    }
}

/// The running cluster. Worker threads are owned by the shared state so
/// scale events can join them individually; [`Cluster::shutdown`]
/// drains and joins whatever is still running.
pub struct Cluster {
    handle: ClusterHandle,
}

/// Cloneable, `Send + Sync` front-end to the cluster.
#[derive(Clone)]
pub struct ClusterHandle {
    shared: Arc<Shared>,
}

/// A consistent routing-state snapshot (one lock acquisition) — see
/// [`ClusterHandle::routing_snapshot`]. This is what the simulation
/// harness's invariant monitor reads: checking placement against a
/// routable set captured at a different instant would report phantom
/// violations around every failover.
#[derive(Debug, Clone)]
pub struct RoutingSnapshot {
    pub placement: Placement,
    /// Slot indices that are Active with a live thread.
    pub routable: Vec<usize>,
    /// The placement is the everything-everywhere fallback: per-worker
    /// budget accounting is knowingly suspended until a policy
    /// placement fits again.
    pub degraded: bool,
}

/// One submitted request: the response channel plus (when cluster
/// admission is on) the in-flight permit, released when the ticket is
/// dropped — normally right after [`ClusterTicket::recv`] returns.
pub struct ClusterTicket {
    rx: mpsc::Receiver<Result<Response>>,
    _permit: Option<AdmissionPermit>,
}

impl ClusterTicket {
    /// Block until the response arrives (consumes the ticket, releasing
    /// the admission slot).
    pub fn recv(self) -> Result<Response> {
        self.rx.recv()
            .map_err(|_| anyhow!("worker dropped the request"))?
    }

    /// Non-blocking poll; `None` while the request is still in flight.
    /// A vanished worker (dropped reply channel) surfaces as an error,
    /// same as [`ClusterTicket::recv`] — never as a permanent `None`.
    pub fn try_recv(&self) -> Option<Result<Response>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(anyhow!("worker dropped the request")))
            }
        }
    }
}

impl Cluster {
    /// Start one worker per factory; tenant placement is computed first
    /// so an impossible packing fails before any engine loads. A
    /// fixed-factory cluster cannot scale up (no way to mint new
    /// cores); use [`Cluster::spawn_elastic`] for that.
    pub fn spawn(cfg: &ClusterConfig, profiles: Vec<TenantProfile>,
                 factories: Vec<CoreFactory>) -> Result<Self> {
        Self::spawn_inner(cfg, profiles, factories, None)
    }

    /// Start an elastic cluster: `initial` workers now, and the
    /// factory-of-factories kept for [`ClusterHandle::spawn_worker`] to
    /// mint more at runtime.
    pub fn spawn_elastic(cfg: &ClusterConfig,
                         profiles: Vec<TenantProfile>, initial: usize,
                         make: WorkerFactoryFn) -> Result<Self> {
        let factories: Vec<CoreFactory> =
            (0..initial).map(|i| make(i)).collect();
        Self::spawn_inner(cfg, profiles, factories, Some(make))
    }

    fn spawn_inner(cfg: &ClusterConfig, profiles: Vec<TenantProfile>,
                   factories: Vec<CoreFactory>,
                   factory_fn: Option<WorkerFactoryFn>) -> Result<Self> {
        if factories.is_empty() {
            bail!("cluster needs at least one worker");
        }
        let n = factories.len();
        let specs: Vec<WorkerSpec> = (0..n).map(|index| WorkerSpec {
            index,
            delta_budget_bytes: cfg.delta_budget_bytes,
        }).collect();
        let placement = cfg.policy.place(&profiles, &specs)?;

        let mut slots = Vec::with_capacity(n);
        for (i, f) in factories.into_iter().enumerate() {
            let (handle, join) =
                spawn_worker(format!("bitdelta-worker-{i}"), f)?;
            slots.push(Slot {
                live: Some(LiveWorker {
                    handle,
                    join: Some(join),
                    spec: specs[i].clone(),
                }),
                state: WorkerState::Active,
                routed: 0,
            });
        }
        let shared = Arc::new(Shared {
            policy: cfg.policy.clone(),
            delta_budget_bytes: cfg.delta_budget_bytes,
            factory_fn,
            admission: cfg.admission.map(AdmissionGate::new),
            next_worker_id: AtomicUsize::new(n),
            state: Mutex::new(ClusterState {
                slots,
                profiles,
                placement,
                degraded: false,
                failovers: 0,
                replaced_tenants: 0,
                scale_ups: 0,
                scale_downs: 0,
                drain: Histogram::default(),
            }),
        });
        Ok(Self { handle: ClusterHandle { shared } })
    }

    /// Engine-backed cluster: every worker runs its own [`Engine`] built
    /// from `ecfg` with the cluster's per-worker delta budget. Elastic:
    /// the autoscaler can mint additional engine workers from the same
    /// config.
    pub fn spawn_engines(cfg: &ClusterConfig, ecfg: &EngineConfig,
                         n_workers: usize,
                         profiles: Vec<TenantProfile>) -> Result<Self> {
        let mut wcfg = ecfg.clone();
        wcfg.delta_budget_bytes = cfg.delta_budget_bytes;
        let make: WorkerFactoryFn = Box::new(move |_id| {
            let wcfg = wcfg.clone();
            let f: CoreFactory = Box::new(move || {
                Ok(Box::new(Engine::from_artifacts(wcfg)?)
                   as Box<dyn WorkerCore>)
            });
            f
        });
        Self::spawn_elastic(cfg, profiles, n_workers, make)
    }

    pub fn handle(&self) -> ClusterHandle {
        self.handle.clone()
    }

    /// Drain every remaining worker and join the threads. The first
    /// worker error (e.g. a death that already triggered failover) is
    /// returned; cleanly retired workers were already joined by their
    /// scale-down and don't participate.
    pub fn shutdown(self) -> Result<()> {
        let joins: Vec<JoinHandle<Result<()>>> = {
            let mut st = lock(&self.handle.shared.state);
            let mut joins = Vec::new();
            for slot in st.slots.iter_mut() {
                let Some(live) = slot.live.as_mut() else {
                    continue; // compacted: joined long ago
                };
                if matches!(slot.state, WorkerState::Active
                            | WorkerState::Draining) {
                    live.handle.shutdown_signal();
                }
                if let Some(j) = live.join.take() {
                    joins.push(j);
                }
            }
            joins
        };
        let mut first_err: Option<anyhow::Error> = None;
        for j in joins {
            let r = match j.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow!("worker thread panicked")),
            };
            if let Err(e) = r {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl ClusterHandle {
    /// Submit a request; the response arrives through the returned
    /// ticket. The request first passes cluster admission (if
    /// configured) — a rejection is a typed [`AdmissionError`]
    /// downcastable from the returned error. Routing retries across
    /// workers when a send hits a dead one, but a request already
    /// accepted by a worker that then dies comes back as an error (no
    /// silent cross-worker replay of maybe-executed work).
    pub fn submit(&self, req: Request) -> Result<ClusterTicket> {
        let permit = match &self.shared.admission {
            Some(gate) => {
                Some(gate.try_admit(&req.tenant)
                         .map_err(anyhow::Error::new)?)
            }
            None => None,
        };
        // terminates: pick_locked only returns routable (Active +
        // alive) workers, and every failed send flips its worker to
        // Dead under the same lock — so each iteration either returns
        // or strictly shrinks the active set, until pick_locked
        // reports "no alive workers"
        loop {
            let mut st = lock(&self.shared.state);
            self.reap(&mut st);
            let w = self.pick_locked(&st, &req.tenant)?;
            // the channel send happens under the state lock so a
            // graceful drain (which marks the slot Draining under the
            // same lock, *then* signals shutdown) can never interleave:
            // every routed request is ordered before the drain command
            // and completes — the zero-error guarantee of scale-down
            // pick_locked only returns routable slots, which are live
            let sent = st.slots[w].handle()
                .map(|h| h.submit(req.clone()));
            match sent {
                Some(Ok(rx)) => {
                    st.slots[w].routed += 1;
                    return Ok(ClusterTicket { rx, _permit: permit });
                }
                _ => self.mark_dead_locked(&mut st, w),
            }
        }
    }

    /// Submit and block until the response arrives.
    pub fn generate(&self, req: Request) -> Result<Response> {
        self.submit(req)?.recv()
    }

    /// Tenants the cluster places (sorted at profile construction).
    pub fn tenants(&self) -> Vec<String> {
        lock(&self.shared.state).profiles.iter()
            .map(|t| t.name.clone()).collect()
    }

    /// Snapshot of the current placement.
    pub fn placement(&self) -> Placement {
        let mut st = lock(&self.shared.state);
        self.reap(&mut st);
        st.placement.clone()
    }

    /// One consistent routing snapshot — placement, routable slots and
    /// the degraded flag read under a single lock acquisition (with a
    /// reap first), so an invariant checker never sees a placement
    /// from before a failover paired with a routable set from after.
    pub fn routing_snapshot(&self) -> RoutingSnapshot {
        let mut st = lock(&self.shared.state);
        self.reap(&mut st);
        RoutingSnapshot {
            placement: st.placement.clone(),
            routable: st.slots.iter().enumerate()
                .filter(|(_, s)| s.routable())
                .map(|(w, _)| w).collect(),
            degraded: st.degraded,
        }
    }

    /// The last re-placement degraded to everything-everywhere (see
    /// [`RoutingSnapshot::degraded`]).
    pub fn placement_degraded(&self) -> bool {
        lock(&self.shared.state).degraded
    }

    /// Per-slot lifetime routed counts, indexed by slot. Every
    /// successful [`Self::submit`] increments exactly one slot's count
    /// under the routing lock, so the sum equals the number of
    /// successfully routed requests — the no-double-routing invariant
    /// the simulation monitor checks.
    pub fn routed_counts(&self) -> Vec<u64> {
        lock(&self.shared.state).slots.iter()
            .map(|s| s.routed).collect()
    }

    /// The per-worker delta residency budget the cluster packs against.
    pub fn delta_budget_bytes(&self) -> usize {
        self.shared.delta_budget_bytes
    }

    /// Live in-flight count of the cluster admission gate (`None`
    /// without one).
    pub fn admission_in_flight(&self) -> Option<usize> {
        self.shared.admission.as_ref().map(|g| g.in_flight())
    }

    /// Replace the tenant population and re-place it across the
    /// active workers — the delta hot-churn path: a model update
    /// re-weights and re-sizes deltas, and placement must follow
    /// without a cluster restart. Profiles are sorted by name (same
    /// normalization as [`tenant_profiles`]) so placement stays
    /// deterministic. Requests for tenants no longer in the set still
    /// route (any active worker serves unknown tenants), they just
    /// lose their placement affinity.
    pub fn update_tenants(&self, mut profiles: Vec<TenantProfile>)
                          -> Result<()> {
        if profiles.is_empty() {
            bail!("update_tenants: refusing an empty tenant set");
        }
        profiles.sort_by(|a, b| a.name.cmp(&b.name));
        let mut st = lock(&self.shared.state);
        self.reap(&mut st);
        st.profiles = profiles;
        self.replace(&mut st);
        Ok(())
    }

    /// Total worker slots ever created (including retired and dead
    /// ones — slot indices are stable and never reused).
    pub fn n_workers(&self) -> usize {
        lock(&self.shared.state).slots.len()
    }

    /// Workers currently routable (Active and alive).
    pub fn active_workers(&self) -> usize {
        let st = lock(&self.shared.state);
        st.slots.iter().filter(|s| s.routable()).count()
    }

    /// Alias of [`Self::active_workers`] kept for the failover-era API.
    pub fn alive_workers(&self) -> usize {
        self.active_workers()
    }

    /// Total outstanding work across active workers (queued + batched +
    /// in flight + channel backlog) — the autoscaler's pressure signal.
    /// A dead-but-unreaped worker is excluded: its published load
    /// freezes at whatever it was when the thread exited, and counting
    /// that phantom score would hold the pressure signal above the
    /// watermark forever.
    pub fn outstanding(&self) -> usize {
        let st = lock(&self.shared.state);
        st.slots.iter()
            .filter(|s| s.routable())
            .filter_map(|s| s.handle())
            .map(|h| h.load().score())
            .sum()
    }

    /// Lifetime scale event counts: `(scale-ups, graceful drains)`.
    pub fn scale_events(&self) -> (u64, u64) {
        let st = lock(&self.shared.state);
        (st.scale_ups, st.scale_downs)
    }

    /// The active worker with the least outstanding work — the natural
    /// scale-down victim (shortest drain).
    pub fn least_loaded_active(&self) -> Option<usize> {
        let st = lock(&self.shared.state);
        st.slots.iter().enumerate()
            .filter(|(_, s)| s.routable())
            .filter_map(|(w, s)| s.handle().map(|h| (w, h)))
            .min_by_key(|(w, h)| (h.load().score(), *w))
            .map(|(w, _)| w)
    }

    /// Scale up: mint a new worker from the elastic factory, then
    /// re-place tenants across the enlarged active set. Blocks while
    /// the new worker's core builds (an engine load), without holding
    /// the routing lock. Returns the new worker's slot index.
    pub fn spawn_worker(&self) -> Result<usize> {
        let make = self.shared.factory_fn.as_ref().ok_or_else(|| {
            anyhow!("cluster was spawned with fixed factories — only \
Cluster::spawn_elastic / spawn_engines clusters can scale up")
        })?;
        let id = self.shared.next_worker_id
            .fetch_add(1, Ordering::Relaxed);
        let factory = make(id);
        let (handle, join) =
            spawn_worker(format!("bitdelta-worker-{id}"), factory)?;
        let mut st = lock(&self.shared.state);
        let index = st.slots.len();
        st.slots.push(Slot {
            live: Some(LiveWorker {
                handle,
                join: Some(join),
                spec: WorkerSpec {
                    index,
                    delta_budget_bytes: self.shared.delta_budget_bytes,
                },
            }),
            state: WorkerState::Active,
            routed: 0,
        });
        st.scale_ups += 1;
        self.replace(&mut st);
        Ok(index)
    }

    /// Scale down worker `w` via graceful drain: stop routing to it,
    /// re-place its tenants across the remaining active workers, let
    /// every request it already accepted run to completion, then join
    /// the thread. Zero in-flight requests are lost (unlike failover,
    /// which errors them). Blocks for the drain; returns its duration.
    pub fn retire_worker(&self, w: usize) -> Result<Duration> {
        self.retire_worker_floor(w, 1)
    }

    /// [`Self::retire_worker`] with a floor: refuses to drain below
    /// `min_active` remaining active workers. The floor is checked
    /// under the routing lock, so a worker death between a scale-down
    /// decision and this call cannot sneak the cluster under the bound
    /// (the autoscaler passes its `min_workers` here).
    pub fn retire_worker_floor(&self, w: usize, min_active: usize)
                               -> Result<Duration> {
        let (handle, join) = {
            let mut st = lock(&self.shared.state);
            self.reap(&mut st);
            if st.active_count() <= min_active.max(1) {
                bail!("cannot retire worker {w}: only {} active, \
floor is {}", st.active_count(), min_active.max(1));
            }
            let slot = st.slots.get_mut(w)
                .ok_or_else(|| anyhow!("no worker slot {w}"))?;
            if slot.state != WorkerState::Active {
                bail!("worker {w} is {:?}, not Active", slot.state);
            }
            let live = slot.live.as_mut()
                .ok_or_else(|| anyhow!("worker {w} already compacted"))?;
            // take the join handle before flipping state, so a
            // concurrent shutdown can't leave the slot Draining with
            // nobody to join it
            let join = live.join.take()
                .ok_or_else(|| anyhow!("worker {w} already joining"))?;
            let handle = live.handle.clone();
            slot.state = WorkerState::Draining;
            // tenants leave the draining worker immediately: new
            // requests route to the survivors while the drain runs
            self.replace(&mut st);
            (handle, join)
        };
        let t0 = Instant::now();
        handle.shutdown_signal();
        let result = join.join();
        let drain = t0.elapsed();
        let mut st = lock(&self.shared.state);
        // the slot is terminal either way and its thread was just
        // joined — compact it immediately so a long-lived elastic
        // cluster never accretes dead handles across scale cycles
        st.slots[w].live = None;
        match result {
            Ok(Ok(())) => {
                st.slots[w].state = WorkerState::Retired;
                st.scale_downs += 1;
                st.drain.observe(drain);
                Ok(drain)
            }
            Ok(Err(e)) => {
                // the worker died mid-drain: its pending requests were
                // errored by the pump loop — count it as a failover,
                // not a clean scale-down
                st.slots[w].state = WorkerState::Dead;
                st.failovers += 1;
                Err(e.context(format!("worker {w} died during drain")))
            }
            Err(_) => {
                st.slots[w].state = WorkerState::Dead;
                st.failovers += 1;
                bail!("worker {w} panicked during drain")
            }
        }
    }

    /// Compact every terminal (Retired / Dead) slot whose thread has
    /// already been joined: drop its channel handle, thread handle and
    /// spec, keeping only the tombstone (state + lifetime routed
    /// count). Slot indices never shift, so placements, routing state
    /// and metrics labels stay valid. A dead worker that has **not**
    /// been joined yet is left alone — [`Cluster::shutdown`] still owes
    /// the caller that thread's error. Returns the number of slots
    /// compacted; clean scale-downs compact eagerly, so this is mostly
    /// a sweep for workers that died and were reaped.
    pub fn compact_slots(&self) -> usize {
        let mut st = lock(&self.shared.state);
        self.reap(&mut st);
        let mut n = 0;
        for slot in st.slots.iter_mut() {
            let terminal = matches!(slot.state, WorkerState::Retired
                                    | WorkerState::Dead);
            let joined = slot.live.as_ref()
                .map_or(false, |l| l.join.is_none());
            if terminal && joined {
                slot.live = None;
                n += 1;
            }
        }
        n
    }

    /// Cluster exposition: rollup across workers, cluster routing /
    /// failover / scale / admission series, then every live worker's
    /// own metrics re-labeled with `worker="i"`.
    pub fn metrics(&self) -> String {
        // scrape outside the lock: worker metrics round-trip a channel
        let handles: Vec<(usize, WorkerHandle)> = {
            let st = lock(&self.shared.state);
            st.slots.iter().enumerate()
                .filter(|(_, s)| s.routable())
                .filter_map(|(w, s)| {
                    s.handle().map(|h| (w, h.clone()))
                })
                .collect()
        };
        let mut texts = Vec::new();
        let mut per_worker = String::new();
        for (w, h) in &handles {
            if let Ok(text) = h.metrics() {
                per_worker.push_str(&relabel(&text, *w));
                texts.push(text);
            }
        }
        let mut out = rollup(&texts);
        {
            let mut st = lock(&self.shared.state);
            self.reap(&mut st);
            let active = st.slots.iter()
                .filter(|s| s.routable()).count();
            let draining = st.slots.iter()
                .filter(|s| s.state == WorkerState::Draining).count();
            out.push_str(&format!(
                "bitdelta_cluster_workers_alive {active}\n\
                 bitdelta_cluster_workers_draining {draining}\n\
                 bitdelta_cluster_placement_degraded {}\n\
                 bitdelta_cluster_failovers_total {}\n\
                 bitdelta_cluster_replaced_tenants_total {}\n\
                 bitdelta_cluster_scale_events_total\
{{direction=\"up\"}} {}\n\
                 bitdelta_cluster_scale_events_total\
{{direction=\"down\"}} {}\n",
                st.degraded as u8, st.failovers, st.replaced_tenants,
                st.scale_ups, st.scale_downs));
            out.push_str(&st.drain.bucket_exposition("cluster_drain"));
            out.push_str(&format!(
                "bitdelta_cluster_drain_us_count {}\n\
                 bitdelta_cluster_drain_us_sum {}\n",
                st.drain.count, st.drain.sum_us));
            for (w, slot) in st.slots.iter().enumerate() {
                out.push_str(&format!(
                    "bitdelta_cluster_routed_total{{worker=\"{w}\"}} \
{}\n", slot.routed));
            }
        }
        if let Some(gate) = &self.shared.admission {
            let (tenant, global) = gate.rejected();
            out.push_str(&format!(
                "bitdelta_cluster_admission_inflight {}\n\
                 bitdelta_cluster_admission_rejected_total\
{{reason=\"per_tenant\"}} {tenant}\n\
                 bitdelta_cluster_admission_rejected_total\
{{reason=\"global\"}} {global}\n",
                gate.in_flight()));
        }
        out.push_str(&per_worker);
        out
    }

    // -- internals --------------------------------------------------------

    /// Choose the worker for one request among routable slots.
    fn pick_locked(&self, st: &ClusterState, tenant: &str)
                   -> Result<usize> {
        let routable = |w: usize| {
            st.slots.get(w).map(|s| s.routable()).unwrap_or(false)
        };
        let mut cands: Vec<usize> = st.placement.workers_of(tenant)
            .iter().copied().filter(|&w| routable(w)).collect();
        if cands.is_empty() {
            // unknown tenant, or every replica died and re-placement
            // degraded: every engine registers every tenant, so any
            // active worker can still serve it
            cands = (0..st.slots.len()).filter(|&w| routable(w))
                .collect();
        }
        if cands.is_empty() {
            // typed, like every other routing failure: a churn race
            // (the only replica died between place and route, and no
            // survivor exists) must surface as a downcastable error
            // the caller can distinguish from an engine fault
            return Err(RouteError::NoCandidates {
                tenant: tenant.to_string(),
            }.into());
        }
        // a typed RouteError (empty replica set mid-failover) surfaces
        // as a request error on the caller side, not a routing panic
        Ok(self.shared.policy.route(tenant, &cands,
                                    &SlotLoads(&st.slots))?)
    }

    fn mark_dead_locked(&self, st: &mut ClusterState, w: usize) {
        if st.slots[w].state == WorkerState::Active {
            st.slots[w].state = WorkerState::Dead;
            st.failovers += 1;
            self.replace(st);
        }
    }

    /// Notice active workers whose threads exited since the last call.
    /// Draining workers are excluded: their `alive` flag also clears on
    /// a *clean* drain exit, and their lifecycle belongs to the
    /// `retire_worker` call that is joining them.
    fn reap(&self, st: &mut ClusterState) {
        let mut newly_dead = 0u64;
        for slot in st.slots.iter_mut() {
            if slot.state == WorkerState::Active && !slot.routable() {
                slot.state = WorkerState::Dead;
                newly_dead += 1;
            }
        }
        if newly_dead > 0 {
            st.failovers += newly_dead;
            self.replace(st);
        }
    }

    /// Re-place every tenant across the active workers.
    fn replace(&self, st: &mut ClusterState) {
        let active: Vec<WorkerSpec> = st.slots.iter()
            .filter(|s| s.state == WorkerState::Active)
            .filter_map(|s| s.live.as_ref().map(|l| l.spec.clone()))
            .collect();
        if active.is_empty() {
            return;
        }
        let moved = st.profiles.iter().filter(|t| {
            st.placement.workers_of(&t.name).iter().any(|&w| {
                st.slots.get(w)
                    .map_or(true, |s| s.state != WorkerState::Active)
            })
        }).count() as u64;
        st.replaced_tenants += moved;
        let (placement, degraded) =
            match self.shared.policy.place(&st.profiles, &active) {
                Ok(p) => (p, false),
                Err(_) => {
                    // the active workers' budgets cannot hold a
                    // policy-respecting placement — degrade to
                    // everything-everywhere: availability over budget
                    let mut p = Placement::default();
                    for t in &st.profiles {
                        for s in &active {
                            p.add(&t.name, s.index, t.resident_bytes);
                        }
                    }
                    (p, true)
                }
            };
        st.placement = placement;
        st.degraded = degraded;
    }
}

/// Build tenant profiles from the manifest: one per tenant of `ecfg`'s
/// model, codec resolved like the engine resolves it, `resident_bytes`
/// estimated from the artifact's on-disk size (the loaded payload is
/// within a few percent for every in-tree codec), uniform weights.
/// Tenants with a fidelity tier in `ecfg.tenant_levels` are sized
/// exactly from the config shapes (`DeltaFile::delta_bytes_for`) — the
/// delta-aware packer sees the level-scaled residency the worker's
/// store will charge after truncating to the tier, with no artifact
/// I/O. Sorted by name so placement is deterministic.
pub fn tenant_profiles(ecfg: &EngineConfig) -> Result<Vec<TenantProfile>> {
    let manifest = Manifest::load(&ecfg.artifacts_dir)?;
    let registry = CodecRegistry::builtin();
    let default_codec = registry.get(&ecfg.default_codec_name())?;
    let mut names: Vec<&String> = manifest.tenants.keys().collect();
    names.sort();
    let mut out = Vec::new();
    for name in names {
        let t = &manifest.tenants[name];
        if t.config != ecfg.model {
            continue;
        }
        let codec = match ecfg.codec_overrides.get(name) {
            Some(c) => registry.get(c)?,
            None => default_codec.clone(),
        };
        let levels = ecfg.tenant_levels.get(name.as_str()).copied()
            .unwrap_or(1);
        // a tenant with no artifact in its codec truly costs 0 bytes
        // (nothing will ever be loaded for it) — but an artifact that
        // exists in the manifest and cannot be sized is an error, or
        // the delta-aware budget guarantees would silently evaporate
        let resident_bytes = match codec
            .artifact_path(&manifest, t, ecfg.distilled, levels) {
            None if levels > 1 => bail!(
                "tenant {name}: no {levels}-level artifact under codec \
{:?} — cannot place a fidelity tier it cannot serve", codec.name()),
            None => 0,
            Some(_) if levels > 1 => {
                // level-scaled: the fidelity artifact carries more
                // levels than the tier serves, so its file size
                // over-counts; the truncated payload's residency is
                // exactly derivable from the config shapes — no
                // artifact I/O at cluster spawn
                let cfg = manifest.config(&ecfg.model)?;
                crate::store::delta_file::DeltaFile::delta_bytes_for(
                    cfg, levels)
            }
            Some(p) => std::fs::metadata(&p).with_context(|| format!(
                "sizing delta artifact {} for tenant {name}",
                p.display()))?.len() as usize,
        };
        out.push(TenantProfile {
            name: name.clone(),
            codec: codec.name().to_string(),
            resident_bytes,
            weight: 0.0,
            levels,
        });
    }
    if out.is_empty() {
        bail!("no tenants for model {} in the manifest", ecfg.model);
    }
    let w = 1.0 / out.len() as f64;
    for t in &mut out {
        t.weight = w;
    }
    Ok(out)
}

/// Overwrite profile weights from per-trace-rank request counts:
/// trace rank `i` maps onto profile `i % len` (the same mapping the
/// loadtest replay uses), so the delta-aware policy replicates exactly
/// the tenants the trace actually hammers.
pub fn apply_trace_weights(profiles: &mut [TenantProfile],
                           counts: &[usize]) {
    if profiles.is_empty() {
        return;
    }
    let mut per = vec![0usize; profiles.len()];
    for (i, &c) in counts.iter().enumerate() {
        per[i % profiles.len()] += c;
    }
    let total: usize = per.iter().sum();
    if total == 0 {
        return;
    }
    for (t, &c) in profiles.iter_mut().zip(&per) {
        t.weight = c as f64 / total as f64;
    }
}

/// Aggregate result of a multi-threaded trace replay.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    /// Request latencies in seconds, sorted ascending.
    pub latencies: Vec<f64>,
    pub tokens: usize,
    /// Real request failures (dead worker, dropped channel, …).
    pub errors: usize,
    /// Load shed by cluster admission control (typed rejections) —
    /// counted apart from `errors` because shedding is the intended
    /// overload behavior, not a failure.
    pub rejected: usize,
    pub wall_seconds: f64,
    /// Kernel worker-pool width the engines ran with, so a replay
    /// number can never be quoted without its thread config.
    pub kernel_threads: usize,
    /// Active kernel dispatch tier (`"scalar"`, `"avx2"`, `"neon"`).
    pub dispatch_tier: &'static str,
    /// KV block pool usage summed across workers, scraped from the
    /// cluster rollup when the replay ends. All four stay 0 when the
    /// workers run the dense-slab fallback (no kv series exported).
    pub kv_blocks_used: u64,
    pub kv_blocks_total: u64,
    /// Prefix-cache admissions that reused at least one KV block.
    pub kv_prefix_hits: u64,
    pub kv_prefix_lookups: u64,
}

impl ReplayReport {
    pub fn served(&self) -> usize {
        self.latencies.len()
    }

    /// Fraction of the paged KV pool resident at replay end (0.0 under
    /// the slab fallback).
    pub fn kv_occupancy(&self) -> f64 {
        if self.kv_blocks_total == 0 {
            return 0.0;
        }
        self.kv_blocks_used as f64 / self.kv_blocks_total as f64
    }

    /// Fraction of admissions that reused prefix-cached KV blocks.
    pub fn kv_prefix_hit_rate(&self) -> f64 {
        if self.kv_prefix_lookups == 0 {
            return 0.0;
        }
        self.kv_prefix_hits as f64 / self.kv_prefix_lookups as f64
    }

    /// Aggregate decode throughput over the whole replay.
    pub fn tok_per_s(&self) -> f64 {
        self.tokens as f64 / self.wall_seconds.max(1e-9)
    }

    pub fn quantile_ms(&self, q: f64) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        let i = ((self.latencies.len() - 1) as f64 * q) as usize;
        self.latencies[i] * 1e3
    }
}

/// Replay a workload trace against the cluster from `clients` threads,
/// honoring arrival times (open loop): client `c` takes events
/// `c, c+clients, …`, sleeps until each event's `at`, submits without
/// blocking, then collects every response. Trace tenant ranks map onto
/// `names` by `rank % names.len()` — the same fold
/// [`apply_trace_weights`] uses, so routing sees the skew the placement
/// was computed for. Admission rejections are tallied separately from
/// request errors.
pub fn replay_trace(handle: &ClusterHandle, trace: &[TraceEvent],
                    names: &[String], prompts: &[&str], clients: usize)
                    -> Result<ReplayReport> {
    let clients = clients.max(1);
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let h = handle.clone();
        let names = names.to_vec();
        let prompts: Vec<String> =
            prompts.iter().map(|p| p.to_string()).collect();
        let events: Vec<TraceEvent> =
            trace.iter().skip(c).step_by(clients).cloned().collect();
        joins.push(thread::spawn(move || {
            let mut tickets: Vec<ClusterTicket> = Vec::new();
            let mut latencies = Vec::new();
            let mut tokens = 0usize;
            let mut errors = 0usize;
            let mut rejected = 0usize;
            for e in &events {
                let now = t0.elapsed().as_secs_f64();
                if e.at > now {
                    clock::sleep(Duration::from_secs_f64(e.at - now));
                }
                // collect whatever finished during the wait *before*
                // submitting, so its admission permit frees up first:
                // the gate caps live in-flight work, not cumulative
                // submissions — harvesting after the submit would hold
                // completed requests' permits one event too long and
                // count spurious rejections on an idle cluster
                tickets.retain(|t| match t.try_recv() {
                    None => true,
                    Some(Ok(r)) => {
                        latencies.push(r.latency.as_secs_f64());
                        tokens += r.tokens.len();
                        false
                    }
                    Some(Err(_)) => {
                        errors += 1;
                        false
                    }
                });
                let req = Request {
                    tenant: names[e.tenant % names.len()].clone(),
                    prompt: prompts[e.prompt_idx % prompts.len()]
                        .clone(),
                    max_new_tokens: e.max_new_tokens,
                    sampling: SamplingParams::greedy(),
                };
                match h.submit(req) {
                    Ok(t) => tickets.push(t),
                    Err(e) if e.downcast_ref::<AdmissionError>()
                        .is_some() => rejected += 1,
                    Err(_) => errors += 1,
                }
            }
            for t in tickets {
                match t.recv() {
                    Ok(r) => {
                        latencies.push(r.latency.as_secs_f64());
                        tokens += r.tokens.len();
                    }
                    Err(_) => errors += 1,
                }
            }
            (latencies, tokens, errors, rejected)
        }));
    }
    let mut report = ReplayReport {
        latencies: Vec::new(),
        tokens: 0,
        errors: 0,
        rejected: 0,
        wall_seconds: 0.0,
        kernel_threads: crate::gemm::dispatch::pool_threads(),
        dispatch_tier: crate::gemm::dispatch::active_tier().name(),
        kv_blocks_used: 0,
        kv_blocks_total: 0,
        kv_prefix_hits: 0,
        kv_prefix_lookups: 0,
    };
    for j in joins {
        let (l, t, e, rj) = j.join()
            .map_err(|_| anyhow!("client thread panicked"))?;
        report.latencies.extend(l);
        report.tokens += t;
        report.errors += e;
        report.rejected += rj;
    }
    report.wall_seconds = t0.elapsed().as_secs_f64();
    report.latencies.sort_by(|a, b| a.total_cmp(b));
    // scrape KV paging occupancy from the cluster rollup so the report
    // carries cache behavior beside its latency quantiles
    let m = handle.metrics();
    report.kv_blocks_used = scrape(&m, "bitdelta_kv_blocks_used");
    report.kv_blocks_total = scrape(&m, "bitdelta_kv_blocks_total");
    report.kv_prefix_hits = scrape(&m, "bitdelta_kv_prefix_hits_total");
    report.kv_prefix_lookups =
        scrape(&m, "bitdelta_kv_prefix_lookups_total");
    Ok(report)
}

/// First un-labeled `name <value>` sample in a Prometheus exposition
/// (the rollup section precedes the `{worker=…}` relabels, so this
/// reads the cluster-wide sum). Missing series read as 0.
fn scrape(exposition: &str, name: &str) -> u64 {
    exposition.lines()
        .filter_map(|l| l.trim().strip_prefix(name))
        .filter_map(|rest| rest.strip_prefix(' '))
        .find_map(|v| v.trim().parse::<f64>().ok())
        .unwrap_or(0.0) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    use crate::sync::atomic::AtomicBool;

    use crate::cluster::placement::policy_by_name;
    use crate::cluster::testutil::{elastic_mock, profiles, req,
                                   MockCore};

    fn mock_factories(n: usize) -> Vec<CoreFactory> {
        (0..n).map(|i| {
            let f: CoreFactory = Box::new(move || {
                Ok(Box::new(MockCore::new(i)) as Box<dyn WorkerCore>)
            });
            f
        }).collect()
    }

    fn cfg(policy: &str) -> ClusterConfig {
        ClusterConfig {
            policy: policy_by_name(policy).unwrap(),
            delta_budget_bytes: 1 << 20,
            admission: None,
        }
    }

    #[test]
    fn cluster_serves_many_client_threads() {
        let cluster = Cluster::spawn(
            &cfg("least-loaded"), profiles(&["a", "b", "c", "d"], 10),
            mock_factories(2)).unwrap();
        let handle = cluster.handle();
        let tenants = handle.tenants();

        let mut joins = Vec::new();
        for c in 0..3 {
            let h = handle.clone();
            let ts = tenants.clone();
            joins.push(thread::spawn(move || {
                (0..5).map(|i| {
                    h.generate(req(&ts[(c + i) % ts.len()]))
                }).collect::<Result<Vec<_>>>()
            }));
        }
        let mut served = 0;
        for j in joins {
            served += j.join().unwrap().unwrap().len();
        }
        assert_eq!(served, 15);

        let m = handle.metrics();
        // rollup sums the per-worker counters
        assert!(m.contains("bitdelta_requests_total 15"), "{m}");
        assert!(m.contains("bitdelta_cluster_workers_alive 2"), "{m}");
        // per-worker relabeled series are also present
        assert!(m.contains("bitdelta_requests_total{worker=\"0\"}"),
                "{m}");
        cluster.shutdown().unwrap();
    }

    #[test]
    fn worker_death_fails_inflight_then_replaces_tenants() {
        let kills: Vec<Arc<AtomicBool>> =
            (0..2).map(|_| Arc::new(AtomicBool::new(false))).collect();
        let factories: Vec<CoreFactory> = (0..2).map(|i| {
            let k = kills[i].clone();
            let f: CoreFactory = Box::new(move || {
                Ok(Box::new(MockCore::new(i).with_kill_switch(k))
                   as Box<dyn WorkerCore>)
            });
            f
        }).collect();
        let cfg = ClusterConfig {
            policy: policy_by_name("delta-aware").unwrap(),
            delta_budget_bytes: 25,
            admission: None,
        };
        // two 10 B tenants on two workers with budget 25: the packer
        // spreads them one per worker
        let cluster = Cluster::spawn(&cfg, profiles(&["a", "b"], 10),
                                     factories).unwrap();
        let handle = cluster.handle();
        let placed = handle.placement();
        assert_eq!(placed.workers_of("a").len(), 1);
        assert_eq!(placed.workers_of("b").len(), 1);
        let w_a = placed.workers_of("a")[0];
        assert_ne!(w_a, placed.workers_of("b")[0]);

        // kill tenant a's worker: the in-flight request comes back as
        // an error, not a hang
        kills[w_a].store(true, Ordering::Relaxed);
        assert!(handle.generate(req("a")).is_err());

        // routing notices the death and re-places "a" on the survivor
        let mut ok = None;
        for _ in 0..200 {
            match handle.generate(req("a")) {
                Ok(r) => {
                    ok = Some(r);
                    break;
                }
                Err(_) => clock::sleep(Duration::from_millis(2)),
            }
        }
        let r = ok.expect("tenant a never failed over");
        let survivor = 1 - w_a;
        assert_eq!(r.text, format!("w{survivor}"));
        assert_eq!(handle.placement().workers_of("a"), &[survivor][..]);
        assert_eq!(handle.alive_workers(), 1);

        let m = handle.metrics();
        assert!(m.contains("bitdelta_cluster_failovers_total 1"), "{m}");
        assert!(m.contains("bitdelta_cluster_workers_alive 1"), "{m}");
        // the dead worker's engine failed: shutdown reports it
        assert!(cluster.shutdown().is_err());
    }

    #[test]
    fn all_workers_dead_is_an_error_not_a_hang() {
        let kill = Arc::new(AtomicBool::new(false));
        let k = kill.clone();
        let factories: Vec<CoreFactory> = vec![Box::new(move || {
            Ok(Box::new(MockCore::new(0).with_kill_switch(k))
               as Box<dyn WorkerCore>)
        })];
        let cluster = Cluster::spawn(&cfg("affinity"),
                                     profiles(&["a"], 10),
                                     factories).unwrap();
        let handle = cluster.handle();
        kill.store(true, Ordering::Relaxed);
        for _ in 0..50 {
            if handle.alive_workers() == 0 {
                break;
            }
            let _ = handle.generate(req("a"));
            clock::sleep(Duration::from_millis(2));
        }
        let err = handle.generate(req("a")).unwrap_err();
        // no survivors: the routing failure is the typed RouteError,
        // not an opaque engine fault — churn callers key on this
        assert!(err.downcast_ref::<RouteError>().is_some(), "{err:#}");
        let _ = cluster.shutdown();
    }

    #[test]
    fn spawn_fails_fast_on_impossible_packing() {
        let cfg = ClusterConfig {
            policy: policy_by_name("delta-aware").unwrap(),
            delta_budget_bytes: 5,
            admission: None,
        };
        assert!(Cluster::spawn(&cfg, profiles(&["a"], 10),
                               mock_factories(2)).is_err());
    }

    #[test]
    fn replay_trace_collects_all_responses() {
        let cluster = Cluster::spawn(&cfg("least-loaded"),
                                     profiles(&["a", "b"], 10),
                                     mock_factories(2)).unwrap();
        let handle = cluster.handle();
        let trace: Vec<TraceEvent> = (0..10).map(|i| TraceEvent {
            at: 0.0,
            tenant: i % 5,          // ranks fold onto the 2 tenants
            prompt_idx: i,
            max_new_tokens: 4,
        }).collect();
        let names = handle.tenants();
        let r = replay_trace(&handle, &trace, &names, &["Q:"], 3)
            .unwrap();
        assert_eq!(r.served(), 10);
        assert_eq!(r.errors, 0);
        assert_eq!(r.rejected, 0);
        assert_eq!(r.tokens, 40);
        assert!(r.quantile_ms(0.99) >= r.quantile_ms(0.5));
        assert!(r.tok_per_s() > 0.0);
        // mock cores export no kv series: the report reads as slab
        assert_eq!(r.kv_blocks_total, 0);
        assert_eq!(r.kv_occupancy(), 0.0);
        assert_eq!(r.kv_prefix_hit_rate(), 0.0);
        cluster.shutdown().unwrap();
    }

    #[test]
    fn scrape_reads_rollup_not_relabeled_series() {
        let text = "bitdelta_kv_blocks_used 12\n\
                    bitdelta_kv_blocks_used{worker=\"0\"} 5\n\
                    bitdelta_kv_blocks_total 64\n";
        assert_eq!(scrape(text, "bitdelta_kv_blocks_used"), 12);
        assert_eq!(scrape(text, "bitdelta_kv_blocks_total"), 64);
        assert_eq!(scrape(text, "bitdelta_kv_prefix_hits_total"), 0);
    }

    #[test]
    fn trace_weights_fold_onto_profiles() {
        let mut ps = profiles(&["a", "b", "c"], 10);
        // ranks 0..5 fold mod 3: a gets ranks 0+3, b 1+4, c 2
        apply_trace_weights(&mut ps, &[10, 4, 2, 2, 2, 0]);
        assert!((ps[0].weight - 12.0 / 20.0).abs() < 1e-9);
        assert!((ps[1].weight - 6.0 / 20.0).abs() < 1e-9);
        assert!((ps[2].weight - 2.0 / 20.0).abs() < 1e-9);
    }

    // -- elasticity ---------------------------------------------------

    #[test]
    fn spawn_worker_grows_an_elastic_cluster() {
        let cluster = Cluster::spawn_elastic(
            &cfg("least-loaded"), profiles(&["a", "b"], 10), 1,
            elastic_mock(Duration::ZERO)).unwrap();
        let handle = cluster.handle();
        assert_eq!(handle.active_workers(), 1);
        let w1 = handle.spawn_worker().unwrap();
        assert_eq!(w1, 1);
        assert_eq!(handle.active_workers(), 2);
        // least-loaded places every tenant on every active worker
        assert_eq!(handle.placement().workers_of("a").len(), 2);
        // the new worker actually serves
        for _ in 0..6 {
            handle.generate(req("a")).unwrap();
        }
        let m = handle.metrics();
        assert!(m.contains(
            "bitdelta_cluster_scale_events_total{direction=\"up\"} 1"),
                "{m}");
        cluster.shutdown().unwrap();
    }

    #[test]
    fn fixed_cluster_cannot_scale_up() {
        let cluster = Cluster::spawn(&cfg("affinity"),
                                     profiles(&["a"], 10),
                                     mock_factories(1)).unwrap();
        let err = cluster.handle().spawn_worker()
            .unwrap_err().to_string();
        assert!(err.contains("fixed factories"), "{err}");
        cluster.shutdown().unwrap();
    }

    #[test]
    fn graceful_drain_completes_inflight_with_zero_errors() {
        let cluster = Cluster::spawn_elastic(
            &cfg("least-loaded"), profiles(&["a", "b"], 10), 2,
            elastic_mock(Duration::from_millis(1))).unwrap();
        let handle = cluster.handle();
        assert_eq!(handle.active_workers(), 2);

        // pile up work so the drained worker has accepted requests
        // still queued when the retire lands
        let tickets: Vec<ClusterTicket> = (0..24)
            .map(|i| handle.submit(req(["a", "b"][i % 2])).unwrap())
            .collect();
        let drain = handle.retire_worker(1).unwrap();

        // zero request errors: drain, not failover
        let mut texts = Vec::new();
        for t in tickets {
            texts.push(t.recv().expect("drain lost a request").text);
        }
        assert_eq!(texts.len(), 24);
        assert_eq!(handle.active_workers(), 1);
        // the drained worker really did serve some of the work
        assert!(texts.iter().any(|t| t == "w1"), "{texts:?}");

        // tenants re-placed onto the survivor only
        assert_eq!(handle.placement().workers_of("a"), &[0][..]);
        assert_eq!(handle.placement().workers_of("b"), &[0][..]);

        // new requests still served (by the survivor)
        assert_eq!(handle.generate(req("a")).unwrap().text, "w0");

        let m = handle.metrics();
        assert!(m.contains(
            "bitdelta_cluster_scale_events_total{direction=\"down\"} 1"),
                "{m}");
        assert!(m.contains("bitdelta_cluster_drain_us_count 1"), "{m}");
        assert!(m.contains("bitdelta_cluster_failovers_total 0"), "{m}");
        assert!(drain >= Duration::ZERO);
        cluster.shutdown().unwrap();
    }

    #[test]
    fn cannot_retire_the_last_active_worker() {
        let cluster = Cluster::spawn_elastic(
            &cfg("affinity"), profiles(&["a"], 10), 1,
            elastic_mock(Duration::ZERO)).unwrap();
        let handle = cluster.handle();
        let err = handle.retire_worker(0).unwrap_err().to_string();
        assert!(err.contains("only 1 active"), "{err}");
        // still serving
        handle.generate(req("a")).unwrap();
        cluster.shutdown().unwrap();
    }

    #[test]
    fn compaction_frees_retired_slots_but_keeps_identity() {
        let cluster = Cluster::spawn_elastic(
            &cfg("least-loaded"), profiles(&["a"], 10), 3,
            elastic_mock(Duration::ZERO)).unwrap();
        let handle = cluster.handle();
        for _ in 0..4 {
            handle.generate(req("a")).unwrap();
        }
        // a clean retire compacts its slot eagerly…
        handle.retire_worker(1).unwrap();
        assert_eq!(handle.compact_slots(), 0,
                   "retire already compacted its slot");
        // …and the tombstone still answers every external query: the
        // index, the lifecycle state, and the per-slot metrics label
        assert_eq!(handle.n_workers(), 3);
        assert_eq!(handle.active_workers(), 2);
        let err = handle.retire_worker(1).unwrap_err().to_string();
        assert!(err.contains("Retired"), "{err}");
        let m = handle.metrics();
        assert!(m.contains(
            "bitdelta_cluster_routed_total{worker=\"1\"}"), "{m}");
        // compacted slots are never reused: new workers extend the table
        assert_eq!(handle.spawn_worker().unwrap(), 3);
        handle.generate(req("a")).unwrap();
        cluster.shutdown().unwrap();
    }

    #[test]
    fn retire_twice_is_an_error_and_slots_stay_stable() {
        let cluster = Cluster::spawn_elastic(
            &cfg("least-loaded"), profiles(&["a"], 10), 3,
            elastic_mock(Duration::ZERO)).unwrap();
        let handle = cluster.handle();
        handle.retire_worker(1).unwrap();
        assert!(handle.retire_worker(1).is_err());
        // slot indices survive the retire: worker 2 is still worker 2
        assert_eq!(handle.n_workers(), 3);
        assert_eq!(handle.active_workers(), 2);
        let placed = handle.placement();
        assert!(placed.workers_of("a").contains(&0));
        assert!(placed.workers_of("a").contains(&2));
        cluster.shutdown().unwrap();
    }

    // -- cluster admission --------------------------------------------

    #[test]
    fn admission_sheds_load_with_typed_rejections() {
        let mut config = cfg("least-loaded");
        config.admission = Some(AdmissionPolicy {
            per_tenant_cap: 2, total_cap: 2,
        });
        let cluster = Cluster::spawn_elastic(
            &config, profiles(&["a"], 10), 1,
            elastic_mock(Duration::from_millis(5))).unwrap();
        let handle = cluster.handle();

        let t1 = handle.submit(req("a")).unwrap();
        let t2 = handle.submit(req("a")).unwrap();
        // budget exhausted: typed rejection, not a queue-grow
        let err = handle.submit(req("a")).unwrap_err();
        let ae = err.downcast_ref::<AdmissionError>()
            .expect("admission rejection must stay typed");
        assert_eq!(ae.tenant, "a");

        let m = handle.metrics();
        assert!(m.contains("bitdelta_cluster_admission_inflight 2"),
                "{m}");
        assert!(m.contains(
            "bitdelta_cluster_admission_rejected_total"), "{m}");

        // completing a request frees its slot
        t1.recv().unwrap();
        t2.recv().unwrap();
        handle.submit(req("a")).unwrap().recv().unwrap();
        assert_eq!(handle.admission_in_flight(), Some(0));
        cluster.shutdown().unwrap();
    }

    // -- churn + snapshot accessors -----------------------------------

    #[test]
    fn update_tenants_replaces_population_and_replaces_placement() {
        let cluster = Cluster::spawn_elastic(
            &cfg("least-loaded"), profiles(&["a", "b"], 10), 2,
            elastic_mock(Duration::ZERO)).unwrap();
        let handle = cluster.handle();
        assert_eq!(handle.tenants(), vec!["a", "b"]);

        // churn: swap in a re-weighted, re-sized population (out of
        // order — update_tenants normalizes by sorting)
        handle.update_tenants(profiles(&["d", "c", "a"], 20)).unwrap();
        assert_eq!(handle.tenants(), vec!["a", "c", "d"]);
        let snap = handle.routing_snapshot();
        assert!(!snap.degraded);
        for t in ["a", "c", "d"] {
            let ws = snap.placement.workers_of(t);
            assert!(ws.iter().any(|w| snap.routable.contains(w)),
                    "tenant {t} placed on {ws:?}, routable {:?}",
                    snap.routable);
        }
        // dropped tenant still routes (any worker serves unknowns)
        handle.generate(req("b")).unwrap();
        // new tenant serves
        handle.generate(req("c")).unwrap();

        assert!(handle.update_tenants(Vec::new()).is_err(),
                "empty tenant set must be refused");
        cluster.shutdown().unwrap();
    }

    #[test]
    fn routed_counts_sum_to_successful_submits() {
        let cluster = Cluster::spawn(
            &cfg("least-loaded"), profiles(&["a", "b"], 10),
            mock_factories(2)).unwrap();
        let handle = cluster.handle();
        for i in 0..9 {
            handle.generate(req(["a", "b"][i % 2])).unwrap();
        }
        assert_eq!(handle.routed_counts().iter().sum::<u64>(), 9);
        assert_eq!(handle.delta_budget_bytes(), 1 << 20);
        assert_eq!(handle.admission_in_flight(), None);
        cluster.shutdown().unwrap();
    }

    #[test]
    fn budget_overload_degrades_and_recovery_clears_the_flag() {
        let config = ClusterConfig {
            policy: policy_by_name("delta-aware").unwrap(),
            delta_budget_bytes: 25,
            admission: None,
        };
        // two 10 B tenants fit two budget-25 workers one-per-worker
        let cluster = Cluster::spawn_elastic(
            &config, profiles(&["a", "b"], 10), 2,
            elastic_mock(Duration::ZERO)).unwrap();
        let handle = cluster.handle();
        assert!(!handle.placement_degraded());

        // churn to three 10 B tenants on one eventual survivor: after
        // retiring a worker the packing (30 B into 25 B) is impossible
        // and the placement must degrade rather than refuse to serve
        handle.update_tenants(profiles(&["a", "b", "c"], 10)).unwrap();
        handle.retire_worker(0).unwrap();
        assert!(handle.placement_degraded());
        handle.generate(req("c")).unwrap();

        // scale back up: a policy placement fits again, flag clears
        handle.spawn_worker().unwrap();
        assert!(!handle.placement_degraded());
        let snap = handle.routing_snapshot();
        assert!(!snap.degraded);
        cluster.shutdown().unwrap();
    }
}
