//! Deterministic fake [`WorkerCore`] — lets cluster scheduling,
//! failover, and metrics rollup be unit-tested without artifacts or a
//! PJRT runtime. Compiled into the library proper (not `cfg(test)`)
//! because [`crate::simharness`] drives real clusters over these
//! mocks; the step delay goes through the [`crate::sync::clock`] seam
//! so a simulated core's service time dilates with virtual time.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::cluster::frontend::WorkerFactoryFn;
use crate::cluster::placement::TenantProfile;
use crate::cluster::worker::{CoreFactory, WorkerCore};
use crate::model::sampling::SamplingParams;
use crate::serving::request::{Request, RequestError, Response};

/// A canned greedy request for `tenant`.
pub fn req(tenant: &str) -> Request {
    Request { tenant: tenant.into(), prompt: "Q:".into(),
              max_new_tokens: 4, sampling: SamplingParams::greedy() }
}

/// Uniform-weight tenant profiles, `bytes` resident each. (Unit-test
/// only: the simulation harness generates its own populations.)
#[cfg_attr(not(test), allow(dead_code))]
pub fn profiles(names: &[&str], bytes: usize) -> Vec<TenantProfile> {
    let w = 1.0 / names.len() as f64;
    names.iter().map(|n| TenantProfile {
        name: n.to_string(), codec: "bitdelta".into(),
        resident_bytes: bytes, weight: w, levels: 1,
    }).collect()
}

/// Elastic worker factory minting [`MockCore`]s with a per-step delay
/// (zero = as fast as the pump loop spins). (Unit-test only: the
/// harness wires kill switches into its factory, see
/// `simharness::harness`.)
#[cfg_attr(not(test), allow(dead_code))]
pub fn elastic_mock(step_delay: Duration) -> WorkerFactoryFn {
    Box::new(move |id| {
        let f: CoreFactory = Box::new(move || {
            Ok(Box::new(MockCore::new(id).with_step_delay(step_delay))
               as Box<dyn WorkerCore>)
        });
        f
    })
}

/// A fake engine: each `step` completes one queued request with a
/// canned response. A shared kill switch makes `step` fail, modelling a
/// worker death mid-flight.
pub struct MockCore {
    id: usize,
    queue: VecDeque<(Request,
                     mpsc::Sender<Result<Response, RequestError>>)>,
    kill: Option<Arc<AtomicBool>>,
    /// Optional per-step delay, to make load imbalance observable.
    pub step_delay: Option<Duration>,
    served: u64,
    next_id: u64,
}

impl MockCore {
    pub fn new(id: usize) -> Self {
        Self { id, queue: VecDeque::new(), kill: None, step_delay: None,
               served: 0, next_id: 1 }
    }

    /// `step` fails as soon as the switch is set.
    pub fn with_kill_switch(mut self, kill: Arc<AtomicBool>) -> Self {
        self.kill = Some(kill);
        self
    }

    /// Sleep this long per `step` — makes queues (and therefore load
    /// imbalance, drain windows, and admission backpressure)
    /// observable in tests.
    pub fn with_step_delay(mut self, delay: Duration) -> Self {
        self.step_delay = (!delay.is_zero()).then_some(delay);
        self
    }
}

impl WorkerCore for MockCore {
    fn submit(&mut self, req: Request)
              -> Result<mpsc::Receiver<Result<Response, RequestError>>> {
        let (tx, rx) = mpsc::channel();
        self.queue.push_back((req, tx));
        Ok(rx)
    }

    fn step(&mut self) -> Result<()> {
        if let Some(k) = &self.kill {
            if k.load(Ordering::Relaxed) {
                bail!("mock worker {} killed", self.id);
            }
        }
        if let Some(d) = self.step_delay {
            // virtual under an installed sim clock, real otherwise
            crate::sync::clock::sleep(d);
        }
        if let Some((req, tx)) = self.queue.pop_front() {
            let id = self.next_id;
            self.next_id += 1;
            self.served += 1;
            let _ = tx.send(Ok(Response {
                id,
                tenant: req.tenant,
                text: format!("w{}", self.id),
                tokens: vec![0; req.max_new_tokens],
                latency: Duration::from_micros(10),
                ttft: Duration::from_micros(5),
                prompt_tokens: req.prompt.len(),
            }));
        }
        Ok(())
    }

    fn has_work(&self) -> bool {
        !self.queue.is_empty()
    }

    fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    fn occupancy(&self) -> usize {
        0
    }

    fn drain(&mut self) -> Result<()> {
        while self.has_work() {
            self.step()?;
        }
        Ok(())
    }

    fn metrics_text(&self) -> String {
        format!("bitdelta_requests_total {}\n\
                 bitdelta_completed_total {}\n",
                self.served, self.served)
    }
}
