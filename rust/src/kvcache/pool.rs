//! Fixed-size KV block pool — the physical memory layer of the paged
//! KV cache.
//!
//! A [`BlockPool`] owns two flat `f32` arenas (K and V) carved into
//! `n_blocks` fixed-size blocks of [`BlockDims`] geometry
//! `[n_layers, n_heads, block_size, head_dim]`. Allocation is a
//! free-list pop; running out of blocks is a typed [`KvOomError`]
//! callers can downcast and react to (the engine reclaims prefix-index
//! entries and retries) instead of the old scheme of preallocating a
//! full `max_seq_len` dense slab per sequence up front.
//!
//! Blocks are **ref-counted** so several sequences can map the same
//! physical block (shared prompt prefixes, forked tables). Writers go
//! through [`BlockTable::append_row`], which copy-on-writes a shared
//! tail block before mutating it; the pool provides `retain` /
//! `release` and counts the copies.
//!
//! **Concurrency.** The pool is deliberately `&mut self`-only: all
//! synchronization lives in the callers (the engine owns its pool; the
//! cluster wraps shared pools in `crate::sync` locks). The refcount
//! conservation law — `used + free == total`, every refcount matches
//! the number of live table references — is model-checked under
//! concurrent churn by the loom model in `tests/loom_models.rs`.
//!
//! [`BlockTable::append_row`]: crate::kvcache::BlockTable::append_row

use std::fmt;

use crate::config::ModelConfig;

/// Identifier of one physical block in a [`BlockPool`].
pub type BlockId = u32;

/// Out-of-blocks: the pool could not satisfy an allocation. A typed
/// error (downcast with `anyhow::Error::downcast_ref::<KvOomError>`)
/// so admission control can distinguish "KV full" from a bug, instead
/// of string-matching a panic message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvOomError {
    /// Blocks the failed call asked for.
    pub requested: usize,
    /// Free blocks at the time of the failure.
    pub free: usize,
    /// Total blocks in the pool.
    pub total: usize,
}

impl fmt::Display for KvOomError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "KV pool out of blocks: requested {} with {}/{} free",
               self.requested, self.free, self.total)
    }
}

impl std::error::Error for KvOomError {}

/// Geometry of one KV block: a `[n_layers, n_heads, block_size,
/// head_dim]` f32 tensor per arena (one K, one V), holding
/// `block_size` consecutive token positions of one sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockDims {
    pub n_layers: usize,
    pub n_heads: usize,
    /// Token positions per block.
    pub block_size: usize,
    pub head_dim: usize,
}

impl BlockDims {
    pub fn from_config(cfg: &ModelConfig, block_size: usize) -> Self {
        assert!(block_size > 0, "block_size must be positive");
        Self { n_layers: cfg.n_layers, n_heads: cfg.n_heads,
               block_size, head_dim: cfg.head_dim() }
    }

    /// f32 elements in one block (per arena).
    pub fn block_floats(&self) -> usize {
        self.n_layers * self.n_heads * self.block_size * self.head_dim
    }

    /// f32 elements in one token row: `[n_layers, n_heads, head_dim]`.
    pub fn row_floats(&self) -> usize {
        self.n_layers * self.n_heads * self.head_dim
    }
}

/// Ref-counted free-list allocator over two flat K/V arenas.
#[derive(Debug)]
pub struct BlockPool {
    dims: BlockDims,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Per-block reference count; 0 = on the free list.
    refs: Vec<u32>,
    free: Vec<BlockId>,
    /// Lifetime allocation count (free-list pops).
    pub allocs: u64,
    /// Lifetime free count (refcount reaching zero).
    pub frees: u64,
    /// Copy-on-write block copies (bumped by `BlockTable`).
    pub cow_copies: u64,
    peak_used: usize,
}

impl BlockPool {
    pub fn new(dims: BlockDims, n_blocks: usize) -> Self {
        assert!(n_blocks > 0, "pool needs at least one block");
        assert!(n_blocks <= BlockId::MAX as usize);
        let per = dims.block_floats();
        Self {
            dims,
            k: vec![0.0; n_blocks * per],
            v: vec![0.0; n_blocks * per],
            refs: vec![0; n_blocks],
            // pop order low-to-high block ids (cosmetic, deterministic)
            free: (0..n_blocks as BlockId).rev().collect(),
            allocs: 0,
            frees: 0,
            cow_copies: 0,
            peak_used: 0,
        }
    }

    pub fn dims(&self) -> BlockDims {
        self.dims
    }

    pub fn total_blocks(&self) -> usize {
        self.refs.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.total_blocks() - self.free_blocks()
    }

    /// High-water mark of `used_blocks()` over the pool's lifetime.
    pub fn peak_used(&self) -> usize {
        self.peak_used
    }

    /// Bytes of K+V arena currently backing live blocks.
    pub fn resident_bytes(&self) -> usize {
        self.used_blocks() * self.dims.block_floats() * 4 * 2
    }

    /// Pop a free block (zeroed, refcount 1) or fail with a typed
    /// [`KvOomError`] — never panics on exhaustion.
    pub fn alloc(&mut self) -> Result<BlockId, KvOomError> {
        let Some(id) = self.free.pop() else {
            return Err(KvOomError { requested: 1, free: 0,
                                    total: self.total_blocks() });
        };
        let per = self.dims.block_floats();
        let at = id as usize * per;
        self.k[at..at + per].fill(0.0);
        self.v[at..at + per].fill(0.0);
        self.refs[id as usize] = 1;
        self.allocs += 1;
        self.peak_used = self.peak_used.max(self.used_blocks());
        Ok(id)
    }

    pub fn ref_count(&self, id: BlockId) -> u32 {
        self.refs[id as usize]
    }

    /// Add a reference to a live block.
    pub fn retain(&mut self, id: BlockId) {
        assert!(self.refs[id as usize] > 0,
                "retain of free block {id}");
        self.refs[id as usize] += 1;
    }

    /// Drop a reference; the block returns to the free list when the
    /// count reaches zero. Releasing an already-free block is a
    /// double-free and panics.
    pub fn release(&mut self, id: BlockId) {
        let r = &mut self.refs[id as usize];
        assert!(*r > 0, "double free of block {id}");
        *r -= 1;
        if *r == 0 {
            self.free.push(id);
            self.frees += 1;
        }
    }

    pub fn block_k(&self, id: BlockId) -> &[f32] {
        let per = self.dims.block_floats();
        let at = id as usize * per;
        &self.k[at..at + per]
    }

    pub fn block_v(&self, id: BlockId) -> &[f32] {
        let per = self.dims.block_floats();
        let at = id as usize * per;
        &self.v[at..at + per]
    }

    /// Write one token row (`[n_layers, n_heads, head_dim]` order)
    /// into slot `q` of block `id`.
    pub fn write_row(&mut self, id: BlockId, q: usize, k_row: &[f32],
                     v_row: &[f32]) {
        let d = self.dims;
        assert!(q < d.block_size, "row {q} out of block");
        assert_eq!(k_row.len(), d.row_floats());
        assert_eq!(v_row.len(), d.row_floats());
        let (bs, hd) = (d.block_size, d.head_dim);
        let base = id as usize * d.block_floats();
        for lh in 0..d.n_layers * d.n_heads {
            let src = lh * hd;
            let dst = base + (lh * bs + q) * hd;
            self.k[dst..dst + hd]
                .copy_from_slice(&k_row[src..src + hd]);
            self.v[dst..dst + hd]
                .copy_from_slice(&v_row[src..src + hd]);
        }
    }

    /// Copy the full contents of block `src` into block `dst`
    /// (copy-on-write body; the caller owns the bookkeeping).
    pub fn copy_block(&mut self, src: BlockId, dst: BlockId) {
        assert_ne!(src, dst);
        let per = self.dims.block_floats();
        let (s, d) = (src as usize * per, dst as usize * per);
        self.k.copy_within(s..s + per, d);
        self.v.copy_within(s..s + per, d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> BlockDims {
        BlockDims { n_layers: 2, n_heads: 2, block_size: 4,
                    head_dim: 3 }
    }

    #[test]
    fn alloc_free_roundtrip_and_counters() {
        let mut p = BlockPool::new(dims(), 3);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_eq!(p.used_blocks(), 2);
        assert_eq!(p.peak_used(), 2);
        p.release(a);
        assert_eq!(p.used_blocks(), 1);
        let c = p.alloc().unwrap();
        assert_eq!(p.used_blocks(), 2);
        p.release(b);
        p.release(c);
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.allocs, 3);
        assert_eq!(p.frees, 3);
        assert_eq!(p.peak_used(), 2);
    }

    #[test]
    fn oom_is_a_typed_error_with_counts() {
        let mut p = BlockPool::new(dims(), 2);
        let _a = p.alloc().unwrap();
        let _b = p.alloc().unwrap();
        let e = p.alloc().unwrap_err();
        assert_eq!(e, KvOomError { requested: 1, free: 0, total: 2 });
        assert!(e.to_string().contains("out of blocks"));
    }

    #[test]
    fn refcounts_keep_shared_blocks_alive() {
        let mut p = BlockPool::new(dims(), 2);
        let a = p.alloc().unwrap();
        p.retain(a);
        assert_eq!(p.ref_count(a), 2);
        p.release(a);
        assert_eq!(p.used_blocks(), 1, "still one live reference");
        p.release(a);
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut p = BlockPool::new(dims(), 1);
        let a = p.alloc().unwrap();
        p.release(a);
        p.release(a);
    }

    #[test]
    fn rows_land_in_block_layout() {
        let d = dims();
        let mut p = BlockPool::new(d, 1);
        let id = p.alloc().unwrap();
        let row_k: Vec<f32> = (0..d.row_floats())
            .map(|i| i as f32).collect();
        let row_v: Vec<f32> = row_k.iter().map(|x| -x).collect();
        p.write_row(id, 2, &row_k, &row_v);
        let bk = p.block_k(id);
        let bv = p.block_v(id);
        for lh in 0..d.n_layers * d.n_heads {
            for e in 0..d.head_dim {
                let got = bk[(lh * d.block_size + 2) * d.head_dim + e];
                assert_eq!(got, (lh * d.head_dim + e) as f32);
                let got = bv[(lh * d.block_size + 2) * d.head_dim + e];
                assert_eq!(got, -((lh * d.head_dim + e) as f32));
            }
        }
        // untouched slots stay zero
        assert_eq!(bk[0], 0.0);
    }

    #[test]
    fn realloc_zeroes_stale_contents() {
        let d = dims();
        let mut p = BlockPool::new(d, 1);
        let id = p.alloc().unwrap();
        p.write_row(id, 0, &vec![1.0; d.row_floats()],
                    &vec![2.0; d.row_floats()]);
        p.release(id);
        let id2 = p.alloc().unwrap();
        assert!(p.block_k(id2).iter().all(|&x| x == 0.0));
        assert!(p.block_v(id2).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn copy_block_copies_both_arenas() {
        let d = dims();
        let mut p = BlockPool::new(d, 2);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        p.write_row(a, 1, &vec![3.5; d.row_floats()],
                    &vec![-3.5; d.row_floats()]);
        p.copy_block(a, b);
        assert_eq!(p.block_k(a), p.block_k(b));
        assert_eq!(p.block_v(a), p.block_v(b));
    }

    #[test]
    fn resident_bytes_track_usage() {
        let d = dims();
        let mut p = BlockPool::new(d, 4);
        assert_eq!(p.resident_bytes(), 0);
        let _a = p.alloc().unwrap();
        assert_eq!(p.resident_bytes(), d.block_floats() * 4 * 2);
    }
}
