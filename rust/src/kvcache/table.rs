//! Per-sequence block table: the paged replacement for the dense
//! `SeqCache` slab.
//!
//! A [`BlockTable`] maps a sequence's token positions onto physical
//! [`BlockPool`] blocks: position `p` lives in `blocks[p / block_size]`
//! at in-block slot `p % block_size`. Appending grows the table one
//! block at a time (explicit [`KvOomError`] instead of up-front
//! `max_seq_len` preallocation), and appending into a block another
//! table also references **copies on write** first, so a sequence that
//! diverges from a shared prefix never corrupts its siblings.

use super::pool::{BlockId, BlockPool, KvOomError};

/// One sequence's view onto the pool.
#[derive(Debug, Default, Clone)]
pub struct BlockTable {
    blocks: Vec<BlockId>,
    len: usize,
}

impl BlockTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start a table over an already-populated shared prefix: every
    /// block is retained (the prefix owner keeps its own references),
    /// and `len` is the full `blocks.len() * block_size` positions.
    pub fn with_shared_prefix(pool: &mut BlockPool, blocks: &[BlockId])
                              -> Self {
        for &b in blocks {
            pool.retain(b);
        }
        Self { blocks: blocks.to_vec(),
               len: blocks.len() * pool.dims().block_size }
    }

    /// Token positions stored.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn blocks(&self) -> &[BlockId] {
        &self.blocks
    }

    /// Append one token row (`[n_layers, n_heads, head_dim]` order).
    /// Allocates a fresh block at block boundaries; copy-on-writes a
    /// shared tail block before mutating it. On [`KvOomError`] the
    /// table is unchanged and the append can be retried after the
    /// caller frees blocks elsewhere.
    pub fn append_row(&mut self, pool: &mut BlockPool, k_row: &[f32],
                      v_row: &[f32]) -> Result<(), KvOomError> {
        let bs = pool.dims().block_size;
        let q = self.len % bs;
        let dest = if q == 0 {
            let id = pool.alloc()?;
            self.blocks.push(id);
            id
        } else {
            let Some(&tail) = self.blocks.last() else {
                unreachable!("len % block_size != 0 implies a tail block");
            };
            if pool.ref_count(tail) > 1 {
                let copy = pool.alloc()?;
                pool.copy_block(tail, copy);
                pool.release(tail);
                self.blocks.pop();
                self.blocks.push(copy);
                pool.cow_copies += 1;
                copy
            } else {
                tail
            }
        };
        pool.write_row(dest, q, k_row, v_row);
        self.len += 1;
        Ok(())
    }

    /// A second table over the same physical blocks (every block
    /// retained). Divergent appends trigger COW on the shared tail.
    pub fn fork(&self, pool: &mut BlockPool) -> Self {
        for &b in &self.blocks {
            pool.retain(b);
        }
        Self { blocks: self.blocks.clone(), len: self.len }
    }

    /// Release every block reference and empty the table.
    pub fn free(&mut self, pool: &mut BlockPool) {
        for &b in &self.blocks {
            pool.release(b);
        }
        self.blocks.clear();
        self.len = 0;
    }

    /// Scatter this sequence into batch slot `slot` of a dense
    /// `[n_layers, batch, n_heads, max_seq, head_dim]` staging pair —
    /// the incremental restack: only the changed slot is rewritten,
    /// never the whole batch.
    pub fn gather_into(&self, pool: &BlockPool, slot: usize,
                       batch: usize, max_seq: usize, k_dst: &mut [f32],
                       v_dst: &mut [f32]) {
        let d = pool.dims();
        let (bs, hd) = (d.block_size, d.head_dim);
        assert!(self.len <= max_seq, "sequence overflows staging");
        for (bi, &id) in self.blocks.iter().enumerate() {
            let start = bi * bs;
            let n = bs.min(self.len - start);
            let bk = pool.block_k(id);
            let bv = pool.block_v(id);
            for lh in 0..d.n_layers * d.n_heads {
                let (l, h) = (lh / d.n_heads, lh % d.n_heads);
                let src = lh * bs * hd;
                let dst = (((l * batch + slot) * d.n_heads + h)
                           * max_seq + start) * hd;
                k_dst[dst..dst + n * hd]
                    .copy_from_slice(&bk[src..src + n * hd]);
                v_dst[dst..dst + n * hd]
                    .copy_from_slice(&bv[src..src + n * hd]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::pool::BlockDims;
    use super::*;

    fn pool(n_blocks: usize) -> BlockPool {
        BlockPool::new(BlockDims { n_layers: 2, n_heads: 2,
                                   block_size: 2, head_dim: 3 },
                       n_blocks)
    }

    fn row(pool: &BlockPool, x: f32) -> Vec<f32> {
        vec![x; pool.dims().row_floats()]
    }

    #[test]
    fn append_grows_one_block_per_block_size_rows() {
        let mut p = pool(4);
        let mut t = BlockTable::new();
        for i in 0..5 {
            let r = row(&p, i as f32);
            t.append_row(&mut p, &r, &r).unwrap();
        }
        assert_eq!(t.len(), 5);
        assert_eq!(t.n_blocks(), 3, "ceil(5/2) blocks");
        assert_eq!(p.used_blocks(), 3);
        t.free(&mut p);
        assert_eq!(p.used_blocks(), 0);
        assert!(t.is_empty());
    }

    #[test]
    fn append_oom_leaves_table_retryable() {
        let mut p = pool(1);
        let mut t = BlockTable::new();
        let r = row(&p, 1.0);
        t.append_row(&mut p, &r, &r).unwrap();
        t.append_row(&mut p, &r, &r).unwrap();
        let e = t.append_row(&mut p, &r, &r).unwrap_err();
        assert_eq!(e.free, 0);
        assert_eq!(t.len(), 2, "failed append must not half-commit");
        // free something and the same append succeeds
        let mut other = BlockTable::new();
        assert!(other.append_row(&mut p, &r, &r).is_err());
        t.free(&mut p);
        other.append_row(&mut p, &r, &r).unwrap();
    }

    #[test]
    fn fork_then_append_copies_on_write() {
        let mut p = pool(4);
        let mut a = BlockTable::new();
        let r1 = row(&p, 1.0);
        a.append_row(&mut p, &r1, &r1).unwrap();
        let mut b = a.fork(&mut p);
        assert_eq!(p.ref_count(a.blocks()[0]), 2);

        // b writes into the shared, half-full tail block: must COW
        let r2 = row(&p, 2.0);
        b.append_row(&mut p, &r2, &r2).unwrap();
        assert_eq!(p.cow_copies, 1);
        assert_ne!(a.blocks()[0], b.blocks()[0]);
        // a's copy of position 0 is untouched, b carried it over
        assert_eq!(p.block_k(a.blocks()[0])[0], 1.0);
        assert_eq!(p.block_k(b.blocks()[0])[0], 1.0);
        a.free(&mut p);
        b.free(&mut p);
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    fn full_shared_block_is_not_copied() {
        let mut p = pool(4);
        let mut a = BlockTable::new();
        let r = row(&p, 1.0);
        a.append_row(&mut p, &r, &r).unwrap();
        a.append_row(&mut p, &r, &r).unwrap(); // block now full
        let mut b = a.fork(&mut p);
        b.append_row(&mut p, &r, &r).unwrap(); // new block, no COW
        assert_eq!(p.cow_copies, 0);
        assert_eq!(a.blocks()[0], b.blocks()[0]);
        a.free(&mut p);
        b.free(&mut p);
    }

    #[test]
    fn gather_matches_dense_reference() {
        let mut p = pool(8);
        let d = p.dims();
        let (batch, max_seq, slot) = (3usize, 6usize, 1usize);
        let mut t = BlockTable::new();
        let n_rows = 5;
        // row r gets value r+1 in every element
        for r in 0..n_rows {
            let kr = row(&p, (r + 1) as f32);
            let vr = row(&p, -((r + 1) as f32));
            t.append_row(&mut p, &kr, &vr).unwrap();
        }
        let total = d.n_layers * batch * d.n_heads * max_seq
            * d.head_dim;
        let mut k = vec![9.9f32; total];
        let mut v = vec![9.9f32; total];
        // pre-zero the slot the way the engine does on admission
        for l in 0..d.n_layers {
            let per = d.n_heads * max_seq * d.head_dim;
            let at = (l * batch + slot) * per;
            k[at..at + per].fill(0.0);
            v[at..at + per].fill(0.0);
        }
        t.gather_into(&p, slot, batch, max_seq, &mut k, &mut v);
        for l in 0..d.n_layers {
            for h in 0..d.n_heads {
                for s in 0..max_seq {
                    let at = (((l * batch + slot) * d.n_heads + h)
                              * max_seq + s) * d.head_dim;
                    let want = if s < n_rows { (s + 1) as f32 }
                               else { 0.0 };
                    assert_eq!(k[at], want, "k at l{l} h{h} s{s}");
                    assert_eq!(v[at], -want, "v at l{l} h{h} s{s}");
                }
            }
        }
        // other slots untouched
        assert_eq!(k[0], 9.9);
        t.free(&mut p);
    }

    #[test]
    fn shared_prefix_table_starts_at_prefix_len() {
        let mut p = pool(4);
        let mut a = BlockTable::new();
        let r = row(&p, 4.0);
        a.append_row(&mut p, &r, &r).unwrap();
        a.append_row(&mut p, &r, &r).unwrap();
        let t = BlockTable::with_shared_prefix(&mut p, a.blocks());
        assert_eq!(t.len(), 2);
        assert_eq!(p.ref_count(a.blocks()[0]), 2);
        let mut t = t;
        t.free(&mut p);
        a.free(&mut p);
        assert_eq!(p.used_blocks(), 0);
    }
}
