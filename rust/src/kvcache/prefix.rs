//! Content-hash prefix index: sequences with identical token prefixes
//! map to the same physical KV blocks — within **and across** tenants.
//!
//! The BitDelta twist on vLLM-style prefix caching: every tenant is a
//! delta on one shared base model, so when two tenants are served
//! through the *same* weights (same codec, fidelity level, artifact,
//! and rope scale — summarized in a `sig` hash), an identical system
//! prompt produces bit-identical KV, and the blocks can be shared
//! across tenant boundaries. No per-model serving stack can do this.
//!
//! Correctness rule: KV at position `p` depends on the **entire**
//! token prefix `[0..=p]`, the rope scale, and the serving weights.
//! The index therefore keys on `(sig, rope_bits, full token prefix)`
//! and verifies the stored tokens **exactly** on lookup — the FNV hash
//! only buckets; a collision can never alias two different prefixes.
//!
//! Entries hold their own block references, so a registered prefix
//! survives the sequence that produced it (a prompt cache). Under
//! pool pressure [`PrefixIndex::reclaim`] drops the oldest entries
//! until enough blocks are free.

use std::collections::BTreeMap;

use super::pool::{BlockId, BlockPool};

fn fnv1a(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

const FNV_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// Hash a set of string parts into a weight-identity signature (the
/// `sig` half of the index key). The engine derives one per tenant
/// from everything that changes served weights: codec name, fidelity
/// level, artifact path, distillation flag.
pub fn share_sig(parts: &[&str]) -> u64 {
    let mut h = FNV_SEED;
    for p in parts {
        h = fnv1a(h, p.as_bytes());
        h = fnv1a(h, &[0xff]); // separator: ("ab","c") != ("a","bc")
    }
    h
}

fn key_hash(sig: u64, rope_bits: u32, tokens: &[i32]) -> u64 {
    let mut h = fnv1a(FNV_SEED, &sig.to_le_bytes());
    h = fnv1a(h, &rope_bits.to_le_bytes());
    for t in tokens {
        h = fnv1a(h, &t.to_le_bytes());
    }
    h
}

#[derive(Debug)]
struct Entry {
    sig: u64,
    rope_bits: u32,
    tokens: Vec<i32>,
    blocks: Vec<BlockId>,
    stamp: u64,
}

impl Entry {
    fn matches(&self, sig: u64, rope_bits: u32, tokens: &[i32])
               -> bool {
        self.sig == sig && self.rope_bits == rope_bits
            && self.tokens == tokens
    }
}

/// Exact-match prefix → block mapping with hit/lookup counters.
#[derive(Debug, Default)]
pub struct PrefixIndex {
    buckets: BTreeMap<u64, Vec<Entry>>,
    n_entries: usize,
    /// Lifetime lookup count (admissions that consulted the index).
    pub lookups: u64,
    /// Lifetime hit count (admissions that reused at least one block).
    pub hits: u64,
    next_stamp: u64,
}

impl PrefixIndex {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.n_entries
    }

    pub fn is_empty(&self) -> bool {
        self.n_entries == 0
    }

    /// Register `blocks` as the KV of `tokens` (a whole number of
    /// blocks: `tokens.len() == blocks.len() * block_size`) under
    /// weight signature `sig` and rope scale `rope`. The index takes
    /// its own references; re-registering a known prefix is a no-op.
    /// Returns whether a new entry was added.
    pub fn register(&mut self, pool: &mut BlockPool, sig: u64,
                    rope: f32, tokens: &[i32], blocks: &[BlockId])
                    -> bool {
        assert_eq!(tokens.len(),
                   blocks.len() * pool.dims().block_size,
                   "prefix must cover whole blocks");
        let rope_bits = rope.to_bits();
        let h = key_hash(sig, rope_bits, tokens);
        let bucket = self.buckets.entry(h).or_default();
        if bucket.iter().any(|e| e.matches(sig, rope_bits, tokens)) {
            return false;
        }
        for &b in blocks {
            pool.retain(b);
        }
        bucket.push(Entry { sig, rope_bits, tokens: tokens.to_vec(),
                            blocks: blocks.to_vec(),
                            stamp: self.next_stamp });
        self.next_stamp += 1;
        self.n_entries += 1;
        true
    }

    /// Longest registered prefix of `tokens` (in whole blocks) under
    /// `(sig, rope)`. Returns the shared blocks and the prefix length
    /// in tokens; the caller takes references via
    /// [`BlockTable::with_shared_prefix`].
    ///
    /// [`BlockTable::with_shared_prefix`]:
    /// crate::kvcache::BlockTable::with_shared_prefix
    pub fn lookup(&mut self, sig: u64, rope: f32, tokens: &[i32],
                  block_size: usize) -> Option<(Vec<BlockId>, usize)> {
        self.lookups += 1;
        let rope_bits = rope.to_bits();
        for n in (1..=tokens.len() / block_size).rev() {
            let len = n * block_size;
            let h = key_hash(sig, rope_bits, &tokens[..len]);
            let hit = self.buckets.get(&h).and_then(|b| {
                b.iter().find(|e| e.matches(sig, rope_bits,
                                            &tokens[..len]))
            });
            if let Some(e) = hit {
                self.hits += 1;
                return Some((e.blocks.clone(), len));
            }
        }
        None
    }

    /// Drop oldest entries (releasing their blocks) until the pool has
    /// at least `want_free` free blocks or the index is empty. Returns
    /// the number of entries dropped.
    pub fn reclaim(&mut self, pool: &mut BlockPool, want_free: usize)
                   -> usize {
        let mut dropped = 0;
        while pool.free_blocks() < want_free && self.n_entries > 0 {
            // One flat pass over every (bucket, entry) pair for the
            // globally oldest stamp — no per-bucket min + re-lookup.
            let victim = self.buckets.iter()
                .flat_map(|(&h, b)| {
                    b.iter().enumerate().map(move |(i, e)| (e.stamp, h, i))
                })
                .min_by_key(|&(stamp, _, _)| stamp);
            let Some((_, h, oldest)) = victim else {
                break; // n_entries drifted from the buckets; stop early
            };
            let Some(bucket) = self.buckets.get_mut(&h) else { break };
            let e = bucket.remove(oldest);
            if bucket.is_empty() {
                self.buckets.remove(&h);
            }
            for &b in &e.blocks {
                pool.release(b);
            }
            self.n_entries -= 1;
            dropped += 1;
        }
        dropped
    }

    /// Release every entry (pool drains back to free).
    pub fn clear(&mut self, pool: &mut BlockPool) {
        for (_, bucket) in std::mem::take(&mut self.buckets) {
            for e in bucket {
                for &b in &e.blocks {
                    pool.release(b);
                }
            }
        }
        self.n_entries = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::super::pool::BlockDims;
    use super::super::table::BlockTable;
    use super::*;

    fn pool(n_blocks: usize) -> BlockPool {
        BlockPool::new(BlockDims { n_layers: 1, n_heads: 1,
                                   block_size: 2, head_dim: 2 },
                       n_blocks)
    }

    fn table_of(p: &mut BlockPool, rows: usize, x: f32) -> BlockTable {
        let mut t = BlockTable::new();
        let r = vec![x; p.dims().row_floats()];
        for _ in 0..rows {
            t.append_row(p, &r, &r).unwrap();
        }
        t
    }

    #[test]
    fn longest_whole_block_prefix_wins() {
        let mut p = pool(8);
        let mut ix = PrefixIndex::new();
        let t = table_of(&mut p, 4, 1.0);
        let toks = [5, 6, 7, 8];
        assert!(ix.register(&mut p, 42, 1.0, &toks[..2],
                            &t.blocks()[..1]));
        assert!(ix.register(&mut p, 42, 1.0, &toks, t.blocks()));
        assert!(!ix.register(&mut p, 42, 1.0, &toks, t.blocks()),
                "re-register is a no-op");
        assert_eq!(ix.len(), 2);

        // 5 prompt tokens: longest whole-block match is all 4
        let (blocks, n) = ix.lookup(42, 1.0, &[5, 6, 7, 8, 9], 2)
            .unwrap();
        assert_eq!(n, 4);
        assert_eq!(blocks, t.blocks());
        // 3 tokens: falls back to the 1-block entry
        let (blocks, n) = ix.lookup(42, 1.0, &[5, 6, 7], 2).unwrap();
        assert_eq!(n, 2);
        assert_eq!(blocks, &t.blocks()[..1]);
        assert_eq!(ix.hits, 2);
        assert_eq!(ix.lookups, 2);
    }

    #[test]
    fn sig_rope_and_tokens_all_gate_sharing() {
        let mut p = pool(8);
        let mut ix = PrefixIndex::new();
        let t = table_of(&mut p, 2, 1.0);
        ix.register(&mut p, 42, 1.0, &[5, 6], t.blocks());
        assert!(ix.lookup(43, 1.0, &[5, 6], 2).is_none(),
                "different weights must not share KV");
        assert!(ix.lookup(42, 2.0, &[5, 6], 2).is_none(),
                "different rope scale must not share KV");
        assert!(ix.lookup(42, 1.0, &[5, 9], 2).is_none(),
                "different tokens must not share KV");
        assert!(ix.lookup(42, 1.0, &[5], 2).is_none(),
                "sub-block prefixes never match");
        assert_eq!(ix.hits, 0);
        assert_eq!(ix.lookups, 4);
    }

    #[test]
    fn index_refs_keep_blocks_alive_after_sequence_release() {
        let mut p = pool(4);
        let mut ix = PrefixIndex::new();
        let mut t = table_of(&mut p, 2, 3.0);
        let blocks = t.blocks().to_vec();
        ix.register(&mut p, 1, 1.0, &[7, 8], &blocks);
        t.free(&mut p);
        // the prompt cache holds the block
        assert_eq!(p.used_blocks(), 1);
        let (got, n) = ix.lookup(1, 1.0, &[7, 8], 2).unwrap();
        assert_eq!((got, n), (blocks, 2));
        ix.clear(&mut p);
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    fn reclaim_drops_oldest_until_free() {
        let mut p = pool(4);
        let mut ix = PrefixIndex::new();
        let mut tables = Vec::new();
        for i in 0..4 {
            let t = table_of(&mut p, 2, i as f32);
            ix.register(&mut p, 9, 1.0, &[i, i + 1], t.blocks());
            tables.push(t);
        }
        for mut t in tables {
            t.free(&mut p);
        }
        assert_eq!(p.free_blocks(), 0);
        let dropped = ix.reclaim(&mut p, 2);
        assert_eq!(dropped, 2);
        assert_eq!(p.free_blocks(), 2);
        // oldest entries went first
        assert!(ix.lookup(9, 1.0, &[0, 1], 2).is_none());
        assert!(ix.lookup(9, 1.0, &[3, 4], 2).is_some());
        ix.clear(&mut p);
        assert_eq!(p.free_blocks(), 4);
    }

    #[test]
    fn share_sig_separates_parts() {
        assert_ne!(share_sig(&["ab", "c"]), share_sig(&["a", "bc"]));
        assert_eq!(share_sig(&["bitdelta", "2"]),
                   share_sig(&["bitdelta", "2"]));
    }
}
