//! KV-cache management for batched multi-tenant decode.
//!
//! The decode executables take a stacked cache
//! `[n_layers, B, n_heads, max_seq, head_dim]` plus a per-sequence
//! `pos` vector. Two designs for a sequence's backing memory coexist:
//!
//! * **Paged (default)** — [`BlockPool`] carves two flat K/V arenas
//!   into fixed-size ref-counted blocks; each sequence owns a
//!   [`BlockTable`] mapping positions onto blocks; appending past a
//!   shared block copy-on-writes; a content-hash [`PrefixIndex`]
//!   deduplicates identical token prefixes within and across tenants
//!   (BitDelta tenants share one base, so identically-served prompts
//!   produce bit-identical KV). Allocation failure is a typed
//!   [`KvOomError`]. Restacking is incremental: only a changed batch
//!   slot is gathered into the dense staging buffers.
//! * **Slab (fallback)** — the pre-paging design: every sequence
//!   preallocates a full `max_seq_len` dense slab ([`SeqCache`]).
//!   Retained behind `EngineConfig::kv_slab_fallback` as the A/B
//!   escape hatch; tests pin the two paths token-identical.
//!
//! [`SeqKv`] is the per-sequence handle the batcher carries — one
//! variant per design, unified behind `pos()`.

mod pool;
mod prefix;
mod table;

pub use pool::{BlockDims, BlockId, BlockPool, KvOomError};
pub use prefix::{share_sig, PrefixIndex};
pub use table::BlockTable;

use crate::config::ModelConfig;

/// A sequence's KV backing: paged block table or dense slab.
#[derive(Debug, Clone)]
pub enum SeqKv {
    /// Paged: positions live in pool blocks via a [`BlockTable`].
    Paged(BlockTable),
    /// Dense slab fallback (`EngineConfig::kv_slab_fallback`).
    Slab(SeqCache),
}

impl SeqKv {
    /// Current sequence length (valid KV positions).
    pub fn pos(&self) -> usize {
        match self {
            SeqKv::Paged(t) => t.len(),
            SeqKv::Slab(c) => c.pos,
        }
    }

    /// The paged table (panics on a slab — caller knows the mode).
    pub fn table(&self) -> &BlockTable {
        match self {
            SeqKv::Paged(t) => t,
            SeqKv::Slab(_) => panic!("slab sequence has no BlockTable"),
        }
    }

    pub fn table_mut(&mut self) -> &mut BlockTable {
        match self {
            SeqKv::Paged(t) => t,
            SeqKv::Slab(_) => panic!("slab sequence has no BlockTable"),
        }
    }

    /// The slab (panics on a paged table — caller knows the mode).
    pub fn slab_mut(&mut self) -> &mut SeqCache {
        match self {
            SeqKv::Paged(_) => panic!("paged sequence has no SeqCache"),
            SeqKv::Slab(c) => c,
        }
    }
}

/// Per-sequence KV cache: `[n_layers, n_heads, max_seq, head_dim]` pair.
#[derive(Debug, Clone)]
pub struct SeqCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Number of valid slots (== current sequence length).
    pub pos: usize,
    layer_stride: usize,
    cfg_dims: (usize, usize, usize, usize), // (L, H, S, hd)
}

impl SeqCache {
    pub fn new(cfg: &ModelConfig) -> Self {
        let (l, h, s, hd) = (cfg.n_layers, cfg.n_heads, cfg.max_seq_len,
                             cfg.head_dim());
        let n = l * h * s * hd;
        Self { k: vec![0.0; n], v: vec![0.0; n], pos: 0,
               layer_stride: h * s * hd, cfg_dims: (l, h, s, hd) }
    }

    pub fn dims(&self) -> (usize, usize, usize, usize) {
        self.cfg_dims
    }

    /// Bytes of valid cache content.
    pub fn valid_bytes(&self) -> usize {
        let (l, h, _, hd) = self.cfg_dims;
        2 * l * h * self.pos * hd * 4
    }

    pub fn layer_k(&self, layer: usize) -> &[f32] {
        &self.k[layer * self.layer_stride..(layer + 1) * self.layer_stride]
    }

    pub fn layer_v(&self, layer: usize) -> &[f32] {
        &self.v[layer * self.layer_stride..(layer + 1) * self.layer_stride]
    }
}

/// Stacked batch cache in the executable ABI layout
/// `[L, B, H, S, hd]` — assembled from per-sequence caches and scattered
/// back after the batch runs.
#[derive(Debug, Clone)]
pub struct BatchCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub batch: usize,
    dims: (usize, usize, usize, usize),
}

impl BatchCache {
    pub fn stack(cfg: &ModelConfig, seqs: &[&SeqCache], batch: usize)
                 -> Self {
        assert!(seqs.len() <= batch,
                "{} sequences > batch {batch}", seqs.len());
        let (l, h, s, hd) = (cfg.n_layers, cfg.n_heads, cfg.max_seq_len,
                             cfg.head_dim());
        let per_seq_layer = h * s * hd;
        let mut k = vec![0.0f32; l * batch * per_seq_layer];
        let mut v = vec![0.0f32; l * batch * per_seq_layer];
        for (b, seq) in seqs.iter().enumerate() {
            assert_eq!(seq.cfg_dims, (l, h, s, hd));
            for layer in 0..l {
                let dst = (layer * batch + b) * per_seq_layer;
                k[dst..dst + per_seq_layer]
                    .copy_from_slice(seq.layer_k(layer));
                v[dst..dst + per_seq_layer]
                    .copy_from_slice(seq.layer_v(layer));
            }
        }
        Self { k, v, batch, dims: (l, h, s, hd) }
    }

    /// Shape in the executable ABI.
    pub fn shape(&self) -> [usize; 5] {
        let (l, h, s, hd) = self.dims;
        [l, self.batch, h, s, hd]
    }

    /// Scatter slot `b` of a (possibly updated) stacked cache back into a
    /// per-sequence cache.
    pub fn unstack_into(&self, b: usize, seq: &mut SeqCache) {
        let (l, h, s, hd) = self.dims;
        assert_eq!(seq.cfg_dims, (l, h, s, hd));
        let per_seq_layer = h * s * hd;
        for layer in 0..l {
            let src = (layer * self.batch + b) * per_seq_layer;
            seq.k[layer * per_seq_layer..(layer + 1) * per_seq_layer]
                .copy_from_slice(&self.k[src..src + per_seq_layer]);
            seq.v[layer * per_seq_layer..(layer + 1) * per_seq_layer]
                .copy_from_slice(&self.v[src..src + per_seq_layer]);
        }
    }

    /// Replace the stacked buffers with fresh device output (same shape).
    pub fn replace(&mut self, k: Vec<f32>, v: Vec<f32>) {
        assert_eq!(k.len(), self.k.len());
        assert_eq!(v.len(), self.v.len());
        self.k = k;
        self.v = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig { name: "t".into(), vocab_size: 16, d_model: 8,
                      n_layers: 2, n_heads: 2, max_seq_len: 4, d_ff: 16,
                      rope_theta: 1e4, norm_eps: 1e-5 }
    }

    #[test]
    fn stack_unstack_roundtrip() {
        let cfg = cfg();
        let mut a = SeqCache::new(&cfg);
        let mut b = SeqCache::new(&cfg);
        for (i, x) in a.k.iter_mut().enumerate() {
            *x = i as f32;
        }
        for (i, x) in b.k.iter_mut().enumerate() {
            *x = -(i as f32);
        }
        a.pos = 2;
        b.pos = 3;
        let stacked = BatchCache::stack(&cfg, &[&a, &b], 2);
        let mut a2 = SeqCache::new(&cfg);
        let mut b2 = SeqCache::new(&cfg);
        stacked.unstack_into(0, &mut a2);
        stacked.unstack_into(1, &mut b2);
        assert_eq!(a.k, a2.k);
        assert_eq!(b.k, b2.k);
    }

    #[test]
    fn stack_pads_missing_slots() {
        let cfg = cfg();
        let a = SeqCache::new(&cfg);
        let stacked = BatchCache::stack(&cfg, &[&a], 4);
        assert_eq!(stacked.shape(), [2, 4, 2, 4, 4]);
        assert!(stacked.k.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn layer_views_disjoint() {
        let cfg = cfg();
        let c = SeqCache::new(&cfg);
        assert_eq!(c.layer_k(0).len(), c.layer_k(1).len());
        assert_eq!(c.layer_k(0).len() * cfg.n_layers, c.k.len());
    }

    #[test]
    fn seqkv_pos_unifies_both_backings() {
        let cfg = cfg();
        let mut slab = SeqKv::Slab(SeqCache::new(&cfg));
        assert_eq!(slab.pos(), 0);
        slab.slab_mut().pos = 3;
        assert_eq!(slab.pos(), 3);

        let mut pool = BlockPool::new(BlockDims::from_config(&cfg, 2),
                                      4);
        let mut paged = SeqKv::Paged(BlockTable::new());
        let row = vec![0.0; pool.dims().row_floats()];
        paged.table_mut().append_row(&mut pool, &row, &row).unwrap();
        assert_eq!(paged.pos(), 1);
        paged.table_mut().free(&mut pool);
    }
}
