//! House lint (`cargo xtask lint`) — the repo's static rules that
//! rustc/clippy cannot express, in the openvmm xtask style: a plain
//! binary that parses `rust/src` with [`syn`] and greps the docs,
//! wired into CI as its own job.
//!
//! Rules (each reported as `path:line: [rule] ...`):
//!
//! * `unwrap` / `expect` — forbidden outside tests unless the site (or
//!   one of the 4 lines above it) carries
//!   `// lint: allow(unwrap, reason)` (resp. `expect`). Honest
//!   invariants get a grep-able justification; request paths get typed
//!   errors.
//! * `safety` — every `unsafe` block is preceded by a `// SAFETY:`
//!   comment (attributes and comment lines may sit between).
//! * `metric` — every `bitdelta_*` token in a string literal or a
//!   docs code span must be an exact member or proper prefix of
//!   `coordinator::metric_names::EXPORTED_SERIES`.
//!   `// lint: allow(metric, reason)` exempts non-metric tokens.
//! * `exec-kind` — every string literal that *is* a `decode_*` word
//!   must be a member of `delta::codec::KNOWN_EXEC_KINDS`.
//! * `codec-registered` — every module under `src/delta/codecs/` is
//!   wired into `CodecRegistry::builtin()`.
//! * `std-sync` — the migrated concurrency core must import sync and
//!   thread primitives from `crate::sync`, not `std::sync` /
//!   `std::thread` (loom swaps the shim; direct std types would be
//!   invisible to the model checker). `// lint: allow(std-sync, ...)`
//!   marks the deliberate exceptions (const-constructible config
//!   cells).
//! * `raw-time` — the clock-migrated files (cluster layer, admission,
//!   the simulation harness and its test suites) must take time from
//!   `crate::sync::clock` (`clock::Instant` / `clock::sleep`), never
//!   `std::time::Instant` or a `thread::sleep` — a raw source would
//!   not dilate under the simulation harness's virtual clock. Unlike
//!   `std-sync` this rule scans *test* code too (sleep-paced tests are
//!   exactly what the virtual clock retires);
//!   `// lint: allow(raw-time, reason)` marks the deliberate real
//!   pacing naps.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use proc_macro2::TokenTree;
use syn::visit::Visit;

/// Files that must route all synchronization through `crate::sync`.
const SYNC_MIGRATED: &[&str] = &[
    "src/cluster/worker.rs",
    "src/cluster/frontend.rs",
    "src/cluster/autoscaler.rs",
    "src/coordinator/admission.rs",
    "src/gemm/dispatch.rs",
    "src/kvcache/pool.rs",
];

/// Files (relative to `rust/`) whose time sources must route through
/// `crate::sync::clock`. Includes integration tests — unlike the
/// syn-driven rules, `raw-time` deliberately covers test regions.
/// `src/sync.rs` itself is excluded (it implements the seam) and so
/// is `src/main.rs` (binary entry points measure real wall time).
const TIME_MIGRATED: &[&str] = &[
    "src/cluster/autoscaler.rs",
    "src/cluster/frontend.rs",
    "src/cluster/metrics.rs",
    "src/cluster/placement.rs",
    "src/cluster/testutil.rs",
    "src/cluster/worker.rs",
    "src/coordinator/admission.rs",
    "src/simharness/harness.rs",
    "src/simharness/mod.rs",
    "src/simharness/monitor.rs",
    "src/simharness/schedule.rs",
    "src/simharness/tenants.rs",
    "tests/service_concurrency.rs",
    "tests/sim_cluster.rs",
];

/// Docs scanned by the `metric` rule (CHANGES.md is a historical log
/// and deliberately not checked).
const DOC_FILES: &[&str] = &["README.md", "ROADMAP.md"];

fn main() -> ExitCode {
    let mode = std::env::args().nth(1).unwrap_or_default();
    if mode != "lint" {
        eprintln!("usage: cargo xtask lint");
        return ExitCode::from(2);
    }
    let root = repo_root();
    let rust = root.join("rust");

    let registry = parse_string_table(
        &read(&rust.join("src/coordinator/metric_names.rs")),
        "EXPORTED_SERIES",
    );
    let exec_kinds = parse_string_table(
        &read(&rust.join("src/delta/codec.rs")),
        "KNOWN_EXEC_KINDS",
    );
    if registry.is_empty() || exec_kinds.is_empty() {
        eprintln!("xtask: failed to parse the metric/exec registries");
        return ExitCode::FAILURE;
    }

    let mut findings: Vec<String> = Vec::new();
    for file in rust_sources(&rust.join("src")) {
        lint_rust_file(&file, &rust, &registry, &exec_kinds,
                       &mut findings);
    }
    lint_codec_registration(&rust, &mut findings);
    lint_raw_time(&rust, &mut findings);
    for doc in DOC_FILES {
        lint_doc(&root.join(doc), &registry, &mut findings);
    }
    for doc in md_files(&root.join("docs")) {
        lint_doc(&doc, &registry, &mut findings);
    }

    if findings.is_empty() {
        println!("xtask lint: clean");
        ExitCode::SUCCESS
    } else {
        findings.sort();
        for f in &findings {
            println!("{f}");
        }
        println!("xtask lint: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}

fn repo_root() -> PathBuf {
    // run from rust/ (the cargo alias) or from the repo root
    if Path::new("rust/src").is_dir() {
        PathBuf::from(".")
    } else {
        PathBuf::from("..")
    }
}

fn read(p: &Path) -> String {
    std::fs::read_to_string(p).unwrap_or_default()
}

fn rust_sources(dir: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else { return out };
    for e in entries.flatten() {
        let p = e.path();
        if p.is_dir() {
            out.extend(rust_sources(&p));
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    out.sort();
    out
}

fn md_files(dir: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else { return out };
    for e in entries.flatten() {
        let p = e.path();
        if p.extension().is_some_and(|x| x == "md") {
            out.push(p);
        }
    }
    out.sort();
    out
}

/// Extract the string members of `pub const NAME: &[&str] = &[...]`
/// from a source file, without compiling the crate.
fn parse_string_table(src: &str, name: &str) -> Vec<String> {
    let Some(start) = src.find(&format!("const {name}")) else {
        return Vec::new();
    };
    let Some(end) = src[start..].find("];") else { return Vec::new() };
    let body = &src[start..start + end];
    let mut out = Vec::new();
    let mut rest = body;
    while let Some(q0) = rest.find('"') {
        let tail = &rest[q0 + 1..];
        let Some(q1) = tail.find('"') else { break };
        out.push(tail[..q1].to_string());
        rest = &tail[q1 + 1..];
    }
    out
}

// ---------------------------------------------------------------------
// Rust-file rules (syn-driven)
// ---------------------------------------------------------------------

struct RustLinter<'a> {
    rel: String,
    lines: Vec<&'a str>,
    registry: &'a [String],
    exec_kinds: &'a [String],
    in_tests: bool,
    findings: &'a mut Vec<String>,
}

impl RustLinter<'_> {
    fn allowed(&self, line: usize, rule: &str) -> bool {
        let lo = line.saturating_sub(5); // site line + 4 above
        self.lines[lo..line.min(self.lines.len())]
            .iter()
            .any(|l| l.contains("lint: allow(")
                 && l.contains(rule))
    }

    fn finding(&mut self, line: usize, rule: &str, msg: String) {
        self.findings
            .push(format!("{}:{}: [{}] {}", self.rel, line, rule, msg));
    }

    fn check_call(&mut self, method: &str, line: usize) {
        if self.in_tests || (method != "unwrap" && method != "expect") {
            return;
        }
        if !self.allowed(line, method) {
            self.finding(line, method.into(), format!(
                ".{method}() without `// lint: allow({method}, reason)` \
— return a typed error or justify the invariant"));
        }
    }

    fn check_unsafe(&mut self, line: usize) {
        // walk up over comments and attributes looking for SAFETY:
        let mut i = line.saturating_sub(1); // 0-based index of prev line
        while i > 0 {
            let l = self.lines[i - 1].trim_start();
            if l.starts_with("//") {
                if l.contains("SAFETY:") {
                    return;
                }
                i -= 1;
            } else if l.starts_with("#[") || l.is_empty() {
                i -= 1;
            } else {
                break;
            }
        }
        // the unsafe keyword's own line may open mid-statement with
        // the comment above the statement head; also accept same line
        if self.lines.get(line.saturating_sub(1))
            .is_some_and(|l| l.contains("SAFETY:"))
        {
            return;
        }
        self.finding(line, "safety",
                     "unsafe block without a preceding // SAFETY: \
comment".into());
    }

    fn check_literal(&mut self, text: &str, line: usize) {
        // exec-kind: the literal as a whole is a decode_* word
        if is_exec_word(text)
            && !self.exec_kinds.iter().any(|k| k == text)
            && !self.allowed(line, "exec-kind")
        {
            self.finding(line, "exec-kind", format!(
                "{text:?} is not in delta::codec::KNOWN_EXEC_KINDS"));
        }
        // metric: every bitdelta_* token must be registered
        for tok in metric_tokens(text) {
            if !registered(self.registry, &tok)
                && !self.allowed(line, "metric")
            {
                self.finding(line, "metric", format!(
                    "{tok:?} is not in \
metric_names::EXPORTED_SERIES (exact or prefix)"));
            }
        }
    }

    fn scan_macro_tokens(&mut self, ts: proc_macro2::TokenStream) {
        for tt in ts {
            match tt {
                TokenTree::Group(g) => self.scan_macro_tokens(g.stream()),
                TokenTree::Ident(id) => {
                    let s = id.to_string();
                    if s == "unwrap" || s == "expect" {
                        self.check_call(&s, id.span().start().line);
                    }
                }
                TokenTree::Literal(l) => {
                    let s = l.to_string();
                    if s.starts_with('"') && s.ends_with('"')
                        && s.len() >= 2
                    {
                        self.check_literal(&s[1..s.len() - 1],
                                           l.span().start().line);
                    }
                }
                TokenTree::Punct(_) => {}
            }
        }
    }
}

fn is_cfg_test(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| {
        a.path().is_ident("cfg")
            && a.parse_args::<syn::Ident>()
                .map(|i| i == "test")
                .unwrap_or(false)
    })
}

impl<'ast> Visit<'ast> for RustLinter<'_> {
    fn visit_item_mod(&mut self, m: &'ast syn::ItemMod) {
        let was = self.in_tests;
        if is_cfg_test(&m.attrs) {
            self.in_tests = true;
        }
        syn::visit::visit_item_mod(self, m);
        self.in_tests = was;
    }

    fn visit_item_fn(&mut self, f: &'ast syn::ItemFn) {
        let was = self.in_tests;
        if is_cfg_test(&f.attrs)
            || f.attrs.iter().any(|a| a.path().is_ident("test"))
        {
            self.in_tests = true;
        }
        syn::visit::visit_item_fn(self, f);
        self.in_tests = was;
    }

    fn visit_expr_method_call(&mut self,
                              e: &'ast syn::ExprMethodCall) {
        let m = e.method.to_string();
        self.check_call(&m, e.method.span().start().line);
        syn::visit::visit_expr_method_call(self, e);
    }

    fn visit_expr_unsafe(&mut self, e: &'ast syn::ExprUnsafe) {
        self.check_unsafe(e.unsafe_token.span.start().line);
        syn::visit::visit_expr_unsafe(self, e);
    }

    fn visit_lit_str(&mut self, s: &'ast syn::LitStr) {
        self.check_literal(&s.value(), s.span().start().line);
    }

    fn visit_macro(&mut self, m: &'ast syn::Macro) {
        self.scan_macro_tokens(m.tokens.clone());
        syn::visit::visit_macro(self, m);
    }
}

fn lint_rust_file(path: &Path, rust_root: &Path, registry: &[String],
                  exec_kinds: &[String],
                  findings: &mut Vec<String>) {
    let src = read(path);
    let rel = path.strip_prefix(rust_root).unwrap_or(path)
        .display().to_string();
    let ast = match syn::parse_file(&src) {
        Ok(a) => a,
        Err(e) => {
            findings.push(format!("{rel}:1: [parse] {e}"));
            return;
        }
    };
    let lines: Vec<&str> = src.lines().collect();
    let mut linter = RustLinter {
        rel: rel.clone(),
        lines: lines.clone(),
        registry,
        exec_kinds,
        in_tests: false,
        findings,
    };
    linter.visit_file(&ast);

    // std-sync: textual, on the migrated concurrency core only
    if SYNC_MIGRATED.iter().any(|m| rel == *m) {
        for (i, l) in non_test_lines(&lines) {
            let code = l.split("//").next().unwrap_or("");
            if (code.contains("std::sync::")
                || code.contains("std::thread::"))
                && !window_allows(&lines, i, "std-sync")
            {
                findings.push(format!(
                    "{rel}:{i}: [std-sync] direct std primitive in a \
loom-migrated module — import from crate::sync"));
            }
        }
    }
}

/// `(1-based line, text)` for lines outside `#[cfg(test)] mod` regions.
fn non_test_lines<'a>(lines: &'a [&'a str])
                      -> Vec<(usize, &'a str)> {
    let mut out = Vec::new();
    let mut depth = 0i64;
    let mut test_at: Option<i64> = None;
    let mut pending_cfg = false;
    for (idx, l) in lines.iter().enumerate() {
        let t = l.trim_start();
        if t.starts_with("#[cfg(test)") {
            pending_cfg = true;
        } else if pending_cfg && t.starts_with("mod ") {
            test_at = test_at.or(Some(depth));
            pending_cfg = false;
        } else if pending_cfg && !t.starts_with("#[") {
            pending_cfg = false;
        }
        depth += l.matches('{').count() as i64;
        depth -= l.matches('}').count() as i64;
        if let Some(d) = test_at {
            if depth <= d {
                test_at = None;
            }
            continue;
        }
        out.push((idx + 1, *l));
    }
    out
}

fn window_allows(lines: &[&str], line: usize, rule: &str) -> bool {
    let lo = line.saturating_sub(5);
    lines[lo..line.min(lines.len())]
        .iter()
        .any(|l| l.contains("lint: allow(") && l.contains(rule))
}

// ---------------------------------------------------------------------
// Token helpers
// ---------------------------------------------------------------------

fn is_exec_word(s: &str) -> bool {
    s.strip_prefix("decode_").is_some_and(|rest| {
        !rest.is_empty()
            && rest.chars()
                .all(|c| c.is_ascii_lowercase()
                     || c.is_ascii_digit() || c == '_')
    })
}

/// `bitdelta_*` word tokens in `text` (word-boundary on the left;
/// stops at the first non-`[a-z0-9_]` char; trailing `_` trimmed so
/// family prefixes compare cleanly).
fn metric_tokens(text: &str) -> Vec<String> {
    let bytes = text.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while let Some(p) = text[i..].find("bitdelta_") {
        let at = i + p;
        let boundary = at == 0 || {
            let c = bytes[at - 1] as char;
            !(c.is_ascii_alphanumeric() || c == '_')
        };
        let mut end = at;
        while end < text.len() {
            let c = bytes[end] as char;
            if c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_' {
                end += 1;
            } else {
                break;
            }
        }
        if boundary {
            let tok = text[at..end].trim_end_matches('_');
            if tok.len() > "bitdelta".len() {
                out.push(tok.to_string());
            }
        }
        i = end.max(at + 1);
    }
    out
}

fn registered(registry: &[String], tok: &str) -> bool {
    registry.iter().any(|s| {
        s == tok || (s.len() > tok.len() && s.starts_with(tok))
    })
}

// ---------------------------------------------------------------------
// Cross-file rules
// ---------------------------------------------------------------------

fn lint_codec_registration(rust: &Path, findings: &mut Vec<String>) {
    let codec_rs = read(&rust.join("src/delta/codec.rs"));
    let dir = rust.join("src/delta/codecs");
    let Ok(entries) = std::fs::read_dir(&dir) else {
        findings.push("src/delta/codecs:1: [codec-registered] \
directory missing".into());
        return;
    };
    for e in entries.flatten() {
        let name = e.file_name().to_string_lossy().into_owned();
        let Some(module) = name.strip_suffix(".rs") else { continue };
        if module == "mod" {
            continue;
        }
        if !codec_rs.contains(&format!("codecs::{module}::")) {
            findings.push(format!(
                "src/delta/codecs/{name}:1: [codec-registered] module \
{module} is not registered in CodecRegistry::builtin()"));
        }
    }
}

/// `raw-time`: wall-clock sources in clock-migrated files. A separate
/// textual pass (not part of `lint_rust_file`) because it covers
/// `tests/` binaries the syn walk never visits, and because — unlike
/// `std-sync` — test regions are *not* exempt. Matches
/// `std::time::Instant` (construction or paths) and any
/// `thread::sleep(` call (std's or the `crate::sync::thread` wrapper —
/// in a migrated file both must be `clock::sleep` or carry an allow).
fn lint_raw_time(rust: &Path, findings: &mut Vec<String>) {
    for rel in TIME_MIGRATED {
        let src = read(&rust.join(rel));
        if src.is_empty() {
            findings.push(format!(
                "{rel}:1: [raw-time] listed in TIME_MIGRATED but \
missing or unreadable — fix the list or restore the file"));
            continue;
        }
        let lines: Vec<&str> = src.lines().collect();
        for (idx, l) in lines.iter().enumerate() {
            let i = idx + 1;
            let code = l.split("//").next().unwrap_or("");
            if (code.contains("std::time::Instant")
                || code.contains("thread::sleep("))
                && !window_allows(&lines, i, "raw-time")
            {
                findings.push(format!(
                    "{rel}:{i}: [raw-time] wall-clock time source in \
a clock-migrated file — use crate::sync::clock (Instant / sleep) so \
the simulation harness's virtual clock dilates it, or justify with \
`// lint: allow(raw-time, reason)`"));
            }
        }
    }
}

fn lint_doc(path: &Path, registry: &[String],
            findings: &mut Vec<String>) {
    let src = read(path);
    if src.is_empty() {
        return;
    }
    let name = path.file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_default();
    for (i, line) in src.lines().enumerate() {
        for tok in metric_tokens(line) {
            if !registered(registry, &tok) {
                findings.push(format!(
                    "{name}:{}: [metric] {tok:?} is not in \
metric_names::EXPORTED_SERIES (exact or prefix)", i + 1));
            }
        }
    }
}
