//! Randomised property tests (in-tree proptest substitute,
//! `util::prop::run_cases`) over the invariants the coordinator and the
//! compression substrate must hold for *every* input, not just the unit
//! fixtures: packing round-trips, kernel linearity, quantizer optimality,
//! router/batcher state machines, store integrity.


use bitdelta::config::ModelConfig;
use bitdelta::coordinator::admission::AdmissionPolicy;
use bitdelta::coordinator::batcher::{ActiveSeq, Batcher};
use bitdelta::coordinator::router::{Router, TenantInfo};
use bitdelta::delta::packing::{pack_signs, packed_row_bytes, popcount,
                               unpack_signs};
use bitdelta::gemm::binary::binary_gemv_bitextract;
use bitdelta::gemm::dispatch::{self, Tier};
use bitdelta::gemm::{batched_binary_gemv, binary_gemv, dense_gemv,
                     lora_gemv, try_binary_gemv, try_binary_gemv_multi};
use bitdelta::kvcache::{BlockDims, BlockPool, BlockTable, PrefixIndex,
                        SeqCache, SeqKv};
use bitdelta::model::sampling::SamplingParams;
use bitdelta::serving::request::{QueuedRequest, Request};
use bitdelta::store::bdw::{parse_bdw, write_bdw, Bdw, RawTensor};
use bitdelta::util::prop::{run_cases, Rng};

/// `force_tier` / `set_pool_threads` are process globals and this
/// binary's tests run in parallel, so every test that mutates them —
/// or asserts *exact* equality between two kernel calls that must see
/// the same tier — serializes on this lock.
static KERNEL_CONFIG: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn kernel_lock() -> std::sync::MutexGuard<'static, ()> {
    KERNEL_CONFIG.lock().unwrap_or_else(|e| e.into_inner())
}

fn tiny_cfg() -> ModelConfig {
    ModelConfig { name: "t".into(), vocab_size: 16, d_model: 8,
                  n_layers: 1, n_heads: 2, d_ff: 16, max_seq_len: 8,
                  rope_theta: 1e4, norm_eps: 1e-5 }
}

#[test]
fn packing_roundtrip_preserves_sign_pattern() {
    run_cases(60, |rng| {
        let rows = rng.usize_in(1, 6);
        let m = rng.usize_in(1, 9) * 8;
        let vals = rng.f32_vec(rows * m);
        let packed = pack_signs(&vals, m);
        assert_eq!(packed.len(), rows * m / 8);
        let signs = unpack_signs(&packed, m);
        for (v, s) in vals.iter().zip(&signs) {
            assert_eq!(*s, if *v > 0.0 { 1.0 } else { -1.0 });
        }
        // popcount consistency
        let pos = vals.iter().filter(|v| **v > 0.0).count();
        assert_eq!(popcount(&packed), pos);
    });
}

#[test]
fn lut_and_bitextract_kernels_agree_at_any_width() {
    // The two independent binary-GEMV implementations must agree on
    // every randomized (shape, seed, alpha) — including logical widths
    // that are NOT multiples of 8, which exercise the byte-boundary
    // padding introduced by the packing layer.
    run_cases(80, |rng| {
        let n = rng.usize_in(1, 12);
        let m = rng.usize_in(1, 41);           // 1..=40, any remainder mod 8
        let vals = rng.f32_vec(n * m);
        let bits = pack_signs(&vals, m);
        let x = rng.f32_vec(m);
        let alpha = rng.f32_pm1().abs() + 0.05;

        let mut y_lut = vec![0f32; n];
        let mut y_ext = vec![0f32; n];
        binary_gemv(&bits, n, m, &x, alpha, &mut y_lut);
        binary_gemv_bitextract(&bits, n, m, &x, alpha, &mut y_ext);
        for (a, b) in y_lut.iter().zip(&y_ext) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0),
                    "n={n} m={m} alpha={alpha}: lut {a} vs bitextract {b}");
        }
        // both must also match the dense ±1 reference
        let signs: Vec<f32> = vals.iter()
            .map(|v| if *v > 0.0 { alpha } else { -alpha }).collect();
        let mut want = vec![0f32; n];
        dense_gemv(&signs, n, m, &x, &mut want);
        for (a, b) in y_lut.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0),
                    "n={n} m={m}: lut {a} vs dense reference {b}");
        }
    });
}

#[test]
fn malformed_packed_buffers_rejected_at_any_width() {
    // Set padding bits must produce a clear error, never a silent wrong
    // dot product.
    run_cases(40, |rng| {
        let n = rng.usize_in(1, 6);
        let m = rng.usize_in(1, 40);
        if m % 8 == 0 {
            return;                            // no padding to corrupt
        }
        let vals = rng.f32_vec(n * m);
        let mut bits = pack_signs(&vals, m);
        let mb = packed_row_bytes(m);
        let row = rng.usize_in(0, n);
        bits[row * mb + mb - 1] |= 1 << 7;     // always a padding bit
        let x = rng.f32_vec(m);
        let mut y = vec![0f32; n];
        let e = try_binary_gemv(&bits, n, m, &x, 1.0, &mut y)
            .unwrap_err();
        assert!(e.to_string().contains("padding"), "{e}");
    });
}

#[test]
fn binary_gemv_is_linear_in_scale_and_x() {
    run_cases(40, |rng| {
        let n = rng.usize_in(1, 8);
        let m = rng.usize_in(1, 6) * 8;
        let vals = rng.f32_vec(n * m);
        let bits = pack_signs(&vals, m);
        let x = rng.f32_vec(m);
        let alpha = 0.5 + rng.f32_pm1().abs();

        let mut y1 = vec![0f32; n];
        binary_gemv(&bits, n, m, &x, alpha, &mut y1);
        // scale linearity
        let mut y2 = vec![0f32; n];
        binary_gemv(&bits, n, m, &x, 2.0 * alpha, &mut y2);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((2.0 * a - b).abs() <= 1e-3 * b.abs().max(1.0),
                    "{a} {b}");
        }
        // x linearity
        let x2: Vec<f32> = x.iter().map(|v| 3.0 * v).collect();
        let mut y3 = vec![0f32; n];
        binary_gemv(&bits, n, m, &x2, alpha, &mut y3);
        for (a, b) in y1.iter().zip(&y3) {
            assert!((3.0 * a - b).abs() <= 1e-3 * b.abs().max(1.0));
        }
    });
}

#[test]
fn binary_gemv_agrees_with_dense_on_sign_matrix() {
    run_cases(40, |rng| {
        let n = rng.usize_in(1, 10);
        let m = rng.usize_in(1, 5) * 8;
        let vals = rng.f32_vec(n * m);
        let bits = pack_signs(&vals, m);
        let dense: Vec<f32> = vals.iter()
            .map(|v| if *v > 0.0 { 1.0 } else { -1.0 }).collect();
        let x = rng.f32_vec(m);
        let mut y1 = vec![0f32; n];
        binary_gemv(&bits, n, m, &x, 1.0, &mut y1);
        let mut y2 = vec![0f32; n];
        dense_gemv(&dense, n, m, &x, &mut y2);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    });
}

#[test]
fn every_dispatch_tier_matches_the_dense_reference() {
    // The same randomized widths as the lut/bitextract agreement test
    // — including logical widths that are NOT multiples of 8 — but
    // swept across every dispatch tier via the force override.
    // Forcing a tier this host cannot run falls back to scalar, so
    // the sweep is portable across x86_64 / aarch64 / anything else.
    let _g = kernel_lock();
    for tier in Tier::ALL {
        dispatch::force_tier(Some(tier));
        run_cases(40, |rng| {
            let n = rng.usize_in(1, 12);
            let m = rng.usize_in(1, 41);
            let vals = rng.f32_vec(n * m);
            let bits = pack_signs(&vals, m);
            let x = rng.f32_vec(m);
            let alpha = rng.f32_pm1().abs() + 0.05;
            let mut y = vec![0f32; n];
            try_binary_gemv(&bits, n, m, &x, alpha, &mut y).unwrap();
            let signs: Vec<f32> = vals.iter()
                .map(|v| if *v > 0.0 { alpha } else { -alpha })
                .collect();
            let mut want = vec![0f32; n];
            dense_gemv(&signs, n, m, &x, &mut want);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0),
                        "tier {tier} n={n} m={m}: {a} vs dense {b}");
            }
        });
    }
    dispatch::force_tier(None);
}

#[test]
fn threaded_tiling_is_bit_identical_on_every_tier() {
    // Per-row results are independent of the row split, so the tiled
    // multicore path must reproduce the single-thread output *bit for
    // bit* on every tier. n=4096 rows of 8 packed bytes clears the
    // 8 KiB-per-chunk tiling threshold at 4 threads (1024-row
    // chunks), so the pool genuinely engages.
    let _g = kernel_lock();
    let prev_threads = dispatch::pool_threads();
    let (n, m) = (4096usize, 64usize);
    let mut rng = Rng::new(11);
    let vals = rng.f32_vec(n * m);
    let bits = pack_signs(&vals, m);
    let vals2 = rng.f32_vec(n * m);
    let bits2 = pack_signs(&vals2, m);
    let x = rng.f32_vec(m);
    let levels: Vec<(&[u8], f32)> =
        vec![(bits.as_slice(), 0.07), (bits2.as_slice(), 0.03)];
    for tier in Tier::ALL {
        dispatch::force_tier(Some(tier));
        dispatch::set_pool_threads(1);
        let mut y1 = vec![0f32; n];
        try_binary_gemv(&bits, n, m, &x, 0.05, &mut y1).unwrap();
        let mut y1m = vec![0f32; n];
        try_binary_gemv_multi(&levels, n, m, &x, &mut y1m).unwrap();
        for threads in [2usize, 4] {
            dispatch::set_pool_threads(threads);
            let mut y = vec![0f32; n];
            try_binary_gemv(&bits, n, m, &x, 0.05, &mut y).unwrap();
            assert!(y.iter().zip(&y1)
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "tier {tier} threads {threads}: single-level \
output depends on the split");
            let mut ym = vec![0f32; n];
            try_binary_gemv_multi(&levels, n, m, &x, &mut ym).unwrap();
            assert!(ym.iter().zip(&y1m)
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "tier {tier} threads {threads}: multi-level \
output depends on the split");
        }
    }
    dispatch::force_tier(None);
    dispatch::set_pool_threads(prev_threads);
}

#[test]
fn zero_scale_levels_are_exact_noops_on_every_tier() {
    // A zero-scale mask level (how narrower fidelity tiers pad up to
    // a shared level count) must contribute exactly 0.0 on every
    // tier, at any width — including non-multiples of 8.
    let _g = kernel_lock();
    for tier in Tier::ALL {
        dispatch::force_tier(Some(tier));
        run_cases(20, |rng| {
            let n = rng.usize_in(1, 8);
            let m = rng.usize_in(1, 33);
            let vals = rng.f32_vec(n * m);
            let bits = pack_signs(&vals, m);
            let vals2 = rng.f32_vec(n * m);
            let bits2 = pack_signs(&vals2, m);
            let x = rng.f32_vec(m);
            let with: Vec<(&[u8], f32)> =
                vec![(bits.as_slice(), 0.06), (bits2.as_slice(), 0.0)];
            let without: Vec<(&[u8], f32)> =
                vec![(bits.as_slice(), 0.06)];
            let mut ya = vec![0f32; n];
            try_binary_gemv_multi(&with, n, m, &x, &mut ya).unwrap();
            let mut yb = vec![0f32; n];
            try_binary_gemv_multi(&without, n, m, &x, &mut yb)
                .unwrap();
            for (a, b) in ya.iter().zip(&yb) {
                assert_eq!(a.to_bits(), b.to_bits(),
                           "tier {tier} n={n} m={m}: {a} vs {b}");
            }
        });
    }
    dispatch::force_tier(None);
}

#[test]
fn batched_binary_gemv_equals_per_tenant_loop() {
    // exact equality between two kernel calls — hold the config lock
    // so a concurrent tier sweep cannot flip dispatch mid-compare
    let _g = kernel_lock();
    run_cases(25, |rng| {
        let b = rng.usize_in(1, 5);
        let n = rng.usize_in(1, 6);
        let m = rng.usize_in(1, 4) * 8;
        let vals = rng.f32_vec(b * n * m);
        let bits: Vec<u8> = (0..b).flat_map(|bi| {
            pack_signs(&vals[bi * n * m..(bi + 1) * n * m], m)
        }).collect();
        let xs = rng.f32_vec(b * m);
        let alphas: Vec<f32> = (0..b).map(|_| rng.f32_pm1().abs() + 0.1)
            .collect();
        let mut ys = vec![0f32; b * n];
        batched_binary_gemv(&bits, n, m, &xs, &alphas, b, &mut ys);
        for bi in 0..b {
            let mut y = vec![0f32; n];
            binary_gemv(&bits[bi * n * m / 8..(bi + 1) * n * m / 8],
                        n, m, &xs[bi * m..(bi + 1) * m], alphas[bi],
                        &mut y);
            assert_eq!(&ys[bi * n..(bi + 1) * n], &y[..]);
        }
    });
}

#[test]
fn lora_gemv_rank_additivity() {
    // adapters compose: [A1;A2],[B1 B2] == A1,B1 + A2,B2
    run_cases(25, |rng| {
        let n = rng.usize_in(2, 8);
        let m = rng.usize_in(2, 8);
        let r1 = rng.usize_in(1, 3);
        let r2 = rng.usize_in(1, 3);
        let a1 = rng.f32_vec(r1 * m);
        let a2 = rng.f32_vec(r2 * m);
        let b1 = rng.f32_vec(n * r1);
        let b2 = rng.f32_vec(n * r2);
        let x = rng.f32_vec(m);

        let mut cat_a = a1.clone();
        cat_a.extend(&a2);
        // b rows interleave: [n, r1+r2] row-major
        let mut cat_b = Vec::with_capacity(n * (r1 + r2));
        for i in 0..n {
            cat_b.extend(&b1[i * r1..(i + 1) * r1]);
            cat_b.extend(&b2[i * r2..(i + 1) * r2]);
        }
        let mut y_cat = vec![0f32; n];
        lora_gemv(&cat_a, &cat_b, r1 + r2, n, m, &x, &mut y_cat);
        let mut y1 = vec![0f32; n];
        lora_gemv(&a1, &b1, r1, n, m, &x, &mut y1);
        let mut y2 = vec![0f32; n];
        lora_gemv(&a2, &b2, r2, n, m, &x, &mut y2);
        for i in 0..n {
            assert!((y_cat[i] - (y1[i] + y2[i])).abs()
                    < 1e-3 * y_cat[i].abs().max(1.0));
        }
    });
}

#[test]
fn alpha_mean_abs_is_l2_optimal() {
    // Paper Eq. 3-4: among all scalars for a FIXED sign matrix,
    // α = mean|Δ| minimises the L2 error.
    run_cases(40, |rng| {
        let k = rng.usize_in(4, 64);
        let d = rng.f32_vec(k);
        let alpha: f32 = d.iter().map(|v| v.abs()).sum::<f32>() / k as f32;
        let err = |a: f32| -> f64 {
            d.iter().map(|v| {
                let s = if *v > 0.0 { a } else { -a };
                ((*v - s) as f64).powi(2)
            }).sum()
        };
        let e0 = err(alpha);
        for factor in [0.8f32, 0.95, 1.05, 1.25] {
            assert!(e0 <= err(alpha * factor) + 1e-9,
                    "alpha {alpha} beaten by x{factor}");
        }
    });
}

#[test]
fn bdw_roundtrip_arbitrary_tensors() {
    run_cases(25, |rng| {
        let mut bdw = Bdw::new();
        let n_tensors = rng.usize_in(1, 6);
        for i in 0..n_tensors {
            let rows = rng.usize_in(1, 5);
            let cols = rng.usize_in(1, 7);
            if rng.bool() {
                let vals = rng.f32_vec(rows * cols);
                bdw.insert(format!("t{i}"),
                           RawTensor::f32(vec![rows, cols], &vals));
            } else {
                let vals: Vec<u8> = (0..rows * cols)
                    .map(|_| (rng.next_u64() & 0xFF) as u8).collect();
                bdw.insert(format!("t{i}"),
                           RawTensor::u8(vec![rows, cols], vals));
            }
        }
        let path = std::env::temp_dir()
            .join(format!("prop_bdw_{}.bdw", rng.next_u64()));
        write_bdw(&path, &bdw).unwrap();
        let buf = std::fs::read(&path).unwrap();
        let back = parse_bdw(&buf).unwrap();
        assert_eq!(back.names, bdw.names);
        for name in &bdw.names {
            assert_eq!(back.get(name).unwrap(), bdw.get(name).unwrap());
        }
        // any truncation must be detected
        let cut = rng.usize_in(1, buf.len());
        assert!(parse_bdw(&buf[..buf.len() - cut]).is_err());
        std::fs::remove_file(path).ok();
    });
}

fn mk_req(tenant: &str, id: u64) -> QueuedRequest {
    QueuedRequest::for_test(Request {
        tenant: tenant.into(), prompt: "Q".into(), max_new_tokens: 2,
        sampling: SamplingParams::greedy(),
    }, id)
}

#[test]
fn router_conservation_and_fairness() {
    // Invariant: enqueued == drained + still-queued + rejected-none;
    // drain never exceeds request count; round-robin serves every
    // tenant with pending work before repeats.
    run_cases(30, |rng| {
        let mut r = Router::new(AdmissionPolicy {
            per_tenant_cap: 1000, total_cap: 10_000 });
        let tenants = ["a", "b", "c"];
        for t in tenants {
            r.register_tenant(TenantInfo::new(t, 1.0));
        }
        let mut pushed = 0u64;
        for i in 0..rng.usize_in(1, 30) {
            let t = rng.choose(&tenants);
            r.enqueue(mk_req(t, i as u64)).unwrap();
            pushed += 1;
        }
        let mut drained = 0u64;
        loop {
            let take = rng.usize_in(1, 5);
            let got = r.drain(take);
            drained += got.len() as u64;
            if got.is_empty() {
                break;
            }
        }
        assert_eq!(drained, pushed);
        assert_eq!(r.total_queued(), 0);
    });
}

#[test]
fn batcher_slots_conserved() {
    // admitted == released + occupied, always; composition id strictly
    // increases on every topology change.
    run_cases(30, |rng| {
        let cap = rng.usize_in(1, 6);
        let mut b = Batcher::new(cap);
        let cfg = tiny_cfg();
        let mut last_comp = b.composition_id();
        let mut live: Vec<usize> = Vec::new();
        for step in 0..rng.usize_in(5, 40) {
            if rng.bool() && live.len() < cap {
                let seq = ActiveSeq {
                    req: mk_req("a", step as u64),
                    tenant: "a".into(),
                    rope_scale: 1.0,
                    kv: SeqKv::Slab(SeqCache::new(&cfg)),
                    prompt: vec![1],
                    prompt_pos: 0,
                    generated: vec![],
                    next_token: 1,
                    started: std::time::Instant::now(),
                    first_token_at: None,
                };
                let slot = match b.admit(seq) {
                    Ok(s) => s,
                    Err(_) => continue,
                };
                assert!(!live.contains(&slot));
                live.push(slot);
                assert!(b.composition_id() > last_comp);
                last_comp = b.composition_id();
            } else if let Some(pos) = (!live.is_empty())
                .then(|| rng.usize_in(0, live.len())) {
                let slot = live.swap_remove(pos);
                assert!(b.release(slot).is_some());
                assert!(b.composition_id() > last_comp);
                last_comp = b.composition_id();
            }
            assert_eq!(b.occupancy(), live.len());
            assert_eq!(b.free_slots(), cap - live.len());
            assert_eq!(b.admitted - b.completed, live.len() as u64);
        }
    });
}

#[test]
fn admission_policy_total_ordering() {
    // if a request is rejected at queue state (t, g), it is also
    // rejected at any (t' >= t, g' >= g)
    run_cases(40, |rng| {
        let p = AdmissionPolicy {
            per_tenant_cap: rng.usize_in(1, 10),
            total_cap: rng.usize_in(1, 40),
        };
        let t = rng.usize_in(0, 12);
        let g = rng.usize_in(t, 50);
        use bitdelta::coordinator::admission::Verdict;
        if matches!(p.admit(t, g), Verdict::Reject(_)) {
            assert!(matches!(p.admit(t + 1, g + 1), Verdict::Reject(_)));
            assert!(matches!(p.admit(t, g + 5), Verdict::Reject(_))
                    || t >= p.per_tenant_cap);
        }
    });
}

#[test]
fn block_pool_conserves_blocks_under_random_churn() {
    // Shadow-refcount model: after any interleaving of alloc / retain /
    // release, pool bookkeeping matches the model exactly — no leaks,
    // no premature frees — and a full drain returns every block.
    run_cases(30, |rng| {
        let total = rng.usize_in(2, 13);
        let dims = BlockDims { n_layers: 1, n_heads: 1,
                               block_size: 2, head_dim: 2 };
        let mut pool = BlockPool::new(dims, total);
        let mut live: Vec<(u32, u32)> = Vec::new(); // (id, shadow rc)
        for _ in 0..rng.usize_in(10, 60) {
            match rng.usize_in(0, 3) {
                0 => match pool.alloc() {
                    Ok(id) => live.push((id, 1)),
                    Err(e) => {
                        assert_eq!(e.free, 0, "OOM only when empty");
                        assert_eq!(pool.free_blocks(), 0);
                    }
                },
                1 => if !live.is_empty() {
                    let i = rng.usize_in(0, live.len());
                    pool.retain(live[i].0);
                    live[i].1 += 1;
                },
                _ => if !live.is_empty() {
                    let i = rng.usize_in(0, live.len());
                    pool.release(live[i].0);
                    live[i].1 -= 1;
                    if live[i].1 == 0 {
                        live.swap_remove(i);
                    }
                },
            }
            assert_eq!(pool.used_blocks(), live.len());
            assert_eq!(pool.used_blocks() + pool.free_blocks(),
                       pool.total_blocks());
            for &(id, rc) in &live {
                assert_eq!(pool.ref_count(id), rc);
            }
        }
        for (id, rc) in live.drain(..) {
            for _ in 0..rc {
                pool.release(id);
            }
        }
        assert_eq!(pool.used_blocks(), 0);
        assert_eq!(pool.free_blocks(), pool.total_blocks());
        assert_eq!(pool.resident_bytes(), 0);
    });
}

#[test]
fn block_tables_waste_at_most_one_partial_block() {
    // Unshared tables use exactly ceil(len / block_size) blocks each —
    // internal fragmentation is bounded by one block per live
    // sequence, and freeing a table returns all of its blocks.
    run_cases(25, |rng| {
        let bs = rng.usize_in(1, 5);
        let dims = BlockDims { n_layers: 1, n_heads: 2,
                               block_size: bs, head_dim: 2 };
        let mut pool = BlockPool::new(dims, 64);
        let rf = dims.row_floats();
        let mut tables: Vec<BlockTable> = Vec::new();
        for _ in 0..rng.usize_in(10, 50) {
            match rng.usize_in(0, 3) {
                0 => tables.push(BlockTable::new()),
                1 => if !tables.is_empty() {
                    let i = rng.usize_in(0, tables.len());
                    let r = rng.f32_vec(rf);
                    tables[i].append_row(&mut pool, &r, &r).unwrap();
                },
                _ => if !tables.is_empty() {
                    let i = rng.usize_in(0, tables.len());
                    let mut t = tables.swap_remove(i);
                    t.free(&mut pool);
                },
            }
            let want: usize = tables.iter()
                .map(|t| t.len().div_ceil(bs)).sum();
            assert_eq!(pool.used_blocks(), want);
            for t in &tables {
                assert!(t.n_blocks() * bs < t.len() + bs,
                        "more than one partial block of waste");
            }
        }
        for t in &mut tables {
            t.free(&mut pool);
        }
        assert_eq!(pool.used_blocks(), 0);
    });
}

#[test]
fn shared_prefix_gather_is_bit_identical_to_private_copy() {
    // A table admitted over an index-shared prefix must decode exactly
    // like a table that wrote the same rows privately — bit-for-bit —
    // and divergent appends by the prefix owner must not leak across.
    run_cases(20, |rng| {
        let dims = BlockDims { n_layers: 2, n_heads: 2,
                               block_size: 2, head_dim: 3 };
        let bs = dims.block_size;
        let mut pool = BlockPool::new(dims, 64);
        let rf = dims.row_floats();

        let n_shared = rng.usize_in(1, 4) * bs;
        let shared: Vec<(Vec<f32>, Vec<f32>)> = (0..n_shared)
            .map(|_| (rng.f32_vec(rf), rng.f32_vec(rf))).collect();

        // the owner prefills the prompt and registers it
        let mut owner = BlockTable::new();
        for (k, v) in &shared {
            owner.append_row(&mut pool, k, v).unwrap();
        }
        let mut ix = PrefixIndex::new();
        let toks: Vec<i32> = (0..n_shared as i32).collect();
        let sig = rng.next_u64();
        assert!(ix.register(&mut pool, sig, 1.0, &toks,
                            owner.blocks()));

        // a later admission reuses the prefix; a reference sequence
        // writes the identical rows without sharing
        let (blocks, len) = ix.lookup(sig, 1.0, &toks, bs).unwrap();
        assert_eq!(len, n_shared);
        let mut reuser =
            BlockTable::with_shared_prefix(&mut pool, &blocks);
        let mut reference = BlockTable::new();
        for (k, v) in &shared {
            reference.append_row(&mut pool, k, v).unwrap();
        }

        // both decode on; the owner diverges with different rows
        for _ in 0..rng.usize_in(0, 5) {
            let (k, v) = (rng.f32_vec(rf), rng.f32_vec(rf));
            reuser.append_row(&mut pool, &k, &v).unwrap();
            reference.append_row(&mut pool, &k, &v).unwrap();
            let (ko, vo) = (rng.f32_vec(rf), rng.f32_vec(rf));
            owner.append_row(&mut pool, &ko, &vo).unwrap();
        }
        assert_eq!(reuser.len(), reference.len());

        let (batch, max_seq) = (2usize, 16usize);
        let total = dims.n_layers * batch * dims.n_heads * max_seq
            * dims.head_dim;
        let mut k_a = vec![0f32; total];
        let mut v_a = vec![0f32; total];
        let mut k_b = vec![0f32; total];
        let mut v_b = vec![0f32; total];
        reuser.gather_into(&pool, 0, batch, max_seq, &mut k_a,
                           &mut v_a);
        reference.gather_into(&pool, 0, batch, max_seq, &mut k_b,
                              &mut v_b);
        assert!(k_a.iter().zip(k_b.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits()),
                "shared-prefix K diverged from private copy");
        assert!(v_a.iter().zip(v_b.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits()),
                "shared-prefix V diverged from private copy");

        owner.free(&mut pool);
        reuser.free(&mut pool);
        reference.free(&mut pool);
        ix.clear(&mut pool);
        assert_eq!(pool.used_blocks(), 0, "leak after full teardown");
    });
}
