//! Integration: rust PJRT runtime over the real AOT artifacts.
//!
//! Skipped (cleanly) when `artifacts/` hasn't been built — run
//! `make artifacts` first. These tests pin the python↔rust executable
//! ABI: positional argument order, output tuple layout, and numerical
//! agreement between independent execution paths.

use std::path::Path;

use bitdelta::config::Manifest;
use bitdelta::delta::bitdelta::materialize;
use bitdelta::delta::codec::{CodecRegistry, Payload};
use bitdelta::model::tokenizer::ByteTokenizer;
use bitdelta::runtime::client::{literal_f32, Runtime};
use bitdelta::runtime::variants::{BaseLinears, DecodeOut, DenseArgs};
use bitdelta::store::delta_file::{load_model, DeltaFile};

fn artifacts() -> Option<Manifest> {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Manifest::load("artifacts").unwrap())
}

#[test]
fn logits_fwd_runs_and_is_causal() {
    let Some(m) = artifacts() else { return };
    let cfg = m.config("sim-s").unwrap().clone();
    let exec = m.find_exec("sim-s", "logits_fwd", 8).unwrap();
    let mut rt = Runtime::cpu().unwrap();
    let exe = rt.load(m.path(&exec.path)).unwrap();
    let model = load_model(
        m.path(&m.models["sim-s-base"].file), &cfg).unwrap();
    let args = DenseArgs::from_model(&rt, &cfg, &model).unwrap();

    let tok = ByteTokenizer::new();
    let prompt = tok.encode("the sky is");
    let run = |toks: &[i32]| -> Vec<f32> {
        let mut rows = vec![0i32; exec.batch * exec.seq];
        rows[..toks.len()].copy_from_slice(toks);
        let tbuf = rt.upload_i32(&rows, &[exec.batch, exec.seq]).unwrap();
        let mut a: Vec<&xla::PjRtBuffer> = args.refs();
        a.push(&tbuf);
        let lits = exe.run_buffers(&a).unwrap();
        literal_f32(&lits[0]).unwrap()
    };

    let l1 = run(&prompt);
    assert_eq!(l1.len(), exec.batch * exec.seq * cfg.vocab_size);
    assert!(l1.iter().all(|v| v.is_finite()));

    // causality: changing the LAST token must not change logits at
    // earlier positions (row 0)
    let mut p2 = prompt.clone();
    let last = p2.len() - 1;
    p2[last] = (p2[last] + 1) % 256;
    let l2 = run(&p2);
    let v = cfg.vocab_size;
    for pos in 0..last {
        for j in 0..v {
            let a = l1[pos * v + j];
            let b = l2[pos * v + j];
            assert!((a - b).abs() < 1e-4,
                    "pos {pos} logit {j}: {a} vs {b}");
        }
    }
}

#[test]
fn decode_bitdelta_matches_materialized_dense() {
    // The serving path (shared base + packed delta through the Pallas
    // kernel) must equal the dequantized dense forward — the invariant
    // that lets the eval harness use the dense path for quality tables.
    let Some(m) = artifacts() else { return };
    let cfg = m.config("sim-s").unwrap().clone();
    let mut rt = Runtime::cpu().unwrap();

    let base = load_model(
        m.path(&m.models["sim-s-base"].file), &cfg).unwrap();
    let t = &m.tenants["sim-s-chat"];
    let delta = DeltaFile::load(m.path(&t.delta), &cfg).unwrap();
    let dense = materialize(&cfg, &base, &delta).unwrap();

    let b = 1usize;
    let bd_exec = m.find_exec("sim-s", "decode_bitdelta", b).unwrap();
    let dn_exec = m.find_exec("sim-s", "decode_dense", b).unwrap();
    let bd = rt.load(m.path(&bd_exec.path)).unwrap();
    let dn = rt.load(m.path(&dn_exec.path)).unwrap();

    let base_lin = BaseLinears::from_model(&rt, &cfg, &base).unwrap();
    let codec = CodecRegistry::builtin().get("bitdelta").unwrap();
    let stacked = codec
        .assemble(&rt, &cfg, &[&delta as &dyn Payload], b).unwrap();
    let dense_args = DenseArgs::from_model(&rt, &cfg, &dense).unwrap();

    let kv_shape = [cfg.n_layers, b, cfg.n_heads, cfg.max_seq_len,
                    cfg.head_dim()];
    let kv_len: usize = kv_shape.iter().product();
    let zeros = vec![0f32; kv_len];
    let tok = ByteTokenizer::new();
    let seq = tok.encode("Q: hi\nA:");

    let mut kv1 = (zeros.clone(), zeros.clone());
    let mut kv2 = (zeros.clone(), zeros.clone());
    for (t_i, &token) in seq.iter().enumerate() {
        let pos = rt.upload_i32(&[t_i as i32], &[b]).unwrap();
        let tk = rt.upload_i32(&[token], &[b]).unwrap();
        let rope = rt.upload_f32(&[1.0], &[b]).unwrap();

        let k1 = rt.upload_f32(&kv1.0, &kv_shape).unwrap();
        let v1 = rt.upload_f32(&kv1.1, &kv_shape).unwrap();
        let mut a1: Vec<&xla::PjRtBuffer> =
            base_lin.buffers.iter().collect();
        a1.extend(stacked.buffers.iter());
        a1.extend([&k1, &v1, &pos, &tk, &rope]);
        let o1 = DecodeOut::from_literals(
            bd.run_buffers(&a1).unwrap(), b).unwrap();
        kv1 = (o1.k.clone(), o1.v.clone());

        let k2 = rt.upload_f32(&kv2.0, &kv_shape).unwrap();
        let v2 = rt.upload_f32(&kv2.1, &kv_shape).unwrap();
        let mut a2: Vec<&xla::PjRtBuffer> = dense_args.refs();
        a2.extend([&k2, &v2, &pos, &tk, &rope]);
        let o2 = DecodeOut::from_literals(
            dn.run_buffers(&a2).unwrap(), b).unwrap();
        kv2 = (o2.k.clone(), o2.v.clone());

        for (x, y) in o1.logits.iter().zip(&o2.logits) {
            assert!((x - y).abs() < 2e-2,
                    "step {t_i}: bitdelta {x} vs dense {y}");
        }
    }
}

#[test]
fn logits_bitdelta_executable_cross_check() {
    // The full-sequence Pallas serving path == dense materialized path
    // through the OTHER executable pair (logits_bitdelta vs logits_fwd).
    let Some(m) = artifacts() else { return };
    let cfg = m.config("sim-s").unwrap().clone();
    let mut rt = Runtime::cpu().unwrap();

    let base = load_model(
        m.path(&m.models["sim-s-base"].file), &cfg).unwrap();
    let t = &m.tenants["sim-s-chat"];
    let delta = DeltaFile::load(m.path(&t.delta), &cfg).unwrap();
    let dense = materialize(&cfg, &base, &delta).unwrap();

    let bd_exec = m.find_exec("sim-s", "logits_bitdelta", 1).unwrap();
    let fwd_exec = m.find_exec("sim-s", "logits_fwd", 1).unwrap();
    let bd = rt.load(m.path(&bd_exec.path)).unwrap();
    let fwd = rt.load(m.path(&fwd_exec.path)).unwrap();

    let tok = ByteTokenizer::new();
    let mut toks = vec![0i32; bd_exec.seq];
    let prompt = tok.encode("Q: what color is the sky ?\nA: the sky is");
    toks[..prompt.len()].copy_from_slice(&prompt);
    let tbuf = rt.upload_i32(&toks, &[1, bd_exec.seq]).unwrap();

    let base_lin = BaseLinears::from_model(&rt, &cfg, &base).unwrap();
    let codec = CodecRegistry::builtin().get("bitdelta").unwrap();
    let stacked = codec
        .assemble(&rt, &cfg, &[&delta as &dyn Payload], 1).unwrap();
    let mut a1: Vec<&xla::PjRtBuffer> = base_lin.buffers.iter().collect();
    a1.extend(stacked.buffers.iter());
    a1.push(&tbuf);
    let z1 = literal_f32(&bd.run_buffers(&a1).unwrap()[0]).unwrap();

    let dense_args = DenseArgs::from_model(&rt, &cfg, &dense).unwrap();
    let mut a2: Vec<&xla::PjRtBuffer> = dense_args.refs();
    a2.push(&tbuf);
    let z2 = literal_f32(&fwd.run_buffers(&a2).unwrap()[0]).unwrap();

    assert_eq!(z1.len(), z2.len());
    let valid = prompt.len() * cfg.vocab_size;
    for i in 0..valid {
        assert!((z1[i] - z2[i]).abs() < 2e-2,
                "logit {i}: {} vs {}", z1[i], z2[i]);
    }
}
