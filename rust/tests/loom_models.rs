//! Exhaustive-interleaving models of the crate's concurrency
//! protocols, checked with [loom].
//!
//! These tests are compiled **only** under `--cfg loom` and driven by
//! the `loom` CI job:
//!
//! ```text
//! RUSTFLAGS="--cfg loom --check-cfg=cfg(loom)" \
//!     cargo test --release --test loom_models
//! ```
//!
//! Each `loom::model` body is re-executed under every feasible thread
//! interleaving (and, for the lock-free parts, every allowed weak-
//! memory outcome), so an invariant asserted here is *proved* over the
//! model, not sampled. The price is state-space growth: models stay at
//! 2–3 threads and a handful of lock acquisitions each — enough to
//! cover every ordering that matters, small enough to stay exhaustive.
//!
//! What is modeled and why:
//!
//! * **route-ordered-before-drain** — the cluster frontend's zero-error
//!   drain guarantee: `submit` routes under the routing lock, retire
//!   flips the worker to Draining under the same lock *before* sending
//!   Shutdown, so no request can trail the Shutdown marker.
//! * **concurrent retires respect the floor** — `retire_worker_floor`'s
//!   check-then-retire is atomic under the routing lock; two racing
//!   retires can never take the active count below the floor.
//! * **admission gate** — the real [`AdmissionGate`]: concurrent
//!   submitters can never collectively overshoot the budget, and a
//!   permit release is atomic with the counts (the PR-5 regression:
//!   release racing `try_admit` must never double-free or strand a
//!   slot).
//! * **block-pool conservation** — the real [`BlockPool`] behind a
//!   `crate::sync` lock: alloc/retain/release churn from two threads
//!   conserves `used + free == total` and drains back to zero.
//! * **worker pool** — the real `gemm::dispatch` queue/condvar
//!   protocol via `scope_on`/`worker_loop`: every spawned task runs
//!   exactly once before the scope returns, and shutdown never drops
//!   queued work.
//!
//! [loom]: https://docs.rs/loom
#![cfg(loom)]

use bitdelta::coordinator::admission::{AdmissionGate, AdmissionPolicy};
use bitdelta::gemm::dispatch::{scope_on, worker_loop, PoolInner};
use bitdelta::kvcache::{BlockDims, BlockPool};
use bitdelta::sync::atomic::{AtomicUsize, Ordering};
use bitdelta::sync::{lock, Arc, Mutex};
use loom::thread;

// ---------------------------------------------------------------------
// Cluster frontend: drain protocol
// ---------------------------------------------------------------------

/// The slice of frontend state the drain protocol depends on: one
/// worker's routability flag, guarded by the routing lock, plus the
/// worker's inbox (a `Mutex<Vec>` stands in for the mpsc channel,
/// which loom does not model).
#[derive(PartialEq, Eq, Clone, Copy, Debug)]
enum Msg {
    Req,
    Shutdown,
}

struct DrainModel {
    /// true = Routable, false = Draining. In the real frontend this is
    /// `WorkerSlot::state`, only ever read or written under
    /// `shared.state`'s lock.
    routable: Mutex<bool>,
    inbox: Mutex<Vec<Msg>>,
}

/// `ClusterHandle::submit`: route-and-send as one critical section.
fn model_submit(m: &DrainModel) -> bool {
    let routable = lock(&m.routable);
    if !*routable {
        return false;
    }
    // send happens while the routing decision is still valid — this
    // ordering (send under the routing lock) is the whole guarantee
    lock(&m.inbox).push(Msg::Req);
    true
}

/// `retire_worker_floor`: flip to Draining under the routing lock,
/// then send Shutdown (after release — the real code does too).
fn model_retire(m: &DrainModel) {
    {
        let mut routable = lock(&m.routable);
        *routable = false;
    }
    lock(&m.inbox).push(Msg::Shutdown);
}

#[test]
fn no_request_trails_shutdown() {
    loom::model(|| {
        let m = Arc::new(DrainModel {
            routable: Mutex::new(true),
            inbox: Mutex::new(Vec::new()),
        });
        let m1 = m.clone();
        let m2 = m.clone();
        let submitter = thread::spawn(move || {
            model_submit(&m1);
            model_submit(&m1)
        });
        let retirer = thread::spawn(move || model_retire(&m2));
        submitter.join().unwrap();
        retirer.join().unwrap();

        let inbox = lock(&m.inbox);
        let shutdown_at = inbox.iter().position(|&x| x == Msg::Shutdown)
            .expect("retire always sends Shutdown");
        for (i, &msg) in inbox.iter().enumerate() {
            if msg == Msg::Req {
                assert!(i < shutdown_at,
                        "request routed after Shutdown: {inbox:?}");
            }
        }
    });
}

// ---------------------------------------------------------------------
// Cluster frontend: retire floor
// ---------------------------------------------------------------------

/// One `retire_worker_floor` attempt over a shared alive-set: the
/// floor check and the retirement are one critical section.
fn retire_with_floor(alive: &Mutex<Vec<bool>>, floor: usize) -> bool {
    let mut a = lock(alive);
    let n_alive = a.iter().filter(|&&x| x).count();
    if n_alive <= floor {
        return false;
    }
    if let Some(slot) = a.iter_mut().find(|x| **x) {
        *slot = false;
        return true;
    }
    false
}

#[test]
fn concurrent_retires_respect_floor() {
    const FLOOR: usize = 1;
    loom::model(|| {
        let alive = Arc::new(Mutex::new(vec![true, true]));
        let a1 = alive.clone();
        let a2 = alive.clone();
        let t1 = thread::spawn(move || retire_with_floor(&a1, FLOOR));
        let t2 = thread::spawn(move || retire_with_floor(&a2, FLOOR));
        let r1 = t1.join().unwrap();
        let r2 = t2.join().unwrap();

        let n_alive = lock(&alive).iter().filter(|&&x| x).count();
        assert!(n_alive >= FLOOR,
                "retires breached the floor: {n_alive} < {FLOOR}");
        // exactly one of the two racing retires can win at floor 1
        assert!(r1 ^ r2, "both retires claimed the single headroom slot");
    });
}

// ---------------------------------------------------------------------
// Admission gate (real type)
// ---------------------------------------------------------------------

#[test]
fn gate_never_overshoots_budget() {
    loom::model(|| {
        let gate = Arc::new(AdmissionGate::new(AdmissionPolicy {
            per_tenant_cap: 2,
            total_cap: 2,
        }));
        // park permits so nothing is released mid-model
        let held = Arc::new(Mutex::new(Vec::new()));
        let mut joins = Vec::new();
        for _ in 0..2 {
            let gate = gate.clone();
            let held = held.clone();
            joins.push(thread::spawn(move || {
                for _ in 0..2 {
                    if let Ok(p) = gate.try_admit("t") {
                        lock(&held).push(p);
                    }
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        // 4 attempts, no releases, budget 2: exactly 2 succeed under
        // every interleaving
        assert_eq!(lock(&held).len(), 2);
        assert_eq!(gate.in_flight(), 2);
        lock(&held).clear();
        assert_eq!(gate.in_flight(), 0, "permit drop leaked a slot");
    });
}

/// The PR-5 interleaving: one thread releases the only permit while
/// another tries to admit. Whatever the ordering, the gate's counts
/// must equal the number of live permits — a release is never lost
/// and never double-counted.
#[test]
fn permit_release_races_try_admit() {
    loom::model(|| {
        let gate = Arc::new(AdmissionGate::new(AdmissionPolicy {
            per_tenant_cap: 1,
            total_cap: 1,
        }));
        let first = gate.try_admit("t").expect("empty gate admits");
        let gate2 = gate.clone();
        let releaser = thread::spawn(move || drop(first));
        let admitter = thread::spawn({
            let gate = gate.clone();
            move || gate.try_admit("t").ok()
        });
        releaser.join().unwrap();
        let won = admitter.join().unwrap();

        match won {
            // admitted after (or interleaved with) the release: the
            // slot must be accounted to the new permit alone
            Some(p) => {
                assert_eq!(gate2.in_flight(), 1);
                drop(p);
                assert_eq!(gate2.in_flight(), 0);
            }
            // lost the race: the release must still have landed
            None => {
                assert_eq!(gate2.in_flight(), 0);
                assert!(gate2.try_admit("t").is_ok(),
                        "released slot is stranded");
            }
        }
    });
}

// ---------------------------------------------------------------------
// Block pool conservation (real type, externally locked)
// ---------------------------------------------------------------------

fn tiny_pool(n_blocks: usize) -> BlockPool {
    BlockPool::new(
        BlockDims { n_layers: 1, n_heads: 1, block_size: 1, head_dim: 1 },
        n_blocks,
    )
}

#[test]
fn block_pool_conserves_blocks_under_churn() {
    loom::model(|| {
        let pool = Arc::new(Mutex::new(tiny_pool(3)));
        let mut joins = Vec::new();
        for _ in 0..2 {
            let pool = pool.clone();
            joins.push(thread::spawn(move || {
                // alloc → share → unshare → free, checking the
                // conservation law inside every critical section
                let id = {
                    let mut p = lock(&pool);
                    let id = p.alloc().expect("3 blocks, 2 threads");
                    assert_eq!(p.used_blocks() + p.free_blocks(),
                               p.total_blocks());
                    id
                };
                {
                    let mut p = lock(&pool);
                    p.retain(id);
                    assert_eq!(p.ref_count(id), 2);
                    p.release(id);
                    assert_eq!(p.ref_count(id), 1);
                }
                let mut p = lock(&pool);
                p.release(id);
                assert_eq!(p.used_blocks() + p.free_blocks(),
                           p.total_blocks());
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let p = lock(&pool);
        assert_eq!(p.used_blocks(), 0, "churn leaked a block");
        assert_eq!(p.free_blocks(), p.total_blocks());
    });
}

// ---------------------------------------------------------------------
// GEMV worker pool (real protocol objects)
// ---------------------------------------------------------------------

#[test]
fn scope_tasks_complete_before_scope_returns() {
    loom::model(|| {
        let inner = Arc::new(PoolInner::new());
        let worker = {
            let inner = inner.clone();
            thread::spawn(move || worker_loop(inner))
        };

        let done = Arc::new(AtomicUsize::new(0));
        scope_on(Some(inner.clone()), |s| {
            for _ in 0..2 {
                let done = done.clone();
                s.spawn(move || {
                    done.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        // the scope's contract: after it returns, every spawned task
        // has run — whether the worker took it or the caller helped
        assert_eq!(done.load(Ordering::SeqCst), 2,
                   "scope returned with tasks unfinished");

        inner.shut_down();
        worker.join().unwrap();
    });
}

#[test]
fn pool_shutdown_drains_queued_work() {
    loom::model(|| {
        let inner = Arc::new(PoolInner::new());
        let ran = Arc::new(AtomicUsize::new(0));

        // enqueue first, then raise shutdown, then start the worker:
        // the worker must still drain the queue before exiting
        {
            let inner = inner.clone();
            let ran = ran.clone();
            scope_on(Some(inner.clone()), move |s| {
                let r = ran.clone();
                s.spawn(move || {
                    r.fetch_add(1, Ordering::SeqCst);
                });
                // the scope itself may drain the task; either way the
                // count lands at 1 by the time the scope returns
            });
        }
        assert_eq!(ran.load(Ordering::SeqCst), 1);

        inner.shut_down();
        let worker = {
            let inner = inner.clone();
            thread::spawn(move || worker_loop(inner))
        };
        // a worker started after shutdown exits promptly (empty queue
        // + flag) instead of waiting forever on the condvar
        worker.join().unwrap();
    });
}
