//! Simulation-harness tiers over the real cluster stack.
//!
//! Smoke tier (default `cargo test`): 10^4 tenants, the canonical
//! schedule with every fault kind — kill mid-drain, kill during
//! re-placement, autoscale oscillation under square-wave load, an
//! admission storm, delta hot-churn — with the invariant monitor
//! running continuously. Soak tier (`-- --ignored`, nightly CI):
//! 10^5–10^6 tenants with a rotating seed and a seed-derived random
//! schedule; on failure it writes `sim_soak_failure.log` (seed +
//! schedule + violations) for CI to upload.
//!
//! Everything runs on the `bitdelta::sync::clock` virtual clock — no
//! raw sleeps and no wall-clock `Instant` in this file (lint-enforced
//! by the `raw-time` rule of `cargo xtask lint`).

use bitdelta::coordinator::workload::{self, TraceConfig};
use bitdelta::simharness::{
    generate_population, run, smoke_schedule, FaultEvent,
    FaultSchedule, PopulationConfig, SimConfig,
};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// The CI smoke run: a real elastic cluster with admission and an
/// autoscaler, 10^4 Zipf tenants, every fault kind scripted, all
/// invariants green. `SIM_SEED` rotates the seed from CI.
#[test]
fn sim_smoke_full_schedule_keeps_every_invariant() {
    let cfg = SimConfig::smoke(env_u64("SIM_SEED", 11));
    let report = run(&cfg, &smoke_schedule()).unwrap();
    assert!(report.ok(), "{}", report.render_failure());
    // the run must have actually exercised the machinery it claims to
    assert!(report.submitted > 500,
            "too little load ran: {}", report.render_failure());
    assert!(report.served > 0, "{}", report.render_failure());
    assert!(report.rejected > 0,
            "the admission storm should shed load: {}",
            report.render_failure());
    assert!(report.failovers >= 1,
            "scripted kills should surface as failovers: {}",
            report.render_failure());
    assert!(report.scale_ups >= 2,
            "two spawns are scripted: {}", report.render_failure());
    assert_eq!(report.route_errors + report.submit_errors, 0,
               "a survivor was always routable: {}",
               report.render_failure());
}

/// Injected-violation regression: a harness configured to leak every
/// ticket (permits never released, responses never harvested) must be
/// caught by the monitor — with the seed and printable schedule in the
/// failure rendering, so the report is replayable as-is.
#[test]
fn leaked_permits_and_hung_tickets_are_caught_and_replayable() {
    let cfg = SimConfig {
        seed: 1234,
        n_tenants: 200,
        requests: 120,
        sim_ms: 150,
        leak_tickets: true,
        ..SimConfig::default()
    };
    let schedule = FaultSchedule::new()
        .at_ms(40, FaultEvent::AdmissionStorm {
            tenant_rank: 0,
            burst: 32,
        });
    let report = run(&cfg, &schedule).unwrap();
    assert!(!report.ok(), "the leak must be detected");
    let names: Vec<&str> =
        report.violations.iter().map(|v| v.invariant).collect();
    assert!(names.contains(&"hung-tickets"), "{names:?}");
    assert!(names.contains(&"admission-in-flight"), "{names:?}");
    assert_eq!(report.seed, 1234);
    let failure = report.render_failure();
    assert!(failure.contains("SIM_SEED=1234"), "{failure}");
    assert!(failure.contains("admission-storm tenant=0 burst=32"),
            "{failure}");
}

/// Churn regression (the place/route race): a tenant whose only
/// replica dies keeps getting *typed* `RouteError`s — never a hang —
/// and every admission permit comes back. The cluster has one worker
/// and no autoscaler, so the kill leaves zero survivors.
#[test]
fn killed_last_replica_fails_typed_and_releases_every_permit() {
    let cfg = SimConfig {
        seed: 77,
        n_tenants: 300,
        initial_workers: 1,
        requests: 150,
        sim_ms: 200,
        ..SimConfig::default()
    };
    let schedule = FaultSchedule::new()
        .at_ms(60, FaultEvent::KillWorker { slot: 0 });
    let report = run(&cfg, &schedule).unwrap();
    // no hung tickets, no leaked permits, bookkeeping closed — the
    // invariants hold even with the whole fleet dead
    assert!(report.ok(), "{}", report.render_failure());
    assert!(report.route_errors > 0,
            "submits after the kill must fail with RouteError: {}",
            report.render_failure());
    assert_eq!(report.submit_errors, 0,
               "no untyped submit failures allowed: {}",
               report.render_failure());
    assert_eq!(report.served + report.errored, report.submitted,
               "{}", report.render_failure());
}

/// Seed replay is exact: population, trace and random schedules are
/// bit-identical across generations — the property that makes a
/// failing seed from CI reproducible anywhere.
#[test]
fn seed_replays_population_trace_and_schedule_bit_identically() {
    let pcfg = PopulationConfig {
        n_tenants: 10_000,
        ..PopulationConfig::default()
    };
    let a = generate_population(42, &pcfg);
    let b = generate_population(42, &pcfg);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.codec, y.codec);
        assert_eq!(x.resident_bytes, y.resident_bytes);
        assert_eq!(x.levels, y.levels);
        assert_eq!(x.weight.to_bits(), y.weight.to_bits());
    }

    let tc = TraceConfig {
        n_tenants: 10_000,
        n_requests: 500,
        seed: 42,
        ..TraceConfig::default()
    };
    let t1 = workload::generate(&tc);
    let t2 = workload::generate(&tc);
    assert_eq!(t1.len(), t2.len());
    for (x, y) in t1.iter().zip(&t2) {
        assert_eq!(x.at.to_bits(), y.at.to_bits());
        assert_eq!(x.tenant, y.tenant);
        assert_eq!(x.max_new_tokens, y.max_new_tokens);
    }

    assert_eq!(FaultSchedule::random(42, 2000, 4),
               FaultSchedule::random(42, 2000, 4));
}

/// Nightly soak: 10^5 (default) to 10^6 tenants, seed-derived random
/// schedule covering every fault kind. On violation, writes the
/// replayable failure block to `sim_soak_failure.log` (uploaded by
/// the `sim-soak` CI job) and panics with it.
#[test]
#[ignore = "soak tier — run nightly via `cargo test -- --ignored` \
with SIM_SEED / SIM_TENANTS"]
fn sim_soak_random_schedule_at_scale() {
    let seed = env_u64("SIM_SEED", 1);
    let cfg = SimConfig {
        n_tenants: env_u64("SIM_TENANTS", 100_000) as usize,
        requests: 4_000,
        sim_ms: 2_000,
        ..SimConfig::smoke(seed)
    };
    let schedule = FaultSchedule::random(
        seed, cfg.sim_ms, cfg.initial_workers + 1);
    let report = run(&cfg, &schedule).unwrap();
    if !report.ok() {
        let failure = report.render_failure();
        let _ = std::fs::write("sim_soak_failure.log", &failure);
        panic!("{failure}");
    }
}
