//! Integration: the multi-tenant serving engine over real artifacts.
//! Pins tenant isolation, compression cross-checks, and the concurrent
//! front-end. Skipped cleanly when artifacts are missing.

use std::path::Path;

use bitdelta::config::{Manifest, ModelConfig};
use bitdelta::delta::bitdelta::compress;
use bitdelta::model::sampling::SamplingParams;
use bitdelta::serving::engine::{Engine, EngineConfig, ExecMode};
use bitdelta::serving::request::Request;
use bitdelta::serving::service::ServingService;
use bitdelta::store::delta_file::{load_model, DeltaFile};

fn have_artifacts() -> bool {
    let ok = Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts not built");
    }
    ok
}

fn req(tenant: &str, prompt: &str, n: usize) -> Request {
    Request { tenant: tenant.into(), prompt: prompt.into(),
              max_new_tokens: n, sampling: SamplingParams::greedy() }
}

#[test]
fn engine_serves_and_isolates_tenants() {
    if !have_artifacts() {
        return;
    }
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = 2;
    let mut engine = Engine::from_artifacts(ec).unwrap();

    // same prompt to two different tenants in ONE batch: outputs must
    // reflect each tenant's own delta (greedy => deterministic)
    let prompt = "Q: what color is the sky ?\nA:";
    let c1 = engine.submit(req("sim-s-chat", prompt, 16)).unwrap();
    let c2 = engine.submit(req("sim-s-math", prompt, 16)).unwrap();
    engine.run_until_idle(100_000).unwrap();
    let r1 = c1.recv().unwrap();
    let r2 = c2.recv().unwrap();
    assert!(!r1.tokens.is_empty() && !r2.tokens.is_empty());
    assert_ne!(r1.tokens, r2.tokens,
               "different tenants produced identical output: {:?}",
               r1.text);
    // the chat tenant actually answers the question
    assert!(r1.text.contains("blue") || r1.text.contains("sky"),
            "chat tenant said {:?}", r1.text);
}

#[test]
fn greedy_generation_is_deterministic_across_batches() {
    if !have_artifacts() {
        return;
    }
    let run = |batch: usize| -> Vec<i32> {
        let mut ec = EngineConfig::new("artifacts");
        ec.batch = batch;
        let mut engine = Engine::from_artifacts(ec).unwrap();
        let c = engine.submit(
            req("sim-s-chat", "Q: where does ada live ?\nA:", 12))
            .unwrap();
        engine.run_until_idle(100_000).unwrap();
        c.recv().unwrap().tokens
    };
    // same request alone at batch width 1 and width 2 (padded slots)
    assert_eq!(run(1), run(2));
}

#[test]
fn rust_compressor_matches_python_artifact() {
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load("artifacts").unwrap();
    let cfg: ModelConfig = m.config("sim-s").unwrap().clone();
    let base = load_model(m.path(&m.models["sim-s-base"].file),
                          &cfg).unwrap();
    let fine = load_model(m.path(&m.models["sim-s-chat"].file),
                          &cfg).unwrap();
    let ours = compress(&cfg, &base, &fine).unwrap();
    let t = &m.tenants["sim-s-chat"];
    let py = DeltaFile::load(m.path(&t.delta_initial), &cfg).unwrap();
    for name in cfg.linear_names() {
        assert_eq!(py.levels[0].bits[&name],
                   ours.delta.levels[0].bits[&name],
                   "sign masks differ on {name}");
    }
    for (a, b) in py.levels[0].scales.iter()
        .zip(&ours.delta.levels[0].scales) {
        assert!((a - b).abs() <= 1e-5 * a.abs().max(1e-3),
                "python {a} vs rust {b}");
    }
}

#[test]
fn service_handles_concurrent_clients() {
    if !have_artifacts() {
        return;
    }
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = 4;
    let service = ServingService::spawn(ec).unwrap();
    let mut clients = Vec::new();
    for i in 0..3 {
        let h = service.handle();
        clients.push(std::thread::spawn(move || {
            let tenant = ["sim-s-chat", "sim-s-math",
                          "sim-s-rlhf"][i % 3];
            h.generate(req(tenant, "Q: what does bob eat ?\nA:", 8))
        }));
    }
    for c in clients {
        let resp = c.join().unwrap().unwrap();
        assert!(!resp.tokens.is_empty());
    }
    let metrics = service.handle().metrics().unwrap();
    assert!(metrics.contains("bitdelta_completed_total 3"), "{metrics}");
    service.shutdown().unwrap();
}

#[test]
fn unknown_tenant_rejected_via_service() {
    if !have_artifacts() {
        return;
    }
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = 1;
    let service = ServingService::spawn(ec).unwrap();
    let err = service.handle()
        .generate(req("no-such-tenant", "Q:", 4));
    assert!(err.is_err());
    service.shutdown().unwrap();
}

#[test]
fn naive_and_lora_modes_serve() {
    if !have_artifacts() {
        return;
    }
    for mode in [ExecMode::Naive, ExecMode::Lora] {
        let mut ec = EngineConfig::new("artifacts");
        ec.mode = mode;
        ec.batch = 2;
        let mut engine = Engine::from_artifacts(ec).unwrap();
        let c = engine.submit(
            req("sim-s-chat", "Q: what color is the snow ?\nA:", 12))
            .unwrap();
        engine.run_until_idle(100_000).unwrap();
        let r = c.recv().unwrap();
        assert!(!r.tokens.is_empty(), "{mode:?} produced nothing");
    }
}

#[test]
fn mixed_codec_batch_serves_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load("artifacts").unwrap();
    if m.tenants["sim-s-chat"].svd_r16.is_none() {
        eprintln!("skipping: sim-s-chat has no svd factors");
        return;
    }
    // one decode batch, two tenants, two different codecs: chat rides
    // the low-rank codec, math stays on bitdelta
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = 2;
    ec.codec_overrides.insert("sim-s-chat".into(), "lora".into());
    let mut engine = Engine::from_artifacts(ec).unwrap();
    assert_eq!(engine.tenant_codec("sim-s-chat"), Some("lora"));
    assert_eq!(engine.tenant_codec("sim-s-math"), Some("bitdelta"));

    let prompt = "Q: what color is the sky ?\nA:";
    let c1 = engine.submit(req("sim-s-chat", prompt, 16)).unwrap();
    let c2 = engine.submit(req("sim-s-math", prompt, 16)).unwrap();
    engine.run_until_idle(100_000).unwrap();
    let r1 = c1.recv().unwrap();
    let r2 = c2.recv().unwrap();
    assert!(!r1.tokens.is_empty() && !r2.tokens.is_empty());
    assert_ne!(r1.tokens, r2.tokens,
               "mixed-codec tenants produced identical output");
    // the mixed composition must have gone through the dense fallback
    let metrics = engine.metrics.exposition();
    assert!(metrics.contains("bitdelta_mixed_batches_total"),
            "no mixed batch recorded:\n{metrics}");
}

#[test]
fn svd_codec_serves_via_registry_only() {
    // The acceptance demo for "adding a codec costs one module + one
    // registry line": the svd codec has no precomputed artifact at all —
    // it factorizes the fine-tune at load time — yet serves end-to-end
    // through the same engine path.
    if !have_artifacts() {
        return;
    }
    let mut ec = EngineConfig::new("artifacts");
    ec.codec = Some("svd".into());
    ec.batch = 2;
    let mut engine = match Engine::from_artifacts(ec) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let c = engine.submit(
        req("sim-s-chat", "Q: what color is the sky ?\nA:", 8)).unwrap();
    engine.run_until_idle(100_000).unwrap();
    let r = c.recv().unwrap();
    assert!(!r.tokens.is_empty(), "svd codec produced nothing");
}

#[test]
fn rope_extension_tenant_uses_scaled_positions() {
    if !have_artifacts() {
        return;
    }
    // chat and chat-ext share training data but differ in rope_scale;
    // greedy outputs on the same prompt should diverge
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = 2;
    let mut engine = Engine::from_artifacts(ec).unwrap();
    let prompt = "Q: where does kim live ?\nA:";
    let c1 = engine.submit(req("sim-s-chat", prompt, 16)).unwrap();
    let c2 = engine.submit(req("sim-s-chat-ext", prompt, 16)).unwrap();
    engine.run_until_idle(100_000).unwrap();
    let r1 = c1.recv().unwrap();
    let r2 = c2.recv().unwrap();
    assert_ne!(r1.tokens, r2.tokens);
}
