//! Integration: the multi-tenant serving engine over real artifacts.
//! Pins tenant isolation, compression cross-checks, and the concurrent
//! front-end. Skipped cleanly when artifacts are missing.

use std::path::Path;

use bitdelta::config::{Manifest, ModelConfig};
use bitdelta::delta::bitdelta::compress;
use bitdelta::model::sampling::SamplingParams;
use bitdelta::serving::engine::{Engine, EngineConfig, ExecMode};
use bitdelta::serving::request::{Request, RequestError};
use bitdelta::serving::service::ServingService;
use bitdelta::store::delta_file::{load_model, DeltaFile};

fn have_artifacts() -> bool {
    let ok = Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts not built");
    }
    ok
}

fn req(tenant: &str, prompt: &str, n: usize) -> Request {
    Request { tenant: tenant.into(), prompt: prompt.into(),
              max_new_tokens: n, sampling: SamplingParams::greedy() }
}

#[test]
fn engine_serves_and_isolates_tenants() {
    if !have_artifacts() {
        return;
    }
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = 2;
    let mut engine = Engine::from_artifacts(ec).unwrap();

    // same prompt to two different tenants in ONE batch: outputs must
    // reflect each tenant's own delta (greedy => deterministic)
    let prompt = "Q: what color is the sky ?\nA:";
    let c1 = engine.submit(req("sim-s-chat", prompt, 16)).unwrap();
    let c2 = engine.submit(req("sim-s-math", prompt, 16)).unwrap();
    engine.run_until_idle(100_000).unwrap();
    let r1 = c1.recv().unwrap().unwrap();
    let r2 = c2.recv().unwrap().unwrap();
    assert!(!r1.tokens.is_empty() && !r2.tokens.is_empty());
    assert_ne!(r1.tokens, r2.tokens,
               "different tenants produced identical output: {:?}",
               r1.text);
    // the chat tenant actually answers the question
    assert!(r1.text.contains("blue") || r1.text.contains("sky"),
            "chat tenant said {:?}", r1.text);
}

#[test]
fn greedy_generation_is_deterministic_across_batches() {
    if !have_artifacts() {
        return;
    }
    let run = |batch: usize| -> Vec<i32> {
        let mut ec = EngineConfig::new("artifacts");
        ec.batch = batch;
        let mut engine = Engine::from_artifacts(ec).unwrap();
        let c = engine.submit(
            req("sim-s-chat", "Q: where does ada live ?\nA:", 12))
            .unwrap();
        engine.run_until_idle(100_000).unwrap();
        c.recv().unwrap().unwrap().tokens
    };
    // same request alone at batch width 1 and width 2 (padded slots)
    assert_eq!(run(1), run(2));
}

#[test]
fn mixed_fidelity_batch_matches_each_tenant_served_alone() {
    // The fidelity-tier batching guarantee: tenants at levels {1, 2, 4}
    // sharing one decode batch (zero-scale padding to the batch-max
    // tier) produce per-tenant outputs identical to each tenant served
    // alone at its own tier.
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load("artifacts").unwrap();
    if m.find_exec("sim-s", "decode_bitdelta_l2", 4).is_none()
        || m.find_exec("sim-s", "decode_bitdelta_l4", 4).is_none() {
        eprintln!("skipping: no decode_bitdelta_l{{2,4}}_b4 executables \
(rebuild artifacts)");
        return;
    }
    let has_fid = |t: &str, k: usize| m.tenants.get(t)
        .map_or(false, |e| e.fidelity.contains_key(&k.to_string()));
    if !has_fid("sim-s-chat", 4) || !has_fid("sim-s-math", 2) {
        eprintln!("skipping: fidelity artifacts missing \
(rebuild artifacts)");
        return;
    }

    // a typo'd tenant in --tenant-levels is a construction error, not
    // a silently-ignored fidelity upgrade
    let mut bad = EngineConfig::new("artifacts");
    bad.tenant_levels.insert("sim-s-chta".into(), 4);
    let e = Engine::from_artifacts(bad).unwrap_err().to_string();
    assert!(e.contains("unknown tenant"), "{e}");

    let tiers = [("sim-s-chat", 4usize), ("sim-s-math", 2),
                 ("sim-s-rlhf", 1)];
    let prompt = "Q: what color is the sky ?\nA:";
    let config = || {
        let mut ec = EngineConfig::new("artifacts");
        ec.batch = 4;
        for (t, k) in tiers {
            ec.tenant_levels.insert(t.to_string(), k);
        }
        ec
    };

    // each tenant alone at its own tier
    let mut alone = Vec::new();
    for (t, k) in tiers {
        let mut engine = Engine::from_artifacts(config()).unwrap();
        assert_eq!(engine.tenant_fidelity(t), k);
        let c = engine.submit(req(t, prompt, 12)).unwrap();
        engine.run_until_idle(100_000).unwrap();
        alone.push(c.recv().unwrap().unwrap().tokens);
    }

    // all three tiers in ONE batch
    let mut engine = Engine::from_artifacts(config()).unwrap();
    let chans: Vec<_> = tiers.iter()
        .map(|(t, _)| engine.submit(req(t, prompt, 12)).unwrap())
        .collect();
    engine.run_until_idle(100_000).unwrap();
    for ((c, (t, k)), want) in chans.into_iter().zip(tiers).zip(&alone) {
        let got = c.recv().unwrap().unwrap().tokens;
        assert_eq!(&got, want,
                   "{t} at tier {k}: mixed-batch output diverged");
    }

    // higher tiers actually change the served model: chat at tier 4
    // vs tier 1 must decode differently on at least one prompt
    let mut ec1 = EngineConfig::new("artifacts");
    ec1.batch = 4;
    let mut engine = Engine::from_artifacts(ec1).unwrap();
    let c = engine.submit(req("sim-s-chat", prompt, 12)).unwrap();
    engine.run_until_idle(100_000).unwrap();
    let tier1 = c.recv().unwrap().unwrap().tokens;
    // (not asserted unequal — a saturated tier can legitimately agree —
    // but both paths must serve successfully)
    assert!(!tier1.is_empty() && !alone[0].is_empty());
}

#[test]
fn rust_compressor_matches_python_artifact() {
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load("artifacts").unwrap();
    let cfg: ModelConfig = m.config("sim-s").unwrap().clone();
    let base = load_model(m.path(&m.models["sim-s-base"].file),
                          &cfg).unwrap();
    let fine = load_model(m.path(&m.models["sim-s-chat"].file),
                          &cfg).unwrap();
    let ours = compress(&cfg, &base, &fine).unwrap();
    let t = &m.tenants["sim-s-chat"];
    let py = DeltaFile::load(m.path(&t.delta_initial), &cfg).unwrap();
    for name in cfg.linear_names() {
        assert_eq!(py.levels[0].bits[&name],
                   ours.delta.levels[0].bits[&name],
                   "sign masks differ on {name}");
    }
    for (a, b) in py.levels[0].scales.iter()
        .zip(&ours.delta.levels[0].scales) {
        assert!((a - b).abs() <= 1e-5 * a.abs().max(1e-3),
                "python {a} vs rust {b}");
    }
}

#[test]
fn service_handles_concurrent_clients() {
    if !have_artifacts() {
        return;
    }
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = 4;
    let service = ServingService::spawn(ec).unwrap();
    let mut clients = Vec::new();
    for i in 0..3 {
        let h = service.handle();
        clients.push(std::thread::spawn(move || {
            let tenant = ["sim-s-chat", "sim-s-math",
                          "sim-s-rlhf"][i % 3];
            h.generate(req(tenant, "Q: what does bob eat ?\nA:", 8))
        }));
    }
    for c in clients {
        let resp = c.join().unwrap().unwrap();
        assert!(!resp.tokens.is_empty());
    }
    let metrics = service.handle().metrics().unwrap();
    assert!(metrics.contains("bitdelta_completed_total 3"), "{metrics}");
    service.shutdown().unwrap();
}

#[test]
fn unknown_tenant_rejected_via_service() {
    if !have_artifacts() {
        return;
    }
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = 1;
    let service = ServingService::spawn(ec).unwrap();
    let err = service.handle()
        .generate(req("no-such-tenant", "Q:", 4));
    assert!(err.is_err());
    service.shutdown().unwrap();
}

#[test]
fn naive_and_lora_modes_serve() {
    if !have_artifacts() {
        return;
    }
    for mode in [ExecMode::Naive, ExecMode::Lora] {
        let mut ec = EngineConfig::new("artifacts");
        ec.mode = mode;
        ec.batch = 2;
        let mut engine = Engine::from_artifacts(ec).unwrap();
        let c = engine.submit(
            req("sim-s-chat", "Q: what color is the snow ?\nA:", 12))
            .unwrap();
        engine.run_until_idle(100_000).unwrap();
        let r = c.recv().unwrap().unwrap();
        assert!(!r.tokens.is_empty(), "{mode:?} produced nothing");
    }
}

#[test]
fn mixed_codec_batch_serves_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load("artifacts").unwrap();
    if m.tenants["sim-s-chat"].svd_r16.is_none() {
        eprintln!("skipping: sim-s-chat has no svd factors");
        return;
    }
    // one decode batch, two tenants, two different codecs: chat rides
    // the low-rank codec, math stays on bitdelta
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = 2;
    ec.codec_overrides.insert("sim-s-chat".into(), "lora".into());
    let mut engine = Engine::from_artifacts(ec).unwrap();
    assert_eq!(engine.tenant_codec("sim-s-chat"), Some("lora"));
    assert_eq!(engine.tenant_codec("sim-s-math"), Some("bitdelta"));

    let prompt = "Q: what color is the sky ?\nA:";
    let c1 = engine.submit(req("sim-s-chat", prompt, 16)).unwrap();
    let c2 = engine.submit(req("sim-s-math", prompt, 16)).unwrap();
    engine.run_until_idle(100_000).unwrap();
    let r1 = c1.recv().unwrap().unwrap();
    let r2 = c2.recv().unwrap().unwrap();
    assert!(!r1.tokens.is_empty() && !r2.tokens.is_empty());
    assert_ne!(r1.tokens, r2.tokens,
               "mixed-codec tenants produced identical output");
    // the mixed composition must run as native per-codec sub-batches —
    // never through the stacked-dense decode_naive materialization
    let metrics = engine.metrics.exposition();
    assert!(metrics.contains("bitdelta_mixed_batches_total"),
            "no mixed batch recorded:\n{metrics}");
    assert!(metrics.contains("bitdelta_mixed_native_subbatches_total"),
            "mixed batch did not run native sub-batches:\n{metrics}");
    assert!(!metrics.contains("bitdelta_decode_naive_total"),
            "mixed batch took the stacked-dense detour:\n{metrics}");
}

#[test]
fn mixed_format_batch_native_equals_dense_fallback() {
    // Four codecs in ONE decode batch — bitdelta at k=1 (chat-ext),
    // bitdelta at k=2 (math via --tenant-levels), lora (chat
    // override), svd (rlhf override) — served twice: natively (one
    // sub-batch per codec) and through the materialize-everything
    // `mixed_dense_fallback` escape hatch. Greedy outputs must match
    // per tenant, and only the fallback run may touch decode_naive.
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load("artifacts").unwrap();
    if m.tenants["sim-s-chat"].svd_r16.is_none() {
        eprintln!("skipping: sim-s-chat has no svd factors");
        return;
    }
    if m.find_exec("sim-s", "decode_bitdelta_l2", 4).is_none()
        || m.find_exec("sim-s", "decode_lora", 4).is_none()
        || m.find_exec("sim-s", "decode_naive", 4).is_none() {
        eprintln!("skipping: no b4 executables (rebuild artifacts)");
        return;
    }
    if !m.tenants.get("sim-s-math")
        .map_or(false, |e| e.fidelity.contains_key("2")) {
        eprintln!("skipping: fidelity artifacts missing \
(rebuild artifacts)");
        return;
    }

    let tenants = ["sim-s-chat-ext", "sim-s-math", "sim-s-chat",
                   "sim-s-rlhf"];
    let prompt = "Q: what color is the sky ?\nA:";
    let run = |fallback: bool| -> Option<(Vec<Vec<i32>>, String)> {
        let mut ec = EngineConfig::new("artifacts");
        ec.batch = 4;
        ec.tenant_levels.insert("sim-s-math".into(), 2);
        ec.codec_overrides.insert("sim-s-chat".into(), "lora".into());
        ec.codec_overrides.insert("sim-s-rlhf".into(), "svd".into());
        ec.mixed_dense_fallback = fallback;
        let mut engine = match Engine::from_artifacts(ec) {
            Ok(e) => e,
            Err(e) => {
                // load-time svd factorization may be unavailable on
                // thin artifacts; skip like the svd registry test
                eprintln!("skipping: {e}");
                return None;
            }
        };
        let chans: Vec<_> = tenants.iter()
            .map(|t| engine.submit(req(t, prompt, 12)).unwrap())
            .collect();
        engine.run_until_idle(100_000).unwrap();
        let tokens = chans.into_iter()
            .map(|c| c.recv().unwrap().unwrap().tokens)
            .collect();
        Some((tokens, engine.metrics.exposition()))
    };

    let Some((native, nm)) = run(false) else { return };
    let Some((fallback, fm)) = run(true) else { return };
    for ((t, a), b) in tenants.iter().zip(&native).zip(&fallback) {
        assert!(!a.is_empty(), "{t}: native run produced nothing");
        assert_eq!(a, b, "{t}: native and dense-fallback mixed \
batches decoded differently");
    }
    assert!(nm.contains("bitdelta_mixed_native_subbatches_total"),
            "native run recorded no sub-batches:\n{nm}");
    assert!(!nm.contains("bitdelta_decode_naive_total"),
            "native run took the stacked-dense detour:\n{nm}");
    assert!(fm.contains("bitdelta_decode_naive_total"),
            "fallback run never hit decode_naive:\n{fm}");
}

/// First value of an exposed metric series, 0 when absent (rollup
/// line, not a `{worker=...}` relabel).
fn metric(exposition: &str, name: &str) -> f64 {
    exposition.lines()
        .filter_map(|l| l.trim().strip_prefix(name))
        .filter_map(|rest| rest.strip_prefix(' '))
        .find_map(|v| v.trim().parse::<f64>().ok())
        .unwrap_or(0.0)
}

#[test]
fn paged_kv_equals_slab_fallback_across_churn() {
    // The paged-KV acceptance gate: block-pooled tables with prefix
    // sharing, COW, and incremental restacking must decode exactly
    // like the dense-slab design — across admission/completion churn
    // (more requests than batch slots), mixed tenants, mixed rope
    // scales, and mixed fidelity tiers, with greedy sampling.
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load("artifacts").unwrap();
    if m.find_exec("sim-s", "decode_bitdelta_l2", 2).is_none() {
        eprintln!("skipping: no decode_bitdelta_l2_b2 executable \
(rebuild artifacts)");
        return;
    }
    if !m.tenants.get("sim-s-math")
        .map_or(false, |e| e.fidelity.contains_key("2")) {
        eprintln!("skipping: fidelity artifacts missing \
(rebuild artifacts)");
        return;
    }

    // six requests into two slots: admissions interleave with
    // completions, slots get reused, and the repeated chat prompt
    // exercises the prompt cache on the paged run
    let jobs: [(&str, &str, usize); 6] = [
        ("sim-s-chat", "Q: what color is the sky ?\nA:", 12),
        ("sim-s-math", "Q: what color is the sky ?\nA:", 9),
        ("sim-s-chat-ext", "Q: where does ada live ?\nA:", 14),
        ("sim-s-rlhf", "Q: what color is the sky ?\nA:", 7),
        ("sim-s-chat", "Q: what color is the sky ?\nA:", 12),
        ("sim-s-math", "Q: what does bob eat ?\nA:", 10),
    ];
    let run = |slab: bool| -> (Vec<Vec<i32>>, String) {
        let mut ec = EngineConfig::new("artifacts");
        ec.batch = 2;
        ec.tenant_levels.insert("sim-s-math".into(), 2);
        ec.kv_slab_fallback = slab;
        ec.kv_block_size = 4; // small blocks: boundaries every 4 rows
        let mut engine = Engine::from_artifacts(ec).unwrap();
        let chans: Vec<_> = jobs.iter()
            .map(|(t, p, n)| engine.submit(req(t, p, *n)).unwrap())
            .collect();
        engine.run_until_idle(400_000).unwrap();
        let tokens = chans.into_iter()
            .map(|c| c.recv().unwrap().unwrap().tokens)
            .collect();
        (tokens, engine.metrics.exposition())
    };

    let (paged, pm) = run(false);
    let (slab, sm) = run(true);
    for ((t, p, _), (a, b)) in jobs.iter().zip(paged.iter().zip(&slab)) {
        assert!(!a.is_empty(), "{t} {p:?}: paged run produced nothing");
        assert_eq!(a, b, "{t} {p:?}: paged and slab KV backings \
decoded differently");
    }
    // identical requests decode identically regardless of whether the
    // second admission re-derived the prompt KV or reused blocks
    assert_eq!(paged[0], paged[4], "repeat request diverged");

    // the paged run actually paged: pool gauges exported, every
    // admission consulted the index, and the repeated prompt hit
    assert!(metric(&pm, "bitdelta_kv_blocks_total") > 0.0,
            "paged run exported no pool gauges:\n{pm}");
    assert_eq!(metric(&pm, "bitdelta_kv_prefix_lookups_total"),
               jobs.len() as f64, "every admission consults the index");
    assert!(metric(&pm, "bitdelta_kv_prefix_hits_total") >= 1.0,
            "repeated prompt never hit the prompt cache:\n{pm}");
    // slab fallback must not fake paging metrics
    assert_eq!(metric(&sm, "bitdelta_kv_blocks_total"), 0.0,
               "slab run exported pool gauges:\n{sm}");
}

#[test]
fn device_resident_equals_roundtrip_across_churn() {
    // The device-resident decode acceptance gate: keeping K/V on the
    // device across steps (downloading only logits plus each active
    // slot's freshly written KV row) must decode token-identically to
    // the full per-step host<->device round trip (--kv-roundtrip) —
    // across admission/completion churn, slot reuse, and mixed
    // fidelity tiers — and in steady state it must actually stop
    // moving the full KV tensors.
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load("artifacts").unwrap();
    if m.find_exec("sim-s", "decode_bitdelta_l2", 2).is_none() {
        eprintln!("skipping: no decode_bitdelta_l2_b2 executable \
(rebuild artifacts)");
        return;
    }
    if !m.tenants.get("sim-s-math")
        .map_or(false, |e| e.fidelity.contains_key("2")) {
        eprintln!("skipping: fidelity artifacts missing \
(rebuild artifacts)");
        return;
    }
    // artifacts predating the untupled decode export carry no row
    // extractor; the engine then transparently round-trips, making
    // the bytes assertions below vacuous
    let resident_capable =
        m.find_exec("sim-s", "kv_row_extract", 2).is_some();

    let cfg: ModelConfig = m.config("sim-s").unwrap().clone();
    // k + v for the whole batch: what the round trip moves every step
    let full_kv_bytes = (2 * cfg.n_layers * 2 * cfg.n_heads
                         * cfg.max_seq_len * cfg.head_dim() * 4) as u64;

    let jobs: [(&str, &str, usize); 6] = [
        ("sim-s-chat", "Q: what color is the sky ?\nA:", 12),
        ("sim-s-math", "Q: what color is the sky ?\nA:", 9),
        ("sim-s-chat-ext", "Q: where does ada live ?\nA:", 14),
        ("sim-s-rlhf", "Q: what color is the sky ?\nA:", 7),
        ("sim-s-chat", "Q: what color is the sky ?\nA:", 12),
        ("sim-s-math", "Q: what does bob eat ?\nA:", 10),
    ];
    let run = |roundtrip: bool| {
        let mut ec = EngineConfig::new("artifacts");
        ec.batch = 2;
        ec.tenant_levels.insert("sim-s-math".into(), 2);
        ec.kv_block_size = 4;
        ec.kv_roundtrip = roundtrip;
        let mut engine = Engine::from_artifacts(ec).unwrap();
        let chans: Vec<_> = jobs.iter()
            .map(|(t, p, n)| engine.submit(req(t, p, *n)).unwrap())
            .collect();
        let mut reports = Vec::new();
        while engine.batcher.occupancy() > 0
            || engine.router.total_queued() > 0 {
            reports.push(engine.step().unwrap());
            assert!(reports.len() < 400_000, "engine never went idle");
        }
        let tokens: Vec<Vec<i32>> = chans.into_iter()
            .map(|c| c.recv().unwrap().unwrap().tokens)
            .collect();
        // steady-state = steps that admitted nothing: the composition
        // they decode under was already resident before the step
        let steady_h2d = reports.iter().filter(|r| r.admitted == 0)
            .map(|r| r.bytes_h2d).min();
        let steady_d2h = reports.iter().filter(|r| r.admitted == 0)
            .map(|r| r.bytes_d2h).min();
        (tokens, engine.metrics.exposition(), steady_h2d, steady_d2h)
    };

    let (resident, rm, res_h2d, res_d2h) = run(false);
    let (roundtrip, tm, rt_h2d, _) = run(true);
    for ((t, p, _), (a, b)) in jobs.iter()
        .zip(resident.iter().zip(&roundtrip)) {
        assert!(!a.is_empty(),
                "{t} {p:?}: resident run produced nothing");
        assert_eq!(a, b, "{t} {p:?}: device-resident and round-trip \
decode paths diverged");
    }

    // the A/B switch is honest: a forced round trip never reports a
    // device-resident step
    assert_eq!(metric(&tm, "bitdelta_step_kv_device_total"), 0.0,
               "--kv-roundtrip still took the resident path:\n{tm}");
    if resident_capable {
        assert!(metric(&rm, "bitdelta_step_kv_device_total") > 0.0,
                "resident-capable artifacts never took the fast \
path:\n{rm}");
        // zero full-KV transfers in steady state: the cheapest
        // admission-free resident step moves a small fraction of the
        // KV tensors, while the round trip uploads at least the full
        // KV on every step
        let h2d = res_h2d.expect("no steady-state steps observed");
        assert!(h2d < full_kv_bytes / 8,
                "steady-state step still uploads KV: {h2d} B of \
full-KV {full_kv_bytes} B");
        let d2h = res_d2h.expect("no steady-state steps observed");
        assert!(d2h < full_kv_bytes / 8,
                "steady-state step still downloads full KV: {d2h} B");
        assert!(rt_h2d.expect("no steady-state steps observed")
                >= full_kv_bytes,
                "round-trip run moved less than the full KV");
        // compositions repeat across churn (four tenants cycling
        // through two slots) — the content-keyed plan cache must hit
        assert!(metric(&rm, "bitdelta_plan_cache_hits_total") >= 1.0,
                "no stacked-plan cache hits across churn:\n{rm}");
    }
}

#[test]
fn device_resident_mixed_codec_falls_back_transparently() {
    // Mixed-codec compositions decode through per-codec sub-batches —
    // not a single launch — so the engine must transparently take the
    // round-trip merge path and still match a forced --kv-roundtrip
    // run token for token.
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load("artifacts").unwrap();
    if m.tenants["sim-s-chat"].svd_r16.is_none() {
        eprintln!("skipping: sim-s-chat has no svd factors");
        return;
    }
    let prompt = "Q: what color is the sky ?\nA:";
    let run = |roundtrip: bool| {
        let mut ec = EngineConfig::new("artifacts");
        ec.batch = 2;
        ec.codec_overrides.insert("sim-s-chat".into(), "lora".into());
        ec.kv_roundtrip = roundtrip;
        let mut engine = Engine::from_artifacts(ec).unwrap();
        let c1 = engine.submit(req("sim-s-chat", prompt, 12)).unwrap();
        let c2 = engine.submit(req("sim-s-math", prompt, 12)).unwrap();
        engine.run_until_idle(100_000).unwrap();
        (c1.recv().unwrap().unwrap().tokens,
         c2.recv().unwrap().unwrap().tokens,
         engine.metrics.exposition())
    };
    let (a1, a2, am) = run(false);
    let (b1, b2, _) = run(true);
    assert_eq!(a1, b1, "mixed-codec chat diverged across KV modes");
    assert_eq!(a2, b2, "mixed-codec math diverged across KV modes");
    // multi-sub plans never claim the device-resident fast path
    assert_eq!(metric(&am, "bitdelta_step_kv_device_total"), 0.0,
               "mixed-codec plan claimed a single-launch resident \
step:\n{am}");
}

#[test]
fn malformed_requests_rejected_on_their_own_channel() {
    // Regression: an empty prompt or an over-window request fails on
    // its OWN response channel with a typed error — it must not
    // poison the engine step for healthy requests sharing the batch.
    if !have_artifacts() {
        return;
    }
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = 2;
    let mut engine = Engine::from_artifacts(ec).unwrap();
    let prompt = "Q: what color is the sky ?\nA:";

    let good = engine.submit(req("sim-s-chat", prompt, 8)).unwrap();
    let empty = engine.submit(req("sim-s-chat", "", 8)).unwrap();
    let long = engine.submit(req("sim-s-math", prompt, 1_000_000))
        .unwrap();
    engine.run_until_idle(100_000).unwrap();

    assert!(matches!(empty.recv().unwrap(),
                     Err(RequestError::EmptyPrompt { .. })),
            "empty prompt not rejected as EmptyPrompt");
    match long.recv().unwrap() {
        Err(RequestError::TooLong { need, max_seq_len, .. }) => {
            assert!(need > max_seq_len);
        }
        other => panic!("over-window request got {other:?}"),
    }
    let r = good.recv().unwrap().unwrap();
    assert!(!r.tokens.is_empty(),
            "healthy request starved by rejected neighbours");
    let m = engine.metrics.exposition();
    assert!(metric(&m, "bitdelta_rejected_total") >= 2.0,
            "rejections not counted:\n{m}");
}

#[test]
fn prefix_cache_survives_sequence_completion() {
    // The prompt cache: a registered prefix outlives the sequence that
    // produced it, so a later identical prompt skips prefill work and
    // reuses physical blocks — while a *different* tenant with the
    // same prompt must NOT share (weights differ => sig differs).
    if !have_artifacts() {
        return;
    }
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = 1; // strictly sequential: completion precedes re-admission
    ec.kv_block_size = 4;
    let mut engine = Engine::from_artifacts(ec).unwrap();
    let prompt = "Q: what color is the sky ?\nA:";

    let c1 = engine.submit(req("sim-s-chat", prompt, 8)).unwrap();
    engine.run_until_idle(100_000).unwrap();
    let first = c1.recv().unwrap().unwrap().tokens;
    let hits_before =
        metric(&engine.metrics.exposition(),
               "bitdelta_kv_prefix_hits_total");

    let c2 = engine.submit(req("sim-s-chat", prompt, 8)).unwrap();
    let c3 = engine.submit(req("sim-s-math", prompt, 8)).unwrap();
    engine.run_until_idle(100_000).unwrap();
    let second = c2.recv().unwrap().unwrap().tokens;
    let other = c3.recv().unwrap().unwrap().tokens;

    assert_eq!(first, second,
               "prefix reuse changed a greedy decode");
    assert_ne!(second, other,
               "different tenants must not share decode output");
    let m = engine.metrics.exposition();
    assert!(metric(&m, "bitdelta_kv_prefix_hits_total") > hits_before,
            "second identical prompt missed the prompt cache:\n{m}");
}

#[test]
fn svd_codec_serves_via_registry_only() {
    // The acceptance demo for "adding a codec costs one module + one
    // registry line": the svd codec has no precomputed artifact at all —
    // it factorizes the fine-tune at load time — yet serves end-to-end
    // through the same engine path.
    if !have_artifacts() {
        return;
    }
    let mut ec = EngineConfig::new("artifacts");
    ec.codec = Some("svd".into());
    ec.batch = 2;
    let mut engine = match Engine::from_artifacts(ec) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let c = engine.submit(
        req("sim-s-chat", "Q: what color is the sky ?\nA:", 8)).unwrap();
    engine.run_until_idle(100_000).unwrap();
    let r = c.recv().unwrap().unwrap();
    assert!(!r.tokens.is_empty(), "svd codec produced nothing");
}

#[test]
fn rope_extension_tenant_uses_scaled_positions() {
    if !have_artifacts() {
        return;
    }
    // chat and chat-ext share training data but differ in rope_scale;
    // greedy outputs on the same prompt should diverge
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = 2;
    let mut engine = Engine::from_artifacts(ec).unwrap();
    let prompt = "Q: where does kim live ?\nA:";
    let c1 = engine.submit(req("sim-s-chat", prompt, 16)).unwrap();
    let c2 = engine.submit(req("sim-s-chat-ext", prompt, 16)).unwrap();
    engine.run_until_idle(100_000).unwrap();
    let r1 = c1.recv().unwrap().unwrap();
    let r2 = c2.recv().unwrap().unwrap();
    assert_ne!(r1.tokens, r2.tokens);
}
