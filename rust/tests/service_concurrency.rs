//! Concurrency contract of the `ServingService` front-end: many client
//! threads submitting at once, shutdown draining every in-flight
//! request, and clean errors (never hangs) after shutdown. Skipped
//! cleanly when artifacts are missing.
//!
//! Wall-clock-free by contract: these tests synchronize on channels
//! and joins only — no sleep pacing, no `Instant` deadlines — so they
//! cannot go flaky under load and stay valid under the virtual clock.
//! The `raw-time` rule of `cargo xtask lint` enforces that this file
//! stays that way (any timing-dependent scenario belongs in the
//! `bitdelta::simharness` virtual-clock harness, see
//! `tests/sim_cluster.rs`).

use std::path::Path;

use bitdelta::model::sampling::SamplingParams;
use bitdelta::serving::engine::EngineConfig;
use bitdelta::serving::request::Request;
use bitdelta::serving::service::ServingService;

fn have_artifacts() -> bool {
    let ok = Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts not built");
    }
    ok
}

fn req(tenant: &str, n: usize) -> Request {
    Request {
        tenant: tenant.into(),
        prompt: "Q: what color is the sky ?\nA:".into(),
        max_new_tokens: n,
        sampling: SamplingParams::greedy(),
    }
}

#[test]
fn many_client_threads_submit_concurrently() {
    if !have_artifacts() {
        return;
    }
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = 4;
    let service = ServingService::spawn(ec).unwrap();
    let tenants = ["sim-s-chat".to_string(), "sim-s-math".to_string()];

    let mut joins = Vec::new();
    for c in 0..8 {
        let h = service.handle();
        let tenants = tenants.clone();
        joins.push(std::thread::spawn(move || {
            (0..4).map(|i| {
                h.generate(req(&tenants[(c + i) % tenants.len()], 8))
            }).collect::<Vec<_>>()
        }));
    }
    let mut served = 0;
    for j in joins {
        for r in j.join().unwrap() {
            let resp = r.expect("concurrent generate failed");
            assert!(!resp.tokens.is_empty());
            served += 1;
        }
    }
    assert_eq!(served, 32);
    service.shutdown().unwrap();
}

#[test]
fn shutdown_drains_all_inflight_requests() {
    if !have_artifacts() {
        return;
    }
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = 2;
    let service = ServingService::spawn(ec).unwrap();
    let h = service.handle();

    // submit a pile without waiting, then shut down immediately: every
    // receiver must still get its response (shutdown drains first)
    let chans: Vec<_> = (0..6)
        .map(|_| h.submit(req("sim-s-chat", 6)).unwrap())
        .collect();
    service.shutdown().unwrap();
    for c in chans {
        let resp = c.recv().expect("response channel dropped")
            .expect("request failed during shutdown drain");
        assert!(!resp.tokens.is_empty());
    }
}

#[test]
fn submit_after_shutdown_fails_cleanly() {
    if !have_artifacts() {
        return;
    }
    let service = ServingService::spawn(
        EngineConfig::new("artifacts")).unwrap();
    let h = service.handle();
    service.shutdown().unwrap();
    // a dead service must reject, not hang
    assert!(h.submit(req("sim-s-chat", 4)).is_err());
    assert!(h.generate(req("sim-s-chat", 4)).is_err());
    assert!(h.metrics().is_err());
}
